//! Speculative-decoding properties that need no artifacts:
//!
//! 1. **Acceptance-sampling equivalence** — for any seed and sampling
//!    params, the speculative pipeline's committed token stream (tokens
//!    *and* logprobs) is bit-identical to the sequential pipeline's, for
//!    self-drafting and the smaller-model drafter, chain and tree, with
//!    k ∈ {1, 2, 4, 8}. Draft quality moves only the pass count.
//! 2. **Multi-query lean exactness** — the verify pass's staggered-
//!    causal cascade expansion computes exact attention: every row of
//!    every draft block matches the dense host oracle over the composed
//!    per-row KV, with and without fork-family grouping, while gathering
//!    strictly fewer KV bytes than the flat expansion whenever a block
//!    has >= 2 rows of real context.
//! 3. **Self-drafter sanity** — n-gram drafts always come from the
//!    history's alphabet and exactly continue perfect repetitions.

use lean_attention::attention::attention_host;
use lean_attention::partition::cascade::{build_cascade_plan, PrefixGroup};
use lean_attention::partition::multi_query::{
    MultiQueryInputs, MultiQueryProblem, MultiQuerySeq,
};
use lean_attention::runtime::attention_exec::{
    lean_multi_query_host, roll_cascade_tasks, rolled_kv_bytes,
};
use lean_attention::sampling::{seq_rng, SamplingParams};
use lean_attention::spec::{
    sequential_generate, spec_generate, spec_generate_tree, DraftKind, DraftSource,
    NGramDrafter, SyntheticModel,
};
use lean_attention::util::rng::Rng;
use lean_attention::util::testing::{max_abs_err, prop_check};

fn random_params(rng: &mut Rng) -> SamplingParams {
    SamplingParams {
        temperature: *rng.choose(&[0.0f32, 0.5, 0.8, 1.0, 1.5]),
        top_k: *rng.choose(&[0usize, 1, 3, 8]),
        top_p: *rng.choose(&[1.0f32, 0.95, 0.7, 0.3]),
        repetition_penalty: *rng.choose(&[1.0f32, 1.1, 1.5]),
    }
}

/// A mixed workload: repetitive spans (draftable) with random
/// interruptions (forcing rejections).
fn random_prompt(rng: &mut Rng, vocab: usize) -> Vec<i32> {
    let len = rng.urange(4, 40);
    let period = rng.urange(1, 9);
    (0..len)
        .map(|i| {
            if rng.chance(0.15) {
                rng.urange(0, vocab) as i32
            } else {
                (i % period) as i32
            }
        })
        .collect()
}

#[test]
fn spec_stream_is_bit_identical_to_sequential_for_any_params() {
    prop_check("spec == sequential (self-draft)", 60, |rng| {
        let vocab = rng.urange(8, 48);
        let sharpness = *rng.choose(&[0.0f32, 2.0, 6.0]);
        let model = SyntheticModel::new(vocab, rng.next_u64(), sharpness);
        let prompt = random_prompt(rng, vocab);
        let params = random_params(rng);
        let max_new = rng.urange(1, 33);
        let seed = rng.next_u64();
        let id = rng.next_u64();

        let mut oracle_rng = seq_rng(seed, id);
        let want = sequential_generate(&model, &prompt, max_new, &params, &mut oracle_rng);
        for k in [1usize, 2, 4, 8] {
            let mut drafter = NGramDrafter::default();
            let mut rng2 = seq_rng(seed, id);
            let run =
                spec_generate(&model, &mut drafter, k, &prompt, max_new, &params, &mut rng2);
            if run.tokens != want {
                return Err(format!("k={k}: stream diverged from sequential"));
            }
            if run.stats.committed != max_new {
                return Err(format!(
                    "k={k}: committed {} != {max_new}",
                    run.stats.committed
                ));
            }
            if run.stats.verify_passes > max_new {
                return Err(format!("k={k}: more passes than tokens"));
            }
        }
        Ok(())
    });
}

#[test]
fn spec_stream_equivalence_holds_for_model_and_tree_drafting() {
    prop_check("spec == sequential (model drafter, tree)", 30, |rng| {
        let vocab = rng.urange(8, 32);
        let model = SyntheticModel::new(vocab, rng.next_u64(), 5.0);
        let prompt = random_prompt(rng, vocab);
        let params = random_params(rng);
        let max_new = rng.urange(1, 25);
        let seed = rng.next_u64();

        let mut oracle_rng = seq_rng(seed, 0);
        let want = sequential_generate(&model, &prompt, max_new, &params, &mut oracle_rng);

        // Smaller-model drafter (a different-seed synthetic model).
        let mut drafter = DraftKind::Model.build(vocab, rng.next_u64());
        let mut r2 = seq_rng(seed, 0);
        let run = spec_generate(
            &model,
            drafter.as_mut(),
            4,
            &prompt,
            max_new,
            &params,
            &mut r2,
        );
        if run.tokens != want {
            return Err("model-drafter stream diverged".into());
        }

        // Tree drafting over both drafters at once.
        let mut drafters: Vec<Box<dyn DraftSource>> = vec![
            DraftKind::NGram.build(vocab, 0),
            DraftKind::Model.build(vocab, rng.next_u64()),
        ];
        let mut r3 = seq_rng(seed, 0);
        let run =
            spec_generate_tree(&model, &mut drafters, 4, &prompt, max_new, &params, &mut r3);
        if run.tokens != want {
            return Err("tree stream diverged".into());
        }
        Ok(())
    });
}

/// Dense-oracle check of one multi-query problem: every expanded row's
/// attention matches exact attention over the composed per-row KV.
fn assert_multi_query_exact(p: &MultiQueryProblem, seed: u64) -> Result<(), String> {
    let inputs = MultiQueryInputs::random(p, seed);
    let (cp, t) = p.tensors(&inputs).map_err(|e| e.to_string())?;
    let (k_full, v_full, n_max) = t.full_kv(&cp);
    let lens: Vec<u32> = (0..cp.outputs())
        .map(|g| cp.ctx_lens[g / cp.heads])
        .collect();
    let want = attention_host(
        &t.q,
        &k_full,
        &v_full,
        cp.outputs(),
        n_max,
        cp.head_dim,
        &lens,
    );
    for (slots, batch_rows) in [(3usize, 2usize), (16, 64), (64, 7)] {
        let (got, _) = lean_multi_query_host(p, &inputs, slots, batch_rows)
            .map_err(|e| e.to_string())?;
        let err = max_abs_err(&got, &want);
        if err > 1e-4 {
            return Err(format!("slots {slots} rows {batch_rows}: err {err}"));
        }
    }
    Ok(())
}

#[test]
fn multi_query_lean_matches_dense_attention_with_staggered_causality() {
    prop_check("lean_multi_query == dense oracle", 30, |rng| {
        let heads = rng.urange(1, 3);
        let d = *rng.choose(&[4usize, 8]);
        let n_seqs = rng.urange(1, 4);
        let seqs: Vec<MultiQuerySeq> = (0..n_seqs)
            .map(|_| MultiQuerySeq {
                base_len: rng.urange(0, 41),
                q_len: rng.urange(1, 5),
            })
            .collect();
        let p = MultiQueryProblem::new(heads, d, seqs, Vec::new())
            .map_err(|e| e.to_string())?
            .with_tile(*rng.choose(&[8usize, 16]));
        assert_multi_query_exact(&p, rng.next_u64())
    });
}

#[test]
fn multi_query_fork_family_stays_exact_and_dedups_shared_history() {
    prop_check("family multi-query exact + deduped", 20, |rng| {
        let heads = rng.urange(1, 3);
        let d = 8usize;
        let shared = rng.urange(16, 33);
        let siblings = rng.urange(2, 4);
        let q_len = rng.urange(2, 5);
        let seqs: Vec<MultiQuerySeq> = (0..siblings)
            .map(|_| MultiQuerySeq {
                base_len: shared + rng.urange(0, 3),
                q_len,
            })
            .collect();
        let family = PrefixGroup {
            prefix_len: shared as u32,
            members: (0..siblings as u32).collect(),
        };
        let p = MultiQueryProblem::new(heads, d, seqs, vec![family])
            .map_err(|e| e.to_string())?
            .with_tile(8);
        assert_multi_query_exact(&p, rng.next_u64())?;

        // Any grouped expansion gathers fewer bytes than the flat twin.
        let cp = p.expand();
        let flat = p.expand_flat();
        let grouped = rolled_kv_bytes(
            &roll_cascade_tasks(&cp, &build_cascade_plan(&cp, 16)),
            d,
        );
        let ungrouped = rolled_kv_bytes(
            &roll_cascade_tasks(&flat, &build_cascade_plan(&flat, 16)),
            d,
        );
        if grouped >= ungrouped {
            return Err(format!("no dedup: grouped {grouped} >= flat {ungrouped}"));
        }
        Ok(())
    });
}

#[test]
fn ngram_drafts_come_from_history_and_continue_exact_repeats() {
    prop_check("ngram drafter sanity", 40, |rng| {
        let vocab = rng.urange(4, 32);
        let mut drafter = NGramDrafter::default();
        // Arbitrary history: drafted tokens must come from its alphabet.
        let hist: Vec<i32> =
            (0..rng.urange(1, 30)).map(|_| rng.urange(0, vocab) as i32).collect();
        let k = rng.urange(1, 9);
        let draft = drafter.draft(&hist, k);
        if draft.len() != k {
            return Err(format!("draft len {} != {k}", draft.len()));
        }
        if draft.iter().any(|t| !hist.contains(t)) {
            return Err("drafted a token absent from history".into());
        }

        // A perfect repetition must be continued exactly.
        let period = rng.urange(1, 6);
        let reps = rng.urange(2, 5);
        let phist: Vec<i32> = (0..period * reps).map(|i| (i % period) as i32).collect();
        let draft = drafter.draft(&phist, k);
        for (j, &t) in draft.iter().enumerate() {
            let want = ((phist.len() + j) % period) as i32;
            if t != want {
                return Err(format!("position {j}: drafted {t}, period says {want}"));
            }
        }
        Ok(())
    });
}
