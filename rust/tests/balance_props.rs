//! Property tests for the partition-balance plane: the greedy list
//! scheduler's invariants on arbitrary duration vectors, the per-tile
//! work ledger's bit-exact agreement with the closed-form accounting on
//! random ragged problems, the PartitionReport JSON schema round-trip,
//! and the drift detector's flight-recorder contract (a sustained shift
//! writes a validated `drift` bundle; a stationary stream writes none).

use lean_attention::coordinator::{Metrics, PagedKvCache};
use lean_attention::obs::attrib::{account_decode_problem, account_plan, WorkAccounting};
use lean_attention::obs::balance::{partition_report, plan_balance, validate_partition_report};
use lean_attention::obs::{
    validate_bundle, Attrs, DriftDetector, FlightRecorder, FlightSnapshot,
    FlightTrigger, Phase, Tracer,
};
use lean_attention::partition::planners::build_plan;
use lean_attention::partition::{DecodeProblem, Strategy};
use lean_attention::sim::{list_schedule, CostCoefficients, GpuArch};
use lean_attention::util::json::Json;
use lean_attention::util::testing::prop_check;

// --------------------------------------------------- list-schedule laws

/// The scheduler every balance number is computed from must obey the
/// classic bounds on any input: makespan at least the critical job and
/// at least the perfectly-level share, at most the Graham greedy bound,
/// busy fraction in (0, 1], and per-job finishes consistent with the
/// reported makespan.
#[test]
fn list_schedule_invariants_hold_on_random_durations() {
    prop_check("list_schedule bounds", 80, |rng| {
        let n = rng.urange(1, 200);
        let slots = rng.urange(1, 64);
        let durations: Vec<f64> =
            (0..n).map(|_| rng.range(1, 2000) as f64 / 100.0).collect();
        let total: f64 = durations.iter().sum();
        let max_d = durations.iter().cloned().fold(0.0, f64::max);
        // list_schedule never opens more slots than it has jobs.
        let m = slots.min(n).max(1) as f64;
        let eps = 1e-9 * (1.0 + total);

        let (finish, makespan) = list_schedule(&durations, slots);
        if finish.len() != n {
            return Err(format!("{} finish times for {n} jobs", finish.len()));
        }
        if makespan + eps < max_d {
            return Err(format!("makespan {makespan} below critical job {max_d}"));
        }
        if makespan + eps < total / m {
            return Err(format!(
                "makespan {makespan} below the level share {} ({n} jobs, {m} slots)",
                total / m
            ));
        }
        if makespan > total / m + max_d + eps {
            return Err(format!(
                "makespan {makespan} exceeds the Graham bound {}",
                total / m + max_d
            ));
        }
        let occupancy = total / (makespan * m);
        if !(occupancy > 0.0 && occupancy <= 1.0 + 1e-9) {
            return Err(format!("busy fraction {occupancy} outside (0, 1]"));
        }
        let max_finish = finish.iter().cloned().fold(0.0, f64::max);
        if max_finish != makespan {
            return Err(format!(
                "latest finish {max_finish} disagrees with makespan {makespan}"
            ));
        }
        for (i, (&f, &d)) in finish.iter().zip(&durations).enumerate() {
            if f + eps < d {
                return Err(format!("job {i} finished at {f} before its duration {d}"));
            }
        }
        // Same input, same schedule — the simulator must be a function.
        let again = list_schedule(&durations, slots);
        if again.0 != finish || again.1 != makespan {
            return Err("list_schedule is not deterministic".into());
        }
        Ok(())
    });
}

// ------------------------------------------- ledger bit-exactness laws

/// On any ragged problem and any strategy, the per-CTA ledger must sum
/// bit-exactly to the closed-form problem accounting, and the derived
/// balance numbers must sit in their documented ranges with the
/// critical-path CTA actually setting the makespan.
#[test]
fn plan_ledger_and_balance_invariants_hold_on_random_problems() {
    prop_check("plan_balance == closed-form accounting", 30, |rng| {
        let arch = GpuArch::a100();
        let kv_heads = *rng.choose(&[1usize, 2, 4]);
        let heads = kv_heads * rng.urange(1, 4);
        let batch = rng.urange(1, 6);
        let lens: Vec<u32> =
            (0..batch).map(|_| rng.urange(1, 600) as u32).collect();
        let d = *rng.choose(&[8usize, 16, 32]);
        let tile = *rng.choose(&[16usize, 32, 64]);
        let p = DecodeProblem::ragged(heads, lens, d)
            .with_tile(tile)
            .with_kv_heads(kv_heads);
        let want = account_decode_problem(&p);
        let slots = rng.urange(1, 80);
        for strategy in
            [Strategy::Dense, Strategy::StreamK, Strategy::fixed_split_auto(&p, slots)]
        {
            let plan = build_plan(&p, strategy, slots);
            let b = plan_balance(&p, &plan, &arch);
            if b.grid != plan.grid() || b.ledger.len() != b.grid {
                return Err(format!(
                    "{strategy:?}: {} ledger rows for a grid of {}",
                    b.ledger.len(),
                    plan.grid()
                ));
            }
            let sum = b
                .ledger
                .iter()
                .fold(WorkAccounting::default(), |a, r| a + r.work);
            if sum != b.total || b.total != account_plan(&p, &plan) || b.total != want {
                return Err(format!(
                    "{strategy:?}: ledger sum {sum:?} / total {:?} drifted from \
                     the closed form {want:?}",
                    b.total
                ));
            }
            if b.imbalance < 1.0 - 1e-9 {
                return Err(format!("{strategy:?}: imbalance {} below 1", b.imbalance));
            }
            if !(b.wave_efficiency > 0.0 && b.wave_efficiency <= 1.0 + 1e-9) {
                return Err(format!(
                    "{strategy:?}: wave efficiency {} outside (0, 1]",
                    b.wave_efficiency
                ));
            }
            let crit = b
                .ledger
                .iter()
                .find(|r| r.cta == b.critical_cta)
                .ok_or_else(|| format!("{strategy:?}: critical CTA not in ledger"))?;
            if crit.finish_us != b.makespan_us {
                return Err(format!(
                    "{strategy:?}: critical CTA finishes at {} but makespan is {}",
                    crit.finish_us, b.makespan_us
                ));
            }
            if b.tiles_hist.iter().sum::<u64>() != b.grid as u64 {
                return Err(format!(
                    "{strategy:?}: tiles histogram counts {} CTAs of {}",
                    b.tiles_hist.iter().sum::<u64>(),
                    b.grid
                ));
            }
        }
        Ok(())
    });
}

/// The full cross-strategy report must validate against its schema and
/// survive a parse round-trip unchanged, for any ragged problem.
#[test]
fn partition_report_round_trips_and_validates_on_random_problems() {
    prop_check("PartitionReport JSON round-trip", 12, |rng| {
        let heads = rng.urange(1, 5);
        let batch = rng.urange(1, 5);
        let lens: Vec<u32> =
            (0..batch).map(|_| rng.urange(1, 800) as u32).collect();
        let d = *rng.choose(&[16usize, 32]);
        let p = DecodeProblem::ragged(heads, lens, d);
        let report = partition_report(&p, &GpuArch::a100());
        let j = report.to_json();
        validate_partition_report(&j).map_err(|e| format!("self-validation: {e:#}"))?;
        let back = Json::parse(&j.to_string()).map_err(|e| format!("parse: {e}"))?;
        if back != j {
            return Err("JSON round-trip changed the report".into());
        }
        validate_partition_report(&back)
            .map_err(|e| format!("round-trip validation: {e:#}"))?;
        Ok(())
    });
}

// ---------------------------------------- drift -> flight recorder e2e

fn drift_snapshot_parts() -> (Json, Json, Json) {
    let tracer = Tracer::enabled(16);
    tracer.instant(Phase::Decode, Attrs::default());
    let trace = tracer.export_chrome_trace();
    let metrics = Metrics::default().snapshot().to_json();
    let cache = PagedKvCache::new(1, 1, 4, 4, 8).report(None, 4).to_json();
    (trace, metrics, cache)
}

/// Artifact-free half of the drift e2e contract: when the detector
/// declares a sustained breach, recording the flight snapshot must leave
/// a `drift`-trigger bundle on disk that re-validates; until then, the
/// recorder directory must not even exist.
#[test]
fn drift_breach_records_a_validated_drift_bundle() {
    let dir = std::env::temp_dir()
        .join(format!("leanattn-drift-props-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let coeffs = CostCoefficients::nominal();
    let work = WorkAccounting::slice(4096, 64, 8);
    let base = coeffs.predict_us(&work);
    let mut d = DriftDetector::new(coeffs, 0.10);

    // Stationary stream: warm, judged, quiet — and nothing on disk.
    for _ in 0..DriftDetector::WARMUP + 40 {
        d.observe(&work, base);
    }
    assert_eq!(d.breaches(), 0, "stationary stream must not breach");
    assert!(!d.take_breach());
    assert!(!dir.exists(), "no breach, no recorder directory");

    // Sustained 2x shift: one breach, one bundle.
    let mut rec = FlightRecorder::new(dir.to_string_lossy().as_ref());
    let (trace, metrics, cache) = drift_snapshot_parts();
    let mut bundle = None;
    for step in 0..20u64 {
        d.observe(&work, 2.0 * base);
        if d.take_breach() {
            let snap = FlightSnapshot {
                trace: &trace,
                metrics: &metrics,
                cache_report: &cache,
                slo_text: "drift props bundle (synthetic stream)",
            };
            bundle = rec
                .record(FlightTrigger::Drift, step, &snap)
                .expect("record bundle")
                .or(bundle);
            break;
        }
    }
    let bundle = bundle.expect("a sustained 2x shift must breach and record");
    let name = bundle.file_name().unwrap().to_string_lossy().into_owned();
    assert!(name.contains("drift"), "bundle dir {name:?} lacks the trigger");
    validate_bundle(&bundle).expect("drift bundle re-validates from disk");
    assert_eq!(d.breaches(), 1, "exactly one sustained event");
    let _ = std::fs::remove_dir_all(&dir);
}
