//! Property tests for the sparse page-selection subsystem.
//!
//! * The sparse lean executor ([`lean_sparse_host`]) is **exact** against
//!   dense attention restricted to the selected pages, for random shapes,
//!   lengths, selections **and GQA groupings** (`h_kv` sweeps MQA through
//!   ungrouped) — the oracle behind the engine's sparse decode gather.
//! * Degenerate sparsity dissolves: a budget covering the context selects
//!   every page, the selected-page gather reproduces the dense gather
//!   bit-for-bit under arbitrary fork/COW/truncate churn, and the host
//!   pseudo-decode streams (tokens, logprobs, RNG trajectory) are
//!   bit-identical — mirroring the single-member-cascade dissolution
//!   tests of `sampling_props.rs`.
//! * Selection invariants: deterministic, budget-bounded, ascending, and
//!   sink/window ordinals always retained.
//! * Needle retention: attention mass planted in one early page is never
//!   dropped, and `SparseStats` reports pages-scanned/pages-total plus
//!   coverage.

use lean_attention::attention::attention_host;
use lean_attention::bench_harness::{compare_sparse, SparseBenchCase};
use lean_attention::coordinator::PagedKvCache;
use lean_attention::runtime::attention_exec::lean_sparse_host;
use lean_attention::sparse::{select_pages, selected_token_indices, SparsePolicy};
use lean_attention::util::rng::Rng;
use lean_attention::util::testing::{max_abs_err, prop_check};

#[test]
fn sparse_lean_executor_matches_the_restricted_dense_oracle() {
    prop_check("lean_sparse_host == oracle | selected pages", 30, |rng| {
        let batch = rng.urange(1, 4);
        // GQA plane: `gs` query heads share each kv head (gs = 1 is the
        // ungrouped layout, kv_heads = 1 with gs > 1 is MQA).
        let kv_heads = rng.urange(1, 3);
        let gs = *rng.choose(&[1usize, 1, 2, 4]);
        let heads = kv_heads * gs;
        let d = *rng.choose(&[4usize, 8]);
        let pt = *rng.choose(&[4usize, 8]);
        let n = rng.urange(1, 7) * pt;
        let lens: Vec<u32> =
            (0..batch).map(|_| rng.urange(1, n + 1) as u32).collect();
        let q = rng.normal_vec(batch * heads * d);
        let k = rng.normal_vec(batch * kv_heads * n * d);
        let v = rng.normal_vec(batch * kv_heads * n * d);
        // Random non-empty ascending selections over each lane's pages.
        let mut sels: Vec<Vec<usize>> = Vec::new();
        for &len in &lens {
            let used = (len as usize).div_ceil(pt);
            let mut sel: Vec<usize> =
                (0..used).filter(|_| rng.chance(0.6)).collect();
            if sel.is_empty() {
                sel.push(rng.urange(0, used));
            }
            sels.push(sel);
        }
        let tile = *rng.choose(&[4usize, 8, 16]);
        let slots = rng.urange(1, 20);
        let batch_rows = rng.urange(1, 9);
        let (o, _) = lean_sparse_host(
            &q, &k, &v, &lens, heads, kv_heads, n, d, pt, &sels, tile, slots,
            batch_rows,
        )
        .map_err(|e| e.to_string())?;

        // Independent oracle: compact by token index, exact attention,
        // one (sequence, query head) output at a time — each reading the
        // KV stream of its kv head (`h / gs`).
        for s in 0..batch {
            let idx = selected_token_indices(lens[s] as usize, pt, &sels[s]);
            let n_sel = idx.len();
            for h in 0..heads {
                let gi = s * heads + h;
                let ki = s * kv_heads + h / gs;
                let mut kc = vec![0.0f32; n_sel.max(1) * d];
                let mut vc = vec![0.0f32; kc.len()];
                for (j, &t) in idx.iter().enumerate() {
                    let src = (ki * n + t) * d;
                    kc[j * d..(j + 1) * d].copy_from_slice(&k[src..src + d]);
                    vc[j * d..(j + 1) * d].copy_from_slice(&v[src..src + d]);
                }
                let want = attention_host(
                    &q[gi * d..(gi + 1) * d],
                    &kc,
                    &vc,
                    1,
                    n_sel.max(1),
                    d,
                    &[n_sel as u32],
                );
                let err = max_abs_err(&o[gi * d..(gi + 1) * d], &want);
                if err > 1e-4 {
                    return Err(format!(
                        "seq {s} head {h}: executor err {err} (sel {:?})",
                        sels[s]
                    ));
                }
            }
        }
        Ok(())
    });
}

const PT: usize = 4;
const PAGES: usize = 24;
/// KV-head planes the churn suites sweep: MQA, grouped (h/4 for a
/// 4-query-head model), and the ungrouped h_kv == h plane.
const KV_HEAD_PLANES: [usize; 3] = [1, 2, 4];

fn churned_cache(
    rng: &mut Rng,
    kv_heads: usize,
) -> Result<(PagedKvCache, Vec<u64>), String> {
    let mut cache = PagedKvCache::new(1, kv_heads, 4, PT, PAGES);
    let mut active: Vec<u64> = Vec::new();
    let mut next_id = 0u64;
    let kv = move |rng: &mut Rng, tokens: usize| {
        let n = kv_heads * tokens * 4;
        (rng.normal_vec(n), rng.normal_vec(n))
    };
    for _ in 0..24 {
        match rng.urange(0, 5) {
            0 => {
                let len = rng.urange(1, 3 * PT);
                let (k, v) = kv(rng, len);
                if cache.insert_seq(next_id, &k, &v, len).is_ok() {
                    active.push(next_id);
                }
                next_id += 1;
            }
            1 if !active.is_empty() => {
                let donor = *rng.choose(&active);
                let full = cache.seq_len(donor).unwrap() / PT;
                if full == 0 {
                    continue;
                }
                let take = rng.urange(1, full + 1);
                let shared: Vec<usize> =
                    cache.seq_pages(donor).unwrap()[..take].to_vec();
                let suffix = rng.urange(0, 2 * PT);
                let (k, v) = kv(rng, suffix);
                if cache.insert_seq_shared(next_id, &shared, &k, &v, suffix).is_ok() {
                    active.push(next_id);
                }
                next_id += 1;
            }
            2 if !active.is_empty() => {
                let id = *rng.choose(&active);
                let (k, v) = kv(rng, 1);
                let _ = cache.append_token(id, &k, &v);
            }
            3 if !active.is_empty() => {
                let donor = *rng.choose(&active);
                if cache.fork_seq(donor, next_id).is_ok() {
                    active.push(next_id);
                }
                next_id += 1;
            }
            4 if !active.is_empty() => {
                let id = *rng.choose(&active);
                let len = cache.seq_len(id).unwrap();
                let _ = cache.truncate_seq(id, rng.urange(0, len + 1));
            }
            _ => {}
        }
        // Churn must never desynchronize the sparse selector's per-page
        // key statistics — at any kv-head granularity.
        cache.validate_page_meta().map_err(|e| e.to_string())?;
    }
    Ok((cache, active))
}

#[test]
fn covering_selection_gathers_bit_identically_to_dense() {
    prop_check("full selection == dense gather", 30, |rng| {
        let kv_heads = *rng.choose(&KV_HEAD_PLANES);
        let (cache, active) = churned_cache(rng, kv_heads)?;
        let live: Vec<u64> = active
            .iter()
            .copied()
            .filter(|&id| cache.seq_len(id).unwrap_or(0) > 0)
            .take(5)
            .collect();
        if live.is_empty() {
            return Ok(());
        }
        let slots: Vec<Option<u64>> = live.iter().copied().map(Some).collect();
        let mut ctx = PT;
        let mut sels: Vec<Vec<usize>> = Vec::new();
        for &id in &live {
            let len = cache.seq_len(id).unwrap();
            ctx = ctx.max(len);
            let used = cache.seq_pages(id).unwrap().len().min(len.div_ceil(PT));
            // A covering budget must select every page — through the one
            // shared selection implementation the engine serves with.
            let policy = SparsePolicy {
                dense_threshold_pages: 0,
                ..SparsePolicy::with_budget(used + rng.urange(0, 3))
            };
            let (sel, _) = cache
                .select_seq_pages(id, &policy)
                .ok_or("live sequence must select")?;
            if sel != (0..used).collect::<Vec<_>>() {
                return Err(format!("covering budget pruned: {sel:?} of {used}"));
            }
            sels.push(sel);
        }
        let ctx = ctx.next_multiple_of(PT);
        let n = slots.len() * kv_heads * ctx * 4;
        let (mut kf, mut vf) = (vec![0.0f32; n], vec![0.0f32; n]);
        cache.gather(&slots, ctx, &mut kf, &mut vf).map_err(|e| e.to_string())?;
        let sg = cache.gather_selected(&slots, &sels).map_err(|e| e.to_string())?;
        let (mut ks, mut vs) = (vec![9.0f32; n], vec![9.0f32; n]);
        sg.compose_dense(ctx, &mut ks, &mut vs).map_err(|e| e.to_string())?;
        if kf != ks || vf != vs {
            return Err("selected gather diverged from dense".into());
        }
        if sg.shared_bytes > sg.flat_bytes {
            return Err("selected gather grew past dense".into());
        }
        Ok(())
    });
}

#[test]
fn selection_is_deterministic_budget_bounded_and_retains_sink_window() {
    prop_check("selection invariants", 200, |rng| {
        let total = rng.urange(1, 40);
        let scores: Vec<f32> =
            (0..total).map(|_| rng.normal() as f32).collect();
        let sink = rng.urange(0, 4);
        let window = rng.urange(0, 4);
        let budget = rng.urange(sink + window + 1, sink + window + 10);
        let policy = SparsePolicy {
            budget_pages: budget,
            sink_pages: sink,
            window_pages: window,
            dense_threshold_pages: rng.urange(0, 5),
        };
        let sel = select_pages(&policy, &scores);
        if !sel.windows(2).all(|w| w[0] < w[1]) {
            return Err(format!("not strictly ascending: {sel:?}"));
        }
        if sel != select_pages(&policy, &scores) {
            return Err("selection is not deterministic".into());
        }
        if policy.bypasses(total) || budget >= total {
            if sel.len() != total {
                return Err(format!("bypass must select all: {}", sel.len()));
            }
            return Ok(());
        }
        if sel.len() != budget {
            return Err(format!("selected {} of budget {budget}", sel.len()));
        }
        for o in 0..sink.min(total) {
            if !sel.contains(&o) {
                return Err(format!("sink ordinal {o} dropped"));
            }
        }
        for o in total - window.min(total)..total {
            if !sel.contains(&o) {
                return Err(format!("window ordinal {o} dropped"));
            }
        }
        Ok(())
    });
}

#[test]
fn needle_page_is_always_retained_and_reported() {
    // Attention mass planted in one early page: selection at a small
    // budget must keep it every scored step (recall = 1.0), and the
    // stats must report pages-scanned/pages-total plus coverage.
    let case = SparseBenchCase::default_case();
    let c = compare_sparse(case, 1, 5).expect("comparison");
    assert!(
        (c.needle_recall() - 1.0).abs() < 1e-12,
        "needle recall {}",
        c.needle_recall()
    );
    assert_eq!(c.sparse.stats.selection_steps, case.steps);
    assert!(c.sparse.stats.pages_scanned < c.sparse.stats.pages_total);
    let cov = c.sparse.stats.mean_coverage();
    assert!(cov > 0.0 && cov <= 1.0, "coverage {cov}");
    assert!(c.sparse.gathered_bytes < c.dense.gathered_bytes);
    assert!(c.exec_max_err < 1e-3, "executor err {}", c.exec_max_err);
}

#[test]
fn covering_budget_streams_are_bit_identical_to_dense() {
    // The degenerate-sparsity guarantee end to end on the host loop:
    // budget >= context pages => identical tokens, logprobs and RNG
    // trajectory, and exactly the dense gather traffic.
    let mut case = SparseBenchCase::default_case();
    case.policy.budget_pages = case.pages_cap() + 1;
    case.policy.dense_threshold_pages = 0;
    let c = compare_sparse(case, 1, 17).expect("comparison");
    assert!(c.streams_equal(), "covering budget must not move the stream");
    assert_eq!(c.sparse.gathered_bytes, c.dense.gathered_bytes);
    // Same semantics as the engine: past the dense threshold the sparse
    // path stays engaged (complete selections), but nothing is scored.
    assert_eq!(c.sparse.stats.selection_steps, case.steps);
    assert_eq!(c.sparse.stats.lanes_scored, 0, "nothing scored");
    assert_eq!(
        c.sparse.stats.gather_bytes_sparse,
        c.sparse.stats.gather_bytes_dense
    );
}
