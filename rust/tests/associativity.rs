//! Property tests for the paper's central theorem (§IV-A): softmax
//! re-scaling is an associative reduction with identity, so *any* split of
//! the context into unequal blocks, reduced in *any* association order,
//! yields exact attention.

use lean_attention::attention::{
    attention_host, partial_attention_host, Partials, RowStats,
};
use lean_attention::util::rng::Rng;
use lean_attention::util::testing::{max_abs_err, prop_check};

/// Split [0, n) at `cuts` and compute per-slice partials.
fn split_partials(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    g: usize,
    n: usize,
    d: usize,
    lens: &[u32],
    cuts: &[usize],
) -> Vec<Partials> {
    let mut bounds = vec![0usize];
    bounds.extend(cuts.iter().copied().filter(|&c| c > 0 && c < n));
    bounds.push(n);
    bounds.sort_unstable();
    bounds.dedup();
    bounds
        .windows(2)
        .map(|w| {
            let (lo, hi) = (w[0], w[1]);
            let mut ks = Vec::with_capacity(g * (hi - lo) * d);
            let mut vs = Vec::with_capacity(g * (hi - lo) * d);
            for gi in 0..g {
                ks.extend_from_slice(&k[gi * n * d + lo * d..gi * n * d + hi * d]);
                vs.extend_from_slice(&v[gi * n * d + lo * d..gi * n * d + hi * d]);
            }
            partial_attention_host(q, &ks, &vs, g, hi - lo, d, lens, lo)
        })
        .collect()
}

fn reduce_in_order(parts: &[Partials], order: &[usize], g: usize, d: usize) -> Vec<f32> {
    let mut acc = Partials::identity(g, d);
    for &i in order {
        acc.reduce_from(&parts[i]);
    }
    acc.finalize()
}

#[test]
fn arbitrary_splits_and_orders_equal_direct_attention() {
    prop_check("associativity end-to-end", 120, |rng| {
        let g = rng.urange(1, 5);
        let n = rng.urange(8, 200);
        let d = *rng.choose(&[4usize, 16, 64]);
        let q = rng.normal_vec(g * d);
        let k = rng.normal_vec(g * n * d);
        let v = rng.normal_vec(g * n * d);
        let lens: Vec<u32> = (0..g).map(|_| rng.range(1, n as u64 + 1) as u32).collect();
        let want = attention_host(&q, &k, &v, g, n, d, &lens);

        let ncuts = rng.urange(0, 6);
        let cuts: Vec<usize> = (0..ncuts).map(|_| rng.urange(1, n)).collect();
        let parts = split_partials(&q, &k, &v, g, n, d, &lens, &cuts);

        // random permutation of the reduce order
        let mut order: Vec<usize> = (0..parts.len()).collect();
        for i in (1..order.len()).rev() {
            let j = rng.urange(0, i + 1);
            order.swap(i, j);
        }
        let got = reduce_in_order(&parts, &order, g, d);
        let err = max_abs_err(&got, &want);
        if err > 5e-4 {
            return Err(format!("err {err} with {} cuts", cuts.len()));
        }
        Ok(())
    });
}

#[test]
fn tree_vs_linear_reduction_agree() {
    prop_check("tree == linear", 60, |rng| {
        let (g, n, d) = (2usize, 96usize, 8usize);
        let q = rng.normal_vec(g * d);
        let k = rng.normal_vec(g * n * d);
        let v = rng.normal_vec(g * n * d);
        let lens = vec![n as u32; g];
        let cuts = vec![16, 32, 48, 64, 80];
        let parts = split_partials(&q, &k, &v, g, n, d, &lens, &cuts);

        // linear
        let linear = reduce_in_order(&parts, &(0..parts.len()).collect::<Vec<_>>(), g, d);
        // pairwise tree
        let mut level: Vec<Partials> = parts;
        while level.len() > 1 {
            let mut next = Vec::new();
            for pair in level.chunks(2) {
                if pair.len() == 2 {
                    let mut a = pair[0].clone();
                    a.reduce_from(&pair[1]);
                    next.push(a);
                } else {
                    next.push(pair[0].clone());
                }
            }
            level = next;
        }
        let tree = level.remove(0).finalize();
        let err = max_abs_err(&linear, &tree);
        if err > 1e-5 {
            return Err(format!("tree vs linear err {err}"));
        }
        Ok(())
    });
}

#[test]
fn identity_element_absorbs_anywhere() {
    prop_check("identity anywhere", 60, |rng| {
        let (g, d) = (3usize, 8usize);
        let n = 64usize;
        let q = rng.normal_vec(g * d);
        let k = rng.normal_vec(g * n * d);
        let v = rng.normal_vec(g * n * d);
        let lens = vec![n as u32; g];
        let parts = split_partials(&q, &k, &v, g, n, d, &lens, &[20, 40]);
        let want = reduce_in_order(&parts, &[0, 1, 2], g, d);

        // interleave identities at random positions
        let mut acc = Partials::identity(g, d);
        for i in 0..parts.len() {
            if rng.chance(0.5) {
                acc.reduce_from(&Partials::identity(g, d));
            }
            acc.reduce_from(&parts[i]);
        }
        acc.reduce_from(&Partials::identity(g, d));
        let got = acc.finalize();
        let err = max_abs_err(&got, &want);
        if err > 1e-6 {
            return Err(format!("identity err {err}"));
        }
        Ok(())
    });
}

#[test]
fn numerical_stability_under_extreme_stats() {
    // Reduction must stay finite when partial maxima differ by hundreds
    // (long-context regime where naive exp would overflow).
    let mut rng = Rng::new(99);
    let d = 8;
    let mut acc = Partials::identity(1, d);
    for m in [-300.0f32, 250.0, -50.0, 249.0, 0.0] {
        let p = Partials {
            g: 1,
            d,
            o: rng.normal_vec(d),
            stats: vec![RowStats { m, l: 1.0 }],
        };
        acc.reduce_from(&p);
        assert!(acc.o.iter().all(|x| x.is_finite()), "m={m}");
        assert!(acc.stats[0].l.is_finite());
    }
    let out = acc.finalize();
    assert!(out.iter().all(|x| x.is_finite()));
}
