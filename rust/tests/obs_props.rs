//! Property tests for the observability plane: tracer ring semantics,
//! span nesting, Chrome-trace round-trips through the hand-rolled JSON
//! parser, histogram quantile exactness bounds on random workloads, and
//! the metrics consistency audit (every documented counter reaches both
//! exporter outputs). Everything here is artifact-free.

use lean_attention::coordinator::{Metrics, DOCUMENTED_METRICS};
use lean_attention::obs::{
    validate_chrome_trace, Attrs, LogHistogram, Phase, RequestTimeline,
    TimelineRecorder, Tracer, SNAPSHOT_VERSION,
};
use lean_attention::util::json::Json;
use lean_attention::util::rng::Rng;
use lean_attention::util::stats::Summary;

// ---------------------------------------------------------------- tracer

#[test]
fn ring_overflow_keeps_newest_events_with_monotonic_drop_counter() {
    for capacity in [1usize, 2, 7, 64] {
        let t = Tracer::enabled(capacity);
        let total = 200u64;
        let mut last_dropped = 0;
        for i in 0..total {
            t.instant(Phase::Admit, Attrs { seq: Some(i), ..Default::default() });
            let d = t.dropped();
            assert!(d >= last_dropped, "drop counter went backwards");
            last_dropped = d;
        }
        assert_eq!(t.len(), capacity, "ring holds exactly its capacity");
        assert_eq!(t.dropped(), total - capacity as u64);
        let seqs: Vec<u64> =
            t.events().iter().map(|e| e.attrs.seq.unwrap()).collect();
        let expect: Vec<u64> = (total - capacity as u64..total).collect();
        assert_eq!(seqs, expect, "cap {capacity}: newest events survive, in order");
        // The per-phase histogram saw every event, overflow or not.
        assert_eq!(t.phase_hist(Phase::Admit).unwrap().count(), total);
    }
}

/// Open a stack of spans and let them unwind (inner closes first).
fn nest(t: &Tracer, phases: &[Phase]) {
    if let Some((first, rest)) = phases.split_first() {
        let _guard = t.span(*first);
        nest(t, rest);
    }
}

#[test]
fn span_nesting_records_inner_first_with_contained_intervals() {
    let mut rng = Rng::new(41);
    for _trial in 0..20 {
        let depth = rng.urange(1, 8);
        let phases: Vec<Phase> = (0..depth)
            .map(|_| Phase::ALL[rng.urange(0, Phase::ALL.len())])
            .collect();
        let t = Tracer::enabled(64);
        nest(&t, &phases);
        let evs = t.events();
        assert_eq!(evs.len(), depth);
        for (i, ev) in evs.iter().enumerate() {
            // Close order is the reverse of open order, so event i is the
            // span opened at depth (depth - 1 - i).
            assert_eq!(ev.phase, phases[depth - 1 - i]);
            assert_eq!(ev.depth as usize, depth - 1 - i);
            if i > 0 {
                let inner = &evs[i - 1];
                assert!(ev.start_us <= inner.start_us, "outer opens first");
                assert!(
                    inner.start_us + inner.dur_us
                        <= ev.start_us + ev.dur_us + 1e-3,
                    "outer closes last"
                );
            }
        }
    }
}

#[test]
fn chrome_trace_round_trips_through_util_json() {
    let t = Tracer::enabled(256);
    let mut rng = Rng::new(7);
    for i in 0..60u64 {
        if rng.f64() < 0.5 {
            let mut s = t.span(Phase::ALL[rng.urange(0, Phase::ALL.len())]);
            s.set_seq(i);
            s.set_bytes(rng.range(0, 1 << 20));
            s.set_pages(rng.urange(0, 64));
            s.set_flops(rng.range(0, 1 << 24));
        } else {
            t.instant(
                Phase::SpecCommit,
                Attrs { seq: Some(i), k: Some(rng.urange(1, 6)), ..Default::default() },
            );
        }
        if i % 10 == 0 {
            t.advance_step();
        }
    }
    let trace = t.export_chrome_trace();
    validate_chrome_trace(&trace).expect("export matches the schema");
    let text = trace.to_string();
    let parsed = Json::parse(&text).expect("export parses back");
    assert_eq!(parsed, trace, "parse(to_string(trace)) is the identity");
    validate_chrome_trace(&parsed).expect("parsed trace still validates");
    assert_eq!(parsed.as_arr().unwrap().len(), t.len());
}

// ------------------------------------------------------------- histogram

/// Nearest-rank exact quantile, matching the histogram's rank rule.
fn exact_quantile(sorted: &[f64], q: f64) -> f64 {
    let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

#[test]
fn histogram_quantiles_within_one_bucket_of_exact_on_random_workloads() {
    let growth = LogHistogram::growth();
    for seed in [3u64, 11, 42, 99] {
        let mut rng = Rng::new(seed);
        let mut samples = Vec::new();
        let mut h = LogHistogram::new();
        for _ in 0..2000 {
            // Mixed workload: uniform, exponential tail, heavy spikes —
            // the shapes serving latencies actually take.
            let u = rng.f64();
            let v = match rng.urange(0, 3) {
                0 => 10.0 + 990.0 * u,
                1 => -500.0 * (1.0 - u).max(1e-12).ln(),
                _ => 5e4 * (0.5 + u),
            };
            samples.push(v);
            h.record(v);
        }
        samples.sort_by(|a, b| a.total_cmp(b));
        for q in [0.5, 0.9, 0.95, 0.99, 0.999] {
            let exact = exact_quantile(&samples, q);
            let est = h.quantile(q);
            assert!(
                est <= exact * (1.0 + 1e-9) && exact < est * growth * (1.0 + 1e-9),
                "seed {seed} q={q}: est {est} vs exact {exact}"
            );
        }
        // Summary::from_histogram carries the same estimates plus exact
        // moments — the capped replacement for unbounded sample Vecs.
        let s = Summary::from_histogram(&h).unwrap();
        assert_eq!(s.n, samples.len());
        assert_eq!(s.min, samples[0]);
        assert_eq!(s.max, *samples.last().unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        assert!((s.mean - mean).abs() / mean < 1e-9);
    }
}

#[test]
fn histogram_merge_matches_one_histogram_over_the_union() {
    let mut rng = Rng::new(17);
    let (mut a, mut b, mut all) =
        (LogHistogram::new(), LogHistogram::new(), LogHistogram::new());
    for _ in 0..1500 {
        let v = 1.0 + 1e6 * rng.f64();
        all.record(v);
        if rng.f64() < 0.4 {
            a.record(v);
        } else {
            b.record(v);
        }
    }
    a.merge(&b);
    assert_eq!(a.count(), all.count());
    assert_eq!(a.min(), all.min());
    assert_eq!(a.max(), all.max());
    assert!((a.sum() - all.sum()).abs() / all.sum() < 1e-12);
    // Bucket contents are integer counts: quantiles agree exactly.
    for q in [0.1, 0.5, 0.9, 0.99, 0.999] {
        assert_eq!(a.quantile(q), all.quantile(q), "q={q}");
    }
}

// ----------------------------------------------------- timelines and SLO

#[test]
fn slo_attainment_tracks_the_exact_fraction_on_random_timelines() {
    let mut rng = Rng::new(23);
    let mut rec = TimelineRecorder::default();
    let slo_ms = 40.0;
    let mut within = 0usize;
    let n = 400;
    for i in 0..n {
        // e2e between ~1ms and ~800ms, log-uniform.
        let e2e_us = 1e3 * (800f64).powf(rng.f64());
        let tl = RequestTimeline {
            id: i as u64,
            queue_us: e2e_us * 0.1,
            prefill_us: e2e_us * 0.3,
            decode_us: e2e_us * 0.6,
            tokens: rng.urange(1, 32),
        };
        if tl.e2e_us() <= slo_ms * 1e3 {
            within += 1;
        }
        rec.observe(tl);
    }
    let rep = rec.slo_report(slo_ms, 2.0);
    assert_eq!(rep.requests, n as u64);
    let exact = within as f64 / n as f64;
    assert!(
        (rep.attainment - exact).abs() < 0.05,
        "attainment {} vs exact {exact}",
        rep.attainment
    );
    assert!((rep.goodput_rps - exact * n as f64 / 2.0).abs() / rep.goodput_rps < 0.1);
    // Percentile rows are monotone and rendered.
    assert!(rep.e2e_ms.p50 <= rep.e2e_ms.p95 && rep.e2e_ms.p95 <= rep.e2e_ms.p999);
    let out = rep.render();
    assert!(out.contains("SLO"), "{out}");

    // Merging two replicas' recorders sums their populations.
    let mut other = TimelineRecorder::default();
    other.observe(RequestTimeline {
        id: 1000,
        queue_us: 5.0,
        prefill_us: 10.0,
        decode_us: 20.0,
        tokens: 3,
    });
    let mut merged = rec.clone();
    merged.merge(&other);
    assert_eq!(merged.requests(), rec.requests() + 1);
    assert_eq!(merged.tokens(), rec.tokens() + 3);
}

// ------------------------------------------------------ consistency audit

#[test]
fn every_documented_metric_reaches_both_exporters() {
    let mut m = Metrics::default();
    // Touch a few recording paths so the snapshot is not all-zero.
    m.prefill_calls = 3;
    m.decode_steps = 40;
    m.tokens_generated = 160;
    m.requests_finished = 3;
    m.step_us.record(812.5);
    m.prefill_us.record(15_000.0);
    m.record_projection(120.0, 310.0, 0.92);
    m.record_cascade_projection(95.0, 262_144.0);

    let snap = m.snapshot();
    assert_eq!(
        snap.names(),
        DOCUMENTED_METRICS.to_vec(),
        "snapshot exports exactly the documented metric list, in order"
    );

    let prom = snap.to_prometheus();
    let json = snap.to_json();
    assert_eq!(json.usize_at("version"), SNAPSHOT_VERSION as usize);
    let metrics = json.get("metrics").and_then(Json::as_obj).unwrap();
    let kinds = json.get("kinds").and_then(Json::as_obj).unwrap();
    for name in DOCUMENTED_METRICS {
        assert!(
            prom.contains(&format!("leanattn_{name} ")),
            "{name} missing a Prometheus sample line"
        );
        assert!(
            prom.contains(&format!("# TYPE leanattn_{name} ")),
            "{name} missing a Prometheus TYPE line"
        );
        assert!(metrics.contains_key(*name), "{name} missing from the JSON export");
        assert!(kinds.contains_key(*name), "{name} missing a JSON kind");
    }
    assert_eq!(metrics.len(), DOCUMENTED_METRICS.len());

    // Spot-check values survive serialization.
    assert_eq!(
        metrics.get("decode_steps_total"),
        Some(&Json::Num(40.0)),
        "counter value reaches the JSON export"
    );
    assert!(prom.contains("leanattn_decode_steps_total 40\n"));

    // Router-style merge keeps the snapshot well-formed.
    let mut folded = Metrics::default();
    folded.merge(&m);
    folded.merge(&m);
    let snap2 = folded.snapshot();
    assert_eq!(snap2.get("decode_steps_total").unwrap().value, 80.0);
    assert_eq!(snap2.names(), DOCUMENTED_METRICS.to_vec());
}

/// A metric name Prometheus accepts: `[a-zA-Z_:][a-zA-Z0-9_:]*`.
fn legal_metric_name(name: &str) -> bool {
    let mut chars = name.chars();
    let Some(first) = chars.next() else { return false };
    (first.is_ascii_alphabetic() || first == '_' || first == ':')
        && chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

#[test]
fn prometheus_exposition_parses_back_line_exact() {
    // Parse the exposition text the way a scraper would: every line is a
    // HELP comment, a TYPE comment, or a `name value` sample; names are
    // legal; every documented metric appears as exactly one sample with
    // its HELP and TYPE lines directly above it; every value parses as a
    // finite f64 that round-trips to the snapshot's.
    let mut m = Metrics::default();
    m.decode_steps = 17;
    m.tokens_generated = 321;
    m.step_us.record(99.5);
    m.audit.runs = 2;
    m.audit.audit_us = 123.25;
    let snap = m.snapshot();
    let text = snap.to_prometheus();

    let mut samples: std::collections::BTreeMap<&str, f64> =
        std::collections::BTreeMap::new();
    let mut last_help: Option<&str> = None;
    let mut last_type: Option<(&str, &str)> = None;
    for line in text.lines() {
        assert!(!line.trim().is_empty(), "no blank lines in the exposition");
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let (name, help) =
                rest.split_once(' ').expect("HELP carries name + text");
            assert!(legal_metric_name(name), "illegal HELP name {name:?}");
            assert!(!help.trim().is_empty(), "{name} has empty help text");
            last_help = Some(name);
        } else if let Some(rest) = line.strip_prefix("# TYPE ") {
            let (name, kind) =
                rest.split_once(' ').expect("TYPE carries name + kind");
            assert!(legal_metric_name(name), "illegal TYPE name {name:?}");
            assert!(
                kind == "counter" || kind == "gauge",
                "{name}: unknown type {kind:?}"
            );
            assert_eq!(last_help, Some(name), "TYPE must follow its HELP line");
            last_type = Some((name, kind));
        } else {
            let (name, value) =
                line.split_once(' ').expect("sample is `name value`");
            assert!(legal_metric_name(name), "illegal sample name {name:?}");
            assert!(
                !name.contains('{'),
                "exposition is label-free; got {name:?}"
            );
            assert_eq!(
                last_type.map(|(n, _)| n),
                Some(name),
                "sample must follow its TYPE line"
            );
            let v: f64 = value.parse().expect("sample value parses as f64");
            assert!(v.is_finite(), "{name} exports a non-finite value");
            assert!(
                samples.insert(name, v).is_none(),
                "{name} sampled more than once"
            );
        }
    }

    // Exactly the documented set, each matching the snapshot's value and
    // kind bit-for-bit.
    assert_eq!(samples.len(), DOCUMENTED_METRICS.len());
    for name in DOCUMENTED_METRICS {
        let prom_name = format!("leanattn_{name}");
        let metric = snap.get(name).expect("documented metric in snapshot");
        let v = samples
            .get(prom_name.as_str())
            .unwrap_or_else(|| panic!("{name} missing from the exposition"));
        assert_eq!(*v, metric.value, "{name}: exposition value drifted");
        assert!(
            text.contains(&format!("# TYPE {prom_name} {}\n", metric.kind.as_str())),
            "{name}: TYPE line disagrees with the snapshot kind"
        );
    }
}
