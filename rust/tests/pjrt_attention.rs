//! Integration: PJRT attention artifacts vs the Rust host oracle, and the
//! LeanAttention partial path vs the fused kernel. Requires
//! `make artifacts`; tests self-skip when artifacts are absent.

use std::path::Path;
use std::rc::Rc;

use lean_attention::attention::attention_host;
use lean_attention::partition::cascade::{
    build_cascade_plan, CascadeProblem, CascadeTensors, PrefixGroup,
};
use lean_attention::partition::plan::{build_plan, DecodeProblem, Strategy};
use lean_attention::runtime::attention_exec::{
    lean_cascade_host, lean_sparse_host, AttentionProblem,
};
use lean_attention::runtime::{AttentionExecutor, Manifest, Runtime};
use lean_attention::sparse::selected_token_indices;
use lean_attention::util::rng::Rng;
use lean_attention::util::testing::assert_allclose;

fn setup() -> Option<AttentionExecutor> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    let runtime = Rc::new(Runtime::cpu().expect("pjrt cpu client"));
    let manifest = Rc::new(Manifest::load(dir).expect("manifest"));
    Some(AttentionExecutor::new(runtime, manifest))
}

struct Case {
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    lens: Vec<u32>,
    g: usize,
    n: usize,
    d: usize,
}

fn random_case(seed: u64, g: usize, n: usize, d: usize, ragged: bool) -> Case {
    let mut rng = Rng::new(seed);
    let lens = (0..g)
        .map(|_| {
            if ragged {
                rng.range(1, n as u64 + 1) as u32
            } else {
                n as u32
            }
        })
        .collect();
    Case {
        q: rng.normal_vec(g * d),
        k: rng.normal_vec(g * n * d),
        v: rng.normal_vec(g * n * d),
        lens,
        g,
        n,
        d,
    }
}

impl Case {
    fn problem(&self) -> AttentionProblem<'_> {
        AttentionProblem {
            q: &self.q,
            k: &self.k,
            v: &self.v,
            lens: &self.lens,
            g: self.g,
            n: self.n,
            d: self.d,
        }
    }

    fn oracle(&self) -> Vec<f32> {
        attention_host(&self.q, &self.k, &self.v, self.g, self.n, self.d, &self.lens)
    }
}

#[test]
fn full_artifact_matches_oracle() {
    let Some(exec) = setup() else { return };
    for (seed, g, n) in [(1u64, 4usize, 256usize), (2, 8, 1024), (3, 6, 700)] {
        let case = random_case(seed, g, n, 64, true);
        let (o, _lse) = exec.full(&case.problem()).expect("full attention");
        assert_allclose(&o, &case.oracle(), 2e-4, 2e-4, "full vs oracle");
    }
}

#[test]
fn full_artifact_head_dim_128() {
    let Some(exec) = setup() else { return };
    let case = random_case(9, 4, 256, 128, true);
    let (o, _) = exec.full(&case.problem()).expect("d=128 attention");
    assert_allclose(&o, &case.oracle(), 2e-4, 2e-4, "d128 vs oracle");
}

#[test]
fn lean_partial_path_matches_fused_kernel() {
    let Some(exec) = setup() else { return };
    let case = random_case(4, 6, 1024, 64, true);
    let (o_full, lse_full) = exec.full(&case.problem()).expect("full");

    // One head per batch element makes group i's context exactly lens[i],
    // matching the ragged per-group lengths of the raw tensors.
    let problem = DecodeProblem {
        heads: 1,
        kv_heads: 1,
        head_dim: 64,
        ctx_lens: case.lens.clone(),
        tile: 256,
    };
    let plan = build_plan(&problem, Strategy::StreamK, 13);
    plan.validate(&problem).expect("plan valid");
    let (o_lean, lse_lean) = exec.lean(&case.problem(), &plan).expect("lean");
    assert_allclose(&o_lean, &o_full, 3e-4, 3e-4, "lean vs fused");
    assert_allclose(&lse_lean, &lse_full, 1e-3, 1e-3, "lse lean vs fused");
}

#[test]
fn lean_path_all_strategies_match_oracle() {
    let Some(exec) = setup() else { return };
    let case = random_case(5, 8, 1024, 64, true);
    let want = case.oracle();
    let problem = DecodeProblem {
        heads: 1,
        kv_heads: 1,
        head_dim: 64,
        ctx_lens: case.lens.clone(),
        tile: 256,
    };
    for strategy in [
        Strategy::Dense,
        Strategy::FixedSplit { splits: 3 },
        Strategy::StreamK,
    ] {
        let plan = build_plan(&problem, strategy, 7);
        plan.validate(&problem).expect("plan valid");
        let (o, _) = exec.lean(&case.problem(), &plan).expect("lean exec");
        assert_allclose(&o, &want, 3e-4, 3e-4, strategy.name());
    }
}

#[test]
fn lean_cascade_matches_host_oracle_and_host_twin() {
    let Some(exec) = setup() else { return };
    // Two sequences share one 256-token (= artifact tile) prefix; a third
    // is solo; one sharer's context is exactly the prefix (empty suffix).
    let p = CascadeProblem::new(
        1,
        vec![640, 256, 300],
        64,
        vec![PrefixGroup { prefix_len: 256, members: vec![0, 1] }],
    )
    .unwrap()
    .with_tile(256);
    let t = CascadeTensors::random(&p, 11);
    let cp = build_cascade_plan(&p, 13);
    cp.plan.validate(&cp.segment_problem).expect("plan valid");

    let (o, lse) = exec.lean_cascade(&p, &t, &cp).expect("lean cascade");

    // Exact oracle over the composed per-sequence K/V.
    let (k, v, n_max) = t.full_kv(&p);
    let lens: Vec<u32> = (0..p.outputs())
        .map(|g| p.ctx_lens[g / p.heads])
        .collect();
    let want = attention_host(&t.q, &k, &v, p.outputs(), n_max, 64, &lens);
    assert_allclose(&o, &want, 3e-4, 3e-4, "lean_cascade vs oracle");

    // And against the artifact-free twin (same driver, host partials).
    let (o_host, lse_host) = lean_cascade_host(&p, &t, &cp, 8);
    assert_allclose(&o, &o_host, 3e-4, 3e-4, "pjrt vs host twin");
    assert_allclose(&lse, &lse_host, 1e-3, 1e-3, "lse pjrt vs host twin");
}

#[test]
fn lean_sparse_matches_host_twin_and_restricted_oracle() {
    let Some(exec) = setup() else { return };
    // Two sequences, 1024-token contexts over 256-token pages; each lane
    // keeps a different page subset and lane 1's kept tail is partial.
    let (heads, n, d, pt) = (1usize, 1024usize, 64usize, 256usize);
    let batch = 2;
    let g = batch * heads;
    let mut rng = Rng::new(23);
    let q = rng.normal_vec(g * d);
    let k = rng.normal_vec(g * n * d);
    let v = rng.normal_vec(g * n * d);
    let lens = vec![1024u32, 900];
    let sels: Vec<Vec<usize>> = vec![vec![0, 2, 3], vec![0, 1, 3]];

    let (o, lse) = exec
        .lean_sparse(&q, &k, &v, &lens, heads, heads, n, d, pt, &sels, 256, 13)
        .expect("lean sparse");
    let (o_host, lse_host) =
        lean_sparse_host(&q, &k, &v, &lens, heads, heads, n, d, pt, &sels, 256, 13, 8)
            .expect("host twin");
    assert_allclose(&o, &o_host, 3e-4, 3e-4, "pjrt vs host twin");
    assert_allclose(&lse, &lse_host, 1e-3, 1e-3, "lse pjrt vs host twin");

    // Dense oracle restricted to the selected pages, per sequence.
    for s in 0..batch {
        let idx = selected_token_indices(lens[s] as usize, pt, &sels[s]);
        let n_sel = idx.len();
        let mut kc = vec![0.0f32; n_sel * d];
        let mut vc = vec![0.0f32; kc.len()];
        for (j, &t) in idx.iter().enumerate() {
            let src = (s * n + t) * d;
            kc[j * d..(j + 1) * d].copy_from_slice(&k[src..src + d]);
            vc[j * d..(j + 1) * d].copy_from_slice(&v[src..src + d]);
        }
        let want =
            attention_host(&q[s * d..(s + 1) * d], &kc, &vc, 1, n_sel, d, &[n_sel as u32]);
        assert_allclose(
            &o[s * d..(s + 1) * d],
            &want,
            3e-4,
            3e-4,
            "lean_sparse vs restricted oracle",
        );
    }
}

#[test]
fn executable_cache_reuses_compilations() {
    let Some(exec) = setup() else { return };
    let case = random_case(6, 4, 256, 64, false);
    exec.full(&case.problem()).unwrap();
    let after_first = exec.compiled_count();
    exec.full(&case.problem()).unwrap();
    assert_eq!(exec.compiled_count(), after_first, "no recompilation");
}

#[test]
fn padding_does_not_leak() {
    // Same logical problem executed at two bucket sizes must agree: run a
    // g=4/n=256 case (fits g8/c256) and again forced through g8/c1024 by
    // growing n with garbage rows beyond lens.
    let Some(exec) = setup() else { return };
    let small = random_case(7, 4, 256, 64, true);
    let (o_small, _) = exec.full(&small.problem()).unwrap();

    // embed into n=1024 with poison in the padding region
    let n2 = 1024;
    let mut k2 = vec![7.7f32; small.g * n2 * 64];
    let mut v2 = vec![-9.9f32; small.g * n2 * 64];
    for gi in 0..small.g {
        k2[gi * n2 * 64..gi * n2 * 64 + 256 * 64]
            .copy_from_slice(&small.k[gi * 256 * 64..(gi + 1) * 256 * 64]);
        v2[gi * n2 * 64..gi * n2 * 64 + 256 * 64]
            .copy_from_slice(&small.v[gi * 256 * 64..(gi + 1) * 256 * 64]);
    }
    let big = AttentionProblem {
        q: &small.q,
        k: &k2,
        v: &v2,
        lens: &small.lens,
        g: small.g,
        n: n2,
        d: 64,
    };
    let (o_big, _) = exec.full(&big).unwrap();
    assert_allclose(&o_big, &o_small, 1e-5, 1e-5, "bucket invariance");
}
