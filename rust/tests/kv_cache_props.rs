//! Property tests for [`PagedKvCache`] page accounting: across random
//! workloads of inserts, shared-prefix inserts, appends (with
//! copy-on-write), zero-copy forks, speculative truncations, external
//! retains (the radix index), releases and frees, the cache must
//! (a) never leak a page, (b) never double-free, (c) keep every
//! holder's refcount exact, and (d) return a page to the free list
//! exactly when its last reference drops. Truncation of a shared page
//! run must never disturb another holder's view — the next append
//! copy-on-writes instead of mutating the sibling's bytes. The same
//! churn must also never desynchronize the per-page key statistics the
//! sparse selector scores against: after every operation the
//! incrementally-maintained metadata must match a from-scratch recompute
//! over each live page's filled rows ([`PagedKvCache::validate_page_meta`]).
//!
//! The cache's head plane is the **KV-head** plane (GQA/MQA stores one
//! stream per kv head, not per query head), so every suite sweeps
//! `h_kv ∈ {1, h/4, h}` for a 4-query-head model — page accounting must
//! be indifferent to the grouping.

use std::collections::HashMap;

use lean_attention::coordinator::PagedKvCache;
use lean_attention::util::rng::Rng;
use lean_attention::util::testing::prop_check;

const LAYERS: usize = 1;
const DH: usize = 4;
const PAGE_TOKENS: usize = 4;
const PAGES: usize = 24;
/// KV-head planes under test: MQA, grouped (h/4), ungrouped (h_kv == h).
const KV_HEAD_PLANES: [usize; 3] = [1, 2, 4];

fn new_cache(kv_heads: usize) -> PagedKvCache {
    PagedKvCache::new(LAYERS, kv_heads, DH, PAGE_TOKENS, PAGES)
}

fn kv(rng: &mut Rng, kv_heads: usize, tokens: usize) -> (Vec<f32>, Vec<f32>) {
    let n = LAYERS * kv_heads * tokens * DH;
    (rng.normal_vec(n), rng.normal_vec(n))
}

/// Shadow refcount model: every active sequence holds one reference per
/// page in its page list; every tracked external retain holds one more.
fn expected_refs(
    cache: &PagedKvCache,
    active: &[u64],
    retains: &[usize],
) -> HashMap<usize, u32> {
    let mut refs: HashMap<usize, u32> = HashMap::new();
    for &id in active {
        for &p in cache.seq_pages(id).unwrap() {
            *refs.entry(p).or_insert(0) += 1;
        }
    }
    for &p in retains {
        *refs.entry(p).or_insert(0) += 1;
    }
    refs
}

fn check_invariants(
    cache: &PagedKvCache,
    active: &[u64],
    retains: &[usize],
) -> Result<(), String> {
    let refs = expected_refs(cache, active, retains);
    for p in 0..PAGES {
        let want = refs.get(&p).copied().unwrap_or(0);
        let got = cache.page_ref(p);
        if got != want {
            return Err(format!("page {p}: refcount {got}, shadow says {want}"));
        }
    }
    let live = refs.values().filter(|&&r| r > 0).count();
    if cache.used_pages() != live {
        return Err(format!(
            "used {} but {live} pages have holders (leak or phantom)",
            cache.used_pages()
        ));
    }
    if cache.free_pages() + cache.used_pages() != PAGES {
        return Err("free + used != total".into());
    }
    Ok(())
}

#[test]
fn random_workload_never_leaks_or_double_frees() {
    prop_check("kv cache refcount invariants", 40, |rng| {
        let kv_heads = *rng.choose(&KV_HEAD_PLANES);
        let mut cache = new_cache(kv_heads);
        let mut active: Vec<u64> = Vec::new();
        let mut retains: Vec<usize> = Vec::new();
        let mut next_id = 0u64;

        for _ in 0..120 {
            match rng.urange(0, 8) {
                // Plain insert.
                0 => {
                    let len = rng.urange(1, 3 * PAGE_TOKENS + 2);
                    let (k, v) = kv(rng, kv_heads, len);
                    let id = next_id;
                    next_id += 1;
                    if cache.insert_seq(id, &k, &v, len).is_ok() {
                        active.push(id);
                    }
                }
                // Shared-prefix insert: share an existing sequence's full
                // pages (only fully-occupied ones are shareable).
                1 if !active.is_empty() => {
                    let donor = *rng.choose(&active);
                    let donor_len = cache.seq_len(donor).unwrap();
                    let full = donor_len / PAGE_TOKENS;
                    if full == 0 {
                        continue;
                    }
                    let take = rng.urange(1, full + 1);
                    let shared: Vec<usize> =
                        cache.seq_pages(donor).unwrap()[..take].to_vec();
                    let suffix = rng.urange(0, PAGE_TOKENS + 3);
                    if shared.is_empty() && suffix == 0 {
                        continue;
                    }
                    let (k, v) = kv(rng, kv_heads, suffix);
                    let id = next_id;
                    next_id += 1;
                    if cache
                        .insert_seq_shared(id, &shared, &k, &v, suffix)
                        .is_ok()
                    {
                        active.push(id);
                    }
                }
                // Append (may copy-on-write if the tail page is shared).
                2 if !active.is_empty() => {
                    let id = *rng.choose(&active);
                    let (k, v) = kv(rng, kv_heads, 1);
                    let _ = cache.append_token(id, &k, &v);
                }
                // Free a sequence.
                3 if !active.is_empty() => {
                    let i = rng.urange(0, active.len());
                    let id = active.swap_remove(i);
                    cache.free_seq(id);
                }
                // External retain (radix-index style) on a live page.
                4 if !active.is_empty() => {
                    let id = *rng.choose(&active);
                    let pages = cache.seq_pages(id).unwrap();
                    let p = pages[rng.urange(0, pages.len())];
                    cache.retain_page(p).map_err(|e| e.to_string())?;
                    retains.push(p);
                }
                // Release one external retain ("eviction at refcount 1"
                // is the caller's policy; releasing is legal at any
                // refcount >= 1 and frees only at 0).
                5 if !retains.is_empty() => {
                    let i = rng.urange(0, retains.len());
                    let p = retains.swap_remove(i);
                    cache.release_page(p).map_err(|e| e.to_string())?;
                }
                // Zero-copy fork: a sibling takes one reference per page.
                6 if !active.is_empty() => {
                    let donor = *rng.choose(&active);
                    let id = next_id;
                    next_id += 1;
                    cache.fork_seq(donor, id).map_err(|e| e.to_string())?;
                    active.push(id);
                }
                // Speculative rollback: truncate to a random shorter
                // length, releasing whole dropped pages (shared ones
                // survive for their other holders).
                7 if !active.is_empty() => {
                    let id = *rng.choose(&active);
                    let len = cache.seq_len(id).unwrap();
                    let new_len = rng.urange(0, len + 1);
                    cache.truncate_seq(id, new_len).map_err(|e| e.to_string())?;
                }
                _ => {}
            }
            check_invariants(&cache, &active, &retains)?;
            // Fork → COW → truncate → append churn must keep the page
            // statistics equal to a from-scratch recompute.
            cache.validate_page_meta().map_err(|e| e.to_string())?;
        }

        // Drain everything: no page may leak.
        for id in active.drain(..) {
            cache.free_seq(id);
        }
        for p in retains.drain(..) {
            cache.release_page(p).map_err(|e| e.to_string())?;
        }
        if cache.free_pages() != PAGES {
            return Err(format!(
                "leak: {} of {PAGES} pages free after draining",
                cache.free_pages()
            ));
        }
        // Everything is free now: any further release is a double free.
        for p in 0..PAGES {
            if cache.release_page(p).is_ok() {
                return Err(format!("double free of page {p} not rejected"));
            }
        }
        Ok(())
    });
}

#[test]
fn gather_shared_equals_flat_gather_on_random_sharing() {
    // Across random mixes of solo sequences, shared-prefix inserts and
    // appends (including COW forks of the page lists), the deduplicated
    // gather composed back into dense views must equal the flat gather
    // bit-for-bit, while never materializing more bytes than it.
    prop_check("gather_shared == gather", 40, |rng| {
        let kv_heads = *rng.choose(&KV_HEAD_PLANES);
        let mut cache = new_cache(kv_heads);
        let mut active: Vec<u64> = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..20 {
            match rng.urange(0, 5) {
                0 => {
                    let len = rng.urange(1, 3 * PAGE_TOKENS);
                    let (k, v) = kv(rng, kv_heads, len);
                    if cache.insert_seq(next_id, &k, &v, len).is_ok() {
                        active.push(next_id);
                    }
                    next_id += 1;
                }
                1 if !active.is_empty() => {
                    let donor = *rng.choose(&active);
                    let full = cache.seq_len(donor).unwrap() / PAGE_TOKENS;
                    if full == 0 {
                        continue;
                    }
                    let take = rng.urange(1, full + 1);
                    let shared: Vec<usize> =
                        cache.seq_pages(donor).unwrap()[..take].to_vec();
                    let suffix = rng.urange(0, 2 * PAGE_TOKENS);
                    let (k, v) = kv(rng, kv_heads, suffix);
                    if cache
                        .insert_seq_shared(next_id, &shared, &k, &v, suffix)
                        .is_ok()
                    {
                        active.push(next_id);
                    }
                    next_id += 1;
                }
                2 if !active.is_empty() => {
                    let id = *rng.choose(&active);
                    let (k, v) = kv(rng, kv_heads, 1);
                    let _ = cache.append_token(id, &k, &v);
                }
                3 if !active.is_empty() => {
                    let donor = *rng.choose(&active);
                    if cache.fork_seq(donor, next_id).is_ok() {
                        active.push(next_id);
                    }
                    next_id += 1;
                }
                4 if !active.is_empty() => {
                    let id = *rng.choose(&active);
                    let len = cache.seq_len(id).unwrap();
                    let _ = cache.truncate_seq(id, rng.urange(0, len + 1));
                }
                _ => {}
            }
            cache.validate_page_meta().map_err(|e| e.to_string())?;
        }
        if active.is_empty() {
            return Ok(());
        }
        // Random slot layout over the active set, with gaps.
        let mut slots: Vec<Option<u64>> = Vec::new();
        let mut ctx = PAGE_TOKENS;
        for &id in active.iter().take(6) {
            if rng.urange(0, 4) == 0 {
                slots.push(None);
            }
            slots.push(Some(id));
            ctx = ctx.max(cache.seq_len(id).unwrap());
        }
        let ctx = ctx.next_multiple_of(PAGE_TOKENS);
        let n = LAYERS * slots.len() * kv_heads * ctx * DH;
        let (mut kf, mut vf) = (vec![0.0; n], vec![0.0; n]);
        cache
            .gather(&slots, ctx, &mut kf, &mut vf)
            .map_err(|e| e.to_string())?;
        let sg = cache.gather_shared(&slots).map_err(|e| e.to_string())?;
        let (mut ks, mut vs) = (vec![9.0; n], vec![9.0; n]);
        sg.compose_dense(ctx, &mut ks, &mut vs)
            .map_err(|e| e.to_string())?;
        if kf != ks || vf != vs {
            return Err("composed views differ from flat gather".into());
        }
        if sg.shared_bytes > sg.flat_bytes {
            return Err(format!(
                "dedup gather grew: {} > {}",
                sg.shared_bytes, sg.flat_bytes
            ));
        }
        for id in active.drain(..) {
            cache.free_seq(id);
        }
        Ok(())
    });
}

#[test]
fn truncate_fork_append_interleavings_preserve_sibling_views() {
    // The speculative-decoding serving shape: a fork sibling shares the
    // parent's pages (including a partial tail) while the parent churns
    // through eager draft appends and rollback truncates. Whatever the
    // interleaving, the sibling's gathered view must stay bit-identical
    // — truncation never mutates shared pages, and appends into a still-
    // shared tail copy-on-write first.
    prop_check("truncate x fork x append keeps sibling views", 30, |rng| {
        let kv_heads = *rng.choose(&KV_HEAD_PLANES);
        let mut cache = new_cache(kv_heads);
        let len = rng.urange(1, 3 * PAGE_TOKENS);
        let (k, v) = kv(rng, kv_heads, len);
        cache.insert_seq(0, &k, &v, len).map_err(|e| e.to_string())?;
        cache.fork_seq(0, 1).map_err(|e| e.to_string())?;

        let ctx = 4 * PAGE_TOKENS;
        let n = LAYERS * kv_heads * ctx * DH;
        let (mut k0, mut v0) = (vec![0.0; n], vec![0.0; n]);
        cache
            .gather(&[Some(1)], ctx, &mut k0, &mut v0)
            .map_err(|e| e.to_string())?;

        let (mut kx, mut vx) = (vec![0.0; n], vec![0.0; n]);
        for step in 0..12 {
            if rng.chance(0.5) {
                let (nk, nv) = kv(rng, kv_heads, 1);
                let _ = cache.append_token(0, &nk, &nv);
            } else {
                let plen = cache.seq_len(0).unwrap();
                cache
                    .truncate_seq(0, rng.urange(0, plen + 1))
                    .map_err(|e| e.to_string())?;
            }
            cache
                .gather(&[Some(1)], ctx, &mut kx, &mut vx)
                .map_err(|e| e.to_string())?;
            if kx != k0 || vx != v0 {
                return Err(format!("sibling view changed at step {step}"));
            }
            cache.validate_page_meta().map_err(|e| e.to_string())?;
        }

        cache.free_seq(0);
        cache.free_seq(1);
        if cache.free_pages() != PAGES {
            return Err("interleaving leaked pages".into());
        }
        Ok(())
    });
}

#[test]
fn eviction_frees_only_at_refcount_zero() {
    for kv_heads in KV_HEAD_PLANES {
        let mut rng = Rng::new(9);
        let mut cache = new_cache(kv_heads);
        // Seq 1 owns two full pages; an index-style retain pins both.
        let (k, v) = kv(&mut rng, kv_heads, 2 * PAGE_TOKENS);
        cache.insert_seq(1, &k, &v, 2 * PAGE_TOKENS).unwrap();
        let pages: Vec<usize> = cache.seq_pages(1).unwrap().to_vec();
        for &p in &pages {
            cache.retain_page(p).unwrap();
            assert_eq!(cache.page_ref(p), 2);
        }

        // "Evicting" (releasing the index reference) while the sequence
        // is alive must not free the pages.
        assert!(!cache.release_page(pages[0]).unwrap());
        assert_eq!(cache.page_ref(pages[0]), 1);
        assert_eq!(cache.free_pages(), PAGES - 2);

        // Once the sequence is gone, the remaining reference is the last
        // holder: releasing it frees the page.
        cache.free_seq(1);
        assert_eq!(cache.free_pages(), PAGES - 1); // pages[1] index-held
        assert!(cache.release_page(pages[1]).unwrap());
        assert_eq!(cache.free_pages(), PAGES);
    }
}

#[test]
fn cow_keeps_both_views_consistent_under_shared_partial_pages() {
    for kv_heads in KV_HEAD_PLANES {
        let mut rng = Rng::new(11);
        let mut cache = new_cache(kv_heads);
        // Donor with 1.5 pages; a fork retains its partial tail page.
        let len = PAGE_TOKENS + PAGE_TOKENS / 2;
        let (k, v) = kv(&mut rng, kv_heads, len);
        cache.insert_seq(1, &k, &v, len).unwrap();
        let tail = *cache.seq_pages(1).unwrap().last().unwrap();
        cache.retain_page(tail).unwrap();

        // Append: the tail is shared, so the cache must clone it.
        let (nk, nv) = kv(&mut rng, kv_heads, 1);
        let cow = cache.append_token(1, &nk, &nv).unwrap();
        assert!(cow);
        let new_tail = *cache.seq_pages(1).unwrap().last().unwrap();
        assert_ne!(new_tail, tail);
        assert_eq!(cache.page_ref(tail), 1, "fork still owns the original");

        // The sequence's gathered view has the old rows plus the new
        // token.
        let ctx = 2 * PAGE_TOKENS;
        let mut ko = vec![0.0; LAYERS * kv_heads * ctx * DH];
        let mut vo = vec![0.0; ko.len()];
        cache.gather(&[Some(1)], ctx, &mut ko, &mut vo).unwrap();
        // layer 0, kv head 0: original token `len - 1`, then the
        // appended token.
        let row = |t: usize| t * DH;
        let orig = (len - 1) * DH;
        assert_eq!(&ko[row(len - 1)..row(len - 1) + DH], &k[orig..orig + DH]);
        assert_eq!(&ko[row(len)..row(len) + DH], &nk[..DH]);

        cache.free_seq(1);
        cache.release_page(tail).unwrap();
        assert_eq!(cache.free_pages(), PAGES);
    }
}
