//! Integration: transformer model artifacts (prefill + decode) through
//! PJRT — determinism, shape contracts, prefill/decode consistency.
//! Self-skips when artifacts are absent.

use std::path::Path;
use std::rc::Rc;

use lean_attention::runtime::{Manifest, ModelRuntime, Runtime};
use lean_attention::util::rng::Rng;
use lean_attention::util::testing::assert_allclose;

fn setup() -> Option<(Rc<Runtime>, Manifest)> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some((
        Rc::new(Runtime::cpu().expect("pjrt")),
        Manifest::load(dir).expect("manifest"),
    ))
}

fn random_prompt(rng: &mut Rng, vocab: usize, len: usize) -> Vec<i32> {
    (0..len).map(|_| rng.range(0, vocab as u64) as i32).collect()
}

#[test]
fn prefill_shapes_and_determinism() {
    let Some((rt, m)) = setup() else { return };
    let model = ModelRuntime::load(&rt, &m, "tiny").expect("load tiny");
    let a = &model.art;
    let mut rng = Rng::new(1);
    let tokens = random_prompt(&mut rng, a.vocab, a.batch * a.prefill_bucket);
    let lengths: Vec<i32> = (0..a.batch)
        .map(|i| ((i + 1) * a.prefill_bucket / a.batch).max(1) as i32)
        .collect();

    let o1 = model.prefill(&tokens, &lengths).expect("prefill");
    assert_eq!(o1.logits.len(), a.batch * a.vocab);
    assert_eq!(
        o1.k.len(),
        a.n_layers * a.batch * a.n_heads * a.prefill_bucket * a.head_dim
    );
    assert!(o1.logits.iter().all(|x| x.is_finite()));

    let o2 = model.prefill(&tokens, &lengths).expect("prefill again");
    assert_eq!(o1.logits, o2.logits, "deterministic");
}

#[test]
fn decode_consistent_with_prefill() {
    // Prefill p-1 tokens then decode token p-1: last-token logits must
    // match prefilling all p tokens directly (same check as the python
    // test, but through the compiled artifacts and the Rust cache path).
    let Some((rt, m)) = setup() else { return };
    let model = ModelRuntime::load(&rt, &m, "tiny").expect("load tiny");
    let a = model.art.clone();
    let mut rng = Rng::new(2);
    let p = a.prefill_bucket;
    let prompt: Vec<i32> = random_prompt(&mut rng, a.vocab, a.batch * p);

    // Path A: full prefill.
    let full_lens = vec![p as i32; a.batch];
    let full = model.prefill(&prompt, &full_lens).expect("full prefill");

    // Path B: prefill p-1, then one decode step.
    let part_lens = vec![(p - 1) as i32; a.batch];
    let part = model.prefill(&prompt, &part_lens).expect("part prefill");
    let c = a.ctx_bucket;
    let (l, b, h, dh) = (a.n_layers, a.batch, a.n_heads, a.head_dim);
    let mut kc = vec![0.0f32; l * b * h * c * dh];
    let mut vc = vec![0.0f32; l * b * h * c * dh];
    // copy [l,b,h,p,dh] -> [l,b,h,c,dh] (only first p-1 rows are real)
    for li in 0..l {
        for bi in 0..b {
            for hi in 0..h {
                let src = (((li * b) + bi) * h + hi) * p * dh;
                let dst = (((li * b) + bi) * h + hi) * c * dh;
                kc[dst..dst + (p - 1) * dh]
                    .copy_from_slice(&part.k[src..src + (p - 1) * dh]);
                vc[dst..dst + (p - 1) * dh]
                    .copy_from_slice(&part.v[src..src + (p - 1) * dh]);
            }
        }
    }
    let tokens: Vec<i32> = (0..b).map(|bi| prompt[bi * p + p - 1]).collect();
    let positions = vec![(p - 1) as i32; b];
    let dec = model.decode(&tokens, &kc, &vc, &positions).expect("decode");

    assert_allclose(&dec.logits, &full.logits, 5e-3, 5e-3, "decode vs prefill");
}

#[test]
fn decode_rejects_bad_shapes() {
    let Some((rt, m)) = setup() else { return };
    let model = ModelRuntime::load(&rt, &m, "tiny").expect("load tiny");
    let a = model.art.clone();
    let n = model.cache_elems();
    // wrong cache size
    assert!(model
        .decode(&vec![0; a.batch], &vec![0.0; n - 1], &vec![0.0; n], &vec![0; a.batch])
        .is_err());
    // position out of bucket
    assert!(model
        .decode(
            &vec![0; a.batch],
            &vec![0.0; n],
            &vec![0.0; n],
            &vec![a.ctx_bucket as i32; a.batch],
        )
        .is_err());
    // prompt length 0
    assert!(model
        .prefill(&vec![0; a.batch * a.prefill_bucket], &vec![0; a.batch])
        .is_err());
}
