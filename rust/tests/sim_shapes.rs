//! Simulator shape tests: the qualitative claims of the paper's
//! evaluation, asserted across broad parameter ranges (the "who wins,
//! roughly by how much, where are the crossovers" contract of DESIGN.md).

use lean_attention::partition::plan::{DecodeProblem, Strategy};
use lean_attention::sim::schedule::{simulate, simulate_all};
use lean_attention::sim::GpuArch;
use lean_attention::util::testing::prop_check;

#[test]
fn lean_dominates_everywhere() {
    // §IV-C: "LeanAttention will either always perform better or the same
    // as FlashAttention-2 and FlashDecoding."
    prop_check("LA never loses", 150, |rng| {
        let batch = rng.urange(1, 33);
        let heads = *rng.choose(&[8usize, 16, 32, 56, 64, 128]);
        let ctx = 1usize << rng.urange(10, 19);
        let p = DecodeProblem::uniform(batch, heads, ctx, 64);
        let arch = if rng.chance(0.5) { GpuArch::a100() } else { GpuArch::h100() };
        let rs = simulate_all(&p, &arch);
        let (fa2, fd, la) = (&rs[0], &rs[1], &rs[3]);
        if la.latency_us > fa2.latency_us * 1.05 {
            return Err(format!(
                "LA {:.1} > FA2 {:.1} at b{batch} h{heads} c{ctx}",
                la.latency_us, fa2.latency_us
            ));
        }
        if la.latency_us > fd.latency_us * 1.05 {
            return Err(format!(
                "LA {:.1} > FD {:.1} at b{batch} h{heads} c{ctx}",
                la.latency_us, fd.latency_us
            ));
        }
        Ok(())
    });
}

#[test]
fn speedup_band_matches_paper_at_headline_points() {
    let arch = GpuArch::a100();
    // 256k ctx, 56 heads, BS 2 — paper's 2.18x point. Accept 1.5-3x.
    let p = DecodeProblem::uniform(2, 56, 262_144, 64);
    let fd = simulate(&p, Strategy::fixed_split_auto(&p, arch.num_sms), &arch);
    let la = simulate(&p, Strategy::StreamK, &arch);
    let s = fd.latency_us / la.latency_us;
    assert!((1.4..3.2).contains(&s), "headline speedup {s}");
}

#[test]
fn fa2_latency_flat_in_heads_until_saturation() {
    // FA2 parallelizes only over batch*heads: below device capacity its
    // latency is context-bound and constant in heads.
    let arch = GpuArch::a100();
    let l8 = simulate(&DecodeProblem::uniform(1, 8, 65536, 64), Strategy::Dense, &arch);
    let l64 =
        simulate(&DecodeProblem::uniform(1, 64, 65536, 64), Strategy::Dense, &arch);
    let ratio = l64.latency_us / l8.latency_us;
    assert!((0.9..1.1).contains(&ratio), "FA2 flat: {ratio}");
}

#[test]
fn fd_quantization_cliff_when_heads_exceed_sms() {
    // Fig 7b: once groups > SMs, FD stops splitting and rides partially
    // full waves; LA keeps its advantage.
    let arch = GpuArch::a100();
    let p = DecodeProblem::uniform(4, 32, 262_144, 64); // 128 groups > 0.8*108
    let fd = simulate(&p, Strategy::fixed_split_auto(&p, arch.num_sms), &arch);
    let la = simulate(&p, Strategy::StreamK, &arch);
    assert_eq!(fd.kernel_launches, 1, "FD resorts to vanilla FA2");
    assert!(fd.latency_us / la.latency_us > 1.2);
}

#[test]
fn h100_faster_than_a100_all_mechanisms() {
    let p = DecodeProblem::uniform(4, 32, 65536, 64);
    for s in [Strategy::Dense, Strategy::StreamK] {
        let a = simulate(&p, s, &GpuArch::a100());
        let h = simulate(&p, s, &GpuArch::h100());
        assert!(h.latency_us < a.latency_us, "{}", s.name());
    }
}

#[test]
fn multi_gpu_scales_lean_nearly_linearly() {
    let p = DecodeProblem::uniform(4, 256, 262_144, 64);
    let one = simulate(&p, Strategy::StreamK, &GpuArch::a100());
    let eight = simulate(&p, Strategy::StreamK, &GpuArch::a100().multi(8));
    let scaling = one.latency_us / eight.latency_us;
    assert!(
        (5.0..8.5).contains(&scaling),
        "8-GPU scaling {scaling} (paper: near-linear with TP)"
    );
}

#[test]
fn energy_ordering_follows_occupancy() {
    prop_check("energy ordering", 60, |rng| {
        let heads = *rng.choose(&[32usize, 56]);
        let ctx = 1usize << rng.urange(14, 19);
        let p = DecodeProblem::uniform(1, heads, ctx, 64);
        let rs = simulate_all(&p, &GpuArch::a100());
        let (fa2, fd, la) = (&rs[0], &rs[1], &rs[3]);
        if la.energy_j > fd.energy_j * 1.02 {
            return Err(format!("LA {} > FD {} energy", la.energy_j, fd.energy_j));
        }
        if fa2.energy_j < la.energy_j * 0.98 {
            return Err("FA2 cheaper than LA?".into());
        }
        Ok(())
    });
}

#[test]
fn occupancy_independent_of_problem_size_for_lean() {
    // The paper's core claim: near-100% occupancy irrespective of problem
    // size (given enough tiles to fill one wave).
    let arch = GpuArch::a100();
    for (b, h, ctx) in [
        (1usize, 12usize, 1 << 17),
        (2, 56, 1 << 18),
        (8, 8, 1 << 16),
        (16, 128, 1 << 14),
        (1, 96, 1 << 19),
    ] {
        let p = DecodeProblem::uniform(b, h, ctx, 64);
        let la = simulate(&p, Strategy::StreamK, &arch);
        assert!(
            la.occupancy > 0.9,
            "b{b} h{h} ctx{ctx}: occupancy {}",
            la.occupancy
        );
    }
}

#[test]
fn reduction_overhead_constant_in_context_for_lean() {
    // §I: LA has constant reduction overheads vs FD's split-scaling ones.
    let arch = GpuArch::a100();
    let short = DecodeProblem::uniform(1, 8, 1 << 14, 64);
    let long = DecodeProblem::uniform(1, 8, 1 << 18, 64);
    let rs = simulate(&short, Strategy::StreamK, &arch);
    let rl = simulate(&long, Strategy::StreamK, &arch);
    // absolute reduce time must not blow up with 16x context
    assert!(
        rl.reduce_us <= rs.reduce_us * 4.0 + 5.0,
        "reduce grew {} -> {}",
        rs.reduce_us,
        rl.reduce_us
    );
}
