//! Parallel-sampling properties that need no artifacts:
//!
//! 1. **Oracle replay** — a recorded decode trace (raw logits per step)
//!    resampled through `sampling::sample_token` with the same seed
//!    reproduces the identical token and logprob sequence; the logprob
//!    of every sampled token is a valid log-probability of the processed
//!    distribution.
//! 2. **Degenerate-group invariant** — a cascade plan whose groups are
//!    all single-member is *structurally* the flat plan: identical
//!    rolled tasks and bit-identical `lean_cascade_host` output versus
//!    the flat lean host twin, across randomized shapes.
//! 3. **Fork-family storage** — random fork/append/free interleavings
//!    on the paged cache keep refcounts exact and never copy at fork.

use lean_attention::coordinator::PagedKvCache;
use lean_attention::partition::cascade::{
    build_cascade_plan, CascadeProblem, CascadeTensors, PrefixGroup,
};
use lean_attention::runtime::attention_exec::{
    lean_cascade_host, roll_cascade_tasks, rolled_kv_bytes,
};
use lean_attention::sampling::{sample_token, seq_rng, SampledToken, SamplingParams};
use lean_attention::util::rng::Rng;
use lean_attention::util::testing::prop_check;

fn random_params(rng: &mut Rng) -> SamplingParams {
    SamplingParams {
        temperature: *rng.choose(&[0.0f32, 0.5, 0.8, 1.0, 1.5]),
        top_k: *rng.choose(&[0usize, 1, 3, 8]),
        top_p: *rng.choose(&[1.0f32, 0.95, 0.7, 0.3]),
        repetition_penalty: *rng.choose(&[1.0f32, 1.1, 1.5]),
    }
}

#[test]
fn sampled_traces_replay_exactly_through_the_oracle() {
    prop_check("logprob trace replays bit-exactly", 50, |rng| {
        let vocab = rng.urange(4, 40);
        let steps = rng.urange(1, 16);
        let params = random_params(rng);
        params.validate().map_err(|e| e.to_string())?;
        let seed = rng.next_u64();
        let id = rng.next_u64();

        // "Serve": sample a trace from per-step random logits.
        let logits: Vec<Vec<f32>> =
            (0..steps).map(|_| rng.normal_vec(vocab)).collect();
        let mut history: Vec<i32> =
            (0..rng.urange(1, 8)).map(|_| rng.urange(0, vocab) as i32).collect();
        let prompt = history.clone();
        let mut served: Vec<SampledToken> = Vec::new();
        let mut srng = seq_rng(seed, id);
        for l in &logits {
            let s = sample_token(l, &history, &params, &mut srng);
            if !(0..vocab as i32).contains(&s.token) {
                return Err(format!("token {} outside vocab {vocab}", s.token));
            }
            if !(s.logprob <= 1e-6 && s.logprob.is_finite()) {
                return Err(format!("invalid logprob {}", s.logprob));
            }
            history.push(s.token);
            served.push(s);
        }

        // "Verify": the exact host oracle replays the identical trace
        // from the recorded raw logits and the same (seed, id).
        let mut replay_hist = prompt;
        let mut orng = seq_rng(seed, id);
        for (l, want) in logits.iter().zip(&served) {
            let got = sample_token(l, &replay_hist, &params, &mut orng);
            if got != *want {
                return Err(format!("replay diverged: {got:?} vs {want:?}"));
            }
            replay_hist.push(got.token);
        }
        Ok(())
    });
}

#[test]
fn greedy_trace_is_temperature_zero_of_the_same_pipeline() {
    // Greedy is the same oracle at temperature 0 — no RNG consumption,
    // so the trace is independent of the seed entirely.
    prop_check("greedy ignores the seed", 30, |rng| {
        let vocab = rng.urange(3, 20);
        let logits = rng.normal_vec(vocab);
        let params = SamplingParams {
            repetition_penalty: *rng.choose(&[1.0f32, 1.3]),
            ..SamplingParams::greedy()
        };
        let hist = [0i32, 1];
        let a = sample_token(&logits, &hist, &params, &mut Rng::new(1));
        let b = sample_token(&logits, &hist, &params, &mut Rng::new(999));
        if a != b {
            return Err(format!("greedy diverged across seeds: {a:?} vs {b:?}"));
        }
        Ok(())
    });
}

/// Random flat decode shapes for the degenerate-group invariant.
fn random_shape(rng: &mut Rng) -> (usize, Vec<u32>, usize, usize) {
    let batch = rng.urange(1, 6);
    let heads = rng.urange(1, 4);
    let d = *rng.choose(&[16usize, 32]);
    let tile = *rng.choose(&[16usize, 32, 64]);
    let lens: Vec<u32> = (0..batch).map(|_| rng.range(1, 300) as u32).collect();
    (heads, lens, d, tile)
}

#[test]
fn all_singleton_groups_are_bit_identical_to_the_flat_lean_path() {
    // The satellite invariant: single-member "groups" must not change
    // the computation at all. `CascadeProblem::new` dissolves them, so
    // the segment problem, the stream-K plan, the rolled tasks and the
    // executed output are all *identical* — not merely close — to the
    // flat lean host twin.
    prop_check("degenerate cascade == flat, bitwise", 40, |rng| {
        let (heads, lens, d, tile) = random_shape(rng);
        // Every sequence gets its own singleton group with a random
        // prefix cut.
        let groups: Vec<PrefixGroup> = lens
            .iter()
            .enumerate()
            .map(|(i, &ctx)| PrefixGroup {
                prefix_len: rng.range(1, u64::from(ctx) + 1) as u32,
                members: vec![i as u32],
            })
            .collect();
        let grouped = CascadeProblem::new(heads, lens.clone(), d, groups)
            .map_err(|e| e.to_string())?
            .with_tile(tile);
        let flat = CascadeProblem::new(heads, lens, d, Vec::new())
            .map_err(|e| e.to_string())?
            .with_tile(tile);
        if !grouped.prefix_groups.is_empty() {
            return Err("singleton groups survived construction".into());
        }

        let slots = rng.urange(1, 64);
        let cp_g = build_cascade_plan(&grouped, slots);
        let cp_f = build_cascade_plan(&flat, slots);
        cp_g.plan
            .validate(&cp_g.segment_problem)
            .map_err(|e| e.to_string())?;

        let tasks_g = roll_cascade_tasks(&grouped, &cp_g);
        let tasks_f = roll_cascade_tasks(&flat, &cp_f);
        if tasks_g != tasks_f {
            return Err(format!(
                "rolled tasks differ: {} vs {} tasks",
                tasks_g.len(),
                tasks_f.len()
            ));
        }
        if rolled_kv_bytes(&tasks_g, d) != rolled_kv_bytes(&tasks_f, d) {
            return Err("gathered-KV bytes differ".into());
        }

        // Identical tensor draws (both problems have zero groups, so the
        // RNG consumption sequence matches), identical batching, and the
        // outputs must be bit-identical — same ops in the same order.
        let tseed = rng.next_u64();
        let t_g = CascadeTensors::random(&grouped, tseed);
        let t_f = CascadeTensors::random(&flat, tseed);
        let batch_rows = rng.urange(1, 17);
        let (o_g, lse_g) = lean_cascade_host(&grouped, &t_g, &cp_g, batch_rows);
        let (o_f, lse_f) = lean_cascade_host(&flat, &t_f, &cp_f, batch_rows);
        if o_g != o_f {
            return Err("outputs are not bit-identical".into());
        }
        if lse_g != lse_f {
            return Err("LSEs are not bit-identical".into());
        }
        Ok(())
    });
}

#[test]
fn fork_families_keep_refcounts_exact_under_random_interleavings() {
    prop_check("fork/append/free refcount invariants", 40, |rng| {
        const PAGE_TOKENS: usize = 4;
        const PAGES: usize = 32;
        let mut cache = PagedKvCache::new(1, 1, 2, PAGE_TOKENS, PAGES);
        let mut active: Vec<u64> = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..80 {
            match rng.urange(0, 4) {
                0 => {
                    let len = rng.urange(1, 3 * PAGE_TOKENS);
                    let n = len * 2;
                    let (k, v) = (rng.normal_vec(n), rng.normal_vec(n));
                    if cache.insert_seq(next_id, &k, &v, len).is_ok() {
                        active.push(next_id);
                    }
                    next_id += 1;
                }
                1 if !active.is_empty() => {
                    let parent = *rng.choose(&active);
                    let free_before = cache.free_pages();
                    if cache.fork_seq(parent, next_id).is_ok() {
                        if cache.free_pages() != free_before {
                            return Err("fork allocated pages".into());
                        }
                        if cache.seq_len(next_id) != cache.seq_len(parent) {
                            return Err("fork length mismatch".into());
                        }
                        active.push(next_id);
                    }
                    next_id += 1;
                }
                2 if !active.is_empty() => {
                    let id = *rng.choose(&active);
                    let (k, v) = (rng.normal_vec(2), rng.normal_vec(2));
                    let _ = cache.append_token(id, &k, &v);
                }
                3 if !active.is_empty() => {
                    let i = rng.urange(0, active.len());
                    let id = active.swap_remove(i);
                    cache.free_seq(id);
                }
                _ => {}
            }
            // Shadow refcounts: one per holding sequence per page.
            let mut refs = vec![0u32; PAGES];
            for &id in &active {
                for &p in cache.seq_pages(id).unwrap() {
                    refs[p] += 1;
                }
            }
            for (p, &want) in refs.iter().enumerate() {
                if cache.page_ref(p) != want {
                    return Err(format!(
                        "page {p}: refcount {} vs shadow {want}",
                        cache.page_ref(p)
                    ));
                }
            }
            let live = refs.iter().filter(|&&r| r > 0).count();
            if cache.used_pages() != live {
                return Err("leak or phantom page".into());
            }
        }
        for id in active.drain(..) {
            cache.free_seq(id);
        }
        if cache.free_pages() != PAGES {
            return Err("fork family leaked pages".into());
        }
        Ok(())
    });
}
