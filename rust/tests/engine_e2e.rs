//! End-to-end engine integration: continuous batching over the PJRT
//! artifacts, paged cache, sampling, router. Self-skips without artifacts.

use std::path::Path;
use std::rc::Rc;

use lean_attention::coordinator::request::FinishReason;
use lean_attention::coordinator::{Engine, EngineConfig, Router};
use lean_attention::runtime::{Manifest, Runtime};
use lean_attention::util::rng::Rng;

fn setup() -> Option<(Rc<Runtime>, Manifest)> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some((
        Rc::new(Runtime::cpu().expect("pjrt")),
        Manifest::load(dir).expect("manifest"),
    ))
}

fn engine(rt: &Rc<Runtime>, m: &Manifest) -> Engine {
    Engine::new(rt, m, EngineConfig::default()).expect("engine")
}

fn random_prompt(rng: &mut Rng, vocab: usize, len: usize) -> Vec<i32> {
    (0..len).map(|_| rng.range(0, vocab as u64) as i32).collect()
}

#[test]
fn single_request_completes() {
    let Some((rt, m)) = setup() else { return };
    let mut e = engine(&rt, &m);
    let mut rng = Rng::new(1);
    let vocab = 512;
    let id = e.submit(random_prompt(&mut rng, vocab, 10), 8).unwrap();
    let fin = e.run_until_idle().expect("run");
    assert_eq!(fin.len(), 1);
    assert_eq!(fin[0].id, id);
    assert_eq!(fin[0].output.len(), 8);
    assert_eq!(fin[0].reason, FinishReason::Length);
    assert!(fin[0].output.iter().all(|&t| t >= 0 && (t as usize) < vocab));
    assert!(e.metrics.decode_steps >= 7);
}

#[test]
fn generation_is_deterministic() {
    let Some((rt, m)) = setup() else { return };
    let prompt: Vec<i32> = vec![5, 17, 333, 7, 42];
    let gen = |rt: &Rc<Runtime>, m: &Manifest| {
        let mut e = engine(rt, m);
        e.submit(prompt.clone(), 12).unwrap();
        e.run_until_idle().unwrap().remove(0).output
    };
    assert_eq!(gen(&rt, &m), gen(&rt, &m));
}

#[test]
fn continuous_batching_many_requests() {
    // More requests than slots: the batcher must cycle them all through.
    let Some((rt, m)) = setup() else { return };
    let mut e = engine(&rt, &m);
    let slots = e.batch_size();
    let mut rng = Rng::new(3);
    let n_req = slots * 3 + 1;
    let mut ids = Vec::new();
    for _ in 0..n_req {
        let len = rng.urange(1, e.prefill_bucket() + 1);
        let max_new = rng.urange(1, 6);
        ids.push(e.submit(random_prompt(&mut rng, 512, len), max_new).unwrap());
    }
    let fin = e.run_until_idle().expect("run");
    assert_eq!(fin.len(), n_req);
    let mut got: Vec<_> = fin.iter().map(|f| f.id).collect();
    got.sort();
    assert_eq!(got, ids);
    assert!(e.metrics.prefill_calls >= 3, "multiple admission waves");
    // all pages returned
    assert_eq!(e.active(), 0);
}

#[test]
fn varied_prompt_lengths_ragged_batch() {
    let Some((rt, m)) = setup() else { return };
    let mut e = engine(&rt, &m);
    let mut rng = Rng::new(4);
    let p = e.prefill_bucket();
    for len in [1usize, p / 3, p] {
        e.submit(random_prompt(&mut rng, 512, len.max(1)), 4).unwrap();
    }
    let fin = e.run_until_idle().expect("run");
    assert_eq!(fin.len(), 3);
    for f in &fin {
        assert_eq!(f.output.len(), 4);
    }
    // ragged projection was recorded
    assert!(!e.metrics.projected_lean_us.is_empty());
    assert!(e.metrics.projected_speedup().unwrap() >= 0.9);
}

#[test]
fn generation_budget_is_exact() {
    // Regression: the Length check used to run only in decode (after the
    // push), so a one-token budget emitted two tokens. The budget must be
    // exact for small and large values, and zero is rejected at submit.
    let Some((rt, m)) = setup() else { return };
    let mut e = engine(&rt, &m);
    for max_new in [1usize, 2, 16] {
        let id = e.submit(vec![1, 2, 3], max_new).unwrap();
        let fin = e.run_until_idle().expect("run");
        assert_eq!(fin.len(), 1, "budget {max_new}");
        assert_eq!(fin[0].id, id);
        assert_eq!(
            fin[0].output.len(),
            max_new,
            "budget {max_new} must emit exactly {max_new} tokens"
        );
        assert_eq!(fin[0].reason, FinishReason::Length);
        assert_eq!(e.active(), 0);
    }
    assert!(
        e.submit(vec![1, 2, 3], 0).is_err(),
        "max_new_tokens = 0 has no contract and is rejected"
    );
    // One-token budgets release their whole reservation: a fresh burst of
    // them cannot exhaust the page pool.
    for _ in 0..8 {
        e.submit(vec![7, 8, 9, 10], 1).unwrap();
    }
    let fin = e.run_until_idle().expect("run burst");
    assert_eq!(fin.len(), 8);
    assert!(fin.iter().all(|f| f.output.len() == 1));
}

#[test]
fn cascade_gather_dedups_shared_decode_steps() {
    // Decode steps whose lanes physically share a prefix run must take
    // the deduplicated gather and record the measured saving.
    let Some((rt, m)) = setup() else { return };
    let mut e = engine(&rt, &m);
    if e.batch_size() < 2 {
        eprintln!("skipping: batch size 1 cannot co-schedule sharers");
        return;
    }
    if e.prefill_bucket() < 16 + 2 {
        eprintln!("skipping: prefill bucket too small for a shared page");
        return;
    }
    // Warm the index with one full page of system prompt.
    let system: Vec<i32> = (0..16).map(|t| (t * 5 + 1) % 512).collect();
    let mut first = system.clone();
    first.extend([40, 41]);
    e.submit(first, 2).unwrap();
    e.run_until_idle().expect("warm");
    assert_eq!(e.metrics.cascade_gather_steps, 0, "solo run stays flat");

    // Two sharers decode together: their leading page run is physical.
    for tail in 0..2i32 {
        let mut prompt = system.clone();
        prompt.extend([50 + tail, 60 + tail]);
        e.submit(prompt, 6).unwrap();
    }
    e.run_until_idle().expect("shared");
    assert!(
        e.metrics.cascade_gather_steps > 0,
        "shared steps must take the cascade gather: {:?}",
        e.metrics.cascade_gather_steps
    );
    assert!(
        e.metrics.gather_bytes_shared < e.metrics.gather_bytes_flat,
        "dedup must be measured: {} vs {}",
        e.metrics.gather_bytes_shared,
        e.metrics.gather_bytes_flat
    );
    let rep = e.metrics.report();
    assert!(rep.contains("cascade gather"), "{rep}");
}

#[test]
fn context_full_terminates_gracefully() {
    let Some((rt, m)) = setup() else { return };
    let mut e = engine(&rt, &m);
    let ctx = e.ctx_bucket();
    let p = e.prefill_bucket();
    // Ask for more tokens than the context can hold.
    e.submit(vec![1; p], ctx).unwrap();
    let fin = e.run_until_idle().expect("run");
    assert_eq!(fin.len(), 1);
    assert_eq!(fin[0].reason, FinishReason::ContextFull);
    assert!(fin[0].output.len() < ctx);
}

#[test]
fn prompt_validation() {
    let Some((rt, m)) = setup() else { return };
    let mut e = engine(&rt, &m);
    assert!(e.submit(vec![], 4).is_err());
    assert!(e.submit(vec![0; e.prefill_bucket() + 1], 4).is_err());
    assert!(e.submit(vec![-1], 4).is_err());
    assert!(e.submit(vec![1_000_000], 4).is_err());
}

#[test]
fn router_least_loaded_across_replicas() {
    let Some((rt, m)) = setup() else { return };
    let e1 = engine(&rt, &m);
    let e2 = engine(&rt, &m);
    let mut router = Router::new(vec![e1, e2]);
    let mut rng = Rng::new(5);
    let mut ids = Vec::new();
    for _ in 0..6 {
        ids.push(router.submit(random_prompt(&mut rng, 512, 8), 3).unwrap());
    }
    // both replicas should have received work
    assert!(router.engines().iter().all(|e| !e.is_idle()));
    let fin = router.run_until_idle().expect("run");
    assert_eq!(fin.len(), 6);
    let mut got: Vec<_> = fin.iter().map(|f| f.id).collect();
    got.sort();
    assert_eq!(got, ids);
}

#[test]
fn cache_pressure_queues_and_recovers() {
    // A cache too small for two concurrent sequences must serialize them
    // via admission control, not fail.
    let Some((rt, m)) = setup() else { return };
    let mut e = Engine::new(
        &rt,
        &m,
        EngineConfig {
            model: "tiny".into(),
            cache_pages: 4, // 4 pages x 16 tokens = 64 tokens of KV budget
            page_tokens: 16,
            project_hardware: false,
            ..EngineConfig::default()
        },
    )
    .expect("engine");
    let mut rng = Rng::new(7);
    // each request needs ceil((prompt 30 + 16 new)/16) = 3 pages
    let ids: Vec<_> = (0..3)
        .map(|_| e.submit(random_prompt(&mut rng, 512, 30), 16).unwrap())
        .collect();
    let fin = e.run_until_idle().expect("run");
    assert_eq!(fin.len(), 3);
    let mut got: Vec<_> = fin.iter().map(|f| f.id).collect();
    got.sort();
    assert_eq!(got, ids);
    // admission happened in separate waves (at most one resident at a time)
    assert!(e.metrics.prefill_calls >= 3, "serialized admissions");
}

#[test]
fn oversubscribed_generation_budget_respects_cache() {
    // Generation budget larger than remaining cache must finish with
    // ContextFull rather than corrupt state; pages are all returned.
    let Some((rt, m)) = setup() else { return };
    let mut e = Engine::new(
        &rt,
        &m,
        EngineConfig {
            model: "tiny".into(),
            cache_pages: 64,
            page_tokens: 16,
            project_hardware: false,
            ..EngineConfig::default()
        },
    )
    .expect("engine");
    let p = e.prefill_bucket();
    e.submit(vec![3; p], e.ctx_bucket() * 2).unwrap();
    let fin = e.run_until_idle().expect("run");
    assert_eq!(fin.len(), 1);
    assert_eq!(fin[0].reason, FinishReason::ContextFull);
    assert_eq!(e.active(), 0);
}

#[test]
fn shared_prefix_prompts_hit_the_radix_cache() {
    let Some((rt, m)) = setup() else { return };
    let mut e = engine(&rt, &m);
    if e.prefill_bucket() < 16 + 2 {
        eprintln!("skipping: prefill bucket too small for a full shared page");
        return;
    }
    // One full page (16 tokens) of shared system prompt + distinct tails.
    let system: Vec<i32> = (0..16).map(|t| (t * 7 + 3) % 512).collect();
    let pages_before = e.prefix_index_pages();
    // First request registers the system prompt's page in the index.
    let mut first = system.clone();
    first.extend([100, 200]);
    e.submit(first, 3).unwrap();
    let fin = e.run_until_idle().expect("run");
    assert_eq!(fin.len(), 1);
    assert_eq!(e.metrics.prefix.hits, 0, "cold start cannot hit");
    // Later requests sharing the prefix must hit it.
    for tail in 1..3i32 {
        let mut prompt = system.clone();
        prompt.extend([100 + tail, 200 + tail]);
        e.submit(prompt, 3).unwrap();
    }
    let fin = e.run_until_idle().expect("run");
    assert_eq!(fin.len(), 2);
    assert!(e.metrics.prefix.lookups >= 3);
    assert_eq!(
        e.metrics.prefix.hits, 2,
        "both warm prompts hit: {:?}",
        e.metrics.prefix
    );
    assert!(e.metrics.prefix.hit_rate() > 0.0);
    assert!(e.metrics.prefix.kv_bytes_deduped > 0);
    assert!(e.prefix_index_pages() > pages_before);
    // All request-held pages were returned; only index pages remain.
    assert_eq!(e.active(), 0);
    let rep = e.metrics.report();
    assert!(rep.contains("prefix cache"), "{rep}");
}

#[test]
fn prefix_cache_disabled_takes_the_plain_path() {
    let Some((rt, m)) = setup() else { return };
    let mut e = Engine::new(
        &rt,
        &m,
        EngineConfig { enable_prefix_cache: false, ..EngineConfig::default() },
    )
    .expect("engine");
    let prompt: Vec<i32> = (0..20).map(|t| t % 512).collect();
    e.submit(prompt.clone(), 2).unwrap();
    e.submit(prompt, 2).unwrap();
    let fin = e.run_until_idle().expect("run");
    assert_eq!(fin.len(), 2);
    assert_eq!(e.metrics.prefix.lookups, 0);
    assert_eq!(e.prefix_index_pages(), 0);
}

#[test]
fn metrics_populated() {
    let Some((rt, m)) = setup() else { return };
    let mut e = engine(&rt, &m);
    e.submit(vec![1, 2, 3], 5).unwrap();
    e.run_until_idle().unwrap();
    let rep = e.metrics.report();
    assert!(rep.contains("finished=1"), "{rep}");
    assert!(e.metrics.decode_tps() > 0.0);
    assert!(e.metrics.step_summary().is_some());
}
