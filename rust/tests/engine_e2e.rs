//! End-to-end engine integration: continuous batching over the PJRT
//! artifacts, paged cache, sampling, fork/parallel-sampling lifecycle,
//! router. Self-skips without artifacts.

use std::path::Path;
use std::rc::Rc;

use lean_attention::coordinator::request::FinishReason;
use lean_attention::coordinator::{AuditPlan, Engine, EngineConfig, Router};
use lean_attention::obs::validate_bundle;
use lean_attention::runtime::{Manifest, Runtime};
use lean_attention::sampling::{BeamSearch, BestOfN, SamplingParams};
use lean_attention::sparse::SparsePolicy;
use lean_attention::util::rng::Rng;

fn setup() -> Option<(Rc<Runtime>, Manifest)> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some((
        Rc::new(Runtime::cpu().expect("pjrt")),
        Manifest::load(dir).expect("manifest"),
    ))
}

fn engine(rt: &Rc<Runtime>, m: &Manifest) -> Engine {
    Engine::new(rt, m, EngineConfig::default()).expect("engine")
}

fn random_prompt(rng: &mut Rng, vocab: usize, len: usize) -> Vec<i32> {
    (0..len).map(|_| rng.range(0, vocab as u64) as i32).collect()
}

#[test]
fn single_request_completes() {
    let Some((rt, m)) = setup() else { return };
    let mut e = engine(&rt, &m);
    let mut rng = Rng::new(1);
    let vocab = 512;
    let id = e.submit(random_prompt(&mut rng, vocab, 10), 8).unwrap();
    let fin = e.run_until_idle().expect("run");
    assert_eq!(fin.len(), 1);
    assert_eq!(fin[0].id, id);
    assert_eq!(fin[0].output.len(), 8);
    assert_eq!(fin[0].reason, FinishReason::Length);
    assert!(fin[0].output.iter().all(|&t| t >= 0 && (t as usize) < vocab));
    assert!(e.metrics.decode_steps >= 7);
}

#[test]
fn generation_is_deterministic() {
    let Some((rt, m)) = setup() else { return };
    let prompt: Vec<i32> = vec![5, 17, 333, 7, 42];
    let gen = |rt: &Rc<Runtime>, m: &Manifest| {
        let mut e = engine(rt, m);
        e.submit(prompt.clone(), 12).unwrap();
        e.run_until_idle().unwrap().remove(0).output
    };
    assert_eq!(gen(&rt, &m), gen(&rt, &m));
}

#[test]
fn continuous_batching_many_requests() {
    // More requests than slots: the batcher must cycle them all through.
    let Some((rt, m)) = setup() else { return };
    let mut e = engine(&rt, &m);
    let slots = e.batch_size();
    let mut rng = Rng::new(3);
    let n_req = slots * 3 + 1;
    let mut ids = Vec::new();
    for _ in 0..n_req {
        let len = rng.urange(1, e.prefill_bucket() + 1);
        let max_new = rng.urange(1, 6);
        ids.push(e.submit(random_prompt(&mut rng, 512, len), max_new).unwrap());
    }
    let fin = e.run_until_idle().expect("run");
    assert_eq!(fin.len(), n_req);
    let mut got: Vec<_> = fin.iter().map(|f| f.id).collect();
    got.sort();
    assert_eq!(got, ids);
    assert!(e.metrics.prefill_calls >= 3, "multiple admission waves");
    // all pages returned
    assert_eq!(e.active(), 0);
}

#[test]
fn varied_prompt_lengths_ragged_batch() {
    let Some((rt, m)) = setup() else { return };
    let mut e = engine(&rt, &m);
    let mut rng = Rng::new(4);
    let p = e.prefill_bucket();
    for len in [1usize, p / 3, p] {
        e.submit(random_prompt(&mut rng, 512, len.max(1)), 4).unwrap();
    }
    let fin = e.run_until_idle().expect("run");
    assert_eq!(fin.len(), 3);
    for f in &fin {
        assert_eq!(f.output.len(), 4);
    }
    // ragged projection was recorded
    assert!(!e.metrics.projected_lean_us.is_empty());
    assert!(e.metrics.projected_speedup().unwrap() >= 0.9);
}

#[test]
fn generation_budget_is_exact() {
    // Regression: the Length check used to run only in decode (after the
    // push), so a one-token budget emitted two tokens. The budget must be
    // exact for small and large values, and zero is rejected at submit.
    let Some((rt, m)) = setup() else { return };
    let mut e = engine(&rt, &m);
    for max_new in [1usize, 2, 16] {
        let id = e.submit(vec![1, 2, 3], max_new).unwrap();
        let fin = e.run_until_idle().expect("run");
        assert_eq!(fin.len(), 1, "budget {max_new}");
        assert_eq!(fin[0].id, id);
        assert_eq!(
            fin[0].output.len(),
            max_new,
            "budget {max_new} must emit exactly {max_new} tokens"
        );
        assert_eq!(fin[0].reason, FinishReason::Length);
        assert_eq!(e.active(), 0);
    }
    assert!(
        e.submit(vec![1, 2, 3], 0).is_err(),
        "max_new_tokens = 0 has no contract and is rejected"
    );
    // One-token budgets release their whole reservation: a fresh burst of
    // them cannot exhaust the page pool.
    for _ in 0..8 {
        e.submit(vec![7, 8, 9, 10], 1).unwrap();
    }
    let fin = e.run_until_idle().expect("run burst");
    assert_eq!(fin.len(), 8);
    assert!(fin.iter().all(|f| f.output.len() == 1));
}

#[test]
fn cascade_gather_dedups_shared_decode_steps() {
    // Decode steps whose lanes physically share a prefix run must take
    // the deduplicated gather and record the measured saving.
    let Some((rt, m)) = setup() else { return };
    let mut e = engine(&rt, &m);
    if e.batch_size() < 2 {
        eprintln!("skipping: batch size 1 cannot co-schedule sharers");
        return;
    }
    if e.prefill_bucket() < 16 + 2 {
        eprintln!("skipping: prefill bucket too small for a shared page");
        return;
    }
    // Warm the index with one full page of system prompt.
    let system: Vec<i32> = (0..16).map(|t| (t * 5 + 1) % 512).collect();
    let mut first = system.clone();
    first.extend([40, 41]);
    e.submit(first, 2).unwrap();
    e.run_until_idle().expect("warm");
    assert_eq!(e.metrics.cascade_gather_steps, 0, "solo run stays flat");

    // Two sharers decode together: their leading page run is physical.
    for tail in 0..2i32 {
        let mut prompt = system.clone();
        prompt.extend([50 + tail, 60 + tail]);
        e.submit(prompt, 6).unwrap();
    }
    e.run_until_idle().expect("shared");
    assert!(
        e.metrics.cascade_gather_steps > 0,
        "shared steps must take the cascade gather: {:?}",
        e.metrics.cascade_gather_steps
    );
    assert!(
        e.metrics.gather_bytes_shared < e.metrics.gather_bytes_flat,
        "dedup must be measured: {} vs {}",
        e.metrics.gather_bytes_shared,
        e.metrics.gather_bytes_flat
    );
    let rep = e.metrics.report();
    assert!(rep.contains("cascade gather"), "{rep}");
}

#[test]
fn fork_with_partial_page_cows_exactly_once_per_sibling() {
    // Fork mid-page, then diverge: the shared partial last page must be
    // copy-on-write cloned exactly once per sibling (the last holder
    // writes in place), and the fork itself must allocate zero pages.
    let Some((rt, m)) = setup() else { return };
    let mut e = engine(&rt, &m);
    let siblings = 2usize;
    if e.batch_size() < siblings + 1 {
        eprintln!("skipping: batch too small for a fork family");
        return;
    }
    let pt = e.config.page_tokens;
    // After one step the cache holds prompt + 1 tokens; choose the
    // prompt so that lands mid-page.
    let prompt_len = (pt / 2).max(1);
    assert!((prompt_len + 1) % pt != 0, "fork point must be mid-page");
    let parent = e.submit(random_prompt(&mut Rng::new(2), 512, prompt_len), 6).unwrap();
    e.step().expect("admit + first decode");
    assert_eq!(e.metrics.prefix.cow_copies, 0, "no sharing yet");

    let used_before = e.kv_used_pages();
    let kids = e.fork(parent, siblings).expect("fork");
    assert_eq!(kids.len(), siblings);
    assert_eq!(
        e.kv_used_pages(),
        used_before,
        "fork must allocate zero pages (refcount-only)"
    );
    assert_eq!(e.metrics.sampling.fork_calls, 1);
    assert_eq!(e.metrics.sampling.forked_siblings, siblings);

    let fin = e.run_until_idle().expect("family decode");
    assert_eq!(fin.len(), siblings + 1);
    assert_eq!(
        e.metrics.prefix.cow_copies, siblings,
        "one COW clone per sibling with a partial last page"
    );
    for f in &fin {
        assert_eq!(f.output.len(), 6);
        assert_eq!(f.logprobs.len(), f.output.len());
        let sum: f64 = f.logprobs.iter().map(|&x| f64::from(x)).sum();
        assert!((f.cum_logprob - sum).abs() < 1e-6);
        if kids.contains(&f.id) {
            assert_eq!(f.parent, Some(parent), "lineage surfaces on finish");
        }
    }
    assert_eq!(e.active(), 0);
}

#[test]
fn fork_on_page_boundary_never_cows_and_joins_a_cascade_group() {
    // Fork exactly at a page boundary: zero COW copies, and the family's
    // shared full-page history makes the decode steps take the cascade
    // (deduplicated) gather.
    let Some((rt, m)) = setup() else { return };
    let mut e = engine(&rt, &m);
    let siblings = 2usize;
    let pt = e.config.page_tokens;
    if e.batch_size() < siblings + 1 || e.prefill_bucket() < pt {
        eprintln!("skipping: engine too small for an aligned fork family");
        return;
    }
    // prompt + 1 sampled token == exactly one full page at the fork.
    let prompt_len = pt - 1;
    let parent = e.submit(random_prompt(&mut Rng::new(3), 512, prompt_len), 8).unwrap();
    e.step().expect("admit + first decode");

    let used_before = e.kv_used_pages();
    e.fork(parent, siblings).expect("fork");
    assert_eq!(e.kv_used_pages(), used_before, "zero page copies at fork");

    e.run_until_idle().expect("family decode");
    assert_eq!(
        e.metrics.prefix.cow_copies, 0,
        "page-boundary fork must never copy"
    );
    assert!(
        e.metrics.cascade_gather_steps > 0,
        "fork siblings must decode as a cascade group"
    );
    assert!(
        e.metrics.gather_bytes_shared < e.metrics.gather_bytes_flat,
        "sibling-cascade decode reads fewer gathered-KV bytes: {} vs {}",
        e.metrics.gather_bytes_shared,
        e.metrics.gather_bytes_flat
    );
    let rep = e.metrics.report();
    assert!(rep.contains("parallel sampling"), "{rep}");
}

#[test]
fn fork_requires_live_sequence_and_free_slots() {
    let Some((rt, m)) = setup() else { return };
    let mut e = engine(&rt, &m);
    assert!(e.fork(42, 1).is_err(), "unknown sequence");
    let id = e.submit(vec![1, 2, 3], 4).unwrap();
    assert!(e.fork(id, 1).is_err(), "queued but not yet active");
    e.step().expect("admit");
    let free = e.free_slots();
    assert!(e.fork(id, free + 1).is_err(), "more siblings than slots");
    e.run_until_idle().expect("drain");
}

#[test]
fn best_of_n_is_deterministic_and_ranked() {
    let Some((rt, m)) = setup() else { return };
    let params = SamplingParams {
        temperature: 0.7,
        top_k: 0,
        top_p: 1.0,
        repetition_penalty: 1.0,
    };
    let n = 3usize;
    let run = |rt: &Rc<Runtime>, m: &Manifest| {
        let mut e = engine(rt, m);
        if e.batch_size() < n {
            return None;
        }
        let ctl = BestOfN { n, max_new: 6, params: params.clone() };
        let out = ctl.run(&mut e, vec![5, 17, 333, 7, 42]).expect("best-of-n");
        Some(
            out.candidates
                .iter()
                .map(|c| (c.finished.id, c.finished.output.clone(), c.score))
                .collect::<Vec<_>>(),
        )
    };
    let Some(a) = run(&rt, &m) else {
        eprintln!("skipping: batch too small for best-of-3");
        return;
    };
    let b = run(&rt, &m).unwrap();
    assert_eq!(a, b, "fixed seed must reproduce candidates bit-exactly");
    assert_eq!(a.len(), n);
    for w in a.windows(2) {
        assert!(w[0].2 >= w[1].2, "candidates sorted by score desc");
    }
    for (_, output, _) in &a {
        assert_eq!(output.len(), 6);
    }
}

#[test]
fn beam_search_prunes_deterministically() {
    let Some((rt, m)) = setup() else { return };
    let params = SamplingParams {
        temperature: 0.9,
        top_k: 0,
        top_p: 1.0,
        repetition_penalty: 1.0,
    };
    let run = |rt: &Rc<Runtime>, m: &Manifest| {
        let mut e = engine(rt, m);
        if e.batch_size() < 4 {
            return None;
        }
        let ctl = BeamSearch { width: 2, expand: 2, max_new: 5, params: params.clone() };
        let out = ctl.run(&mut e, vec![9, 8, 7]).expect("beam");
        Some((
            out.candidates
                .iter()
                .map(|c| (c.finished.id, c.finished.output.clone(), c.score))
                .collect::<Vec<_>>(),
            e.metrics.sampling.cancelled,
        ))
    };
    let Some((a, cancelled_a)) = run(&rt, &m) else {
        eprintln!("skipping: batch too small for beam search");
        return;
    };
    let (b, _) = run(&rt, &m).unwrap();
    assert_eq!(a, b, "beam search must reproduce under a fixed seed");
    assert!(cancelled_a > 0, "expansion must have pruned some hypotheses");
    // The winner is a completed generation, not a pruned stub.
    assert!(!a.is_empty());
    assert_eq!(a[0].1.len(), 5, "winner ran to its budget");
}

#[test]
fn stochastic_sampling_is_seed_deterministic_end_to_end() {
    let Some((rt, m)) = setup() else { return };
    let params = SamplingParams {
        temperature: 0.8,
        top_k: 8,
        top_p: 0.95,
        repetition_penalty: 1.1,
    };
    let gen = |rt: &Rc<Runtime>, m: &Manifest| {
        let mut e = engine(rt, m);
        e.submit_with(vec![5, 17, 333, 7, 42], 10, params.clone()).unwrap();
        let f = e.run_until_idle().unwrap().remove(0);
        (f.output, f.logprobs, f.cum_logprob)
    };
    let a = gen(&rt, &m);
    let b = gen(&rt, &m);
    assert_eq!(a, b, "same engine seed, same stochastic generation");
    assert_eq!(a.0.len(), 10);
    assert!(a.1.iter().all(|lp| lp.is_finite() && *lp <= 1e-6));
}

#[test]
fn context_full_terminates_gracefully() {
    let Some((rt, m)) = setup() else { return };
    let mut e = engine(&rt, &m);
    let ctx = e.ctx_bucket();
    let p = e.prefill_bucket();
    // Ask for more tokens than the context can hold.
    e.submit(vec![1; p], ctx).unwrap();
    let fin = e.run_until_idle().expect("run");
    assert_eq!(fin.len(), 1);
    assert_eq!(fin[0].reason, FinishReason::ContextFull);
    assert!(fin[0].output.len() < ctx);
}

#[test]
fn prompt_validation() {
    let Some((rt, m)) = setup() else { return };
    let mut e = engine(&rt, &m);
    assert!(e.submit(vec![], 4).is_err());
    assert!(e.submit(vec![0; e.prefill_bucket() + 1], 4).is_err());
    assert!(e.submit(vec![-1], 4).is_err());
    assert!(e.submit(vec![1_000_000], 4).is_err());
}

#[test]
fn router_spreads_cold_prompts_round_robin() {
    // Nothing is cached anywhere, so prefix routing ties at zero and
    // the round-robin tiebreak spreads load over both replicas.
    let Some((rt, m)) = setup() else { return };
    let e1 = engine(&rt, &m);
    let e2 = engine(&rt, &m);
    let mut router = Router::new(vec![e1, e2]);
    let mut rng = Rng::new(5);
    let mut ids = Vec::new();
    for _ in 0..6 {
        ids.push(router.submit(random_prompt(&mut rng, 512, 8), 3).unwrap());
    }
    // both replicas should have received work
    assert!(router.engines().iter().all(|e| !e.is_idle()));
    let fin = router.run_until_idle().expect("run");
    assert_eq!(fin.len(), 6);
    let mut got: Vec<_> = fin.iter().map(|f| f.id).collect();
    got.sort();
    assert_eq!(got, ids);
}

#[test]
fn router_colocates_same_prefix_requests_on_the_warm_replica() {
    let Some((rt, m)) = setup() else { return };
    let e1 = engine(&rt, &m);
    let page = e1.config.page_tokens;
    if e1.prefill_bucket() < page + 2 {
        eprintln!("skipping: prefill bucket too small for a full shared page");
        return;
    }
    let e2 = engine(&rt, &m);
    let mut router = Router::new(vec![e1, e2]);

    // Warm: the first (cold) submit round-robins to replica 0 and
    // registers the prefix page there.
    let system: Vec<i32> = (0..page as i32).map(|t| (t * 11 + 2) % 512).collect();
    let mut warm = system.clone();
    warm.extend([7, 8]);
    let warm_id = router.submit(warm, 2).unwrap();
    assert_eq!(router.route_of(warm_id), Some(0));
    router.run_until_idle().expect("warm");

    // Affinity: same-prefix requests all steer to replica 0 even while
    // the rr cursor keeps advancing.
    let mut affine_ids = Vec::new();
    for tail in 0..3i32 {
        let mut prompt = system.clone();
        prompt.extend([20 + tail, 30 + tail]);
        affine_ids.push(router.submit(prompt, 2).unwrap());
    }
    for &id in &affine_ids {
        assert_eq!(router.route_of(id), Some(0), "same-prefix requests colocate");
    }
    router.run_until_idle().expect("affine");
    assert_eq!(
        router.engines()[0].metrics.prefix.hits,
        3,
        "all three warm prompts hit replica 0's radix index"
    );
    assert_eq!(router.engines()[1].metrics.prefix.hits, 0);

    // Cold prompts still spread round-robin across the tie.
    let cold_a = router.submit(vec![400, 401, 402], 1).unwrap();
    let cold_b = router.submit(vec![410, 411, 412], 1).unwrap();
    assert_ne!(
        router.route_of(cold_a),
        router.route_of(cold_b),
        "cold ties alternate replicas"
    );
    router.run_until_idle().expect("drain");
}

#[test]
fn cache_pressure_queues_and_recovers() {
    // A cache too small for two concurrent sequences must serialize them
    // via admission control, not fail.
    let Some((rt, m)) = setup() else { return };
    let mut e = Engine::new(
        &rt,
        &m,
        EngineConfig {
            model: "tiny".into(),
            cache_pages: 4, // 4 pages x 16 tokens = 64 tokens of KV budget
            page_tokens: 16,
            project_hardware: false,
            ..EngineConfig::default()
        },
    )
    .expect("engine");
    let mut rng = Rng::new(7);
    // each request needs ceil((prompt 30 + 16 new)/16) = 3 pages
    let ids: Vec<_> = (0..3)
        .map(|_| e.submit(random_prompt(&mut rng, 512, 30), 16).unwrap())
        .collect();
    let fin = e.run_until_idle().expect("run");
    assert_eq!(fin.len(), 3);
    let mut got: Vec<_> = fin.iter().map(|f| f.id).collect();
    got.sort();
    assert_eq!(got, ids);
    // admission happened in separate waves (at most one resident at a time)
    assert!(e.metrics.prefill_calls >= 3, "serialized admissions");
}

#[test]
fn oversubscribed_generation_budget_respects_cache() {
    // Generation budget larger than remaining cache must finish with
    // ContextFull rather than corrupt state; pages are all returned.
    let Some((rt, m)) = setup() else { return };
    let mut e = Engine::new(
        &rt,
        &m,
        EngineConfig {
            model: "tiny".into(),
            cache_pages: 64,
            page_tokens: 16,
            project_hardware: false,
            ..EngineConfig::default()
        },
    )
    .expect("engine");
    let p = e.prefill_bucket();
    e.submit(vec![3; p], e.ctx_bucket() * 2).unwrap();
    let fin = e.run_until_idle().expect("run");
    assert_eq!(fin.len(), 1);
    assert_eq!(fin[0].reason, FinishReason::ContextFull);
    assert_eq!(e.active(), 0);
}

#[test]
fn shared_prefix_prompts_hit_the_radix_cache() {
    let Some((rt, m)) = setup() else { return };
    let mut e = engine(&rt, &m);
    if e.prefill_bucket() < 16 + 2 {
        eprintln!("skipping: prefill bucket too small for a full shared page");
        return;
    }
    // One full page (16 tokens) of shared system prompt + distinct tails.
    let system: Vec<i32> = (0..16).map(|t| (t * 7 + 3) % 512).collect();
    let pages_before = e.prefix_index_pages();
    // First request registers the system prompt's page in the index.
    let mut first = system.clone();
    first.extend([100, 200]);
    e.submit(first, 3).unwrap();
    let fin = e.run_until_idle().expect("run");
    assert_eq!(fin.len(), 1);
    assert_eq!(e.metrics.prefix.hits, 0, "cold start cannot hit");
    // Later requests sharing the prefix must hit it.
    for tail in 1..3i32 {
        let mut prompt = system.clone();
        prompt.extend([100 + tail, 200 + tail]);
        e.submit(prompt, 3).unwrap();
    }
    let fin = e.run_until_idle().expect("run");
    assert_eq!(fin.len(), 2);
    assert!(e.metrics.prefix.lookups >= 3);
    assert_eq!(
        e.metrics.prefix.hits, 2,
        "both warm prompts hit: {:?}",
        e.metrics.prefix
    );
    assert!(e.metrics.prefix.hit_rate() > 0.0);
    assert!(e.metrics.prefix.kv_bytes_deduped > 0);
    assert!(e.prefix_index_pages() > pages_before);
    // All request-held pages were returned; only index pages remain.
    assert_eq!(e.active(), 0);
    let rep = e.metrics.report();
    assert!(rep.contains("prefix cache"), "{rep}");
}

#[test]
fn prefix_cache_disabled_takes_the_plain_path() {
    let Some((rt, m)) = setup() else { return };
    let mut e = Engine::new(
        &rt,
        &m,
        EngineConfig { enable_prefix_cache: false, ..EngineConfig::default() },
    )
    .expect("engine");
    let prompt: Vec<i32> = (0..20).map(|t| t % 512).collect();
    e.submit(prompt.clone(), 2).unwrap();
    e.submit(prompt, 2).unwrap();
    let fin = e.run_until_idle().expect("run");
    assert_eq!(fin.len(), 2);
    assert_eq!(e.metrics.prefix.lookups, 0);
    assert_eq!(e.prefix_index_pages(), 0);
}

#[test]
fn metrics_populated() {
    let Some((rt, m)) = setup() else { return };
    let mut e = engine(&rt, &m);
    e.submit(vec![1, 2, 3], 5).unwrap();
    e.run_until_idle().unwrap();
    let rep = e.metrics.report();
    assert!(rep.contains("finished=1"), "{rep}");
    assert!(e.metrics.decode_tps() > 0.0);
    assert!(e.metrics.step_summary().is_some());
}

/// Speculative decoding end to end: the committed stream must be
/// bit-identical to plain decode, with fewer verify passes than tokens
/// on a repetitive (self-draftable) prompt, and no KV-page leaks from
/// the eager-append + rollback cycle. Requires artifacts built with a
/// verify step (older artifact sets self-skip).
#[test]
fn speculative_decode_matches_plain_stream_and_rolls_back() {
    let Some((rt, m)) = setup() else { return };
    let mut plain = engine(&rt, &m);
    let mut spec = Engine::new(
        &rt,
        &m,
        EngineConfig { spec_k: 3, ..EngineConfig::default() },
    )
    .expect("engine");
    if !spec.spec_enabled() {
        eprintln!("skipping: artifact set has no verify step");
        return;
    }

    // Repetitive prompt: the n-gram self-drafter's best case.
    let prompt: Vec<i32> = (0..24).map(|t| t % 6).collect();
    let max_new = 24;
    let a = plain.submit(prompt.clone(), max_new).unwrap();
    let b = spec.submit(prompt, max_new).unwrap();
    let fin_plain = plain.run_until_idle().expect("plain run");
    let fin_spec = spec.run_until_idle().expect("spec run");
    assert_eq!(fin_plain.len(), 1);
    assert_eq!(fin_spec.len(), 1);
    assert_eq!(fin_plain[0].id, a);
    assert_eq!(fin_spec[0].id, b);
    assert_eq!(
        fin_spec[0].output, fin_plain[0].output,
        "speculative stream must equal the plain decode stream"
    );
    assert_eq!(fin_spec[0].reason, FinishReason::Length);

    let s = spec.metrics.spec;
    assert!(s.verify_passes > 0, "spec engine must run verify passes");
    // The first token comes from prefill; every later token was
    // committed by a verify pass.
    assert_eq!(s.committed, max_new - 1, "verify passes commit the rest");
    // Speculation never takes *more* steps than plain decode, and every
    // accepted draft shaves one off (acceptance itself depends on how
    // draftable this tiny random-weight model's stream happens to be).
    assert!(
        spec.metrics.decode_steps <= plain.metrics.decode_steps,
        "spec took more steps ({} vs {})",
        spec.metrics.decode_steps,
        plain.metrics.decode_steps
    );
    assert_eq!(
        spec.metrics.decode_steps + s.accepted,
        plain.metrics.decode_steps,
        "each accepted draft saves exactly one decode step"
    );
    // Rollback accounting: every pass appended a full block and
    // truncated the rejects; nothing may leak.
    assert_eq!(spec.kv_used_pages(), spec.prefix_index_pages());
    assert_eq!(spec.active(), 0);
}

/// Sparse decode with a covering budget: the whole sparse machinery —
/// scoring, selection, the selected-page gather, compacted positions —
/// engages on every step (dense threshold 0) but selects every page, so
/// the stream must be bit-identical to dense decode. The engine half of
/// the degenerate-sparsity guarantee.
#[test]
fn sparse_covering_budget_stream_matches_dense() {
    let Some((rt, m)) = setup() else { return };
    let mut dense = engine(&rt, &m);
    let mut sparse = Engine::new(
        &rt,
        &m,
        EngineConfig {
            sparse: Some(SparsePolicy {
                budget_pages: 1 << 20,
                sink_pages: 1,
                window_pages: 2,
                dense_threshold_pages: 0,
            }),
            ..EngineConfig::default()
        },
    )
    .expect("engine");

    let mut rng = Rng::new(9);
    let prompt = random_prompt(&mut rng, 512, 12);
    let a = dense.submit(prompt.clone(), 12).unwrap();
    let b = sparse.submit(prompt, 12).unwrap();
    let fin_dense = dense.run_until_idle().expect("dense run");
    let fin_sparse = sparse.run_until_idle().expect("sparse run");
    assert_eq!(fin_dense[0].id, a);
    assert_eq!(fin_sparse[0].id, b);
    assert_eq!(
        fin_sparse[0].output, fin_dense[0].output,
        "covering sparse budget must not move the stream"
    );
    assert_eq!(fin_sparse[0].logprobs, fin_dense[0].logprobs);
    let st = &sparse.metrics.sparse;
    assert!(st.selection_steps > 0, "sparse gather path must have run");
    assert_eq!(
        st.gather_bytes_sparse, st.gather_bytes_dense,
        "complete selections gather exactly the dense bytes"
    );
}

/// Sub-context budget: selection genuinely prunes pages (small pages, a
/// budget below the context), the engine completes, and the sparse
/// gather reads strictly fewer bytes than dense would have.
#[test]
fn sparse_sub_budget_prunes_and_completes() {
    let Some((rt, m)) = setup() else { return };
    let mut e = Engine::new(
        &rt,
        &m,
        EngineConfig {
            page_tokens: 4,
            sparse: Some(SparsePolicy {
                budget_pages: 3,
                sink_pages: 1,
                window_pages: 1,
                dense_threshold_pages: 3,
            }),
            ..EngineConfig::default()
        },
    )
    .expect("engine");
    let mut rng = Rng::new(11);
    // Sized so selection is guaranteed to engage: with 4-token pages and
    // a 3-page threshold, the context passes 13 tokens (4 pages) well
    // before the 16-token generation budget runs out, for any prompt
    // length >= 1.
    let len = 12.min(e.prefill_bucket());
    let prompt = random_prompt(&mut rng, 512, len);
    e.submit(prompt, 16).unwrap();
    let fin = e.run_until_idle().expect("run");
    assert_eq!(fin.len(), 1);
    assert_eq!(fin[0].output.len(), 16);
    let st = &e.metrics.sparse;
    assert!(st.selection_steps > 0, "selection must engage on this shape");
    assert!(
        st.gather_bytes_sparse < st.gather_bytes_dense,
        "sub-context selection must shed gather bytes ({} vs {})",
        st.gather_bytes_sparse,
        st.gather_bytes_dense
    );
    assert!(st.pages_scanned < st.pages_total);
    let rep = e.metrics.report();
    assert!(rep.contains("sparse selection"), "{rep}");
    assert_eq!(e.active(), 0);
}

/// Eviction-storm flight recording end to end: a tiny page pool plus
/// distinct-prefix churn forces the admission path to evict LRU radix
/// pages; with a 1-page storm threshold the trigger fires, a post-mortem
/// bundle lands under `flight_dir`, and the bundle re-validates from
/// disk (manifest, Chrome trace, metrics snapshot, cache report, SLO
/// text).
#[test]
fn eviction_storm_records_a_flight_bundle_on_disk() {
    let Some((rt, m)) = setup() else { return };
    let dir = std::env::temp_dir()
        .join(format!("leanattn-flight-e2e-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut e = Engine::new(
        &rt,
        &m,
        EngineConfig {
            cache_pages: 12,
            page_tokens: 4,
            project_hardware: false,
            trace_capacity: 512,
            eviction_storm_pages: 1,
            flight_dir: Some(dir.to_string_lossy().into_owned()),
            ..EngineConfig::default()
        },
    )
    .expect("engine");
    let mut rng = Rng::new(13);
    let len = 8.min(e.prefill_bucket()).max(1);
    for _ in 0..8 {
        e.submit(random_prompt(&mut rng, 512, len), 4).unwrap();
        e.run_until_idle().expect("wave");
        if e.flight_bundles() > 0 {
            break;
        }
    }
    assert!(
        e.metrics.prefix.evicted_pages > 0,
        "churn against 12 pages must evict index pages"
    );
    assert!(e.flight_bundles() > 0, "the storm trigger must record a bundle");

    let mut found = 0u64;
    for entry in std::fs::read_dir(&dir).expect("flight dir exists") {
        let p = entry.unwrap().path();
        if !p.is_dir() {
            continue;
        }
        let name = p.file_name().unwrap().to_string_lossy().into_owned();
        assert!(name.contains("eviction_storm"), "unexpected trigger: {name}");
        validate_bundle(&p).expect("bundle re-validates from disk");
        found += 1;
    }
    assert_eq!(found, e.flight_bundles(), "every recorded bundle is on disk");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Cost-model drift flight recording end to end: with an absurdly tight
/// relative-error limit, ordinary wall-clock noise against the nominal
/// priors sustains a breach once the detector is warm, and the engine
/// must land a `drift`-trigger post-mortem bundle that re-validates from
/// disk.
#[test]
fn drift_breach_records_a_flight_bundle_on_disk() {
    let Some((rt, m)) = setup() else { return };
    let dir = std::env::temp_dir()
        .join(format!("leanattn-drift-e2e-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut e = Engine::new(
        &rt,
        &m,
        EngineConfig {
            drift_limit: 1e-9,
            project_hardware: false,
            trace_capacity: 512,
            flight_dir: Some(dir.to_string_lossy().into_owned()),
            ..EngineConfig::default()
        },
    )
    .expect("engine");
    let mut rng = Rng::new(29);
    // Plenty of decode steps past warmup (16) + patience (4).
    e.submit(random_prompt(&mut rng, 512, 6), 48).unwrap();
    e.run_until_idle().expect("run");
    assert!(
        e.metrics.balance.drift_observations > 16,
        "the detector must have been fed past its warmup"
    );
    assert!(e.metrics.balance.drift_breaches > 0, "a 1e-9 limit must breach");
    assert!(e.flight_bundles() > 0, "the drift trigger must record a bundle");

    let mut found = 0u64;
    for entry in std::fs::read_dir(&dir).expect("flight dir exists") {
        let p = entry.unwrap().path();
        if !p.is_dir() {
            continue;
        }
        let name = p.file_name().unwrap().to_string_lossy().into_owned();
        assert!(name.contains("drift"), "unexpected trigger: {name}");
        validate_bundle(&p).expect("drift bundle re-validates from disk");
        found += 1;
    }
    assert_eq!(found, e.flight_bundles(), "every recorded bundle is on disk");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The healthy twin: a stationary run under a generous limit observes
/// every decode step but never breaches, and writes nothing to the
/// flight directory.
#[test]
fn healthy_run_under_a_generous_drift_limit_writes_no_bundle() {
    let Some((rt, m)) = setup() else { return };
    let dir = std::env::temp_dir()
        .join(format!("leanattn-drift-quiet-e2e-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut e = Engine::new(
        &rt,
        &m,
        EngineConfig {
            drift_limit: 100.0,
            project_hardware: false,
            flight_dir: Some(dir.to_string_lossy().into_owned()),
            ..EngineConfig::default()
        },
    )
    .expect("engine");
    let mut rng = Rng::new(31);
    e.submit(random_prompt(&mut rng, 512, 6), 48).unwrap();
    e.run_until_idle().expect("run");
    assert!(
        e.metrics.balance.drift_observations > 16,
        "the detector must still observe every decode step"
    );
    assert_eq!(e.metrics.balance.drift_breaches, 0, "stationary run stays quiet");
    assert_eq!(e.flight_bundles(), 0, "no breach, no bundle");
    assert!(!dir.exists(), "the recorder must not even create the directory");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Sampled invariant audits on a healthy run: an every-step plan must
/// execute on every engine iteration and find nothing.
#[test]
fn sampled_audits_pass_on_a_healthy_run() {
    let Some((rt, m)) = setup() else { return };
    let mut e = Engine::new(
        &rt,
        &m,
        EngineConfig { audit: AuditPlan::every(1), ..EngineConfig::default() },
    )
    .expect("engine");
    let mut rng = Rng::new(15);
    for _ in 0..3 {
        e.submit(random_prompt(&mut rng, 512, 8), 4).unwrap();
    }
    e.run_until_idle().expect("run");
    assert!(e.metrics.audit.runs > 0, "an every-step plan must have audited");
    assert_eq!(e.metrics.audit.failures, 0, "healthy engine, zero findings");
    assert!(e.metrics.audit.audit_us > 0.0, "audit time must be accounted");
    assert!(e.run_audit().is_empty(), "direct audit agrees: no findings");
    assert!(e.healthy(), "disabled watchdog reports healthy");
}

/// Fleet fold: every merged counter and histogram must be the exact
/// union of the replicas' — merging never invents or drops samples.
#[test]
fn merged_metrics_and_timelines_union_the_fleet() {
    let Some((rt, m)) = setup() else { return };
    let mut router = Router::new(vec![engine(&rt, &m), engine(&rt, &m)]);
    let mut rng = Rng::new(21);
    for _ in 0..6 {
        router.submit(random_prompt(&mut rng, 512, 8), 3).unwrap();
    }
    router.run_until_idle().expect("run");
    let engines = router.engines();
    assert!(
        engines.iter().all(|e| e.metrics.requests_finished > 0),
        "round-robin must have spread work to both replicas"
    );
    let merged = router.merged_metrics();
    let sums: [(usize, fn(&Engine) -> usize); 4] = [
        (merged.requests_finished, |e| e.metrics.requests_finished),
        (merged.tokens_generated, |e| e.metrics.tokens_generated),
        (merged.prefill_calls, |e| e.metrics.prefill_calls),
        (merged.decode_steps, |e| e.metrics.decode_steps),
    ];
    for (got, per) in sums {
        assert_eq!(got, engines.iter().map(per).sum::<usize>(), "merged != union");
    }
    assert_eq!(
        merged.step_us.count(),
        engines.iter().map(|e| e.metrics.step_us.count()).sum::<u64>(),
        "step histogram union"
    );
    let t = router.merged_timelines();
    assert_eq!(
        t.requests(),
        engines.iter().map(|e| e.timelines.requests()).sum::<u64>()
    );
    assert_eq!(t.tokens(), engines.iter().map(|e| e.timelines.tokens()).sum::<u64>());
    assert_eq!(
        t.e2e().count(),
        engines.iter().map(|e| e.timelines.e2e().count()).sum::<u64>(),
        "e2e latency histogram union"
    );
}

/// SLO attainment computed from the merged histograms must equal the
/// request-weighted mean of the per-replica attainments — identical
/// log-bucket boundaries make the fleet fold exact, for any target.
#[test]
fn merged_slo_attainment_matches_the_per_replica_fold() {
    let Some((rt, m)) = setup() else { return };
    let mut router = Router::new(vec![engine(&rt, &m), engine(&rt, &m)]);
    let mut rng = Rng::new(23);
    for _ in 0..6 {
        let len = rng.urange(2, 10);
        let max_new = rng.urange(1, 5);
        router.submit(random_prompt(&mut rng, 512, len), max_new).unwrap();
    }
    router.run_until_idle().expect("run");
    let total: u64 = router.engines().iter().map(|e| e.timelines.requests()).sum();
    assert!(total > 0);
    let merged = router.merged_timelines();
    for slo_ms in [0.001, 1.0, 50.0, 1e6] {
        let got = merged.slo_report(slo_ms, 1.0).attainment;
        let folded: f64 = router
            .engines()
            .iter()
            .filter(|e| e.timelines.requests() > 0)
            .map(|e| {
                e.timelines.slo_report(slo_ms, 1.0).attainment
                    * e.timelines.requests() as f64
            })
            .sum::<f64>()
            / total as f64;
        assert!(
            (got - folded).abs() < 1e-9,
            "slo {slo_ms} ms: merged attainment {got} != folded {folded}"
        );
    }
    // Extremes anchor the fold: nothing meets a ~0 target, everything
    // meets a huge one.
    assert_eq!(merged.slo_report(1e9, 1.0).attainment, 1.0);
}

/// Acceptance-aware draft sizing must never move the committed stream —
/// it only re-sizes drafts from the running acceptance rate.
#[test]
fn adaptive_spec_preserves_the_stream() {
    let Some((rt, m)) = setup() else { return };
    let mut plain = engine(&rt, &m);
    let mut adaptive = Engine::new(
        &rt,
        &m,
        EngineConfig { spec_k: 3, adaptive_spec: true, ..EngineConfig::default() },
    )
    .expect("engine");
    if !adaptive.spec_enabled() {
        eprintln!("skipping: artifact set has no verify step");
        return;
    }
    let prompt: Vec<i32> = (0..24).map(|t| t % 6).collect();
    let a = plain.submit(prompt.clone(), 20).unwrap();
    let b = adaptive.submit(prompt, 20).unwrap();
    let fin_plain = plain.run_until_idle().expect("plain");
    let fin_adaptive = adaptive.run_until_idle().expect("adaptive");
    assert_eq!(fin_plain[0].id, a);
    assert_eq!(fin_adaptive[0].id, b);
    assert_eq!(
        fin_adaptive[0].output, fin_plain[0].output,
        "adaptive draft sizing must not move the stream"
    );
    assert!(adaptive.metrics.spec.verify_passes > 0);
}
