//! Property tests over the partition planners: structural invariants
//! (exact coverage, host/finishing uniqueness), stream-K balance, the
//! paper's special-case generalizations, and numerical equivalence of
//! every plan under host execution — sequential and parallel.

use lean_attention::attention::attention_host;
use lean_attention::coordinator::pool::execute_plan_host_parallel;
use lean_attention::partition::host_exec::{execute_plan_host, HostTensors};
use lean_attention::partition::plan::{
    build_plan, fd_heuristic_splits, DecodeProblem, Strategy,
};
use lean_attention::util::testing::{max_abs_err, prop_check};

fn random_problem(rng: &mut lean_attention::util::rng::Rng) -> DecodeProblem {
    let batch = rng.urange(1, 6);
    let heads = *rng.choose(&[1usize, 2, 4, 8, 32, 56]);
    let head_dim = *rng.choose(&[32usize, 64, 128]);
    let ctx_lens: Vec<u32> = (0..batch)
        .map(|_| rng.range(1, 100_000) as u32)
        .collect();
    DecodeProblem::ragged(heads, ctx_lens, head_dim)
}

#[test]
fn every_strategy_produces_valid_plans() {
    prop_check("plan validity", 200, |rng| {
        let p = random_problem(rng);
        let slots = rng.urange(1, 512);
        let strategies = [
            Strategy::Dense,
            Strategy::FixedSplit { splits: rng.urange(1, 20) },
            Strategy::PagedFixedSplit { splits: rng.urange(1, 20), page: 16 },
            Strategy::StreamK,
            Strategy::fixed_split_auto(&p, 108),
        ];
        for s in strategies {
            let plan = build_plan(&p, s, slots);
            plan.validate(&p)
                .map_err(|e| format!("{}: {e}", s.name()))?;
        }
        Ok(())
    });
}

#[test]
fn stream_k_load_balance_is_optimal() {
    prop_check("stream-K balance", 200, |rng| {
        let p = random_problem(rng);
        let slots = rng.urange(1, 1000);
        let plan = build_plan(&p, Strategy::StreamK, slots);
        let tiles = plan.tiles_per_cta();
        let max = *tiles.iter().max().unwrap_or(&0);
        let min = *tiles.iter().min().unwrap_or(&0);
        if max.saturating_sub(min) > 1 {
            return Err(format!("load range {min}..{max}"));
        }
        // total preserved
        let total: u64 = tiles.iter().sum();
        if total != p.total_tiles() {
            return Err("tile count mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn stream_k_generalizes_to_fa2_when_tiles_equal_grid() {
    // Paper §IV-C: when output tiles == grid size, LA == FA2 (one CTA per
    // tile, all host+finishing).
    let p = DecodeProblem::uniform(2, 8, 256, 64); // 16 groups x 1 tile
    let lean = build_plan(&p, Strategy::StreamK, 16);
    let dense = build_plan(&p, Strategy::Dense, 16);
    assert_eq!(lean.grid(), dense.grid());
    for (a, b) in lean.ctas.iter().zip(&dense.ctas) {
        assert_eq!(a.segments, b.segments);
    }
}

#[test]
fn stream_k_generalizes_to_fixed_split_on_even_multiple() {
    // Grid an even multiple of output tiles -> same chunk sizes as FD.
    let p = DecodeProblem::uniform(1, 4, 8 * 256, 64); // 4 groups x 8 tiles
    let lean = build_plan(&p, Strategy::StreamK, 8); // 2 CTAs per group
    let fd = build_plan(&p, Strategy::FixedSplit { splits: 2 }, 8);
    let mut lean_tiles = lean.tiles_per_cta();
    let mut fd_tiles = fd.tiles_per_cta();
    lean_tiles.sort_unstable();
    fd_tiles.sort_unstable();
    assert_eq!(lean_tiles, fd_tiles);
    assert!(lean.ctas.iter().all(|c| c.segments.len() == 1));
}

#[test]
fn fd_heuristic_matches_paper_behaviour() {
    // No split once groups ~fill the device (Fig 7c: FD stops splitting
    // at batch >= 4 with 32 heads on 108 SMs).
    for batch in [4usize, 8, 16, 32] {
        let p = DecodeProblem::uniform(batch, 32, 65536, 64);
        assert_eq!(fd_heuristic_splits(&p, 108, 128), 1, "batch {batch}");
    }
    // Splits appear for small grids.
    let p = DecodeProblem::uniform(1, 32, 65536, 64);
    assert!(fd_heuristic_splits(&p, 108, 128) > 1);
    // Never exceeds tiles available.
    let p = DecodeProblem::uniform(1, 2, 512, 64); // 2 tiles per group
    assert!(fd_heuristic_splits(&p, 108, 128) <= 2);
}

#[test]
fn all_plans_numerically_exact_sequential_and_parallel() {
    prop_check("plan numerics", 25, |rng| {
        let batch = rng.urange(1, 3);
        let heads = rng.urange(1, 4);
        let ctx_lens: Vec<u32> = (0..batch).map(|_| rng.range(1, 500) as u32).collect();
        let p = DecodeProblem::ragged(heads, ctx_lens, 32).with_tile(32);
        let t = HostTensors::random(&p, rng.next_u64());
        let want = attention_host(
            &t.q,
            &t.k,
            &t.v,
            p.groups(),
            t.n_max,
            p.head_dim,
            &t.group_lens(&p),
        );
        for s in [
            Strategy::Dense,
            Strategy::FixedSplit { splits: 4 },
            Strategy::StreamK,
        ] {
            let plan = build_plan(&p, s, rng.urange(1, 32));
            let seq = execute_plan_host(&plan, &p, &t, Some(rng.next_u64()));
            let par = execute_plan_host_parallel(&plan, &p, &t, 3);
            let e1 = max_abs_err(&seq, &want);
            let e2 = max_abs_err(&par, &want);
            if e1 > 5e-4 || e2 > 5e-4 {
                return Err(format!("{}: seq {e1} par {e2}", s.name()));
            }
        }
        Ok(())
    });
}

#[test]
fn lean_tile_counts_scale_with_problem() {
    // Eq. 2 sanity: TilesPerCTA tracks problem size over fixed grid.
    let arch_slots = 216;
    let small = DecodeProblem::uniform(1, 8, 16_384, 64);
    let large = DecodeProblem::uniform(1, 8, 262_144, 64);
    let ps = build_plan(&small, Strategy::StreamK, arch_slots);
    let pl = build_plan(&large, Strategy::StreamK, arch_slots);
    let t_small = *ps.tiles_per_cta().iter().max().unwrap();
    let t_large = *pl.tiles_per_cta().iter().max().unwrap();
    // Eq. 2: TilesPerCTA = ceil(total / grid)
    assert_eq!(t_small, small.total_tiles().div_ceil(ps.grid() as u64));
    assert_eq!(t_large, large.total_tiles().div_ceil(pl.grid() as u64));
    // and 16x the context is ~16x the per-CTA work (within quantization)
    let ratio = t_large as f64 / t_small as f64;
    assert!((10.0..=16.5).contains(&ratio), "ratio {ratio}");
}
