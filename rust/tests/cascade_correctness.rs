//! Cascade (shared-prefix) attention correctness and traffic accounting.
//!
//! The load-bearing claims:
//!
//! 1. **Exactness** — for any batch with shared-prefix structure (mixed
//!    freely with solo sequences), computing each shared prefix's
//!    partials from a single KV walk and merging them with per-sequence
//!    suffix partials through the §IV-A rescale operator equals plain
//!    exact attention over the composed per-sequence contexts, for every
//!    legal stream-K segment plan and any reduction order.
//! 2. **Traffic** — the cascade segment plan streams strictly fewer
//!    modeled KV bytes than the flat plan whenever ≥ 2 sequences share
//!    at least one LeanTile of prefix.

use lean_attention::attention::attention_host;
use lean_attention::partition::cascade::{
    build_cascade_plan, execute_cascade_host, CascadeProblem, CascadeTensors,
    PrefixGroup,
};
use lean_attention::sim::cascade::simulate_cascade;
use lean_attention::sim::GpuArch;
use lean_attention::util::rng::Rng;
use lean_attention::util::testing::{max_abs_err, prop_check};

/// Exact attention over the composed (prefix + suffix) per-sequence KV.
fn reference(p: &CascadeProblem, t: &CascadeTensors) -> Vec<f32> {
    let (k, v, n_max) = t.full_kv(p);
    let lens: Vec<u32> = (0..p.outputs())
        .map(|g| p.ctx_lens[g / p.heads])
        .collect();
    attention_host(&t.q, &k, &v, p.outputs(), n_max, p.head_dim, &lens)
}

/// Random cascade problem: ragged contexts, zero to two disjoint prefix
/// groups (group sizes 1..batch allowed — singletons must also be exact).
fn random_problem(rng: &mut Rng) -> CascadeProblem {
    let batch = rng.urange(2, 7);
    let heads = rng.urange(1, 4);
    let d = *rng.choose(&[16usize, 32]);
    let ctx_lens: Vec<u32> = (0..batch).map(|_| rng.range(1, 400) as u32).collect();

    // Partition a shuffled batch into up to two candidate groups.
    let mut order: Vec<u32> = (0..batch as u32).collect();
    for i in (1..order.len()).rev() {
        let j = rng.urange(0, i + 1);
        order.swap(i, j);
    }
    let mut groups = Vec::new();
    let n_groups = rng.urange(0, 3);
    let mut cursor = 0usize;
    for _ in 0..n_groups {
        if cursor >= order.len() {
            break;
        }
        let take = rng.urange(1, order.len() - cursor + 1);
        let members: Vec<u32> = order[cursor..cursor + take].to_vec();
        cursor += take;
        let min_ctx = members
            .iter()
            .map(|&m| ctx_lens[m as usize])
            .min()
            .unwrap();
        let prefix_len = rng.range(1, u64::from(min_ctx) + 1) as u32;
        groups.push(PrefixGroup { prefix_len, members });
    }

    CascadeProblem::new(heads, ctx_lens, d, groups)
        .expect("generator builds valid problems")
        .with_tile(*rng.choose(&[16usize, 32, 64]))
}

#[test]
fn cascade_equals_reference_on_random_problems() {
    prop_check("cascade host exec == direct attention", 60, |rng| {
        let p = random_problem(rng);
        let t = CascadeTensors::random(&p, rng.next_u64());
        let want = reference(&p, &t);
        let slots = rng.urange(1, 64);
        let cp = build_cascade_plan(&p, slots);
        cp.plan
            .validate(&cp.segment_problem)
            .map_err(|e| e.to_string())?;
        let got = execute_cascade_host(&cp, &p, &t, Some(rng.next_u64()));
        let err = max_abs_err(&got, &want);
        if err > 5e-4 {
            return Err(format!(
                "err {err} (batch {}, groups {:?})",
                p.batch(),
                p.prefix_groups
            ));
        }
        Ok(())
    });
}

#[test]
fn mixed_shared_and_solo_batch_is_exact() {
    // Two sequences share a prefix, one is solo, one shares nothing but
    // has the *same length* as the group prefix (an aliasing trap).
    let p = CascadeProblem::new(
        2,
        vec![200, 150, 96, 80],
        16,
        vec![PrefixGroup { prefix_len: 96, members: vec![0, 1] }],
    )
    .unwrap()
    .with_tile(32);
    let t = CascadeTensors::random(&p, 42);
    let want = reference(&p, &t);
    for slots in [1usize, 5, 17, 216] {
        let cp = build_cascade_plan(&p, slots);
        cp.plan.validate(&cp.segment_problem).unwrap();
        let got = execute_cascade_host(&cp, &p, &t, None);
        let err = max_abs_err(&got, &want);
        assert!(err < 1e-4, "slots {slots}: err {err}");
    }
}

#[test]
fn member_with_empty_suffix_is_exact() {
    // One member's context *is* the shared prefix (suffix length 0): its
    // output must come entirely from the shared segment partials.
    let p = CascadeProblem::new(
        3,
        vec![64, 100],
        16,
        vec![PrefixGroup { prefix_len: 64, members: vec![0, 1] }],
    )
    .unwrap()
    .with_tile(16);
    let t = CascadeTensors::random(&p, 7);
    let want = reference(&p, &t);
    let cp = build_cascade_plan(&p, 12);
    let got = execute_cascade_host(&cp, &p, &t, Some(3));
    assert!(max_abs_err(&got, &want) < 1e-4);
}

#[test]
fn unaligned_prefix_boundaries_stay_exact() {
    // Prefix cuts that straddle LeanTile boundaries exercise the
    // associativity of the merge, not just tile-aligned splits.
    for prefix in [1u32, 17, 33, 250] {
        let p = CascadeProblem::new(
            1,
            vec![300, 260],
            16,
            vec![PrefixGroup { prefix_len: prefix, members: vec![0, 1] }],
        )
        .unwrap()
        .with_tile(32);
        let t = CascadeTensors::random(&p, u64::from(prefix));
        let want = reference(&p, &t);
        let cp = build_cascade_plan(&p, 7);
        let got = execute_cascade_host(&cp, &p, &t, None);
        assert!(
            max_abs_err(&got, &want) < 1e-4,
            "prefix {prefix} mismatch"
        );
    }
}

#[test]
fn shared_prefix_streams_strictly_fewer_bytes_than_flat() {
    let arch = GpuArch::a100();
    for batch in [2usize, 3, 8] {
        let p = CascadeProblem::new(
            8,
            vec![32_768; batch],
            64,
            vec![PrefixGroup {
                prefix_len: 16_384,
                members: (0..batch as u32).collect(),
            }],
        )
        .unwrap();
        let r = simulate_cascade(&p, &arch);
        assert!(
            r.kv_bytes < r.baseline_kv_bytes,
            "batch {batch}: {} vs {}",
            r.kv_bytes,
            r.baseline_kv_bytes
        );
        assert!(r.bytes_saved_fraction() > 0.0);
    }

    // Solo batch (batch 1 group pruned by tile alignment): no saving,
    // and tile_aligned() reports that by dropping the group.
    let solo = CascadeProblem::new(
        8,
        vec![32_768],
        64,
        vec![PrefixGroup { prefix_len: 16_384, members: vec![0] }],
    )
    .unwrap()
    .tile_aligned();
    assert!(solo.prefix_groups.is_empty());
}

#[test]
fn tile_aligned_cascade_never_exceeds_flat_traffic() {
    prop_check("aligned cascade bytes <= flat bytes", 100, |rng| {
        let p = random_problem(rng).tile_aligned();
        let cascade = p.segment_problem().total_tiles();
        let flat = p.baseline_problem().total_tiles();
        if cascade > flat {
            return Err(format!(
                "cascade {cascade} > flat {flat} for groups {:?}",
                p.prefix_groups
            ));
        }
        Ok(())
    });
}
