//! Cascade (shared-prefix) attention correctness and traffic accounting.
//!
//! The load-bearing claims:
//!
//! 1. **Exactness** — for any batch with shared-prefix structure (mixed
//!    freely with solo sequences), computing each shared prefix's
//!    partials from a single KV walk and merging them with per-sequence
//!    suffix partials through the §IV-A rescale operator equals plain
//!    exact attention over the composed per-sequence contexts, for every
//!    legal stream-K segment plan and any reduction order.
//! 2. **Traffic** — the cascade segment plan streams strictly fewer
//!    modeled KV bytes than the flat plan whenever ≥ 2 sequences share
//!    at least one LeanTile of prefix.

use lean_attention::attention::attention_host;
use lean_attention::partition::cascade::{
    build_cascade_plan, execute_cascade_host, CascadeProblem, CascadeTensors,
    PrefixGroup, SegKind,
};
use lean_attention::runtime::attention_exec::{
    lean_cascade_host, roll_cascade_tasks, rolled_kv_bytes,
};
use lean_attention::sim::cascade::simulate_cascade;
use lean_attention::sim::GpuArch;
use lean_attention::util::rng::Rng;
use lean_attention::util::testing::{max_abs_err, prop_check};

/// Exact attention over the composed (prefix + suffix) per-sequence KV.
fn reference(p: &CascadeProblem, t: &CascadeTensors) -> Vec<f32> {
    let (k, v, n_max) = t.full_kv(p);
    let lens: Vec<u32> = (0..p.outputs())
        .map(|g| p.ctx_lens[g / p.heads])
        .collect();
    attention_host(&t.q, &k, &v, p.outputs(), n_max, p.head_dim, &lens)
}

/// Random cascade problem: ragged contexts, zero to two disjoint prefix
/// groups (group sizes 1..batch allowed — singletons must also be exact).
fn random_problem(rng: &mut Rng) -> CascadeProblem {
    let batch = rng.urange(2, 7);
    let heads = rng.urange(1, 4);
    let d = *rng.choose(&[16usize, 32]);
    let ctx_lens: Vec<u32> = (0..batch).map(|_| rng.range(1, 400) as u32).collect();

    // Partition a shuffled batch into up to two candidate groups.
    let mut order: Vec<u32> = (0..batch as u32).collect();
    for i in (1..order.len()).rev() {
        let j = rng.urange(0, i + 1);
        order.swap(i, j);
    }
    let mut groups = Vec::new();
    let n_groups = rng.urange(0, 3);
    let mut cursor = 0usize;
    for _ in 0..n_groups {
        if cursor >= order.len() {
            break;
        }
        let take = rng.urange(1, order.len() - cursor + 1);
        let members: Vec<u32> = order[cursor..cursor + take].to_vec();
        cursor += take;
        let min_ctx = members
            .iter()
            .map(|&m| ctx_lens[m as usize])
            .min()
            .unwrap();
        let prefix_len = rng.range(1, u64::from(min_ctx) + 1) as u32;
        groups.push(PrefixGroup { prefix_len, members });
    }

    CascadeProblem::new(heads, ctx_lens, d, groups)
        .expect("generator builds valid problems")
        .with_tile(*rng.choose(&[16usize, 32, 64]))
}

#[test]
fn cascade_equals_reference_on_random_problems() {
    prop_check("cascade host exec == direct attention", 60, |rng| {
        let p = random_problem(rng);
        let t = CascadeTensors::random(&p, rng.next_u64());
        let want = reference(&p, &t);
        let slots = rng.urange(1, 64);
        let cp = build_cascade_plan(&p, slots);
        cp.plan
            .validate(&cp.segment_problem)
            .map_err(|e| e.to_string())?;
        let got = execute_cascade_host(&cp, &p, &t, Some(rng.next_u64()));
        let err = max_abs_err(&got, &want);
        if err > 5e-4 {
            return Err(format!(
                "err {err} (batch {}, groups {:?})",
                p.batch(),
                p.prefix_groups
            ));
        }
        Ok(())
    });
}

#[test]
fn mixed_shared_and_solo_batch_is_exact() {
    // Two sequences share a prefix, one is solo, one shares nothing but
    // has the *same length* as the group prefix (an aliasing trap).
    let p = CascadeProblem::new(
        2,
        vec![200, 150, 96, 80],
        16,
        vec![PrefixGroup { prefix_len: 96, members: vec![0, 1] }],
    )
    .unwrap()
    .with_tile(32);
    let t = CascadeTensors::random(&p, 42);
    let want = reference(&p, &t);
    for slots in [1usize, 5, 17, 216] {
        let cp = build_cascade_plan(&p, slots);
        cp.plan.validate(&cp.segment_problem).unwrap();
        let got = execute_cascade_host(&cp, &p, &t, None);
        let err = max_abs_err(&got, &want);
        assert!(err < 1e-4, "slots {slots}: err {err}");
    }
}

#[test]
fn member_with_empty_suffix_is_exact() {
    // One member's context *is* the shared prefix (suffix length 0): its
    // output must come entirely from the shared segment partials.
    let p = CascadeProblem::new(
        3,
        vec![64, 100],
        16,
        vec![PrefixGroup { prefix_len: 64, members: vec![0, 1] }],
    )
    .unwrap()
    .with_tile(16);
    let t = CascadeTensors::random(&p, 7);
    let want = reference(&p, &t);
    let cp = build_cascade_plan(&p, 12);
    let got = execute_cascade_host(&cp, &p, &t, Some(3));
    assert!(max_abs_err(&got, &want) < 1e-4);
}

#[test]
fn unaligned_prefix_boundaries_stay_exact() {
    // Prefix cuts that straddle LeanTile boundaries exercise the
    // associativity of the merge, not just tile-aligned splits.
    for prefix in [1u32, 17, 33, 250] {
        let p = CascadeProblem::new(
            1,
            vec![300, 260],
            16,
            vec![PrefixGroup { prefix_len: prefix, members: vec![0, 1] }],
        )
        .unwrap()
        .with_tile(32);
        let t = CascadeTensors::random(&p, u64::from(prefix));
        let want = reference(&p, &t);
        let cp = build_cascade_plan(&p, 7);
        let got = execute_cascade_host(&cp, &p, &t, None);
        assert!(
            max_abs_err(&got, &want) < 1e-4,
            "prefix {prefix} mismatch"
        );
    }
}

#[test]
fn lean_cascade_matches_oracle_on_random_problems() {
    // The executor-path property of the tentpole: the task-rolling +
    // partial-batching + group-broadcast-fold driver (the exact code the
    // PJRT `lean_cascade` runs, here with host partials) must equal the
    // exact oracle for any legal plan, any batching granularity.
    prop_check("lean_cascade (host partials) == direct attention", 60, |rng| {
        let p = random_problem(rng);
        let t = CascadeTensors::random(&p, rng.next_u64());
        let want = reference(&p, &t);
        let cp = build_cascade_plan(&p, rng.urange(1, 64));
        cp.plan
            .validate(&cp.segment_problem)
            .map_err(|e| e.to_string())?;
        let batch_rows = rng.urange(1, 33);
        let (got, _lse) = lean_cascade_host(&p, &t, &cp, batch_rows);
        let err = max_abs_err(&got, &want);
        if err > 1e-4 {
            return Err(format!(
                "err {err} (batch {}, rows {batch_rows}, groups {:?})",
                p.batch(),
                p.prefix_groups
            ));
        }
        Ok(())
    });
}

#[test]
fn lean_cascade_page_aligned_groups_of_every_size() {
    // Page-aligned prompts (prefix a multiple of the tile), group sizes
    // 2..=8, one member whose context *is* the prefix (empty suffix), one
    // COW-forked pair (identical contexts, divergent suffix numbers), and
    // a solo straggler.
    let tile = 16usize;
    let prefix = 4 * tile as u32; // page-aligned: 4 whole tiles
    for gsize in 2..=8usize {
        let mut ctx_lens: Vec<u32> = (0..gsize as u32)
            .map(|i| match i {
                0 => prefix, // empty suffix
                1 => prefix + 37,
                2 => prefix + 37, // fork twin of member 1
                i => prefix + 11 * i,
            })
            .collect();
        ctx_lens.push(23); // solo
        let p = CascadeProblem::new(
            2,
            ctx_lens,
            16,
            vec![PrefixGroup {
                prefix_len: prefix,
                members: (0..gsize as u32).collect(),
            }],
        )
        .unwrap()
        .with_tile(tile);
        let t = CascadeTensors::random(&p, 100 + gsize as u64);
        let want = reference(&p, &t);
        for slots in [1usize, 9, 216] {
            let cp = build_cascade_plan(&p, slots);
            cp.plan.validate(&cp.segment_problem).unwrap();
            let (got, _) = lean_cascade_host(&p, &t, &cp, 8);
            let err = max_abs_err(&got, &want);
            assert!(err < 1e-4, "gsize {gsize} slots {slots}: err {err}");
        }
    }
}

#[test]
fn rolled_tasks_cover_every_output_exactly() {
    // Every output row's context is covered exactly once by the rolled
    // tasks (shared tasks count toward every member), for random problems
    // and random grids.
    prop_check("cascade task coverage", 100, |rng| {
        let p = random_problem(rng);
        let cp = build_cascade_plan(&p, rng.urange(1, 128));
        let tasks = roll_cascade_tasks(&p, &cp);
        let mut covered = vec![0u64; p.outputs()];
        for task in &tasks {
            match task.kind {
                SegKind::Shared { pg, head } => {
                    for &m in &p.prefix_groups[pg].members {
                        covered[m as usize * p.heads + head] += task.width as u64;
                    }
                }
                SegKind::Suffix { seq, head } => {
                    covered[seq * p.heads + head] += task.width as u64;
                }
            }
        }
        for (out, &c) in covered.iter().enumerate() {
            let want = u64::from(p.ctx_lens[out / p.heads]);
            if c != want {
                return Err(format!("output {out}: covered {c} of {want}"));
            }
        }
        Ok(())
    });
}

#[test]
fn cascade_tasks_gather_fewer_bytes_than_flat_tasks() {
    // The executor-level dedup claim: on a tile-aligned shared batch the
    // rolled cascade tasks read strictly fewer KV bytes than the flat
    // rolling of the same contexts (shared slices count once per task).
    let lens = vec![128u32; 4];
    let grouped = CascadeProblem::new(
        2,
        lens.clone(),
        16,
        vec![PrefixGroup { prefix_len: 64, members: vec![0, 1, 2, 3] }],
    )
    .unwrap()
    .with_tile(16);
    let flat = CascadeProblem::new(2, lens, 16, vec![]).unwrap().with_tile(16);
    let gb = rolled_kv_bytes(
        &roll_cascade_tasks(&grouped, &build_cascade_plan(&grouped, 32)),
        16,
    );
    let fb = rolled_kv_bytes(
        &roll_cascade_tasks(&flat, &build_cascade_plan(&flat, 32)),
        16,
    );
    // flat: 4 seqs x 128 tokens x 2 heads; cascade: (64 + 4 x 64) x 2.
    let token = 2 * 16 * 4;
    assert_eq!(fb, 4 * 128 * 2 * token);
    assert_eq!(gb, (64 + 4 * 64) * 2 * token);
    assert!(gb < fb);
}

#[test]
fn shared_prefix_streams_strictly_fewer_bytes_than_flat() {
    let arch = GpuArch::a100();
    for batch in [2usize, 3, 8] {
        let p = CascadeProblem::new(
            8,
            vec![32_768; batch],
            64,
            vec![PrefixGroup {
                prefix_len: 16_384,
                members: (0..batch as u32).collect(),
            }],
        )
        .unwrap();
        let r = simulate_cascade(&p, &arch);
        assert!(
            r.kv_bytes < r.baseline_kv_bytes,
            "batch {batch}: {} vs {}",
            r.kv_bytes,
            r.baseline_kv_bytes
        );
        assert!(r.bytes_saved_fraction() > 0.0);
    }

    // Solo batch (batch 1 group pruned by tile alignment): no saving,
    // and tile_aligned() reports that by dropping the group.
    let solo = CascadeProblem::new(
        8,
        vec![32_768],
        64,
        vec![PrefixGroup { prefix_len: 16_384, members: vec![0] }],
    )
    .unwrap()
    .tile_aligned();
    assert!(solo.prefix_groups.is_empty());
}

#[test]
fn tile_aligned_cascade_never_exceeds_flat_traffic() {
    prop_check("aligned cascade bytes <= flat bytes", 100, |rng| {
        let p = random_problem(rng).tile_aligned();
        let cascade = p.segment_problem().total_tiles();
        let flat = p.baseline_problem().total_tiles();
        if cascade > flat {
            return Err(format!(
                "cascade {cascade} > flat {flat} for groups {:?}",
                p.prefix_groups
            ));
        }
        Ok(())
    });
}
