//! Property tests for the cache introspection report: across random
//! workloads of inserts, shared-prefix inserts, appends (with
//! copy-on-write), zero-copy forks, speculative truncations, radix-style
//! retains/releases, gathers, sparse page selections, frees and tick
//! advances, [`PagedKvCache::report`] must equal — field for field, bit
//! for bit — an independent from-scratch recompute over the per-page
//! accessors (`page_ref`, `HeatTracker::total_hits`, ...). The JSON
//! export must round-trip through the parser unchanged and pass
//! [`validate_cache_report`] at every checkpoint.
//!
//! The cache's head plane is the KV-head plane, so the suite sweeps
//! `h_kv ∈ {1, 2, 4}` like the page-accounting properties — the report
//! must be indifferent to the grouping.

use std::cmp::Reverse;
use std::collections::BTreeMap;

use lean_attention::coordinator::PagedKvCache;
use lean_attention::obs::cache_stats::{HeatStats, PoolStats, SharingStats};
use lean_attention::obs::{heat_bucket, validate_cache_report, CacheReport, HotRun};
use lean_attention::sparse::SparsePolicy;
use lean_attention::util::json::Json;
use lean_attention::util::rng::Rng;
use lean_attention::util::testing::prop_check;

const LAYERS: usize = 1;
const DH: usize = 4;
const PAGE_TOKENS: usize = 4;
const PAGES: usize = 24;
const KV_HEAD_PLANES: [usize; 3] = [1, 2, 4];

fn kv(rng: &mut Rng, kv_heads: usize, tokens: usize) -> (Vec<f32>, Vec<f32>) {
    let n = LAYERS * kv_heads * tokens * DH;
    (rng.normal_vec(n), rng.normal_vec(n))
}

/// From-scratch recompute of the full report over the public per-page
/// accessors — deliberately reimplemented here, not routed through
/// `CacheReport::build`, so the two derivations check each other.
fn recompute_report(cache: &PagedKvCache, top_k: usize) -> CacheReport {
    let heat = cache.heat();
    let total = cache.total_pages();
    let refs: Vec<u32> = (0..total).map(|p| cache.page_ref(p)).collect();
    let free: Vec<usize> = (0..total).filter(|&p| refs[p] == 0).collect();
    let used: Vec<usize> = (0..total).filter(|&p| refs[p] > 0).collect();

    let mut free_runs = 0usize;
    let mut largest = 0usize;
    let mut run = 0usize;
    for (i, &p) in free.iter().enumerate() {
        if i == 0 || p != free[i - 1] + 1 {
            free_runs += 1;
            run = 0;
        }
        run += 1;
        largest = largest.max(run);
    }
    let fragmentation = if free.is_empty() {
        0.0
    } else {
        1.0 - largest as f64 / free.len() as f64
    };

    let mut refcount_hist: BTreeMap<u32, u64> = BTreeMap::new();
    for &r in &refs {
        *refcount_hist.entry(r).or_insert(0) += 1;
    }
    let shared_pages = refs.iter().filter(|&&r| r >= 2).count();
    let max_refcount = refs.iter().copied().max().unwrap_or(0);

    let max_bucket =
        used.iter().map(|&p| heat_bucket(heat.total_hits(p))).max().unwrap_or(0);
    let mut histogram = vec![0u64; max_bucket + 1];
    for &p in &used {
        histogram[heat_bucket(heat.total_hits(p))] += 1;
    }

    let mut ranked = used.clone();
    ranked.sort_by_key(|&p| (Reverse(heat.total_hits(p)), p));
    ranked.truncate(top_k);
    ranked.sort_unstable();
    let mut hottest: Vec<HotRun> = Vec::new();
    for &p in &ranked {
        match hottest.last_mut() {
            Some(r) if r.start + r.pages == p => {
                r.pages += 1;
                r.touches += heat.total_hits(p);
            }
            _ => hottest.push(HotRun { start: p, pages: 1, touches: heat.total_hits(p) }),
        }
    }
    hottest.sort_by_key(|r| (Reverse(r.touches), r.start));

    CacheReport {
        pool: PoolStats {
            pages_total: total,
            pages_used: used.len(),
            pages_free: free.len(),
            page_tokens: PAGE_TOKENS,
            token_bytes: cache.token_bytes(),
            free_runs,
            largest_free_run: largest,
            fragmentation,
        },
        sharing: SharingStats {
            refcount_hist,
            shared_pages,
            max_refcount,
            cow_clones_total: heat.cow_clones(),
        },
        heat: HeatStats {
            clock: heat.clock(),
            gather_touches_total: heat.gather_total(),
            append_touches_total: heat.append_total(),
            select_touches_total: heat.select_total(),
            histogram,
            hottest,
        },
        radix: None,
    }
}

fn check_report(cache: &PagedKvCache, top_k: usize) -> Result<(), String> {
    let rep = cache.report(None, top_k);
    let expect = recompute_report(cache, top_k);
    if rep != expect {
        return Err(format!(
            "report diverged from recompute (top_k {top_k}):\n got {rep:?}\nwant {expect:?}"
        ));
    }
    let j = rep.to_json();
    validate_cache_report(&j).map_err(|e| format!("schema: {e}"))?;
    let parsed = Json::parse(&j.to_string()).map_err(|e| format!("parse-back: {e}"))?;
    if parsed != j {
        return Err("JSON round-trip is not the identity".into());
    }
    Ok(())
}

#[test]
fn report_matches_from_scratch_recompute_under_churn() {
    prop_check("cache report == recompute", 30, |rng| {
        let kv_heads = *rng.choose(&KV_HEAD_PLANES);
        let mut cache = PagedKvCache::new(LAYERS, kv_heads, DH, PAGE_TOKENS, PAGES);
        let mut active: Vec<u64> = Vec::new();
        let mut retains: Vec<usize> = Vec::new();
        let mut next_id = 0u64;
        let policy = SparsePolicy::with_budget(2);

        for step in 0..100 {
            match rng.urange(0, 11) {
                0 => {
                    let len = rng.urange(1, 3 * PAGE_TOKENS + 2);
                    let (k, v) = kv(rng, kv_heads, len);
                    if cache.insert_seq(next_id, &k, &v, len).is_ok() {
                        active.push(next_id);
                    }
                    next_id += 1;
                }
                1 if !active.is_empty() => {
                    let donor = *rng.choose(&active);
                    let full = cache.seq_len(donor).unwrap() / PAGE_TOKENS;
                    if full == 0 {
                        continue;
                    }
                    let take = rng.urange(1, full + 1);
                    let shared: Vec<usize> =
                        cache.seq_pages(donor).unwrap()[..take].to_vec();
                    let suffix = rng.urange(0, PAGE_TOKENS + 3);
                    let (k, v) = kv(rng, kv_heads, suffix);
                    if cache.insert_seq_shared(next_id, &shared, &k, &v, suffix).is_ok() {
                        active.push(next_id);
                    }
                    next_id += 1;
                }
                // Append — COW when the tail page is shared; both the
                // append touch and the clone must land in the heat state.
                2 if !active.is_empty() => {
                    let id = *rng.choose(&active);
                    let (k, v) = kv(rng, kv_heads, 1);
                    let _ = cache.append_token(id, &k, &v);
                }
                3 if !active.is_empty() => {
                    let donor = *rng.choose(&active);
                    if cache.fork_seq(donor, next_id).is_ok() {
                        active.push(next_id);
                    }
                    next_id += 1;
                }
                4 if !active.is_empty() => {
                    let id = *rng.choose(&active);
                    let len = cache.seq_len(id).unwrap();
                    cache
                        .truncate_seq(id, rng.urange(0, len + 1))
                        .map_err(|e| e.to_string())?;
                }
                5 if !active.is_empty() => {
                    let i = rng.urange(0, active.len());
                    cache.free_seq(active.swap_remove(i));
                }
                // Radix-style external retain / release: report sharing
                // counts must follow `page_ref`, whoever the holder is.
                6 if !active.is_empty() => {
                    let id = *rng.choose(&active);
                    let pages = cache.seq_pages(id).unwrap();
                    let p = pages[rng.urange(0, pages.len())];
                    cache.retain_page(p).map_err(|e| e.to_string())?;
                    retains.push(p);
                }
                7 if !retains.is_empty() => {
                    let i = rng.urange(0, retains.len());
                    let p = retains.swap_remove(i);
                    cache.release_page(p).map_err(|e| e.to_string())?;
                }
                // Flat gather over a few live lanes: per-page gather
                // touches accumulate.
                8 if !active.is_empty() => {
                    let lanes: Vec<Option<u64>> =
                        active.iter().take(3).map(|&id| Some(id)).collect();
                    let ctx = lanes
                        .iter()
                        .filter_map(|s| s.and_then(|id| cache.seq_len(id)))
                        .max()
                        .unwrap_or(PAGE_TOKENS)
                        .max(1)
                        .next_multiple_of(PAGE_TOKENS);
                    let n = LAYERS * lanes.len() * kv_heads * ctx * DH;
                    let (mut kb, mut vb) = (vec![0.0; n], vec![0.0; n]);
                    cache
                        .gather(&lanes, ctx, &mut kb, &mut vb)
                        .map_err(|e| e.to_string())?;
                }
                // Sparse page selection: select touches accumulate.
                9 if !active.is_empty() => {
                    let id = *rng.choose(&active);
                    let _ = cache.select_seq_pages(id, &policy);
                }
                _ => cache.heat_tick(),
            }
            // Bit-exact at every step, across several top-k widths.
            let top_k = [0, 1, 4, PAGES][step % 4];
            check_report(&cache, top_k)?;
        }

        for id in active.drain(..) {
            cache.free_seq(id);
        }
        for p in retains.drain(..) {
            cache.release_page(p).map_err(|e| e.to_string())?;
        }
        // Drained pool: the report must agree that everything is free and
        // the lifetime totals survive page reuse.
        let rep = cache.report(None, 4);
        if rep.pool.pages_free != PAGES || rep.pool.pages_used != 0 {
            return Err("drained pool not reported as fully free".into());
        }
        if !rep.heat.hottest.is_empty() {
            return Err("hottest runs listed over an empty pool".into());
        }
        check_report(&cache, 4)
    });
}

#[test]
fn disabled_heat_reports_zero_touch_state() {
    // The bench baseline: a cache with the tracker disabled still builds
    // a valid report — pool and sharing sections live, heat section
    // all-zero.
    let mut rng = Rng::new(17);
    let mut cache = PagedKvCache::new(LAYERS, 2, DH, PAGE_TOKENS, PAGES);
    cache.disable_heat();
    let (k, v) = kv(&mut rng, 2, 2 * PAGE_TOKENS);
    cache.insert_seq(1, &k, &v, 2 * PAGE_TOKENS).unwrap();
    let ctx = 2 * PAGE_TOKENS;
    let n = LAYERS * 2 * ctx * DH;
    let (mut kb, mut vb) = (vec![0.0; n], vec![0.0; n]);
    cache.gather(&[Some(1)], ctx, &mut kb, &mut vb).unwrap();
    cache.heat_tick();

    let rep = cache.report(None, 8);
    assert_eq!(rep.pool.pages_used, 2);
    assert_eq!(rep.heat.clock, 0);
    assert_eq!(rep.heat.gather_touches_total, 0);
    assert_eq!(rep.heat.histogram, vec![2], "both pages in the cold bucket");
    assert_eq!(rep, recompute_report(&cache, 8));
    validate_cache_report(&rep.to_json()).unwrap();
    cache.free_seq(1);
}
