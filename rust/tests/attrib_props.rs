//! Property tests for the work-attribution plane (`obs::attrib`): the
//! single accounting function must agree **bit-exactly** with every
//! consumer that claims to do the same arithmetic — stream-K plans of
//! any strategy, rolled cascade task lists, and the paged KV cache's
//! gather byte counters (the numbers the engine exports as
//! `attrib_*_bytes_total` metrics). Any drift between these means a
//! perf-attribution report is lying about where the bytes went.

use lean_attention::coordinator::PagedKvCache;
use lean_attention::obs::attrib::{
    account_cascade_problem, account_cascade_tasks, account_decode_problem,
    account_plan, flat_gather_bytes, selected_gather_bytes,
    shared_gather_bytes,
};
use lean_attention::partition::planners::build_plan;
use lean_attention::partition::{
    build_cascade_plan, CascadeProblem, DecodeProblem, PrefixGroup, Strategy,
};
use lean_attention::runtime::attention_exec::{
    roll_cascade_tasks, rolled_kv_bytes,
};
use lean_attention::util::rng::Rng;
use lean_attention::util::testing::prop_check;

// ------------------------------------------------------- plan accounting

/// Work is a property of the *problem*, not of how a plan slices it:
/// every strategy covers each KV stream exactly once, so accounting a
/// plan segment-by-segment must reproduce the problem totals exactly.
#[test]
fn every_strategy_accounts_identically_to_its_problem() {
    prop_check("account_plan == account_decode_problem", 40, |rng| {
        let kv_heads = *rng.choose(&[1usize, 2, 4]);
        let heads = kv_heads * rng.urange(1, 4);
        let batch = rng.urange(1, 6);
        let lens: Vec<u32> =
            (0..batch).map(|_| rng.urange(1, 400) as u32).collect();
        let d = *rng.choose(&[8usize, 16, 32]);
        let tile = *rng.choose(&[16usize, 32, 64]);
        let p = DecodeProblem::ragged(heads, lens, d)
            .with_tile(tile)
            .with_kv_heads(kv_heads);
        let want = account_decode_problem(&p);
        if want.tiles != p.total_tiles() {
            return Err(format!(
                "problem accounting counts {} tiles, planner geometry says {}",
                want.tiles,
                p.total_tiles()
            ));
        }
        let slots = rng.urange(1, 80);
        for strategy in
            [Strategy::Dense, Strategy::StreamK, Strategy::fixed_split_auto(&p, slots)]
        {
            let plan = build_plan(&p, strategy, slots);
            plan.validate(&p).map_err(|e| format!("{strategy:?}: {e}"))?;
            let got = account_plan(&p, &plan);
            if got != want {
                return Err(format!(
                    "{strategy:?}: plan work {got:?} != problem work {want:?}"
                ));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------- cascade accounting

/// Rolled cascade tasks are the executor's ground truth of what it
/// gathers; the closed-form problem accounting must match them task for
/// task — including the KV-byte total `rolled_kv_bytes` reports.
#[test]
fn rolled_cascade_tasks_account_identically_to_the_problem() {
    prop_check("cascade tasks == cascade problem work", 40, |rng| {
        let kv_heads = *rng.choose(&[1usize, 2]);
        let heads = kv_heads * rng.urange(1, 4);
        let tile = *rng.choose(&[16usize, 32]);
        let d = 16;
        let batch = rng.urange(2, 7);
        let ctx_lens: Vec<u32> =
            (0..batch).map(|_| rng.urange(tile, 8 * tile) as u32).collect();
        // Disjoint prefix groups over consecutive lanes, random prefixes
        // (tile_aligned() floors them and drops sub-tile groups).
        let mut groups = Vec::new();
        let mut lane = 0;
        while lane + 1 < batch {
            let take = rng.urange(2, 4).min(batch - lane);
            if rng.chance(0.7) {
                let members: Vec<u32> =
                    (lane..lane + take).map(|m| m as u32).collect();
                let min_ctx = members
                    .iter()
                    .map(|&m| ctx_lens[m as usize])
                    .min()
                    .unwrap();
                let prefix_len = rng.range(1, u64::from(min_ctx) + 1) as u32;
                groups.push(PrefixGroup { prefix_len, members });
            }
            lane += take;
        }
        let p = CascadeProblem::new(heads, ctx_lens, d, groups)
            .map_err(|e| e.to_string())?
            .with_tile(tile)
            .with_kv_heads(kv_heads)
            .tile_aligned();
        let want = account_cascade_problem(&p);
        let cplan = build_cascade_plan(&p, rng.urange(1, 64));
        cplan
            .plan
            .validate(&cplan.segment_problem)
            .map_err(|e| e.to_string())?;
        let tasks = roll_cascade_tasks(&p, &cplan);
        let got = account_cascade_tasks(&p, &tasks);
        if got != want {
            return Err(format!(
                "task work {got:?} != problem work {want:?} \
                 ({} groups, tile {tile})",
                p.prefix_groups.len()
            ));
        }
        if rolled_kv_bytes(&tasks, d) as u64 != want.gathered_kv_bytes {
            return Err(format!(
                "rolled_kv_bytes {} != accounted bytes {}",
                rolled_kv_bytes(&tasks, d),
                want.gathered_kv_bytes
            ));
        }
        Ok(())
    });
}

// ------------------------------------------------- cache gather counters

const LAYERS: usize = 1;
const DH: usize = 4;
const PT: usize = 8;

fn token_pair(rng: &mut Rng, kv_heads: usize, tokens: usize) -> (Vec<f32>, Vec<f32>) {
    let n = LAYERS * kv_heads * DH * tokens;
    (rng.normal_vec(n), rng.normal_vec(n))
}

/// The engine's shared-gather byte counters (what `attrib_*_bytes_total`
/// accumulates) must equal the closed-form predictions bit-exactly, for
/// fork families with divergent suffixes, loner lanes and empty slots.
/// The predicted prefix group is the parent history floored to full
/// pages — copy-on-write keeps exactly those pages physically shared.
#[test]
fn cache_shared_gather_counters_match_attrib_predictions_bit_exactly() {
    prop_check("gather_shared == attrib prediction", 25, |rng| {
        let kv_heads = *rng.choose(&[1usize, 2]);
        let mut cache = PagedKvCache::new(LAYERS, kv_heads, DH, PT, 512);
        let mut slots: Vec<Option<u64>> = Vec::new();
        let mut groups: Vec<PrefixGroup> = Vec::new();
        let mut next_id = 0u64;
        for _family in 0..rng.urange(1, 4) {
            let history = rng.urange(1, 5 * PT);
            let siblings = rng.urange(2, 5);
            let parent = next_id;
            next_id += 1;
            let (k, v) = token_pair(rng, kv_heads, history);
            cache.insert_seq(parent, &k, &v, history).map_err(|e| e.to_string())?;
            // Fork the whole family before anyone appends, so the shared
            // history is exactly `history` tokens.
            let mut ids = vec![parent];
            for _ in 1..siblings {
                let child = next_id;
                next_id += 1;
                cache.fork_seq(parent, child).map_err(|e| e.to_string())?;
                ids.push(child);
            }
            let mut members = Vec::new();
            for id in ids {
                members.push(slots.len() as u32);
                slots.push(Some(id));
                for _ in 0..rng.urange(0, 2 * PT) {
                    let (tk, tv) = token_pair(rng, kv_heads, 1);
                    cache.append_token(id, &tk, &tv).map_err(|e| e.to_string())?;
                }
            }
            groups.push(PrefixGroup {
                prefix_len: ((history / PT) * PT) as u32,
                members,
            });
        }
        // Loner lanes and holes: flat traffic only, no sharing.
        for _ in 0..rng.urange(0, 5) {
            if rng.chance(0.3) {
                slots.push(None);
                continue;
            }
            let len = rng.urange(1, 4 * PT);
            let id = next_id;
            next_id += 1;
            let (k, v) = token_pair(rng, kv_heads, len);
            cache.insert_seq(id, &k, &v, len).map_err(|e| e.to_string())?;
            slots.push(Some(id));
        }

        let lens: Vec<u32> = slots
            .iter()
            .map(|s| s.map_or(0, |id| cache.seq_len(id).unwrap() as u32))
            .collect();
        let tb = cache.token_bytes();
        let sg = cache.gather_shared(&slots).map_err(|e| e.to_string())?;
        if sg.flat_bytes as u64 != flat_gather_bytes(&lens, tb) {
            return Err(format!(
                "flat: cache counted {} bytes, attrib predicts {}",
                sg.flat_bytes,
                flat_gather_bytes(&lens, tb)
            ));
        }
        let want = shared_gather_bytes(&lens, &groups, tb);
        if sg.shared_bytes as u64 != want {
            return Err(format!(
                "shared: cache counted {} bytes, attrib predicts {want} \
                 (lens {lens:?}, groups {groups:?})",
                sg.shared_bytes
            ));
        }
        Ok(())
    });
}

/// Sparse selection byte counters: over independent lanes (no physical
/// page sharing) the selected gather streams exactly the selected
/// tokens of each lane, and its `flat_bytes` still reports the dense
/// traffic the selection avoided — both closed forms in `obs::attrib`.
#[test]
fn cache_selected_gather_counters_match_attrib_predictions_bit_exactly() {
    prop_check("gather_selected == attrib prediction", 25, |rng| {
        let kv_heads = *rng.choose(&[1usize, 2]);
        let mut cache = PagedKvCache::new(LAYERS, kv_heads, DH, PT, 512);
        let batch = rng.urange(1, 7);
        let mut slots: Vec<Option<u64>> = Vec::new();
        let mut sels: Vec<Vec<usize>> = Vec::new();
        let mut lens: Vec<usize> = Vec::new();
        for id in 0..batch as u64 {
            let len = rng.urange(1, 6 * PT);
            let (k, v) = token_pair(rng, kv_heads, len);
            cache.insert_seq(id, &k, &v, len).map_err(|e| e.to_string())?;
            // Random ascending page subset; may be empty (lane skipped).
            let used = len.div_ceil(PT);
            let sel: Vec<usize> =
                (0..used).filter(|_| rng.chance(0.5)).collect();
            slots.push(Some(id));
            sels.push(sel);
            lens.push(len);
        }
        let tb = cache.token_bytes();
        let sg = cache.gather_selected(&slots, &sels).map_err(|e| e.to_string())?;
        let lens32: Vec<u32> = lens.iter().map(|&l| l as u32).collect();
        if sg.flat_bytes as u64 != flat_gather_bytes(&lens32, tb) {
            return Err(format!(
                "dense side: cache counted {} bytes, attrib predicts {}",
                sg.flat_bytes,
                flat_gather_bytes(&lens32, tb)
            ));
        }
        let want: u64 = lens
            .iter()
            .zip(&sels)
            .map(|(&len, sel)| selected_gather_bytes(len, PT, sel, tb))
            .sum();
        if sg.shared_bytes as u64 != want {
            return Err(format!(
                "selected side: cache counted {} bytes, attrib predicts \
                 {want} (lens {lens:?}, sels {sels:?})",
                sg.shared_bytes
            ));
        }
        Ok(())
    });
}
