//! Fig 9 bench: 8xA100 tensor-parallel speedups (256+ heads, 1k-1M ctx).
use lean_attention::bench_harness::figures::fig09_multigpu;
fn main() {
    for (i, t) in fig09_multigpu().iter().enumerate() {
        t.emit(&format!("fig09{}", ['a', 'b', 'c', 'd'][i]));
    }
}
