//! Fig 11 bench: head-dim-128 model family (LLaMA-2 / Mistral / Phi-3).
use lean_attention::bench_harness::figures::fig11_headdim128;
fn main() {
    fig11_headdim128().emit("fig11");
}
