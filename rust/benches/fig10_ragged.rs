//! Fig 10 bench: ragged-batch speedup vs batch-context ratio.
use lean_attention::bench_harness::figures::fig10_ragged;
fn main() {
    fig10_ragged().emit("fig10");
}
