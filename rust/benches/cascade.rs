//! Shared-prefix (cascade) decode: modeled KV traffic + simulated latency
//! vs the flat stream-K plan, a host-exec microbench of the cascade
//! reduction path, and the flat-lean vs cascade **execution** comparison
//! (gathered KV bytes + wall-clock) through the partial-attention driver —
//! over the PJRT artifacts when built, the host oracle otherwise.
//!
//! ```sh
//! cargo bench --bench cascade            # full run
//! cargo bench --bench cascade -- --smoke # CI smoke: small cases, fast
//! ```

use std::path::Path;
use std::rc::Rc;

use lean_attention::bench_harness::cascade_exec::{compare_exec, ExecCase};
use lean_attention::bench_harness::runner::{bench, save};
use lean_attention::bench_harness::Table;
use lean_attention::partition::cascade::{
    build_cascade_plan, execute_cascade_host, CascadeProblem, CascadeTensors,
    PrefixGroup,
};
use lean_attention::partition::plan::Strategy;
use lean_attention::runtime::{AttentionExecutor, Manifest, Runtime};
use lean_attention::sim::cascade::simulate_cascade;
use lean_attention::sim::schedule::simulate;
use lean_attention::sim::GpuArch;
use lean_attention::util::timer::black_box;

fn shared_batch(batch: usize, prefix: u32, suffix: u32, heads: usize) -> CascadeProblem {
    CascadeProblem::new(
        heads,
        vec![prefix + suffix; batch],
        64,
        vec![PrefixGroup {
            prefix_len: prefix,
            members: (0..batch as u32).collect(),
        }],
    )
    .expect("valid cascade problem")
}

/// Executors for the exec comparison: the PJRT artifact path when
/// `artifacts/manifest.json` exists, the host-oracle path otherwise.
fn attention_executor() -> Option<AttentionExecutor> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        return None;
    }
    let runtime = Rc::new(Runtime::cpu().ok()?);
    let manifest = Rc::new(Manifest::load(dir).ok()?);
    Some(AttentionExecutor::new(runtime, manifest))
}

fn main() {
    // `--smoke` (after `--` with cargo bench) shrinks the sweep so CI can
    // exercise the whole bench path in seconds; `--seed N` makes every
    // randomized case reproduce run-to-run (default 0, like the CLI
    // bench subcommands).
    let argv: Vec<String> = std::env::args().collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let seed: u64 = argv
        .iter()
        .position(|a| a == "--seed")
        .and_then(|i| argv.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    let arch = GpuArch::a100();

    // --- modeled traffic + latency sweep over batch size ----------------
    let mut t = Table::new(
        "cascade vs flat stream-K (A100, 32 heads, 64k shared prefix + 2k suffix)",
        &[
            "batch",
            "flat_KV_MiB",
            "cascade_KV_MiB",
            "bytes_saved",
            "flat_us",
            "cascade_us",
            "speedup",
        ],
    );
    for batch in [1usize, 2, 4, 8, 16, 32] {
        let p = shared_batch(batch, 65_536, 2_048, 32);
        let r = simulate_cascade(&p, &arch);
        let flat = simulate(&p.baseline_problem(), Strategy::StreamK, &arch);
        t.row(vec![
            batch.to_string(),
            format!("{:.1}", r.baseline_kv_bytes / (1024.0 * 1024.0)),
            format!("{:.1}", r.kv_bytes / (1024.0 * 1024.0)),
            format!("{:.1}%", r.bytes_saved_fraction() * 100.0),
            format!("{:.1}", flat.latency_us),
            format!("{:.1}", r.latency_us),
            format!("{:.2}x", flat.latency_us / r.latency_us),
        ]);
    }
    t.note("shared prefix KV is streamed once per group, not once per sequence");
    t.note("batch 1 shares with nobody: bytes and latency match the flat plan");
    t.emit("cascade_sweep");

    // --- prefix-length sweep at fixed batch -----------------------------
    let mut t2 = Table::new(
        "savings vs shared-prefix length (A100, batch 8, 32 heads, 2k suffix)",
        &["prefix_tokens", "bytes_saved", "speedup_vs_flat"],
    );
    for prefix in [1_024u32, 4_096, 16_384, 65_536, 262_144] {
        let p = shared_batch(8, prefix, 2_048, 32);
        let r = simulate_cascade(&p, &arch);
        let flat = simulate(&p.baseline_problem(), Strategy::StreamK, &arch);
        t2.row(vec![
            prefix.to_string(),
            format!("{:.1}%", r.bytes_saved_fraction() * 100.0),
            format!("{:.2}x", flat.latency_us / r.latency_us),
        ]);
    }
    t2.emit("cascade_prefix_sweep");

    // --- host-path microbench: plan + execute + merge -------------------
    let mut results = Vec::new();
    let micro_cases: &[(usize, u32, u32)] = if smoke {
        &[(4, 512, 128)]
    } else {
        &[(4, 512, 128), (8, 1024, 128)]
    };
    let micro_iters = if smoke { 3 } else { 20 };
    for &(batch, prefix, suffix) in micro_cases {
        let p = shared_batch(batch, prefix, suffix, 2).with_tile(64);
        let tens = CascadeTensors::random(&p, seed ^ 3);
        let cplan = build_cascade_plan(&p, 216);
        results.push(bench(
            &format!("cascade_host_b{batch}_p{prefix}_s{suffix}"),
            micro_iters,
            || {
                black_box(execute_cascade_host(&cplan, &p, &tens, None));
            },
        ));
        results.push(bench(&format!("cascade_plan_b{batch}_p{prefix}"), 50, || {
            black_box(build_cascade_plan(&p, 216));
        }));
    }
    save("cascade", &results);

    // --- execution: flat-lean vs cascade over the same numbers ----------
    // Both paths run the same task-rolling + group-broadcast-fold driver;
    // only the prefix structure differs, so the byte and latency gaps are
    // the cascade mechanism itself. With artifacts the partials go through
    // the PJRT `attn_partial` kernel, otherwise the host oracle.
    let exec = attention_executor();
    let backend = if exec.is_some() { "pjrt artifacts" } else { "host oracle" };
    let mut t3 = Table::new(
        format!("flat-lean vs cascade execution ({backend})"),
        &[
            "batch",
            "prefix",
            "suffix",
            "flat_KV_KiB",
            "cascade_KV_KiB",
            "bytes_saved",
            "flat_us",
            "cascade_us",
            "speedup",
            "max_err",
        ],
    );
    let exec_iters = if smoke { 2 } else { 10 };
    let exec_cases: &[(usize, u32, u32)] = if smoke {
        &[(2, 64, 32), (4, 128, 32)]
    } else {
        &[(2, 256, 64), (4, 512, 64), (8, 1024, 128)]
    };
    for &(batch, prefix, suffix) in exec_cases {
        // d=64/tile=256 matches the artifact buckets; the smoke/host run
        // uses a small head_dim + tile so it stays fast.
        let case = if exec.is_some() {
            ExecCase { batch, prefix: prefix.max(256), suffix, heads: 1, head_dim: 64, tile: 256, slots: 64 }
        } else {
            ExecCase { batch, prefix, suffix, heads: 2, head_dim: 16, tile: 32, slots: 64 }
        };
        let c = compare_exec(case, exec_iters, exec.as_ref(), seed)
            .expect("exec comparison");
        assert!(
            c.cascade_kv_bytes < c.flat_kv_bytes,
            "cascade must gather fewer KV bytes on shared batches \
             ({} vs {})",
            c.cascade_kv_bytes,
            c.flat_kv_bytes
        );
        assert!(
            c.max_err < 1e-3,
            "flat and cascade outputs diverged: {}",
            c.max_err
        );
        t3.row(vec![
            case.batch.to_string(),
            case.prefix.to_string(),
            case.suffix.to_string(),
            format!("{:.1}", c.flat_kv_bytes as f64 / 1024.0),
            format!("{:.1}", c.cascade_kv_bytes as f64 / 1024.0),
            format!("{:.1}%", c.bytes_saved_fraction() * 100.0),
            format!("{:.1}", c.flat_us.p50),
            format!("{:.1}", c.cascade_us.p50),
            format!("{:.2}x", c.flat_us.p50 / c.cascade_us.p50),
            format!("{:.1e}", c.max_err),
        ]);
    }
    t3.note("gathered KV bytes are what each path reads from its KV streams");
    t3.note("shared prefix slices are materialized once per group task");
    t3.emit("cascade_exec");
}
