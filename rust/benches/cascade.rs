//! Shared-prefix (cascade) decode: modeled KV traffic + simulated latency
//! vs the flat stream-K plan, and a host-exec microbench of the cascade
//! reduction path.
//!
//! ```sh
//! cargo bench --bench cascade
//! ```

use lean_attention::bench_harness::runner::{bench, save};
use lean_attention::bench_harness::Table;
use lean_attention::partition::cascade::{
    build_cascade_plan, execute_cascade_host, CascadeProblem, CascadeTensors,
    PrefixGroup,
};
use lean_attention::partition::plan::Strategy;
use lean_attention::sim::cascade::simulate_cascade;
use lean_attention::sim::schedule::simulate;
use lean_attention::sim::GpuArch;
use lean_attention::util::timer::black_box;

fn shared_batch(batch: usize, prefix: u32, suffix: u32, heads: usize) -> CascadeProblem {
    CascadeProblem::new(
        heads,
        vec![prefix + suffix; batch],
        64,
        vec![PrefixGroup {
            prefix_len: prefix,
            members: (0..batch as u32).collect(),
        }],
    )
    .expect("valid cascade problem")
}

fn main() {
    let arch = GpuArch::a100();

    // --- modeled traffic + latency sweep over batch size ----------------
    let mut t = Table::new(
        "cascade vs flat stream-K (A100, 32 heads, 64k shared prefix + 2k suffix)",
        &[
            "batch",
            "flat_KV_MiB",
            "cascade_KV_MiB",
            "bytes_saved",
            "flat_us",
            "cascade_us",
            "speedup",
        ],
    );
    for batch in [1usize, 2, 4, 8, 16, 32] {
        let p = shared_batch(batch, 65_536, 2_048, 32);
        let r = simulate_cascade(&p, &arch);
        let flat = simulate(&p.baseline_problem(), Strategy::StreamK, &arch);
        t.row(vec![
            batch.to_string(),
            format!("{:.1}", r.baseline_kv_bytes / (1024.0 * 1024.0)),
            format!("{:.1}", r.kv_bytes / (1024.0 * 1024.0)),
            format!("{:.1}%", r.bytes_saved_fraction() * 100.0),
            format!("{:.1}", flat.latency_us),
            format!("{:.1}", r.latency_us),
            format!("{:.2}x", flat.latency_us / r.latency_us),
        ]);
    }
    t.note("shared prefix KV is streamed once per group, not once per sequence");
    t.note("batch 1 shares with nobody: bytes and latency match the flat plan");
    t.emit("cascade_sweep");

    // --- prefix-length sweep at fixed batch -----------------------------
    let mut t2 = Table::new(
        "savings vs shared-prefix length (A100, batch 8, 32 heads, 2k suffix)",
        &["prefix_tokens", "bytes_saved", "speedup_vs_flat"],
    );
    for prefix in [1_024u32, 4_096, 16_384, 65_536, 262_144] {
        let p = shared_batch(8, prefix, 2_048, 32);
        let r = simulate_cascade(&p, &arch);
        let flat = simulate(&p.baseline_problem(), Strategy::StreamK, &arch);
        t2.row(vec![
            prefix.to_string(),
            format!("{:.1}%", r.bytes_saved_fraction() * 100.0),
            format!("{:.2}x", flat.latency_us / r.latency_us),
        ]);
    }
    t2.emit("cascade_prefix_sweep");

    // --- host-path microbench: plan + execute + merge -------------------
    let mut results = Vec::new();
    for (batch, prefix, suffix) in [(4usize, 512u32, 128u32), (8, 1024, 128)] {
        let p = shared_batch(batch, prefix, suffix, 2).with_tile(64);
        let tens = CascadeTensors::random(&p, 3);
        let cplan = build_cascade_plan(&p, 216);
        results.push(bench(
            &format!("cascade_host_b{batch}_p{prefix}_s{suffix}"),
            20,
            || {
                black_box(execute_cascade_host(&cplan, &p, &tens, None));
            },
        ));
        results.push(bench(&format!("cascade_plan_b{batch}_p{prefix}"), 50, || {
            black_box(build_cascade_plan(&p, 216));
        }));
    }
    save("cascade", &results);
}
