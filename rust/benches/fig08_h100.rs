//! Fig 8 bench: H100 speedups vs context / heads / batch (d=64).
use lean_attention::bench_harness::figures::fig08_h100;
fn main() {
    for (i, t) in fig08_h100().iter().enumerate() {
        t.emit(&format!("fig08{}", ['a', 'b', 'c'][i]));
    }
}
