//! Fig 12 bench: end-to-end Phi-3 Medium speedup (8:1 prompt:output).
use lean_attention::bench_harness::figures::fig12_e2e;
fn main() {
    fig12_e2e().emit("fig12");
}
