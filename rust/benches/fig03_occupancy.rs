//! Fig 3 bench: SM occupancy LA vs FD (56 heads, BS 1, A100).
use lean_attention::bench_harness::figures::fig03_occupancy;
use lean_attention::bench_harness::runner::{bench, save};
fn main() {
    fig03_occupancy().emit("fig03");
    let r = bench("fig03_generation", 5, || {
        std::hint::black_box(fig03_occupancy());
    });
    save("fig03", &[r]);
}
