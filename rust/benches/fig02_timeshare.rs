//! Fig 2 bench: regenerate the prefill/decode timeshare table and time
//! the analytic model itself.
use lean_attention::bench_harness::figures::fig02_timeshare;
use lean_attention::bench_harness::runner::{bench, save};
fn main() {
    fig02_timeshare().emit("fig02");
    let r = bench("fig02_generation", 5, || {
        std::hint::black_box(fig02_timeshare());
    });
    save("fig02", &[r]);
}
