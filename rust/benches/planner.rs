//! Microbench: plan construction (per-step scheduling cost in the
//! engine hot loop). Perf-pass target in EXPERIMENTS.md §Perf.

use lean_attention::bench_harness::runner::{bench, save};
use lean_attention::partition::plan::{build_plan, DecodeProblem, Strategy};
use lean_attention::util::timer::black_box;

fn main() {
    let mut results = Vec::new();
    for (b, h, ctx) in [(4usize, 32usize, 65_536usize), (8, 56, 262_144), (32, 128, 1 << 20)] {
        let p = DecodeProblem::uniform(b, h, ctx, 64);
        for (label, s) in [
            ("stream_k", Strategy::StreamK),
            ("fixed_split_auto", Strategy::fixed_split_auto(&p, 108)),
            ("dense", Strategy::Dense),
        ] {
            results.push(bench(
                &format!("{label}_b{b}_h{h}_ctx{ctx}"),
                100,
                || {
                    black_box(build_plan(&p, s, 216));
                },
            ));
        }
    }
    // ragged planning (engine path builds one per decode step)
    let ragged = DecodeProblem::ragged(32, (1..=32).map(|i| i * 4096).collect(), 64);
    results.push(bench("stream_k_ragged_b32", 100, || {
        black_box(build_plan(&ragged, Strategy::StreamK, 216));
    }));
    save("planner", &results);
}
