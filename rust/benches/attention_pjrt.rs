//! PJRT attention wall-clock on this CPU: fused kernel vs the stream-K
//! partial path (plumbing cost; exactness is asserted). Self-skips when
//! artifacts are absent. Perf-pass subject in EXPERIMENTS.md §Perf.

use std::rc::Rc;

use lean_attention::bench_harness::runner::{bench, save};
use lean_attention::partition::plan::{build_plan, DecodeProblem, Strategy};
use lean_attention::runtime::attention_exec::AttentionProblem;
use lean_attention::runtime::{AttentionExecutor, Manifest, Runtime};
use lean_attention::util::rng::Rng;

fn main() {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        println!("skipping attention_pjrt: artifacts not built");
        return;
    }
    let runtime = Rc::new(Runtime::cpu().expect("pjrt"));
    let manifest = Rc::new(Manifest::load(dir).expect("manifest"));
    let exec = AttentionExecutor::new(runtime, manifest);

    let mut results = Vec::new();
    for (g, n) in [(8usize, 1024usize), (16, 4096)] {
        let d = 64;
        let mut rng = Rng::new(3);
        let q = rng.normal_vec(g * d);
        let k = rng.normal_vec(g * n * d);
        let v = rng.normal_vec(g * n * d);
        let lens: Vec<u32> = vec![n as u32; g];
        let ap = AttentionProblem { q: &q, k: &k, v: &v, lens: &lens, g, n, d };

        results.push(bench(&format!("pjrt_full_g{g}_n{n}"), 5, || {
            std::hint::black_box(exec.full(&ap).expect("full"));
        }));

        let problem = DecodeProblem {
            heads: 1,
            kv_heads: 1,
            head_dim: d,
            ctx_lens: lens.clone(),
            tile: 256,
        };
        let plan = build_plan(&problem, Strategy::StreamK, 216);
        results.push(bench(&format!("pjrt_lean_g{g}_n{n}"), 5, || {
            std::hint::black_box(exec.lean(&ap, &plan).expect("lean"));
        }));
    }
    save("attention_pjrt", &results);
}
