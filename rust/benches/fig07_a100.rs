//! Fig 7 bench: A100 speedups vs context / heads / batch (d=64).
use lean_attention::bench_harness::figures::fig07_a100;
fn main() {
    for (i, t) in fig07_a100().iter().enumerate() {
        t.emit(&format!("fig07{}", ['a', 'b', 'c'][i]));
    }
}
