//! Microbench: the softmax re-scaling reduction (the L3 hot path).
//! Perf-pass target recorded in EXPERIMENTS.md §Perf.

use lean_attention::attention::Partials;
use lean_attention::bench_harness::runner::{bench, save};
use lean_attention::util::rng::Rng;
use lean_attention::util::timer::black_box;

fn random_partials(rng: &mut Rng, g: usize, d: usize) -> Partials {
    Partials::from_flat(
        g,
        d,
        rng.normal_vec(g * d),
        &rng.normal_vec(g),
        &rng.normal_vec(g).iter().map(|x| x.abs() + 0.1).collect::<Vec<_>>(),
    )
}

fn main() {
    let mut results = Vec::new();
    for (g, d) in [(32usize, 64usize), (128, 64), (128, 128), (1024, 64)] {
        let mut rng = Rng::new(7);
        let parts: Vec<Partials> = (0..16).map(|_| random_partials(&mut rng, g, d)).collect();
        results.push(bench(
            &format!("reduce_16_partials_g{g}_d{d}"),
            50,
            || {
                let mut acc = Partials::identity(g, d);
                for p in &parts {
                    acc.reduce_from(p);
                }
                black_box(&acc);
            },
        ));
        let one = random_partials(&mut rng, g, d);
        results.push(bench(&format!("finalize_g{g}_d{d}"), 50, || {
            black_box(one.clone().finalize());
        }));
    }
    save("reduction", &results);
}
