//! Fig 13 bench: attention energy relative to FlashDecoding.
use lean_attention::bench_harness::figures::fig13_energy;
fn main() {
    fig13_energy().emit("fig13");
}
