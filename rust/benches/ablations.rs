//! Ablation benches for the design choices DESIGN.md calls out:
//! LeanTile granularity (§IV-B), co-resident CTAs per SM (Eq. 2),
//! FlashInfer page size (§V), and the mixed prefill+decode extension.
use lean_attention::bench_harness::figures::{
    ablation_ctas_per_sm, ablation_fi_page, ablation_lean_tile, mixed_phase_batching,
};
fn main() {
    ablation_lean_tile().emit("ablation_lean_tile");
    ablation_ctas_per_sm().emit("ablation_ctas_per_sm");
    ablation_fi_page().emit("ablation_fi_page");
    mixed_phase_batching().emit("ext_mixed_phase");
}
