//! Draft trees: several candidate continuations sharing a prefix.
//!
//! Chain drafting speculates one continuation; tree drafting hedges
//! across several (e.g. the n-gram self-draft *and* a smaller-model
//! rollout), deduplicating their shared prefixes so each distinct token
//! is scored once by the multi-query verify pass. Node lineage rides the
//! PR 3 [`ForkTree`] — a draft node is a (virtual) fork of its parent at
//! depth `d`, the same parent/child/fork-point bookkeeping the engine
//! uses for real KV forks — plus a per-node token table.

use std::collections::HashMap;

use crate::sampling::ForkTree;

/// A tree of drafted continuation tokens. The root is the sequence's
/// current state and carries no token; every other node proposes one
/// token extending its parent's path.
#[derive(Debug, Default)]
pub struct DraftTree {
    lineage: ForkTree,
    tokens: HashMap<u64, i32>,
    next: u64,
}

impl DraftTree {
    /// The root node id (the sequence's current state).
    pub const ROOT: u64 = 0;

    pub fn new() -> DraftTree {
        DraftTree::default()
    }

    /// Number of draft nodes (root excluded).
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Depth of a node (root = 0).
    pub fn depth(&self, id: u64) -> usize {
        if id == Self::ROOT {
            0
        } else {
            self.lineage
                .fork_point(id)
                .map(|fp| fp.token_len)
                .unwrap_or(0)
        }
    }

    /// The token a node proposes (`None` for the root).
    pub fn token(&self, id: u64) -> Option<i32> {
        self.tokens.get(&id).copied()
    }

    /// Direct children of `id`, in insertion order.
    pub fn children_of(&self, id: u64) -> &[u64] {
        self.lineage.children_of(id)
    }

    /// The child of `parent` proposing `token`, if any.
    pub fn child_with_token(&self, parent: u64, token: i32) -> Option<u64> {
        self.lineage
            .children_of(parent)
            .iter()
            .copied()
            .find(|&c| self.tokens.get(&c) == Some(&token))
    }

    /// Add a child of `parent` proposing `token`; returns its id.
    pub fn add_child(&mut self, parent: u64, token: i32) -> u64 {
        assert!(
            parent == Self::ROOT || self.tokens.contains_key(&parent),
            "unknown parent node {parent}"
        );
        self.next += 1;
        let id = self.next;
        self.lineage.register(parent, id, self.depth(parent) + 1);
        self.tokens.insert(id, token);
        id
    }

    /// Add a whole chain from the root, reusing existing nodes for any
    /// already-drafted prefix (this is what deduplicates several
    /// drafters' agreeing prefixes). Returns the node ids along the
    /// chain.
    pub fn add_chain(&mut self, chain: &[i32]) -> Vec<u64> {
        let mut cur = Self::ROOT;
        let mut ids = Vec::with_capacity(chain.len());
        for &t in chain {
            cur = match self.child_with_token(cur, t) {
                Some(c) => c,
                None => self.add_child(cur, t),
            };
            ids.push(cur);
        }
        ids
    }

    /// Every draft node id, in creation order (stable across runs —
    /// this fixes the verify pass's query-row order).
    pub fn nodes(&self) -> Vec<u64> {
        (1..=self.next).filter(|id| self.tokens.contains_key(id)).collect()
    }

    /// Leaf nodes (draft nodes with no children), in creation order.
    pub fn leaves(&self) -> Vec<u64> {
        self.nodes()
            .into_iter()
            .filter(|&id| self.lineage.children_of(id).is_empty())
            .collect()
    }

    /// Root-to-node token path (empty for the root).
    pub fn path_tokens(&self, id: u64) -> Vec<i32> {
        let mut out = Vec::new();
        let mut cur = id;
        while cur != Self::ROOT {
            out.push(self.tokens[&cur]);
            cur = self
                .lineage
                .fork_point(cur)
                .expect("non-root draft nodes have parents")
                .parent;
        }
        out.reverse();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chains_share_prefixes() {
        let mut t = DraftTree::new();
        assert!(t.is_empty());
        let a = t.add_chain(&[1, 2, 3]);
        let b = t.add_chain(&[1, 2, 4]);
        assert_eq!(t.len(), 4, "prefix [1, 2] deduplicated");
        assert_eq!(a[0], b[0]);
        assert_eq!(a[1], b[1]);
        assert_ne!(a[2], b[2]);
        assert_eq!(t.path_tokens(a[2]), vec![1, 2, 3]);
        assert_eq!(t.path_tokens(b[2]), vec![1, 2, 4]);
        assert_eq!(t.depth(a[2]), 3);
        assert_eq!(t.leaves(), vec![a[2], b[2]]);
    }

    #[test]
    fn child_lookup_and_tokens() {
        let mut t = DraftTree::new();
        let ids = t.add_chain(&[5, 6]);
        assert_eq!(t.child_with_token(DraftTree::ROOT, 5), Some(ids[0]));
        assert_eq!(t.child_with_token(DraftTree::ROOT, 6), None);
        assert_eq!(t.child_with_token(ids[0], 6), Some(ids[1]));
        assert_eq!(t.token(ids[1]), Some(6));
        assert_eq!(t.token(DraftTree::ROOT), None);
        assert_eq!(t.path_tokens(DraftTree::ROOT), Vec::<i32>::new());
        assert_eq!(t.children_of(DraftTree::ROOT), &[ids[0]]);
    }

    #[test]
    fn nodes_enumerate_in_creation_order() {
        let mut t = DraftTree::new();
        t.add_chain(&[9]);
        t.add_chain(&[9, 8]);
        t.add_chain(&[7]);
        let nodes = t.nodes();
        assert_eq!(nodes.len(), 3);
        assert!(nodes.windows(2).all(|w| w[0] < w[1]));
    }
}
