//! Draft sources: where speculative tokens come from.
//!
//! A [`DraftSource`] proposes `k` continuation tokens for a sequence's
//! history. Two built-in drafters cover the common serving deployments:
//!
//! * [`NGramDrafter`] — a **self-drafter**: suffix lookup over the
//!   sequence's own history (find the most recent earlier occurrence of
//!   the trailing n-gram, propose what followed it). Needs no second
//!   model, costs O(history) per step, and is highly effective on
//!   repetitive workloads — retrieval answers, code, templated text.
//! * [`ModelDrafter`] — a **smaller-model drafter**: greedy rollout of a
//!   cheaper [`TokenModel`]. [`ModelDrafter::from_config`] configures one
//!   from an existing [`crate::model::ModelConfig`], so draft quality can
//!   be traded against draft cost along the usual model-size axis.
//!
//! Draft quality only affects *speed* (acceptance rate), never
//! correctness: the verifier ([`super::accept`]) commits exactly the
//! sequential sampler's stream regardless of what was proposed.

use crate::model::ModelConfig;
use crate::sampling::{sample_token, SamplingParams};
use crate::util::rng::{splitmix64, Rng};

/// A source of speculative draft tokens.
pub trait DraftSource {
    /// Short human-readable identifier (`"ngram"`, `"model"`, ...).
    fn name(&self) -> &'static str;

    /// Propose up to `k` continuation tokens for `history` (the prompt
    /// plus everything committed so far). May return fewer than `k`;
    /// callers treat a short draft as a smaller speculation window.
    fn draft(&mut self, history: &[i32], k: usize) -> Vec<i32>;
}

/// Which built-in drafter to use (CLI/engine configuration surface).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DraftKind {
    /// Suffix-lookup self-drafting ([`NGramDrafter`]); no second model.
    NGram,
    /// Greedy rollout of a smaller synthetic model ([`ModelDrafter`]).
    Model,
}

impl DraftKind {
    /// Parse a CLI spelling.
    pub fn parse(s: &str) -> Option<DraftKind> {
        match s {
            "ngram" => Some(DraftKind::NGram),
            "model" => Some(DraftKind::Model),
            _ => None,
        }
    }

    /// Build the drafter this kind names, for a `vocab`-sized target.
    pub fn build(self, vocab: usize, seed: u64) -> Box<dyn DraftSource> {
        match self {
            DraftKind::NGram => Box::new(NGramDrafter::default()),
            DraftKind::Model => Box::new(ModelDrafter {
                model: SyntheticModel::new(vocab, seed ^ 0xD8AF_7E11, 4.0),
            }),
        }
    }
}

impl std::fmt::Display for DraftKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DraftKind::NGram => write!(f, "ngram"),
            DraftKind::Model => write!(f, "model"),
        }
    }
}

/// Suffix-lookup self-drafter: match the trailing `n`-gram (longest
/// first) against earlier history and propose the tokens that followed
/// its most recent occurrence. When the continuation runs off the end of
/// history it self-extends (reads its own proposal), so a perfectly
/// periodic sequence drafts its full period.
#[derive(Clone, Copy, Debug)]
pub struct NGramDrafter {
    /// Longest trailing n-gram to match (tried first).
    pub max_n: usize,
    /// Shortest n-gram worth matching.
    pub min_n: usize,
}

impl Default for NGramDrafter {
    fn default() -> Self {
        NGramDrafter { max_n: 4, min_n: 1 }
    }
}

impl DraftSource for NGramDrafter {
    fn name(&self) -> &'static str {
        "ngram"
    }

    fn draft(&mut self, history: &[i32], k: usize) -> Vec<i32> {
        if k == 0 || history.is_empty() {
            return Vec::new();
        }
        let len = history.len();
        let hi = self.max_n.max(self.min_n).min(len.saturating_sub(1));
        let lo = self.min_n.max(1);
        for n in (lo..=hi).rev() {
            let pat = &history[len - n..];
            // Most recent earlier occurrence with a continuation token.
            let found = (0..len - n).rev().find(|&p| &history[p..p + n] == pat);
            if let Some(p) = found {
                let start = p + n;
                let mut out = Vec::with_capacity(k);
                for j in 0..k {
                    let q = start + j;
                    // Past the end of history the draft continues itself.
                    let t = if q < len { history[q] } else { out[q - len] };
                    out.push(t);
                }
                return out;
            }
        }
        // No match anywhere: propose repeating the last token.
        vec![*history.last().unwrap(); k]
    }
}

/// A next-token logit model the host pipeline can query directly — the
/// target of the host speculative decoder and the substrate of the
/// smaller-model drafter. (The engine's target is the PJRT model
/// artifact; this trait is its artifact-free stand-in.)
pub trait TokenModel {
    fn vocab(&self) -> usize;

    /// Raw next-token logits after `history` (`history` non-empty).
    fn logits(&self, history: &[i32]) -> Vec<f32>;
}

/// Deterministic synthetic language model: hash-noise bigram logits plus
/// an induction-head bonus (the token that followed the most recent
/// earlier occurrence of the current token gets `sharpness` extra
/// logit). With `sharpness` above the noise range the model locks onto
/// repetition — a workload where self-drafting shines, and a target
/// whose behaviour is reproducible from `(vocab, seed)` alone.
#[derive(Clone, Copy, Debug)]
pub struct SyntheticModel {
    vocab: usize,
    seed: u64,
    sharpness: f32,
}

impl SyntheticModel {
    /// `sharpness` is the induction-signal strength in logits (base
    /// noise spans `[-1, 1]`; values above ~2 make repetition dominant).
    pub fn new(vocab: usize, seed: u64, sharpness: f32) -> SyntheticModel {
        assert!(vocab >= 2, "vocab must be >= 2");
        assert!(sharpness >= 0.0);
        SyntheticModel { vocab, seed, sharpness }
    }

    /// Configure from a transformer config: vocab carries over and the
    /// induction signal sharpens with depth, so a deeper config stands
    /// in for a stronger (and costlier) model.
    pub fn from_config(cfg: &ModelConfig, seed: u64) -> SyntheticModel {
        let sharpness = (2.0 + cfg.n_layers as f32 * 0.25).min(12.0);
        SyntheticModel::new(cfg.vocab, seed, sharpness)
    }
}

/// Deterministic uniform in `[0, 1)` from a hash seed.
fn unit(seed: u64) -> f32 {
    let mut s = seed;
    (splitmix64(&mut s) >> 40) as f32 / (1u64 << 24) as f32
}

impl TokenModel for SyntheticModel {
    fn vocab(&self) -> usize {
        self.vocab
    }

    fn logits(&self, history: &[i32]) -> Vec<f32> {
        let last = *history.last().expect("history must be non-empty");
        let prev = if history.len() >= 2 {
            history[history.len() - 2]
        } else {
            -1
        };
        let ctx = self.seed
            ^ (last as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ (prev as i64 as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F);
        let mut l: Vec<f32> = (0..self.vocab)
            .map(|t| unit(ctx ^ (t as u64).wrapping_mul(0xFF51_AFD7_ED55_8CCD)) * 2.0 - 1.0)
            .collect();
        // Induction head: continue the most recent earlier occurrence of
        // the current token.
        if let Some(p) = (0..history.len() - 1).rev().find(|&p| history[p] == last) {
            let tgt = history[p + 1];
            if tgt >= 0 && (tgt as usize) < self.vocab {
                l[tgt as usize] += self.sharpness;
            }
        }
        l
    }
}

/// Smaller-model drafter: greedy rollout of an inner [`TokenModel`].
/// Greedy drafting touches no RNG, so the drafter never perturbs the
/// target pipeline's draw stream.
#[derive(Clone, Debug)]
pub struct ModelDrafter<M: TokenModel> {
    pub model: M,
}

impl ModelDrafter<SyntheticModel> {
    /// A drafter over the synthetic stand-in for `cfg` — the
    /// "smaller model" knob expressed through [`ModelConfig`].
    pub fn from_config(cfg: &ModelConfig, seed: u64) -> ModelDrafter<SyntheticModel> {
        ModelDrafter { model: SyntheticModel::from_config(cfg, seed) }
    }
}

impl<M: TokenModel> DraftSource for ModelDrafter<M> {
    fn name(&self) -> &'static str {
        "model"
    }

    fn draft(&mut self, history: &[i32], k: usize) -> Vec<i32> {
        let greedy = SamplingParams::greedy();
        let mut rng = Rng::new(0); // untouched by greedy sampling
        let mut ctx = history.to_vec();
        let mut out = Vec::with_capacity(k);
        for _ in 0..k {
            if ctx.is_empty() {
                break;
            }
            let l = self.model.logits(&ctx);
            let s = sample_token(&l, &ctx, &greedy, &mut rng);
            out.push(s.token);
            ctx.push(s.token);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ngram_drafts_the_period_of_a_repetitive_history() {
        let mut d = NGramDrafter::default();
        // Period-4 history: 0 1 2 3 0 1 2 3 0 1
        let h: Vec<i32> = (0..10).map(|i| i % 4).collect();
        let draft = d.draft(&h, 6);
        assert_eq!(draft, vec![2, 3, 0, 1, 2, 3], "continues the period");
    }

    #[test]
    fn ngram_self_extends_past_the_end_of_history() {
        let mut d = NGramDrafter::default();
        let h = vec![5, 6, 5, 6];
        // Matching "5 6" at p=0 continues 5,6,5,6,... by self-reading.
        let draft = d.draft(&h, 5);
        assert_eq!(draft, vec![5, 6, 5, 6, 5]);
    }

    #[test]
    fn ngram_falls_back_to_repeating_the_last_token() {
        let mut d = NGramDrafter::default();
        let draft = d.draft(&[1, 2, 3, 4], 3);
        assert_eq!(draft, vec![4, 4, 4], "no repeat anywhere: repeat last");
        assert!(d.draft(&[], 3).is_empty());
        assert!(d.draft(&[1, 2], 0).is_empty());
    }

    #[test]
    fn ngram_prefers_the_longest_match() {
        let mut d = NGramDrafter::default();
        // "..1 2" occurred twice with different continuations; the 2-gram
        // match (7 after [1,2] at p=3) must win over any 1-gram match.
        let h = vec![1, 2, 9, 1, 2, 7, 3, 1, 2];
        let draft = d.draft(&h, 1);
        assert_eq!(draft, vec![7], "most recent longest match continues");
    }

    #[test]
    fn synthetic_model_is_deterministic_and_induction_biased() {
        let m = SyntheticModel::new(32, 7, 6.0);
        let h = vec![1, 2, 3, 1];
        let a = m.logits(&h);
        let b = m.logits(&h);
        assert_eq!(a, b, "deterministic");
        assert_eq!(a.len(), 32);
        // Last token 1 occurred earlier at p=0 followed by 2: token 2
        // carries the induction bonus and dominates the [-1,1] noise.
        let argmax = a
            .iter()
            .enumerate()
            .max_by(|x, y| x.1.total_cmp(y.1))
            .unwrap()
            .0;
        assert_eq!(argmax, 2);
    }

    #[test]
    fn model_drafter_continues_the_induction_pattern() {
        let cfg = ModelConfig::bench_d64(2);
        let mut d = ModelDrafter::from_config(&cfg, 3);
        assert_eq!(d.name(), "model");
        let h: Vec<i32> = (0..12).map(|i| i % 3).collect(); // 0 1 2 0 1 2 ...
        let draft = d.draft(&h, 4);
        assert_eq!(draft, vec![0, 1, 2, 0], "induction locks onto the period");
    }

    #[test]
    fn draft_kind_parses_and_builds() {
        assert_eq!(DraftKind::parse("ngram"), Some(DraftKind::NGram));
        assert_eq!(DraftKind::parse("model"), Some(DraftKind::Model));
        assert_eq!(DraftKind::parse("x"), None);
        let mut d = DraftKind::NGram.build(16, 0);
        assert_eq!(d.name(), "ngram");
        assert_eq!(d.draft(&[1, 1, 1], 2), vec![1, 1]);
        let mut m = DraftKind::Model.build(16, 0);
        assert_eq!(m.name(), "model");
        assert_eq!(m.draft(&[2, 3, 2], 1).len(), 1);
        assert_eq!(format!("{} {}", DraftKind::NGram, DraftKind::Model), "ngram model");
    }
}
