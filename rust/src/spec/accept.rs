//! Exact acceptance: committing draft tokens without changing the
//! target distribution — or the target *stream*.
//!
//! The PR 3 logits pipeline ([`crate::sampling::sample_token`]) is a
//! deterministic function of `(logits, history, params, rng state)`, so
//! classical acceptance-rejection sampling (accept a draft with
//! probability `min(1, p/q)`, resample the residual on reject) collapses
//! to something strictly stronger: at every draft position we *replay*
//! the sequential sampler against the target's per-position logits and
//! accept the draft iff it equals the token the sequential pipeline
//! would have drawn. The committed stream is therefore **bit-identical**
//! to sequential decoding — same tokens, same logprobs, same RNG
//! trajectory — for any sampling parameters, not merely equal in
//! distribution (property-tested in `rust/tests/spec_props.rs`).
//!
//! The RNG advances exactly once per *committed* token (and not at all
//! for greedy params), never per drafted token: a rejected draft
//! consumes no draws, so the draw stream stays aligned with the
//! sequential pipeline position-for-position.

use crate::sampling::{sample_token, SampledToken, SamplingParams};
use crate::util::rng::Rng;

use super::tree::DraftTree;

/// Outcome of verifying one draft chain.
#[derive(Clone, Debug)]
pub struct ChainVerdict {
    /// Tokens committed by this pass, in order — exactly the sequential
    /// sampler's continuation. Length is `accepted + 1`: the accepted
    /// draft prefix plus one correction/bonus token.
    pub committed: Vec<SampledToken>,
    /// Draft tokens accepted (length of the matching prefix).
    pub accepted: usize,
}

/// Verify a draft chain against per-position target logits.
///
/// `logits[i]` is the target distribution after
/// `history ++ draft[..i]` — row 0 scores the position the draft begins
/// at, row `draft.len()` is the bonus row used when every draft token is
/// accepted; all rows come from **one** multi-query attention pass over
/// the cached context. Commits between 1 and `draft.len() + 1` tokens.
pub fn verify_chain(
    logits: &[&[f32]],
    draft: &[i32],
    history: &[i32],
    params: &SamplingParams,
    rng: &mut Rng,
) -> ChainVerdict {
    assert_eq!(
        logits.len(),
        draft.len() + 1,
        "need one logit row per draft position plus the bonus row"
    );
    let mut ext = history.to_vec();
    let mut committed = Vec::with_capacity(logits.len());
    for (i, row) in logits.iter().enumerate() {
        let s = sample_token(row, &ext, params, rng);
        committed.push(s);
        if i < draft.len() && draft[i] == s.token {
            ext.push(s.token);
        } else {
            break;
        }
    }
    let accepted = committed.len() - 1;
    ChainVerdict { committed, accepted }
}

/// Outcome of verifying a draft tree.
#[derive(Clone, Debug)]
pub struct TreeVerdict {
    /// Tokens committed by this pass — the sequential stream, as in
    /// [`ChainVerdict`].
    pub committed: Vec<SampledToken>,
    /// Accepted tree nodes, root-to-leaf along the accepted path.
    pub path: Vec<u64>,
}

impl TreeVerdict {
    /// Draft tokens accepted (depth of the accepted path).
    pub fn accepted(&self) -> usize {
        self.path.len()
    }
}

/// Verify a [`DraftTree`] of candidate continuations: walk the oracle
/// stream from the root, descending into whichever child proposed the
/// token the sequential sampler actually draws; stop at the first
/// position no candidate predicted. `logits_of(node)` must return the
/// target logits after `history ++ path(node)` — one multi-query pass
/// scores every tree node at once.
pub fn verify_tree(
    tree: &DraftTree,
    mut logits_of: impl FnMut(u64) -> Vec<f32>,
    history: &[i32],
    params: &SamplingParams,
    rng: &mut Rng,
) -> TreeVerdict {
    let mut ext = history.to_vec();
    let mut cur = DraftTree::ROOT;
    let mut committed = Vec::new();
    let mut path = Vec::new();
    loop {
        let row = logits_of(cur);
        let s = sample_token(&row, &ext, params, rng);
        committed.push(s);
        match tree.child_with_token(cur, s.token) {
            Some(c) => {
                cur = c;
                path.push(c);
                ext.push(s.token);
            }
            None => break,
        }
    }
    TreeVerdict { committed, path }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Peaked logits: `win` gets logit 10, everything else 0.
    fn peaked(vocab: usize, win: i32) -> Vec<f32> {
        let mut l = vec![0.0; vocab];
        l[win as usize] = 10.0;
        l
    }

    #[test]
    fn full_acceptance_commits_k_plus_one_tokens() {
        let rows = [peaked(8, 3), peaked(8, 5), peaked(8, 1)];
        let refs: Vec<&[f32]> = rows.iter().map(Vec::as_slice).collect();
        let mut rng = Rng::new(0);
        let v = verify_chain(&refs, &[3, 5], &[7], &SamplingParams::greedy(), &mut rng);
        assert_eq!(v.accepted, 2);
        let toks: Vec<i32> = v.committed.iter().map(|s| s.token).collect();
        assert_eq!(toks, vec![3, 5, 1], "both drafts plus the bonus token");
    }

    #[test]
    fn first_mismatch_commits_the_oracle_token_and_stops() {
        let rows = [peaked(8, 3), peaked(8, 5), peaked(8, 1)];
        let refs: Vec<&[f32]> = rows.iter().map(Vec::as_slice).collect();
        let mut rng = Rng::new(0);
        // Draft proposes 4 where the oracle draws 3: reject at position 0.
        let v = verify_chain(&refs, &[4, 5], &[7], &SamplingParams::greedy(), &mut rng);
        assert_eq!(v.accepted, 0);
        assert_eq!(v.committed.len(), 1);
        assert_eq!(v.committed[0].token, 3, "the oracle token is committed");
    }

    #[test]
    fn empty_draft_is_a_plain_sequential_step() {
        let rows = [peaked(8, 2)];
        let refs: Vec<&[f32]> = rows.iter().map(Vec::as_slice).collect();
        let mut rng = Rng::new(9);
        let v = verify_chain(&refs, &[], &[1], &SamplingParams::greedy(), &mut rng);
        assert_eq!(v.accepted, 0);
        assert_eq!(v.committed[0].token, 2);
    }

    #[test]
    fn rng_advances_once_per_committed_token_only() {
        let params = SamplingParams::stochastic(1.0);
        let rows = [peaked(8, 3), peaked(8, 5), peaked(8, 1)];
        let refs: Vec<&[f32]> = rows.iter().map(Vec::as_slice).collect();
        let mut rng = Rng::new(42);
        let v = verify_chain(&refs, &[3, 5], &[7], &params, &mut rng);
        // Peaked logits make the stochastic draw all but deterministic.
        let m = v.committed.len();
        let mut expect = Rng::new(42);
        for _ in 0..m {
            let _ = expect.f64();
        }
        assert_eq!(rng.next_u64(), expect.next_u64(), "one draw per commit");
    }

    #[test]
    fn tree_verification_follows_the_oracle_path() {
        let mut tree = DraftTree::default();
        tree.add_chain(&[3, 5]); // the oracle's actual continuation
        tree.add_chain(&[3, 6]); // a sibling branch
        tree.add_chain(&[4]); // a wrong first guess
        let vocab = 8;
        // The oracle draws 3, then 5, then 1; every other context peaks
        // at 0, so descending any wrong branch would be visible.
        let mut rng = Rng::new(0);
        let v = verify_tree(
            &tree,
            |node| match tree.path_tokens(node).as_slice() {
                [] => peaked(vocab, 3),
                [3] => peaked(vocab, 5),
                [3, 5] => peaked(vocab, 1),
                _ => peaked(vocab, 0),
            },
            &[7],
            &SamplingParams::greedy(),
            &mut rng,
        );
        assert_eq!(v.accepted(), 2, "descended 3 -> 5");
        let toks: Vec<i32> = v.committed.iter().map(|s| s.token).collect();
        assert_eq!(toks, vec![3, 5, 1]);
    }
}
