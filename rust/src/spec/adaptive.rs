//! Acceptance-aware draft-length control.
//!
//! A fixed `spec_k` wastes verify rows whenever the drafter is cold: a
//! pass that drafts 8 and accepts 0 still scores (and rolls back) all 8
//! rows. [`AdaptiveK`] tracks each sequence's running acceptance rate as
//! an EWMA and sizes the next draft proportionally — a stream whose
//! drafts keep missing converges to `k = 1` (one draft row per pass, the
//! cheapest probe that can still win), and recovers toward `k_max` as
//! soon as acceptances return. The committed stream is unaffected by
//! construction: acceptance verification is exact for *any* draft length
//! (`rust/tests/spec_props.rs`), so adapting `k` only moves the pass
//! count and the rolled-back-row count, never the tokens.

/// Per-sequence draft-length controller driven by the running acceptance
/// rate (the serving-side consumer of `Metrics::spec`-style accounting).
#[derive(Clone, Debug)]
pub struct AdaptiveK {
    k_max: usize,
    /// EWMA of per-pass acceptance rates, optimistic start (1.0) so the
    /// first passes probe at full depth.
    ewma: f64,
    /// Smoothing gain of each new observation.
    gain: f64,
}

impl AdaptiveK {
    /// A controller bounded by the configured `spec_k`.
    pub fn new(k_max: usize) -> AdaptiveK {
        AdaptiveK { k_max, ewma: 1.0, gain: 0.4 }
    }

    /// Draft length for the next pass: the acceptance estimate scaled
    /// into `1..=k_max` (0 only when speculation is off entirely).
    pub fn k(&self) -> usize {
        if self.k_max == 0 {
            return 0;
        }
        ((self.ewma * self.k_max as f64).round() as usize).clamp(1, self.k_max)
    }

    /// Current acceptance estimate in `[0, 1]`.
    pub fn acceptance_estimate(&self) -> f64 {
        self.ewma
    }

    /// Fold one verify pass's outcome into the estimate. Passes that
    /// drafted nothing (budget-capped) carry no signal and are skipped.
    pub fn observe(&mut self, drafted: usize, accepted: usize) {
        if drafted == 0 {
            return;
        }
        let rate = (accepted.min(drafted)) as f64 / drafted as f64;
        self.ewma = (1.0 - self.gain) * self.ewma + self.gain * rate;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_at_full_depth() {
        let c = AdaptiveK::new(8);
        assert_eq!(c.k(), 8);
        assert_eq!(AdaptiveK::new(0).k(), 0, "speculation off stays off");
    }

    #[test]
    fn low_acceptance_stream_converges_to_k_one() {
        let mut c = AdaptiveK::new(8);
        let mut sizes = Vec::new();
        for _ in 0..12 {
            let k = c.k();
            sizes.push(k);
            c.observe(k, 0); // every draft rejected
        }
        assert_eq!(*sizes.last().unwrap(), 1, "converges to the minimum");
        assert!(
            sizes.windows(2).all(|w| w[1] <= w[0]),
            "k shrinks monotonically under all-reject: {sizes:?}"
        );
        assert!(c.acceptance_estimate() < 0.01);
    }

    #[test]
    fn recovers_when_acceptance_returns() {
        let mut c = AdaptiveK::new(6);
        for _ in 0..10 {
            c.observe(c.k(), 0);
        }
        assert_eq!(c.k(), 1);
        for _ in 0..10 {
            c.observe(c.k(), c.k()); // everything accepted again
        }
        assert_eq!(c.k(), 6, "estimate climbs back to full depth");
    }

    #[test]
    fn empty_passes_carry_no_signal() {
        let mut c = AdaptiveK::new(4);
        let before = c.acceptance_estimate();
        c.observe(0, 0);
        assert_eq!(c.acceptance_estimate(), before);
    }
}
