//! Host draft-and-verify pipeline and its sequential oracle.
//!
//! [`spec_generate`] runs the full speculative loop over any
//! [`TokenModel`]: draft a chain (or a tree via
//! [`spec_generate_tree`]), compute the target's per-position logits —
//! the host stand-in for the engine's single multi-query lean pass —
//! verify with [`verify_chain`] / [`verify_tree`], and commit 1..=k+1
//! tokens per pass. [`sequential_generate`] is the oracle it must equal
//! **bit-for-bit** for every `(seed, params, k)`; `rust/tests/
//! spec_props.rs` pins that equivalence, and the acceptance *rate* only
//! moves the pass count, never the stream.

use crate::obs::{Attrs, Phase, Tracer};
use crate::sampling::{sample_token, SampledToken, SamplingParams};
use crate::util::rng::Rng;

use super::accept::{verify_chain, verify_tree};
use super::draft::{DraftSource, TokenModel};
use super::tree::DraftTree;

/// Counters of one speculative decode run (also embedded in the engine
/// metrics for the serving-side pipeline).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpecStats {
    /// Multi-query verify passes executed (one per engine step and
    /// sequence).
    pub verify_passes: usize,
    /// Draft tokens proposed.
    pub drafted: usize,
    /// Draft tokens accepted (committed as-is).
    pub accepted: usize,
    /// Tokens committed in total (accepted drafts + one correction or
    /// bonus token per pass).
    pub committed: usize,
    /// Speculative KV rows rolled back by `truncate_seq` (engine path
    /// only; the host pipeline stores no KV).
    pub rolled_back: usize,
}

impl SpecStats {
    /// Mean tokens committed per verify pass (>= 1 once any pass ran;
    /// > 1 is the speculative win over one-token-per-step decode).
    pub fn tokens_per_pass(&self) -> f64 {
        if self.verify_passes == 0 {
            0.0
        } else {
            self.committed as f64 / self.verify_passes as f64
        }
    }

    /// Fraction of drafted tokens that were accepted.
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }

    /// Fold another run's counters in (metrics merge across engines).
    pub fn merge(&mut self, o: &SpecStats) {
        self.verify_passes += o.verify_passes;
        self.drafted += o.drafted;
        self.accepted += o.accepted;
        self.committed += o.committed;
        self.rolled_back += o.rolled_back;
    }
}

/// The sequential oracle: one token per model call through the exact
/// sampling pipeline. This is what the engine's non-speculative decode
/// loop computes, restated over a host [`TokenModel`].
pub fn sequential_generate<M: TokenModel + ?Sized>(
    model: &M,
    prompt: &[i32],
    max_new: usize,
    params: &SamplingParams,
    rng: &mut Rng,
) -> Vec<SampledToken> {
    assert!(!prompt.is_empty(), "empty prompt");
    let mut hist = prompt.to_vec();
    let mut out = Vec::with_capacity(max_new);
    for _ in 0..max_new {
        let l = model.logits(&hist);
        let s = sample_token(&l, &hist, params, rng);
        hist.push(s.token);
        out.push(s);
    }
    out
}

/// A finished speculative run.
#[derive(Clone, Debug)]
pub struct SpecRun {
    /// The committed stream — identical to [`sequential_generate`] under
    /// the same `(prompt, params, rng seed)`.
    pub tokens: Vec<SampledToken>,
    pub stats: SpecStats,
}

/// Target logits for the draft-block positions: row `i` scores the
/// position after `history ++ draft[..i]`. On the engine these rows come
/// out of one multi-query lean attention pass; on the host the model is
/// queried per extended context (same numbers, no batching to exploit).
fn target_rows<M: TokenModel + ?Sized>(
    model: &M,
    history: &[i32],
    draft: &[i32],
) -> Vec<Vec<f32>> {
    let mut rows = Vec::with_capacity(draft.len() + 1);
    let mut ctx = history.to_vec();
    rows.push(model.logits(&ctx));
    for &d in draft {
        ctx.push(d);
        rows.push(model.logits(&ctx));
    }
    rows
}

/// The chain draft-and-verify loop behind both the fixed-`k` and the
/// acceptance-adaptive entry points: one verify pass per iteration, the
/// draft never longer than the remaining budget (a pass commits at most
/// `k + 1`), and the controller — when present — sizes each pass and
/// folds its acceptance back in.
#[allow(clippy::too_many_arguments)]
fn spec_generate_chain<M: TokenModel + ?Sized, D: DraftSource + ?Sized>(
    model: &M,
    drafter: &mut D,
    k_max: usize,
    mut ctrl: Option<&mut super::AdaptiveK>,
    prompt: &[i32],
    max_new: usize,
    params: &SamplingParams,
    rng: &mut Rng,
    tracer: &Tracer,
) -> SpecRun {
    assert!(!prompt.is_empty(), "empty prompt");
    let mut hist = prompt.to_vec();
    let mut tokens = Vec::with_capacity(max_new);
    let mut stats = SpecStats::default();
    while tokens.len() < max_new {
        tracer.advance_step();
        let remaining = max_new - tokens.len();
        let k_pass = ctrl.as_deref().map_or(k_max, |c| c.k().min(k_max));
        let k_step = k_pass.min(remaining.saturating_sub(1));
        let draft_start = tracer.now();
        let mut draft = if k_step > 0 {
            drafter.draft(&hist, k_step)
        } else {
            Vec::new()
        };
        draft.truncate(k_step);
        let draft_attrs = Attrs { k: Some(draft.len()), ..Default::default() };
        tracer.record_since(Phase::SpecDraft, draft_start, draft_attrs);
        let verify_start = tracer.now();
        let rows = target_rows(model, &hist, &draft);
        let row_refs: Vec<&[f32]> = rows.iter().map(Vec::as_slice).collect();
        let verdict = verify_chain(&row_refs, &draft, &hist, params, rng);
        let verify_attrs = Attrs { k: Some(verdict.accepted), ..Default::default() };
        tracer.record_since(Phase::SpecVerify, verify_start, verify_attrs);
        if let Some(c) = ctrl.as_deref_mut() {
            c.observe(draft.len(), verdict.accepted);
        }
        stats.verify_passes += 1;
        stats.drafted += draft.len();
        stats.accepted += verdict.accepted;
        stats.committed += verdict.committed.len();
        let commit_attrs = Attrs { k: Some(verdict.committed.len()), ..Default::default() };
        tracer.instant(Phase::SpecCommit, commit_attrs);
        for s in &verdict.committed {
            hist.push(s.token);
            tokens.push(*s);
        }
    }
    SpecRun { tokens, stats }
}

/// Speculative decoding with a single draft chain per pass.
pub fn spec_generate<M: TokenModel + ?Sized, D: DraftSource + ?Sized>(
    model: &M,
    drafter: &mut D,
    k: usize,
    prompt: &[i32],
    max_new: usize,
    params: &SamplingParams,
    rng: &mut Rng,
) -> SpecRun {
    let tracer = Tracer::disabled();
    spec_generate_chain(model, drafter, k, None, prompt, max_new, params, rng, &tracer)
}

/// [`spec_generate`] with every pass traced: `spec_draft` /
/// `spec_verify` spans (the verify span is the multi-query lean pass
/// stand-in) and a `spec_commit` instant carrying the commit count.
/// The committed stream is unchanged — tracing never touches the rng.
#[allow(clippy::too_many_arguments)]
pub fn spec_generate_traced<M: TokenModel + ?Sized, D: DraftSource + ?Sized>(
    model: &M,
    drafter: &mut D,
    k: usize,
    prompt: &[i32],
    max_new: usize,
    params: &SamplingParams,
    rng: &mut Rng,
    tracer: &Tracer,
) -> SpecRun {
    spec_generate_chain(model, drafter, k, None, prompt, max_new, params, rng, tracer)
}

/// Speculative decoding with an [`AdaptiveK`](super::AdaptiveK)
/// controller sizing every pass's draft from the running acceptance rate
/// instead of a fixed `k`. The committed stream is still bit-identical to
/// [`sequential_generate`] — adaptation only moves the pass count.
/// Returns the run plus the controller's final draft length (a
/// low-acceptance stream converges to 1).
pub fn spec_generate_adaptive<M: TokenModel + ?Sized, D: DraftSource + ?Sized>(
    model: &M,
    drafter: &mut D,
    k_max: usize,
    prompt: &[i32],
    max_new: usize,
    params: &SamplingParams,
    rng: &mut Rng,
) -> (SpecRun, usize) {
    let mut ctrl = super::AdaptiveK::new(k_max);
    let tracer = Tracer::disabled();
    let run = spec_generate_chain(
        model,
        drafter,
        k_max,
        Some(&mut ctrl),
        prompt,
        max_new,
        params,
        rng,
        &tracer,
    );
    let k_final = ctrl.k();
    (run, k_final)
}

/// Speculative decoding over a [`DraftTree`] merged from several
/// drafters: agreeing prefixes are scored once, and the verify pass
/// follows whichever branch matches the oracle stream.
pub fn spec_generate_tree<M: TokenModel + ?Sized>(
    model: &M,
    drafters: &mut [Box<dyn DraftSource>],
    k: usize,
    prompt: &[i32],
    max_new: usize,
    params: &SamplingParams,
    rng: &mut Rng,
) -> SpecRun {
    assert!(!prompt.is_empty(), "empty prompt");
    let mut hist = prompt.to_vec();
    let mut tokens = Vec::with_capacity(max_new);
    let mut stats = SpecStats::default();
    while tokens.len() < max_new {
        let remaining = max_new - tokens.len();
        let k_step = k.min(remaining.saturating_sub(1));
        let mut tree = DraftTree::new();
        if k_step > 0 {
            for d in drafters.iter_mut() {
                let mut chain = d.draft(&hist, k_step);
                chain.truncate(k_step);
                tree.add_chain(&chain);
            }
        }
        stats.drafted += tree.len();
        let verdict = verify_tree(
            &tree,
            |node| {
                let mut ctx = hist.clone();
                ctx.extend(tree.path_tokens(node));
                model.logits(&ctx)
            },
            &hist,
            params,
            rng,
        );
        stats.verify_passes += 1;
        stats.accepted += verdict.accepted();
        // The accepted path is bounded by k_step, so this never commits
        // past the budget.
        stats.committed += verdict.committed.len();
        for s in &verdict.committed {
            hist.push(s.token);
            tokens.push(*s);
        }
    }
    SpecRun { tokens, stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::seq_rng;
    use crate::spec::draft::{DraftKind, NGramDrafter, SyntheticModel};

    fn periodic_prompt(len: usize, period: usize) -> Vec<i32> {
        (0..len).map(|i| (i % period) as i32).collect()
    }

    #[test]
    fn greedy_spec_stream_equals_sequential_and_wins_passes() {
        let model = SyntheticModel::new(32, 5, 6.0);
        let prompt = periodic_prompt(24, 6);
        let params = SamplingParams::greedy();
        let mut r1 = seq_rng(1, 2);
        let seq = sequential_generate(&model, &prompt, 40, &params, &mut r1);
        let mut r2 = seq_rng(1, 2);
        let mut drafter = NGramDrafter::default();
        let run = spec_generate(&model, &mut drafter, 4, &prompt, 40, &params, &mut r2);
        assert_eq!(run.tokens, seq, "bit-identical stream");
        assert_eq!(run.stats.committed, 40);
        assert!(
            run.stats.verify_passes < 40,
            "repetitive workload must commit >1 token/pass ({} passes)",
            run.stats.verify_passes
        );
        assert!(run.stats.tokens_per_pass() > 1.0);
        assert!(run.stats.acceptance_rate() > 0.5);
    }

    #[test]
    fn budget_is_never_overshot() {
        let model = SyntheticModel::new(16, 9, 6.0);
        let prompt = periodic_prompt(12, 3);
        let params = SamplingParams::greedy();
        for max_new in [1usize, 2, 3, 5, 7] {
            let mut rng = seq_rng(3, 4);
            let mut drafter = NGramDrafter::default();
            let run =
                spec_generate(&model, &mut drafter, 4, &prompt, max_new, &params, &mut rng);
            assert_eq!(run.tokens.len(), max_new);
            let mut oracle_rng = seq_rng(3, 4);
            let seq = sequential_generate(&model, &prompt, max_new, &params, &mut oracle_rng);
            assert_eq!(run.tokens, seq);
        }
    }

    #[test]
    fn tree_spec_stream_equals_sequential() {
        let model = SyntheticModel::new(24, 11, 6.0);
        let prompt = periodic_prompt(20, 5);
        let params = SamplingParams::stochastic(0.7);
        let mut r1 = seq_rng(8, 1);
        let seq = sequential_generate(&model, &prompt, 30, &params, &mut r1);
        let mut drafters: Vec<Box<dyn DraftSource>> =
            vec![DraftKind::NGram.build(24, 0), DraftKind::Model.build(24, 11)];
        let mut r2 = seq_rng(8, 1);
        let run =
            spec_generate_tree(&model, &mut drafters, 4, &prompt, 30, &params, &mut r2);
        assert_eq!(run.tokens, seq, "tree verification preserves the stream");
        assert_eq!(run.stats.committed, 30);
    }

    #[test]
    fn adaptive_low_acceptance_stream_converges_to_small_k() {
        // A drafter that always proposes a token the sharp synthetic
        // model never samples: acceptance stays ~0, so the controller
        // must shrink the draft length to 1 while the stream remains
        // bit-identical to the sequential oracle.
        struct OffByOneDrafter;
        impl crate::spec::DraftSource for OffByOneDrafter {
            fn name(&self) -> &'static str {
                "off-by-one"
            }
            fn draft(&mut self, history: &[i32], k: usize) -> Vec<i32> {
                let wrong = (history.last().copied().unwrap_or(0) + 7) % 16;
                vec![wrong; k]
            }
        }
        let model = SyntheticModel::new(16, 3, 8.0);
        let prompt = periodic_prompt(12, 4);
        let params = SamplingParams::greedy();
        let mut r1 = seq_rng(5, 6);
        let seq = sequential_generate(&model, &prompt, 30, &params, &mut r1);
        let mut r2 = seq_rng(5, 6);
        let (run, final_k) = spec_generate_adaptive(
            &model,
            &mut OffByOneDrafter,
            8,
            &prompt,
            30,
            &params,
            &mut r2,
        );
        assert_eq!(run.tokens, seq, "adaptation never touches the stream");
        assert_eq!(final_k, 1, "all-reject stream converges to k = 1");
        assert!(
            run.stats.drafted < 8 * run.stats.verify_passes,
            "shrunken drafts: {} drafted over {} passes",
            run.stats.drafted,
            run.stats.verify_passes
        );
    }

    #[test]
    fn adaptive_keeps_full_depth_on_an_accepting_stream() {
        let model = SyntheticModel::new(32, 5, 6.0);
        let prompt = periodic_prompt(24, 6);
        let params = SamplingParams::greedy();
        let mut r1 = seq_rng(1, 2);
        let seq = sequential_generate(&model, &prompt, 40, &params, &mut r1);
        let mut r2 = seq_rng(1, 2);
        let mut drafter = NGramDrafter::default();
        let (run, final_k) =
            spec_generate_adaptive(&model, &mut drafter, 4, &prompt, 40, &params, &mut r2);
        assert_eq!(run.tokens, seq);
        assert!(final_k >= 2, "accepting stream keeps a deep draft");
        assert!(run.stats.tokens_per_pass() > 1.0);
    }

    #[test]
    fn traced_run_emits_spans_without_touching_the_stream() {
        let model = SyntheticModel::new(32, 5, 6.0);
        let prompt = periodic_prompt(24, 6);
        let params = SamplingParams::greedy();
        let mut r1 = seq_rng(1, 2);
        let mut d1 = NGramDrafter::default();
        let plain = spec_generate(&model, &mut d1, 4, &prompt, 40, &params, &mut r1);
        let tracer = Tracer::enabled(4096);
        let mut r2 = seq_rng(1, 2);
        let mut d2 = NGramDrafter::default();
        let traced =
            spec_generate_traced(&model, &mut d2, 4, &prompt, 40, &params, &mut r2, &tracer);
        assert_eq!(traced.tokens, plain.tokens, "tracing never moves the stream");
        let evs = tracer.events();
        let verifies = evs.iter().filter(|e| e.phase == Phase::SpecVerify).count();
        assert_eq!(verifies, traced.stats.verify_passes);
        let commits: usize = evs
            .iter()
            .filter(|e| e.phase == Phase::SpecCommit)
            .map(|e| e.attrs.k.unwrap())
            .sum();
        assert_eq!(commits, traced.stats.committed);
        assert!(tracer.phase_hist(Phase::SpecDraft).is_some());
    }

    #[test]
    fn stats_merge_adds_counters() {
        let mut a = SpecStats { verify_passes: 2, drafted: 6, ..Default::default() };
        let b = SpecStats { verify_passes: 1, drafted: 3, accepted: 2, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.verify_passes, 3);
        assert_eq!(a.drafted, 9);
        assert_eq!(a.accepted, 2);
    }

    #[test]
    fn stats_ratios() {
        let s = SpecStats {
            verify_passes: 4,
            drafted: 12,
            accepted: 9,
            committed: 13,
            rolled_back: 3,
        };
        assert!((s.tokens_per_pass() - 3.25).abs() < 1e-12);
        assert!((s.acceptance_rate() - 0.75).abs() < 1e-12);
        assert_eq!(SpecStats::default().tokens_per_pass(), 0.0);
        assert_eq!(SpecStats::default().acceptance_rate(), 0.0);
    }
}
