//! Speculative decoding: draft-and-verify trees on the COW paged KV,
//! verified in one multi-token lean pass.
//!
//! Decode-phase attention is memory-bound: a 1-query step streams the
//! whole cached context from HBM to produce one token. Verifying `k`
//! drafted tokens turns `k` such steps into **one** pass with `k + 1`
//! query rows over the *same* context stream — the arithmetic-intensity
//! win the paper's stream-K machinery is built to exploit, and the
//! natural consumer of the PR 1-3 substrate (COW `fork_seq`, pending-
//! token resampling, cascade gather).
//!
//! * [`draft`] — pluggable [`DraftSource`]s: the n-gram/suffix-lookup
//!   **self-drafter** (no second model) and the **smaller-model
//!   drafter** configured from [`crate::model::ModelConfig`].
//! * [`tree`] — [`DraftTree`]: several candidate continuations sharing
//!   scored prefixes, with lineage on the PR 3 `ForkTree`.
//! * [`accept`] — exact acceptance: the deterministic sampling pipeline
//!   makes acceptance-rejection collapse to replaying the sequential
//!   sampler, so the committed stream is **bit-identical** to
//!   non-speculative decoding for any `(seed, params)` — not merely
//!   equal in distribution.
//! * [`decode`] — the host draft-and-verify loop plus its sequential
//!   oracle and [`SpecStats`] accounting.
//!
//! The serving half lives in the coordinator/runtime/partition layers:
//! `partition::multi_query` poses the draft block as staggered-causal
//! cascade lanes, `runtime::attention_exec::lean_multi_query` executes
//! it, the model artifacts grow a multi-token `verify` step surfacing
//! per-position logits, and `Engine` commits 1..=k+1 tokens per step,
//! rolling rejected draft KV back with the COW-aware
//! `PagedKvCache::truncate_seq`.

pub mod accept;
pub mod adaptive;
pub mod decode;
pub mod draft;
pub mod tree;

pub use accept::{verify_chain, verify_tree, ChainVerdict, TreeVerdict};
pub use adaptive::AdaptiveK;
pub use decode::{
    sequential_generate, spec_generate, spec_generate_adaptive, spec_generate_traced,
    spec_generate_tree, SpecRun, SpecStats,
};
pub use draft::{
    DraftKind, DraftSource, ModelDrafter, NGramDrafter, SyntheticModel, TokenModel,
};
pub use tree::DraftTree;
