//! Plan types shared by all partitioners, plus the dense (FlashAttention-2)
//! and fixed-split (FlashDecoding / FlashInfer) planners and the
//! FlashDecoding split-factor heuristic.

use super::lean_tile::{lean_tile_for, tiles_for_ctx};

/// A decode-phase attention problem: one KV walk per `(batch, kv_head)`
/// group (the decode query is a single token), context lengths per batch
/// element (ragged batches supported — §IV-C "Lean Ragged Batching").
///
/// Under grouped-query attention (`kv_heads < heads`) each group's KV
/// stream serves `heads / kv_heads` query rows at once, so the plan's
/// tile space — and the KV bytes it prices — shrinks by the group size
/// while the output rows stay at `batch × heads`. With
/// `kv_heads == heads` (the default every constructor sets) the layout
/// is exactly the pre-GQA one.
#[derive(Clone, Debug, PartialEq)]
pub struct DecodeProblem {
    /// Query heads (output rows per batch element).
    pub heads: usize,
    /// KV heads; divides `heads`. Equal to `heads` unless set through
    /// [`DecodeProblem::with_kv_heads`].
    pub kv_heads: usize,
    pub head_dim: usize,
    /// Context length per batch element.
    pub ctx_lens: Vec<u32>,
    /// LeanTile size in tokens (defaults to the §IV-B table).
    pub tile: usize,
}

impl DecodeProblem {
    /// Uniform batch: every sequence has the same context length.
    pub fn uniform(batch: usize, heads: usize, ctx: usize, head_dim: usize) -> Self {
        DecodeProblem {
            heads,
            kv_heads: heads,
            head_dim,
            ctx_lens: vec![ctx as u32; batch],
            tile: lean_tile_for(head_dim),
        }
    }

    /// Ragged batch with per-sequence context lengths.
    pub fn ragged(heads: usize, ctx_lens: Vec<u32>, head_dim: usize) -> Self {
        let tile = lean_tile_for(head_dim);
        DecodeProblem { heads, kv_heads: heads, head_dim, ctx_lens, tile }
    }

    pub fn with_tile(mut self, tile: usize) -> Self {
        assert!(tile > 0);
        self.tile = tile;
        self
    }

    /// Grouped-query layout: `kv_heads` KV heads shared by `heads` query
    /// heads (`kv_heads == 1` is multi-query attention).
    pub fn with_kv_heads(mut self, kv_heads: usize) -> Self {
        assert!(kv_heads >= 1, "kv_heads must be >= 1");
        assert!(
            self.heads % kv_heads == 0,
            "heads {} not divisible by kv_heads {kv_heads}",
            self.heads
        );
        self.kv_heads = kv_heads;
        self
    }

    pub fn batch(&self) -> usize {
        self.ctx_lens.len()
    }

    /// Query heads sharing one KV head's stream (1 without GQA).
    pub fn group_size(&self) -> usize {
        self.heads / self.kv_heads
    }

    /// KV walks = flattened groups (batch-major, kv heads inner) — the
    /// `batch → heads → context` linearization of §IV-C, at kv-head
    /// granularity under GQA.
    pub fn groups(&self) -> usize {
        self.batch() * self.kv_heads
    }

    /// Query/output rows: `batch × heads`. Equals [`Self::groups`] only
    /// when `kv_heads == heads`.
    pub fn outputs(&self) -> usize {
        self.batch() * self.heads
    }

    pub fn ctx_for_group(&self, group: usize) -> usize {
        self.ctx_lens[group / self.kv_heads] as usize
    }

    pub fn tiles_for_group(&self, group: usize) -> u64 {
        tiles_for_ctx(self.ctx_for_group(group), self.tile)
    }

    pub fn total_tiles(&self) -> u64 {
        (0..self.groups()).map(|g| self.tiles_for_group(g)).sum()
    }

    /// Prefix sums of tiles per group: `cum[g]` = tiles before group `g`;
    /// `cum[groups]` = total. The "cumulative sequence lengths" pointer
    /// array of Lean ragged batching, in tile units.
    pub fn cum_tiles(&self) -> Vec<u64> {
        let groups = self.groups();
        let mut cum = Vec::with_capacity(groups + 1);
        let mut acc = 0u64;
        cum.push(0);
        for g in 0..groups {
            acc += self.tiles_for_group(g);
            cum.push(acc);
        }
        cum
    }

    /// Ratio of average to maximum context length — the paper's batch
    /// heterogeneity measure (Fig 10's x-axis).
    pub fn batch_context_ratio(&self) -> f64 {
        let max = self.ctx_lens.iter().copied().max().unwrap_or(0) as f64;
        if max == 0.0 {
            return 1.0;
        }
        let avg =
            self.ctx_lens.iter().map(|&c| c as f64).sum::<f64>() / self.batch() as f64;
        avg / max
    }
}

/// Partitioning strategy selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// FlashAttention-2: one CTA per output tile, sequential context walk.
    Dense,
    /// FlashDecoding-style fixed split of every output tile into `splits`
    /// same-sized chunks.
    FixedSplit { splits: usize },
    /// FlashInfer batch-decode flavour: fixed split at page granularity
    /// (chunks are multiples of `page` tokens). Latency-wise FlashInfer's
    /// fixed-split behaves like FlashDecoding (§III-C); the page size
    /// matters for the simulator's gather-efficiency penalty.
    PagedFixedSplit { splits: usize, page: usize },
    /// LeanAttention stream-K: equalized tile split over a fixed grid.
    StreamK,
    /// Shared-prefix cascade: stream-K over a *segment problem* whose
    /// groups are shared prefix streams (one KV walk serving every member
    /// query) plus per-sequence suffixes — see [`super::cascade`]. On a
    /// plain [`DecodeProblem`] (no prefix structure) this degenerates to
    /// stream-K; real cascade plans come from
    /// [`super::cascade::build_cascade_plan`].
    Cascade,
}

impl Strategy {
    /// FlashDecoding with its split-factor heuristic resolved for a GPU
    /// with `num_sms` compute units.
    pub fn fixed_split_auto(problem: &DecodeProblem, num_sms: usize) -> Strategy {
        Strategy::FixedSplit { splits: fd_heuristic_splits(problem, num_sms, 128) }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Dense => "flashattention2",
            Strategy::FixedSplit { .. } => "flashdecoding",
            Strategy::PagedFixedSplit { .. } => "flashinfer",
            Strategy::StreamK => "leanattention",
            Strategy::Cascade => "cascade",
        }
    }
}

/// One contiguous run of LeanTile iterations a CTA performs for a single
/// output tile (Alg 2 lines 11-16).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Segment {
    /// Output tile = flattened `(batch, head)` group index.
    pub group: u32,
    /// First LeanTile index within the group's context.
    pub tile_begin: u32,
    pub tile_count: u32,
    /// Host CTA for this output tile: owns tile 0 and performs the
    /// reduction (Alg 2 line 17).
    pub is_host: bool,
    /// Covers the group's final LeanTile (Alg 2 line 18): a host that is
    /// also finishing needs no reduction at all.
    pub is_finishing: bool,
}

/// All work assigned to one CTA.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CtaWork {
    pub segments: Vec<Segment>,
}

impl CtaWork {
    pub fn tiles(&self) -> u64 {
        self.segments.iter().map(|s| s.tile_count as u64).sum()
    }
}

/// A complete partitioning of a [`DecodeProblem`].
#[derive(Clone, Debug)]
pub struct Plan {
    pub strategy: Strategy,
    pub tile: usize,
    pub ctas: Vec<CtaWork>,
    pub groups: usize,
}

impl Plan {
    pub fn grid(&self) -> usize {
        self.ctas.len()
    }

    pub fn tiles_per_cta(&self) -> Vec<u64> {
        self.ctas.iter().map(|c| c.tiles()).collect()
    }

    /// max/mean tile load — 1.0 means perfectly balanced.
    pub fn imbalance(&self) -> f64 {
        let tiles = self.tiles_per_cta();
        let max = *tiles.iter().max().unwrap_or(&0) as f64;
        let sum: u64 = tiles.iter().sum();
        if sum == 0 {
            return 1.0;
        }
        max * self.grid() as f64 / sum as f64
    }

    /// Number of partials produced for each group (1 = no reduction
    /// needed; k > 1 = k-1 global-memory stores + a k-way host reduce).
    pub fn partials_per_group(&self) -> Vec<u32> {
        let mut counts = vec![0u32; self.groups];
        for cta in &self.ctas {
            for seg in &cta.segments {
                counts[seg.group as usize] += 1;
            }
        }
        counts
    }

    /// Structural validation: every group's tiles covered exactly once by
    /// contiguous segments, exactly one host and one finishing segment per
    /// group, flags consistent. The planner invariants the property tests
    /// sweep.
    pub fn validate(&self, problem: &DecodeProblem) -> anyhow::Result<()> {
        use anyhow::{bail, ensure};
        ensure!(self.groups == problem.groups(), "group count mismatch");
        ensure!(self.tile == problem.tile, "tile mismatch");

        // Gather segments per group.
        let mut per_group: Vec<Vec<Segment>> = vec![Vec::new(); self.groups];
        for (ci, cta) in self.ctas.iter().enumerate() {
            for seg in &cta.segments {
                ensure!(
                    (seg.group as usize) < self.groups,
                    "cta {ci}: group {} out of range",
                    seg.group
                );
                ensure!(seg.tile_count > 0, "cta {ci}: empty segment");
                per_group[seg.group as usize].push(*seg);
            }
        }

        for (g, segs) in per_group.iter_mut().enumerate() {
            let need = problem.tiles_for_group(g);
            if need == 0 {
                ensure!(segs.is_empty(), "group {g}: segments for empty context");
                continue;
            }
            ensure!(!segs.is_empty(), "group {g}: no coverage");
            segs.sort_by_key(|s| s.tile_begin);
            let mut cursor = 0u64;
            let mut hosts = 0;
            let mut finishers = 0;
            for seg in segs.iter() {
                if seg.tile_begin as u64 != cursor {
                    bail!(
                        "group {g}: gap/overlap at tile {} (expected {cursor})",
                        seg.tile_begin
                    );
                }
                cursor += seg.tile_count as u64;
                ensure!(
                    seg.is_host == (seg.tile_begin == 0),
                    "group {g}: host flag wrong on tile {}",
                    seg.tile_begin
                );
                let finishes = cursor == need;
                ensure!(
                    seg.is_finishing == finishes,
                    "group {g}: finishing flag wrong on tile {}",
                    seg.tile_begin
                );
                hosts += seg.is_host as u32;
                finishers += seg.is_finishing as u32;
            }
            ensure!(cursor == need, "group {g}: covered {cursor} of {need} tiles");
            ensure!(hosts == 1, "group {g}: {hosts} hosts");
            ensure!(finishers == 1, "group {g}: {finishers} finishers");
        }
        Ok(())
    }
}

/// Build a plan for `problem` under `strategy` on a device exposing
/// `sm_slots` co-resident CTA slots (`num_sms × max CTAs per SM`).
pub fn build_plan(problem: &DecodeProblem, strategy: Strategy, sm_slots: usize) -> Plan {
    match strategy {
        Strategy::Dense => dense_plan(problem),
        Strategy::FixedSplit { splits } => fixed_split_plan(problem, splits, strategy),
        Strategy::PagedFixedSplit { splits, page } => {
            // Page granularity only coarsens the chunk boundaries; with
            // tile >= page (typical: 256 >= 16) chunk boundaries already
            // land on page boundaries, so the CTA structure matches
            // fixed-split. The simulator applies the paged-gather penalty.
            let _ = page;
            fixed_split_plan(problem, splits, strategy)
        }
        Strategy::StreamK => super::stream_k::stream_k_plan(problem, sm_slots),
        Strategy::Cascade => {
            // Prefix structure is not expressible on a bare DecodeProblem;
            // build_cascade_plan owns the real path. Keep the strategy tag
            // so simulators report the mechanism they were asked for.
            let mut plan = super::stream_k::stream_k_plan(problem, sm_slots);
            plan.strategy = Strategy::Cascade;
            plan
        }
    }
}

/// FlashAttention-2: one CTA per output tile.
pub fn dense_plan(problem: &DecodeProblem) -> Plan {
    let mut ctas = Vec::with_capacity(problem.groups());
    for g in 0..problem.groups() {
        let tiles = problem.tiles_for_group(g);
        if tiles == 0 {
            continue;
        }
        ctas.push(CtaWork {
            segments: vec![Segment {
                group: g as u32,
                tile_begin: 0,
                tile_count: tiles as u32,
                is_host: true,
                is_finishing: true,
            }],
        });
    }
    Plan {
        strategy: Strategy::Dense,
        tile: problem.tile,
        ctas,
        groups: problem.groups(),
    }
}

/// FlashDecoding: split every group's tile range into `splits` same-sized
/// chunks (ceil-division; trailing chunks may be smaller, and groups with
/// fewer tiles than `splits` get one chunk per tile).
pub fn fixed_split_plan(problem: &DecodeProblem, splits: usize, strategy: Strategy) -> Plan {
    assert!(splits > 0, "splits must be >= 1");
    let mut ctas = Vec::new();
    for g in 0..problem.groups() {
        let tiles = problem.tiles_for_group(g);
        if tiles == 0 {
            continue;
        }
        let s = (splits as u64).min(tiles);
        let chunk = tiles.div_ceil(s);
        let mut begin = 0u64;
        while begin < tiles {
            let count = chunk.min(tiles - begin);
            ctas.push(CtaWork {
                segments: vec![Segment {
                    group: g as u32,
                    tile_begin: begin as u32,
                    tile_count: count as u32,
                    is_host: begin == 0,
                    is_finishing: begin + count == tiles,
                }],
            });
            begin += count;
        }
    }
    Plan { strategy, tile: problem.tile, ctas, groups: problem.groups() }
}

/// FlashDecoding's split-factor heuristic (flash-attention
/// `num_splits_heuristic`): if the unsplit grid already fills ≥ 80% of the
/// SMs, don't split; otherwise pick the smallest split count whose wave
/// efficiency is within 85% of the best achievable, skipping split counts
/// that don't actually shrink the per-CTA chunk.
pub fn fd_heuristic_splits(
    problem: &DecodeProblem,
    num_sms: usize,
    max_splits: usize,
) -> usize {
    let batch_nheads = problem.groups(); // N_q = 1 -> one m-block per group
    if batch_nheads as f64 >= 0.8 * num_sms as f64 {
        return 1;
    }
    let num_n_blocks = problem
        .ctx_lens
        .iter()
        .map(|&c| tiles_for_ctx(c as usize, problem.tile))
        .max()
        .unwrap_or(1)
        .max(1) as usize;
    let max_splits = max_splits.min(num_sms).min(num_n_blocks).max(1);

    let eff = |s: usize| -> f64 {
        let n_waves = (batch_nheads * s) as f64 / num_sms as f64;
        n_waves / n_waves.ceil()
    };
    let is_split_eligible = |s: usize| -> bool {
        s == 1 || num_n_blocks.div_ceil(s) != num_n_blocks.div_ceil(s - 1)
    };

    let mut max_eff = 0.0f64;
    for s in 1..=max_splits {
        if is_split_eligible(s) {
            max_eff = max_eff.max(eff(s));
        }
    }
    for s in 1..=max_splits {
        if is_split_eligible(s) && eff(s) >= 0.85 * max_eff {
            return s;
        }
    }
    1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_problem_accessors() {
        let p = DecodeProblem::uniform(4, 32, 65536, 64);
        assert_eq!(p.batch(), 4);
        assert_eq!(p.groups(), 128);
        assert_eq!(p.tile, 256);
        assert_eq!(p.tiles_for_group(0), 256);
        assert_eq!(p.total_tiles(), 128 * 256);
        assert_eq!(p.batch_context_ratio(), 1.0);
    }

    #[test]
    fn gqa_shrinks_groups_but_not_outputs() {
        let p = DecodeProblem::uniform(2, 8, 1024, 64).with_kv_heads(2);
        assert_eq!(p.group_size(), 4);
        assert_eq!(p.groups(), 4); // 2 batch x 2 kv heads
        assert_eq!(p.outputs(), 16); // 2 batch x 8 query heads
        assert_eq!(p.ctx_for_group(3), 1024);
        // Total tiles shrink by exactly the group size.
        let dense = DecodeProblem::uniform(2, 8, 1024, 64);
        assert_eq!(dense.total_tiles(), p.total_tiles() * 4);
    }

    #[test]
    fn kv_heads_equal_heads_is_the_default_identity() {
        let a = DecodeProblem::uniform(3, 4, 2048, 64);
        let b = DecodeProblem::uniform(3, 4, 2048, 64).with_kv_heads(4);
        assert_eq!(a, b);
        assert_eq!(a.kv_heads, a.heads);
        assert_eq!(a.groups(), a.outputs());
        assert_eq!(a.group_size(), 1);
    }

    #[test]
    #[should_panic]
    fn kv_heads_must_divide_heads() {
        let _ = DecodeProblem::uniform(1, 8, 1024, 64).with_kv_heads(3);
    }

    #[test]
    fn ragged_cum_tiles() {
        let p = DecodeProblem::ragged(2, vec![256, 512, 1024], 64);
        // tiles per seq: 1, 2, 4; per group (2 heads each): 1,1,2,2,4,4
        assert_eq!(p.cum_tiles(), vec![0, 1, 2, 4, 6, 10, 14]);
        assert!((p.batch_context_ratio() - (597.33 / 1024.0)).abs() < 0.01);
    }

    #[test]
    fn dense_plan_structure() {
        let p = DecodeProblem::uniform(2, 4, 1024, 64);
        let plan = dense_plan(&p);
        assert_eq!(plan.grid(), 8);
        plan.validate(&p).unwrap();
        assert!(plan.partials_per_group().iter().all(|&c| c == 1));
        assert_eq!(plan.imbalance(), 1.0);
    }

    #[test]
    fn fixed_split_covers_and_chunks() {
        let p = DecodeProblem::uniform(1, 2, 10 * 256, 64); // 10 tiles/group
        let plan = fixed_split_plan(&p, 4, Strategy::FixedSplit { splits: 4 });
        plan.validate(&p).unwrap();
        assert_eq!(plan.grid(), 8); // 2 groups x 4 splits
        // ceil(10/4)=3 -> chunks 3,3,3,1
        let tiles: Vec<u64> = plan.tiles_per_cta();
        assert_eq!(tiles, vec![3, 3, 3, 1, 3, 3, 3, 1]);
        assert!(plan.imbalance() > 1.0);
    }

    #[test]
    fn fixed_split_clamps_to_tiles() {
        let p = DecodeProblem::uniform(1, 1, 256, 64); // 1 tile
        let plan = fixed_split_plan(&p, 8, Strategy::FixedSplit { splits: 8 });
        plan.validate(&p).unwrap();
        assert_eq!(plan.grid(), 1);
    }

    #[test]
    fn fd_heuristic_no_split_when_busy() {
        // groups >= 0.8 * sms -> no split (paper: FD behaves like FA2 at
        // high batch, Fig 7c discussion).
        let p = DecodeProblem::uniform(8, 32, 65536, 64); // 256 groups
        assert_eq!(fd_heuristic_splits(&p, 108, 128), 1);
    }

    #[test]
    fn fd_heuristic_splits_when_idle() {
        let p = DecodeProblem::uniform(1, 8, 65536, 64); // 8 groups, 108 SMs
        let s = fd_heuristic_splits(&p, 108, 128);
        assert!(s > 1, "should split, got {s}");
        assert!(8 * s <= 2 * 108, "not absurdly oversplit: {s}");
    }

    #[test]
    fn validate_catches_gap() {
        let p = DecodeProblem::uniform(1, 1, 512, 64); // 2 tiles
        let plan = Plan {
            strategy: Strategy::Dense,
            tile: p.tile,
            groups: 1,
            ctas: vec![CtaWork {
                segments: vec![Segment {
                    group: 0,
                    tile_begin: 0,
                    tile_count: 1,
                    is_host: true,
                    is_finishing: false,
                }],
            }],
        };
        assert!(plan.validate(&p).is_err());
    }

    #[test]
    fn validate_catches_wrong_host_flag() {
        let p = DecodeProblem::uniform(1, 1, 256, 64);
        let plan = Plan {
            strategy: Strategy::Dense,
            tile: p.tile,
            groups: 1,
            ctas: vec![CtaWork {
                segments: vec![Segment {
                    group: 0,
                    tile_begin: 0,
                    tile_count: 1,
                    is_host: false,
                    is_finishing: true,
                }],
            }],
        };
        assert!(plan.validate(&p).is_err());
    }

    #[test]
    fn strategy_names() {
        assert_eq!(Strategy::Dense.name(), "flashattention2");
        assert_eq!(Strategy::StreamK.name(), "leanattention");
    }
}
