//! Generalized work specification: any attention workload reduces to a
//! list of *output tiles*, each needing some number of LeanTile
//! iterations along the context. Decode problems (`N_q = 1`) produce one
//! output tile per `(batch, head)`; prefill and mixed prefill+decode
//! batches (§V "Batching": heterogeneous batching such as prefill queries
//! with decode) produce several query tiles per sequence with *causal*
//! per-tile iteration counts. The stream-K planner operates on this
//! representation directly, which is what makes LeanAttention's equalized
//! split apply unchanged to every phase mix.

use super::lean_tile::{lean_tile_for, tiles_for_ctx};
use super::plan::{CtaWork, DecodeProblem, Plan, Segment, Strategy};

/// Query-tile height used for prefill output tiles (FA2's m-block).
pub const Q_TILE: usize = 64;

/// One sequence in a mixed batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PhaseReq {
    /// Decode step: a single query token attending to `ctx` cached tokens.
    Decode { ctx: u32 },
    /// Prefill of `q_len` prompt tokens (causal over themselves plus
    /// `past` cached tokens — `past > 0` models chunked prefill).
    Prefill { q_len: u32, past: u32 },
}

/// A heterogeneous batch of prefill and decode requests sharing the GPU.
///
/// Output tiles are emitted at **kv-head** granularity: under GQA each
/// group of `heads / kv_heads` query heads shares one KV walk, so one
/// output tile (and one LeanTile iteration stream) serves the whole
/// group. With `kv_heads == heads` (the default) this is the classic
/// one-tile-per-query-head layout.
#[derive(Clone, Debug)]
pub struct MixedWorkload {
    pub heads: usize,
    /// KV heads; divides `heads`. Defaults to `heads` (no grouping).
    pub kv_heads: usize,
    pub head_dim: usize,
    pub reqs: Vec<PhaseReq>,
    pub tile: usize,
}

impl MixedWorkload {
    pub fn new(heads: usize, head_dim: usize, reqs: Vec<PhaseReq>) -> MixedWorkload {
        MixedWorkload { heads, kv_heads: heads, head_dim, reqs, tile: lean_tile_for(head_dim) }
    }

    /// Switch to a grouped-query layout with `kv_heads` KV heads.
    pub fn with_kv_heads(mut self, kv_heads: usize) -> MixedWorkload {
        assert!(kv_heads >= 1, "kv_heads must be >= 1");
        assert!(
            self.heads % kv_heads == 0,
            "heads {} not divisible by kv_heads {kv_heads}",
            self.heads
        );
        self.kv_heads = kv_heads;
        self
    }

    /// Flatten into per-output-tile iteration counts
    /// (request-major, kv heads inner, query tiles innermost).
    pub fn tile_counts(&self) -> Vec<u64> {
        let mut counts = Vec::new();
        for req in &self.reqs {
            match *req {
                PhaseReq::Decode { ctx } => {
                    let c = tiles_for_ctx(ctx as usize, self.tile);
                    for _ in 0..self.kv_heads {
                        counts.push(c);
                    }
                }
                PhaseReq::Prefill { q_len, past } => {
                    let q_tiles = (q_len as usize).div_ceil(Q_TILE);
                    for _ in 0..self.kv_heads {
                        for qi in 0..q_tiles {
                            // Causal: query tile qi sees `past` cached
                            // tokens plus prompt tokens up to its last row.
                            let visible = past as usize
                                + ((qi + 1) * Q_TILE).min(q_len as usize);
                            counts.push(tiles_for_ctx(visible, self.tile));
                        }
                    }
                }
            }
        }
        counts
    }

    pub fn total_tiles(&self) -> u64 {
        self.tile_counts().iter().sum()
    }
}

/// Build a stream-K plan from raw per-output-tile iteration counts —
/// the core of Algorithm 2 lines 4-9, independent of what the output
/// tiles represent.
pub fn stream_k_from_counts(counts: &[u64], tile: usize, sm_slots: usize) -> Plan {
    assert!(sm_slots > 0);
    let groups = counts.len();
    let mut cum = Vec::with_capacity(groups + 1);
    let mut acc = 0u64;
    cum.push(0);
    for &c in counts {
        acc += c;
        cum.push(acc);
    }
    let total = acc;
    if total == 0 {
        return Plan { strategy: Strategy::StreamK, tile, ctas: Vec::new(), groups };
    }

    let grid = (sm_slots as u64).min(total) as usize;
    let base = total / grid as u64;
    let rem = (total % grid as u64) as usize;

    let mut ctas = Vec::with_capacity(grid);
    let mut iter = 0u64;
    let mut group = 0usize;
    for cta in 0..grid {
        let take = base + u64::from(cta < rem);
        let end = iter + take;
        let mut work = CtaWork::default();
        while iter < end {
            while cum[group + 1] <= iter {
                group += 1;
            }
            let (g_begin, g_end) = (cum[group], cum[group + 1]);
            let seg_begin = iter - g_begin;
            let seg_end = end.min(g_end) - g_begin;
            work.segments.push(Segment {
                group: group as u32,
                tile_begin: seg_begin as u32,
                tile_count: (seg_end - seg_begin) as u32,
                is_host: seg_begin == 0,
                is_finishing: g_begin + seg_end == g_end,
            });
            iter = g_begin + seg_end;
        }
        ctas.push(work);
    }
    Plan { strategy: Strategy::StreamK, tile, ctas, groups }
}

/// Fixed-split over raw counts (the FD baseline for mixed batches).
pub fn fixed_split_from_counts(
    counts: &[u64],
    tile: usize,
    splits: usize,
    strategy: Strategy,
) -> Plan {
    assert!(splits > 0);
    let mut ctas = Vec::new();
    for (g, &tiles) in counts.iter().enumerate() {
        if tiles == 0 {
            continue;
        }
        let s = (splits as u64).min(tiles);
        let chunk = tiles.div_ceil(s);
        let mut begin = 0u64;
        while begin < tiles {
            let count = chunk.min(tiles - begin);
            ctas.push(CtaWork {
                segments: vec![Segment {
                    group: g as u32,
                    tile_begin: begin as u32,
                    tile_count: count as u32,
                    is_host: begin == 0,
                    is_finishing: begin + count == tiles,
                }],
            });
            begin += count;
        }
    }
    Plan { strategy, tile, ctas, groups: counts.len() }
}

/// Validate a plan against raw counts (shared invariant checker for
/// count-based plans; mirrors `Plan::validate`).
pub fn validate_counts(plan: &Plan, counts: &[u64]) -> anyhow::Result<()> {
    // Reuse Plan::validate by wrapping counts in a fake decode problem
    // with heads=1 and ctx = count*tile per "batch element".
    let ctx_lens: Vec<u32> = counts
        .iter()
        .map(|&c| (c as usize * plan.tile) as u32)
        .collect();
    let p = DecodeProblem { heads: 1, kv_heads: 1, head_dim: 64, ctx_lens, tile: plan.tile };
    plan.validate(&p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::prop_check;

    #[test]
    fn decode_counts_match_decode_problem() {
        let w = MixedWorkload::new(4, 64, vec![
            PhaseReq::Decode { ctx: 1000 },
            PhaseReq::Decode { ctx: 70_000 },
        ]);
        let p = DecodeProblem::ragged(4, vec![1000, 70_000], 64);
        let counts = w.tile_counts();
        let expect: Vec<u64> = (0..p.groups()).map(|g| p.tiles_for_group(g)).collect();
        assert_eq!(counts, expect);
    }

    #[test]
    fn gqa_counts_match_a_kv_head_sized_workload() {
        // One output tile per kv head: an 8-head/2-kv-head workload plans
        // exactly like a 2-head dense one (each tile just carries 4 query
        // rows at execution time).
        let reqs = vec![
            PhaseReq::Decode { ctx: 1000 },
            PhaseReq::Prefill { q_len: 256, past: 64 },
        ];
        let grouped = MixedWorkload::new(8, 64, reqs.clone()).with_kv_heads(2);
        let dense_small = MixedWorkload::new(2, 64, reqs);
        assert_eq!(grouped.tile_counts(), dense_small.tile_counts());
        assert_eq!(grouped.total_tiles(), dense_small.total_tiles());
    }

    #[test]
    fn prefill_causal_counts_are_triangular() {
        let w = MixedWorkload::new(1, 64, vec![PhaseReq::Prefill { q_len: 256, past: 0 }]);
        // q tiles of 64: visible 64, 128, 192, 256 -> tiles (tile=256): 1,1,1,1
        assert_eq!(w.tile_counts(), vec![1, 1, 1, 1]);
        let w2 = MixedWorkload {
            tile: 64,
            ..MixedWorkload::new(1, 64, vec![PhaseReq::Prefill { q_len: 256, past: 0 }])
        };
        assert_eq!(w2.tile_counts(), vec![1, 2, 3, 4]); // causal triangle
    }

    #[test]
    fn chunked_prefill_includes_past() {
        let w = MixedWorkload {
            tile: 64,
            ..MixedWorkload::new(1, 64, vec![PhaseReq::Prefill { q_len: 64, past: 128 }])
        };
        assert_eq!(w.tile_counts(), vec![3]); // 128 past + 64 new = 3 tiles
    }

    #[test]
    fn mixed_batch_stream_k_balanced() {
        let w = MixedWorkload::new(8, 64, vec![
            PhaseReq::Decode { ctx: 131_072 },
            PhaseReq::Prefill { q_len: 2048, past: 0 },
            PhaseReq::Decode { ctx: 512 },
        ]);
        let counts = w.tile_counts();
        let plan = stream_k_from_counts(&counts, w.tile, 216);
        validate_counts(&plan, &counts).unwrap();
        let tiles = plan.tiles_per_cta();
        let max = *tiles.iter().max().unwrap();
        let min = *tiles.iter().min().unwrap();
        assert!(max - min <= 1, "mixed-batch balance {min}..{max}");
    }

    #[test]
    fn stream_k_from_counts_matches_decode_planner() {
        let p = DecodeProblem::ragged(4, vec![9000, 255, 70_000], 64);
        let counts: Vec<u64> = (0..p.groups()).map(|g| p.tiles_for_group(g)).collect();
        let a = super::super::stream_k::stream_k_plan(&p, 108);
        let b = stream_k_from_counts(&counts, p.tile, 108);
        assert_eq!(a.grid(), b.grid());
        for (x, y) in a.ctas.iter().zip(&b.ctas) {
            assert_eq!(x.segments, y.segments);
        }
    }

    #[test]
    fn property_mixed_plans_valid() {
        prop_check("mixed-batch plan invariants", 100, |rng| {
            let heads = rng.urange(1, 9);
            let n = rng.urange(1, 8);
            let reqs: Vec<PhaseReq> = (0..n)
                .map(|_| {
                    if rng.chance(0.5) {
                        PhaseReq::Decode { ctx: rng.range(1, 200_000) as u32 }
                    } else {
                        PhaseReq::Prefill {
                            q_len: rng.range(1, 4096) as u32,
                            past: rng.range(0, 10_000) as u32,
                        }
                    }
                })
                .collect();
            let w = MixedWorkload::new(heads, 64, reqs);
            let counts = w.tile_counts();
            let slots = rng.urange(1, 512);
            let plan = stream_k_from_counts(&counts, w.tile, slots);
            validate_counts(&plan, &counts).map_err(|e| e.to_string())?;
            let fd = fixed_split_from_counts(
                &counts,
                w.tile,
                rng.urange(1, 16),
                Strategy::FixedSplit { splits: 1 },
            );
            validate_counts(&fd, &counts).map_err(|e| e.to_string())?;
            Ok(())
        });
    }
}
