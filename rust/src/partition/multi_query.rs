//! Multi-query decode partitioning: `q_len = k` query rows per sequence
//! over one shared context stream — the attention shape of a
//! speculative-decoding verify pass (and of true frontier beam search).
//!
//! A draft block of `q_len` tokens is causal *within* the block: query
//! row `i` attends to the `base_len` cached tokens plus block tokens
//! `0..=i`. That is exactly a **ragged cascade problem** over expanded
//! row-lanes: every row of a sequence shares the sequence's cached
//! context as a prefix group (streamed **once** for all `q_len` rows —
//! the `k` memory-bound single-token steps collapse into one walk of the
//! KV stream), and row `i`'s private suffix is the tiny staggered slice
//! of draft-block K/V it alone may see. Fork families compose: siblings
//! sharing history form one prefix group spanning *all* their rows, so
//! speculative verification of a best-of-n family still deduplicates the
//! shared pages like any cascade group.
//!
//! Everything downstream is reused, not re-implemented: the expansion
//! produces a [`CascadeProblem`], the stream-K planner schedules it, and
//! `runtime::attention_exec::lean_multi_query` executes it through the
//! same task-rolling + group-broadcast-fold driver as every other
//! cascade plan (exactness property-tested in `rust/tests/spec_props.rs`
//! against the dense host oracle).

use anyhow::{ensure, Result};

use crate::util::rng::Rng;

use super::cascade::{CascadeProblem, CascadeTensors, PrefixGroup};
use super::lean_tile::lean_tile_for;

/// One sequence's draft block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MultiQuerySeq {
    /// Cached context tokens the whole block attends to.
    pub base_len: usize,
    /// Query rows in the block (pending token + drafts), >= 1.
    pub q_len: usize,
}

/// A batch of draft blocks, optionally grouped into fork families.
#[derive(Clone, Debug)]
pub struct MultiQueryProblem {
    /// Query heads.
    pub heads: usize,
    /// KV heads (GQA); divides `heads`, == `heads` when ungrouped.
    pub kv_heads: usize,
    pub head_dim: usize,
    pub seqs: Vec<MultiQuerySeq>,
    /// LeanTile size in tokens.
    pub tile: usize,
    /// Fork families: `members` index [`Self::seqs`], `prefix_len`
    /// counts **base** tokens every member's cached context begins with
    /// (byte-identical leading KV, e.g. shared pages after a fork).
    pub families: Vec<PrefixGroup>,
}

impl MultiQueryProblem {
    /// Build and validate.
    pub fn new(
        heads: usize,
        head_dim: usize,
        seqs: Vec<MultiQuerySeq>,
        families: Vec<PrefixGroup>,
    ) -> Result<MultiQueryProblem> {
        ensure!(heads >= 1 && head_dim >= 1, "need heads and head_dim >= 1");
        ensure!(!seqs.is_empty(), "need at least one sequence");
        for (i, s) in seqs.iter().enumerate() {
            ensure!(s.q_len >= 1, "sequence {i} has an empty draft block");
        }
        let mut owner = vec![false; seqs.len()];
        for (fi, f) in families.iter().enumerate() {
            ensure!(!f.members.is_empty(), "family {fi} has no members");
            ensure!(f.prefix_len >= 1, "family {fi} has an empty prefix");
            for &m in &f.members {
                let m = m as usize;
                ensure!(m < seqs.len(), "family {fi}: member {m} out of range");
                ensure!(!owner[m], "sequence {m} in more than one family");
                owner[m] = true;
                ensure!(
                    f.prefix_len as usize <= seqs[m].base_len,
                    "family {fi}: prefix {} exceeds member {m} base {}",
                    f.prefix_len,
                    seqs[m].base_len
                );
            }
        }
        Ok(MultiQueryProblem {
            heads,
            kv_heads: heads,
            head_dim,
            seqs,
            tile: lean_tile_for(head_dim),
            families,
        })
    }

    pub fn with_tile(mut self, tile: usize) -> Self {
        assert!(tile > 0);
        self.tile = tile;
        self
    }

    /// Switch to a grouped-query layout with `kv_heads` KV heads.
    pub fn with_kv_heads(mut self, kv_heads: usize) -> Self {
        assert!(kv_heads >= 1, "kv_heads must be >= 1");
        assert!(
            self.heads % kv_heads == 0,
            "heads {} not divisible by kv_heads {kv_heads}",
            self.heads
        );
        self.kv_heads = kv_heads;
        self
    }

    /// Total query rows across all draft blocks.
    pub fn rows(&self) -> usize {
        self.seqs.iter().map(|s| s.q_len).sum()
    }

    /// First row index of sequence `seq`.
    pub fn row_start(&self, seq: usize) -> usize {
        self.seqs[..seq].iter().map(|s| s.q_len).sum()
    }

    /// `(sequence, block position)` of a global row index.
    pub fn seq_of_row(&self, row: usize) -> (usize, usize) {
        let mut r = row;
        for (s, q) in self.seqs.iter().enumerate() {
            if r < q.q_len {
                return (s, r);
            }
            r -= q.q_len;
        }
        panic!("row {row} out of range");
    }

    /// Context length row `i` of sequence `seq` attends to (causal
    /// within the block: cached base + block tokens `0..=i`).
    pub fn ctx_of(&self, seq: usize, i: usize) -> usize {
        self.seqs[seq].base_len + i + 1
    }

    /// Prefix groups over the expanded row-lanes. Grouping is disjoint
    /// (nested/hierarchical groups are a ROADMAP item), so per family we
    /// pick whichever grouping deduplicates more bytes: the family-wide
    /// group over the shared history, or each member's private per-block
    /// group over its whole base. Ungrouped sequences with >= 2 rows
    /// always get the per-block group.
    fn row_groups(&self) -> Vec<PrefixGroup> {
        let mut out = Vec::new();
        let mut family_of = vec![false; self.seqs.len()];
        for f in &self.families {
            let rows_total: usize =
                f.members.iter().map(|&m| self.seqs[m as usize].q_len).sum();
            if rows_total < 2 {
                continue;
            }
            // Tokens the family group saves vs tokens the members' own
            // per-block groups would save.
            let family_saving = f.prefix_len as usize * (rows_total - 1);
            let per_seq_saving: usize = f
                .members
                .iter()
                .map(|&m| {
                    let s = self.seqs[m as usize];
                    s.base_len * (s.q_len - 1)
                })
                .sum();
            if family_saving >= per_seq_saving {
                let members: Vec<u32> = f
                    .members
                    .iter()
                    .flat_map(|&m| {
                        let start = self.row_start(m as usize) as u32;
                        let q = self.seqs[m as usize].q_len as u32;
                        start..start + q
                    })
                    .collect();
                out.push(PrefixGroup { prefix_len: f.prefix_len, members });
                for &m in &f.members {
                    family_of[m as usize] = true;
                }
            }
        }
        for (s, seq) in self.seqs.iter().enumerate() {
            if family_of[s] || seq.q_len < 2 || seq.base_len == 0 {
                continue;
            }
            let start = self.row_start(s) as u32;
            out.push(PrefixGroup {
                prefix_len: seq.base_len as u32,
                members: (start..start + seq.q_len as u32).collect(),
            });
        }
        out
    }

    /// Expand to the cascade problem over per-row lanes.
    pub fn expand(&self) -> CascadeProblem {
        let lens: Vec<u32> = self
            .seqs
            .iter()
            .flat_map(|s| (0..s.q_len).map(move |i| (s.base_len + i + 1) as u32))
            .collect();
        CascadeProblem::new(self.heads, lens, self.head_dim, self.row_groups())
            .expect("expansion of a validated multi-query problem")
            .with_tile(self.tile)
            .with_kv_heads(self.kv_heads)
    }

    /// The sharing-oblivious twin: same row-lanes, no prefix structure
    /// (every row streams its whole context) — the byte baseline.
    pub fn expand_flat(&self) -> CascadeProblem {
        let lens = self.expand().ctx_lens;
        CascadeProblem::new(self.heads, lens, self.head_dim, Vec::new())
            .expect("flat expansion is always valid")
            .with_tile(self.tile)
            .with_kv_heads(self.kv_heads)
    }

    /// Build the expanded problem plus its tensors from per-sequence
    /// inputs. Returns `(cascade problem, tensors)` ready for
    /// `lean_cascade` / `lean_cascade_host`; outputs are
    /// `[rows * heads, head_dim]` in expanded row order.
    pub fn tensors(&self, inputs: &MultiQueryInputs) -> Result<(CascadeProblem, CascadeTensors)> {
        let (h, hk, d) = (self.heads, self.kv_heads, self.head_dim);
        let n = self.seqs.len();
        ensure!(
            inputs.q.len() == n
                && inputs.base_k.len() == n
                && inputs.base_v.len() == n
                && inputs.draft_k.len() == n
                && inputs.draft_v.len() == n,
            "inputs must cover every sequence"
        );
        for (s, seq) in self.seqs.iter().enumerate() {
            ensure!(inputs.q[s].len() == seq.q_len * h * d, "seq {s}: q shape");
            ensure!(
                inputs.base_k[s].len() == hk * seq.base_len * d
                    && inputs.base_v[s].len() == inputs.base_k[s].len(),
                "seq {s}: base kv shape"
            );
            ensure!(
                inputs.draft_k[s].len() == hk * seq.q_len * d
                    && inputs.draft_v[s].len() == inputs.draft_k[s].len(),
                "seq {s}: draft kv shape"
            );
        }

        let cp = self.expand();

        // Query rows: per-seq [q_len, heads, d] blocks concatenate into
        // the expanded [rows * heads, d] layout directly.
        let mut q = Vec::with_capacity(self.rows() * h * d);
        for qs in &inputs.q {
            q.extend_from_slice(qs);
        }

        // Shared tensors, one per surviving prefix group, in group
        // order: the leading `prefix` base tokens of the group's first
        // member row's sequence, `[kv_heads, prefix, d]`.
        let mut k_shared = Vec::with_capacity(cp.prefix_groups.len());
        let mut v_shared = Vec::with_capacity(cp.prefix_groups.len());
        for g in &cp.prefix_groups {
            let (s0, _) = self.seq_of_row(g.members[0] as usize);
            let base = self.seqs[s0].base_len;
            let prefix = g.prefix_len as usize;
            let mut ks = Vec::with_capacity(hk * prefix * d);
            let mut vs = Vec::with_capacity(hk * prefix * d);
            for hi in 0..hk {
                let src = hi * base * d;
                ks.extend_from_slice(&inputs.base_k[s0][src..src + prefix * d]);
                vs.extend_from_slice(&inputs.base_v[s0][src..src + prefix * d]);
            }
            k_shared.push(ks);
            v_shared.push(vs);
        }

        // Per-row suffixes: base remainder past the row's group prefix,
        // then draft-block tokens 0..=i, `[kv_heads, suffix, d]`.
        let rows = self.rows();
        let mut k_suffix = Vec::with_capacity(rows);
        let mut v_suffix = Vec::with_capacity(rows);
        for row in 0..rows {
            let (s, i) = self.seq_of_row(row);
            let base = self.seqs[s].base_len;
            let q_len = self.seqs[s].q_len;
            let prefix = cp.prefix_of(row) as usize;
            let suffix = self.ctx_of(s, i) - prefix;
            let mut ks = Vec::with_capacity(hk * suffix * d);
            let mut vs = Vec::with_capacity(hk * suffix * d);
            for hi in 0..hk {
                let bsrc = (hi * base + prefix) * d;
                ks.extend_from_slice(&inputs.base_k[s][bsrc..hi * base * d + base * d]);
                vs.extend_from_slice(&inputs.base_v[s][bsrc..hi * base * d + base * d]);
                let dsrc = hi * q_len * d;
                ks.extend_from_slice(&inputs.draft_k[s][dsrc..dsrc + (i + 1) * d]);
                vs.extend_from_slice(&inputs.draft_v[s][dsrc..dsrc + (i + 1) * d]);
            }
            debug_assert_eq!(ks.len(), hk * suffix * d);
            k_suffix.push(ks);
            v_suffix.push(vs);
        }

        Ok((cp, CascadeTensors { q, k_shared, v_shared, k_suffix, v_suffix }))
    }
}

/// Per-sequence host tensors for a [`MultiQueryProblem`].
#[derive(Clone, Debug, Default)]
pub struct MultiQueryInputs {
    /// Per sequence: `[q_len, heads, d]` query rows (block positions).
    pub q: Vec<Vec<f32>>,
    /// Per sequence: `[kv_heads, base_len, d]` cached K rows.
    pub base_k: Vec<Vec<f32>>,
    pub base_v: Vec<Vec<f32>>,
    /// Per sequence: `[kv_heads, q_len, d]` draft-block K rows.
    pub draft_k: Vec<Vec<f32>>,
    pub draft_v: Vec<Vec<f32>>,
}

impl MultiQueryInputs {
    /// Random inputs for `p`, deterministic in `seed`. Family members'
    /// leading `prefix_len` base tokens are generated once per family
    /// and copied into every member, honoring the byte-identical-prefix
    /// contract real shared KV pages provide. With `kv_heads == heads`
    /// the draw sequence matches the ungrouped one.
    pub fn random(p: &MultiQueryProblem, seed: u64) -> MultiQueryInputs {
        let mut rng = Rng::new(seed);
        let (h, hk, d) = (p.heads, p.kv_heads, p.head_dim);
        // Shared leading base tokens per family, `[kv_heads, prefix, d]`.
        let shared: Vec<Vec<f32>> = p
            .families
            .iter()
            .map(|f| rng.normal_vec(hk * f.prefix_len as usize * d))
            .collect();
        let shared_v: Vec<Vec<f32>> = p
            .families
            .iter()
            .map(|f| rng.normal_vec(hk * f.prefix_len as usize * d))
            .collect();
        let family_of = |s: usize| -> Option<usize> {
            p.families
                .iter()
                .position(|f| f.members.contains(&(s as u32)))
        };

        let mut out = MultiQueryInputs::default();
        for (s, seq) in p.seqs.iter().enumerate() {
            out.q.push(rng.normal_vec(seq.q_len * h * d));
            let (mut bk, mut bv) =
                (rng.normal_vec(hk * seq.base_len * d), rng.normal_vec(hk * seq.base_len * d));
            if let Some(fi) = family_of(s) {
                let prefix = p.families[fi].prefix_len as usize;
                for hi in 0..hk {
                    let dst = hi * seq.base_len * d;
                    let src = hi * prefix * d;
                    bk[dst..dst + prefix * d]
                        .copy_from_slice(&shared[fi][src..src + prefix * d]);
                    bv[dst..dst + prefix * d]
                        .copy_from_slice(&shared_v[fi][src..src + prefix * d]);
                }
            }
            out.base_k.push(bk);
            out.base_v.push(bv);
            out.draft_k.push(rng.normal_vec(hk * seq.q_len * d));
            out.draft_v.push(rng.normal_vec(hk * seq.q_len * d));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(base_len: usize, q_len: usize) -> MultiQuerySeq {
        MultiQuerySeq { base_len, q_len }
    }

    #[test]
    fn expansion_staggers_causal_lens() {
        let p = MultiQueryProblem::new(2, 8, vec![seq(64, 3), seq(40, 1)], vec![])
            .unwrap()
            .with_tile(16);
        assert_eq!(p.rows(), 4);
        assert_eq!(p.row_start(1), 3);
        assert_eq!(p.seq_of_row(2), (0, 2));
        assert_eq!(p.seq_of_row(3), (1, 0));
        let cp = p.expand();
        assert_eq!(cp.ctx_lens, vec![65, 66, 67, 41]);
        // Seq 0's three rows share its 64-token base; seq 1 is a single
        // row (no group).
        assert_eq!(cp.prefix_groups.len(), 1);
        assert_eq!(cp.prefix_groups[0].prefix_len, 64);
        assert_eq!(cp.prefix_groups[0].members, vec![0, 1, 2]);
        assert!(p.expand_flat().prefix_groups.is_empty());
    }

    #[test]
    fn expansion_dedups_fewer_tiles_than_flat() {
        let p = MultiQueryProblem::new(2, 8, vec![seq(256, 5)], vec![])
            .unwrap()
            .with_tile(16);
        let cascade = p.expand().segment_problem().total_tiles();
        let flat = p.expand_flat().segment_problem().total_tiles();
        assert!(
            cascade < flat,
            "multi-query expansion must stream the base once ({cascade} vs {flat})"
        );
    }

    #[test]
    fn family_grouping_spans_sibling_rows_when_it_saves_more() {
        // Two siblings share 96 of their 100 base tokens, 3 rows each:
        // family saving 96*(6-1)=480 > per-seq 100*2*2=400.
        let fam = PrefixGroup { prefix_len: 96, members: vec![0, 1] };
        let p = MultiQueryProblem::new(1, 8, vec![seq(100, 3), seq(100, 3)], vec![fam])
            .unwrap()
            .with_tile(16);
        let cp = p.expand();
        assert_eq!(cp.prefix_groups.len(), 1);
        assert_eq!(cp.prefix_groups[0].prefix_len, 96);
        assert_eq!(cp.prefix_groups[0].members, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn shallow_family_falls_back_to_per_block_groups() {
        // Siblings share only 8 of 100 base tokens: per-block grouping
        // saves more, so the family dissolves into two row groups.
        let fam = PrefixGroup { prefix_len: 8, members: vec![0, 1] };
        let p = MultiQueryProblem::new(1, 8, vec![seq(100, 3), seq(100, 3)], vec![fam])
            .unwrap()
            .with_tile(16);
        let cp = p.expand();
        assert_eq!(cp.prefix_groups.len(), 2);
        assert!(cp.prefix_groups.iter().all(|g| g.prefix_len == 100));
    }

    #[test]
    fn validation_rejects_bad_shapes() {
        assert!(MultiQueryProblem::new(1, 8, vec![], vec![]).is_err());
        assert!(MultiQueryProblem::new(1, 8, vec![seq(4, 0)], vec![]).is_err());
        let fam = PrefixGroup { prefix_len: 8, members: vec![0] };
        assert!(MultiQueryProblem::new(1, 8, vec![seq(4, 1)], vec![fam]).is_err());
        let fam = PrefixGroup { prefix_len: 2, members: vec![0, 2] };
        assert!(MultiQueryProblem::new(1, 8, vec![seq(4, 1), seq(4, 1)], vec![fam]).is_err());
    }

    #[test]
    fn tensors_compose_shared_and_staggered_suffixes() {
        let p = MultiQueryProblem::new(2, 4, vec![seq(8, 2)], vec![])
            .unwrap()
            .with_tile(4);
        let inputs = MultiQueryInputs::random(&p, 3);
        let (cp, t) = p.tensors(&inputs).unwrap();
        assert_eq!(cp.prefix_groups.len(), 1);
        assert_eq!(t.k_shared[0].len(), 2 * 8 * 4);
        assert_eq!(t.k_shared[0], inputs.base_k[0]);
        // Row 0 suffix: draft token 0 only; row 1: draft tokens 0..=1.
        assert_eq!(t.k_suffix[0].len(), 2 * 4);
        assert_eq!(t.k_suffix[1].len(), 2 * 2 * 4);
        // Head 0 of row 1's suffix equals draft tokens 0 and 1, head 0.
        assert_eq!(&t.k_suffix[1][..2 * 4], &inputs.draft_k[0][..2 * 4]);
        // q concatenates per-seq blocks in row order.
        assert_eq!(t.q, inputs.q[0]);
    }

    #[test]
    fn gqa_expansion_and_tensors_use_the_kv_head_plane() {
        let p = MultiQueryProblem::new(4, 8, vec![seq(64, 3), seq(40, 1)], vec![])
            .unwrap()
            .with_tile(16)
            .with_kv_heads(2);
        let cp = p.expand();
        assert_eq!(cp.kv_heads, 2);
        assert_eq!(p.expand_flat().kv_heads, 2);
        let inputs = MultiQueryInputs::random(&p, 5);
        // KV at [kv_heads, len, d]; q stays at query-head rows.
        assert_eq!(inputs.base_k[0].len(), 2 * 64 * 8);
        assert_eq!(inputs.draft_k[0].len(), 2 * 3 * 8);
        assert_eq!(inputs.q[0].len(), 3 * 4 * 8);
        let (cp2, t) = p.tensors(&inputs).unwrap();
        assert_eq!(cp2.group_size(), 2);
        assert_eq!(t.k_shared[0].len(), 2 * 64 * 8);
        assert_eq!(t.q.len(), p.rows() * 4 * 8);
    }

    #[test]
    fn random_family_inputs_share_prefix_bytes() {
        let fam = PrefixGroup { prefix_len: 6, members: vec![0, 1] };
        let p = MultiQueryProblem::new(2, 4, vec![seq(8, 2), seq(10, 2)], vec![fam]).unwrap();
        let inputs = MultiQueryInputs::random(&p, 9);
        // Head-wise: the first 6 base tokens agree across members.
        for hi in 0..2 {
            let a = &inputs.base_k[0][hi * 8 * 4..hi * 8 * 4 + 6 * 4];
            let b = &inputs.base_k[1][hi * 10 * 4..hi * 10 * 4 + 6 * 4];
            assert_eq!(a, b, "head {hi} prefix bytes differ");
        }
    }
}
