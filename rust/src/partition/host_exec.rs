//! Host execution of a partition [`Plan`] on real numbers.
//!
//! Every simulated CTA runs Algorithm 1 over its segments with the Rust
//! oracle; the host CTAs then perform Algorithm 2's reduction in an
//! arbitrary (optionally shuffled) order. The output must equal plain
//! exact attention for **every** legal plan — this is the repo's
//! numerical witness of the paper's associativity theorem applied to the
//! actual planners, and the integration point the property tests sweep.

use crate::attention::{partial_attention_host, Partials};
use crate::util::rng::Rng;

use super::plan::{DecodeProblem, Plan};

/// Padded host tensors for a decode problem: `q [outputs, d]` (one row
/// per query head), `k/v [groups, n_max, d]` (one KV stream per
/// **kv head**) with per-group valid lengths from the problem. With
/// `kv_heads == heads` outputs == groups and this is the classic layout.
pub struct HostTensors {
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub n_max: usize,
}

impl HostTensors {
    /// Random tensors for `problem` (deterministic in `seed`; with
    /// `kv_heads == heads` the draw sequence matches the ungrouped one).
    pub fn random(problem: &DecodeProblem, seed: u64) -> HostTensors {
        let mut rng = Rng::new(seed);
        let g = problem.groups();
        let d = problem.head_dim;
        let n_max = problem
            .ctx_lens
            .iter()
            .copied()
            .max()
            .unwrap_or(0) as usize;
        HostTensors {
            q: rng.normal_vec(problem.outputs() * d),
            k: rng.normal_vec(g * n_max * d),
            v: rng.normal_vec(g * n_max * d),
            n_max,
        }
    }

    pub fn group_lens(&self, problem: &DecodeProblem) -> Vec<u32> {
        (0..problem.groups())
            .map(|gi| problem.ctx_for_group(gi) as u32)
            .collect()
    }

    /// Per-output valid lengths (each group's length once per query head).
    pub fn output_lens(&self, problem: &DecodeProblem) -> Vec<u32> {
        (0..problem.outputs())
            .map(|o| problem.ctx_lens[o / problem.heads])
            .collect()
    }

    /// The repeated-KV dense oracle view: K/V materialized per **query
    /// head** (`[outputs, n_max, d]`) by repeating each kv-head stream
    /// `group_size` times. With `kv_heads == heads` this is a plain copy.
    pub fn repeated_kv(&self, problem: &DecodeProblem) -> (Vec<f32>, Vec<f32>) {
        let d = problem.head_dim;
        let (h, hk) = (problem.heads, problem.kv_heads);
        let gs = problem.group_size();
        let stride = self.n_max * d;
        let mut k = vec![0.0f32; problem.outputs() * stride];
        let mut v = vec![0.0f32; k.len()];
        for o in 0..problem.outputs() {
            let (b, hi) = (o / h, o % h);
            let gi = b * hk + hi / gs;
            k[o * stride..(o + 1) * stride]
                .copy_from_slice(&self.k[gi * stride..(gi + 1) * stride]);
            v[o * stride..(o + 1) * stride]
                .copy_from_slice(&self.v[gi * stride..(gi + 1) * stride]);
        }
        (k, v)
    }
}

/// Execute `plan` on host numbers. `shuffle_seed` randomizes the order in
/// which each group's partials are reduced (None = CTA order) — the result
/// must not depend on it.
pub fn execute_plan_host(
    plan: &Plan,
    problem: &DecodeProblem,
    t: &HostTensors,
    shuffle_seed: Option<u64>,
) -> Vec<f32> {
    let d = problem.head_dim;
    let (heads, kv_heads) = (problem.heads, problem.kv_heads);
    let gs = problem.group_size();
    let tile = plan.tile;
    let lens = t.group_lens(problem);

    // Phase 1: every CTA computes one partial per segment (Alg 1). A
    // segment's group is a (batch, kv head) pair: under GQA its KV slice
    // serves all `gs` query heads of that group.
    let mut per_output: Vec<Vec<Partials>> = vec![Vec::new(); problem.outputs()];
    for cta in &plan.ctas {
        for seg in &cta.segments {
            let gi = seg.group as usize;
            let start = seg.tile_begin as usize * tile;
            let end = ((seg.tile_begin + seg.tile_count) as usize * tile)
                .min(t.n_max);
            let width = end - start;
            // Views into the padded K/V for this group's slice.
            let k_slice =
                &t.k[gi * t.n_max * d + start * d..gi * t.n_max * d + end * d];
            let v_slice =
                &t.v[gi * t.n_max * d + start * d..gi * t.n_max * d + end * d];
            for j in 0..gs {
                let out = (gi / kv_heads) * heads + (gi % kv_heads) * gs + j;
                let q_row = &t.q[out * d..(out + 1) * d];
                let p = partial_attention_host(
                    q_row,
                    k_slice,
                    v_slice,
                    1,
                    width,
                    d,
                    &[lens[gi]],
                    start,
                );
                per_output[out].push(p);
            }
        }
    }

    // Phase 2: host-CTA reduction (Alg 2 lines 24-39), order-shuffled.
    let mut rng = shuffle_seed.map(Rng::new);
    let mut out = vec![0.0f32; problem.outputs() * d];
    for (oi, mut parts) in per_output.into_iter().enumerate() {
        if parts.is_empty() {
            continue; // empty context
        }
        if let Some(r) = rng.as_mut() {
            // Fisher-Yates
            for i in (1..parts.len()).rev() {
                let j = r.urange(0, i + 1);
                parts.swap(i, j);
            }
        }
        let mut acc = Partials::identity(1, d);
        for p in &parts {
            acc.reduce_from(p);
        }
        out[oi * d..(oi + 1) * d].copy_from_slice(&acc.finalize());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::attention_host;
    use crate::partition::plan::{build_plan, Strategy};
    use crate::util::testing::{max_abs_err, prop_check};

    fn direct(problem: &DecodeProblem, t: &HostTensors) -> Vec<f32> {
        // Repeated-KV dense oracle: exact attention per query head over
        // KV materialized to query-head count (a copy when ungrouped).
        let (k, v) = t.repeated_kv(problem);
        attention_host(
            &t.q,
            &k,
            &v,
            problem.outputs(),
            t.n_max,
            problem.head_dim,
            &t.output_lens(problem),
        )
    }

    #[test]
    fn all_strategies_compute_exact_attention() {
        let problem = DecodeProblem::uniform(2, 3, 700, 64).with_tile(64);
        let t = HostTensors::random(&problem, 42);
        let want = direct(&problem, &t);
        for strategy in [
            Strategy::Dense,
            Strategy::FixedSplit { splits: 4 },
            Strategy::StreamK,
        ] {
            let plan = build_plan(&problem, strategy, 10);
            plan.validate(&problem).unwrap();
            let got = execute_plan_host(&plan, &problem, &t, None);
            let err = max_abs_err(&got, &want);
            assert!(err < 1e-4, "{}: err {err}", strategy.name());
        }
    }

    #[test]
    fn reduction_order_does_not_matter() {
        let problem = DecodeProblem::uniform(1, 4, 1500, 64).with_tile(32);
        let t = HostTensors::random(&problem, 7);
        let plan = build_plan(&problem, Strategy::StreamK, 13);
        let a = execute_plan_host(&plan, &problem, &t, None);
        for seed in [1u64, 2, 3] {
            let b = execute_plan_host(&plan, &problem, &t, Some(seed));
            assert!(max_abs_err(&a, &b) < 1e-5);
        }
    }

    #[test]
    fn gqa_grouped_exec_matches_the_repeated_kv_oracle() {
        // 8 query heads over {1, 2, 8} kv heads, every strategy.
        for kv_heads in [1usize, 2, 8] {
            let problem = DecodeProblem::uniform(2, 8, 700, 64)
                .with_tile(64)
                .with_kv_heads(kv_heads);
            let t = HostTensors::random(&problem, 42);
            let want = direct(&problem, &t);
            for strategy in [
                Strategy::Dense,
                Strategy::FixedSplit { splits: 4 },
                Strategy::StreamK,
            ] {
                let plan = build_plan(&problem, strategy, 10);
                plan.validate(&problem).unwrap();
                let got = execute_plan_host(&plan, &problem, &t, None);
                let err = max_abs_err(&got, &want);
                assert!(err < 1e-4, "kv_heads {kv_heads} {}: err {err}", strategy.name());
            }
        }
    }

    #[test]
    fn explicit_ungrouped_kv_plane_is_bit_identical_to_the_default() {
        // The headline GQA invariant: `with_kv_heads(heads)` is not
        // "approximately the old path" — the problem, the plan, the RNG
        // draw sequence and the executed op order are all identical, so
        // outputs must match bit for bit (Vec equality, no tolerance).
        prop_check("with_kv_heads(heads) == default, bitwise", 25, |rng| {
            let batch = rng.urange(1, 4);
            let heads = rng.urange(1, 5);
            let ctx_lens: Vec<u32> =
                (0..batch).map(|_| rng.range(1, 600) as u32).collect();
            let base = DecodeProblem::ragged(heads, ctx_lens, 32)
                .with_tile(*rng.choose(&[16usize, 32, 64]));
            let pinned = base.clone().with_kv_heads(heads);
            if base != pinned {
                return Err("pinning kv_heads == heads moved the problem".into());
            }
            let seed = rng.next_u64();
            let ta = HostTensors::random(&base, seed);
            let tb = HostTensors::random(&pinned, seed);
            if ta.q != tb.q || ta.k != tb.k || ta.v != tb.v {
                return Err("random draw sequence moved under grouping".into());
            }
            let strategy = *rng.choose(&[
                Strategy::Dense,
                Strategy::FixedSplit { splits: 4 },
                Strategy::StreamK,
            ]);
            let slots = rng.urange(1, 64);
            let shuffle = rng.next_u64();
            let a = execute_plan_host(
                &build_plan(&base, strategy, slots),
                &base,
                &ta,
                Some(shuffle),
            );
            let b = execute_plan_host(
                &build_plan(&pinned, strategy, slots),
                &pinned,
                &tb,
                Some(shuffle),
            );
            if a != b {
                return Err(format!("{}: bit-identity broken", strategy.name()));
            }
            Ok(())
        });
    }

    #[test]
    fn property_random_problems_random_strategies() {
        prop_check("host exec == direct attention", 40, |rng| {
            let batch = rng.urange(1, 4);
            let kv_heads = rng.urange(1, 5);
            let group_size = *rng.choose(&[1usize, 1, 2, 4]);
            let heads = kv_heads * group_size;
            let ctx_lens: Vec<u32> =
                (0..batch).map(|_| rng.range(1, 600) as u32).collect();
            let mut p = DecodeProblem::ragged(heads, ctx_lens, 32).with_kv_heads(kv_heads);
            p = p.with_tile(*rng.choose(&[16usize, 32, 64]));
            let t = HostTensors::random(&p, rng.next_u64());
            let want = direct(&p, &t);
            let strategy = *rng.choose(&[
                Strategy::Dense,
                Strategy::FixedSplit { splits: 3 },
                Strategy::FixedSplit { splits: 8 },
                Strategy::StreamK,
            ]);
            let slots = rng.urange(1, 64);
            let plan = build_plan(&p, strategy, slots);
            plan.validate(&p).map_err(|e| e.to_string())?;
            let got = execute_plan_host(&plan, &p, &t, Some(rng.next_u64()));
            let err = max_abs_err(&got, &want);
            if err > 5e-4 {
                return Err(format!("{} err {err}", strategy.name()));
            }
            Ok(())
        });
    }
}
