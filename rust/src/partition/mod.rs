//! Attention partitioning strategies (§IV of the paper).
//!
//! A decode-attention problem is a set of *output tiles* — one per
//! `(batch, head)` group, since the decode query is a single token — each
//! needing `ceil(ctx / LeanTile)` tile iterations along the context. A
//! [`Plan`] assigns every tile iteration to exactly one CTA:
//!
//! * [`dense`]       — FlashAttention-2: one CTA per output tile, no
//!   context split (the paper's "vanilla" baseline).
//! * [`fixed_split`] — FlashDecoding / FlashInfer: every output tile is
//!   cut into `s` equal chunks (plus the split-factor heuristic both
//!   libraries use).
//! * [`stream_k`]    — LeanAttention: all tile iterations of all output
//!   tiles are linearized and divided *equally* across a fixed grid,
//!   crossing head boundaries as needed; host CTAs reduce the partials
//!   with the softmax re-scaling operator.
//!
//! [`host_exec`] runs any plan on real numbers with the Rust oracle — the
//! numerical witness that every legal plan computes exact attention.

pub mod cascade;
pub mod host_exec;
pub mod lean_tile;
pub mod multi_query;
pub mod plan;
pub mod stream_k;
pub mod tensor_parallel;
pub mod workspec;

pub use cascade::{build_cascade_plan, CascadePlan, CascadeProblem, PrefixGroup};
pub use lean_tile::lean_tile_for;
pub use multi_query::{MultiQueryInputs, MultiQueryProblem, MultiQuerySeq};
pub use plan::{CtaWork, DecodeProblem, Plan, Segment, Strategy};

/// Re-exported planner entry points.
pub mod planners {
    pub use super::plan::build_plan;
    pub use super::stream_k::stream_k_plan;
}
