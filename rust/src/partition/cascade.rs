//! Cascade (shared-prefix) attention partitioning.
//!
//! When many sequences in a decode batch share a common context prefix —
//! one system prompt serving every user, parallel sampling, few-shot
//! templates — plain stream-K streams that prefix's K/V from HBM once
//! **per sequence**. But the §IV-A rescale operator is associative, so
//! each output row can be computed as
//!
//! ```text
//! O(seq, head) = rescale( partial(prefix KV, q_seq), partial(suffix KV, q_seq) )
//! ```
//!
//! and the prefix partials of *all* member sequences can be produced by a
//! single walk over the shared KV stream: one KV load, many query rows —
//! the decode GEMV becomes a skinny GEMM, the same bandwidth argument as
//! multi-query attention. This module turns a batch + prefix-group
//! description into a **segment problem** whose groups are the shared
//! prefix streams (counted once per group) plus the per-sequence
//! suffixes; the existing stream-K planner then schedules those segments
//! as first-class LeanTiles, and [`execute_cascade_host`] is the
//! numerical witness that the composition is exact.

use crate::attention::{partial_attention_host, Partials};
use crate::util::rng::Rng;

use super::lean_tile::lean_tile_for;
use super::plan::{DecodeProblem, Plan, Strategy};
use super::stream_k::stream_k_plan;

/// A set of sequences sharing one context prefix.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PrefixGroup {
    /// Shared tokens at the head of every member's context.
    pub prefix_len: u32,
    /// Batch indices of the member sequences.
    pub members: Vec<u32>,
}

/// A decode batch annotated with shared-prefix structure.
#[derive(Clone, Debug)]
pub struct CascadeProblem {
    /// Query heads.
    pub heads: usize,
    /// KV heads (GQA); divides `heads`, == `heads` when ungrouped.
    pub kv_heads: usize,
    pub head_dim: usize,
    /// Total context per sequence (prefix + suffix for group members).
    pub ctx_lens: Vec<u32>,
    /// LeanTile size in tokens.
    pub tile: usize,
    /// Disjoint prefix groups; sequences in no group are solo.
    pub prefix_groups: Vec<PrefixGroup>,
}

/// What a segment-problem group stands for. `head` is a **kv-head**
/// index: under GQA one segment serves the `heads / kv_heads` query
/// heads of that kv head from a single KV walk.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SegKind {
    /// The shared prefix stream of `prefix_groups[pg]` for one kv head:
    /// every LeanTile serves all member queries at once.
    Shared { pg: usize, head: usize },
    /// One sequence's private suffix for one kv head.
    Suffix { seq: usize, head: usize },
}

impl CascadeProblem {
    /// Build and validate. Groups must be disjoint, members in range,
    /// and every member's context at least as long as its group's prefix.
    pub fn new(
        heads: usize,
        ctx_lens: Vec<u32>,
        head_dim: usize,
        prefix_groups: Vec<PrefixGroup>,
    ) -> anyhow::Result<CascadeProblem> {
        use anyhow::ensure;
        let batch = ctx_lens.len();
        let mut owner = vec![false; batch];
        for (gi, g) in prefix_groups.iter().enumerate() {
            ensure!(!g.members.is_empty(), "prefix group {gi} has no members");
            ensure!(g.prefix_len >= 1, "prefix group {gi} has empty prefix");
            for &m in &g.members {
                let m = m as usize;
                ensure!(m < batch, "prefix group {gi}: member {m} out of range");
                ensure!(!owner[m], "sequence {m} in more than one prefix group");
                owner[m] = true;
                ensure!(
                    g.prefix_len <= ctx_lens[m],
                    "prefix group {gi}: prefix {} exceeds member {m} context {}",
                    g.prefix_len,
                    ctx_lens[m]
                );
            }
        }
        // Dissolve degenerate (single-member) groups into plain suffix
        // lanes after validation: a one-sequence "shared" stream saves
        // nothing, and dissolving it here makes the degenerate-group
        // invariant structural — a problem whose groups are all
        // single-member has the *same* segment problem as the flat
        // problem, so plans, rolled tasks and executor outputs are
        // bit-identical to the flat lean path (property-tested in
        // rust/tests/sampling_props.rs). Exactness is untouched: the
        // prefix tokens simply stay in the member's own suffix.
        let prefix_groups: Vec<PrefixGroup> = prefix_groups
            .into_iter()
            .filter(|g| g.members.len() >= 2)
            .collect();
        Ok(CascadeProblem {
            heads,
            kv_heads: heads,
            head_dim,
            ctx_lens,
            tile: lean_tile_for(head_dim),
            prefix_groups,
        })
    }

    pub fn with_tile(mut self, tile: usize) -> Self {
        assert!(tile > 0);
        self.tile = tile;
        self
    }

    /// Switch to a grouped-query layout with `kv_heads` KV heads.
    pub fn with_kv_heads(mut self, kv_heads: usize) -> Self {
        assert!(kv_heads >= 1, "kv_heads must be >= 1");
        assert!(
            self.heads % kv_heads == 0,
            "heads {} not divisible by kv_heads {kv_heads}",
            self.heads
        );
        self.kv_heads = kv_heads;
        self
    }

    /// Query heads per KV head.
    pub fn group_size(&self) -> usize {
        self.heads / self.kv_heads
    }

    pub fn batch(&self) -> usize {
        self.ctx_lens.len()
    }

    /// Output rows: one per `(sequence, head)`.
    pub fn outputs(&self) -> usize {
        self.batch() * self.heads
    }

    /// The shared-prefix length covering sequence `seq` (0 if solo).
    pub fn prefix_of(&self, seq: usize) -> u32 {
        self.prefix_groups
            .iter()
            .find(|g| g.members.contains(&(seq as u32)))
            .map_or(0, |g| g.prefix_len)
    }

    /// Floor every group's shared boundary to a LeanTile multiple and
    /// drop groups left with no shared tiles or fewer than two members.
    /// Splitting at a tile boundary guarantees the cascade plan never
    /// streams *more* tiles than the flat plan (unaligned cuts can add a
    /// boundary tile per sequence); the trimmed prefix tokens simply move
    /// into the member suffixes, which stays exact by associativity.
    pub fn tile_aligned(&self) -> CascadeProblem {
        let tile = self.tile as u32;
        let groups = self
            .prefix_groups
            .iter()
            .filter_map(|g| {
                let aligned = (g.prefix_len / tile) * tile;
                (aligned >= tile && g.members.len() >= 2).then(|| PrefixGroup {
                    prefix_len: aligned,
                    members: g.members.clone(),
                })
            })
            .collect();
        CascadeProblem { prefix_groups: groups, ..self.clone() }
    }

    /// The flat (no sharing) problem this batch poses — the baseline.
    pub fn baseline_problem(&self) -> DecodeProblem {
        DecodeProblem::ragged(self.heads, self.ctx_lens.clone(), self.head_dim)
            .with_tile(self.tile)
            .with_kv_heads(self.kv_heads)
    }

    /// The segment problem the planner partitions: synthetic batch lanes
    /// `[0, n_groups)` carry the shared prefix streams (context =
    /// `prefix_len`, counted **once** per group), lanes `[n_groups,
    /// n_groups + batch)` carry the per-sequence suffixes (context =
    /// `ctx - prefix`, possibly 0). Group `g = lane * kv_heads + head`
    /// follows the usual batch-major linearization over **kv heads**, so
    /// [`stream_k_plan`] equalizes LeanTiles across shared and suffix
    /// segments alike; under GQA each segment's walk serves all
    /// `heads / kv_heads` query heads of its group.
    pub fn segment_problem(&self) -> DecodeProblem {
        let mut lens: Vec<u32> =
            self.prefix_groups.iter().map(|g| g.prefix_len).collect();
        for (seq, &ctx) in self.ctx_lens.iter().enumerate() {
            lens.push(ctx - self.prefix_of(seq));
        }
        DecodeProblem::ragged(self.heads, lens, self.head_dim)
            .with_tile(self.tile)
            .with_kv_heads(self.kv_heads)
    }

    /// Meaning of segment-problem group `g` (`head` is a kv head).
    pub fn seg_kind(&self, g: usize) -> SegKind {
        let lane = g / self.kv_heads;
        let head = g % self.kv_heads;
        let n_pg = self.prefix_groups.len();
        if lane < n_pg {
            SegKind::Shared { pg: lane, head }
        } else {
            SegKind::Suffix { seq: lane - n_pg, head }
        }
    }

    /// Query rows served by one LeanTile of segment-problem group `g`
    /// (prefix-group members for shared streams, 1 otherwise — each
    /// scaled by the query-head group size under GQA).
    pub fn queries_of(&self, g: usize) -> usize {
        let rows = match self.seg_kind(g) {
            SegKind::Shared { pg, .. } => self.prefix_groups[pg].members.len(),
            SegKind::Suffix { .. } => 1,
        };
        rows * self.group_size()
    }
}

/// A stream-K plan over a cascade segment problem.
#[derive(Clone, Debug)]
pub struct CascadePlan {
    /// CTA → LeanTile assignment over [`CascadePlan::segment_problem`].
    pub plan: Plan,
    /// The synthetic problem the plan partitions.
    pub segment_problem: DecodeProblem,
}

/// Partition a cascade problem for a device with `sm_slots` co-resident
/// CTA slots: shared prefix streams and suffixes are linearized into one
/// LeanTile space and split equally, exactly like plain stream-K.
pub fn build_cascade_plan(problem: &CascadeProblem, sm_slots: usize) -> CascadePlan {
    let segment_problem = problem.segment_problem();
    let mut plan = stream_k_plan(&segment_problem, sm_slots);
    plan.strategy = Strategy::Cascade;
    CascadePlan { plan, segment_problem }
}

/// Host tensors for a cascade problem: per-group shared prefix K/V plus
/// per-sequence suffix K/V (each `[kv_heads, len, d]` row-major), and
/// one query row per output (query heads).
pub struct CascadeTensors {
    /// `[batch * heads, d]` query rows.
    pub q: Vec<f32>,
    /// Per prefix group: `[kv_heads, prefix_len, d]`.
    pub k_shared: Vec<Vec<f32>>,
    pub v_shared: Vec<Vec<f32>>,
    /// Per sequence: `[kv_heads, suffix_len, d]` with `suffix_len = ctx - prefix`.
    pub k_suffix: Vec<Vec<f32>>,
    pub v_suffix: Vec<Vec<f32>>,
}

impl CascadeTensors {
    /// Random tensors for `problem` (deterministic in `seed`; with
    /// `kv_heads == heads` the draw sequence matches the ungrouped one).
    pub fn random(problem: &CascadeProblem, seed: u64) -> CascadeTensors {
        let mut rng = Rng::new(seed);
        let (h, hk, d) = (problem.heads, problem.kv_heads, problem.head_dim);
        let q = rng.normal_vec(problem.batch() * h * d);
        let mut k_shared = Vec::new();
        let mut v_shared = Vec::new();
        for g in &problem.prefix_groups {
            let n = hk * g.prefix_len as usize * d;
            k_shared.push(rng.normal_vec(n));
            v_shared.push(rng.normal_vec(n));
        }
        let mut k_suffix = Vec::new();
        let mut v_suffix = Vec::new();
        for (seq, &ctx) in problem.ctx_lens.iter().enumerate() {
            let sl = (ctx - problem.prefix_of(seq)) as usize;
            k_suffix.push(rng.normal_vec(hk * sl * d));
            v_suffix.push(rng.normal_vec(hk * sl * d));
        }
        CascadeTensors { q, k_shared, v_shared, k_suffix, v_suffix }
    }

    /// Materialize each sequence's full per-**query-head** K/V — prefix
    /// rows taken from the group's shared tensors, each kv head repeated
    /// `heads / kv_heads` times — padded to `[batch*heads, n_max, d]`.
    /// This is what a sharing- and grouping-oblivious engine would store
    /// per sequence; grouped paths must match exact attention over it
    /// (the repeated-KV dense oracle for GQA).
    pub fn full_kv(&self, problem: &CascadeProblem) -> (Vec<f32>, Vec<f32>, usize) {
        let (h, d) = (problem.heads, problem.head_dim);
        let gs = problem.group_size();
        let n_max = problem.ctx_lens.iter().copied().max().unwrap_or(0) as usize;
        let g_out = problem.outputs();
        let mut k = vec![0.0f32; g_out * n_max * d];
        let mut v = vec![0.0f32; g_out * n_max * d];
        for (seq, &ctx) in problem.ctx_lens.iter().enumerate() {
            let ctx = ctx as usize;
            let pg = problem
                .prefix_groups
                .iter()
                .position(|g| g.members.contains(&(seq as u32)));
            let prefix = pg.map_or(0, |p| {
                problem.prefix_groups[p].prefix_len as usize
            });
            for hi in 0..h {
                let kvh = hi / gs; // kv head serving query head `hi`
                let out_base = (seq * h + hi) * n_max * d;
                if let Some(p) = pg {
                    let src = kvh * prefix * d;
                    k[out_base..out_base + prefix * d]
                        .copy_from_slice(&self.k_shared[p][src..src + prefix * d]);
                    v[out_base..out_base + prefix * d]
                        .copy_from_slice(&self.v_shared[p][src..src + prefix * d]);
                }
                let sl = ctx - prefix;
                let src = kvh * sl * d;
                let dst = out_base + prefix * d;
                k[dst..dst + sl * d]
                    .copy_from_slice(&self.k_suffix[seq][src..src + sl * d]);
                v[dst..dst + sl * d]
                    .copy_from_slice(&self.v_suffix[seq][src..src + sl * d]);
            }
        }
        (k, v, n_max)
    }
}

/// Execute a cascade plan on host numbers: every CTA computes its
/// segments' partials (a shared segment computes one partial **per member
/// query** from a single walk of the shared KV slice; under GQA every
/// query head of the segment's kv-head group rides that same walk), then
/// each output row folds its shared + suffix partials with the rescale
/// operator in an arbitrary (optionally shuffled) order and normalizes.
/// Must equal plain exact attention over the composed (and, for GQA,
/// repeated) per-query-head K/V for every legal plan — the cascade
/// extension of the associativity witness.
pub fn execute_cascade_host(
    cplan: &CascadePlan,
    problem: &CascadeProblem,
    t: &CascadeTensors,
    shuffle_seed: Option<u64>,
) -> Vec<f32> {
    let (h, hk, d) = (problem.heads, problem.kv_heads, problem.head_dim);
    let gs = problem.group_size();
    let tile = cplan.plan.tile;
    let n_pg = problem.prefix_groups.len();

    // Phase 1: per-CTA partials, routed to the output rows they serve.
    let mut per_output: Vec<Vec<Partials>> = vec![Vec::new(); problem.outputs()];
    for cta in &cplan.plan.ctas {
        for seg in &cta.segments {
            let g = seg.group as usize;
            let lane = g / hk;
            let kvh = g % hk;
            let ctx = cplan.segment_problem.ctx_for_group(g);
            let start = seg.tile_begin as usize * tile;
            let end = ((seg.tile_begin + seg.tile_count) as usize * tile).min(ctx);
            let width = end - start;
            if width == 0 {
                continue;
            }
            if lane < n_pg {
                // Shared prefix stream: one KV slice, all member queries
                // of every query head in the kv-head group.
                let group = &problem.prefix_groups[lane];
                let prefix = group.prefix_len as usize;
                let base = (kvh * prefix + start) * d;
                let k_slice = &t.k_shared[lane][base..base + width * d];
                let v_slice = &t.v_shared[lane][base..base + width * d];
                for &m in &group.members {
                    for j in 0..gs {
                        let out = m as usize * h + kvh * gs + j;
                        let q_row = &t.q[out * d..(out + 1) * d];
                        per_output[out].push(partial_attention_host(
                            q_row,
                            k_slice,
                            v_slice,
                            1,
                            width,
                            d,
                            &[group.prefix_len],
                            start,
                        ));
                    }
                }
            } else {
                // Private suffix segment.
                let seq = lane - n_pg;
                let sl = ctx; // suffix length for this lane
                let base = (kvh * sl + start) * d;
                let k_slice = &t.k_suffix[seq][base..base + width * d];
                let v_slice = &t.v_suffix[seq][base..base + width * d];
                for j in 0..gs {
                    let out = seq * h + kvh * gs + j;
                    let q_row = &t.q[out * d..(out + 1) * d];
                    per_output[out].push(partial_attention_host(
                        q_row,
                        k_slice,
                        v_slice,
                        1,
                        width,
                        d,
                        &[sl as u32],
                        start,
                    ));
                }
            }
        }
    }

    // Phase 2: fold each output's partials (order-insensitive).
    let mut rng = shuffle_seed.map(Rng::new);
    let mut out = vec![0.0f32; problem.outputs() * d];
    for (oi, mut parts) in per_output.into_iter().enumerate() {
        if parts.is_empty() {
            continue; // empty context
        }
        if let Some(r) = rng.as_mut() {
            for i in (1..parts.len()).rev() {
                let j = r.urange(0, i + 1);
                parts.swap(i, j);
            }
        }
        let mut acc = Partials::identity(1, d);
        for p in &parts {
            acc.reduce_from(p);
        }
        out[oi * d..(oi + 1) * d].copy_from_slice(&acc.finalize());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::attention_host;
    use crate::util::testing::max_abs_err;

    fn two_group_problem() -> CascadeProblem {
        // 4 seqs: 0,1 share a 96-token prefix; 2 solo; 3 in its own pair
        // with seq 1? No — groups disjoint. 3 solo too.
        CascadeProblem::new(
            2,
            vec![160, 130, 70, 96],
            8,
            vec![PrefixGroup { prefix_len: 96, members: vec![0, 1] }],
        )
        .unwrap()
        .with_tile(32)
    }

    #[test]
    fn validation_rejects_bad_groups() {
        // member out of range
        assert!(CascadeProblem::new(
            1,
            vec![10],
            8,
            vec![PrefixGroup { prefix_len: 4, members: vec![1] }],
        )
        .is_err());
        // overlapping groups
        assert!(CascadeProblem::new(
            1,
            vec![10, 10],
            8,
            vec![
                PrefixGroup { prefix_len: 4, members: vec![0] },
                PrefixGroup { prefix_len: 2, members: vec![0, 1] },
            ],
        )
        .is_err());
        // prefix longer than a member's context
        assert!(CascadeProblem::new(
            1,
            vec![10, 3],
            8,
            vec![PrefixGroup { prefix_len: 4, members: vec![0, 1] }],
        )
        .is_err());
    }

    #[test]
    fn segment_problem_counts_shared_tiles_once() {
        let p = two_group_problem();
        let seg = p.segment_problem();
        // lanes: [prefix 96] + suffixes [64, 34, 70, 96]
        assert_eq!(seg.ctx_lens, vec![96, 64, 34, 70, 96]);
        assert_eq!(seg.tile, 32);
        // shared tiles counted once: 3 + (2 + 2 + 3 + 3) = 13 tiles/head
        assert_eq!(seg.total_tiles(), 2 * 13);
        // baseline streams the prefix per member: (5+5+3+3)=16 tiles/head
        assert_eq!(p.baseline_problem().total_tiles(), 2 * 16);
    }

    #[test]
    fn seg_kind_and_queries_mapping() {
        let p = two_group_problem();
        assert_eq!(p.seg_kind(0), SegKind::Shared { pg: 0, head: 0 });
        assert_eq!(p.seg_kind(1), SegKind::Shared { pg: 0, head: 1 });
        assert_eq!(p.seg_kind(2), SegKind::Suffix { seq: 0, head: 0 });
        assert_eq!(p.seg_kind(9), SegKind::Suffix { seq: 3, head: 1 });
        assert_eq!(p.queries_of(0), 2);
        assert_eq!(p.queries_of(3), 1);
        assert_eq!(p.prefix_of(0), 96);
        assert_eq!(p.prefix_of(2), 0);
    }

    #[test]
    fn singleton_groups_dissolve_at_construction() {
        // A single-member group is validated (bad members still error)
        // and then dissolved: the problem is structurally flat.
        let p = CascadeProblem::new(
            2,
            vec![100, 60],
            8,
            vec![PrefixGroup { prefix_len: 64, members: vec![0] }],
        )
        .unwrap()
        .with_tile(32);
        assert!(p.prefix_groups.is_empty());
        assert_eq!(p.prefix_of(0), 0);
        let flat = CascadeProblem::new(2, vec![100, 60], 8, vec![]).unwrap().with_tile(32);
        assert_eq!(p.segment_problem().ctx_lens, flat.segment_problem().ctx_lens);
        // Validation still sees the group before dissolution.
        assert!(CascadeProblem::new(
            1,
            vec![10],
            8,
            vec![PrefixGroup { prefix_len: 40, members: vec![0] }],
        )
        .is_err());
    }

    #[test]
    fn tile_alignment_floors_and_prunes() {
        let p = CascadeProblem::new(
            1,
            vec![100, 100, 50, 50],
            8,
            vec![
                PrefixGroup { prefix_len: 70, members: vec![0, 1] },
                PrefixGroup { prefix_len: 20, members: vec![2, 3] },
            ],
        )
        .unwrap()
        .with_tile(32);
        let a = p.tile_aligned();
        // 70 -> 64; 20 -> 0 (pruned)
        assert_eq!(a.prefix_groups.len(), 1);
        assert_eq!(a.prefix_groups[0].prefix_len, 64);
    }

    #[test]
    fn cascade_plan_validates_and_balances() {
        let p = two_group_problem();
        let cp = build_cascade_plan(&p, 6);
        assert_eq!(cp.plan.strategy, Strategy::Cascade);
        cp.plan.validate(&cp.segment_problem).unwrap();
        let tiles = cp.plan.tiles_per_cta();
        let max = *tiles.iter().max().unwrap();
        let min = *tiles.iter().min().unwrap();
        assert!(max - min <= 1, "stream-K balance holds: {min}..{max}");
    }

    #[test]
    fn cascade_matches_reference_exactly() {
        let p = two_group_problem();
        let t = CascadeTensors::random(&p, 11);
        let (k, v, n_max) = t.full_kv(&p);
        let want = attention_host(
            &t.q,
            &k,
            &v,
            p.outputs(),
            n_max,
            p.head_dim,
            &(0..p.outputs())
                .map(|g| p.ctx_lens[g / p.heads])
                .collect::<Vec<_>>(),
        );
        for slots in [1usize, 3, 7, 64] {
            let cp = build_cascade_plan(&p, slots);
            cp.plan.validate(&cp.segment_problem).unwrap();
            let got = execute_cascade_host(&cp, &p, &t, None);
            let err = max_abs_err(&got, &want);
            assert!(err < 1e-4, "slots {slots}: err {err}");
        }
    }

    #[test]
    fn gqa_cascade_matches_the_repeated_kv_oracle() {
        // Grouped execution (4 query heads over 1 or 2 kv heads) must
        // equal dense attention over KV repeated to query-head count.
        for kv_heads in [1usize, 2, 4] {
            let p = CascadeProblem::new(
                4,
                vec![160, 130, 70, 96],
                8,
                vec![PrefixGroup { prefix_len: 96, members: vec![0, 1] }],
            )
            .unwrap()
            .with_tile(32)
            .with_kv_heads(kv_heads);
            let t = CascadeTensors::random(&p, 17);
            let (k, v, n_max) = t.full_kv(&p);
            let want = attention_host(
                &t.q,
                &k,
                &v,
                p.outputs(),
                n_max,
                p.head_dim,
                &(0..p.outputs())
                    .map(|g| p.ctx_lens[g / p.heads])
                    .collect::<Vec<_>>(),
            );
            for slots in [1usize, 5, 64] {
                let cp = build_cascade_plan(&p, slots);
                cp.plan.validate(&cp.segment_problem).unwrap();
                let got = execute_cascade_host(&cp, &p, &t, None);
                let err = max_abs_err(&got, &want);
                assert!(err < 1e-4, "kv_heads {kv_heads} slots {slots}: err {err}");
            }
        }
    }

    #[test]
    fn gqa_segment_problem_shrinks_with_kv_heads() {
        let p = two_group_problem(); // 2 heads
        let g = CascadeProblem { kv_heads: 1, ..p.clone() };
        let seg = g.segment_problem();
        assert_eq!(seg.groups(), p.segment_problem().groups() / 2);
        assert_eq!(seg.total_tiles(), p.segment_problem().total_tiles() / 2);
        // queries_of scales by group size: shared lane serves 2 members
        // x 2 query heads per kv head.
        assert_eq!(g.queries_of(0), 4);
        assert_eq!(g.queries_of(1), 2); // suffix lane, group size 2
    }

    #[test]
    fn merge_order_does_not_matter() {
        let p = two_group_problem();
        let t = CascadeTensors::random(&p, 3);
        let cp = build_cascade_plan(&p, 9);
        let a = execute_cascade_host(&cp, &p, &t, None);
        for seed in [1u64, 5, 9] {
            let b = execute_cascade_host(&cp, &p, &t, Some(seed));
            assert!(max_abs_err(&a, &b) < 1e-5);
        }
    }
}
