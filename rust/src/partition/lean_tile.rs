//! LeanTile granularity (§IV-B): the smallest KV-block size that still
//! reaches peak compute efficiency, fixed per head dimension and
//! architecture. Mirrors `python/compile/kernels/lean_attention.py`
//! (`LEAN_TILE_BY_HEAD_DIM`) — the two tables must stay in sync because
//! the Rust planner counts tiles that the Pallas kernel will execute.

/// Empirically optimal LeanTile token counts on A100-class hardware
/// (paper §IV-B: 256 tokens for d=64, 128 for d=128, FP16→FP32).
pub fn lean_tile_for(head_dim: usize) -> usize {
    match head_dim {
        32 => 256,
        64 => 256,
        96 => 128,
        128 => 128,
        256 => 64,
        d => {
            // Keep the K+V tile footprint roughly constant (≈ 2·T·d elems).
            ((256 * 64) / d.max(1)).max(16)
        }
    }
}

/// Number of LeanTile iterations to cover `ctx` tokens.
pub fn tiles_for_ctx(ctx: usize, tile: usize) -> u64 {
    assert!(tile > 0);
    (ctx as u64).div_ceil(tile as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_table_values() {
        assert_eq!(lean_tile_for(64), 256);
        assert_eq!(lean_tile_for(128), 128);
    }

    #[test]
    fn fallback_keeps_footprint() {
        let t = lean_tile_for(48);
        assert!(t >= 16);
        // footprint within 2x of the d=64 reference
        let fp = t * 48;
        assert!(fp <= 2 * 256 * 64 && fp * 2 >= 256 * 64);
    }

    #[test]
    fn tiles_for_ctx_rounds_up() {
        assert_eq!(tiles_for_ctx(1, 256), 1);
        assert_eq!(tiles_for_ctx(256, 256), 1);
        assert_eq!(tiles_for_ctx(257, 256), 2);
        assert_eq!(tiles_for_ctx(65536, 256), 256);
    }
}
