//! LeanAttention's stream-K decomposition (§IV-C/D, Algorithm 2).
//!
//! All LeanTile iterations of all output tiles are rolled out into one
//! linear iteration space following the `batch → kv heads → context`
//! linearization (ragged batches linearize the same way — the per-group
//! tile counts simply differ). Under GQA/MQA a "group" is a
//! `(batch, kv_head)` pair: the `heads / kv_heads` query heads sharing
//! that KV head ride the same LeanTile walk, so the plan shrinks by the
//! group size while outputs stay per-query-head. That space is divided into `grid` *equal*
//! contiguous ranges, one per CTA; a CTA's range may cross output-tile
//! boundaries, in which case it contributes partial results that the
//! tile's **host** CTA (owner of the tile's first iteration) reduces with
//! the softmax re-scaling operator.
//!
//! Grid sizing follows the paper: the grid is fixed at the device's
//! co-resident CTA capacity (`num_sms × MaxCTAsPerSM`, Eq. 2) — unless the
//! problem has fewer tiles than that, in which case one CTA per tile
//! degenerates to FlashAttention-2-style execution (the "special case"
//! generalization the paper points out).

use super::plan::{CtaWork, DecodeProblem, Plan, Segment, Strategy};

/// Build the LeanAttention plan for a device with `sm_slots` co-resident
/// CTA slots.
pub fn stream_k_plan(problem: &DecodeProblem, sm_slots: usize) -> Plan {
    assert!(sm_slots > 0, "need at least one CTA slot");
    let cum = problem.cum_tiles();
    let total = *cum.last().unwrap();
    let groups = problem.groups();

    if total == 0 {
        return Plan {
            strategy: Strategy::StreamK,
            tile: problem.tile,
            ctas: Vec::new(),
            groups,
        };
    }

    let grid = (sm_slots as u64).min(total) as usize;
    let base = total / grid as u64;
    let rem = (total % grid as u64) as usize;

    let mut ctas = Vec::with_capacity(grid);
    let mut iter = 0u64; // global LeanTile iteration cursor
    let mut group = 0usize; // group containing `iter` (monotonic sweep)
    for cta in 0..grid {
        let take = base + u64::from(cta < rem);
        let end = iter + take;
        let mut work = CtaWork::default();
        while iter < end {
            // advance `group` to the one containing `iter`
            while cum[group + 1] <= iter {
                group += 1;
            }
            let g_begin = cum[group];
            let g_end = cum[group + 1];
            let seg_begin = iter - g_begin;
            let seg_end = (end.min(g_end)) - g_begin;
            work.segments.push(Segment {
                group: group as u32,
                tile_begin: seg_begin as u32,
                tile_count: (seg_end - seg_begin) as u32,
                is_host: seg_begin == 0,
                is_finishing: g_begin + seg_end == g_end,
            });
            iter = g_begin + seg_end;
        }
        ctas.push(work);
    }
    debug_assert_eq!(iter, total);

    Plan { strategy: Strategy::StreamK, tile: problem.tile, ctas, groups }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::prop_check;

    #[test]
    fn equalized_loads() {
        let p = DecodeProblem::uniform(4, 32, 65536, 64); // 128 * 256 tiles
        let plan = stream_k_plan(&p, 216); // A100: 108 SMs x 2 CTAs
        plan.validate(&p).unwrap();
        assert_eq!(plan.grid(), 216);
        let tiles = plan.tiles_per_cta();
        let max = *tiles.iter().max().unwrap();
        let min = *tiles.iter().min().unwrap();
        assert!(max - min <= 1, "stream-K loads differ by at most one tile");
    }

    #[test]
    fn degenerates_to_dense_when_tiles_scarce() {
        // groups == tiles (1 tile each) and plenty of slots: one CTA per
        // output tile == FlashAttention-2 (the paper's special case).
        let p = DecodeProblem::uniform(2, 8, 256, 64);
        let plan = stream_k_plan(&p, 216);
        plan.validate(&p).unwrap();
        assert_eq!(plan.grid(), 16);
        assert!(plan
            .ctas
            .iter()
            .all(|c| c.segments.len() == 1
                && c.segments[0].is_host
                && c.segments[0].is_finishing));
    }

    #[test]
    fn recovers_fixed_split_when_grid_divides_evenly() {
        // grid an even multiple of output tiles -> equal chunks per head,
        // which is exactly FlashDecoding's layout (the paper's second
        // special case).
        let p = DecodeProblem::uniform(1, 2, 8 * 256, 64); // 2 groups x 8 tiles
        let plan = stream_k_plan(&p, 4); // 4 CTAs over 16 tiles -> 4 each
        plan.validate(&p).unwrap();
        let tiles = plan.tiles_per_cta();
        assert_eq!(tiles, vec![4, 4, 4, 4]);
        // every CTA covers a single group (no boundary crossing)
        assert!(plan.ctas.iter().all(|c| c.segments.len() == 1));
    }

    #[test]
    fn crosses_head_boundaries() {
        // 2 groups x 3 tiles, 4 CTAs -> 6 tiles, loads 2,2,1,1: CTA 1 gets
        // the tail of group 0 and the head of group 1 -> two segments.
        let p = DecodeProblem::uniform(1, 2, 3 * 256, 64);
        let plan = stream_k_plan(&p, 4);
        plan.validate(&p).unwrap();
        assert!(plan.ctas.iter().any(|c| c.segments.len() == 2));
    }

    #[test]
    fn gqa_plan_matches_a_kv_head_sized_dense_plan() {
        // Planning is kv-head granular: 32 query heads over 8 KV heads
        // yields exactly the plan of an 8-head dense problem.
        let grouped = DecodeProblem::uniform(4, 32, 65536, 64).with_kv_heads(8);
        let dense_small = DecodeProblem::uniform(4, 8, 65536, 64);
        let a = stream_k_plan(&grouped, 216);
        let b = stream_k_plan(&dense_small, 216);
        a.validate(&grouped).unwrap();
        assert_eq!(a.groups, b.groups);
        assert_eq!(a.grid(), b.grid());
        for (x, y) in a.ctas.iter().zip(&b.ctas) {
            assert_eq!(x.segments, y.segments);
        }
    }

    #[test]
    fn ragged_batch_equalized() {
        let p = DecodeProblem::ragged(4, vec![1024, 65536, 256, 8192], 64);
        let plan = stream_k_plan(&p, 108);
        plan.validate(&p).unwrap();
        let tiles = plan.tiles_per_cta();
        let max = *tiles.iter().max().unwrap();
        let min = *tiles.iter().min().unwrap();
        assert!(max - min <= 1, "ragged loads equalized: {min}..{max}");
    }

    #[test]
    fn empty_context_group() {
        let p = DecodeProblem::ragged(2, vec![0, 512], 64);
        let plan = stream_k_plan(&p, 8);
        plan.validate(&p).unwrap();
        // groups 0,1 (ctx 0) get no tiles; groups 2,3 covered
        assert_eq!(plan.partials_per_group()[0], 0);
    }

    #[test]
    fn property_valid_and_balanced_for_random_problems() {
        prop_check("stream-K invariants", 300, |rng| {
            let batch = rng.urange(1, 9);
            let heads = *rng.choose(&[1usize, 2, 8, 32, 56, 128]);
            let head_dim = *rng.choose(&[64usize, 128]);
            let ctx_lens: Vec<u32> = (0..batch)
                .map(|_| rng.range(1, 300_000) as u32)
                .collect();
            let p = DecodeProblem::ragged(heads, ctx_lens, head_dim);
            let slots = rng.urange(1, 1024);
            let plan = stream_k_plan(&p, slots);
            plan.validate(&p).map_err(|e| e.to_string())?;
            let tiles = plan.tiles_per_cta();
            let max = *tiles.iter().max().unwrap_or(&0);
            let min = *tiles.iter().min().unwrap_or(&0);
            if max.saturating_sub(min) > 1 {
                return Err(format!("imbalance {min}..{max}"));
            }
            if plan.grid() > slots {
                return Err("grid exceeds slots".into());
            }
            Ok(())
        });
    }
}
