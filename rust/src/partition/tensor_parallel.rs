//! Tensor-parallel head sharding (§III-D / §V "Multi-GPU Tensor
//! Parallelism"): the sharding unit is the **KV head** — the thing that
//! owns KV bytes. Each GPU holds `kv_heads / n` KV heads of every layer
//! *together with their whole query-head groups* (under GQA a query head
//! is useless without its group's KV stream, and splitting a group would
//! replicate that stream across GPUs), and runs its own stream-K plan
//! over its shard. Ungrouped models (`kv_heads == heads`) shard exactly
//! as plain per-head partitioning did. Because attention is computed per
//! head, no cross-GPU reduction is needed inside the attention op — the
//! only collective is the later `Wo` all-reduce, outside this kernel —
//! which is exactly why LeanAttention "supports tensor parallelism" while
//! FlashDecoding's fixed grid does not adapt (the paper scales FD to the
//! total SM count instead; our simulator does the same for the baseline).

use anyhow::{ensure, Result};

use super::plan::{build_plan, DecodeProblem, Plan, Strategy};

/// One GPU's share of a tensor-parallel attention problem.
#[derive(Clone, Debug)]
pub struct Shard {
    pub gpu: usize,
    pub problem: DecodeProblem,
    pub plan: Plan,
}

/// Shard `problem`'s KV heads over `n_gpus` and plan each shard
/// independently with `strategy` on a device with `slots_per_gpu` CTA
/// slots. Each shard keeps whole query-head groups (`heads = kv_heads ×
/// group_size`), so no KV stream is ever replicated across GPUs. KV-head
/// counts that do not divide evenly are spread ±1 (the same remainder
/// rule stream-K uses for tiles).
pub fn shard_heads(
    problem: &DecodeProblem,
    n_gpus: usize,
    strategy: Strategy,
    slots_per_gpu: usize,
) -> Result<Vec<Shard>> {
    ensure!(n_gpus >= 1, "need at least one GPU");
    ensure!(
        problem.kv_heads >= n_gpus,
        "cannot shard {} kv heads over {n_gpus} GPUs",
        problem.kv_heads
    );
    let gs = problem.group_size();
    let base = problem.kv_heads / n_gpus;
    let rem = problem.kv_heads % n_gpus;
    let mut shards = Vec::with_capacity(n_gpus);
    for gpu in 0..n_gpus {
        let kv_heads = base + usize::from(gpu < rem);
        let sub = DecodeProblem {
            heads: kv_heads * gs,
            kv_heads,
            head_dim: problem.head_dim,
            ctx_lens: problem.ctx_lens.clone(),
            tile: problem.tile,
        };
        let plan = build_plan(&sub, strategy, slots_per_gpu);
        plan.validate(&sub)?;
        shards.push(Shard { gpu, problem: sub, plan });
    }
    Ok(shards)
}

/// Simulated multi-GPU latency: GPUs run concurrently, so the batch
/// completes when the slowest shard does.
pub fn simulate_sharded(
    shards: &[Shard],
    arch: &crate::sim::GpuArch,
) -> crate::sim::SimResult {
    use crate::sim::schedule::simulate_plan;
    let mut worst: Option<crate::sim::SimResult> = None;
    for s in shards {
        let r = simulate_plan(&s.plan, &s.problem, arch);
        if worst
            .as_ref()
            .map(|w| r.latency_us > w.latency_us)
            .unwrap_or(true)
        {
            worst = Some(r);
        }
    }
    worst.expect("at least one shard")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::GpuArch;
    use crate::util::testing::prop_check;

    #[test]
    fn even_sharding() {
        let p = DecodeProblem::uniform(4, 256, 65536, 64);
        let shards = shard_heads(&p, 8, Strategy::StreamK, 216).unwrap();
        assert_eq!(shards.len(), 8);
        assert!(shards.iter().all(|s| s.problem.heads == 32));
        let total: usize = shards.iter().map(|s| s.problem.heads).sum();
        assert_eq!(total, 256);
    }

    #[test]
    fn uneven_sharding_spreads_remainder() {
        let p = DecodeProblem::uniform(1, 30, 65536, 64);
        let shards = shard_heads(&p, 8, Strategy::StreamK, 216).unwrap();
        let heads: Vec<usize> = shards.iter().map(|s| s.problem.heads).collect();
        assert_eq!(heads.iter().sum::<usize>(), 30);
        let max = heads.iter().max().unwrap();
        let min = heads.iter().min().unwrap();
        assert!(max - min <= 1, "{heads:?}");
    }

    #[test]
    fn too_few_heads_rejected() {
        let p = DecodeProblem::uniform(1, 4, 65536, 64);
        assert!(shard_heads(&p, 8, Strategy::StreamK, 216).is_err());
    }

    #[test]
    fn gqa_sharding_keeps_whole_query_head_groups() {
        // 64 query heads over 8 kv heads, 4 GPUs: each GPU owns 2 kv
        // heads and all 16 query heads of their groups.
        let p = DecodeProblem::uniform(4, 64, 65536, 64).with_kv_heads(8);
        let shards = shard_heads(&p, 4, Strategy::StreamK, 216).unwrap();
        assert_eq!(shards.len(), 4);
        for s in &shards {
            assert_eq!(s.problem.kv_heads, 2);
            assert_eq!(s.problem.heads, 16);
            assert_eq!(s.problem.group_size(), 8);
        }
        assert_eq!(shards.iter().map(|s| s.problem.kv_heads).sum::<usize>(), 8);
        assert_eq!(shards.iter().map(|s| s.problem.heads).sum::<usize>(), 64);
        // MQA cannot tensor-parallel-shard: one kv head owns all KV bytes.
        let mqa = DecodeProblem::uniform(1, 32, 65536, 64).with_kv_heads(1);
        assert!(shard_heads(&mqa, 2, Strategy::StreamK, 216).is_err());
    }

    #[test]
    fn sharded_lean_matches_monolithic_multi_gpu_model() {
        // Sharding heads across 8 GPUs ~= one 8x device in the aggregate
        // simulator (both near-perfect occupancy).
        let p = DecodeProblem::uniform(4, 256, 262_144, 64);
        let single = GpuArch::a100();
        let shards = shard_heads(&p, 8, Strategy::StreamK, single.sm_slots()).unwrap();
        let sharded = simulate_sharded(&shards, &single);
        let mono = crate::sim::simulate(&p, Strategy::StreamK, &single.multi(8));
        let ratio = sharded.latency_us / mono.latency_us;
        assert!((0.8..1.3).contains(&ratio), "TP vs mono ratio {ratio}");
    }

    #[test]
    fn property_shards_cover_all_heads() {
        prop_check("TP sharding coverage", 100, |rng| {
            let kv_heads = rng.urange(8, 128);
            let gs = *rng.choose(&[1usize, 1, 2, 4, 8]);
            let heads = kv_heads * gs;
            let gpus = *rng.choose(&[2usize, 4, 8]);
            if kv_heads < gpus {
                return Ok(());
            }
            let p = DecodeProblem::uniform(rng.urange(1, 5), heads, 1 << rng.urange(10, 18), 64)
                .with_kv_heads(kv_heads);
            let shards =
                shard_heads(&p, gpus, Strategy::StreamK, 216).map_err(|e| e.to_string())?;
            let kv_total: usize = shards.iter().map(|s| s.problem.kv_heads).sum();
            let total: usize = shards.iter().map(|s| s.problem.heads).sum();
            if kv_total != kv_heads {
                return Err(format!("covered {kv_total} of {kv_heads} kv heads"));
            }
            if total != heads {
                return Err(format!("covered {total} of {heads} query heads"));
            }
            for s in &shards {
                if s.problem.group_size() != gs {
                    return Err(format!(
                        "shard {} group size {} != {gs}",
                        s.gpu,
                        s.problem.group_size()
                    ));
                }
            }
            Ok(())
        });
    }
}
