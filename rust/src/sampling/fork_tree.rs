//! Fork lineage tracking for parallel sampling.
//!
//! Every `Engine::fork(seq, n)` creates `n` sibling sequences that share
//! the parent's KV history up to the fork point. The [`ForkTree`] records
//! that lineage — parent, fork position in tokens, children — so
//! controllers can map candidates back to their family, metrics can
//! attribute sharing, and the decode loop can reason about which
//! sequences belong to one cascade group.
//!
//! Removal is lineage-compressing: when a sequence finishes (or is
//! pruned), its children are re-parented to its own parent, keeping
//! `root_of` and `group_of` meaningful for the survivors.

use std::collections::HashMap;

/// Where a sequence was forked from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ForkPoint {
    /// The sequence this one was forked off.
    pub parent: u64,
    /// KV-backed tokens shared with the parent at fork time.
    pub token_len: usize,
}

/// Parent/child lineage of forked sequences.
#[derive(Debug, Default)]
pub struct ForkTree {
    parents: HashMap<u64, ForkPoint>,
    children: HashMap<u64, Vec<u64>>,
}

impl ForkTree {
    pub fn new() -> ForkTree {
        ForkTree::default()
    }

    /// Sequences currently tracked (every id that ever appeared in a
    /// fork and was not removed).
    pub fn len(&self) -> usize {
        let mut ids: Vec<u64> = self.parents.keys().copied().collect();
        ids.extend(self.children.keys());
        ids.sort_unstable();
        ids.dedup();
        ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.parents.is_empty() && self.children.is_empty()
    }

    /// Record that `child` was forked off `parent` with `token_len`
    /// shared KV-backed tokens.
    pub fn register(&mut self, parent: u64, child: u64, token_len: usize) {
        assert_ne!(parent, child, "a sequence cannot fork into itself");
        assert!(
            !self.parents.contains_key(&child),
            "sequence {child} already has a fork parent"
        );
        self.parents.insert(child, ForkPoint { parent, token_len });
        self.children.entry(parent).or_default().push(child);
    }

    /// The fork point of `id`, if it was created by a fork.
    pub fn fork_point(&self, id: u64) -> Option<ForkPoint> {
        self.parents.get(&id).copied()
    }

    /// Direct children of `id`, in fork order.
    pub fn children_of(&self, id: u64) -> &[u64] {
        self.children.get(&id).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Walk parents to the family root (`id` itself if never forked).
    pub fn root_of(&self, id: u64) -> u64 {
        let mut cur = id;
        while let Some(fp) = self.parents.get(&cur) {
            cur = fp.parent;
        }
        cur
    }

    /// Every tracked sequence sharing `id`'s root, sorted (including
    /// `id` itself and the root).
    pub fn group_of(&self, id: u64) -> Vec<u64> {
        let root = self.root_of(id);
        let mut out = vec![root];
        let mut stack = vec![root];
        while let Some(cur) = stack.pop() {
            for &c in self.children_of(cur) {
                out.push(c);
                stack.push(c);
            }
        }
        out.sort_unstable();
        out
    }

    /// Other direct children of `id`'s parent, excluding `id`.
    pub fn siblings_of(&self, id: u64) -> Vec<u64> {
        let Some(fp) = self.parents.get(&id) else {
            return Vec::new();
        };
        self.children_of(fp.parent)
            .iter()
            .copied()
            .filter(|&c| c != id)
            .collect()
    }

    /// Drop `id` from the tree, re-parenting its children to its own
    /// parent (or promoting them to roots). Unknown ids are a no-op.
    pub fn remove(&mut self, id: u64) {
        let fp = self.parents.remove(&id);
        let kids = self.children.remove(&id).unwrap_or_default();
        if let Some(fp) = fp {
            if let Some(sibs) = self.children.get_mut(&fp.parent) {
                sibs.retain(|&c| c != id);
                // Re-parent the orphans; their own fork offsets stay.
                sibs.extend(kids.iter().copied());
                if sibs.is_empty() {
                    self.children.remove(&fp.parent);
                }
            }
            for &k in &kids {
                if let Some(p) = self.parents.get_mut(&k) {
                    p.parent = fp.parent;
                }
            }
        } else {
            // `id` was a root: its children become roots themselves.
            for &k in &kids {
                self.parents.remove(&k);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lineage_and_roots() {
        let mut t = ForkTree::new();
        assert!(t.is_empty());
        t.register(1, 2, 10);
        t.register(1, 3, 10);
        t.register(3, 4, 15);
        assert_eq!(t.fork_point(2), Some(ForkPoint { parent: 1, token_len: 10 }));
        assert_eq!(t.fork_point(1), None);
        assert_eq!(t.root_of(4), 1);
        assert_eq!(t.root_of(1), 1);
        assert_eq!(t.children_of(1), &[2, 3]);
        assert_eq!(t.siblings_of(2), vec![3]);
        assert_eq!(t.group_of(4), vec![1, 2, 3, 4]);
        assert_eq!(t.len(), 4);
    }

    #[test]
    fn remove_reparents_children() {
        let mut t = ForkTree::new();
        t.register(1, 2, 8);
        t.register(2, 5, 12);
        t.register(2, 6, 12);
        t.remove(2);
        // 5 and 6 now hang off 1; their fork offsets are preserved.
        assert_eq!(t.root_of(5), 1);
        assert_eq!(t.fork_point(5).unwrap().token_len, 12);
        assert_eq!(t.children_of(1), &[5, 6]);
        assert_eq!(t.group_of(6), vec![1, 5, 6]);
    }

    #[test]
    fn remove_root_promotes_children() {
        let mut t = ForkTree::new();
        t.register(1, 2, 4);
        t.register(1, 3, 4);
        t.remove(1);
        assert_eq!(t.root_of(2), 2);
        assert_eq!(t.root_of(3), 3);
        assert_eq!(t.fork_point(2), None);
        // Removing an unknown id is a no-op.
        t.remove(99);
    }

    #[test]
    fn group_of_unforked_sequence_is_itself() {
        let t = ForkTree::new();
        assert_eq!(t.group_of(7), vec![7]);
        assert_eq!(t.siblings_of(7), Vec::<u64>::new());
    }
}
