//! Parallel-sampling controllers: best-of-n and (sampled) beam search
//! over the serving engine's fork/cancel lifecycle.
//!
//! Both controllers drive the same loop shape — **submit** one root
//! request, **fork** it into siblings that share the whole KV history by
//! refcount (zero page copies), **score** candidates by cumulative
//! logprob, and (for beam search) **prune** losers with
//! `Engine::cancel`. Fork siblings resample the pending token with their
//! own deterministic RNG, so candidates diverge immediately while the
//! decode loop streams their shared history once per group through the
//! cascade gather.
//!
//! Everything is deterministic under a fixed engine seed: sequence ids
//! are allocated in submission/fork order, each id's RNG is derived from
//! `(seed, id)`, and every ranking below breaks ties by id.

use anyhow::{ensure, Result};

use crate::coordinator::request::{FinishReason, FinishedRequest, RequestId};
use crate::coordinator::Engine;

use super::logits::SamplingParams;

/// One finished candidate with its selection score.
#[derive(Clone, Debug)]
pub struct ScoredCandidate {
    pub finished: FinishedRequest,
    /// Cumulative logprob of the candidate's sampled tokens (higher is
    /// better; the model's own probability of the continuation).
    pub score: f64,
}

/// Outcome of a parallel-sampling run: candidates sorted best-first.
#[derive(Clone, Debug, Default)]
pub struct ParallelOutcome {
    /// This run's candidates, sorted by (completed before pruned, score
    /// desc, id asc).
    pub candidates: Vec<ScoredCandidate>,
    /// Finished requests the engine returned that belong to *other*
    /// traffic sharing the engine (never dropped silently).
    pub unrelated: Vec<FinishedRequest>,
}

impl ParallelOutcome {
    /// The winning candidate.
    pub fn best(&self) -> Option<&ScoredCandidate> {
        self.candidates.first()
    }
}

/// Rank candidates: completed generations before pruned (cancelled)
/// ones, then by cumulative logprob descending, then by id for a total
/// deterministic order.
fn rank(mut cands: Vec<ScoredCandidate>) -> Vec<ScoredCandidate> {
    cands.sort_by(|a, b| {
        let done_a = a.finished.reason != FinishReason::Cancelled;
        let done_b = b.finished.reason != FinishReason::Cancelled;
        done_b
            .cmp(&done_a)
            .then(b.score.partial_cmp(&a.score).unwrap_or(std::cmp::Ordering::Equal))
            .then(a.finished.id.cmp(&b.finished.id))
    });
    cands
}

fn collect(ids: &[RequestId], finished: Vec<FinishedRequest>) -> ParallelOutcome {
    let mut candidates = Vec::new();
    let mut unrelated = Vec::new();
    for f in finished {
        if ids.contains(&f.id) {
            let score = f.cum_logprob;
            candidates.push(ScoredCandidate { finished: f, score });
        } else {
            unrelated.push(f);
        }
    }
    ParallelOutcome { candidates: rank(candidates), unrelated }
}

/// Drive the engine until `root` is resident in a batch slot (or already
/// finished), accumulating any finished requests seen on the way.
fn drive_to_active(
    engine: &mut Engine,
    root: RequestId,
    finished: &mut Vec<FinishedRequest>,
) -> Result<()> {
    while !engine.is_active_seq(root) {
        ensure!(
            !engine.is_idle() || finished.iter().any(|f| f.id == root),
            "request {root} neither active nor finished"
        );
        if finished.iter().any(|f| f.id == root) {
            break;
        }
        finished.extend(engine.step()?);
    }
    Ok(())
}

/// Attempt a fork, degrading gracefully under resource pressure: the
/// count is pre-clamped by the caller to the free slots, so the only
/// remaining failure mode is KV-page reservation pressure — in that
/// case the controller proceeds with the siblings it already has
/// instead of aborting the run and stranding live sequences.
fn try_fork(engine: &mut Engine, seq: RequestId, n: usize) -> Vec<RequestId> {
    if n == 0 {
        return Vec::new();
    }
    engine.fork(seq, n).unwrap_or_default()
}

/// Best-of-n: sample `n` independent continuations of one prompt and
/// pick the highest-scoring one. The prompt is prefilled **once**; the
/// other `n - 1` candidates are zero-copy forks of the first.
///
/// Best-effort under contention: when other traffic holds batch slots
/// or KV pages, fewer than `n` candidates are produced (at minimum the
/// root) rather than failing the run.
#[derive(Clone, Debug)]
pub struct BestOfN {
    /// Candidates to sample (>= 1).
    pub n: usize,
    /// Generation budget per candidate.
    pub max_new: usize,
    /// Logits pipeline for every candidate (usually stochastic —
    /// greedy best-of-n degenerates to n identical outputs only in the
    /// first token; forks still resample it).
    pub params: SamplingParams,
}

impl BestOfN {
    pub fn run(&self, engine: &mut Engine, prompt: Vec<i32>) -> Result<ParallelOutcome> {
        ensure!(self.n >= 1, "best-of-n needs n >= 1");
        ensure!(
            self.n <= engine.batch_size(),
            "best-of-{} exceeds the engine's {} batch slots",
            self.n,
            engine.batch_size()
        );
        self.params.validate()?;

        let root = engine.submit_with(prompt, self.max_new, self.params.clone())?;
        let mut finished = Vec::new();
        drive_to_active(engine, root, &mut finished)?;

        let mut ids = vec![root];
        if self.n > 1 && engine.is_active_seq(root) {
            let k = (self.n - 1).min(engine.free_slots());
            ids.extend(try_fork(engine, root, k));
        }
        finished.extend(engine.run_until_idle()?);
        Ok(collect(&ids, finished))
    }
}

/// Sampled beam search: keep the `width` highest-scoring hypotheses,
/// expanding each live beam into stochastic variants by forking (the
/// fork resamples the pending token) and pruning the rest by cumulative
/// logprob after every decode step.
///
/// This is beam search over *sampled* expansions rather than the full
/// top-`width * vocab` frontier — the engine emits one token per
/// sequence per step, so the frontier is grown by zero-copy forks
/// instead of a vocab-wide enumeration. Scores, pruning and the final
/// ranking follow classic beam search.
#[derive(Clone, Debug)]
pub struct BeamSearch {
    /// Beams kept live after every step (>= 1).
    pub width: usize,
    /// Hypotheses each live beam expands into per step (1 = no
    /// expansion beyond the initial widening).
    pub expand: usize,
    /// Generation budget per beam.
    pub max_new: usize,
    /// Logits pipeline; must be stochastic (greedy forks cannot
    /// diverge the frontier).
    pub params: SamplingParams,
}

impl BeamSearch {
    pub fn run(&self, engine: &mut Engine, prompt: Vec<i32>) -> Result<ParallelOutcome> {
        ensure!(self.width >= 1, "beam width must be >= 1");
        ensure!(self.expand >= 1, "expansion factor must be >= 1");
        ensure!(
            !self.params.is_greedy(),
            "beam expansion needs a stochastic sampler (temperature > 0)"
        );
        ensure!(
            self.width <= engine.batch_size(),
            "beam width {} exceeds the engine's {} batch slots",
            self.width,
            engine.batch_size()
        );
        self.params.validate()?;

        let root = engine.submit_with(prompt, self.max_new, self.params.clone())?;
        let mut finished = Vec::new();
        drive_to_active(engine, root, &mut finished)?;

        let mut members = vec![root];
        // Widen the frontier to `width` beams (best-effort under KV or
        // slot pressure — the search continues with a narrower front).
        if self.width > 1 && engine.is_active_seq(root) {
            let n = (self.width - 1).min(engine.free_slots());
            members.extend(try_fork(engine, root, n));
        }

        loop {
            let live = self.live_ranked(engine, &members);
            if live.is_empty() {
                break;
            }
            // Expansion: best beams first, bounded by free slots; a
            // fork refused for KV pressure ends this round's expansion
            // rather than aborting the search with live beams stranded.
            if self.expand > 1 {
                for &id in &live {
                    let k = (self.expand - 1).min(engine.free_slots());
                    if k == 0 {
                        break;
                    }
                    let forked = try_fork(engine, id, k);
                    let exhausted = forked.is_empty();
                    members.extend(forked);
                    if exhausted {
                        break;
                    }
                }
            }
            finished.extend(engine.step()?);
            // Prune back down to `width` by cumulative logprob.
            let live = self.live_ranked(engine, &members);
            for &id in live.iter().skip(self.width) {
                finished.push(engine.cancel(id)?);
            }
        }
        Ok(collect(&members, finished))
    }

    /// Live members sorted by score descending (id tiebreak).
    fn live_ranked(&self, engine: &Engine, members: &[RequestId]) -> Vec<RequestId> {
        let mut live: Vec<RequestId> = members
            .iter()
            .copied()
            .filter(|&id| engine.is_active_seq(id))
            .collect();
        live.sort_by(|&a, &b| {
            let sa = engine.cum_logprob(a).unwrap_or(f64::NEG_INFINITY);
            let sb = engine.cum_logprob(b).unwrap_or(f64::NEG_INFINITY);
            sb.partial_cmp(&sa)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        live
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fin(id: RequestId, cum: f64, reason: FinishReason) -> FinishedRequest {
        FinishedRequest {
            id,
            prompt_len: 2,
            output: vec![1, 2],
            reason,
            queue_s: 0.0,
            prefill_s: 0.0,
            decode_s: 0.0,
            cum_logprob: cum,
            logprobs: vec![-0.5, -0.5],
            parent: None,
        }
    }

    #[test]
    fn ranking_prefers_completed_then_score_then_id() {
        let out = collect(
            &[1, 2, 3, 4],
            vec![
                fin(1, -2.0, FinishReason::Length),
                fin(2, -1.0, FinishReason::Cancelled),
                fin(3, -1.5, FinishReason::Length),
                fin(4, -1.5, FinishReason::Length),
                fin(9, -0.1, FinishReason::Length), // unrelated traffic
            ],
        );
        let order: Vec<RequestId> =
            out.candidates.iter().map(|c| c.finished.id).collect();
        // Completed (3, 4 tie on score -> id order, then 1), pruned 2 last.
        assert_eq!(order, vec![3, 4, 1, 2]);
        assert_eq!(out.best().unwrap().finished.id, 3);
        assert_eq!(out.unrelated.len(), 1);
        assert_eq!(out.unrelated[0].id, 9);
    }

    #[test]
    fn empty_outcome_has_no_best() {
        let out = ParallelOutcome::default();
        assert!(out.best().is_none());
    }

    // Engine-driving controller tests (need artifacts + PJRT) live in
    // rust/tests/engine_e2e.rs.
}
