//! Parallel-sampling subsystem: logits processing, fork lineage, and
//! best-of-n / beam-search controllers.
//!
//! LeanAttention's stream-K decode shines when many query rows walk the
//! same KV stream — and the highest-multiplicity sharing in real serving
//! is *generated*: best-of-n, beam search and speculative drafts fork a
//! sequence into siblings sharing their entire history up to the fork
//! point. This module supplies the three missing pieces over the
//! copy-on-write paged KV machinery:
//!
//! * [`logits`] — the deterministic logits-processing pipeline
//!   (repetition penalty → temperature → top-k → top-p → draw), which is
//!   both the engine's sampler and the exact replay oracle.
//! * [`fork_tree`] — parent/child lineage of forked sequences with their
//!   fork points.
//! * [`controller`] — [`BestOfN`] and [`BeamSearch`] controllers owning
//!   the submit → fork → score → prune lifecycle over
//!   [`crate::coordinator::Engine::fork`] /
//!   [`crate::coordinator::Engine::cancel`].
//!
//! The serving-side mechanics live in the coordinator: `fork` clones a
//! live sequence purely by page refcounts (COW defers any copying to the
//! first divergent write into a shared partial page), and the decode
//! loop's prefix grouping streams the family's shared history once per
//! group through the cascade gather.

pub mod controller;
pub mod fork_tree;
pub mod logits;

pub use controller::{BeamSearch, BestOfN, ParallelOutcome, ScoredCandidate};
pub use fork_tree::{ForkPoint, ForkTree};
pub use logits::{sample_token, seq_rng, SampledToken, SamplingParams};
