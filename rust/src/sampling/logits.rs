//! Logits-processing pipeline: the exact host oracle for token sampling.
//!
//! The engine's decode loop used to pick `argmax(logits)` implicitly;
//! this module makes the token choice a first-class, *reproducible*
//! pipeline: repetition penalty over the sequence history, temperature,
//! top-k and top-p (nucleus) filtering, then a draw from the renormalized
//! distribution using the deterministic [`crate::util::rng::Rng`]. The
//! same function is the serving sampler **and** the verification oracle —
//! given the same raw logits, history, parameters and RNG state it
//! returns the same `(token, logprob)` pair, so every candidate's logprob
//! trace in a best-of-n or beam run can be replayed exactly
//! (property-tested in `rust/tests/sampling_props.rs`).
//!
//! `temperature == 0` is greedy decoding and bypasses the RNG entirely,
//! so the engine's historical behavior (deterministic argmax) is the
//! default [`SamplingParams`].

use anyhow::{ensure, Result};

use crate::util::rng::{splitmix64, Rng};

/// Parameters of the logits-processing pipeline, applied in order:
/// repetition penalty → temperature → top-k → top-p → draw.
#[derive(Clone, Debug, PartialEq)]
pub struct SamplingParams {
    /// Softmax temperature. `0.0` means greedy (argmax, RNG untouched).
    pub temperature: f32,
    /// Keep only the `top_k` highest-logit tokens (`0` disables).
    pub top_k: usize,
    /// Keep the smallest set of tokens whose probability mass reaches
    /// `top_p` (`1.0` disables nucleus filtering).
    pub top_p: f32,
    /// Divide positive / multiply negative logits of tokens already in
    /// the history by this factor (`1.0` disables).
    pub repetition_penalty: f32,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams::greedy()
    }
}

impl SamplingParams {
    /// Greedy decoding: argmax, no filtering, RNG untouched.
    pub fn greedy() -> SamplingParams {
        SamplingParams {
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
            repetition_penalty: 1.0,
        }
    }

    /// Plain stochastic sampling at `temperature`, no filtering.
    pub fn stochastic(temperature: f32) -> SamplingParams {
        SamplingParams { temperature, ..SamplingParams::greedy() }
    }

    /// Whether this configuration is greedy (deterministic argmax).
    pub fn is_greedy(&self) -> bool {
        self.temperature == 0.0
    }

    /// Reject nonsensical configurations up front (at `submit`, not mid
    /// decode).
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.temperature.is_finite() && self.temperature >= 0.0,
            "temperature must be finite and >= 0, got {}",
            self.temperature
        );
        ensure!(
            self.top_p > 0.0 && self.top_p <= 1.0,
            "top_p must be in (0, 1], got {}",
            self.top_p
        );
        ensure!(
            self.repetition_penalty.is_finite() && self.repetition_penalty > 0.0,
            "repetition_penalty must be finite and > 0, got {}",
            self.repetition_penalty
        );
        Ok(())
    }
}

/// One sampled token with its log-probability under the processed
/// (penalized / filtered / renormalized) distribution.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SampledToken {
    pub token: i32,
    pub logprob: f32,
}

/// The per-sequence sampling RNG: deterministic in `(seed, id)` so a
/// sequence's draw stream survives engine restarts and fork siblings
/// (which get fresh ids) diverge from their parent deterministically.
pub fn seq_rng(seed: u64, id: u64) -> Rng {
    let mut s = seed ^ id.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    Rng::new(splitmix64(&mut s))
}

/// First index of the maximum element (ties keep the lowest index — the
/// engine's historical greedy tie-break).
fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best
}

/// Log-sum-exp over the finite entries of `l` (the normalizer of the
/// masked softmax).
fn log_sum_exp(l: &[f32]) -> f32 {
    let m = l.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    if m == f32::NEG_INFINITY {
        return f32::NEG_INFINITY;
    }
    let s: f32 = l
        .iter()
        .filter(|x| x.is_finite())
        .map(|&x| (x - m).exp())
        .sum();
    m + s.ln()
}

/// Run the full pipeline over one step's raw logits and draw a token.
///
/// `history` is every token already in the sequence (prompt + generated);
/// only the repetition penalty reads it. The returned logprob is the
/// chosen token's log-probability under the final processed distribution
/// (for greedy: the plain log-softmax at the argmax). The RNG advances by
/// exactly one draw for stochastic params and not at all for greedy —
/// which is what makes recorded traces replayable.
pub fn sample_token(
    logits: &[f32],
    history: &[i32],
    params: &SamplingParams,
    rng: &mut Rng,
) -> SampledToken {
    assert!(!logits.is_empty(), "empty logits");
    let mut l = logits.to_vec();

    // Repetition penalty (each history token penalized once).
    if params.repetition_penalty != 1.0 {
        let rp = params.repetition_penalty;
        let mut seen = vec![false; l.len()];
        for &t in history {
            let t = t as usize;
            if t < l.len() && !seen[t] {
                seen[t] = true;
                l[t] = if l[t] > 0.0 { l[t] / rp } else { l[t] * rp };
            }
        }
    }

    if params.is_greedy() {
        let tok = argmax(&l);
        let logprob = l[tok] - log_sum_exp(&l);
        return SampledToken { token: tok as i32, logprob };
    }

    for x in &mut l {
        *x /= params.temperature;
    }

    // Top-k: mask everything strictly below the k-th largest logit
    // (ties at the threshold all survive — deterministic, no RNG use).
    // total_cmp: a NaN logit from a numerically-broken step must not
    // panic the serving loop (the old argmax was NaN-tolerant too).
    if params.top_k > 0 && params.top_k < l.len() {
        let mut sorted = l.clone();
        sorted.sort_by(|a, b| b.total_cmp(a));
        let thresh = sorted[params.top_k - 1];
        for x in &mut l {
            if *x < thresh {
                *x = f32::NEG_INFINITY;
            }
        }
    }

    // Top-p: keep the smallest high-probability set reaching `top_p`
    // mass (always at least the single most likely token).
    if params.top_p < 1.0 {
        let lse = log_sum_exp(&l);
        let mut idx: Vec<usize> = (0..l.len()).filter(|&i| l[i].is_finite()).collect();
        idx.sort_by(|&a, &b| l[b].total_cmp(&l[a]).then(a.cmp(&b)));
        let mut cum = 0.0f64;
        let mut keep = 0usize;
        for &i in &idx {
            cum += f64::from((l[i] - lse).exp());
            keep += 1;
            if cum >= f64::from(params.top_p) {
                break;
            }
        }
        for &i in &idx[keep..] {
            l[i] = f32::NEG_INFINITY;
        }
    }

    // Draw from the renormalized survivors with a single uniform.
    let lse = log_sum_exp(&l);
    let u = rng.f64();
    let mut cum = 0.0f64;
    let mut chosen = None;
    let mut last_finite = 0usize;
    for (i, &x) in l.iter().enumerate() {
        if !x.is_finite() {
            continue;
        }
        last_finite = i;
        cum += f64::from((x - lse).exp());
        if u < cum {
            chosen = Some(i);
            break;
        }
    }
    // Float round-off can leave cum slightly under 1: fall back to the
    // last surviving token.
    let tok = chosen.unwrap_or(last_finite);
    SampledToken { token: tok as i32, logprob: l[tok] - lse }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn logits() -> Vec<f32> {
        vec![0.1, 3.0, -1.0, 2.5, 0.0]
    }

    #[test]
    fn greedy_matches_argmax_and_skips_the_rng() {
        let params = SamplingParams::greedy();
        let mut rng = Rng::new(1);
        let before = rng.clone();
        let s = sample_token(&logits(), &[], &params, &mut rng);
        assert_eq!(s.token, 1);
        assert!(s.logprob < 0.0);
        // RNG untouched: the next draw matches the pristine clone.
        let mut before = before;
        assert_eq!(rng.next_u64(), before.next_u64());
    }

    #[test]
    fn greedy_logprob_is_log_softmax_at_argmax() {
        let l = logits();
        let s = sample_token(&l, &[], &SamplingParams::greedy(), &mut Rng::new(0));
        let m = l.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let z: f32 = l.iter().map(|&x| (x - m).exp()).sum();
        let want = l[1] - (m + z.ln());
        assert!((s.logprob - want).abs() < 1e-6, "{} vs {want}", s.logprob);
    }

    #[test]
    fn deterministic_for_seed_and_advances_one_draw() {
        let params = SamplingParams {
            temperature: 0.8,
            top_k: 3,
            top_p: 0.95,
            repetition_penalty: 1.1,
        };
        let hist = [1, 3, 3];
        let a = sample_token(&logits(), &hist, &params, &mut Rng::new(7));
        let b = sample_token(&logits(), &hist, &params, &mut Rng::new(7));
        assert_eq!(a, b, "same seed, same draw");
        // Exactly one uniform consumed.
        let mut r1 = Rng::new(7);
        let _ = sample_token(&logits(), &hist, &params, &mut r1);
        let mut r2 = Rng::new(7);
        let _ = r2.f64();
        assert_eq!(r1.next_u64(), r2.next_u64());
    }

    #[test]
    fn top_k_one_is_greedy() {
        let params = SamplingParams {
            temperature: 1.0,
            top_k: 1,
            top_p: 1.0,
            repetition_penalty: 1.0,
        };
        for seed in 0..20 {
            let s = sample_token(&logits(), &[], &params, &mut Rng::new(seed));
            assert_eq!(s.token, 1);
            // Sole survivor: probability 1, logprob 0.
            assert!(s.logprob.abs() < 1e-6);
        }
    }

    #[test]
    fn top_k_bounds_the_support() {
        let params = SamplingParams {
            temperature: 1.5,
            top_k: 2,
            top_p: 1.0,
            repetition_penalty: 1.0,
        };
        for seed in 0..64 {
            let s = sample_token(&logits(), &[], &params, &mut Rng::new(seed));
            // Top-2 logits are indices 1 (3.0) and 3 (2.5).
            assert!(s.token == 1 || s.token == 3, "token {}", s.token);
            assert!(s.logprob <= 0.0);
        }
    }

    #[test]
    fn tiny_top_p_keeps_only_the_mode() {
        let params = SamplingParams {
            temperature: 1.0,
            top_k: 0,
            top_p: 1e-6,
            repetition_penalty: 1.0,
        };
        for seed in 0..16 {
            let s = sample_token(&logits(), &[], &params, &mut Rng::new(seed));
            assert_eq!(s.token, 1);
        }
    }

    #[test]
    fn repetition_penalty_can_flip_the_greedy_choice() {
        // Token 1 dominates until the history penalizes it below token 3.
        let params = SamplingParams {
            repetition_penalty: 4.0,
            ..SamplingParams::greedy()
        };
        let s = sample_token(&logits(), &[1], &params, &mut Rng::new(0));
        assert_eq!(s.token, 3);
        // Each history token is penalized once, not per occurrence.
        let s2 = sample_token(&logits(), &[1, 1, 1], &params, &mut Rng::new(0));
        assert_eq!(s2.token, 3);
    }

    #[test]
    fn out_of_vocab_history_is_ignored() {
        let params = SamplingParams {
            repetition_penalty: 2.0,
            ..SamplingParams::greedy()
        };
        let s = sample_token(&logits(), &[999, -1i32], &params, &mut Rng::new(0));
        assert_eq!(s.token, 1);
    }

    #[test]
    fn nan_logits_never_panic_and_never_win() {
        // A numerically-broken step must not take down the serving loop:
        // NaNs are ignored by greedy, top-k, top-p and the draw alike.
        let l = vec![0.1, f32::NAN, 2.0, f32::NAN, 1.0];
        let greedy = sample_token(&l, &[], &SamplingParams::greedy(), &mut Rng::new(0));
        assert_eq!(greedy.token, 2);
        let stochastic = SamplingParams {
            temperature: 1.0,
            top_k: 2,
            top_p: 0.9,
            repetition_penalty: 1.1,
        };
        for seed in 0..32 {
            let s = sample_token(&l, &[2], &stochastic, &mut Rng::new(seed));
            assert!(s.token == 0 || s.token == 2 || s.token == 4, "token {}", s.token);
            assert!(s.logprob.is_finite());
        }
    }

    #[test]
    fn validation_rejects_bad_params() {
        assert!(SamplingParams::greedy().validate().is_ok());
        assert!(SamplingParams { temperature: -1.0, ..SamplingParams::greedy() }
            .validate()
            .is_err());
        assert!(SamplingParams { top_p: 0.0, ..SamplingParams::greedy() }
            .validate()
            .is_err());
        assert!(SamplingParams { top_p: 1.5, ..SamplingParams::greedy() }
            .validate()
            .is_err());
        assert!(
            SamplingParams { repetition_penalty: 0.0, ..SamplingParams::greedy() }
                .validate()
                .is_err()
        );
    }

    #[test]
    fn seq_rng_is_deterministic_and_id_sensitive() {
        let mut a = seq_rng(5, 10);
        let mut b = seq_rng(5, 10);
        assert_eq!(a.next_u64(), b.next_u64());
        let mut c = seq_rng(5, 11);
        let mut a2 = seq_rng(5, 10);
        assert_ne!(a2.next_u64(), c.next_u64());
    }

    #[test]
    fn stochastic_sampling_covers_more_than_the_mode() {
        let params = SamplingParams::stochastic(2.0);
        let mut seen = std::collections::HashSet::new();
        for seed in 0..200 {
            let s = sample_token(&logits(), &[], &params, &mut Rng::new(seed));
            seen.insert(s.token);
            assert!((0..5).contains(&s.token));
        }
        assert!(seen.len() >= 2, "temperature 2 should not be degenerate");
    }
}
