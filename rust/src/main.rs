//! `leanattn` — CLI for the LeanAttention reproduction.
//!
//! ```text
//! leanattn info    [--metrics]           artifact + device inventory
//! leanattn inspect [--json-out r.json]   KV-cache introspection report
//! leanattn serve   [--model tiny] [--requests 8] [--max-new 16]
//! leanattn simulate --batch 4 --heads 32 --ctx 65536 [--arch a100|h100|8xa100]
//! leanattn plan    --batch 1 --heads 8 --ctx 65536 [--slots 216]
//! leanattn figures [fig01|fig02|...|all]
//! leanattn sweep   [--samples 1000] [--arch a100]
//! ```
//!
//! (Arg parsing is hand-rolled: clap is not in the offline crate cache.)

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use lean_attention::bench_harness::figures;
use lean_attention::coordinator::{Engine, EngineConfig};
use lean_attention::partition::plan::{build_plan, DecodeProblem, Strategy};
use lean_attention::runtime::{Manifest, Runtime};
use lean_attention::sampling::{BeamSearch, BestOfN, SamplingParams};
use lean_attention::sim::schedule::simulate_all;
use lean_attention::sim::GpuArch;
use lean_attention::util::rng::Rng;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Tiny flag parser: `--key value` pairs after the subcommand.
struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                match argv.get(i + 1) {
                    Some(v) if !v.starts_with("--") => {
                        flags.insert(key.to_string(), v.clone());
                        i += 2;
                    }
                    _ => {
                        flags.insert(key.to_string(), "true".into());
                        i += 1;
                    }
                }
            } else {
                positional.push(argv[i].clone());
                i += 1;
            }
        }
        Args { positional, flags }
    }

    fn usize(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or(default))
            .unwrap_or(default)
    }

    fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.into())
    }

    fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or(default))
            .unwrap_or(default)
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn arch_by_name(name: &str) -> Result<GpuArch> {
    Ok(match name {
        "a100" => GpuArch::a100(),
        "h100" => GpuArch::h100(),
        "8xa100" => GpuArch::a100().multi(8),
        "8xh100" => GpuArch::h100().multi(8),
        other => bail!("unknown arch {other} (a100|h100|8xa100|8xh100)"),
    })
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(String::as_str).unwrap_or("help");
    let args = Args::parse(&argv[1.min(argv.len())..]);

    match cmd {
        "info" => info(&args),
        "inspect" => inspect_cmd(&args),
        "serve" => serve(&args),
        "simulate" => simulate_cmd(&args),
        "analyze" => analyze_cmd(&args),
        "bench" => bench_cmd(&args),
        "calibrate" => calibrate_cmd(&args),
        "plan" => plan_cmd(&args),
        "figures" => figures_cmd(&args),
        "sweep" => sweep_cmd(&args),
        "trace" => trace_cmd(&args),
        "help" | "--help" | "-h" => {
            println!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command {other}\n{HELP}"),
    }
}

const HELP: &str = "leanattn — LeanAttention (decode-phase stream-K attention) reproduction
commands:
  info     [--metrics]              artifact + PJRT device inventory;
                                    --metrics prints the documented metric
                                    catalog (name, kind, help) without
                                    needing artifacts
  inspect  [--steps 48] [--pages 48] [--page 4] [--top-k 8] [--seed 0]
           [--json-out PATH] [--flight-dir DIR]
                                    KV-cache introspection: deterministic
                                    fork/COW/truncate/evict churn, then the
                                    versioned page-heat / pool / sharing /
                                    radix report (schema-validated);
                                    --flight-dir also records and
                                    re-validates a demo flight bundle
  serve    [--model tiny] [--requests 8] [--max-new 16] [--seed 0]
           [--system-prompt-len N]  share an N-token system prompt across
                                    requests through the radix prefix cache
           [--temperature T] [--top-k K] [--top-p P]   sampling pipeline
           [--best-of N]            N zero-copy fork candidates per prompt,
                                    highest cumulative logprob wins
           [--beam-width W] [--expand E]   sampled beam search over forks
           [--spec-k K] [--draft ngram|model]   speculative decoding: K
                                    drafts verified per multi-token pass
           [--adaptive-spec]        size each sequence's draft from its
                                    running acceptance rate (EWMA)
           [--kv-budget P]          sparse long-context decode: stream only
                                    P selected pages of each context per step
           [--sink-pages S] [--window-pages W] [--dense-threshold T]
                                    always-retained sinks/recency window and
                                    the page count below which decode is dense
           [--slo-ms MS]            print the serving SLO report (TTFT/e2e
                                    percentiles, goodput, attainment)
           [--metrics-out PATH]     write the engine metrics snapshot
                                    (.prom -> Prometheus text exposition,
                                    anything else -> versioned JSON)
           [--trace-capacity N] [--trace-out PATH]   enable the structured
                                    tracer (N-event ring) and write its
                                    Chrome trace-event export
           [--kv-heads N]           pin the expected GQA plane: fail unless
                                    the artifact set has N KV heads
           [--audit-every N]        run the online invariant audit (page
                                    statistics, free list, refcount
                                    exactness, radix consistency) every N
                                    engine steps
           [--flight-dir DIR]       anomaly flight recorder: on a trigger,
                                    write a post-mortem bundle (trace +
                                    metrics + cache report + SLO text)
           [--watchdog-steps N]     mark the engine unhealthy and record a
                                    bundle after N progress-free steps
           [--storm-pages P]        eviction-storm trigger: prefix pages
                                    evicted within one step (default 64)
           [--flight-slo-ms MS]     SLO-breach trigger for the recorder
           [--drift-limit E]        online cost-model drift detection: EWMA
                                    of the predicted-vs-measured relative
                                    step-time error; a sustained breach of E
                                    fires the recorder's drift trigger
           [--drift-calibration PATH]   judge drift against the coefficients
                                    a `calibrate --json-out` run fitted
                                    (defaults to built-in nominal priors)
  simulate --batch B --heads H --ctx N [--head-dim 64] [--arch a100]
           [--kv-heads N]           GQA/MQA: H query heads share N KV heads
                                    (KV streams and bytes shrink by H/N)
           [--model-preset NAME]    head geometry from a named preset
                                    (phi3-medium|llama2-7b|mistral-7b|
                                    opt-30b|llama70b-gqa|mqa)
           [--shared-prefix N]      add the cascade row: batch shares an
                                    N-token prefix, streamed once per group
           [--fork-n N] [--fork-new M]   model a fork family: N siblings
                                    sharing the ctx as history, M decode steps
           [--spec-k K] [--acceptance A]   model a verify pass of K drafts
                                    vs E(A, K) sequential decode steps
           [--sparse-budget P] [--page 16] [--sink-pages S]
           [--window-pages W] [--mass-alpha 0.85]
                                    model a P-page selection: bytes saved +
                                    attention-mass coverage vs dense
  bench    --cascade-exec [--batch 4] [--prefix 256] [--suffix 64]
           [--heads 2] [--head-dim 16] [--tile 32] [--slots 64] [--iters 10]
                                    flat-lean vs cascade execution: gathered
                                    KV bytes + wall-clock (PJRT artifacts
                                    when built, host oracle otherwise)
  bench    --sampling [--n 4] [--history 256] [--suffix 64] [--iters 10]
           [--smoke]                parallel sampling: flat vs sibling-cascade
                                    decode on a forked COW paged KV cache
  bench    --spec [--k 4] [--draft ngram|model] [--history 256] [--smoke]
                                    speculative decoding: stream equality vs
                                    the sequential oracle, one multi-query
                                    verify pass vs k+1 decode steps, rollback
  bench    --sparse [--kv-budget 6] [--context 256] [--seqs 2] [--smoke]
                                    sparse page selection: gathered-KV bytes
                                    vs dense, needle recall, executor
                                    exactness, full-budget stream equality
  bench    --obs [--requests 24] [--trace-out PATH] [--slo-ms 50]
           [--trace-capacity 8192] [--overhead-limit 0.02]
           [--heat-overhead-limit 0.02] [--smoke]
                                    observability plane: traced cascade +
                                    speculative serving loop, per-phase
                                    p50/p95/p99 timings, SLO report, and
                                    the disabled-tracer overhead bound
  bench    --balance [--iters 48] [--drift-limit 0.75] [--smoke]
                                    partition balance: the cross-strategy
                                    PartitionReport on a ragged batch
                                    (stream-K imbalance strictly below
                                    fixed-split), a traced execution whose
                                    per-CTA spans join the work ledger, and
                                    a stationary drift stream that must not
                                    breach
  bench    --gqa [--heads 8] [--kv-heads N] [--batch 2] [--context 512]
           [--steps 4] [--tile 64] [--smoke]
                                    grouped (GQA/MQA) vs dense-per-head
                                    decode over identical draws: KV bytes
                                    shrink by h/h_kv, both streams exact
                                    vs the repeated-KV dense oracle
           (every bench takes [--seed N] for run-to-run reproducibility,
            [--json-out PATH] to write its machine-readable BenchReport,
            [--check-against BASELINE.json] [--tolerance 0.25] to gate the
            run against a committed baseline — counts and work accounting
            bit-exact, float measures within the relative tolerance — and
            [--baseline-out PATH] to fold its report into a baseline file)
  calibrate [--smoke] [--seed 0] [--iters N] [--scale N]
           [--json-out PATH] [--max-rel-err 0.8]
                                    fit cost-model coefficients (ns/byte,
                                    ns/flop, per-tile overhead) from traced
                                    runs of every strategy — flat, cascade,
                                    GQA, multi-query, sparse — against the
                                    exact work accounting, print the
                                    sim-vs-measured drift table, and assert
                                    the per-point relative-error bound
  analyze  --partition [--batch 8] [--heads 4] [--head-dim 32]
           [--ctx-lens 511,64,1290,...] [--arch a100] [--json-out PATH]
                                    per-tile work ledger + occupancy/wave
                                    report: every strategy's CTA schedule on
                                    one (default ragged) problem — grid,
                                    waves, makespan, load-imbalance factor,
                                    wave efficiency, critical-path CTA —
                                    schema-validated, JSON with --json-out
  plan     --batch B --heads H --ctx N [--slots 216]
  figures  [table1|fig01|fig02|fig03|fig07|fig08|fig09|fig10|fig11|fig12|fig13|all]
  sweep    [--samples 1000] [--arch a100]
  trace    [--model tiny] [--requests 16] [--gap 3] [--fixed] [--seed 0]";

fn info(args: &Args) -> Result<()> {
    // `--metrics`: the documented metric catalog — every name in
    // `DOCUMENTED_METRICS` with its kind and help line, read straight
    // from the snapshot both exporters serialize. Artifact-free, so
    // dashboards can be written before anything is served.
    if args.has("metrics") {
        use lean_attention::coordinator::{Metrics, DOCUMENTED_METRICS};
        use lean_attention::obs::MetricKind;
        let snap = Metrics::default().snapshot();
        println!(
            "documented serving metrics ({}, exported as leanattn_<name>):",
            DOCUMENTED_METRICS.len()
        );
        for m in snap.metrics() {
            let kind = match m.kind {
                MetricKind::Counter => "counter",
                MetricKind::Gauge => "gauge",
            };
            println!("  {:<34} {:<8} {}", m.name, kind, m.help);
        }
        println!(
            "\nthe engine snapshot adds live gauges on top (kv_pages_used, \
             engine_healthy,\nkv_pool_fragmentation, flight_bundles_total, ...) \
             — see `serve --metrics-out`."
        );
        return Ok(());
    }
    let manifest = Manifest::load(Manifest::default_dir())
        .context("load artifacts (run `make artifacts`)")?;
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {} ({} device(s))", rt.platform(), rt.device_count());
    println!("artifact dir:  {}", manifest.dir.display());
    println!("attention artifacts:");
    for a in &manifest.attention {
        println!(
            "  {:?} g={} d={} ctx={} tile={} ({})",
            a.kind, a.g, a.d, a.ctx, a.tile, a.file
        );
    }
    println!("models:");
    for (name, m) in &manifest.models {
        println!(
            "  {name}: {} layers, {} heads ({} kv) x d{}, vocab {}, ctx bucket {}, {} params",
            m.n_layers, m.n_heads, m.n_kv_heads, m.head_dim, m.vocab, m.ctx_bucket, m.param_count
        );
    }
    Ok(())
}

/// Deterministic noise plane for the inspect churn.
fn inspect_noise(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.range(0, 2048) as f32 / 1024.0 - 1.0).collect()
}

/// `leanattn inspect`: the KV-cache introspection plane, artifact-free.
/// Drives a deterministic fork/COW/truncate/evict churn over a paged
/// cache plus a radix prefix index — touching every heat site: appends,
/// flat gathers, sparse selection, evictions — then prints the versioned
/// cache report, self-validated against the same schema the flight
/// recorder's bundles re-validate with. `--json-out` writes the JSON
/// report; `--flight-dir` additionally records a demo flight bundle and
/// re-validates it from disk.
fn inspect_cmd(args: &Args) -> Result<()> {
    use lean_attention::coordinator::{
        Metrics, PagedKvCache, RadixPrefixIndex, RequestId,
    };
    use lean_attention::obs::{
        validate_bundle, validate_cache_report, Attrs, FlightRecorder,
        FlightSnapshot, FlightTrigger, Phase, Tracer,
    };
    use lean_attention::sparse::SparsePolicy;

    let seed = args.usize("seed", 0) as u64;
    let steps = args.usize("steps", 48);
    let pages = args.usize("pages", 48);
    let page_tokens = args.usize("page", 4);
    let top_k = args.usize("top-k", 8);
    let (layers, kv_heads, dh) = (2usize, 2usize, 8usize);
    anyhow::ensure!(pages >= 8, "--pages must be >= 8");

    let mut cache = PagedKvCache::new(layers, kv_heads, dh, page_tokens, pages);
    let mut index = RadixPrefixIndex::new(page_tokens);
    let mut rng = Rng::new(seed);
    let plane = layers * kv_heads * dh;
    let policy = SparsePolicy::with_budget(2);
    let mut next_id: RequestId = 1;
    let mut live: Vec<RequestId> = Vec::new();
    println!(
        "inspect: {steps}-step churn over {pages} pages x {page_tokens} tokens \
         ({layers} layers x {kv_heads} kv heads x d{dh}), seed {seed}"
    );

    for step in 0..steps {
        // Admit a fresh sequence and index its full-page prefix.
        if live.len() < 6 && cache.free_pages() >= 4 {
            let id = next_id;
            next_id += 1;
            let len = page_tokens * rng.urange(1, 4) + rng.urange(0, page_tokens);
            let len = len.max(1);
            let k = inspect_noise(&mut rng, plane * len);
            let v = inspect_noise(&mut rng, plane * len);
            if cache.insert_seq(id, &k, &v, len).is_ok() {
                live.push(id);
                let tokens: Vec<i32> =
                    (0..len as i32).map(|t| (id as i32 * 131 + t) % 509).collect();
                let seq_pages = cache.seq_pages(id).unwrap().to_vec();
                for p in index.insert(&tokens, &seq_pages) {
                    cache.retain_page(p)?;
                }
                let _ = index.lookup(&tokens); // hit-depth telemetry
            }
        }
        // Fork + divergent append: the copy-on-write path.
        if step % 3 == 0 && !live.is_empty() && cache.free_pages() >= 2 {
            let parent = live[rng.urange(0, live.len())];
            let child = next_id;
            next_id += 1;
            if cache.fork_seq(parent, child).is_ok() {
                live.push(child);
                let k = inspect_noise(&mut rng, plane);
                let v = inspect_noise(&mut rng, plane);
                let _ = cache.append_token(child, &k, &v);
            }
        }
        // Plain append to a random live sequence.
        if !live.is_empty() && cache.free_pages() >= 1 {
            let id = live[rng.urange(0, live.len())];
            let k = inspect_noise(&mut rng, plane);
            let v = inspect_noise(&mut rng, plane);
            let _ = cache.append_token(id, &k, &v);
        }
        // Speculative-rollback shape: truncate a tail token.
        if step % 5 == 0 {
            if let Some(&id) = live.last() {
                if let Some(len) = cache.seq_len(id) {
                    if len > 1 {
                        cache.truncate_seq(id, len - 1)?;
                    }
                }
            }
        }
        // Flat gather over up to 4 lanes (per-page gather touches).
        let lanes: Vec<Option<RequestId>> =
            live.iter().take(4).map(|&id| Some(id)).collect();
        if !lanes.is_empty() {
            let ctx = pages * page_tokens;
            let n = layers * lanes.len() * kv_heads * ctx * dh;
            let mut kb = vec![0.0f32; n];
            let mut vb = vec![0.0f32; n];
            cache.gather(&lanes, ctx, &mut kb, &mut vb)?;
        }
        // Sparse page selection (select touches).
        if let Some(&id) = live.first() {
            let _ = cache.select_seq_pages(id, &policy);
        }
        // Retire the oldest sequence; evict cold index pages under
        // pressure (the index may hold the last reference).
        if live.len() >= 5 {
            cache.free_seq(live.remove(0));
        }
        if cache.free_pages() < 4 {
            for p in index.evict_lru(4, |p| cache.page_ref(p) == 1) {
                cache.release_page(p)?;
            }
        }
        cache.heat_tick();
    }

    let report = cache.report(Some(index.stats()), top_k);
    let j = report.to_json();
    validate_cache_report(&j).context("cache report failed self-validation")?;
    println!("\n{}", report.render());
    if let Some(path) = args.flags.get("json-out") {
        std::fs::write(path, j.to_string())
            .with_context(|| format!("write cache report to {path}"))?;
        println!("cache report -> {path}");
    }
    if let Some(dir) = args.flags.get("flight-dir") {
        let tracer = Tracer::enabled(64);
        tracer.instant(Phase::Evict, Attrs { pages: Some(1), ..Default::default() });
        let trace = tracer.export_chrome_trace();
        let metrics = Metrics::default().snapshot().to_json();
        let mut rec = FlightRecorder::new(dir.as_str());
        let snap = FlightSnapshot {
            trace: &trace,
            metrics: &metrics,
            cache_report: &j,
            slo_text: "inspect demo bundle (no serving run)",
        };
        let bundle = rec
            .record(FlightTrigger::EvictionStorm, steps as u64, &snap)?
            .expect("first bundle is always under the cap");
        validate_bundle(&bundle).context("demo flight bundle failed re-validation")?;
        println!("flight bundle: {} (re-validated from disk)", bundle.display());
    }
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let model = args.str("model", "tiny");
    let n_requests = args.usize("requests", 8);
    let max_new = args.usize("max-new", 16);
    let seed = args.usize("seed", 0) as u64;
    let system_len = args.usize("system-prompt-len", 0);
    let best_of = args.usize("best-of", 1);
    let beam_width = args.usize("beam-width", 1);
    anyhow::ensure!(
        best_of <= 1 || beam_width <= 1,
        "--best-of and --beam-width are mutually exclusive"
    );
    let spec_k = args.usize("spec-k", 0);
    let spec_draft = lean_attention::spec::DraftKind::parse(&args.str("draft", "ngram"))
        .ok_or_else(|| anyhow::anyhow!("unknown --draft (ngram|model)"))?;
    let adaptive_spec = args.has("adaptive-spec");

    // Sparse long-context decode: a page budget turns on query-aware
    // top-k page selection over the paged cache.
    let kv_budget = args.usize("kv-budget", 0);
    let sparse = if kv_budget > 0 {
        let mut p = lean_attention::sparse::SparsePolicy::with_budget(kv_budget);
        p.sink_pages = args.usize("sink-pages", p.sink_pages);
        p.window_pages = args.usize("window-pages", p.window_pages);
        p.dense_threshold_pages =
            args.usize("dense-threshold", p.dense_threshold_pages);
        p.validate()?;
        Some(p)
    } else {
        None
    };

    // Sampling pipeline: greedy unless a temperature is given; parallel
    // sampling needs a stochastic sampler, so it defaults to 0.8.
    let parallel = best_of > 1 || beam_width > 1;
    let default_temp = if parallel { 0.8 } else { 0.0 };
    let params = SamplingParams {
        temperature: args.f64("temperature", default_temp) as f32,
        top_k: args.usize("top-k", 0),
        top_p: args.f64("top-p", 1.0) as f32,
        repetition_penalty: args.f64("repetition-penalty", 1.0) as f32,
    };
    params.validate()?;

    // Observability: a nonzero ring capacity turns the structured
    // tracer on; the snapshot/SLO surfaces are always available.
    let trace_capacity = args.usize("trace-capacity", 0);

    // The introspection plane: sampled invariant audits, the anomaly
    // flight recorder and its triggers, and the health watchdog.
    let audit = lean_attention::coordinator::AuditPlan::every(args.usize("audit-every", 0));
    let flight_dir = args.flags.get("flight-dir").cloned();
    let watchdog_stall_steps = args.usize("watchdog-steps", 0) as u64;
    let eviction_storm_pages = args.usize("storm-pages", 64);
    let flight_slo_ms = args.f64("flight-slo-ms", 0.0);

    // Online cost-model drift detection: a nonzero EWMA limit arms the
    // detector; `--drift-calibration` judges against the coefficients a
    // `calibrate --json-out` run fitted instead of the nominal priors.
    let drift_limit = args.f64("drift-limit", 0.0);
    let drift_coefficients = match args.flags.get("drift-calibration") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("read calibration report {path}"))?;
            Some(
                parse_calibration_coefficients(&text)
                    .with_context(|| format!("parse calibration report {path}"))?,
            )
        }
        None => None,
    };

    let runtime = Rc::new(Runtime::cpu()?);
    let manifest = Manifest::load(Manifest::default_dir())?;
    let mut engine = Engine::new(
        &runtime,
        &manifest,
        EngineConfig {
            model: model.clone(),
            sampling: params.clone(),
            seed,
            spec_k,
            spec_draft,
            adaptive_spec,
            sparse,
            trace_capacity,
            audit,
            flight_dir,
            watchdog_stall_steps,
            eviction_storm_pages,
            flight_slo_ms,
            drift_limit,
            drift_coefficients,
            ..Default::default()
        },
    )?;
    println!(
        "engine up: model={model} batch={} ctx_bucket={} prefill_bucket={}",
        engine.batch_size(),
        engine.ctx_bucket(),
        engine.prefill_bucket()
    );
    // The KV plane comes from the artifact set; `--kv-heads` pins the
    // expected GQA grouping so a mismatched artifact fails loudly instead
    // of silently serving a different KV budget.
    let kv_heads = args.usize("kv-heads", 0);
    if kv_heads > 0 {
        anyhow::ensure!(
            engine.kv_heads() == kv_heads,
            "--kv-heads {kv_heads} does not match model {model:?}: artifact \
             has {} kv heads ({} query heads)",
            engine.kv_heads(),
            engine.query_heads()
        );
    }
    if kv_heads > 0 || engine.kv_heads() != engine.query_heads() {
        println!(
            "gqa plane: {} query heads over {} kv heads (group {}, KV bytes 1/{} of dense)",
            engine.query_heads(),
            engine.kv_heads(),
            engine.query_heads() / engine.kv_heads(),
            engine.query_heads() / engine.kv_heads(),
        );
    }
    if let Some(p) = &sparse {
        println!(
            "sparse decode on: {} of each context's pages per step \
             ({} sink + {} window retained), dense at <= {} pages",
            p.budget_pages, p.sink_pages, p.window_pages, p.dense_threshold_pages
        );
    }
    if drift_limit > 0.0 {
        println!(
            "drift detection on: rel-err EWMA limit {drift_limit} ({})",
            if args.has("drift-calibration") {
                "calibrated coefficients"
            } else {
                "nominal priors"
            }
        );
    }
    if spec_k > 0 {
        if engine.spec_enabled() {
            println!(
                "speculative decoding on: k={spec_k}{}, draft={spec_draft} \
                 (1..={} tokens committed per verify pass)",
                if adaptive_spec { " (acceptance-adaptive)" } else { "" },
                spec_k + 1
            );
        } else {
            println!(
                "speculative decoding requested but this artifact set has no verify \
                 step — rebuild artifacts (`make artifacts`); decoding plainly"
            );
        }
    }

    let wall0 = std::time::Instant::now();
    let mut rng = Rng::new(seed);
    let vocab = 512u64;
    // A shared system prompt, prepended to every request so the radix
    // prefix cache and the cascade projection have something to share.
    let system_len = system_len.min(engine.prefill_bucket().saturating_sub(1));
    let system: Vec<i32> = (0..system_len)
        .map(|_| rng.range(0, vocab) as i32)
        .collect();
    if system_len > 0 {
        println!("sharing a {system_len}-token system prompt across all requests");
    }

    if parallel {
        // Parallel sampling: each prompt runs through a controller that
        // forks zero-copy siblings over the COW paged KV cache; the
        // siblings' shared history streams once per group through the
        // cascade gather.
        for i in 0..n_requests {
            let len = rng.urange(1, engine.prefill_bucket() - system_len + 1);
            let mut prompt = system.clone();
            prompt.extend((0..len).map(|_| rng.range(0, vocab) as i32));
            let total = prompt.len();
            let outcome = if best_of > 1 {
                println!("\nrequest #{i}: best-of-{best_of} over a {total}-token prompt");
                BestOfN { n: best_of, max_new, params: params.clone() }
                    .run(&mut engine, prompt)?
            } else {
                let expand = args.usize("expand", 2);
                println!(
                    "\nrequest #{i}: beam search (width {beam_width}, expand {expand}) \
                     over a {total}-token prompt"
                );
                BeamSearch {
                    width: beam_width,
                    expand,
                    max_new,
                    params: params.clone(),
                }
                .run(&mut engine, prompt)?
            };
            for (rank, c) in outcome.candidates.iter().enumerate() {
                println!(
                    "  {} candidate {}: {} tokens, cum logprob {:>9.3} ({:?}{})",
                    if rank == 0 { "*" } else { " " },
                    c.finished.id,
                    c.finished.output.len(),
                    c.score,
                    c.finished.reason,
                    c.finished
                        .parent
                        .map(|p| format!(", forked off {p}"))
                        .unwrap_or_default(),
                );
            }
        }
        println!("\n{}", engine.metrics.report());
        serve_obs_out(&engine, args, wall0.elapsed().as_secs_f64())?;
        return Ok(());
    }

    for i in 0..n_requests {
        let len = rng.urange(1, engine.prefill_bucket() - system_len + 1);
        let mut prompt = system.clone();
        prompt.extend((0..len).map(|_| rng.range(0, vocab) as i32));
        let total = prompt.len();
        let id = engine.submit(prompt, max_new)?;
        println!("submitted request {id} (prompt {total} tokens), #{i}");
    }

    let finished = engine.run_until_idle()?;
    println!("\nper-request results:");
    for f in &finished {
        println!(
            "  req {}: {} prompt + {} generated, queue {:.1}ms, prefill {:.1}ms, decode {:.1}ms ({:.1} tok/s), cum logprob {:.3}",
            f.id,
            f.prompt_len,
            f.output.len(),
            f.queue_s * 1e3,
            f.prefill_s * 1e3,
            f.decode_s * 1e3,
            f.decode_tps(),
            f.cum_logprob
        );
    }
    println!("\n{}", engine.metrics.report());
    serve_obs_out(&engine, args, wall0.elapsed().as_secs_f64())?;
    Ok(())
}

/// Extract the fitted [`CostCoefficients`] from a `calibrate --json-out`
/// report, so `serve --drift-calibration` judges drift against exactly
/// the model the calibration run asserted.
fn parse_calibration_coefficients(
    text: &str,
) -> Result<lean_attention::sim::CostCoefficients> {
    use lean_attention::util::json::Json;
    let j = Json::parse(text).context("calibration report is not valid JSON")?;
    let coef = j
        .as_obj()
        .and_then(|o| o.get("coefficients"))
        .and_then(Json::as_obj)
        .ok_or_else(|| anyhow::anyhow!("report has no coefficients object"))?;
    let field = |key: &str| {
        coef.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("coefficients missing {key:?}"))
    };
    let c = lean_attention::sim::CostCoefficients {
        ns_per_byte: field("ns_per_byte")?,
        ns_per_flop: field("ns_per_flop")?,
        tile_overhead_ns: field("tile_overhead_ns")?,
    };
    anyhow::ensure!(
        c.ns_per_byte > 0.0 || c.ns_per_flop > 0.0 || c.tile_overhead_ns > 0.0,
        "calibrated coefficients are all zero — the detector would never observe"
    );
    Ok(c)
}

/// The observability surfaces `serve` exposes after a run: the SLO
/// report (`--slo-ms`), the metrics snapshot (`--metrics-out`, Prometheus
/// text for `.prom` paths and versioned JSON otherwise), and the Chrome
/// trace-event export (`--trace-capacity N --trace-out PATH`).
fn serve_obs_out(engine: &Engine, args: &Args, wall_s: f64) -> Result<()> {
    if args.has("slo-ms") {
        let slo_ms = args.f64("slo-ms", 50.0);
        println!("\n{}", engine.timelines.slo_report(slo_ms, wall_s).render());
    }
    if let Some(path) = args.flags.get("metrics-out") {
        let snap = engine.snapshot();
        let text = if path.ends_with(".prom") {
            snap.to_prometheus()
        } else {
            snap.to_json().to_string()
        };
        std::fs::write(path, &text)
            .with_context(|| format!("write metrics snapshot to {path}"))?;
        println!("metrics snapshot: {} series -> {path}", snap.names().len());
    }
    if let Some(path) = args.flags.get("trace-out") {
        let trace = engine.tracer.export_chrome_trace();
        std::fs::write(path, trace.to_string())
            .with_context(|| format!("write chrome trace to {path}"))?;
        println!(
            "chrome trace: {} events -> {path} ({} dropped to ring overflow; \
             load in chrome://tracing or ui.perfetto.dev)",
            engine.tracer.len(),
            engine.tracer.dropped()
        );
    }
    if engine.flight_bundles() > 0 {
        println!(
            "flight recorder: {} post-mortem bundle(s) written",
            engine.flight_bundles()
        );
    }
    if !engine.healthy() {
        println!("engine health: STALLED (watchdog fired; see the flight bundles)");
    }
    Ok(())
}

fn simulate_cmd(args: &Args) -> Result<()> {
    use lean_attention::model::ModelConfig;

    // A named preset supplies the head geometry (query heads, KV heads,
    // head_dim); explicit flags still override any of them.
    let preset_name = args.str("model-preset", "");
    let preset = if preset_name.is_empty() {
        None
    } else {
        Some(ModelConfig::by_name(&preset_name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown --model-preset {preset_name:?} (one of {})",
                ModelConfig::PRESET_NAMES.join("|")
            )
        })?)
    };
    let batch = args.usize("batch", 4);
    let heads = args.usize("heads", preset.as_ref().map_or(32, |c| c.n_heads));
    let ctx = args.usize("ctx", 65536);
    let head_dim =
        args.usize("head-dim", preset.as_ref().map_or(64, |c| c.head_dim));
    let kv_heads =
        args.usize("kv-heads", preset.as_ref().map_or(heads, |c| c.n_kv_heads));
    anyhow::ensure!(
        kv_heads >= 1 && heads % kv_heads == 0,
        "--kv-heads {kv_heads} must divide --heads {heads}"
    );
    let arch = arch_by_name(&args.str("arch", "a100"))?;

    let p = DecodeProblem::uniform(batch, heads, ctx, head_dim).with_kv_heads(kv_heads);
    if let Some(c) = &preset {
        println!("preset: {} ({} q heads / {} kv heads, d{})", c.name, c.n_heads, c.n_kv_heads, c.head_dim);
    }
    println!(
        "problem: batch={batch} heads={heads} kv_heads={kv_heads} (group {}) ctx={ctx} d={head_dim} tile={} -> {} KV streams, {} LeanTiles",
        p.group_size(),
        p.tile,
        p.groups(),
        p.total_tiles()
    );
    println!("arch: {} ({} SMs, {} CTA slots)\n", arch.name, arch.num_sms, arch.sm_slots());
    println!(
        "{:<18} {:>12} {:>10} {:>8} {:>8} {:>10}",
        "mechanism", "latency_us", "occupancy", "grid", "waves", "energy_mJ"
    );
    let results = simulate_all(&p, &arch);
    let la = results.last().unwrap().latency_us;
    for r in &results {
        println!(
            "{:<18} {:>12.1} {:>9.1}% {:>8} {:>8.2} {:>10.2}   ({:.2}x vs LA)",
            r.name(),
            r.latency_us,
            r.occupancy * 100.0,
            r.grid,
            r.waves,
            r.energy_j * 1e3,
            r.latency_us / la
        );
    }

    // Optional cascade row: the whole batch shares an N-token prefix,
    // streamed once instead of once per sequence.
    let shared = args.usize("shared-prefix", 0);
    if shared > 0 {
        use lean_attention::partition::cascade::{CascadeProblem, PrefixGroup};
        use lean_attention::sim::simulate_cascade;
        anyhow::ensure!(
            shared <= ctx,
            "--shared-prefix {shared} exceeds --ctx {ctx}"
        );
        let cp = CascadeProblem::new(
            heads,
            vec![ctx as u32; batch],
            head_dim,
            vec![PrefixGroup {
                prefix_len: shared as u32,
                members: (0..batch as u32).collect(),
            }],
        )?
        .with_kv_heads(kv_heads)
        .tile_aligned();
        if cp.prefix_groups.is_empty() {
            println!(
                "\ncascade: shared prefix of {shared} tokens is below one \
                 LeanTile or batch < 2 — nothing to share"
            );
        } else {
            let r = simulate_cascade(&cp, &arch);
            println!(
                "\ncascade (shared {shared}-token prefix): {:.1}us, occupancy {:.1}%, \
                 KV bytes {:.1} MiB vs {:.1} MiB flat ({:.0}% saved), {:.2}x vs LA",
                r.latency_us,
                r.occupancy * 100.0,
                r.kv_bytes / (1024.0 * 1024.0),
                r.baseline_kv_bytes / (1024.0 * 1024.0),
                r.bytes_saved_fraction() * 100.0,
                la / r.latency_us,
            );
        }
    }

    // Optional speculative-decoding row: one verify pass of k drafts
    // over the ctx vs the expected number of sequential 1-token steps.
    let spec_k = args.usize("spec-k", 0);
    if spec_k > 0 {
        use lean_attention::sim::{simulate_spec_decode, SpecDecodeCase};
        let acceptance = args.f64("acceptance", 0.8);
        anyhow::ensure!(
            (0.0..=1.0).contains(&acceptance),
            "--acceptance must be in [0, 1]"
        );
        let case = SpecDecodeCase { heads, head_dim, ctx, k: spec_k, acceptance };
        let r = simulate_spec_decode(&case, &arch);
        println!(
            "\nspeculative decode (k={spec_k}, acceptance {acceptance:.2}): \
             {:.2} tokens/pass, verify {:.1}us vs {:.1}us sequential ({:.2}x), \
             KV {:.1} MiB vs {:.1} MiB ({:.0}% saved)",
            r.tokens_per_pass,
            r.verify_us,
            r.sequential_us,
            r.speedup(),
            r.verify_kv_bytes / (1024.0 * 1024.0),
            r.sequential_kv_bytes / (1024.0 * 1024.0),
            r.bytes_saved_fraction() * 100.0,
        );
    }

    // Optional sparse-selection row: each sequence streams only a page
    // budget of its ctx, priced against the dense step.
    let sparse_budget = args.usize("sparse-budget", 0);
    if sparse_budget > 0 {
        use lean_attention::sim::{simulate_sparse_decode, SparseDecodeCase};
        use lean_attention::sparse::SparsePolicy;
        let mut policy = SparsePolicy::with_budget(sparse_budget);
        policy.sink_pages = args.usize("sink-pages", policy.sink_pages);
        policy.window_pages = args.usize("window-pages", policy.window_pages);
        policy.validate()?;
        let case = SparseDecodeCase {
            batch,
            heads,
            head_dim,
            ctx,
            page_tokens: args.usize("page", 16),
            policy,
            mass_alpha: args.f64("mass-alpha", 0.85),
        };
        let r = simulate_sparse_decode(&case, &arch);
        println!(
            "\nsparse decode (budget {} of {} pages): {:.1}us vs {:.1}us dense \
             ({:.2}x), KV {:.1} MiB vs {:.1} MiB ({:.0}% saved), modeled \
             attention-mass coverage {:.2}",
            r.pages_selected,
            r.pages_total,
            r.sparse_us,
            r.dense_us,
            r.speedup(),
            r.sparse_kv_bytes / (1024.0 * 1024.0),
            r.dense_kv_bytes / (1024.0 * 1024.0),
            r.bytes_saved_fraction() * 100.0,
            r.coverage,
        );
    }

    // Optional fork-family row: N siblings share the full ctx as their
    // fork-point history and decode M divergent tokens.
    let fork_n = args.usize("fork-n", 0);
    if fork_n > 0 {
        use lean_attention::sim::{simulate_fork_decode, ForkDecodeCase};
        let case = ForkDecodeCase {
            heads,
            head_dim,
            siblings: fork_n,
            history: ctx,
            decode_steps: args.usize("fork-new", 32),
        };
        let r = simulate_fork_decode(&case, &arch);
        println!(
            "\nfork family ({fork_n} siblings, {ctx}-token history, {} steps): \
             KV {:.1} MiB vs {:.1} MiB flat ({:.0}% saved), {:.2}x speedup",
            r.steps,
            r.cascade_kv_bytes / (1024.0 * 1024.0),
            r.flat_kv_bytes / (1024.0 * 1024.0),
            r.bytes_saved_fraction() * 100.0,
            r.speedup(),
        );
    }
    Ok(())
}

/// `leanattn analyze --partition`: the partition-quality report. Builds
/// every strategy's plan for one decode problem (default: the ragged
/// Fig-10-style batch), joins the per-tile work ledger with the
/// simulated per-CTA timelines, self-validates the result against the
/// versioned schema, and prints the cross-strategy comparison — grid,
/// waves, makespan, load-imbalance factor, wave efficiency and the
/// critical-path CTA. `--json-out` writes the full report (ledger rows
/// included) as JSON.
fn analyze_cmd(args: &Args) -> Result<()> {
    use lean_attention::obs::{partition_report, validate_partition_report};

    anyhow::ensure!(
        args.has("partition"),
        "usage: leanattn analyze --partition [--ctx-lens 511,64,...] \
         [--batch 8 --ctx N] [--heads 4] [--head-dim 32] [--kv-heads N] \
         [--arch a100] [--json-out PATH]"
    );
    let heads = args.usize("heads", 4);
    let head_dim = args.usize("head-dim", 32);
    let arch = arch_by_name(&args.str("arch", "a100"))?;
    // The problem: an explicit ragged list, a uniform batch, or the
    // default ragged batch (the same shape `bench --balance` gates).
    let lens: Vec<u32> = match args.flags.get("ctx-lens") {
        Some(list) => list
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<u32>()
                    .map_err(|_| anyhow::anyhow!("bad --ctx-lens entry {s:?}"))
            })
            .collect::<Result<Vec<u32>>>()?,
        None => {
            let ctx = args.usize("ctx", 0);
            if ctx > 0 {
                vec![ctx as u32; args.usize("batch", 8)]
            } else {
                vec![511, 64, 1290, 32, 777, 96, 2048, 130]
            }
        }
    };
    anyhow::ensure!(!lens.is_empty(), "--ctx-lens is empty");
    let kv_heads = args.usize("kv-heads", heads);
    anyhow::ensure!(
        kv_heads >= 1 && heads % kv_heads == 0,
        "--kv-heads {kv_heads} must divide --heads {heads}"
    );
    let mut p = DecodeProblem::ragged(heads, lens, head_dim).with_kv_heads(kv_heads);
    let tile = args.usize("tile", 0);
    if tile > 0 {
        p = p.with_tile(tile);
    }

    let report = partition_report(&p, &arch);
    validate_partition_report(&report.to_json())
        .context("partition report failed self-validation")?;
    println!("{}", report.render());
    if let Some(lean) = report.stream_k() {
        let mut rows: Vec<_> = lean.ledger.iter().collect();
        rows.sort_by(|a, b| b.finish_us.total_cmp(&a.finish_us));
        println!("stream-K critical path (top CTAs by finish time):");
        for r in rows.iter().take(3) {
            println!(
                "  cta {:>4} slot {:>3}  {:>6} tiles in {} segment(s)  \
                 finish {:>9.1}us",
                r.cta, r.slot, r.work.tiles, r.segments, r.finish_us
            );
        }
    }
    if let Some(path) = args.flags.get("json-out") {
        std::fs::write(path, report.to_json().to_string())
            .with_context(|| format!("write partition report to {path}"))?;
        println!("partition report -> {path}");
    }
    Ok(())
}

/// Shared telemetry plumbing for every bench subcommand: self-validate
/// the machine-readable report, write it (`--json-out`), gate it against
/// a committed baseline (`--check-against` + `--tolerance`), and fold it
/// into a baseline file (`--baseline-out`, read-modify-write so the six
/// harnesses can accumulate into one file).
fn bench_report_out(
    rep: &lean_attention::obs::BenchReport,
    args: &Args,
) -> Result<()> {
    use lean_attention::obs::benchlog;
    let j = rep.to_json();
    benchlog::validate_bench_report(&j)
        .context("emitted bench report failed self-validation")?;
    if let Some(path) = args.flags.get("json-out") {
        std::fs::write(path, j.to_string())
            .with_context(|| format!("write bench report to {path}"))?;
        println!("bench report: {} -> {path}", rep.name);
    }
    if let Some(path) = args.flags.get("check-against") {
        let tol = args.f64("tolerance", 0.25);
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read baseline {path}"))?;
        benchlog::check_against(rep, &text, tol)?;
        println!(
            "baseline gate: {} matches {path} (counts/work exact, \
             measures within {:.0}%)",
            rep.name,
            tol * 100.0
        );
    }
    if let Some(path) = args.flags.get("baseline-out") {
        let mut reports = match std::fs::read_to_string(path) {
            Ok(text) => benchlog::parse_baseline(&text)
                .with_context(|| format!("parse existing baseline {path}"))?,
            Err(_) => Default::default(),
        };
        reports.insert(rep.name.clone(), rep.clone());
        std::fs::write(path, benchlog::baseline_to_json(&reports).to_string())
            .with_context(|| format!("write baseline to {path}"))?;
        println!("baseline: {} entry updated in {path}", rep.name);
    }
    Ok(())
}

/// `leanattn calibrate`: fit cost-model coefficients (ns/byte, ns/flop,
/// per-tile overhead) by joining the tracer's measured gather/exec spans
/// with the exact work accounting over every strategy — flat, cascade,
/// GQA, multi-query and sparse posings — then report per-strategy
/// sim-vs-measured drift and assert the relative-error bound.
fn calibrate_cmd(args: &Args) -> Result<()> {
    use lean_attention::obs::calibrate::{run_calibration, CalibrationCase};

    let smoke = args.has("smoke");
    let base =
        if smoke { CalibrationCase::smoke() } else { CalibrationCase::default_case() };
    let case = CalibrationCase {
        iters: args.usize("iters", base.iters),
        scale: args.usize("scale", base.scale),
        slots: args.usize("slots", base.slots),
        batch_rows: args.usize("batch-rows", base.batch_rows),
    };
    let seed = args.usize("seed", 0) as u64;
    let report = run_calibration(case, seed)?;
    println!("{}", report.render());
    if let Some(path) = args.flags.get("json-out") {
        std::fs::write(path, report.to_json().to_string())
            .with_context(|| format!("write calibration report to {path}"))?;
        println!("calibration report -> {path}");
    }
    // Host timings on shared CI machines are noisy; the default bound
    // asserts the model *tracks* the measurements (no structural drift),
    // not that the machine is quiet.
    let bound = args.f64("max-rel-err", 0.8);
    anyhow::ensure!(
        report.max_rel_err() <= bound,
        "calibrated cost model drifted: max relative error {:.3} exceeds \
         the {bound} bound",
        report.max_rel_err()
    );
    println!(
        "cost model holds: max relative error {:.3} <= {bound} across {} points",
        report.max_rel_err(),
        report.points.len()
    );
    Ok(())
}

fn bench_cmd(args: &Args) -> Result<()> {
    use lean_attention::bench_harness::{compare_exec, ExecCase};
    use lean_attention::runtime::AttentionExecutor;

    // One uniform `--seed` across every bench subcommand and harness
    // runner, so spec/sampling/cascade numbers reproduce run-to-run.
    let seed = args.usize("seed", 0) as u64;
    if args.has("sampling") {
        return bench_sampling(args, seed);
    }
    if args.has("spec") {
        return bench_spec(args, seed);
    }
    if args.has("sparse") {
        return bench_sparse(args, seed);
    }
    if args.has("obs") {
        return bench_obs(args, seed);
    }
    if args.has("gqa") {
        return bench_gqa(args, seed);
    }
    if args.has("balance") {
        return bench_balance(args, seed);
    }
    anyhow::ensure!(
        args.has("cascade-exec"),
        "usage: leanattn bench --cascade-exec [--batch 4] [--prefix 256] ...\n       \
         leanattn bench --sampling [--n 4] [--history 256] [--suffix 64] [--smoke]\n       \
         leanattn bench --spec [--k 4] [--draft ngram|model] [--smoke]\n       \
         leanattn bench --sparse [--kv-budget 6] [--context 256] [--smoke]\n       \
         leanattn bench --obs [--requests 24] [--trace-out PATH] [--smoke]\n       \
         leanattn bench --gqa [--heads 8] [--kv-heads 2] [--smoke]\n       \
         leanattn bench --balance [--iters 48] [--drift-limit 0.75] [--smoke]"
    );
    let case = ExecCase {
        batch: args.usize("batch", 4),
        prefix: args.usize("prefix", 256) as u32,
        suffix: args.usize("suffix", 64) as u32,
        heads: args.usize("heads", 2),
        head_dim: args.usize("head-dim", 16),
        tile: args.usize("tile", 32),
        slots: args.usize("slots", 64),
    };
    anyhow::ensure!(case.batch >= 2, "--batch must be >= 2 to share a prefix");
    let iters = args.usize("iters", 10);

    // PJRT artifacts when present, host oracle otherwise — both run the
    // identical task-rolling + group-broadcast-fold driver.
    let exec = Manifest::load(Manifest::default_dir())
        .ok()
        .and_then(|m| {
            let rt = Rc::new(Runtime::cpu().ok()?);
            Some(AttentionExecutor::new(rt, Rc::new(m)))
        });
    let backend = if exec.is_some() { "pjrt artifacts" } else { "host oracle" };
    println!(
        "cascade-exec: batch={} prefix={} suffix={} heads={} d={} tile={} ({backend})",
        case.batch, case.prefix, case.suffix, case.heads, case.head_dim, case.tile
    );

    let c = compare_exec(case, iters, exec.as_ref(), seed)?;
    println!(
        "flat lean:  {:>10.1} KiB gathered KV, p50 {:>9.1}us",
        c.flat_kv_bytes as f64 / 1024.0,
        c.flat_us.p50
    );
    println!(
        "cascade:    {:>10.1} KiB gathered KV, p50 {:>9.1}us  ({:.1}% bytes saved, {:.2}x)",
        c.cascade_kv_bytes as f64 / 1024.0,
        c.cascade_us.p50,
        c.bytes_saved_fraction() * 100.0,
        c.flat_us.p50 / c.cascade_us.p50
    );
    println!("max |flat - cascade| = {:.2e}", c.max_err);
    bench_report_out(&c.bench_report(seed, args.has("smoke")), args)?;
    Ok(())
}

/// `leanattn bench --sampling`: flat vs sibling-cascade decode for a
/// fork family on the COW paged KV cache (no artifacts needed — the
/// gather paths are host-side, the attention comparison runs the host
/// oracle). Asserts, on every run, that forking allocates zero pages and
/// that the sibling-cascade path reads strictly fewer gathered-KV bytes
/// than flat for >= 2 siblings with nonzero shared history.
fn bench_sampling(args: &Args, seed: u64) -> Result<()> {
    use lean_attention::bench_harness::{compare_sampling, SamplingCase};

    let smoke = args.has("smoke");
    let base = if smoke { SamplingCase::smoke() } else { SamplingCase::default_case() };
    let case = SamplingCase {
        siblings: args.usize("n", base.siblings),
        history: args.usize("history", base.history),
        suffix: args.usize("suffix", base.suffix),
        layers: args.usize("layers", base.layers),
        heads: args.usize("heads", base.heads),
        head_dim: args.usize("head-dim", base.head_dim),
        page_tokens: args.usize("page", base.page_tokens),
        tile: args.usize("tile", base.tile),
    };
    let iters = args.usize("iters", if smoke { 2 } else { 10 });
    println!(
        "sampling: {} siblings, history {} + suffix {} tokens, page {}, \
         {} layers x {} heads x d{}",
        case.siblings,
        case.history,
        case.suffix,
        case.page_tokens,
        case.layers,
        case.heads,
        case.head_dim
    );

    let c = compare_sampling(case, iters, seed)?;
    anyhow::ensure!(
        c.fork_fresh_pages == 0,
        "fork allocated {} pages; forking must be refcount-only",
        c.fork_fresh_pages
    );
    println!(
        "fork: 0 pages allocated at fork time, {} COW page clones during divergence",
        c.cow_copies
    );
    println!(
        "gather  flat:    {:>10.1} KiB/step, p50 {:>9.1}us",
        c.flat_gather_bytes as f64 / 1024.0,
        c.flat_us.p50
    );
    println!(
        "gather  cascade: {:>10.1} KiB/step, p50 {:>9.1}us  ({:.1}% bytes saved, {:.2}x)",
        c.shared_gather_bytes as f64 / 1024.0,
        c.shared_us.p50,
        c.bytes_saved_fraction() * 100.0,
        c.flat_us.p50 / c.shared_us.p50
    );
    println!(
        "attn    flat:    {:>10.1} KiB gathered KV, p50 {:>9.1}us",
        c.attention.flat_kv_bytes as f64 / 1024.0,
        c.attention.flat_us.p50
    );
    println!(
        "attn    cascade: {:>10.1} KiB gathered KV, p50 {:>9.1}us  ({:.1}% saved, max err {:.1e})",
        c.attention.cascade_kv_bytes as f64 / 1024.0,
        c.attention.cascade_us.p50,
        c.attention.bytes_saved_fraction() * 100.0,
        c.attention.max_err
    );
    if case.siblings >= 2 && case.history >= case.page_tokens {
        // Page-granular sharing: at least one full shared page dedups.
        anyhow::ensure!(
            c.shared_gather_bytes < c.flat_gather_bytes,
            "sibling-cascade decode must read strictly fewer gathered-KV bytes \
             than flat ({} vs {})",
            c.shared_gather_bytes,
            c.flat_gather_bytes
        );
    }
    if case.siblings >= 2 && case.history > 0 {
        anyhow::ensure!(
            c.attention.cascade_kv_bytes < c.attention.flat_kv_bytes,
            "cascade attention must gather strictly fewer KV bytes than flat"
        );
        anyhow::ensure!(
            c.attention.max_err < 1e-3,
            "flat and cascade attention diverged: {}",
            c.attention.max_err
        );
    }
    bench_report_out(&c.bench_report(seed, smoke), args)?;
    Ok(())
}

/// `leanattn bench --obs`: the observability plane measured end to end
/// (artifact-free — host cascade executor + synthetic spec model).
/// Runs a traced pseudo-serving loop, prints the per-phase timing table
/// and the serving SLO report, asserts the disabled tracer's overhead
/// bound on the cascade body, and writes the validated Chrome
/// trace-event export with `--trace-out`.
fn bench_obs(args: &Args, seed: u64) -> Result<()> {
    use lean_attention::bench_harness::{run_obs, ObsCase};

    let smoke = args.has("smoke");
    let base = if smoke { ObsCase::smoke() } else { ObsCase::default_case() };
    let case = ObsCase {
        requests: args.usize("requests", base.requests),
        batch: args.usize("batch", base.batch),
        prefix: args.usize("prefix", base.prefix as usize) as u32,
        suffix: args.usize("suffix", base.suffix as usize) as u32,
        heads: args.usize("heads", base.heads),
        head_dim: args.usize("head-dim", base.head_dim),
        tile: args.usize("tile", base.tile),
        slots: args.usize("slots", base.slots),
        spec_k: args.usize("k", base.spec_k),
        max_new: args.usize("max-new", base.max_new),
        vocab: args.usize("vocab", base.vocab),
        trace_capacity: args.usize("trace-capacity", base.trace_capacity),
        slo_ms: args.f64("slo-ms", base.slo_ms),
        overhead_iters: args.usize("iters", base.overhead_iters),
        overhead_limit: args.f64("overhead-limit", base.overhead_limit),
        heat_overhead_limit: args.f64("heat-overhead-limit", base.heat_overhead_limit),
    };
    println!(
        "obs: {} requests, cascade batch {} ({}+{} tokens, {} heads x d{}), \
         spec k={}, ring capacity {}",
        case.requests,
        case.batch,
        case.prefix,
        case.suffix,
        case.heads,
        case.head_dim,
        case.spec_k,
        case.trace_capacity
    );
    let r = run_obs(case, seed)?;
    println!("{}", r.render());
    if let Some(path) = args.flags.get("trace-out") {
        std::fs::write(path, r.chrome.to_string())
            .with_context(|| format!("write chrome trace to {path}"))?;
        println!(
            "chrome trace: {} events -> {path} (load in chrome://tracing or \
             ui.perfetto.dev)",
            r.events
        );
    }
    bench_report_out(&r.bench_report(seed, smoke), args)?;
    Ok(())
}

/// `leanattn bench --sparse`: dense vs sparse-selected decode on the
/// paged KV cache (host pseudo-decode loop — no artifacts needed).
/// Asserts, on every run: strictly fewer gathered-KV bytes at
/// sub-context budgets, needle-page recall 1.0 on the planted workload,
/// the sparse lean executor agreeing with the dense oracle restricted to
/// the selected pages, and bit-identical streams (tokens, logprobs, RNG
/// trajectory) once the budget covers the context.
fn bench_sparse(args: &Args, seed: u64) -> Result<()> {
    use lean_attention::bench_harness::{compare_sparse, SparseBenchCase};
    use lean_attention::sparse::SparsePolicy;

    let smoke = args.has("smoke");
    let base = if smoke {
        SparseBenchCase::smoke()
    } else {
        SparseBenchCase::default_case()
    };
    let policy = SparsePolicy {
        budget_pages: args.usize("kv-budget", base.policy.budget_pages),
        sink_pages: args.usize("sink-pages", base.policy.sink_pages),
        window_pages: args.usize("window-pages", base.policy.window_pages),
        dense_threshold_pages: args
            .usize("dense-threshold", base.policy.dense_threshold_pages),
    };
    policy.validate()?;
    let case = SparseBenchCase {
        seqs: args.usize("seqs", base.seqs),
        context: args.usize("context", base.context),
        steps: args.usize("steps", base.steps),
        heads: args.usize("heads", base.heads),
        head_dim: args.usize("head-dim", base.head_dim),
        page_tokens: args.usize("page", base.page_tokens),
        vocab: args.usize("vocab", base.vocab),
        tile: args.usize("tile", base.tile),
        policy,
        needle_page: args.usize("needle-page", base.needle_page),
    };
    let iters = args.usize("iters", if smoke { 2 } else { 10 });
    let pages = case.context.div_ceil(case.page_tokens);
    println!(
        "sparse: {} seqs x {} tokens ({pages} pages), budget {} \
         (sink {} + window {}), {} steps, {} heads x d{}",
        case.seqs,
        case.context,
        case.policy.budget_pages,
        case.policy.sink_pages,
        case.policy.window_pages,
        case.steps,
        case.heads,
        case.head_dim
    );

    let c = compare_sparse(case, iters, seed)?;
    println!(
        "gather  dense:  {:>10.1} KiB over the run, p50 {:>9.1}us/step",
        c.dense.gathered_bytes as f64 / 1024.0,
        c.dense_us.p50
    );
    println!(
        "gather  sparse: {:>10.1} KiB over the run, p50 {:>9.1}us/step  \
         ({:.1}% bytes saved, {:.2}x)",
        c.sparse.gathered_bytes as f64 / 1024.0,
        c.sparse_us.p50,
        c.bytes_saved_fraction() * 100.0,
        c.dense_us.p50 / c.sparse_us.p50
    );
    println!(
        "selection: {} steps scanned {}/{} pages, mean coverage {:.2}, \
         needle recall {:.2}",
        c.sparse.stats.selection_steps,
        c.sparse.stats.pages_scanned,
        c.sparse.stats.pages_total,
        c.sparse.stats.mean_coverage(),
        c.needle_recall()
    );
    println!(
        "executor: sparse lean vs dense-oracle-on-selected-pages \
         max err {:.2e}",
        c.exec_max_err
    );
    // The strict sub-context assertions only apply when selection can
    // actually prune: a budget below the context that the dense
    // threshold does not bypass.
    let prunable =
        case.policy.budget_pages < pages && pages > case.policy.dense_threshold_pages;
    if prunable {
        anyhow::ensure!(
            c.sparse.stats.lanes_scored > 0,
            "selection never engaged on a prunable shape"
        );
        anyhow::ensure!(
            c.sparse.gathered_bytes < c.dense.gathered_bytes,
            "sub-context budget must gather strictly fewer KV bytes \
             ({} vs {})",
            c.sparse.gathered_bytes,
            c.dense.gathered_bytes
        );
        anyhow::ensure!(
            (c.needle_recall() - 1.0).abs() < 1e-12,
            "selection dropped the needle page (recall {})",
            c.needle_recall()
        );
    } else {
        println!(
            "(budget or dense threshold covers the {pages}-page context — \
             sub-context assertions skipped)"
        );
    }
    anyhow::ensure!(
        c.exec_max_err < 1e-3,
        "sparse executor diverged from the restricted dense oracle: {}",
        c.exec_max_err
    );

    // Full-budget twin: the sparse machinery with a covering budget must
    // reproduce the dense stream bit-for-bit.
    let mut full = case;
    full.policy.budget_pages = full.pages_cap() + 1;
    full.policy.dense_threshold_pages = 0;
    let cf = compare_sparse(full, 1, seed)?;
    anyhow::ensure!(
        cf.streams_equal(),
        "covering budget must be bit-identical to dense decode"
    );
    anyhow::ensure!(
        cf.sparse.gathered_bytes == cf.dense.gathered_bytes,
        "covering budget must gather exactly the dense bytes"
    );
    println!(
        "full budget ({} pages): streams bit-identical to dense \
         (tokens, logprobs, RNG trajectory), {} KiB either way",
        full.policy.budget_pages,
        cf.dense.gathered_bytes / 1024
    );
    bench_report_out(&c.bench_report(seed, smoke), args)?;
    Ok(())
}

/// `leanattn bench --gqa`: grouped (GQA/MQA) vs dense-per-head decode
/// over identical random draws (no artifacts needed — both paths run the
/// stream-K planner + host executor). Asserts, on every run, that the
/// gathered-KV bytes per step shrink by ~`h/h_kv` at each swept grouping
/// and that both streams match the repeated-KV dense oracle.
fn bench_gqa(args: &Args, seed: u64) -> Result<()> {
    use lean_attention::bench_harness::{compare_gqa, GqaCase};

    let smoke = args.has("smoke");
    let base = if smoke { GqaCase::smoke() } else { GqaCase::default_case() };
    let heads = args.usize("heads", base.heads);
    let template = GqaCase {
        batch: args.usize("batch", base.batch),
        heads,
        kv_heads: base.kv_heads,
        ctx: args.usize("context", base.ctx),
        steps: args.usize("steps", base.steps),
        head_dim: args.usize("head-dim", base.head_dim),
        tile: args.usize("tile", base.tile),
        slots: args.usize("slots", base.slots),
    };
    let iters = args.usize("iters", if smoke { 2 } else { 10 });
    println!(
        "gqa: {} lanes x {} query heads, ctx {}+{} steps x tile {}, d{}",
        template.batch, heads, template.ctx, template.steps, template.tile, template.head_dim
    );

    // Sweep MQA (h_kv = 1), the h/4 grouping, and the ungrouped identity;
    // `--kv-heads N` pins a single grouping instead.
    let pinned = args.usize("kv-heads", 0);
    let sweep: Vec<usize> = if pinned > 0 {
        vec![pinned]
    } else {
        let mut s = vec![1, (heads / 4).max(1), heads];
        s.dedup();
        s.retain(|&kv| heads % kv == 0);
        s
    };
    let mut reported = None;
    for kv in sweep {
        let case = GqaCase { kv_heads: kv, ..template };
        let c = compare_gqa(case, iters, seed)?;
        let want = heads as f64 / kv as f64;
        println!(
            "kv_heads={kv:<3} group={:<3} grouped {:>9.1} KiB p50 {:>8.1}us  \
             vs dense {:>9.1} KiB p50 {:>8.1}us  bytes x{:.2} (expect {want:.2}), \
             max err {:.2e}",
            heads / kv,
            c.grouped_kv_bytes as f64 / 1024.0,
            c.grouped_us.p50,
            c.dense_kv_bytes as f64 / 1024.0,
            c.dense_us.p50,
            c.bytes_ratio(),
            c.grouped_err.max(c.dense_err),
        );
        anyhow::ensure!(
            (c.bytes_ratio() - want).abs() <= 0.1 * want,
            "gathered-KV byte ratio {:.3} not within 10% of h/h_kv = {want}",
            c.bytes_ratio()
        );
        anyhow::ensure!(
            c.grouped_err < 1e-3 && c.dense_err < 1e-3,
            "stream diverged from the repeated-KV dense oracle \
             (grouped {:.2e}, dense {:.2e})",
            c.grouped_err,
            c.dense_err
        );
        // The telemetry report covers the first swept grouping (MQA in
        // the default sweep, the pinned one under `--kv-heads`).
        if reported.is_none() {
            reported = Some(c);
        }
    }
    println!("all groupings exact vs the repeated-KV oracle; byte shrink ~= h/h_kv");
    if let Some(c) = reported {
        bench_report_out(&c.bench_report(seed, smoke), args)?;
    }
    Ok(())
}

/// `leanattn bench --balance`: the partition-balance bench (artifact-
/// free). Builds the cross-strategy PartitionReport on a ragged batch
/// and asserts stream-K's load-imbalance factor strictly below the
/// fixed-split baseline's; runs a traced host execution whose per-CTA
/// `gather`/`lean_exec` spans join the work ledger by tile index (fold
/// asserted exact against the direct-softmax oracle); and feeds a
/// stationary drift stream to the online detector, which must stay
/// quiet (zero breaches, rel-err EWMA within the limit).
fn bench_balance(args: &Args, seed: u64) -> Result<()> {
    use lean_attention::bench_harness::{run_balance, BalanceCase};

    let smoke = args.has("smoke");
    let base = if smoke { BalanceCase::smoke() } else { BalanceCase::default_case() };
    let case = BalanceCase {
        heads: args.usize("heads", base.heads),
        head_dim: args.usize("head-dim", base.head_dim),
        exec_slots: args.usize("slots", base.exec_slots),
        drift_iters: args.usize("iters", base.drift_iters),
        drift_limit: args.f64("drift-limit", base.drift_limit),
        ..base
    };
    println!(
        "balance: ragged batch of {} lanes x {} heads d{}; exec {} lanes x \
         {} heads d{} tile {} over {} slots; drift stream {} iters, \
         limit {}",
        case.ctx_lens.len(),
        case.heads,
        case.head_dim,
        case.exec_ctx_lens.len(),
        case.exec_heads,
        case.exec_head_dim,
        case.exec_tile,
        case.exec_slots,
        case.drift_iters,
        case.drift_limit
    );
    let c = run_balance(case, seed)?;
    println!("{}", c.render());
    bench_report_out(&c.bench_report(seed, smoke), args)?;
    Ok(())
}

/// `leanattn bench --spec`: speculative draft-and-verify on the host
/// pipeline (no artifacts needed). Asserts, on every run, that the
/// committed stream is bit-identical to the sequential sampler's and
/// that the repetitive workload commits more tokens than it runs verify
/// passes (>1 token/step).
fn bench_spec(args: &Args, seed: u64) -> Result<()> {
    use lean_attention::bench_harness::{compare_spec, SpecCase};
    use lean_attention::spec::DraftKind;

    let smoke = args.has("smoke");
    let base = if smoke { SpecCase::smoke() } else { SpecCase::default_case() };
    let draft = DraftKind::parse(&args.str("draft", "ngram"))
        .ok_or_else(|| anyhow::anyhow!("unknown --draft (ngram|model)"))?;
    let case = SpecCase {
        k: args.usize("k", base.k),
        max_new: args.usize("max-new", base.max_new),
        prompt_len: args.usize("prompt", base.prompt_len),
        period: args.usize("period", base.period),
        vocab: args.usize("vocab", base.vocab),
        draft,
        history: args.usize("history", base.history),
        heads: args.usize("heads", base.heads),
        head_dim: args.usize("head-dim", base.head_dim),
        layers: args.usize("layers", base.layers),
        page_tokens: args.usize("page", base.page_tokens),
        tile: args.usize("tile", base.tile),
    };
    let iters = args.usize("iters", if smoke { 2 } else { 10 });
    println!(
        "spec: k={} draft={} workload period {} over vocab {}, {} tokens; \
         verify ctx {} ({} heads x d{})",
        case.k,
        case.draft,
        case.period,
        case.vocab,
        case.max_new,
        case.history,
        case.heads,
        case.head_dim
    );

    let c = compare_spec(case, iters, seed)?;
    println!(
        "stream: bit-identical to the sequential sampler ({} tokens committed)",
        c.stats.committed
    );
    println!(
        "draft-and-verify: {} passes, {:.2} tokens/pass, {}/{} drafts accepted ({:.0}%)",
        c.stats.verify_passes,
        c.stats.tokens_per_pass(),
        c.stats.accepted,
        c.stats.drafted,
        c.stats.acceptance_rate() * 100.0
    );
    println!(
        "verify pass ({} query rows): {:>9.1} KiB gathered KV, p50 {:>9.1}us",
        case.k + 1,
        c.verify_kv_bytes as f64 / 1024.0,
        c.verify_us.p50
    );
    println!(
        "sequential ({} 1-row steps):  {:>9.1} KiB gathered KV, p50 {:>9.1}us  \
         ({:.1}% bytes saved, {:.2}x)",
        case.k + 1,
        c.sequential_kv_bytes as f64 / 1024.0,
        c.sequential_us.p50,
        c.bytes_saved_fraction() * 100.0,
        c.sequential_us.p50 / c.verify_us.p50
    );
    println!(
        "rollback: {} draft KV rows truncated per worst-case pass, {} COW clones, \
         sibling view intact, zero leaked pages",
        c.rolled_back_tokens, c.cow_copies
    );
    anyhow::ensure!(
        c.stats.committed > c.stats.verify_passes,
        "speculative decode must commit more than one token per verify pass on the \
         repetitive workload (committed {}, passes {})",
        c.stats.committed,
        c.stats.verify_passes
    );
    anyhow::ensure!(
        c.verify_kv_bytes < c.sequential_kv_bytes,
        "one verify pass must gather strictly fewer KV bytes than {} sequential steps",
        case.k + 1
    );
    bench_report_out(&c.bench_report(seed, smoke), args)?;
    Ok(())
}

fn plan_cmd(args: &Args) -> Result<()> {
    let batch = args.usize("batch", 1);
    let heads = args.usize("heads", 8);
    let ctx = args.usize("ctx", 65536);
    let slots = args.usize("slots", 216);
    let p = DecodeProblem::uniform(batch, heads, ctx, 64);
    let plan = build_plan(&p, Strategy::StreamK, slots);
    plan.validate(&p)?;
    let tiles = plan.tiles_per_cta();
    println!(
        "stream-K plan: {} CTAs over {} LeanTiles ({} tiles/CTA max), imbalance {:.4}",
        plan.grid(),
        p.total_tiles(),
        tiles.iter().max().unwrap(),
        plan.imbalance()
    );
    let multi: usize = plan.ctas.iter().filter(|c| c.segments.len() > 1).count();
    println!("CTAs crossing head boundaries: {multi}");
    let partials = plan.partials_per_group();
    println!(
        "partials per output tile: min {} max {}",
        partials.iter().min().unwrap(),
        partials.iter().max().unwrap()
    );
    Ok(())
}

fn figures_cmd(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    let all = which == "all";
    if all || which == "table1" {
        figures::table1().emit("table1");
    }
    if all || which == "fig01" {
        println!("{}", figures::fig01_schedule());
    }
    if all || which == "fig02" {
        figures::fig02_timeshare().emit("fig02");
    }
    if all || which == "fig03" {
        figures::fig03_occupancy().emit("fig03");
    }
    if all || which == "fig07" {
        for (i, t) in figures::fig07_a100().iter().enumerate() {
            t.emit(&format!("fig07{}", ['a', 'b', 'c'][i]));
        }
    }
    if all || which == "fig08" {
        for (i, t) in figures::fig08_h100().iter().enumerate() {
            t.emit(&format!("fig08{}", ['a', 'b', 'c'][i]));
        }
    }
    if all || which == "fig09" {
        for (i, t) in figures::fig09_multigpu().iter().enumerate() {
            t.emit(&format!("fig09{}", ['a', 'b', 'c', 'd'][i]));
        }
    }
    if all || which == "fig10" {
        figures::fig10_ragged().emit("fig10");
    }
    if all || which == "fig11" {
        figures::fig11_headdim128().emit("fig11");
    }
    if all || which == "fig12" {
        figures::fig12_e2e().emit("fig12");
    }
    if all || which == "fig13" {
        figures::fig13_energy().emit("fig13");
    }
    Ok(())
}

fn sweep_cmd(args: &Args) -> Result<()> {
    let samples = args.usize("samples", 1000);
    let arch = arch_by_name(&args.str("arch", "a100"))?;
    figures::sweep_aggregate(samples, &arch).emit("sweep");
    Ok(())
}

fn trace_cmd(args: &Args) -> Result<()> {
    use lean_attention::bench_harness::trace::{replay, TraceSpec};
    let runtime = Rc::new(Runtime::cpu()?);
    let manifest = Manifest::load(Manifest::default_dir())?;
    let mut engine = Engine::new(
        &runtime,
        &manifest,
        EngineConfig { model: args.str("model", "tiny"), ..Default::default() },
    )?;
    let spec = TraceSpec {
        requests: args.usize("requests", 16),
        mean_gap_steps: args.usize("gap", 3) as f64,
        poisson: !args.flags.contains_key("fixed"),
        prompt_min: 1,
        prompt_max: engine.prefill_bucket(),
        new_min: 1,
        new_max: 16,
        seed: args.usize("seed", 0) as u64,
    };
    let report = replay(&mut engine, &spec)?;
    println!("{}", report.render());
    println!("\n{}", engine.metrics.report());
    Ok(())
}
