//! Exact work accounting per stream-K tile — the attribution layer.
//!
//! Every subsystem that talks about "work" (the engine's gather
//! counters, the simulator's cost model, the bench harnesses' byte
//! columns) derives its numbers from **this one module**, computed
//! directly from the partitioner's own structures
//! ([`DecodeProblem`]/[`CascadeProblem`]/sparse selections). Modeled
//! and measured work therefore cannot drift by construction: the hot
//! path and the report both call the same function.
//!
//! The unit conventions match the host executor exactly:
//! - **bytes** are gathered K+V f32 bytes (`2 · tokens · head_dim · 4`),
//!   shared-prefix slices counted **once per task** (the cascade dedup);
//! - **flops** are the score+weighted-sum MACs of online softmax
//!   (`4 · tokens · head_dim` per query row over a KV slice);
//! - **tiles** are LeanTile-sized KV chunks actually visited (clamped
//!   to each lane's context — padding tiles are never counted);
//! - **rescale folds** are associative softmax merges
//!   (Alg 2 L24-39): one per `(tile, query row)` folded into an
//!   accumulator.

use std::ops::{Add, AddAssign};

use crate::partition::cascade::{CascadeProblem, PrefixGroup, SegKind};
use crate::partition::plan::{DecodeProblem, Plan};
use crate::runtime::attention_exec::CascadeTask;
use crate::sparse::selected_tokens;
use crate::util::json::Json;

/// Bytes per gathered KV element on the host executor (f32). The
/// simulator's [`crate::sim::TileCost`] models fp16 device streams
/// (2 bytes/element); calibrated coefficients are therefore in
/// host-f32-byte units.
pub const HOST_KV_ELEM_BYTES: u64 = 4;

/// Exact work of an attention workload, in executor units.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkAccounting {
    /// LeanTile-sized KV chunks visited (context-clamped).
    pub tiles: u64,
    /// Gathered K+V bytes (f32; shared slices counted once).
    pub gathered_kv_bytes: u64,
    /// Online-softmax MACs: `4 · tokens · head_dim` per query row.
    pub softmax_flops: u64,
    /// Associative rescale merges: one per `(tile, query row)`.
    pub rescale_folds: u64,
}

impl WorkAccounting {
    /// Work of one KV slice of `width` tokens serving `queries` rows.
    pub fn slice(width: usize, head_dim: usize, queries: usize) -> WorkAccounting {
        let (w, d, q) = (width as u64, head_dim as u64, queries as u64);
        WorkAccounting {
            tiles: 1,
            gathered_kv_bytes: 2 * w * d * HOST_KV_ELEM_BYTES,
            softmax_flops: 4 * w * d * q,
            rescale_folds: q,
        }
    }

    /// Whether any work is accounted at all.
    pub fn is_zero(&self) -> bool {
        *self == WorkAccounting::default()
    }

    /// Serialize for [`crate::obs::benchlog::BenchReport`] work sections.
    pub fn to_json(&self) -> Json {
        let mut o = std::collections::BTreeMap::new();
        o.insert("tiles".to_string(), Json::Num(self.tiles as f64));
        o.insert(
            "gathered_kv_bytes".to_string(),
            Json::Num(self.gathered_kv_bytes as f64),
        );
        o.insert("softmax_flops".to_string(), Json::Num(self.softmax_flops as f64));
        o.insert("rescale_folds".to_string(), Json::Num(self.rescale_folds as f64));
        Json::Obj(o)
    }

    /// Inverse of [`WorkAccounting::to_json`]; `None` on shape mismatch.
    pub fn from_json(j: &Json) -> Option<WorkAccounting> {
        Some(WorkAccounting {
            tiles: j.get("tiles")?.as_f64()? as u64,
            gathered_kv_bytes: j.get("gathered_kv_bytes")?.as_f64()? as u64,
            softmax_flops: j.get("softmax_flops")?.as_f64()? as u64,
            rescale_folds: j.get("rescale_folds")?.as_f64()? as u64,
        })
    }
}

impl Add for WorkAccounting {
    type Output = WorkAccounting;
    fn add(self, rhs: WorkAccounting) -> WorkAccounting {
        WorkAccounting {
            tiles: self.tiles + rhs.tiles,
            gathered_kv_bytes: self.gathered_kv_bytes + rhs.gathered_kv_bytes,
            softmax_flops: self.softmax_flops + rhs.softmax_flops,
            rescale_folds: self.rescale_folds + rhs.rescale_folds,
        }
    }
}

impl AddAssign for WorkAccounting {
    fn add_assign(&mut self, rhs: WorkAccounting) {
        *self = *self + rhs;
    }
}

/// Tile chunks covering `[0, ctx)` between token offsets
/// `[begin_tok, end_tok)`, each clamped to the context: the exact
/// chunks the host executors visit for that span. Public so the
/// partition-balance ledger (`obs::balance`) prices individual plan
/// segments with the same closed form the totals use — their sums are
/// bit-exact equal by construction.
pub fn span_work(
    ctx: usize,
    begin_tok: usize,
    end_tok: usize,
    tile: usize,
    head_dim: usize,
    queries: usize,
) -> WorkAccounting {
    let mut w = WorkAccounting::default();
    let end = end_tok.min(ctx);
    let mut tok = begin_tok;
    while tok < end {
        let width = tile.min(end - tok);
        w += WorkAccounting::slice(width, head_dim, queries);
        tok += width;
    }
    w
}

/// Exact work of a flat (or GQA-grouped) decode step: every KV group
/// streams its full context once, serving `group_size` query rows.
/// Plan-independent — any valid [`Plan`] over `p` performs exactly this
/// work ([`account_plan`] is property-tested equal).
pub fn account_decode_problem(p: &DecodeProblem) -> WorkAccounting {
    let mut w = WorkAccounting::default();
    for g in 0..p.groups() {
        let ctx = p.ctx_for_group(g);
        w += span_work(ctx, 0, ctx, p.tile, p.head_dim, p.group_size());
    }
    w
}

/// Exact work of a partitioned decode plan, summed over its CTA
/// segments (context-clamped, so padding tiles beyond a ragged lane's
/// length contribute nothing).
pub fn account_plan(p: &DecodeProblem, plan: &Plan) -> WorkAccounting {
    let mut w = WorkAccounting::default();
    for cta in &plan.ctas {
        for seg in &cta.segments {
            let g = seg.group as usize;
            let ctx = p.ctx_for_group(g);
            let begin = seg.tile_begin as usize * plan.tile;
            let end = (seg.tile_begin + seg.tile_count) as usize * plan.tile;
            w += span_work(ctx, begin, end, plan.tile, p.head_dim, p.group_size());
        }
    }
    w
}

/// Query rows served by one cascade segment lane: all members of a
/// shared-prefix group at once, one sequence otherwise — times the GQA
/// group size. Matches [`CascadeProblem::queries_of`] and the host
/// executor's row expansion exactly.
pub fn cascade_queries(p: &CascadeProblem, kind: SegKind) -> usize {
    let rows = match kind {
        SegKind::Shared { pg, .. } => p.prefix_groups[pg].members.len(),
        SegKind::Suffix { .. } => 1,
    };
    rows * p.group_size()
}

/// Exact work of a cascade decode step: each shared prefix streams once
/// per group serving all members, each suffix streams privately.
/// Plan-independent; [`account_cascade_tasks`] over any rolled task list
/// is property-tested equal.
pub fn account_cascade_problem(p: &CascadeProblem) -> WorkAccounting {
    let seg = p.segment_problem();
    let mut w = WorkAccounting::default();
    for g in 0..seg.groups() {
        let ctx = seg.ctx_for_group(g);
        let queries = cascade_queries(p, p.seg_kind(g));
        w += span_work(ctx, 0, ctx, seg.tile, seg.head_dim, queries);
    }
    w
}

/// Exact work of a rolled cascade task list — what
/// [`crate::runtime::attention_exec::roll_cascade_tasks`] hands the
/// executor. Each task is one context-clamped KV slice.
pub fn account_cascade_tasks(p: &CascadeProblem, tasks: &[CascadeTask]) -> WorkAccounting {
    let mut w = WorkAccounting::default();
    for t in tasks {
        w += WorkAccounting::slice(t.width, p.head_dim, cascade_queries(p, t.kind));
    }
    w
}

/// Gathered K+V bytes of a rolled cascade task list — the single
/// byte-accounting function behind
/// [`crate::runtime::attention_exec::rolled_kv_bytes`], the engine's
/// cascade projection, and every bench harness byte column.
pub fn tasks_kv_bytes(tasks: &[CascadeTask], head_dim: usize) -> u64 {
    tasks
        .iter()
        .map(|t| 2 * t.width as u64 * head_dim as u64 * HOST_KV_ELEM_BYTES)
        .sum()
}

/// Bytes a flat (dense) gather reads for per-lane context lengths, with
/// `token_bytes` = bytes per cached token across layers and kv heads
/// ([`crate::coordinator::PagedKvCache::token_bytes`]). Mirrors
/// `PagedKvCache::gather` exactly.
pub fn flat_gather_bytes(lens: &[u32], token_bytes: usize) -> u64 {
    lens.iter().map(|&l| l as u64 * token_bytes as u64).sum()
}

/// Bytes a shared-prefix gather reads: the flat bytes minus each
/// group's deduplicated prefix re-reads (`members − 1` spared copies of
/// `prefix_len` tokens). Mirrors `PagedKvCache::gather_shared`'s
/// `shared_bytes` exactly; group members index into `lens`.
pub fn shared_gather_bytes(lens: &[u32], groups: &[PrefixGroup], token_bytes: usize) -> u64 {
    let spared: u64 = groups
        .iter()
        .map(|g| (g.members.len() as u64 - 1) * g.prefix_len as u64 * token_bytes as u64)
        .sum();
    flat_gather_bytes(lens, token_bytes) - spared
}

/// Bytes a sparse (page-selected) gather reads for one lane: the
/// compacted token count of the selection over a `len`-token context.
/// Mirrors the engine's `gather_selected` accounting exactly.
pub fn selected_gather_bytes(
    len: usize,
    page_tokens: usize,
    selection: &[usize],
    token_bytes: usize,
) -> u64 {
    selected_tokens(len, page_tokens, selection) as u64 * token_bytes as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::cascade::build_cascade_plan;
    use crate::partition::plan::{build_plan, Strategy};
    use crate::runtime::attention_exec::{roll_cascade_tasks, rolled_kv_bytes};

    #[test]
    fn flat_accounting_matches_hand_count() {
        // 1 lane, 2 kv heads x group 2, ctx 70, tile 32 -> per group:
        // tiles 3 (32+32+6), bytes 2*70*8*4, flops 4*70*8*2, folds 3*2.
        let p = DecodeProblem::uniform(1, 4, 70, 8).with_tile(32).with_kv_heads(2);
        let w = account_decode_problem(&p);
        assert_eq!(w.tiles, 2 * 3);
        assert_eq!(w.gathered_kv_bytes, 2 * (2 * 70 * 8 * 4));
        assert_eq!(w.softmax_flops, 2 * (4 * 70 * 8 * 2));
        assert_eq!(w.rescale_folds, 2 * (3 * 2));
    }

    #[test]
    fn any_valid_plan_accounts_identically_to_its_problem() {
        let p = DecodeProblem::ragged(4, vec![70, 96, 33, 128], 16)
            .with_tile(32)
            .with_kv_heads(2);
        let want = account_decode_problem(&p);
        for strategy in [
            Strategy::Dense,
            Strategy::StreamK,
            Strategy::fixed_split_auto(&p, 24),
        ] {
            let plan = build_plan(&p, strategy, 24);
            plan.validate(&p).unwrap();
            assert_eq!(account_plan(&p, &plan), want, "{strategy:?}");
        }
    }

    #[test]
    fn rolled_tasks_account_identically_to_the_cascade_problem() {
        let p = CascadeProblem::new(
            4,
            vec![96, 96, 34, 70, 96],
            8,
            vec![
                PrefixGroup { prefix_len: 64, members: vec![0, 1] },
                PrefixGroup { prefix_len: 32, members: vec![2, 4] },
            ],
        )
        .unwrap()
        .with_tile(32)
        .with_kv_heads(2);
        let cplan = build_cascade_plan(&p, 12);
        let tasks = roll_cascade_tasks(&p, &cplan);
        let from_tasks = account_cascade_tasks(&p, &tasks);
        assert_eq!(from_tasks, account_cascade_problem(&p));
        assert_eq!(from_tasks.gathered_kv_bytes, tasks_kv_bytes(&tasks, p.head_dim));
        assert_eq!(
            from_tasks.gathered_kv_bytes,
            rolled_kv_bytes(&tasks, p.head_dim) as u64
        );
    }

    #[test]
    fn shared_gather_dedups_each_groups_prefix_rereads() {
        let token = 64;
        let lens = [25, 25, 25];
        let groups = [PrefixGroup { prefix_len: 16, members: vec![0, 1, 2] }];
        assert_eq!(flat_gather_bytes(&lens, token), 3 * 25 * 64);
        // Shared: the 16-token prefix streams once, three 9-token tails.
        assert_eq!(shared_gather_bytes(&lens, &groups, token), (16 + 3 * 9) * 64);
    }

    #[test]
    fn work_accounting_round_trips_through_json() {
        let w = WorkAccounting {
            tiles: 7,
            gathered_kv_bytes: 123_456,
            softmax_flops: 9_999_999,
            rescale_folds: 42,
        };
        assert_eq!(WorkAccounting::from_json(&w.to_json()), Some(w));
        assert_eq!(WorkAccounting::from_json(&Json::Null), None);
    }
}
