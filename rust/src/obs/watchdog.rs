//! Engine health watchdog: a step-progress heartbeat.
//!
//! The engine beats the watchdog once per step with its monotonic
//! progress counter (tokens generated + prefill calls). A configured
//! stall threshold — consecutive steps without progress — marks the
//! engine unhealthy, fires a [`StallEvent`] the engine hands to the
//! flight recorder, and keeps counting so every further whole threshold
//! of stalled steps re-fires. Health is exported as the `engine_healthy`
//! gauge in the engine snapshot.
//!
//! Step-counted (not wall-clock) stall detection keeps the watchdog
//! deterministic and testable; today's synchronous engine cannot stall
//! by construction, but ROADMAP open item 1's async server steps even
//! when lanes are blocked — exactly the state this catches.

/// One stall detection: the heartbeat saw `stalled_steps` consecutive
/// steps without progress.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StallEvent {
    pub stalled_steps: u64,
    /// The progress value the engine has been stuck at.
    pub progress: u64,
}

/// Step-progress watchdog. `stall_steps == 0` disables it (always
/// healthy, never fires).
#[derive(Clone, Debug)]
pub struct Watchdog {
    stall_steps: u64,
    last_progress: u64,
    stalled_for: u64,
    stalls: u64,
    healthy: bool,
}

impl Watchdog {
    pub fn new(stall_steps: u64) -> Watchdog {
        Watchdog {
            stall_steps,
            last_progress: 0,
            stalled_for: 0,
            stalls: 0,
            healthy: true,
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.stall_steps > 0
    }

    /// The configured stall threshold, in steps.
    pub fn stall_steps(&self) -> u64 {
        self.stall_steps
    }

    /// One heartbeat: `progress` is any monotonic counter that moves
    /// when the engine does useful work. Returns the stall event when
    /// the threshold is crossed (and again at every further multiple).
    pub fn beat(&mut self, progress: u64) -> Option<StallEvent> {
        if !self.is_enabled() {
            return None;
        }
        if progress != self.last_progress {
            self.last_progress = progress;
            self.stalled_for = 0;
            self.healthy = true;
            return None;
        }
        self.stalled_for += 1;
        if self.stalled_for % self.stall_steps == 0 {
            self.healthy = false;
            self.stalls += 1;
            return Some(StallEvent { stalled_steps: self.stalled_for, progress });
        }
        None
    }

    /// `false` from the first fired stall until progress resumes.
    pub fn healthy(&self) -> bool {
        self.healthy
    }

    /// Stall events fired so far (monotonic).
    pub fn stalls(&self) -> u64 {
        self.stalls
    }

    /// Consecutive progress-free steps at the last beat.
    pub fn stalled_for(&self) -> u64 {
        self.stalled_for
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_watchdog_never_fires() {
        let mut w = Watchdog::new(0);
        assert!(!w.is_enabled());
        for _ in 0..100 {
            assert_eq!(w.beat(7), None);
        }
        assert!(w.healthy());
        assert_eq!(w.stalls(), 0);
    }

    #[test]
    fn progress_keeps_the_watchdog_quiet() {
        let mut w = Watchdog::new(3);
        for p in 1..50u64 {
            assert_eq!(w.beat(p), None);
        }
        assert!(w.healthy());
        assert_eq!(w.stalls(), 0);
    }

    #[test]
    fn stall_fires_at_the_threshold_and_refires_each_multiple() {
        let mut w = Watchdog::new(3);
        assert_eq!(w.beat(5), None); // progress moves to 5
        assert_eq!(w.beat(5), None); // stalled 1
        assert_eq!(w.beat(5), None); // stalled 2
        let e = w.beat(5).expect("stalled 3 -> fire");
        assert_eq!(e, StallEvent { stalled_steps: 3, progress: 5 });
        assert!(!w.healthy());
        assert_eq!(w.beat(5), None); // 4
        assert_eq!(w.beat(5), None); // 5
        assert!(w.beat(5).is_some(), "re-fires at 6");
        assert_eq!(w.stalls(), 2);

        // Progress resumes: health restored, counter rearmed.
        assert_eq!(w.beat(6), None);
        assert!(w.healthy());
        assert_eq!(w.stalled_for(), 0);
    }
}
