//! Versioned machine-readable bench telemetry.
//!
//! Every bench harness (`bench --cascade-exec/--sampling/--spec/
//! --sparse/--obs/--gqa`) emits one [`BenchReport`] — written as JSON
//! by `--json-out PATH` — and the regression gate
//! (`bench ... --check-against BENCH_baseline.json`) compares a fresh
//! run against a committed baseline so the perf trajectory accumulates
//! in CI instead of scrolling away in logs.
//!
//! A report has four sections with distinct gate semantics:
//!
//! - **counts** — machine-independent integers (gathered bytes, pages,
//!   committed tokens): gated **bit-exactly** against the baseline.
//! - **work** — [`WorkAccounting`] sections from [`super::attrib`]:
//!   also exact integers, gated bit-exactly. These are the sections
//!   the same-seed determinism assertions pin.
//! - **measures** — deterministic-but-float ratios (bytes saved,
//!   acceptance rate): gated within a relative tolerance.
//! - **info** — wall-clock timings and float error maxima: recorded
//!   for trend analysis, never gated (machine-dependent).

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Result};

use crate::util::json::Json;

use super::attrib::WorkAccounting;

/// Schema version stamped into every report; bump on breaking change.
pub const BENCH_SCHEMA_VERSION: u64 = 1;

/// One bench run's machine-readable telemetry.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BenchReport {
    /// Harness name (`cascade-exec`, `sampling`, `spec`, `sparse`,
    /// `obs`, `gqa`) — the key in the baseline file.
    pub name: String,
    /// RNG seed the run used (baselines only compare like seeds).
    pub seed: u64,
    /// Whether the run used the `--smoke` shape.
    pub smoke: bool,
    /// Exact integer metrics, gated bit-exactly.
    pub counts: BTreeMap<String, u64>,
    /// Float metrics gated within a relative tolerance.
    pub measures: BTreeMap<String, f64>,
    /// Ungated context (timings in µs, max float errors).
    pub info: BTreeMap<String, f64>,
    /// Exact work-accounting sections, gated bit-exactly.
    pub work: BTreeMap<String, WorkAccounting>,
}

impl BenchReport {
    pub fn new(name: &str, seed: u64, smoke: bool) -> BenchReport {
        BenchReport { name: name.to_string(), seed, smoke, ..Default::default() }
    }

    pub fn count(&mut self, key: &str, v: u64) {
        self.counts.insert(key.to_string(), v);
    }

    pub fn measure(&mut self, key: &str, v: f64) {
        self.measures.insert(key.to_string(), v);
    }

    pub fn info(&mut self, key: &str, v: f64) {
        self.info.insert(key.to_string(), v);
    }

    pub fn work(&mut self, key: &str, w: WorkAccounting) {
        self.work.insert(key.to_string(), w);
    }

    pub fn to_json(&self) -> Json {
        let num_map = |m: &BTreeMap<String, f64>| {
            Json::Obj(m.iter().map(|(k, &v)| (k.clone(), Json::Num(v))).collect())
        };
        let mut o = BTreeMap::new();
        o.insert("version".to_string(), Json::Num(BENCH_SCHEMA_VERSION as f64));
        o.insert("name".to_string(), Json::Str(self.name.clone()));
        o.insert("seed".to_string(), Json::Num(self.seed as f64));
        o.insert("smoke".to_string(), Json::Bool(self.smoke));
        o.insert(
            "counts".to_string(),
            Json::Obj(
                self.counts
                    .iter()
                    .map(|(k, &v)| (k.clone(), Json::Num(v as f64)))
                    .collect(),
            ),
        );
        o.insert("measures".to_string(), num_map(&self.measures));
        o.insert("info".to_string(), num_map(&self.info));
        o.insert(
            "work".to_string(),
            Json::Obj(self.work.iter().map(|(k, w)| (k.clone(), w.to_json())).collect()),
        );
        Json::Obj(o)
    }

    /// Parse a report, validating against the schema.
    pub fn from_json(j: &Json) -> Result<BenchReport> {
        validate_bench_report(j)?;
        let sec = |key: &str| j.at(key).as_obj().cloned().unwrap_or_default();
        Ok(BenchReport {
            name: j.str_at("name").to_string(),
            seed: j.at("seed").as_f64().unwrap_or(0.0) as u64,
            smoke: matches!(j.at("smoke"), Json::Bool(true)),
            counts: sec("counts")
                .iter()
                .map(|(k, v)| (k.clone(), v.as_f64().unwrap_or(0.0) as u64))
                .collect(),
            measures: sec("measures")
                .iter()
                .map(|(k, v)| (k.clone(), v.as_f64().unwrap_or(0.0)))
                .collect(),
            info: sec("info")
                .iter()
                .map(|(k, v)| (k.clone(), v.as_f64().unwrap_or(0.0)))
                .collect(),
            work: sec("work")
                .iter()
                .map(|(k, v)| (k.clone(), WorkAccounting::from_json(v).expect("validated")))
                .collect(),
        })
    }
}

/// Validate a JSON value against the [`BenchReport`] schema — the check
/// every `--json-out` emission runs on itself before writing.
pub fn validate_bench_report(j: &Json) -> Result<()> {
    ensure!(j.as_obj().is_some(), "bench report must be a JSON object");
    let version = j
        .get("version")
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow::anyhow!("bench report missing numeric version"))?;
    ensure!(
        version as u64 == BENCH_SCHEMA_VERSION,
        "bench report version {version} != supported {BENCH_SCHEMA_VERSION}"
    );
    ensure!(
        j.get("name").and_then(Json::as_str).is_some_and(|n| !n.is_empty()),
        "bench report missing name"
    );
    ensure!(
        j.get("seed").and_then(Json::as_f64).is_some(),
        "bench report missing numeric seed"
    );
    ensure!(
        matches!(j.get("smoke"), Some(Json::Bool(_))),
        "bench report missing boolean smoke flag"
    );
    for section in ["counts", "measures", "info", "work"] {
        let obj = j
            .get(section)
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("bench report missing {section} object"))?;
        for (key, v) in obj {
            if section == "work" {
                ensure!(
                    WorkAccounting::from_json(v).is_some(),
                    "work section {key:?} is not a WorkAccounting object"
                );
            } else {
                let n = v
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("{section}.{key} not a number"))?;
                ensure!(n.is_finite(), "{section}.{key} is not finite");
                if section == "counts" {
                    ensure!(
                        n >= 0.0 && n.fract() == 0.0,
                        "counts.{key} = {n} is not a non-negative integer"
                    );
                }
            }
        }
    }
    Ok(())
}

/// Parse a committed baseline file (`{"version": 1, "reports":
/// {name: report, ...}}`) into its per-harness reports.
pub fn parse_baseline(text: &str) -> Result<BTreeMap<String, BenchReport>> {
    let j = Json::parse(text).map_err(|e| anyhow::anyhow!("baseline parse: {e}"))?;
    let version = j
        .get("version")
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow::anyhow!("baseline missing numeric version"))?;
    ensure!(
        version as u64 == BENCH_SCHEMA_VERSION,
        "baseline version {version} != supported {BENCH_SCHEMA_VERSION}"
    );
    let reports = j
        .get("reports")
        .and_then(Json::as_obj)
        .ok_or_else(|| anyhow::anyhow!("baseline missing reports object"))?;
    let mut out = BTreeMap::new();
    for (name, rj) in reports {
        let r = BenchReport::from_json(rj)
            .map_err(|e| anyhow::anyhow!("baseline report {name:?}: {e}"))?;
        ensure!(r.name == *name, "baseline key {name:?} names report {:?}", r.name);
        out.insert(name.clone(), r);
    }
    Ok(out)
}

/// Serialize baseline reports back into the committed-file format.
pub fn baseline_to_json(reports: &BTreeMap<String, BenchReport>) -> Json {
    let mut o = BTreeMap::new();
    o.insert("version".to_string(), Json::Num(BENCH_SCHEMA_VERSION as f64));
    o.insert(
        "reports".to_string(),
        Json::Obj(reports.iter().map(|(k, r)| (k.clone(), r.to_json())).collect()),
    );
    Json::Obj(o)
}

/// Compare a fresh run against its baseline. Returns the list of gate
/// violations (empty = pass): counts and work sections must match
/// bit-exactly, measures within relative tolerance `tol`
/// (`|a − b| ≤ tol · max(|a|, |b|)`), info is never gated. Metrics the
/// baseline lacks are allowed (schema growth); metrics that disappeared
/// are violations.
pub fn compare_reports(current: &BenchReport, baseline: &BenchReport, tol: f64) -> Vec<String> {
    let mut v = Vec::new();
    if current.name != baseline.name {
        v.push(format!(
            "harness mismatch: ran {:?}, baseline is {:?}",
            current.name, baseline.name
        ));
        return v;
    }
    if current.smoke != baseline.smoke {
        v.push(format!(
            "shape mismatch: run smoke={}, baseline smoke={}",
            current.smoke, baseline.smoke
        ));
        return v;
    }
    if current.seed != baseline.seed {
        v.push(format!(
            "seed mismatch: run seed={}, baseline seed={} (counts only \
             compare across identical seeds)",
            current.seed, baseline.seed
        ));
        return v;
    }
    for (key, &want) in &baseline.counts {
        match current.counts.get(key) {
            None => v.push(format!("counts.{key} disappeared (baseline {want})")),
            Some(&got) if got != want => {
                v.push(format!("counts.{key}: {got} != baseline {want}"))
            }
            _ => {}
        }
    }
    for (key, want) in &baseline.work {
        match current.work.get(key) {
            None => v.push(format!("work.{key} section disappeared")),
            Some(got) if got != want => {
                v.push(format!("work.{key}: {got:?} != baseline {want:?}"))
            }
            _ => {}
        }
    }
    for (key, &want) in &baseline.measures {
        match current.measures.get(key) {
            None => v.push(format!("measures.{key} disappeared (baseline {want})")),
            Some(&got) => {
                let scale = got.abs().max(want.abs());
                if (got - want).abs() > tol * scale + 1e-12 {
                    v.push(format!(
                        "measures.{key}: {got} drifted beyond {:.0}% of baseline {want}",
                        tol * 100.0
                    ));
                }
            }
        }
    }
    v
}

/// Round-trip helper for `--check-against`: parse the baseline file,
/// pick this harness's entry, and gate. Errors on a missing entry.
pub fn check_against(current: &BenchReport, baseline_text: &str, tol: f64) -> Result<()> {
    let baselines = parse_baseline(baseline_text)?;
    let Some(base) = baselines.get(&current.name) else {
        bail!(
            "baseline has no {:?} entry (has: {})",
            current.name,
            baselines.keys().cloned().collect::<Vec<_>>().join(", ")
        );
    };
    let violations = compare_reports(current, base, tol);
    ensure!(
        violations.is_empty(),
        "bench regression gate failed for {:?}:\n  {}",
        current.name,
        violations.join("\n  ")
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> BenchReport {
        let mut r = BenchReport::new("gqa", 7, true);
        r.count("grouped_kv_bytes", 12_288);
        r.count("dense_kv_bytes", 49_152);
        r.measure("bytes_ratio", 4.0);
        r.info("grouped_us_p50", 123.4);
        r.work(
            "grouped",
            WorkAccounting {
                tiles: 6,
                gathered_kv_bytes: 12_288,
                softmax_flops: 98_304,
                rescale_folds: 24,
            },
        );
        r
    }

    #[test]
    fn report_round_trips_and_validates() {
        let r = sample();
        let j = r.to_json();
        validate_bench_report(&j).expect("emitted report is schema-valid");
        let text = j.to_string();
        let back = BenchReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn validator_rejects_malformed_reports() {
        assert!(validate_bench_report(&Json::Null).is_err());
        let mut j = sample().to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("version".to_string(), Json::Num(99.0));
        }
        assert!(validate_bench_report(&j).is_err(), "wrong version");
        let bad_count =
            Json::parse(r#"{"version":1,"name":"x","seed":0,"smoke":false,"counts":{"a":1.5},"measures":{},"info":{},"work":{}}"#)
                .unwrap();
        assert!(validate_bench_report(&bad_count).is_err(), "fractional count");
    }

    #[test]
    fn gate_passes_identical_and_flags_exact_drift() {
        let base = sample();
        assert!(compare_reports(&sample(), &base, 0.25).is_empty());

        let mut drifted = sample();
        drifted.count("grouped_kv_bytes", 12_289);
        let v = compare_reports(&drifted, &base, 0.25);
        assert!(v.iter().any(|s| s.contains("counts.grouped_kv_bytes")), "{v:?}");

        let mut work_drift = sample();
        work_drift.work.get_mut("grouped").unwrap().tiles += 1;
        let v = compare_reports(&work_drift, &base, 0.25);
        assert!(v.iter().any(|s| s.contains("work.grouped")), "{v:?}");
    }

    #[test]
    fn gate_tolerates_measures_within_relative_tolerance() {
        let base = sample();
        let mut near = sample();
        near.measure("bytes_ratio", 4.2);
        assert!(compare_reports(&near, &base, 0.1).is_empty());
        let mut far = sample();
        far.measure("bytes_ratio", 5.0);
        assert!(!compare_reports(&far, &base, 0.1).is_empty());
        // Info is never gated.
        let mut slow = sample();
        slow.info("grouped_us_p50", 99_999.0);
        assert!(compare_reports(&slow, &base, 0.1).is_empty());
    }

    #[test]
    fn gate_refuses_cross_shape_and_cross_seed_comparison() {
        let base = sample();
        let mut full = sample();
        full.smoke = false;
        assert!(!compare_reports(&full, &base, 0.25).is_empty());
        let mut other_seed = sample();
        other_seed.seed = 8;
        assert!(!compare_reports(&other_seed, &base, 0.25).is_empty());
    }

    #[test]
    fn baseline_file_round_trips() {
        let mut reports = BTreeMap::new();
        reports.insert("gqa".to_string(), sample());
        let text = baseline_to_json(&reports).to_string();
        let back = parse_baseline(&text).unwrap();
        assert_eq!(back, reports);
        check_against(&sample(), &text, 0.25).expect("self-comparison passes");
        let mut other = sample();
        other.name = "spec".to_string();
        assert!(check_against(&other, &text, 0.25).is_err(), "missing entry");
    }
}
