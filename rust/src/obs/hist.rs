//! Log-bucketed latency histograms (HDR-style, fixed memory).
//!
//! The serving metrics used to push every per-step latency into an
//! unbounded `Vec<f64>` and sort it on demand — O(steps) memory on a
//! long-running engine. [`LogHistogram`] replaces that on the hot paths:
//! geometric buckets at [`SUB_BUCKETS_PER_OCTAVE`] per power of two give
//! a bounded multiplicative resolution ([`LogHistogram::growth`], ~9%),
//! so any quantile estimate is within **one bucket width** of the exact
//! sample quantile — the bound `rust/tests/obs_props.rs` pins against
//! random workloads. `count`/`sum`/`min`/`max` stay exact, so means and
//! throughput derived from the histogram are not approximations.

/// Geometric sub-buckets per factor-of-two of value range.
pub const SUB_BUCKETS_PER_OCTAVE: usize = 8;

/// log2 of the smallest distinguishable value (smaller values clamp into
/// bucket 0). 2^-10 ≈ 1e-3 — well under a nanosecond in microseconds.
const MIN_LOG2: f64 = -10.0;

/// Octaves covered above [`MIN_LOG2`]: up to 2^44 ≈ 1.8e13, weeks in
/// microseconds. Larger values clamp into the last bucket.
const OCTAVES: usize = 54;

const NBUCKETS: usize = OCTAVES * SUB_BUCKETS_PER_OCTAVE;

/// A fixed-capacity log-bucketed histogram over positive `f64` samples.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LogHistogram {
    /// Lazily allocated on first record so an empty histogram (and a
    /// disabled tracer full of them) costs nothing.
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    sum_sq: f64,
    min: f64,
    max: f64,
}

/// Bucket ordinal a value lands in (monotonic in the value).
fn bucket_of(v: f64) -> usize {
    if v <= 0.0 || v.log2() <= MIN_LOG2 {
        return 0;
    }
    let idx = ((v.log2() - MIN_LOG2) * SUB_BUCKETS_PER_OCTAVE as f64) as usize;
    idx.min(NBUCKETS - 1)
}

/// Lower bound of bucket `i` — the value the quantile walk reports.
fn bucket_lo(i: usize) -> f64 {
    2f64.powf(MIN_LOG2 + i as f64 / SUB_BUCKETS_PER_OCTAVE as f64)
}

impl LogHistogram {
    pub fn new() -> LogHistogram {
        LogHistogram::default()
    }

    /// Multiplicative width of one bucket: any quantile estimate `h` of
    /// an exact quantile `e` satisfies `h <= e < h * growth()`.
    pub fn growth() -> f64 {
        2f64.powf(1.0 / SUB_BUCKETS_PER_OCTAVE as f64)
    }

    /// Record one sample. Non-positive values clamp into the lowest
    /// bucket (the exact `min`/`sum` still see the raw value).
    pub fn record(&mut self, v: f64) {
        if self.buckets.is_empty() {
            self.buckets = vec![0; NBUCKETS];
        }
        self.buckets[bucket_of(v)] += 1;
        if self.count == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.count += 1;
        self.sum += v;
        self.sum_sq += v * v;
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact sum of every recorded sample.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Exact mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Population standard deviation from the exact running moments
    /// (0 when empty).
    pub fn stddev(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let mean = self.mean();
        (self.sum_sq / self.count as f64 - mean * mean).max(0.0).sqrt()
    }

    /// Exact minimum recorded sample (0 when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Exact maximum recorded sample (0 when empty).
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Nearest-rank quantile estimate: the lower bound of the bucket
    /// holding the rank-`ceil(q·n)` sample, clamped into the exact
    /// `[min, max]`. Within one bucket width of the exact quantile.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return bucket_lo(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Approximate fraction of samples `<= v` (linear within the bucket
    /// `v` falls in). 1.0 when empty — a vacuous SLO holds.
    pub fn fraction_le(&self, v: f64) -> f64 {
        if self.count == 0 {
            return 1.0;
        }
        if v >= self.max {
            return 1.0;
        }
        if v < self.min {
            return 0.0;
        }
        let b = bucket_of(v);
        let mut below = 0u64;
        for &c in &self.buckets[..b] {
            below += c;
        }
        let lo = bucket_lo(b);
        let hi = bucket_lo(b + 1);
        let frac = ((v - lo) / (hi - lo)).clamp(0.0, 1.0);
        ((below as f64 + frac * self.buckets[b] as f64) / self.count as f64).min(1.0)
    }

    /// Fold another histogram in (bucket-wise; exact stats combine).
    pub fn merge(&mut self, other: &LogHistogram) {
        if other.count == 0 {
            return;
        }
        if self.buckets.is_empty() {
            self.buckets = vec![0; NBUCKETS];
        }
        for (b, &o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
        if self.count == 0 {
            self.min = other.min;
            self.max = other.max;
        } else {
            self.min = self.min.min(other.min);
            self.max = self.max.max(other.max);
        }
        self.count += other.count;
        self.sum += other.sum;
        self.sum_sq += other.sum_sq;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_is_safe() {
        let h = LogHistogram::new();
        assert!(h.is_empty());
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.fraction_le(1.0), 1.0);
    }

    #[test]
    fn exact_stats_are_exact() {
        let mut h = LogHistogram::new();
        for v in [3.0, 1.0, 2.0, 10.0] {
            h.record(v);
        }
        assert_eq!(h.count(), 4);
        assert_eq!(h.sum(), 16.0);
        assert_eq!(h.mean(), 4.0);
        assert_eq!(h.min(), 1.0);
        assert_eq!(h.max(), 10.0);
        // Population stddev of [3,1,2,10]: sqrt(114/4 - 16) = sqrt(12.5).
        assert!((h.stddev() - 12.5f64.sqrt()).abs() < 1e-12);
        assert_eq!(LogHistogram::new().stddev(), 0.0);
    }

    #[test]
    fn constant_distribution_quantiles_are_exact() {
        let mut h = LogHistogram::new();
        for _ in 0..100 {
            h.record(1000.0);
        }
        // min/max clamping recovers the exact value.
        assert_eq!(h.quantile(0.5), 1000.0);
        assert_eq!(h.quantile(0.999), 1000.0);
    }

    #[test]
    fn quantile_within_one_bucket_of_exact() {
        let samples: Vec<f64> = (1..=1000).map(|i| i as f64 * 3.7).collect();
        let mut h = LogHistogram::new();
        for &s in &samples {
            h.record(s);
        }
        let g = LogHistogram::growth();
        for q in [0.5, 0.9, 0.95, 0.99, 0.999] {
            let rank = ((q * samples.len() as f64).ceil() as usize).max(1);
            let exact = samples[rank - 1];
            let est = h.quantile(q);
            assert!(
                est <= exact * (1.0 + 1e-9) && exact < est * g * (1.0 + 1e-9),
                "q={q}: est {est} vs exact {exact}"
            );
        }
    }

    #[test]
    fn quantiles_are_monotone() {
        let mut h = LogHistogram::new();
        let mut x = 1.0f64;
        for _ in 0..500 {
            h.record(x);
            x *= 1.02;
        }
        assert!(h.quantile(0.5) <= h.quantile(0.95));
        assert!(h.quantile(0.95) <= h.quantile(0.99));
        assert!(h.quantile(0.99) <= h.quantile(0.999));
    }

    #[test]
    fn fraction_le_brackets_the_median() {
        let mut h = LogHistogram::new();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.fraction_le(0.5), 0.0);
        assert_eq!(h.fraction_le(100.0), 1.0);
        let f = h.fraction_le(50.0);
        assert!((0.40..=0.60).contains(&f), "median fraction {f}");
    }

    #[test]
    fn merge_matches_recording_everything_into_one() {
        let (mut a, mut b, mut all) =
            (LogHistogram::new(), LogHistogram::new(), LogHistogram::new());
        for i in 1..=50 {
            a.record(i as f64);
            all.record(i as f64);
        }
        for i in 51..=120 {
            b.record(i as f64 * 2.5);
            all.record(i as f64 * 2.5);
        }
        a.merge(&b);
        assert_eq!(a, all);
        // Merging into an empty histogram copies the other side.
        let mut e = LogHistogram::new();
        e.merge(&all);
        assert_eq!(e.count(), all.count());
        assert_eq!(e.min(), all.min());
        assert_eq!(e.quantile(0.95), all.quantile(0.95));
    }

    #[test]
    fn non_positive_values_clamp_into_bucket_zero() {
        let mut h = LogHistogram::new();
        h.record(0.0);
        h.record(-3.0);
        h.record(5.0);
        assert_eq!(h.count(), 3);
        assert_eq!(h.min(), -3.0);
        assert!(h.quantile(0.1) <= 5.0);
    }
}
