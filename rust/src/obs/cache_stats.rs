//! KV-cache introspection: page-heat telemetry and the versioned cache
//! report.
//!
//! The [`HeatTracker`] is the incremental half: per-page touch counters
//! (gather / append / select), a last-touch stamp on a logical tick
//! clock, and COW-clone accounting, maintained by
//! [`crate::coordinator::PagedKvCache`] at its existing single gather /
//! append / select / alloc sites. Touch recording is interior-mutable
//! (`Cell`) because every gather path takes `&self`, and a disabled
//! tracker costs one branch per call site — the bound
//! `leanattn bench --obs` measures and asserts (< 2% on the gather hot
//! path, like the tracer).
//!
//! The [`CacheReport`] is the from-scratch half: every aggregate — heat
//! histogram, top-k hottest page runs, refcount distribution, pool
//! fragmentation, radix-index shape — is recomputed at report time from
//! the per-page state, so the report can be property-tested bit-exact
//! against an independent recompute over the same accessors.
//! [`validate_cache_report`] is the schema check `leanattn inspect`
//! runs on its own output and the flight recorder runs on bundle
//! read-back.

use std::cell::Cell;
use std::collections::BTreeMap;

use anyhow::{bail, ensure, Result};

use crate::util::json::Json;

/// Version stamp of [`CacheReport::to_json`].
pub const CACHE_REPORT_VERSION: u64 = 1;

/// The page-touch taxonomy: which data-plane operation hit the page.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TouchKind {
    /// Page materialized out of the cache for attention (flat, shared or
    /// selected gather — one touch per page per materialization).
    Gather,
    /// A token row written into the page.
    Append,
    /// Page chosen by sparse page selection.
    Select,
}

/// Incremental per-page heat state. One instance lives inside the paged
/// cache; all mutation goes through `&self` (`Cell`), matching the
/// gather paths' borrows.
#[derive(Debug)]
pub struct HeatTracker {
    /// `false` = record nothing (the bench's comparison baseline).
    enabled: bool,
    /// Logical tick clock — advanced once per engine step (or churn
    /// step), the unit "age since last touch" is measured in.
    clock: Cell<u64>,
    gather: Vec<Cell<u64>>,
    append: Vec<Cell<u64>>,
    select: Vec<Cell<u64>>,
    last_touch: Vec<Cell<u64>>,
    gather_total: Cell<u64>,
    append_total: Cell<u64>,
    select_total: Cell<u64>,
    cow_clones: Cell<u64>,
    resets: Cell<u64>,
}

impl HeatTracker {
    /// Tracking state for `pages` physical pages.
    pub fn enabled(pages: usize) -> HeatTracker {
        HeatTracker {
            enabled: true,
            clock: Cell::new(0),
            gather: vec![Cell::new(0); pages],
            append: vec![Cell::new(0); pages],
            select: vec![Cell::new(0); pages],
            last_touch: vec![Cell::new(0); pages],
            gather_total: Cell::new(0),
            append_total: Cell::new(0),
            select_total: Cell::new(0),
            cow_clones: Cell::new(0),
            resets: Cell::new(0),
        }
    }

    /// A tracker that records nothing — one branch per touch site.
    pub fn disabled() -> HeatTracker {
        HeatTracker {
            enabled: false,
            clock: Cell::new(0),
            gather: Vec::new(),
            append: Vec::new(),
            select: Vec::new(),
            last_touch: Vec::new(),
            gather_total: Cell::new(0),
            append_total: Cell::new(0),
            select_total: Cell::new(0),
            cow_clones: Cell::new(0),
            resets: Cell::new(0),
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Pages tracked (0 when disabled).
    pub fn pages(&self) -> usize {
        self.gather.len()
    }

    /// Advance the logical tick clock.
    pub fn tick(&self) {
        if self.enabled {
            self.clock.set(self.clock.get() + 1);
        }
    }

    pub fn clock(&self) -> u64 {
        self.clock.get()
    }

    /// Record one touch of `page`. The hot-path call — a disabled
    /// tracker returns after one branch.
    #[inline]
    pub fn touch(&self, kind: TouchKind, page: usize) {
        if !self.enabled {
            return;
        }
        let (per_page, total) = match kind {
            TouchKind::Gather => (&self.gather, &self.gather_total),
            TouchKind::Append => (&self.append, &self.append_total),
            TouchKind::Select => (&self.select, &self.select_total),
        };
        per_page[page].set(per_page[page].get() + 1);
        total.set(total.get() + 1);
        self.last_touch[page].set(self.clock.get());
    }

    /// Count one copy-on-write page clone.
    pub fn record_cow(&self) {
        if self.enabled {
            self.cow_clones.set(self.cow_clones.get() + 1);
        }
    }

    /// Forget a page's history — the page was reallocated and now holds
    /// a different incarnation's data.
    pub fn reset_page(&self, page: usize) {
        if !self.enabled {
            return;
        }
        self.gather[page].set(0);
        self.append[page].set(0);
        self.select[page].set(0);
        self.last_touch[page].set(self.clock.get());
        self.resets.set(self.resets.get() + 1);
    }

    pub fn gather_hits(&self, page: usize) -> u64 {
        self.gather.get(page).map_or(0, Cell::get)
    }

    pub fn append_hits(&self, page: usize) -> u64 {
        self.append.get(page).map_or(0, Cell::get)
    }

    pub fn select_hits(&self, page: usize) -> u64 {
        self.select.get(page).map_or(0, Cell::get)
    }

    /// All touches of `page`, every kind.
    pub fn total_hits(&self, page: usize) -> u64 {
        self.gather_hits(page) + self.append_hits(page) + self.select_hits(page)
    }

    /// Tick-clock value at the page's last touch (or last reset).
    pub fn last_touch(&self, page: usize) -> u64 {
        self.last_touch.get(page).map_or(0, Cell::get)
    }

    /// Ticks since the page was last touched.
    pub fn age(&self, page: usize) -> u64 {
        self.clock.get().saturating_sub(self.last_touch(page))
    }

    pub fn gather_total(&self) -> u64 {
        self.gather_total.get()
    }

    pub fn append_total(&self) -> u64 {
        self.append_total.get()
    }

    pub fn select_total(&self) -> u64 {
        self.select_total.get()
    }

    pub fn cow_clones(&self) -> u64 {
        self.cow_clones.get()
    }

    /// Page reallocations observed (heat resets).
    pub fn resets(&self) -> u64 {
        self.resets.get()
    }
}

/// Log2 heat bucket: 0 for a cold page, `floor(log2(t)) + 1` for `t`
/// touches — the integer classification the heat histogram uses, exposed
/// so the property tests can recompute it from scratch.
pub fn heat_bucket(touches: u64) -> usize {
    if touches == 0 {
        0
    } else {
        64 - touches.leading_zeros() as usize
    }
}

/// Shape of the radix prefix index, computed by a full tree walk plus
/// the index's incremental lookup-depth counters.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RadixStats {
    /// Pages currently indexed.
    pub pages: usize,
    /// Deepest chain, in pages (0 for an empty index).
    pub max_depth: usize,
    /// Nodes per depth; `depth_hist[0]` counts the roots.
    pub depth_hist: Vec<u64>,
    /// Nodes by child count; `branching_hist[k]` counts nodes with `k`
    /// children (leaves at index 0).
    pub branching_hist: Vec<u64>,
    /// Lookups by matched depth in pages; `hit_depth_hist[0]` counts
    /// complete misses.
    pub hit_depth_hist: Vec<u64>,
    /// Total `lookup` calls observed.
    pub lookups: u64,
}

/// One contiguous run of hot pages in the report's top-k list.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HotRun {
    /// First physical page of the run.
    pub start: usize,
    /// Consecutive pages in the run.
    pub pages: usize,
    /// Summed touches (all kinds) over the run.
    pub touches: u64,
}

/// Pool occupancy and fragmentation, recomputed from the refcount map.
#[derive(Clone, Debug, PartialEq)]
pub struct PoolStats {
    pub pages_total: usize,
    pub pages_used: usize,
    pub pages_free: usize,
    pub page_tokens: usize,
    /// Bytes one token row occupies across layers and kv heads (K + V).
    pub token_bytes: usize,
    /// Maximal runs of consecutive free page ids.
    pub free_runs: usize,
    pub largest_free_run: usize,
    /// `1 - largest_free_run / pages_free` (0 when nothing is free): 0
    /// means the free space is one contiguous run, → 1 means shattered.
    pub fragmentation: f64,
}

/// Sharing structure: the refcount distribution over every page.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SharingStats {
    /// Pages per refcount value (free pages sit at key 0).
    pub refcount_hist: BTreeMap<u32, u64>,
    /// Pages with refcount >= 2 (COW- or radix-shared).
    pub shared_pages: usize,
    pub max_refcount: u32,
    pub cow_clones_total: u64,
}

/// Heat aggregates over the *used* pages.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HeatStats {
    pub clock: u64,
    pub gather_touches_total: u64,
    pub append_touches_total: u64,
    pub select_touches_total: u64,
    /// Used pages per [`heat_bucket`] of their total touches.
    pub histogram: Vec<u64>,
    /// Top-k hottest pages, merged into contiguous runs, hottest first.
    pub hottest: Vec<HotRun>,
}

/// The versioned cache introspection report `leanattn inspect` emits and
/// the flight recorder snapshots into every bundle.
#[derive(Clone, Debug, PartialEq)]
pub struct CacheReport {
    pub pool: PoolStats,
    pub sharing: SharingStats,
    pub heat: HeatStats,
    pub radix: Option<RadixStats>,
}

impl CacheReport {
    /// Build the report from per-page state. Every aggregate here is a
    /// from-scratch recompute over `ref_counts` and `heat` — nothing is
    /// carried incrementally, so the report stays bit-exact under any
    /// interleaving of cache operations.
    pub fn build(
        ref_counts: &[u32],
        heat: &HeatTracker,
        page_tokens: usize,
        token_bytes: usize,
        radix: Option<RadixStats>,
        top_k: usize,
    ) -> CacheReport {
        let pages_total = ref_counts.len();
        let free: Vec<usize> =
            (0..pages_total).filter(|&p| ref_counts[p] == 0).collect();
        let pages_free = free.len();
        let pages_used = pages_total - pages_free;

        // Fragmentation over the sorted free-id set.
        let (mut free_runs, mut largest_free_run, mut run) = (0usize, 0usize, 0usize);
        for (i, &p) in free.iter().enumerate() {
            if i == 0 || p != free[i - 1] + 1 {
                free_runs += 1;
                run = 0;
            }
            run += 1;
            largest_free_run = largest_free_run.max(run);
        }
        let fragmentation = if pages_free == 0 {
            0.0
        } else {
            1.0 - largest_free_run as f64 / pages_free as f64
        };

        let mut refcount_hist = BTreeMap::new();
        let mut shared_pages = 0usize;
        let mut max_refcount = 0u32;
        for &r in ref_counts {
            *refcount_hist.entry(r).or_insert(0u64) += 1;
            if r >= 2 {
                shared_pages += 1;
            }
            max_refcount = max_refcount.max(r);
        }

        // Heat histogram over used pages, bucketed by total touches.
        let used: Vec<usize> =
            (0..pages_total).filter(|&p| ref_counts[p] > 0).collect();
        let max_bucket =
            used.iter().map(|&p| heat_bucket(heat.total_hits(p))).max().unwrap_or(0);
        let mut histogram = vec![0u64; max_bucket + 1];
        for &p in &used {
            histogram[heat_bucket(heat.total_hits(p))] += 1;
        }

        // Top-k hottest pages (ties break toward lower ids), merged into
        // contiguous runs.
        let mut ranked = used.clone();
        ranked.sort_by_key(|&p| (std::cmp::Reverse(heat.total_hits(p)), p));
        ranked.truncate(top_k);
        ranked.sort_unstable();
        let mut hottest: Vec<HotRun> = Vec::new();
        for &p in &ranked {
            match hottest.last_mut() {
                Some(r) if r.start + r.pages == p => {
                    r.pages += 1;
                    r.touches += heat.total_hits(p);
                }
                _ => hottest.push(HotRun {
                    start: p,
                    pages: 1,
                    touches: heat.total_hits(p),
                }),
            }
        }
        hottest.sort_by_key(|r| (std::cmp::Reverse(r.touches), r.start));

        CacheReport {
            pool: PoolStats {
                pages_total,
                pages_used,
                pages_free,
                page_tokens,
                token_bytes,
                free_runs,
                largest_free_run,
                fragmentation,
            },
            sharing: SharingStats {
                refcount_hist,
                shared_pages,
                max_refcount,
                cow_clones_total: heat.cow_clones(),
            },
            heat: HeatStats {
                clock: heat.clock(),
                gather_touches_total: heat.gather_total(),
                append_touches_total: heat.append_total(),
                select_touches_total: heat.select_total(),
                histogram,
                hottest,
            },
            radix,
        }
    }

    /// The versioned JSON export ([`CACHE_REPORT_VERSION`]).
    pub fn to_json(&self) -> Json {
        let mut pool = BTreeMap::new();
        pool.insert("pages_total".into(), Json::Num(self.pool.pages_total as f64));
        pool.insert("pages_used".into(), Json::Num(self.pool.pages_used as f64));
        pool.insert("pages_free".into(), Json::Num(self.pool.pages_free as f64));
        pool.insert("page_tokens".into(), Json::Num(self.pool.page_tokens as f64));
        pool.insert("token_bytes".into(), Json::Num(self.pool.token_bytes as f64));
        pool.insert("free_runs".into(), Json::Num(self.pool.free_runs as f64));
        pool.insert(
            "largest_free_run".into(),
            Json::Num(self.pool.largest_free_run as f64),
        );
        pool.insert("fragmentation".into(), Json::Num(self.pool.fragmentation));

        let mut sharing = BTreeMap::new();
        sharing.insert(
            "refcount_hist".into(),
            Json::Obj(
                self.sharing
                    .refcount_hist
                    .iter()
                    .map(|(k, v)| (k.to_string(), Json::Num(*v as f64)))
                    .collect(),
            ),
        );
        sharing
            .insert("shared_pages".into(), Json::Num(self.sharing.shared_pages as f64));
        sharing
            .insert("max_refcount".into(), Json::Num(f64::from(self.sharing.max_refcount)));
        sharing.insert(
            "cow_clones_total".into(),
            Json::Num(self.sharing.cow_clones_total as f64),
        );

        let mut heat = BTreeMap::new();
        heat.insert("clock".into(), Json::Num(self.heat.clock as f64));
        heat.insert(
            "gather_touches_total".into(),
            Json::Num(self.heat.gather_touches_total as f64),
        );
        heat.insert(
            "append_touches_total".into(),
            Json::Num(self.heat.append_touches_total as f64),
        );
        heat.insert(
            "select_touches_total".into(),
            Json::Num(self.heat.select_touches_total as f64),
        );
        heat.insert(
            "histogram".into(),
            Json::Arr(self.heat.histogram.iter().map(|&n| Json::Num(n as f64)).collect()),
        );
        heat.insert(
            "hottest".into(),
            Json::Arr(
                self.heat
                    .hottest
                    .iter()
                    .map(|r| {
                        let mut o = BTreeMap::new();
                        o.insert("start".into(), Json::Num(r.start as f64));
                        o.insert("pages".into(), Json::Num(r.pages as f64));
                        o.insert("touches".into(), Json::Num(r.touches as f64));
                        Json::Obj(o)
                    })
                    .collect(),
            ),
        );

        let radix = match &self.radix {
            None => Json::Null,
            Some(r) => {
                let arr = |xs: &[u64]| {
                    Json::Arr(xs.iter().map(|&n| Json::Num(n as f64)).collect())
                };
                let mut o = BTreeMap::new();
                o.insert("pages".into(), Json::Num(r.pages as f64));
                o.insert("max_depth".into(), Json::Num(r.max_depth as f64));
                o.insert("depth_hist".into(), arr(&r.depth_hist));
                o.insert("branching_hist".into(), arr(&r.branching_hist));
                o.insert("hit_depth_hist".into(), arr(&r.hit_depth_hist));
                o.insert("lookups".into(), Json::Num(r.lookups as f64));
                Json::Obj(o)
            }
        };

        let mut top = BTreeMap::new();
        top.insert("version".into(), Json::Num(CACHE_REPORT_VERSION as f64));
        top.insert("pool".into(), Json::Obj(pool));
        top.insert("sharing".into(), Json::Obj(sharing));
        top.insert("heat".into(), Json::Obj(heat));
        top.insert("radix".into(), radix);
        Json::Obj(top)
    }

    /// Human-readable summary: the table `leanattn inspect` prints.
    pub fn render(&self) -> String {
        let mut s = format!(
            "cache report (v{CACHE_REPORT_VERSION}):\n\
             pool: {} pages ({} used / {} free), page {} tokens x {} B/token\n\
             fragmentation: {} free runs, largest {} — index {:.3}\n\
             sharing: {} shared pages, max refcount {}, {} COW clones\n",
            self.pool.pages_total,
            self.pool.pages_used,
            self.pool.pages_free,
            self.pool.page_tokens,
            self.pool.token_bytes,
            self.pool.free_runs,
            self.pool.largest_free_run,
            self.pool.fragmentation,
            self.sharing.shared_pages,
            self.sharing.max_refcount,
            self.sharing.cow_clones_total,
        );
        s.push_str(&format!(
            "heat: clock {} — {} gather / {} append / {} select touches\n",
            self.heat.clock,
            self.heat.gather_touches_total,
            self.heat.append_touches_total,
            self.heat.select_touches_total,
        ));
        s.push_str("heat histogram (touches -> used pages):\n");
        for (b, &n) in self.heat.histogram.iter().enumerate() {
            if n == 0 {
                continue;
            }
            let label = if b == 0 {
                "0".to_string()
            } else {
                format!("{}-{}", 1u64 << (b - 1), (1u64 << b) - 1)
            };
            s.push_str(&format!("  {label:>12}  {n}\n"));
        }
        if !self.heat.hottest.is_empty() {
            s.push_str("hottest page runs:\n");
            for r in &self.heat.hottest {
                s.push_str(&format!(
                    "  pages {}..{}  {} touches\n",
                    r.start,
                    r.start + r.pages - 1,
                    r.touches
                ));
            }
        }
        if let Some(r) = &self.radix {
            s.push_str(&format!(
                "radix: {} pages, max depth {}, {} lookups\n",
                r.pages, r.max_depth, r.lookups
            ));
        }
        s
    }
}

fn num_at(obj: &Json, key: &str) -> Result<f64> {
    obj.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow::anyhow!("cache report: {key} missing or not a number"))
}

fn nonneg_arr(obj: &Json, key: &str) -> Result<Vec<f64>> {
    let Some(arr) = obj.get(key).and_then(Json::as_arr) else {
        bail!("cache report: {key} missing or not an array");
    };
    let mut out = Vec::with_capacity(arr.len());
    for (i, v) in arr.iter().enumerate() {
        let n = v
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("cache report: {key}[{i}] not a number"))?;
        ensure!(n >= 0.0, "cache report: {key}[{i}] is negative");
        out.push(n);
    }
    Ok(out)
}

/// Validate a JSON value against the [`CacheReport::to_json`] schema —
/// the self-check `leanattn inspect` runs on its output and the flight
/// recorder runs when re-validating a bundle.
pub fn validate_cache_report(report: &Json) -> Result<()> {
    ensure!(report.as_obj().is_some(), "cache report must be a JSON object");
    let version = num_at(report, "version")?;
    ensure!(
        version == CACHE_REPORT_VERSION as f64,
        "cache report version {version} != {CACHE_REPORT_VERSION}"
    );

    let pool = report
        .get("pool")
        .filter(|p| p.as_obj().is_some())
        .ok_or_else(|| anyhow::anyhow!("cache report: pool missing"))?;
    let total = num_at(pool, "pages_total")?;
    let used = num_at(pool, "pages_used")?;
    let free = num_at(pool, "pages_free")?;
    ensure!(used + free == total, "pool accounting: used + free != total");
    for key in ["page_tokens", "token_bytes", "free_runs", "largest_free_run"] {
        ensure!(num_at(pool, key)? >= 0.0, "pool {key} is negative");
    }
    let frag = num_at(pool, "fragmentation")?;
    ensure!((0.0..=1.0).contains(&frag), "fragmentation {frag} outside [0, 1]");

    let sharing = report
        .get("sharing")
        .filter(|p| p.as_obj().is_some())
        .ok_or_else(|| anyhow::anyhow!("cache report: sharing missing"))?;
    let hist = sharing
        .get("refcount_hist")
        .and_then(Json::as_obj)
        .ok_or_else(|| anyhow::anyhow!("cache report: refcount_hist missing"))?;
    let mut hist_pages = 0.0;
    for (k, v) in hist {
        ensure!(
            k.parse::<u32>().is_ok(),
            "refcount_hist key {k:?} is not a refcount"
        );
        let n = v
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("refcount_hist[{k}] not a number"))?;
        ensure!(n >= 0.0, "refcount_hist[{k}] is negative");
        hist_pages += n;
    }
    ensure!(
        hist_pages == total,
        "refcount_hist covers {hist_pages} pages, pool has {total}"
    );
    num_at(sharing, "shared_pages")?;
    num_at(sharing, "max_refcount")?;
    num_at(sharing, "cow_clones_total")?;

    let heat = report
        .get("heat")
        .filter(|p| p.as_obj().is_some())
        .ok_or_else(|| anyhow::anyhow!("cache report: heat missing"))?;
    for key in
        ["clock", "gather_touches_total", "append_touches_total", "select_touches_total"]
    {
        ensure!(num_at(heat, key)? >= 0.0, "heat {key} is negative");
    }
    let heat_hist = nonneg_arr(heat, "histogram")?;
    ensure!(
        heat_hist.iter().sum::<f64>() == used,
        "heat histogram covers {} pages, pool has {used} used",
        heat_hist.iter().sum::<f64>()
    );
    let hottest = heat
        .get("hottest")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("cache report: hottest missing"))?;
    for (i, run) in hottest.iter().enumerate() {
        for key in ["start", "pages", "touches"] {
            let n = run.get(key).and_then(Json::as_f64).ok_or_else(|| {
                anyhow::anyhow!("hottest[{i}].{key} missing or not a number")
            })?;
            ensure!(n >= 0.0, "hottest[{i}].{key} is negative");
        }
        ensure!(
            run.get("pages").and_then(Json::as_f64) >= Some(1.0),
            "hottest[{i}] is an empty run"
        );
    }

    match report.get("radix") {
        None => bail!("cache report: radix missing (use null for no index)"),
        Some(Json::Null) => {}
        Some(radix) => {
            ensure!(radix.as_obj().is_some(), "radix must be an object or null");
            num_at(radix, "pages")?;
            num_at(radix, "max_depth")?;
            num_at(radix, "lookups")?;
            let depth = nonneg_arr(radix, "depth_hist")?;
            nonneg_arr(radix, "branching_hist")?;
            let hits = nonneg_arr(radix, "hit_depth_hist")?;
            ensure!(
                hits.iter().sum::<f64>() == num_at(radix, "lookups")?,
                "hit_depth_hist does not cover every lookup"
            );
            ensure!(
                num_at(radix, "pages")? == depth.iter().sum::<f64>(),
                "depth_hist does not cover every indexed page"
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracker_is_inert() {
        let h = HeatTracker::disabled();
        h.tick();
        h.touch(TouchKind::Gather, 3);
        h.touch(TouchKind::Append, 7);
        h.record_cow();
        h.reset_page(1);
        assert!(!h.is_enabled());
        assert_eq!(h.clock(), 0);
        assert_eq!(h.gather_total(), 0);
        assert_eq!(h.total_hits(3), 0);
        assert_eq!(h.cow_clones(), 0);
    }

    #[test]
    fn touches_land_in_per_page_and_total_counters() {
        let h = HeatTracker::enabled(8);
        h.tick();
        h.touch(TouchKind::Gather, 2);
        h.touch(TouchKind::Gather, 2);
        h.touch(TouchKind::Append, 2);
        h.touch(TouchKind::Select, 5);
        assert_eq!(h.gather_hits(2), 2);
        assert_eq!(h.append_hits(2), 1);
        assert_eq!(h.select_hits(5), 1);
        assert_eq!(h.total_hits(2), 3);
        assert_eq!((h.gather_total(), h.append_total(), h.select_total()), (2, 1, 1));
        assert_eq!(h.last_touch(2), 1);
        h.tick();
        h.tick();
        assert_eq!(h.age(2), 2);
        assert_eq!(h.age(5), 2);
        h.reset_page(2);
        assert_eq!(h.total_hits(2), 0);
        assert_eq!(h.age(2), 0);
        assert_eq!(h.resets(), 1);
        // Totals are lifetime counters; resets don't rewind them.
        assert_eq!(h.gather_total(), 2);
    }

    #[test]
    fn heat_buckets_are_log2() {
        assert_eq!(heat_bucket(0), 0);
        assert_eq!(heat_bucket(1), 1);
        assert_eq!(heat_bucket(2), 2);
        assert_eq!(heat_bucket(3), 2);
        assert_eq!(heat_bucket(4), 3);
        assert_eq!(heat_bucket(1023), 10);
        assert_eq!(heat_bucket(1024), 11);
    }

    #[test]
    fn report_round_trips_and_validates() {
        let h = HeatTracker::enabled(6);
        // Pages 0..3 used (0 and 1 shared), 4..5 free — a fragmented
        // pool would need non-adjacent free ids, so free {4, 5} is one
        // run and fragmentation 0.
        let refs = [2u32, 3, 1, 1, 0, 0];
        for _ in 0..5 {
            h.touch(TouchKind::Gather, 0);
        }
        h.touch(TouchKind::Append, 1);
        h.touch(TouchKind::Select, 2);
        h.record_cow();
        let rep = CacheReport::build(&refs, &h, 4, 64, None, 3);
        assert_eq!(rep.pool.pages_used, 4);
        assert_eq!(rep.pool.free_runs, 1);
        assert_eq!(rep.pool.largest_free_run, 2);
        assert_eq!(rep.pool.fragmentation, 0.0);
        assert_eq!(rep.sharing.shared_pages, 2);
        assert_eq!(rep.sharing.max_refcount, 3);
        assert_eq!(rep.sharing.cow_clones_total, 1);
        assert_eq!(rep.sharing.refcount_hist[&0], 2);
        assert_eq!(rep.sharing.refcount_hist[&1], 2);
        // Heat histogram: page 3 cold (bucket 0), pages 1 and 2 at one
        // touch (bucket 1), page 0 at five touches (bucket 3).
        assert_eq!(rep.heat.histogram, vec![1, 2, 0, 1]);
        // Top-3 pages are 0 (5 touches), 1 and 2 (1 each): 1 and 2 merge
        // into one run but page 0 stays hottest.
        assert_eq!(
            rep.heat.hottest,
            vec![
                HotRun { start: 0, pages: 1, touches: 5 },
                HotRun { start: 1, pages: 2, touches: 2 },
            ]
        );
        let j = rep.to_json();
        validate_cache_report(&j).expect("report validates");
        let parsed = Json::parse(&j.to_string()).expect("report parses back");
        assert_eq!(parsed, j, "JSON round-trip is the identity");
        validate_cache_report(&parsed).expect("parsed report still validates");
        let text = rep.render();
        assert!(text.contains("cache report"), "{text}");
        assert!(text.contains("hottest page runs"), "{text}");
    }

    #[test]
    fn fragmented_free_set_is_measured() {
        let h = HeatTracker::enabled(7);
        // Free ids {0, 2, 3, 6}: runs [0], [2,3], [6] -> 3 runs, largest 2.
        let refs = [0u32, 1, 0, 0, 1, 2, 0];
        let rep = CacheReport::build(&refs, &h, 4, 16, None, 4);
        assert_eq!(rep.pool.free_runs, 3);
        assert_eq!(rep.pool.largest_free_run, 2);
        assert_eq!(rep.pool.fragmentation, 1.0 - 2.0 / 4.0);
        validate_cache_report(&rep.to_json()).unwrap();
    }

    #[test]
    fn validator_rejects_malformed_reports() {
        assert!(validate_cache_report(&Json::Null).is_err());
        let h = HeatTracker::enabled(2);
        let good = CacheReport::build(&[1, 0], &h, 4, 16, None, 2).to_json();
        validate_cache_report(&good).unwrap();
        // Wrong version.
        let mut bad = good.clone();
        if let Json::Obj(o) = &mut bad {
            o.insert("version".into(), Json::Num(99.0));
        }
        assert!(validate_cache_report(&bad).is_err());
        // Pool accounting broken.
        let mut bad = good.clone();
        if let Json::Obj(o) = &mut bad {
            if let Some(Json::Obj(pool)) = o.get_mut("pool") {
                pool.insert("pages_used".into(), Json::Num(5.0));
            }
        }
        assert!(validate_cache_report(&bad).is_err());
        // Missing radix key entirely.
        let mut bad = good.clone();
        if let Json::Obj(o) = &mut bad {
            o.remove("radix");
        }
        assert!(validate_cache_report(&bad).is_err());
    }

    #[test]
    fn radix_section_validates_its_accounting() {
        let h = HeatTracker::enabled(2);
        let stats = RadixStats {
            pages: 3,
            max_depth: 2,
            depth_hist: vec![2, 1],
            branching_hist: vec![2, 1],
            hit_depth_hist: vec![1, 0, 2],
            lookups: 3,
        };
        let rep = CacheReport::build(&[1, 1], &h, 4, 16, Some(stats), 2);
        validate_cache_report(&rep.to_json()).unwrap();
        // A lookup the hit-depth histogram misses is rejected.
        let mut bad = rep.clone();
        bad.radix.as_mut().unwrap().lookups = 4;
        assert!(validate_cache_report(&bad.to_json()).is_err());
    }
}
