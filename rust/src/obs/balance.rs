//! Partition-balance observability: the per-tile work ledger and the
//! versioned [`PartitionReport`].
//!
//! LeanAttention's headline claim is a *scheduling* property: stream-K
//! decomposition equalizes per-CTA load where fixed splits leave waves
//! ragged (paper Figs 2/3/10). This module turns that claim into a
//! reportable, enforceable number by joining three views of one plan:
//!
//! 1. **Predicted work** — a per-CTA ledger priced with the exact same
//!    closed form the attribution totals use
//!    ([`span_work`] at segment granularity), so the ledger's
//!    sum is bit-exact equal to [`crate::obs::attrib::account_plan`] /
//!    [`crate::obs::attrib::account_cascade_problem`] by construction.
//! 2. **Simulated timelines** — [`schedule_detail`]'s per-CTA slot
//!    placement and start/finish times on a [`GpuArch`].
//! 3. **Measured spans** — when traced, per-CTA `gather`/`lean_exec`
//!    span times carrying the [`Attrs::tile`] index
//!    ([`execute_plan_traced`] emits them; [`join_measured_events`]
//!    folds them back into the ledger).
//!
//! The summary numbers: **load-imbalance factor** = makespan over mean
//! busy-slot time (1.0 = perfectly level), **wave efficiency** = busy
//! slot-time over `makespan × slots` (1.0 = no wave-quantization
//! waste), and the **critical-path CTA** whose finish sets the
//! makespan. `leanattn analyze --partition` renders the per-strategy
//! comparison; `bench --balance` asserts stream-K's imbalance strictly
//! below the fixed-split baseline on a ragged batch.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{bail, ensure, Result};

use crate::obs::attrib::{span_work, WorkAccounting};
use crate::obs::tracer::{Attrs, Phase, TraceEvent, Tracer};
use crate::partition::cascade::{CascadePlan, CascadeProblem};
use crate::partition::plan::{build_plan, DecodeProblem, Plan, Strategy};
use crate::sim::schedule::{effective_slots, schedule_detail};
use crate::sim::GpuArch;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Schema version stamped into every [`PartitionReport`] JSON export.
pub const PARTITION_REPORT_VERSION: u64 = 1;

/// One CTA's row in the per-tile work ledger: predicted work priced at
/// segment granularity, simulated placement, and (when traced) the
/// measured span time joined by tile index.
#[derive(Clone, Debug)]
pub struct CtaLedgerRow {
    /// CTA index in plan launch order (the `tile` span attribute).
    pub cta: usize,
    /// Simulated slot the CTA landed on.
    pub slot: usize,
    /// Simulated start, microseconds from kernel start.
    pub start_us: f64,
    /// Simulated finish, microseconds from kernel start.
    pub finish_us: f64,
    /// LeanTile segments the CTA runs back-to-back.
    pub segments: usize,
    /// Exact predicted work of those segments (context-clamped).
    pub work: WorkAccounting,
    /// Measured `gather` + `lean_exec` span time for this CTA, when a
    /// traced execution was joined in.
    pub measured_us: Option<f64>,
}

/// Balance summary of one strategy's plan on one problem.
#[derive(Clone, Debug)]
pub struct StrategyBalance {
    /// Strategy name ([`Strategy::name`]).
    pub strategy: &'static str,
    /// CTAs launched.
    pub grid: usize,
    /// Co-resident CTA slots the schedule had available.
    pub slots: usize,
    /// `grid / slots` — fractional waves of the launch.
    pub waves: f64,
    /// Simulated compute makespan, microseconds.
    pub makespan_us: f64,
    /// Mean busy time of the slots that received work, microseconds.
    pub mean_slot_us: f64,
    /// Load-imbalance factor: `makespan / mean_slot_us` (>= 1; 1.0
    /// means every used slot finished together).
    pub imbalance: f64,
    /// Busy slot-time over `makespan x slots` (<= 1; 1.0 means no
    /// wave-quantization idle time on any slot).
    pub wave_efficiency: f64,
    /// CTA whose finish time sets the makespan (the critical path).
    pub critical_cta: usize,
    /// Histogram of per-CTA tile counts in log2 buckets: bucket `i`
    /// counts CTAs with `tiles` in `[2^(i-1), 2^i)` (bucket 0 = zero
    /// tiles). A balanced plan concentrates in one bucket.
    pub tiles_hist: Vec<u64>,
    /// Ledger total — bit-exact equal to the plan's closed-form
    /// accounting.
    pub total: WorkAccounting,
    /// Per-CTA rows, indexed by launch order.
    pub ledger: Vec<CtaLedgerRow>,
}

/// Per-CTA predicted work for a plain decode plan, one row per CTA in
/// launch order. Prices each segment with [`span_work`] — the rows sum
/// bit-exact to [`crate::obs::attrib::account_plan`].
pub fn plan_ledger(p: &DecodeProblem, plan: &Plan) -> Vec<WorkAccounting> {
    plan.ctas
        .iter()
        .map(|cta| {
            let mut w = WorkAccounting::default();
            for seg in &cta.segments {
                let g = seg.group as usize;
                let ctx = p.ctx_for_group(g);
                let begin = seg.tile_begin as usize * plan.tile;
                let end = (seg.tile_begin + seg.tile_count) as usize * plan.tile;
                w += span_work(ctx, begin, end, plan.tile, p.head_dim, p.group_size());
            }
            w
        })
        .collect()
}

/// Per-CTA predicted work for a cascade plan: shared-prefix segments
/// serve every group member's query rows at once, suffixes serve one —
/// [`CascadeProblem::queries_of`] supplies the row count per segment
/// group. Rows sum bit-exact to
/// [`crate::obs::attrib::account_cascade_problem`].
pub fn cascade_ledger(cp: &CascadeProblem, cplan: &CascadePlan) -> Vec<WorkAccounting> {
    let sp = &cplan.segment_problem;
    cplan
        .plan
        .ctas
        .iter()
        .map(|cta| {
            let mut w = WorkAccounting::default();
            for seg in &cta.segments {
                let g = seg.group as usize;
                let ctx = sp.ctx_for_group(g);
                let begin = seg.tile_begin as usize * cplan.plan.tile;
                let end = (seg.tile_begin + seg.tile_count) as usize * cplan.plan.tile;
                w += span_work(ctx, begin, end, cplan.plan.tile, sp.head_dim, cp.queries_of(g));
            }
            w
        })
        .collect()
}

fn tiles_hist(ledger: &[WorkAccounting]) -> Vec<u64> {
    let mut hist = Vec::new();
    for w in ledger {
        let bucket = (u64::BITS - w.tiles.leading_zeros()) as usize;
        if hist.len() <= bucket {
            hist.resize(bucket + 1, 0);
        }
        hist[bucket] += 1;
    }
    hist
}

fn balance_from(
    strategy: &'static str,
    plan: &Plan,
    ledger: Vec<WorkAccounting>,
    problem: &DecodeProblem,
    arch: &GpuArch,
) -> StrategyBalance {
    let slots = effective_slots(plan.strategy, arch);
    let detail = schedule_detail(plan, problem, arch);
    debug_assert_eq!(detail.len(), ledger.len());
    let busy: f64 = detail.iter().map(|c| c.finish_us - c.start_us).sum();
    let makespan_us = detail.iter().map(|c| c.finish_us).fold(0.0, f64::max);
    let used_slots = plan.grid().min(slots).max(1);
    let mean_slot_us = busy / used_slots as f64;
    let imbalance = if mean_slot_us > 0.0 { makespan_us / mean_slot_us } else { 1.0 };
    let wave_efficiency = if makespan_us > 0.0 {
        (busy / (makespan_us * slots as f64)).min(1.0)
    } else {
        1.0
    };
    let critical_cta = detail
        .iter()
        .max_by(|a, b| a.finish_us.total_cmp(&b.finish_us))
        .map_or(0, |c| c.cta);
    let total = ledger.iter().fold(WorkAccounting::default(), |a, &w| a + w);
    let hist = tiles_hist(&ledger);
    let rows = detail
        .iter()
        .zip(&ledger)
        .map(|(c, &work)| CtaLedgerRow {
            cta: c.cta,
            slot: c.slot,
            start_us: c.start_us,
            finish_us: c.finish_us,
            segments: plan.ctas[c.cta].segments.len(),
            work,
            measured_us: None,
        })
        .collect();
    StrategyBalance {
        strategy,
        grid: plan.grid(),
        slots,
        waves: plan.grid() as f64 / slots as f64,
        makespan_us,
        mean_slot_us,
        imbalance,
        wave_efficiency,
        critical_cta,
        tiles_hist: hist,
        total,
        ledger: rows,
    }
}

/// Join the ledger with the simulated per-CTA timeline for one plan.
pub fn plan_balance(p: &DecodeProblem, plan: &Plan, arch: &GpuArch) -> StrategyBalance {
    balance_from(plan.strategy.name(), plan, plan_ledger(p, plan), p, arch)
}

/// Join the cascade ledger with the simulated timeline of the cascade
/// plan's segment problem.
pub fn cascade_balance(
    cp: &CascadeProblem,
    cplan: &CascadePlan,
    arch: &GpuArch,
) -> StrategyBalance {
    balance_from(
        cplan.plan.strategy.name(),
        &cplan.plan,
        cascade_ledger(cp, cplan),
        &cplan.segment_problem,
        arch,
    )
}

/// Fold measured `gather`/`lean_exec` span durations carrying a `tile`
/// attribute back into the ledger rows they index. Events without the
/// attribute (step-level engine spans) are ignored; repeated events for
/// one tile accumulate, so an iterated run joins its total.
pub fn join_measured_events(b: &mut StrategyBalance, events: &[TraceEvent]) {
    for ev in events {
        if !matches!(ev.phase, Phase::Gather | Phase::LeanExec) {
            continue;
        }
        let Some(tile) = ev.attrs.tile else { continue };
        if let Some(row) = b.ledger.get_mut(tile) {
            *row.measured_us.get_or_insert(0.0) += ev.dur_us;
        }
    }
}

/// The partition-quality report for one problem: every strategy's
/// balance summary side by side, schema-validated and versioned.
#[derive(Clone, Debug)]
pub struct PartitionReport {
    pub version: u64,
    /// Human-readable problem shape.
    pub shape: String,
    /// Fig 10's x-axis: average over max context of the batch (1.0 =
    /// uniform, small = one long straggler lane).
    pub batch_context_ratio: f64,
    pub strategies: Vec<StrategyBalance>,
}

/// Build the cross-strategy report for one decode problem: dense
/// (FlashAttention-2), auto fixed-split (FlashDecoding), paged fixed
/// split (FlashInfer) and stream-K (LeanAttention).
pub fn partition_report(p: &DecodeProblem, arch: &GpuArch) -> PartitionReport {
    let fd = Strategy::fixed_split_auto(p, arch.num_sms);
    let fi_splits = match fd {
        Strategy::FixedSplit { splits } => splits,
        _ => 1,
    };
    let strategies = [
        Strategy::Dense,
        fd,
        Strategy::PagedFixedSplit { splits: fi_splits, page: 16 },
        Strategy::StreamK,
    ]
    .into_iter()
    .map(|s| {
        let plan = build_plan(p, s, effective_slots(s, arch));
        plan_balance(p, &plan, arch)
    })
    .collect();
    PartitionReport {
        version: PARTITION_REPORT_VERSION,
        shape: format!(
            "b{} h{}/kv{} d{} ctx {}..{} tile {}",
            p.batch(),
            p.heads,
            p.kv_heads,
            p.head_dim,
            p.ctx_lens.iter().min().copied().unwrap_or(0),
            p.ctx_lens.iter().max().copied().unwrap_or(0),
            p.tile
        ),
        batch_context_ratio: p.batch_context_ratio(),
        strategies,
    }
}

impl StrategyBalance {
    fn to_json(&self) -> Json {
        let ledger = self
            .ledger
            .iter()
            .map(|r| {
                let mut o = BTreeMap::new();
                o.insert("cta".to_string(), Json::Num(r.cta as f64));
                o.insert("slot".to_string(), Json::Num(r.slot as f64));
                o.insert("start_us".to_string(), Json::Num(r.start_us));
                o.insert("finish_us".to_string(), Json::Num(r.finish_us));
                o.insert("segments".to_string(), Json::Num(r.segments as f64));
                o.insert("work".to_string(), r.work.to_json());
                if let Some(m) = r.measured_us {
                    o.insert("measured_us".to_string(), Json::Num(m));
                }
                Json::Obj(o)
            })
            .collect();
        let mut o = BTreeMap::new();
        o.insert("strategy".to_string(), Json::Str(self.strategy.to_string()));
        o.insert("grid".to_string(), Json::Num(self.grid as f64));
        o.insert("slots".to_string(), Json::Num(self.slots as f64));
        o.insert("waves".to_string(), Json::Num(self.waves));
        o.insert("makespan_us".to_string(), Json::Num(self.makespan_us));
        o.insert("mean_slot_us".to_string(), Json::Num(self.mean_slot_us));
        o.insert("imbalance".to_string(), Json::Num(self.imbalance));
        o.insert("wave_efficiency".to_string(), Json::Num(self.wave_efficiency));
        o.insert("critical_cta".to_string(), Json::Num(self.critical_cta as f64));
        o.insert(
            "tiles_hist".to_string(),
            Json::Arr(self.tiles_hist.iter().map(|&c| Json::Num(c as f64)).collect()),
        );
        o.insert("total".to_string(), self.total.to_json());
        o.insert("ledger".to_string(), Json::Arr(ledger));
        Json::Obj(o)
    }
}

impl PartitionReport {
    /// Versioned JSON export (`analyze --partition --json-out`).
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("version".to_string(), Json::Num(self.version as f64));
        o.insert("shape".to_string(), Json::Str(self.shape.clone()));
        o.insert(
            "batch_context_ratio".to_string(),
            Json::Num(self.batch_context_ratio),
        );
        o.insert(
            "strategies".to_string(),
            Json::Arr(self.strategies.iter().map(StrategyBalance::to_json).collect()),
        );
        Json::Obj(o)
    }

    /// The stream-K row, if present (the comparison anchor).
    pub fn stream_k(&self) -> Option<&StrategyBalance> {
        self.strategies.iter().find(|s| s.strategy == "leanattention")
    }

    /// Human-readable comparison table.
    pub fn render(&self) -> String {
        let mut s = format!(
            "partition balance: {} (avg/max ctx {:.2})\n\
             {:<16} {:>6} {:>6} {:>7} {:>11} {:>10} {:>9} {:>9}\n",
            self.shape,
            self.batch_context_ratio,
            "strategy",
            "grid",
            "slots",
            "waves",
            "makespan_us",
            "imbalance",
            "wave_eff",
            "crit_cta",
        );
        for b in &self.strategies {
            s.push_str(&format!(
                "{:<16} {:>6} {:>6} {:>7.2} {:>11.1} {:>10.3} {:>9.3} {:>9}\n",
                b.strategy,
                b.grid,
                b.slots,
                b.waves,
                b.makespan_us,
                b.imbalance,
                b.wave_efficiency,
                b.critical_cta,
            ));
        }
        s
    }
}

fn require_num(o: &BTreeMap<String, Json>, key: &str, at: &str) -> Result<f64> {
    o.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow::anyhow!("{at}: missing numeric {key:?}"))
}

/// Validate a [`PartitionReport`] JSON export against its schema,
/// including the recomputable invariants: ledger length equals the
/// grid, per-row work sums bit-exact to the strategy total, imbalance
/// >= 1 and wave efficiency in (0, 1].
pub fn validate_partition_report(j: &Json) -> Result<()> {
    let Some(root) = j.as_obj() else { bail!("partition report must be an object") };
    ensure!(
        root.get("version").and_then(Json::as_f64) == Some(PARTITION_REPORT_VERSION as f64),
        "unknown partition report version"
    );
    ensure!(
        root.get("shape").and_then(Json::as_str).is_some(),
        "report missing shape string"
    );
    let ratio = require_num(root, "batch_context_ratio", "report")?;
    ensure!(
        ratio > 0.0 && ratio <= 1.0 + 1e-9,
        "batch_context_ratio {ratio} outside (0, 1]"
    );
    let strategies = root
        .get("strategies")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("report missing strategies array"))?;
    ensure!(!strategies.is_empty(), "report has no strategies");
    for sj in strategies {
        let Some(o) = sj.as_obj() else { bail!("strategy entry is not an object") };
        let name = o
            .get("strategy")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("strategy entry missing name"))?;
        ensure!(
            ["flashattention2", "flashdecoding", "flashinfer", "leanattention", "cascade"]
                .contains(&name),
            "unknown strategy name {name:?}"
        );
        let at = format!("strategy {name}");
        let grid = require_num(o, "grid", &at)? as usize;
        ensure!(grid >= 1, "{at}: empty grid");
        ensure!(require_num(o, "slots", &at)? >= 1.0, "{at}: no slots");
        let imb = require_num(o, "imbalance", &at)?;
        ensure!(imb >= 1.0 - 1e-9, "{at}: imbalance {imb} below 1");
        let eff = require_num(o, "wave_efficiency", &at)?;
        ensure!(eff > 0.0 && eff <= 1.0 + 1e-9, "{at}: wave_efficiency {eff} outside (0, 1]");
        require_num(o, "waves", &at)?;
        require_num(o, "makespan_us", &at)?;
        require_num(o, "mean_slot_us", &at)?;
        require_num(o, "critical_cta", &at)?;
        let total = o
            .get("total")
            .and_then(WorkAccounting::from_json)
            .ok_or_else(|| anyhow::anyhow!("{at}: missing work total"))?;
        let ledger = o
            .get("ledger")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("{at}: missing ledger array"))?;
        ensure!(
            ledger.len() == grid,
            "{at}: ledger has {} rows for a grid of {grid}",
            ledger.len()
        );
        let mut sum = WorkAccounting::default();
        for (i, rj) in ledger.iter().enumerate() {
            let Some(r) = rj.as_obj() else { bail!("{at}: ledger row {i} not an object") };
            let rat = format!("{at} row {i}");
            for key in ["cta", "slot", "start_us", "finish_us", "segments"] {
                ensure!(require_num(r, key, &rat)? >= 0.0, "{rat}: negative {key}");
            }
            let w = r
                .get("work")
                .and_then(WorkAccounting::from_json)
                .ok_or_else(|| anyhow::anyhow!("{rat}: missing work"))?;
            sum += w;
        }
        ensure!(
            sum == total,
            "{at}: ledger rows sum to a different work total than reported"
        );
    }
    Ok(())
}

/// Random Q/K/V tensors for a decode problem, laid out per KV group:
/// `q[g]` is `group_size x head_dim`, `k[g]`/`v[g]` are `ctx x
/// head_dim`. The host substrate [`execute_plan_traced`] and its
/// [`oracle`] both read.
pub struct BalanceTensors {
    pub q: Vec<Vec<f32>>,
    pub k: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
}

impl BalanceTensors {
    pub fn random(p: &DecodeProblem, seed: u64) -> BalanceTensors {
        let mut rng = Rng::new(seed);
        let mut noise =
            |n: usize| (0..n).map(|_| rng.range(0, 2048) as f32 / 1024.0 - 1.0).collect();
        let d = p.head_dim;
        let gs = p.group_size();
        let mut q = Vec::with_capacity(p.groups());
        let mut k = Vec::with_capacity(p.groups());
        let mut v = Vec::with_capacity(p.groups());
        for g in 0..p.groups() {
            let ctx = p.ctx_for_group(g);
            q.push(noise(gs * d));
            k.push(noise(ctx * d));
            v.push(noise(ctx * d));
        }
        BalanceTensors { q, k, v }
    }
}

/// One unscaled online-softmax partial: per query row, the running max,
/// the exp-sum and the weighted-V accumulator.
struct Partial {
    group: usize,
    m: Vec<f32>,
    s: Vec<f32>,
    acc: Vec<f32>,
}

/// Outcome of a per-CTA traced host execution of one plan.
pub struct MeasuredPlan {
    /// Per-CTA measured `gather` + `exec` wall time, microseconds, in
    /// launch order — the per-tile join input for [`CtaLedgerRow`] and
    /// the drift detector.
    pub cta_us: Vec<f64>,
    /// Exact attention output per group, `group_size x head_dim`,
    /// folded from the CTA partials in reduction order.
    pub outputs: Vec<Vec<f32>>,
}

/// Execute a plan CTA by CTA on the host: each CTA gathers its
/// segments' KV slices into a contiguous buffer (a `gather` span with
/// the slice bytes), computes the unscaled online-softmax partials (a
/// `lean_exec` span with the segment flops), and both spans carry the
/// CTA index in [`Attrs::tile`] so measured times join the ledger
/// per-tile. Partials fold per group afterwards — associativity makes
/// the result exact against [`oracle`] regardless of the partition.
pub fn execute_plan_traced(
    p: &DecodeProblem,
    plan: &Plan,
    t: &BalanceTensors,
    tracer: &Tracer,
) -> MeasuredPlan {
    let d = p.head_dim;
    let gs = p.group_size();
    let scale = 1.0 / (d as f32).sqrt();
    let ledger = plan_ledger(p, plan);
    let mut cta_us = Vec::with_capacity(plan.ctas.len());
    let mut partials: Vec<Partial> = Vec::new();
    let mut kbuf: Vec<f32> = Vec::new();
    let mut vbuf: Vec<f32> = Vec::new();

    for (ci, cta) in plan.ctas.iter().enumerate() {
        // Token ranges per segment, clamped to each group's context.
        let ranges: Vec<(usize, usize, usize)> = cta
            .segments
            .iter()
            .map(|seg| {
                let g = seg.group as usize;
                let ctx = p.ctx_for_group(g);
                let begin = (seg.tile_begin as usize * plan.tile).min(ctx);
                let end = ((seg.tile_begin + seg.tile_count) as usize * plan.tile).min(ctx);
                (g, begin, end)
            })
            .collect();

        let wall0 = Instant::now();
        let gather_start = tracer.now();
        kbuf.clear();
        vbuf.clear();
        for &(g, begin, end) in &ranges {
            kbuf.extend_from_slice(&t.k[g][begin * d..end * d]);
            vbuf.extend_from_slice(&t.v[g][begin * d..end * d]);
        }
        tracer.record_since(
            Phase::Gather,
            gather_start,
            Attrs {
                bytes: Some(ledger[ci].gathered_kv_bytes),
                tile: Some(ci),
                ..Default::default()
            },
        );

        let exec_start = tracer.now();
        let mut off = 0usize;
        for &(g, begin, end) in &ranges {
            let width = end - begin;
            let mut part = Partial {
                group: g,
                m: vec![f32::NEG_INFINITY; gs],
                s: vec![0.0; gs],
                acc: vec![0.0; gs * d],
            };
            for tok in 0..width {
                let krow = &kbuf[(off + tok) * d..(off + tok + 1) * d];
                let vrow = &vbuf[(off + tok) * d..(off + tok + 1) * d];
                for qi in 0..gs {
                    let qrow = &t.q[g][qi * d..(qi + 1) * d];
                    let score =
                        qrow.iter().zip(krow).map(|(a, b)| a * b).sum::<f32>() * scale;
                    let m_new = part.m[qi].max(score);
                    let corr = (part.m[qi] - m_new).exp();
                    let w = (score - m_new).exp();
                    part.s[qi] = part.s[qi] * corr + w;
                    for di in 0..d {
                        let a = &mut part.acc[qi * d + di];
                        *a = *a * corr + w * vrow[di];
                    }
                    part.m[qi] = m_new;
                }
            }
            off += width;
            if width > 0 {
                partials.push(part);
            }
        }
        tracer.record_since(
            Phase::LeanExec,
            exec_start,
            Attrs {
                flops: Some(ledger[ci].softmax_flops),
                k: Some(cta.segments.len()),
                tile: Some(ci),
                ..Default::default()
            },
        );
        cta_us.push(wall0.elapsed().as_secs_f64() * 1e6);
    }

    // Rescale-fold the partials per group (any order — associative).
    let mut outputs = vec![vec![0.0f32; gs * d]; p.groups()];
    for g in 0..p.groups() {
        let mine: Vec<&Partial> = partials.iter().filter(|pt| pt.group == g).collect();
        for qi in 0..gs {
            let m_star = mine
                .iter()
                .map(|pt| pt.m[qi])
                .fold(f32::NEG_INFINITY, f32::max);
            if m_star == f32::NEG_INFINITY {
                continue;
            }
            let mut s_star = 0.0f32;
            let mut acc_star = vec![0.0f32; d];
            for pt in &mine {
                let corr = (pt.m[qi] - m_star).exp();
                s_star += pt.s[qi] * corr;
                for di in 0..d {
                    acc_star[di] += pt.acc[qi * d + di] * corr;
                }
            }
            for di in 0..d {
                outputs[g][qi * d + di] = acc_star[di] / s_star.max(f32::MIN_POSITIVE);
            }
        }
    }
    MeasuredPlan { cta_us, outputs }
}

/// Direct softmax attention per group — the exactness reference for
/// [`execute_plan_traced`]'s partial folding.
pub fn oracle(p: &DecodeProblem, t: &BalanceTensors) -> Vec<Vec<f32>> {
    let d = p.head_dim;
    let gs = p.group_size();
    let scale = 1.0 / (d as f32).sqrt();
    (0..p.groups())
        .map(|g| {
            let ctx = p.ctx_for_group(g);
            let mut out = vec![0.0f32; gs * d];
            for qi in 0..gs {
                let qrow = &t.q[g][qi * d..(qi + 1) * d];
                let scores: Vec<f32> = (0..ctx)
                    .map(|tok| {
                        let krow = &t.k[g][tok * d..(tok + 1) * d];
                        qrow.iter().zip(krow).map(|(a, b)| a * b).sum::<f32>() * scale
                    })
                    .collect();
                let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let ws: Vec<f32> = scores.iter().map(|&x| (x - m).exp()).collect();
                let s: f32 = ws.iter().sum();
                for (tok, &w) in ws.iter().enumerate() {
                    let vrow = &t.v[g][tok * d..(tok + 1) * d];
                    for di in 0..d {
                        out[qi * d + di] += w * vrow[di] / s;
                    }
                }
            }
            out
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::attrib::{
        account_cascade_problem, account_decode_problem, account_plan,
    };
    use crate::partition::cascade::{build_cascade_plan, PrefixGroup};

    fn ragged() -> DecodeProblem {
        DecodeProblem::ragged(4, vec![511, 64, 1290, 32, 777, 96, 2048, 130], 32)
    }

    #[test]
    fn plan_ledger_sums_bit_exact_to_account_plan() {
        let p = ragged();
        let arch = GpuArch::a100();
        for s in [
            Strategy::Dense,
            Strategy::fixed_split_auto(&p, arch.num_sms),
            Strategy::PagedFixedSplit { splits: 4, page: 16 },
            Strategy::StreamK,
        ] {
            let plan = build_plan(&p, s, 24);
            let ledger = plan_ledger(&p, &plan);
            assert_eq!(ledger.len(), plan.grid());
            let sum = ledger.iter().fold(WorkAccounting::default(), |a, &w| a + w);
            assert_eq!(sum, account_plan(&p, &plan), "strategy {}", s.name());
            assert_eq!(sum, account_decode_problem(&p), "strategy {}", s.name());
        }
    }

    #[test]
    fn cascade_ledger_sums_bit_exact_to_cascade_accounting() {
        let cp = CascadeProblem::new(
            2,
            vec![300, 300, 280, 90],
            16,
            vec![PrefixGroup { prefix_len: 256, members: vec![0, 1, 2] }],
        )
        .unwrap()
        .tile_aligned();
        let cplan = build_cascade_plan(&cp, 24);
        let ledger = cascade_ledger(&cp, &cplan);
        assert_eq!(ledger.len(), cplan.plan.grid());
        let sum = ledger.iter().fold(WorkAccounting::default(), |a, &w| a + w);
        assert_eq!(sum, account_cascade_problem(&cp));
    }

    #[test]
    fn stream_k_imbalance_below_fixed_split_on_ragged_batch() {
        let p = ragged();
        let arch = GpuArch::a100();
        let report = partition_report(&p, &arch);
        let lean = report.stream_k().unwrap();
        let fd = report
            .strategies
            .iter()
            .find(|s| s.strategy == "flashdecoding")
            .unwrap();
        assert!(
            lean.imbalance < fd.imbalance,
            "lean {} vs fd {}",
            lean.imbalance,
            fd.imbalance
        );
        assert!(lean.imbalance >= 1.0 && fd.imbalance >= 1.0);
        assert!(lean.wave_efficiency >= fd.wave_efficiency);
    }

    #[test]
    fn report_json_round_trips_and_validates() {
        let p = ragged();
        let report = partition_report(&p, &GpuArch::a100());
        let j = report.to_json();
        validate_partition_report(&j).expect("schema-valid");
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back, j);
        validate_partition_report(&back).expect("round-trip stays valid");
    }

    #[test]
    fn validator_rejects_tampered_ledgers() {
        let p = ragged();
        let report = partition_report(&p, &GpuArch::a100());
        let mut j = report.to_json();
        // Corrupt one ledger row's tile count: the bit-exact total check
        // must catch it.
        if let Json::Obj(root) = &mut j {
            let Some(Json::Arr(strategies)) = root.get_mut("strategies") else {
                panic!()
            };
            let Some(Json::Obj(s0)) = strategies.first_mut() else { panic!() };
            let Some(Json::Arr(ledger)) = s0.get_mut("ledger") else { panic!() };
            let Some(Json::Obj(row)) = ledger.first_mut() else { panic!() };
            let Some(Json::Obj(work)) = row.get_mut("work") else { panic!() };
            work.insert("tiles".to_string(), Json::Num(9999.0));
        }
        assert!(validate_partition_report(&j).is_err());
    }

    #[test]
    fn traced_execution_is_exact_and_joins_per_tile() {
        let p = DecodeProblem::ragged(2, vec![100, 37, 260], 16);
        let plan = build_plan(&p, Strategy::StreamK, 8);
        let t = BalanceTensors::random(&p, 7);
        let tracer = Tracer::enabled(256);
        let m = execute_plan_traced(&p, &plan, &t, &tracer);
        assert_eq!(m.cta_us.len(), plan.grid());
        let want = oracle(&p, &t);
        let mut max_err = 0.0f32;
        for (got, want) in m.outputs.iter().zip(&want) {
            for (a, b) in got.iter().zip(want) {
                max_err = max_err.max((a - b).abs());
            }
        }
        assert!(max_err < 1e-3, "partition fold drifted: {max_err}");

        let arch = GpuArch::a100();
        let mut b = plan_balance(&p, &plan, &arch);
        join_measured_events(&mut b, &tracer.events());
        assert!(
            b.ledger.iter().all(|r| r.measured_us.is_some()),
            "every CTA row joined a measured span"
        );
    }

    #[test]
    fn uniform_stream_k_is_nearly_level() {
        let p = DecodeProblem::uniform(1, 8, 65536, 64);
        let arch = GpuArch::a100();
        let plan = build_plan(&p, Strategy::StreamK, arch.sm_slots());
        let b = plan_balance(&p, &plan, &arch);
        assert!(b.imbalance < 1.10, "stream-K imbalance {}", b.imbalance);
        assert!(b.wave_efficiency > 0.90, "wave efficiency {}", b.wave_efficiency);
    }
}
