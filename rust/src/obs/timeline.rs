//! Per-request lifecycle timelines and the serving SLO report.
//!
//! Every finished request contributes one [`RequestTimeline`] — its
//! submit → admit → first-token → finish phases — to a
//! [`TimelineRecorder`]: bounded-memory [`LogHistogram`]s over queue
//! wait, TTFT, end-to-end latency and inter-token gaps, plus a small
//! ring of the newest raw timelines for inspection. At report time the
//! recorder folds into an [`SloReport`] — TTFT/e2e percentiles, goodput
//! (within-SLO finishes per second) and SLO attainment at a `--slo-ms`
//! target — the iteration-level serving accounting of arXiv 2407.09111
//! that `examples/load_test.rs` and `leanattn serve --slo-ms` print.

use super::hist::LogHistogram;

/// Raw timelines kept for inspection (newest win on overflow).
const RECENT_CAP: usize = 64;

/// One request's lifecycle, microseconds per phase.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RequestTimeline {
    pub id: u64,
    /// Submit → admission into a batch slot (queue wait).
    pub queue_us: f64,
    /// Admission → first token (prefill).
    pub prefill_us: f64,
    /// First token → finish.
    pub decode_us: f64,
    /// Tokens generated.
    pub tokens: usize,
}

impl RequestTimeline {
    /// Time to first token: queue wait plus prefill.
    pub fn ttft_us(&self) -> f64 {
        self.queue_us + self.prefill_us
    }

    /// End-to-end latency.
    pub fn e2e_us(&self) -> f64 {
        self.queue_us + self.prefill_us + self.decode_us
    }

    /// Mean inter-token gap after the first token (0 for single-token
    /// outputs).
    pub fn inter_token_us(&self) -> f64 {
        if self.tokens <= 1 {
            0.0
        } else {
            self.decode_us / (self.tokens - 1) as f64
        }
    }
}

/// Bounded-memory aggregation of request lifecycles.
#[derive(Clone, Debug, Default)]
pub struct TimelineRecorder {
    queue_us: LogHistogram,
    ttft_us: LogHistogram,
    e2e_us: LogHistogram,
    inter_token_us: LogHistogram,
    requests: u64,
    tokens: u64,
    recent: Vec<RequestTimeline>,
}

impl TimelineRecorder {
    pub fn observe(&mut self, t: RequestTimeline) {
        self.queue_us.record(t.queue_us);
        self.ttft_us.record(t.ttft_us());
        self.e2e_us.record(t.e2e_us());
        if t.tokens > 1 {
            self.inter_token_us.record(t.inter_token_us());
        }
        self.requests += 1;
        self.tokens += t.tokens as u64;
        if self.recent.len() == RECENT_CAP {
            self.recent.remove(0);
        }
        self.recent.push(t);
    }

    pub fn requests(&self) -> u64 {
        self.requests
    }

    pub fn tokens(&self) -> u64 {
        self.tokens
    }

    /// The newest observed timelines (bounded; oldest first).
    pub fn recent(&self) -> &[RequestTimeline] {
        &self.recent
    }

    pub fn ttft(&self) -> &LogHistogram {
        &self.ttft_us
    }

    pub fn e2e(&self) -> &LogHistogram {
        &self.e2e_us
    }

    /// Fold another recorder in (multi-replica aggregation).
    pub fn merge(&mut self, other: &TimelineRecorder) {
        self.queue_us.merge(&other.queue_us);
        self.ttft_us.merge(&other.ttft_us);
        self.e2e_us.merge(&other.e2e_us);
        self.inter_token_us.merge(&other.inter_token_us);
        self.requests += other.requests;
        self.tokens += other.tokens;
        for t in &other.recent {
            if self.recent.len() == RECENT_CAP {
                self.recent.remove(0);
            }
            self.recent.push(*t);
        }
    }

    /// Aggregate into the serving SLO report: attainment is the fraction
    /// of requests whose **end-to-end** latency met `slo_ms`, goodput the
    /// within-SLO finishes per second of `wall_s`.
    pub fn slo_report(&self, slo_ms: f64, wall_s: f64) -> SloReport {
        let attainment = self.e2e_us.fraction_le(slo_ms * 1e3);
        let goodput_rps = if wall_s > 0.0 {
            attainment * self.requests as f64 / wall_s
        } else {
            0.0
        };
        let tokens_per_s =
            if wall_s > 0.0 { self.tokens as f64 / wall_s } else { 0.0 };
        SloReport {
            requests: self.requests,
            tokens: self.tokens,
            wall_s,
            slo_ms,
            queue_ms: Quantiles::of(&self.queue_us, 1e-3),
            ttft_ms: Quantiles::of(&self.ttft_us, 1e-3),
            e2e_ms: Quantiles::of(&self.e2e_us, 1e-3),
            inter_token_ms: Quantiles::of(&self.inter_token_us, 1e-3),
            attainment,
            goodput_rps,
            tokens_per_s,
        }
    }
}

/// p50/p95/p99/p999 pulled out of one histogram (scaled, e.g. us → ms).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Quantiles {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub p999: f64,
}

impl Quantiles {
    pub fn of(h: &LogHistogram, scale: f64) -> Quantiles {
        Quantiles {
            p50: h.quantile(0.5) * scale,
            p95: h.quantile(0.95) * scale,
            p99: h.quantile(0.99) * scale,
            p999: h.quantile(0.999) * scale,
        }
    }
}

/// The exportable serving SLO report.
#[derive(Clone, Debug)]
pub struct SloReport {
    pub requests: u64,
    pub tokens: u64,
    pub wall_s: f64,
    /// The end-to-end latency target attainment is measured against.
    pub slo_ms: f64,
    pub queue_ms: Quantiles,
    pub ttft_ms: Quantiles,
    pub e2e_ms: Quantiles,
    pub inter_token_ms: Quantiles,
    /// Fraction of requests with e2e latency <= `slo_ms`.
    pub attainment: f64,
    /// Within-SLO finishes per second of wall clock.
    pub goodput_rps: f64,
    pub tokens_per_s: f64,
}

impl SloReport {
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "serving SLO report: {} requests, {} tokens in {:.2}s \
             ({:.1} req/s offered-finish rate, {:.1} tok/s)\n",
            self.requests,
            self.tokens,
            self.wall_s,
            if self.wall_s > 0.0 { self.requests as f64 / self.wall_s } else { 0.0 },
            self.tokens_per_s,
        ));
        let row = |name: &str, q: &Quantiles| {
            format!(
                "  {:<9} p50={:.1} p95={:.1} p99={:.1} p999={:.1}\n",
                name, q.p50, q.p95, q.p99, q.p999
            )
        };
        s.push_str(&row("queue_ms", &self.queue_ms));
        s.push_str(&row("ttft_ms", &self.ttft_ms));
        s.push_str(&row("e2e_ms", &self.e2e_ms));
        s.push_str(&row("tpot_ms", &self.inter_token_ms));
        s.push_str(&format!(
            "  SLO (e2e <= {:.0} ms): {:.1}% attained, goodput {:.2} req/s\n",
            self.slo_ms,
            self.attainment * 100.0,
            self.goodput_rps,
        ));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(id: u64, queue: f64, prefill: f64, decode: f64, tokens: usize) -> RequestTimeline {
        RequestTimeline {
            id,
            queue_us: queue,
            prefill_us: prefill,
            decode_us: decode,
            tokens,
        }
    }

    #[test]
    fn timeline_derived_phases() {
        let tl = t(1, 100.0, 400.0, 900.0, 10);
        assert_eq!(tl.ttft_us(), 500.0);
        assert_eq!(tl.e2e_us(), 1400.0);
        assert_eq!(tl.inter_token_us(), 100.0);
        assert_eq!(t(2, 0.0, 1.0, 0.0, 1).inter_token_us(), 0.0);
    }

    #[test]
    fn recorder_counts_and_bounds_recents() {
        let mut r = TimelineRecorder::default();
        for i in 0..(RECENT_CAP as u64 + 10) {
            r.observe(t(i, 10.0, 20.0, 30.0, 4));
        }
        assert_eq!(r.requests(), RECENT_CAP as u64 + 10);
        assert_eq!(r.tokens(), (RECENT_CAP as u64 + 10) * 4);
        assert_eq!(r.recent().len(), RECENT_CAP);
        assert_eq!(r.recent()[0].id, 10, "newest timelines survive");
    }

    #[test]
    fn slo_attainment_splits_fast_and_slow() {
        let mut r = TimelineRecorder::default();
        // 8 fast requests (~2ms e2e), 2 slow (~2s e2e).
        for i in 0..8 {
            r.observe(t(i, 100.0, 400.0, 1500.0, 8));
        }
        for i in 8..10 {
            r.observe(t(i, 100.0, 400.0, 2_000_000.0, 8));
        }
        let rep = r.slo_report(50.0, 4.0);
        assert_eq!(rep.requests, 10);
        assert!(
            (rep.attainment - 0.8).abs() < 0.05,
            "attainment {}",
            rep.attainment
        );
        assert!((rep.goodput_rps - 2.0).abs() < 0.15, "{}", rep.goodput_rps);
        assert!(rep.e2e_ms.p50 < 50.0 && rep.e2e_ms.p999 > 1000.0);
        let out = rep.render();
        assert!(out.contains("serving SLO report"), "{out}");
        assert!(out.contains("ttft_ms"), "{out}");
        assert!(out.contains("goodput"), "{out}");
    }

    #[test]
    fn merge_combines_replicas() {
        let (mut a, mut b) = (TimelineRecorder::default(), TimelineRecorder::default());
        a.observe(t(1, 1.0, 2.0, 3.0, 2));
        b.observe(t(2, 10.0, 20.0, 30.0, 5));
        a.merge(&b);
        assert_eq!(a.requests(), 2);
        assert_eq!(a.tokens(), 7);
        assert_eq!(a.recent().len(), 2);
    }

    #[test]
    fn empty_recorder_reports_safely() {
        let rep = TimelineRecorder::default().slo_report(100.0, 0.0);
        assert_eq!(rep.requests, 0);
        assert_eq!(rep.goodput_rps, 0.0);
        assert_eq!(rep.attainment, 1.0, "vacuous SLO holds");
        assert!(rep.render().contains("0 requests"));
    }
}
