//! Anomaly flight recorder: post-mortem bundles for a serving engine.
//!
//! When a trigger condition fires — SLO breach, eviction storm, audit
//! failure, watchdog stall — the engine freezes its observability state
//! and the recorder writes a **bundle** directory under the configured
//! flight dir: the Chrome trace-event export of the trace ring, the
//! metrics snapshot JSON, the cache introspection report, the rendered
//! SLO/timeline report, and a manifest naming the trigger and step. The
//! bundle is exactly what a human needs to answer "what was the engine
//! doing when it went sideways" after the process is gone.
//!
//! Bundles are capped per recorder ([`FlightRecorder::MAX_BUNDLES`]) so
//! a flapping trigger cannot fill the disk; suppressed recordings are
//! still counted. [`validate_bundle`] re-validates a bundle from disk
//! against the same schema validators the exporters are tested with —
//! the e2e check that what the recorder wrote is what a reader gets.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{ensure, Context, Result};

use crate::util::json::Json;

use super::cache_stats::validate_cache_report;
use super::snapshot::SNAPSHOT_VERSION;
use super::tracer::validate_chrome_trace;

/// Version stamp of the bundle manifest.
pub const FLIGHT_MANIFEST_VERSION: u64 = 1;

/// Why a bundle was recorded.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlightTrigger {
    /// A finished request blew through the configured latency target.
    SloBreach,
    /// One step evicted at least the storm threshold of prefix pages.
    EvictionStorm,
    /// An online invariant audit failed.
    AuditFailure,
    /// The watchdog saw the stall threshold of progress-free steps.
    WatchdogStall,
    /// The online cost-model drift detector saw a sustained breach of
    /// its relative-error limit (`serve --drift-limit`).
    Drift,
}

impl FlightTrigger {
    pub const ALL: [FlightTrigger; 5] = [
        FlightTrigger::SloBreach,
        FlightTrigger::EvictionStorm,
        FlightTrigger::AuditFailure,
        FlightTrigger::WatchdogStall,
        FlightTrigger::Drift,
    ];

    /// Stable name used in manifests and bundle directory names.
    pub fn as_str(self) -> &'static str {
        match self {
            FlightTrigger::SloBreach => "slo_breach",
            FlightTrigger::EvictionStorm => "eviction_storm",
            FlightTrigger::AuditFailure => "audit_failure",
            FlightTrigger::WatchdogStall => "watchdog_stall",
            FlightTrigger::Drift => "drift",
        }
    }

    pub fn parse(s: &str) -> Option<FlightTrigger> {
        FlightTrigger::ALL.iter().copied().find(|t| t.as_str() == s)
    }
}

/// Everything one bundle freezes. The engine assembles this from its
/// live observability state at trigger time.
pub struct FlightSnapshot<'a> {
    /// Chrome trace-event export of the trace ring.
    pub trace: &'a Json,
    /// `MetricsSnapshot::to_json()` of the engine snapshot.
    pub metrics: &'a Json,
    /// `CacheReport::to_json()` of the cache introspection report.
    pub cache_report: &'a Json,
    /// Rendered SLO / timeline report (human-readable post-mortem text).
    pub slo_text: &'a str,
}

/// Writes post-mortem bundles under a directory.
pub struct FlightRecorder {
    dir: PathBuf,
    /// Bundles written (also the next bundle's sequence number).
    written: u64,
    /// Trigger firings seen, including suppressed ones.
    triggers: u64,
}

impl FlightRecorder {
    /// Bundle cap per recorder: a flapping trigger must not fill disk.
    pub const MAX_BUNDLES: u64 = 8;

    pub fn new(dir: impl Into<PathBuf>) -> FlightRecorder {
        FlightRecorder { dir: dir.into(), written: 0, triggers: 0 }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Bundles written so far.
    pub fn bundles(&self) -> u64 {
        self.written
    }

    /// Trigger firings observed (written + suppressed).
    pub fn triggers(&self) -> u64 {
        self.triggers
    }

    /// Record one bundle. Returns the bundle directory, or `None` when
    /// the bundle cap suppressed the write (the firing is still
    /// counted).
    pub fn record(
        &mut self,
        trigger: FlightTrigger,
        step: u64,
        snap: &FlightSnapshot,
    ) -> Result<Option<PathBuf>> {
        self.triggers += 1;
        if self.written >= Self::MAX_BUNDLES {
            return Ok(None);
        }
        let bundle = self
            .dir
            .join(format!("flight-{:04}-{}", self.written, trigger.as_str()));
        std::fs::create_dir_all(&bundle)
            .with_context(|| format!("create flight bundle {}", bundle.display()))?;

        let mut manifest = BTreeMap::new();
        manifest
            .insert("version".to_string(), Json::Num(FLIGHT_MANIFEST_VERSION as f64));
        manifest
            .insert("trigger".to_string(), Json::Str(trigger.as_str().to_string()));
        manifest.insert("step".to_string(), Json::Num(step as f64));
        manifest.insert(
            "files".to_string(),
            Json::Arr(
                ["manifest.json", "trace.json", "metrics.json", "cache_report.json", "slo.txt"]
                    .iter()
                    .map(|f| Json::Str((*f).to_string()))
                    .collect(),
            ),
        );

        let writes: [(&str, String); 5] = [
            ("manifest.json", Json::Obj(manifest).to_string()),
            ("trace.json", snap.trace.to_string()),
            ("metrics.json", snap.metrics.to_string()),
            ("cache_report.json", snap.cache_report.to_string()),
            ("slo.txt", snap.slo_text.to_string()),
        ];
        for (name, text) in &writes {
            let path = bundle.join(name);
            std::fs::write(&path, text)
                .with_context(|| format!("write {}", path.display()))?;
        }
        self.written += 1;
        Ok(Some(bundle))
    }
}

/// Validate a `MetricsSnapshot::to_json()` export: versioned, with
/// `metrics` and `kinds` objects naming exactly the same series and
/// every kind a known one.
pub fn validate_snapshot_json(snap: &Json) -> Result<()> {
    ensure!(snap.as_obj().is_some(), "metrics snapshot must be a JSON object");
    let version = snap
        .get("version")
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow::anyhow!("snapshot missing version"))?;
    ensure!(
        version == SNAPSHOT_VERSION as f64,
        "snapshot version {version} != {SNAPSHOT_VERSION}"
    );
    let metrics = snap
        .get("metrics")
        .and_then(Json::as_obj)
        .ok_or_else(|| anyhow::anyhow!("snapshot missing metrics object"))?;
    let kinds = snap
        .get("kinds")
        .and_then(Json::as_obj)
        .ok_or_else(|| anyhow::anyhow!("snapshot missing kinds object"))?;
    ensure!(
        metrics.len() == kinds.len(),
        "snapshot metrics/kinds disagree on series count"
    );
    for (name, v) in metrics {
        ensure!(v.as_f64().is_some(), "metric {name} is not a number");
        let kind = kinds
            .get(name)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("metric {name} has no kind"))?;
        ensure!(
            kind == "counter" || kind == "gauge",
            "metric {name} has unknown kind {kind:?}"
        );
    }
    Ok(())
}

/// Re-validate a bundle directory from disk: manifest shape, the trace
/// against the Chrome trace-event schema, the metrics snapshot against
/// the snapshot schema, and the cache report against its schema.
pub fn validate_bundle(dir: &Path) -> Result<()> {
    let read = |name: &str| -> Result<Json> {
        let path = dir.join(name);
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        Json::parse(&text).with_context(|| format!("parse {}", path.display()))
    };
    let manifest = read("manifest.json")?;
    let version = manifest
        .get("version")
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow::anyhow!("manifest missing version"))?;
    ensure!(
        version == FLIGHT_MANIFEST_VERSION as f64,
        "manifest version {version} != {FLIGHT_MANIFEST_VERSION}"
    );
    let trigger = manifest
        .get("trigger")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("manifest missing trigger"))?;
    ensure!(
        FlightTrigger::parse(trigger).is_some(),
        "manifest trigger {trigger:?} is not a known trigger"
    );
    ensure!(
        manifest.get("step").and_then(Json::as_f64).is_some(),
        "manifest missing step"
    );

    validate_chrome_trace(&read("trace.json")?).context("bundle trace.json")?;
    validate_snapshot_json(&read("metrics.json")?).context("bundle metrics.json")?;
    validate_cache_report(&read("cache_report.json")?)
        .context("bundle cache_report.json")?;
    ensure!(
        dir.join("slo.txt").exists(),
        "bundle missing slo.txt"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::cache_stats::{CacheReport, HeatTracker};
    use crate::obs::snapshot::MetricsSnapshot;
    use crate::obs::tracer::{Attrs, Phase, Tracer};

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "leanattn-flight-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn demo_snapshot() -> (Json, Json, Json) {
        let t = Tracer::enabled(16);
        t.instant(Phase::Evict, Attrs { pages: Some(4), ..Default::default() });
        let trace = t.export_chrome_trace();
        let mut s = MetricsSnapshot::default();
        s.counter("decode_steps_total", 12.0, "steps");
        s.gauge("kv_pages_used", 3.0, "pages");
        let heat = HeatTracker::enabled(4);
        let report = CacheReport::build(&[1, 2, 0, 0], &heat, 4, 16, None, 2);
        (trace, s.to_json(), report.to_json())
    }

    #[test]
    fn bundle_round_trips_through_the_validators() {
        let dir = tmp_dir("roundtrip");
        let mut rec = FlightRecorder::new(&dir);
        let (trace, metrics, cache) = demo_snapshot();
        let snap = FlightSnapshot {
            trace: &trace,
            metrics: &metrics,
            cache_report: &cache,
            slo_text: "serving SLO report: demo",
        };
        let bundle = rec
            .record(FlightTrigger::EvictionStorm, 7, &snap)
            .expect("record")
            .expect("under the cap");
        assert!(bundle.ends_with("flight-0000-eviction_storm"));
        validate_bundle(&bundle).expect("bundle re-validates from disk");
        assert_eq!(rec.bundles(), 1);
        assert_eq!(rec.triggers(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bundle_cap_suppresses_but_keeps_counting() {
        let dir = tmp_dir("cap");
        let mut rec = FlightRecorder::new(&dir);
        let (trace, metrics, cache) = demo_snapshot();
        let snap = FlightSnapshot {
            trace: &trace,
            metrics: &metrics,
            cache_report: &cache,
            slo_text: "x",
        };
        for i in 0..FlightRecorder::MAX_BUNDLES + 3 {
            let got = rec.record(FlightTrigger::WatchdogStall, i, &snap).unwrap();
            assert_eq!(got.is_some(), i < FlightRecorder::MAX_BUNDLES);
        }
        assert_eq!(rec.bundles(), FlightRecorder::MAX_BUNDLES);
        assert_eq!(rec.triggers(), FlightRecorder::MAX_BUNDLES + 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn validator_rejects_a_tampered_bundle() {
        let dir = tmp_dir("tamper");
        let mut rec = FlightRecorder::new(&dir);
        let (trace, metrics, cache) = demo_snapshot();
        let snap = FlightSnapshot {
            trace: &trace,
            metrics: &metrics,
            cache_report: &cache,
            slo_text: "x",
        };
        let bundle = rec
            .record(FlightTrigger::AuditFailure, 1, &snap)
            .unwrap()
            .unwrap();
        std::fs::write(bundle.join("trace.json"), "[{\"name\":\"nope\"}]").unwrap();
        assert!(validate_bundle(&bundle).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshot_validator_checks_kinds() {
        let mut s = MetricsSnapshot::default();
        s.counter("a_total", 1.0, "a");
        let j = s.to_json();
        validate_snapshot_json(&j).unwrap();
        let mut bad = j.clone();
        if let Json::Obj(o) = &mut bad {
            if let Some(Json::Obj(kinds)) = o.get_mut("kinds") {
                kinds.insert("a_total".into(), Json::Str("mystery".into()));
            }
        }
        assert!(validate_snapshot_json(&bad).is_err());
    }
}
