//! Cost-model calibration: fit [`CostCoefficients`] from traced runs.
//!
//! `leanattn calibrate` runs the host cascade executor over one
//! workload per partitioning strategy — flat, cascade (shared prefix),
//! sparse (page-compacted), multi-query (draft blocks), and GQA — with
//! the PR 6 tracer enabled, joins each run's `gather` + `lean_exec`
//! span durations with the exact [`WorkAccounting`] of the same
//! problem, and least-squares-fits the three-coefficient linear cost
//! model (ns/byte gathered, ns/flop, fixed ns/tile). The residual per
//! strategy is the **sim-vs-measured drift report**: it turns "the
//! simulator says" into "the simulator is within X% of measured, and
//! here is the residual per strategy".
//!
//! Everything here is artifact-free (host executor only) and
//! deterministic in shape — only the measured wall-clock varies run to
//! run, which is why the fit takes the **minimum** over iterations of
//! each point's traced phase time.

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use crate::partition::cascade::{
    build_cascade_plan, CascadeProblem, CascadeTensors, PrefixGroup,
};
use crate::partition::multi_query::{MultiQueryInputs, MultiQueryProblem, MultiQuerySeq};
use crate::runtime::attention_exec::{lean_cascade_host_traced, sparse_compact_problem};
use crate::sim::CostCoefficients;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::attrib::{account_cascade_problem, WorkAccounting};
use super::tracer::{Phase, Tracer};

/// Calibration workload shape.
#[derive(Clone, Copy, Debug)]
pub struct CalibrationCase {
    /// Timing iterations per point (the minimum is the measurement).
    pub iters: usize,
    /// Context-length scale: point `i` of each strategy uses roughly
    /// `scale << i` tokens per lane.
    pub scale: usize,
    /// CTA slots handed to the planner.
    pub slots: usize,
    /// Partial-bucket row capacity of the host executor.
    pub batch_rows: usize,
}

impl CalibrationCase {
    pub fn default_case() -> CalibrationCase {
        CalibrationCase { iters: 7, scale: 512, slots: 24, batch_rows: 64 }
    }

    /// CI-sized shape: same strategy coverage, smaller contexts.
    pub fn smoke() -> CalibrationCase {
        CalibrationCase { iters: 3, scale: 192, slots: 24, batch_rows: 64 }
    }
}

/// One (strategy, shape) sample: exact work joined with the traced
/// minimum phase time of the host executor.
#[derive(Clone, Debug)]
pub struct CalibrationPoint {
    /// Strategy name (`flat`, `cascade`, `sparse`, `multi-query`, `gqa`).
    pub strategy: &'static str,
    /// Human-readable shape label.
    pub shape: String,
    /// Exact accounting of the point's problem.
    pub work: WorkAccounting,
    /// Min over iterations of traced `gather` + `lean_exec` time, µs.
    pub measured_us: f64,
}

/// Per-strategy relative-error breakdown of the fitted model.
#[derive(Clone, Debug)]
pub struct StrategyDrift {
    pub strategy: &'static str,
    pub points: usize,
    pub mean_rel_err: f64,
    pub max_rel_err: f64,
}

/// The calibration outcome: fitted coefficients plus the per-point
/// drift they leave behind.
#[derive(Clone, Debug)]
pub struct CalibrationReport {
    pub case: CalibrationCase,
    pub coefficients: CostCoefficients,
    pub points: Vec<CalibrationPoint>,
}

impl CalibrationReport {
    /// Relative error of the fitted prediction for one point.
    pub fn rel_err(&self, p: &CalibrationPoint) -> f64 {
        let pred = self.coefficients.predict_us(&p.work);
        (pred - p.measured_us).abs() / p.measured_us.max(1e-9)
    }

    /// Per-strategy drift rows, in first-seen order.
    pub fn per_strategy(&self) -> Vec<StrategyDrift> {
        let mut order: Vec<&'static str> = Vec::new();
        let mut errs: BTreeMap<&'static str, Vec<f64>> = BTreeMap::new();
        for p in &self.points {
            if !errs.contains_key(p.strategy) {
                order.push(p.strategy);
            }
            errs.entry(p.strategy).or_default().push(self.rel_err(p));
        }
        order
            .into_iter()
            .map(|s| {
                let e = &errs[s];
                StrategyDrift {
                    strategy: s,
                    points: e.len(),
                    mean_rel_err: e.iter().sum::<f64>() / e.len() as f64,
                    max_rel_err: e.iter().copied().fold(0.0, f64::max),
                }
            })
            .collect()
    }

    /// Worst relative error across every strategy and point.
    pub fn max_rel_err(&self) -> f64 {
        self.points.iter().map(|p| self.rel_err(p)).fold(0.0, f64::max)
    }

    /// Human-readable drift report (the `leanattn calibrate` output).
    pub fn render(&self) -> String {
        let c = self.coefficients;
        let mut s = format!(
            "fitted cost model: t_ns = {:.4} ns/byte + {:.6} ns/flop + {:.1} ns/tile\n\n",
            c.ns_per_byte, c.ns_per_flop, c.tile_overhead_ns
        );
        s.push_str(&format!(
            "{:<12} {:>8} {:>12} {:>12} {:>12} {:>10}\n",
            "strategy", "shape", "bytes", "measured_us", "predicted_us", "rel_err"
        ));
        for p in &self.points {
            s.push_str(&format!(
                "{:<12} {:>8} {:>12} {:>12.1} {:>12.1} {:>9.1}%\n",
                p.strategy,
                p.shape,
                p.work.gathered_kv_bytes,
                p.measured_us,
                self.coefficients.predict_us(&p.work),
                self.rel_err(p) * 100.0
            ));
        }
        s.push_str("\nper-strategy drift (sim vs measured):\n");
        for d in self.per_strategy() {
            s.push_str(&format!(
                "  {:<12} {} points  mean {:>5.1}%  max {:>5.1}%\n",
                d.strategy,
                d.points,
                d.mean_rel_err * 100.0,
                d.max_rel_err * 100.0
            ));
        }
        s
    }

    /// Machine-readable report for `calibrate --json-out`.
    pub fn to_json(&self) -> Json {
        let mut coef = BTreeMap::new();
        coef.insert("ns_per_byte".to_string(), Json::Num(self.coefficients.ns_per_byte));
        coef.insert("ns_per_flop".to_string(), Json::Num(self.coefficients.ns_per_flop));
        coef.insert(
            "tile_overhead_ns".to_string(),
            Json::Num(self.coefficients.tile_overhead_ns),
        );
        let points: Vec<Json> = self
            .points
            .iter()
            .map(|p| {
                let mut o = BTreeMap::new();
                o.insert("strategy".to_string(), Json::Str(p.strategy.to_string()));
                o.insert("shape".to_string(), Json::Str(p.shape.clone()));
                o.insert("work".to_string(), p.work.to_json());
                o.insert("measured_us".to_string(), Json::Num(p.measured_us));
                o.insert(
                    "predicted_us".to_string(),
                    Json::Num(self.coefficients.predict_us(&p.work)),
                );
                o.insert("rel_err".to_string(), Json::Num(self.rel_err(p)));
                Json::Obj(o)
            })
            .collect();
        let drift: Vec<Json> = self
            .per_strategy()
            .iter()
            .map(|d| {
                let mut o = BTreeMap::new();
                o.insert("strategy".to_string(), Json::Str(d.strategy.to_string()));
                o.insert("points".to_string(), Json::Num(d.points as f64));
                o.insert("mean_rel_err".to_string(), Json::Num(d.mean_rel_err));
                o.insert("max_rel_err".to_string(), Json::Num(d.max_rel_err));
                Json::Obj(o)
            })
            .collect();
        let mut o = BTreeMap::new();
        o.insert("version".to_string(), Json::Num(1.0));
        o.insert("coefficients".to_string(), Json::Obj(coef));
        o.insert("points".to_string(), Json::Arr(points));
        o.insert("per_strategy".to_string(), Json::Arr(drift));
        o.insert("max_rel_err".to_string(), Json::Num(self.max_rel_err()));
        Json::Obj(o)
    }
}

/// Measure one cascade problem on the host executor: run it traced
/// `iters + 1` times (first is warmup) and take the minimum over
/// iterations of the `gather` + `lean_exec` span durations — the PR 6
/// tracer is the clock, so the calibration measures exactly the phases
/// the serving engine traces.
fn measure_point(
    cp: &CascadeProblem,
    t: &CascadeTensors,
    case: &CalibrationCase,
    strategy: &'static str,
    shape: String,
) -> CalibrationPoint {
    let cplan = build_cascade_plan(cp, case.slots);
    let work = account_cascade_problem(cp);
    let tracer = Tracer::enabled(2 * (case.iters + 2));
    for _ in 0..=case.iters {
        let _ = lean_cascade_host_traced(cp, t, &cplan, case.batch_rows, &tracer);
    }
    let events = tracer.events();
    // Events arrive as (gather, lean_exec) pairs per call; drop the
    // warmup pair and fold each remaining pair into one sample.
    let mut samples = Vec::new();
    let mut pending_gather = None;
    for ev in &events {
        match ev.phase {
            Phase::Gather => {
                // The accounting and the traced gather bytes come from
                // the same function — drift is impossible, assert it.
                debug_assert_eq!(ev.attrs.bytes, Some(work.gathered_kv_bytes));
                pending_gather = Some(ev.dur_us);
            }
            Phase::LeanExec => {
                if let Some(g) = pending_gather.take() {
                    samples.push(g + ev.dur_us);
                }
            }
            _ => {}
        }
    }
    let measured_us = samples
        .iter()
        .skip(1) // warmup
        .copied()
        .fold(f64::INFINITY, f64::min)
        .min(samples.last().copied().unwrap_or(f64::INFINITY));
    CalibrationPoint { strategy, shape, work, measured_us }
}

/// The traced pseudo-serving workloads, one per strategy, three
/// context scales each. Shapes are chosen to decorrelate the three
/// cost columns: tile size varies (tiles per byte), query fan-out
/// varies (flops per byte: GQA groups, cascade members, draft rows).
fn workload_points(case: &CalibrationCase, seed: u64) -> Result<Vec<CalibrationPoint>> {
    let d = 32;
    let mut points = Vec::new();
    for i in 0..3u32 {
        let ctx = (case.scale << i) as u32;
        let tile = [32usize, 64, 128][i as usize];
        let shape = format!("x{}", 1u32 << i);

        // flat: 4 independent lanes, ungrouped (queries = 1 per stream).
        let flat = CascadeProblem::new(
            4,
            vec![ctx, ctx + 7, ctx / 2 + 3, ctx],
            d,
            Vec::new(),
        )?
        .with_tile(tile);
        let t = CascadeTensors::random(&flat, seed ^ u64::from(i));
        points.push(measure_point(&flat, &t, case, "flat", shape.clone()));

        // cascade: two prefix groups over 4 lanes (shared streams serve
        // 2 query rows each).
        let cascade = CascadeProblem::new(
            4,
            vec![ctx, ctx, ctx + 5, ctx + 5],
            d,
            vec![
                PrefixGroup { prefix_len: ctx / 2, members: vec![0, 1] },
                PrefixGroup { prefix_len: ctx / 4, members: vec![2, 3] },
            ],
        )?
        .with_tile(tile)
        .tile_aligned();
        let t = CascadeTensors::random(&cascade, seed ^ 0x10 ^ u64::from(i));
        points.push(measure_point(&cascade, &t, case, "cascade", shape.clone()));

        // gqa: 4 query heads over 1 KV head (queries = 4 per stream).
        let gqa = CascadeProblem::new(4, vec![ctx, ctx + 9], d, Vec::new())?
            .with_tile(tile)
            .with_kv_heads(1);
        let t = CascadeTensors::random(&gqa, seed ^ 0x20 ^ u64::from(i));
        points.push(measure_point(&gqa, &t, case, "gqa", shape.clone()));

        // multi-query: 2 draft blocks of 5 rows sharing their base
        // context (the spec-verify shape).
        let mq = MultiQueryProblem {
            heads: 4,
            kv_heads: 4,
            head_dim: d,
            seqs: vec![
                MultiQuerySeq { base_len: ctx as usize, q_len: 5 },
                MultiQuerySeq { base_len: ctx as usize / 2, q_len: 5 },
            ],
            tile,
            families: Vec::new(),
        };
        let inputs = MultiQueryInputs::random(&mq, seed ^ 0x30 ^ u64::from(i));
        let (mq_cp, mq_t) = mq.tensors(&inputs)?;
        points.push(measure_point(&mq_cp, &mq_t, case, "multi-query", shape.clone()));

        // sparse: 2 lanes, every other 16-token page selected — the
        // compacted problem the engine's sparse decode executes.
        let page = 16usize;
        let n = ctx as usize;
        let lens = vec![ctx, ctx - (ctx / 3)];
        let mut rng = Rng::new(seed ^ 0x40 ^ u64::from(i));
        let q = rng.normal_vec(2 * 4 * d);
        let k = rng.normal_vec(2 * 4 * n * d);
        let v = rng.normal_vec(2 * 4 * n * d);
        let selections: Vec<Vec<usize>> = lens
            .iter()
            .map(|&l| (0..(l as usize).div_ceil(page)).step_by(2).collect())
            .collect();
        let (sp_cp, sp_t) = sparse_compact_problem(
            &q, &k, &v, &lens, 4, 4, n, d, page, &selections, tile,
        )?;
        points.push(measure_point(&sp_cp, &sp_t, case, "sparse", shape.clone()));
    }
    Ok(points)
}

/// Non-negative least squares over the three work columns (bytes,
/// flops, tiles) against measured nanoseconds: solve the normal
/// equations, and while any active coefficient fits negative, clamp it
/// to zero and refit the rest (physical costs cannot be negative).
fn fit(points: &[CalibrationPoint]) -> CostCoefficients {
    let row = |p: &CalibrationPoint| {
        [
            p.work.gathered_kv_bytes as f64,
            p.work.softmax_flops as f64,
            p.work.tiles as f64,
        ]
    };
    let mut active = [true; 3];
    loop {
        // Normal equations over the active columns.
        let cols: Vec<usize> = (0..3).filter(|&c| active[c]).collect();
        if cols.is_empty() {
            return CostCoefficients::default();
        }
        let n = cols.len();
        let mut ata = vec![vec![0.0f64; n]; n];
        let mut aty = vec![0.0f64; n];
        for p in points {
            let r = row(p);
            let y = p.measured_us * 1e3; // ns
            for (a, &ca) in cols.iter().enumerate() {
                aty[a] += r[ca] * y;
                for (b, &cb) in cols.iter().enumerate() {
                    ata[a][b] += r[ca] * r[cb];
                }
            }
        }
        // Gaussian elimination with partial pivoting.
        let mut x = vec![0.0f64; n];
        let mut singular = false;
        for col in 0..n {
            let piv = (col..n)
                .max_by(|&a, &b| ata[a][col].abs().total_cmp(&ata[b][col].abs()))
                .unwrap();
            if ata[piv][col].abs() < 1e-12 {
                singular = true;
                break;
            }
            ata.swap(col, piv);
            aty.swap(col, piv);
            for r in col + 1..n {
                let f = ata[r][col] / ata[col][col];
                for c in col..n {
                    ata[r][c] -= f * ata[col][c];
                }
                aty[r] -= f * aty[col];
            }
        }
        if singular {
            // Drop the last active column and retry.
            active[*cols.last().unwrap()] = false;
            continue;
        }
        for r in (0..n).rev() {
            let mut s = aty[r];
            for c in r + 1..n {
                s -= ata[r][c] * x[c];
            }
            x[r] = s / ata[r][r];
        }
        let mut coefs = [0.0f64; 3];
        for (i, &c) in cols.iter().enumerate() {
            coefs[c] = x[i];
        }
        // Clamp the most negative coefficient, if any, and refit.
        if let Some(worst) = (0..3)
            .filter(|&c| active[c] && coefs[c] < 0.0)
            .min_by(|&a, &b| coefs[a].total_cmp(&coefs[b]))
        {
            active[worst] = false;
            continue;
        }
        return CostCoefficients {
            ns_per_byte: coefs[0],
            ns_per_flop: coefs[1],
            tile_overhead_ns: coefs[2],
        };
    }
}

/// Run the full calibration: traced workloads, the non-negative
/// least-squares fit, and the drift report.
pub fn run_calibration(case: CalibrationCase, seed: u64) -> Result<CalibrationReport> {
    let points = workload_points(&case, seed)?;
    ensure!(points.iter().all(|p| p.measured_us.is_finite()), "timing failed");
    let coefficients = fit(&points);
    Ok(CalibrationReport { case, coefficients, points })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic points generated from known coefficients must fit back
    /// to those coefficients (the fitter is exact on exact data).
    #[test]
    fn fit_recovers_known_coefficients_exactly() {
        let truth = CostCoefficients {
            ns_per_byte: 0.25,
            ns_per_flop: 0.02,
            tile_overhead_ns: 150.0,
        };
        let mut points = Vec::new();
        for (i, (bytes, flops, tiles)) in [
            (100_000u64, 400_000u64, 12u64),
            (250_000, 500_000, 40),
            (60_000, 900_000, 9),
            (500_000, 2_000_000, 31),
            (90_000, 90_000, 77),
        ]
        .iter()
        .enumerate()
        {
            let work = WorkAccounting {
                tiles: *tiles,
                gathered_kv_bytes: *bytes,
                softmax_flops: *flops,
                rescale_folds: 1,
            };
            points.push(CalibrationPoint {
                strategy: ["flat", "cascade"][i % 2],
                shape: format!("p{i}"),
                work,
                measured_us: truth.predict_us(&work),
            });
        }
        let fitted = fit(&points);
        assert!((fitted.ns_per_byte - truth.ns_per_byte).abs() < 1e-6, "{fitted:?}");
        assert!((fitted.ns_per_flop - truth.ns_per_flop).abs() < 1e-6, "{fitted:?}");
        assert!(
            (fitted.tile_overhead_ns - truth.tile_overhead_ns).abs() < 1e-3,
            "{fitted:?}"
        );
        let report = CalibrationReport {
            case: CalibrationCase::smoke(),
            coefficients: fitted,
            points,
        };
        assert!(report.max_rel_err() < 1e-6);
        assert_eq!(report.per_strategy().len(), 2);
    }

    /// A negative fit (e.g. anti-correlated noise) is clamped to zero
    /// rather than producing a negative physical cost.
    #[test]
    fn fit_clamps_negative_coefficients() {
        // Two points where time *decreases* as tiles increase: the tile
        // coefficient wants to be negative, and must clamp to zero.
        let mk = |bytes: u64, tiles: u64, us: f64| CalibrationPoint {
            strategy: "flat",
            shape: "t".into(),
            work: WorkAccounting {
                tiles,
                gathered_kv_bytes: bytes,
                softmax_flops: 0,
                rescale_folds: 0,
            },
            measured_us: us,
        };
        let points =
            vec![mk(1000, 50, 1.0), mk(2000, 20, 2.0), mk(3000, 80, 2.9)];
        let fitted = fit(&points);
        assert!(fitted.ns_per_byte >= 0.0);
        assert!(fitted.ns_per_flop >= 0.0);
        assert!(fitted.tile_overhead_ns >= 0.0);
    }

    /// End-to-end smoke: the traced workloads produce a fit whose
    /// drift stays within a (deliberately loose, debug-build-safe)
    /// bound for every strategy, and the report serializes.
    #[test]
    fn calibration_fits_all_strategies_within_bound() {
        let case = CalibrationCase { iters: 2, scale: 96, slots: 12, batch_rows: 64 };
        let report = run_calibration(case, 7).unwrap();
        assert_eq!(report.points.len(), 15, "5 strategies x 3 scales");
        let drift = report.per_strategy();
        assert_eq!(drift.len(), 5);
        for d in &drift {
            // Debug builds and CI noise allowed for; the CLI asserts a
            // much tighter bound on release-built runs.
            assert!(
                d.max_rel_err < 10.0,
                "strategy {} drifted {}x",
                d.strategy,
                d.max_rel_err
            );
        }
        let j = report.to_json();
        assert_eq!(j.at("points").as_arr().unwrap().len(), 15);
        assert!(!report.render().is_empty());
    }
}
