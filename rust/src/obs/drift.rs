//! Online cost-model drift detection.
//!
//! The calibration plane (`leanattn calibrate`) fits
//! [`CostCoefficients`] offline and asserts the model's relative error
//! under a bound — once. This module replays the same span ↔ accounting
//! join *at serve time*: every observed step contributes one
//! `(predicted work, measured microseconds)` pair, and an EWMA of the
//! relative error tracks whether the calibrated model still describes
//! the machine it is running on. A sustained breach (several
//! consecutive EWMA samples over the limit) marks real drift — thermal
//! throttling, a noisy neighbour, a regressed gather path — and fires
//! the flight recorder's `drift` trigger so the offending window is
//! preserved for post-mortem.
//!
//! The detector self-calibrates a **scalar gain** instead of re-fitting
//! the three coefficients online: a serve loop's observation stream is
//! close to rank-one (the workload shape barely moves step to step), so
//! a least-squares refit would be singular, while the single gain
//! `Σ measured / Σ predicted` over the warmup window is well-posed on
//! any stream and absorbs host-vs-calibration machine scale. After
//! warmup the *shape* of the model is held fixed — exactly the thing
//! drift detection is supposed to test.

use crate::obs::attrib::WorkAccounting;
use crate::sim::CostCoefficients;

/// Streaming EWMA drift detector over the cost model.
#[derive(Clone, Debug)]
pub struct DriftDetector {
    coeffs: CostCoefficients,
    /// EWMA breach threshold on relative error.
    limit: f64,
    /// Observations used to fit the scalar gain before judging.
    warmup: usize,
    /// EWMA smoothing factor.
    alpha: f64,
    /// Consecutive over-limit samples required to declare a breach.
    patience: usize,
    observations: u64,
    warm_pred: f64,
    warm_meas: f64,
    gain: Option<f64>,
    ewma: Option<f64>,
    streak: usize,
    breaches: u64,
    pending_breach: bool,
}

impl DriftDetector {
    /// Observations used to fit the scalar gain before any judgement.
    pub const WARMUP: usize = 16;
    /// EWMA smoothing factor (weight of the newest sample).
    pub const ALPHA: f64 = 0.2;
    /// Consecutive over-limit EWMA samples that constitute a breach.
    pub const PATIENCE: usize = 4;

    /// Detector judging `coeffs` against measured step times, breaching
    /// when the error EWMA stays above `limit` for [`Self::PATIENCE`]
    /// consecutive observations.
    pub fn new(coeffs: CostCoefficients, limit: f64) -> DriftDetector {
        DriftDetector {
            coeffs,
            limit,
            warmup: Self::WARMUP,
            alpha: Self::ALPHA,
            patience: Self::PATIENCE,
            observations: 0,
            warm_pred: 0.0,
            warm_meas: 0.0,
            gain: None,
            ewma: None,
            streak: 0,
            breaches: 0,
            pending_breach: false,
        }
    }

    /// Feed one `(exact work, measured microseconds)` observation.
    /// Returns the sample's relative error once the detector is warm,
    /// `None` while still fitting the gain. Zero-work or non-positive
    /// measurements are ignored.
    pub fn observe(&mut self, work: &WorkAccounting, measured_us: f64) -> Option<f64> {
        if work.is_zero() || measured_us <= 0.0 {
            return None;
        }
        let base = self.coeffs.predict_us(work);
        if base <= 0.0 {
            return None;
        }
        self.observations += 1;
        let Some(gain) = self.gain else {
            self.warm_pred += base;
            self.warm_meas += measured_us;
            if self.observations as usize >= self.warmup && self.warm_pred > 0.0 {
                self.gain = Some(self.warm_meas / self.warm_pred);
            }
            return None;
        };
        let predicted = gain * base;
        let rel = (predicted - measured_us).abs() / measured_us.max(1e-9);
        // Zero-initialized EWMA: a fresh (or re-armed) detector needs
        // genuinely sustained error to climb over the limit — a single
        // spike contributes only `alpha * rel`.
        let prev = self.ewma.unwrap_or(0.0);
        let ewma = prev + self.alpha * (rel - prev);
        self.ewma = Some(ewma);
        if ewma > self.limit {
            self.streak += 1;
            if self.streak >= self.patience {
                self.breaches += 1;
                self.pending_breach = true;
                // Re-arm: restart the estimate so the lingering EWMA of
                // the event just captured cannot immediately fire again
                // once the workload has recovered.
                self.streak = 0;
                self.ewma = None;
            }
        } else {
            self.streak = 0;
        }
        Some(rel)
    }

    /// Total observations fed (including warmup).
    pub fn observations(&self) -> u64 {
        self.observations
    }

    /// Current relative-error EWMA; `None` until warm and judged once
    /// (and right after a breach re-arms the estimate).
    pub fn rel_err(&self) -> Option<f64> {
        self.ewma
    }

    /// Scalar gain fitted over the warmup window, once available.
    pub fn gain(&self) -> Option<f64> {
        self.gain
    }

    /// Sustained breaches declared so far.
    pub fn breaches(&self) -> u64 {
        self.breaches
    }

    /// Consume the pending-breach flag: `true` exactly once per
    /// declared breach, so the caller records one flight bundle per
    /// sustained event rather than one per over-limit step.
    pub fn take_breach(&mut self) -> bool {
        std::mem::take(&mut self.pending_breach)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn coeffs() -> CostCoefficients {
        CostCoefficients { ns_per_byte: 0.02, ns_per_flop: 0.004, tile_overhead_ns: 300.0 }
    }

    fn work() -> WorkAccounting {
        WorkAccounting::slice(4096, 64, 8)
    }

    #[test]
    fn stationary_stream_stays_quiet() {
        let c = coeffs();
        let w = work();
        // Measurements track the model at 3x scale — the gain absorbs it.
        let mut d = DriftDetector::new(c, 0.10);
        for i in 0..200u64 {
            let jitter = 1.0 + 0.01 * ((i % 7) as f64 - 3.0) / 3.0;
            d.observe(&w, 3.0 * c.predict_us(&w) * jitter);
        }
        assert_eq!(d.breaches(), 0);
        assert!(!d.take_breach());
        let rel = d.rel_err().expect("warm after 200 observations");
        assert!(rel < 0.05, "stationary rel err {rel}");
        let g = d.gain().unwrap();
        assert!((g - 3.0).abs() < 0.05, "gain {g}");
    }

    #[test]
    fn sustained_shift_breaches_once_per_event() {
        let c = coeffs();
        let w = work();
        let mut d = DriftDetector::new(c, 0.10);
        let base = c.predict_us(&w);
        for _ in 0..DriftDetector::WARMUP {
            d.observe(&w, base);
        }
        assert!(d.gain().is_some());
        // 2x slowdown: every sample's rel err is 0.5 >> 0.10; the
        // zero-initialized EWMA needs one extra step to clear the limit
        // before the PATIENCE streak starts counting.
        let mut fired = 0;
        for _ in 0..DriftDetector::PATIENCE + 2 {
            d.observe(&w, 2.0 * base);
            if d.take_breach() {
                fired += 1;
            }
        }
        assert_eq!(fired, 1, "exactly one breach from one sustained event");
        assert_eq!(d.breaches(), 1);
        // Recovery resets the streak; no further breach.
        for _ in 0..50 {
            d.observe(&w, base);
        }
        assert_eq!(d.breaches(), 1);
        assert!(!d.take_breach());
    }

    #[test]
    fn transient_spikes_below_patience_do_not_breach() {
        let c = coeffs();
        let w = work();
        let mut d = DriftDetector::new(c, 0.10);
        let base = c.predict_us(&w);
        for _ in 0..DriftDetector::WARMUP {
            d.observe(&w, base);
        }
        for _ in 0..20 {
            // Short bursts just over the limit, then recovery: the EWMA
            // (alpha 0.2 from zero) peaks near 0.073 < 0.10, so the
            // streak never even starts.
            for _ in 0..DriftDetector::PATIENCE - 1 {
                d.observe(&w, 1.15 * base);
            }
            for _ in 0..8 {
                d.observe(&w, base);
            }
        }
        assert_eq!(d.breaches(), 0);
    }

    #[test]
    fn zero_work_and_zero_time_are_ignored() {
        let mut d = DriftDetector::new(coeffs(), 0.10);
        assert!(d.observe(&WorkAccounting::default(), 5.0).is_none());
        assert!(d.observe(&work(), 0.0).is_none());
        assert_eq!(d.observations(), 0);
    }
}
