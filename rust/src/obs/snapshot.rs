//! Versioned metrics snapshot shared by every exporter.
//!
//! The consistency rule of this module: a counter exists in exactly one
//! place — the [`MetricsSnapshot`] assembled by
//! `coordinator::Metrics::snapshot` — and both exporters (Prometheus
//! text exposition for `leanattn serve --metrics-out`, versioned JSON
//! for dashboards and regression diffs) are pure serializations of that
//! one struct. A metric added to the snapshot can therefore never be
//! silently dropped from one export format; `rust/tests/obs_props.rs`
//! pins this by diffing the documented counter list against both
//! outputs.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Schema version stamped into the JSON export; bump on breaking
/// renames so downstream dashboards can detect skew.
pub const SNAPSHOT_VERSION: u64 = 1;

/// Prometheus metric kind (determines the `# TYPE` line).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    /// Monotonically non-decreasing over the engine's lifetime.
    Counter,
    /// Point-in-time level (may go up or down).
    Gauge,
}

impl MetricKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
        }
    }
}

/// One exported metric: a name in the `leanattn_` namespace, its kind,
/// the sampled value and a help line.
#[derive(Clone, Copy, Debug)]
pub struct Metric {
    pub name: &'static str,
    pub kind: MetricKind,
    pub value: f64,
    pub help: &'static str,
}

/// A point-in-time sample of every exported engine metric.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    metrics: Vec<Metric>,
}

impl MetricsSnapshot {
    pub fn new() -> MetricsSnapshot {
        MetricsSnapshot::default()
    }

    /// Append a counter sample.
    pub fn counter(&mut self, name: &'static str, value: f64, help: &'static str) {
        self.push(Metric { name, kind: MetricKind::Counter, value, help });
    }

    /// Append a gauge sample.
    pub fn gauge(&mut self, name: &'static str, value: f64, help: &'static str) {
        self.push(Metric { name, kind: MetricKind::Gauge, value, help });
    }

    fn push(&mut self, m: Metric) {
        debug_assert!(
            self.get(m.name).is_none(),
            "duplicate metric name {}",
            m.name
        );
        self.metrics.push(m);
    }

    pub fn len(&self) -> usize {
        self.metrics.len()
    }

    pub fn is_empty(&self) -> bool {
        self.metrics.is_empty()
    }

    pub fn metrics(&self) -> &[Metric] {
        &self.metrics
    }

    /// Look a metric up by exported name.
    pub fn get(&self, name: &str) -> Option<&Metric> {
        self.metrics.iter().find(|m| m.name == name)
    }

    pub fn names(&self) -> Vec<&'static str> {
        self.metrics.iter().map(|m| m.name).collect()
    }

    /// Prometheus text exposition format, one `# HELP`/`# TYPE`/sample
    /// triple per metric, all under the `leanattn_` prefix.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        for m in &self.metrics {
            out.push_str(&format!("# HELP leanattn_{} {}\n", m.name, m.help));
            out.push_str(&format!("# TYPE leanattn_{} {}\n", m.name, m.kind.as_str()));
            out.push_str(&format!("leanattn_{} {}\n", m.name, format_value(m.value)));
        }
        out
    }

    /// Versioned JSON export: `{"version": 1, "metrics": {name: value}}`
    /// plus a parallel `kinds` object so consumers can tell counters
    /// from gauges without a schema registry.
    pub fn to_json(&self) -> Json {
        let mut vals = BTreeMap::new();
        let mut kinds = BTreeMap::new();
        for m in &self.metrics {
            vals.insert(m.name.to_string(), Json::Num(m.value));
            kinds.insert(m.name.to_string(), Json::Str(m.kind.as_str().to_string()));
        }
        let mut root = BTreeMap::new();
        root.insert("version".to_string(), Json::Num(SNAPSHOT_VERSION as f64));
        root.insert("metrics".to_string(), Json::Obj(vals));
        root.insert("kinds".to_string(), Json::Obj(kinds));
        Json::Obj(root)
    }
}

/// Prometheus sample values: integers without a trailing `.0`.
fn format_value(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 9e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> MetricsSnapshot {
        let mut s = MetricsSnapshot::new();
        s.counter("decode_steps_total", 42.0, "Engine decode steps taken.");
        s.counter("tokens_generated_total", 123.0, "Tokens sampled.");
        s.gauge("kv_pages_used", 7.0, "Pages currently allocated.");
        s.gauge("step_us_p99", 1234.5, "p99 decode step latency (us).");
        s
    }

    #[test]
    fn prometheus_exposition_has_help_type_and_sample() {
        let text = sample().to_prometheus();
        assert!(text.contains("# HELP leanattn_decode_steps_total Engine decode steps taken.\n"));
        assert!(text.contains("# TYPE leanattn_decode_steps_total counter\n"));
        assert!(text.contains("\nleanattn_decode_steps_total 42\n"));
        assert!(text.contains("# TYPE leanattn_kv_pages_used gauge\n"));
        assert!(text.contains("leanattn_step_us_p99 1234.5\n"));
    }

    #[test]
    fn json_export_is_versioned_and_complete() {
        let s = sample();
        let j = s.to_json();
        assert_eq!(j.usize_at("version"), SNAPSHOT_VERSION as usize);
        let metrics = j.get("metrics").and_then(Json::as_obj).unwrap();
        assert_eq!(metrics.len(), s.len());
        assert_eq!(metrics.get("tokens_generated_total"), Some(&Json::Num(123.0)));
        let kinds = j.get("kinds").and_then(Json::as_obj).unwrap();
        assert_eq!(kinds.get("kv_pages_used"), Some(&Json::Str("gauge".into())));
    }

    #[test]
    fn json_round_trips_through_parser() {
        let j = sample().to_json();
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn every_name_reaches_both_exporters() {
        let s = sample();
        let text = s.to_prometheus();
        let j = s.to_json();
        let metrics = j.get("metrics").and_then(Json::as_obj).unwrap();
        for name in s.names() {
            assert!(text.contains(&format!("leanattn_{name} ")), "{name} in text");
            assert!(metrics.contains_key(name), "{name} in json");
        }
    }

    #[test]
    fn get_and_names_agree() {
        let s = sample();
        assert_eq!(s.names().len(), 4);
        assert_eq!(s.get("kv_pages_used").unwrap().value, 7.0);
        assert!(s.get("missing").is_none());
    }
}
