//! Engine observability plane.
//!
//! Everything the serving stack measures about itself lives here, in
//! layers that the coordinator threads through its hot paths:
//!
//! - [`tracer`] — structured step tracing: lexically-scoped [`Span`]s
//!   over a fixed-capacity ring buffer with a monotonic step clock and
//!   a Chrome trace-event / Perfetto exporter. The span taxonomy
//!   ([`Phase`]) names every engine phase from `admit` to `evict`.
//! - [`hist`] — [`LogHistogram`], the bounded-memory HDR-style latency
//!   histogram behind every percentile this crate reports.
//! - [`timeline`] — per-request lifecycles ([`RequestTimeline`])
//!   aggregated by a [`TimelineRecorder`] into the serving
//!   [`SloReport`] (TTFT/e2e percentiles, goodput, SLO attainment).
//! - [`snapshot`] — the versioned [`MetricsSnapshot`] both exporters
//!   (Prometheus text, JSON) serialize, so no counter can reach one
//!   export format and silently miss the other.
//! - [`attrib`] — exact per-tile work accounting ([`WorkAccounting`])
//!   derived from the partitioner's own structures: the one source of
//!   flop/byte/tile/fold numbers for the engine, the simulator, and
//!   the bench harnesses.
//! - [`calibrate`] — fits [`crate::sim::CostCoefficients`] from traced
//!   host-executor runs joined with the accounting, and reports the
//!   per-strategy sim-vs-measured drift (`leanattn calibrate`).
//! - [`benchlog`] — the versioned machine-readable [`BenchReport`]
//!   every bench harness emits (`--json-out`) and the baseline
//!   regression gate compares (`--check-against`).
//! - [`cache_stats`] — the KV-cache introspection plane: the per-page
//!   [`HeatTracker`] the paged cache maintains at its gather / append /
//!   select sites and the versioned [`CacheReport`] (`leanattn
//!   inspect`) recomputed from scratch over that state.
//! - [`watchdog`] — the step-progress heartbeat ([`Watchdog`]) that
//!   marks engine health and fires the flight recorder on stalls.
//! - [`balance`] — the partition-quality plane: per-tile work ledgers
//!   priced with [`attrib`]'s closed form (bit-exact to the totals),
//!   joined with simulated per-CTA timelines and measured tile spans
//!   into the versioned [`PartitionReport`] (`leanattn analyze
//!   --partition`, `bench --balance`).
//! - [`drift`] — the online EWMA [`DriftDetector`] that replays the
//!   calibration join at serve time and fires the flight recorder's
//!   `drift` trigger on a sustained cost-model breach
//!   (`serve --drift-limit`).
//! - [`flight`] — the anomaly [`FlightRecorder`]: post-mortem bundles
//!   (trace + metrics snapshot + cache report + SLO text) written when
//!   a trigger condition fires, re-validated on read-back.
//!
//! The plane is feature-cheap by construction: a disabled [`Tracer`]
//! reads no clocks and allocates nothing, and `leanattn bench --obs`
//! measures that overhead — and the heat tracker's — and asserts both
//! under 2%.

pub mod attrib;
pub mod balance;
pub mod benchlog;
pub mod cache_stats;
pub mod calibrate;
pub mod drift;
pub mod flight;
pub mod hist;
pub mod snapshot;
pub mod timeline;
pub mod tracer;
pub mod watchdog;

pub use attrib::WorkAccounting;
pub use balance::{
    partition_report, validate_partition_report, PartitionReport,
    StrategyBalance, PARTITION_REPORT_VERSION,
};
pub use benchlog::{compare_reports, validate_bench_report, BenchReport, BENCH_SCHEMA_VERSION};
pub use cache_stats::{
    heat_bucket, validate_cache_report, CacheReport, HeatTracker, HotRun,
    RadixStats, TouchKind, CACHE_REPORT_VERSION,
};
pub use calibrate::{run_calibration, CalibrationReport};
pub use drift::DriftDetector;
pub use flight::{
    validate_bundle, validate_snapshot_json, FlightRecorder, FlightSnapshot,
    FlightTrigger, FLIGHT_MANIFEST_VERSION,
};
pub use hist::LogHistogram;
pub use snapshot::{Metric, MetricKind, MetricsSnapshot, SNAPSHOT_VERSION};
pub use timeline::{Quantiles, RequestTimeline, SloReport, TimelineRecorder};
pub use tracer::{validate_chrome_trace, Attrs, Phase, Span, TraceEvent, Tracer};
pub use watchdog::{StallEvent, Watchdog};
