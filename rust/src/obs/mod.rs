//! Engine observability plane.
//!
//! Everything the serving stack measures about itself lives here, in
//! four layers that the coordinator threads through its hot paths:
//!
//! - [`tracer`] — structured step tracing: lexically-scoped [`Span`]s
//!   over a fixed-capacity ring buffer with a monotonic step clock and
//!   a Chrome trace-event / Perfetto exporter. The span taxonomy
//!   ([`Phase`]) names every engine phase from `admit` to `evict`.
//! - [`hist`] — [`LogHistogram`], the bounded-memory HDR-style latency
//!   histogram behind every percentile this crate reports.
//! - [`timeline`] — per-request lifecycles ([`RequestTimeline`])
//!   aggregated by a [`TimelineRecorder`] into the serving
//!   [`SloReport`] (TTFT/e2e percentiles, goodput, SLO attainment).
//! - [`snapshot`] — the versioned [`MetricsSnapshot`] both exporters
//!   (Prometheus text, JSON) serialize, so no counter can reach one
//!   export format and silently miss the other.
//!
//! The plane is feature-cheap by construction: a disabled [`Tracer`]
//! reads no clocks and allocates nothing, and `leanattn bench --obs`
//! measures that overhead and asserts it under 2%.

pub mod hist;
pub mod snapshot;
pub mod timeline;
pub mod tracer;

pub use hist::LogHistogram;
pub use snapshot::{Metric, MetricKind, MetricsSnapshot, SNAPSHOT_VERSION};
pub use timeline::{Quantiles, RequestTimeline, SloReport, TimelineRecorder};
pub use tracer::{validate_chrome_trace, Attrs, Phase, Span, TraceEvent, Tracer};
