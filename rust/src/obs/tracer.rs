//! Low-overhead structured step tracer.
//!
//! A [`Tracer`] records phase events — lexically-scoped [`Span`] guards
//! or explicit begin/end pairs ([`Tracer::now`] / [`Tracer::record_since`])
//! for regions the borrow checker won't let a guard straddle — into a
//! fixed-capacity ring buffer stamped with a monotonic step clock.
//! Overflow keeps the **newest** events and counts drops monotonically;
//! per-phase durations additionally feed [`LogHistogram`]s that survive
//! ring overflow, so the phase-timing percentiles in the serving report
//! cover the whole run. [`Tracer::export_chrome_trace`] emits the Chrome
//! trace-event JSON (`chrome://tracing` / Perfetto `ui.perfetto.dev`)
//! that `leanattn bench --obs --trace-out` writes.
//!
//! A disabled tracer is near-free: no clock reads, no allocation, one
//! branch per call site — the bound `leanattn bench --obs` measures and
//! asserts (< 2% on the cascade body).

use std::cell::RefCell;
use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{bail, ensure, Result};

use crate::util::json::Json;

use super::hist::LogHistogram;

/// The engine's span taxonomy — one variant per instrumented phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    /// A request admitted into a batch slot (instant; `seq`, `pages`).
    Admit,
    /// Prompt prefill through the model artifact.
    Prefill,
    /// Per-lane sparse page scoring + selection.
    SparseSelect,
    /// KV materialization out of the paged cache (`bytes` gathered).
    Gather,
    /// The lean/cascade attention + model decode execution.
    LeanExec,
    /// Logits processing, sampling and KV append for the step's lanes.
    Sample,
    /// Draft-chain proposal (`k` tokens requested).
    SpecDraft,
    /// The multi-query verify pass over the draft block.
    SpecVerify,
    /// Tokens committed by a verify pass (instant; `k` committed).
    SpecCommit,
    /// Speculative KV rows rolled back (instant; `k` rows).
    Rollback,
    /// Prefix-index pages evicted under cache pressure (instant; `pages`).
    Evict,
}

impl Phase {
    pub const ALL: [Phase; 11] = [
        Phase::Admit,
        Phase::Prefill,
        Phase::SparseSelect,
        Phase::Gather,
        Phase::LeanExec,
        Phase::Sample,
        Phase::SpecDraft,
        Phase::SpecVerify,
        Phase::SpecCommit,
        Phase::Rollback,
        Phase::Evict,
    ];

    /// The stable event name used in trace exports and reports.
    pub fn as_str(self) -> &'static str {
        match self {
            Phase::Admit => "admit",
            Phase::Prefill => "prefill",
            Phase::SparseSelect => "sparse_select",
            Phase::Gather => "gather",
            Phase::LeanExec => "lean_exec",
            Phase::Sample => "sample",
            Phase::SpecDraft => "spec_draft",
            Phase::SpecVerify => "spec_verify",
            Phase::SpecCommit => "spec_commit",
            Phase::Rollback => "rollback",
            Phase::Evict => "evict",
        }
    }

    fn index(self) -> usize {
        Phase::ALL.iter().position(|&p| p == self).expect("phase in ALL")
    }
}

/// Optional per-event attributes. Unset fields are omitted from exports.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Attrs {
    /// Sequence (request) id the event concerns.
    pub seq: Option<u64>,
    /// Pages touched (gathered, selected, evicted).
    pub pages: Option<usize>,
    /// Bytes moved (KV gathered / written).
    pub bytes: Option<u64>,
    /// Exact online-softmax flops the span executes
    /// ([`crate::obs::attrib::WorkAccounting::softmax_flops`]) — with
    /// `bytes`, lets Perfetto derive bandwidth/throughput tracks.
    pub flops: Option<u64>,
    /// Draft length / committed tokens / lane count — phase-dependent.
    pub k: Option<usize>,
    /// CTA / LeanTile segment index within the step's partition plan —
    /// lets per-tile measured `gather`/`lean_exec` spans be joined with
    /// the per-tile work ledger (`obs::balance`).
    pub tile: Option<usize>,
}

/// One recorded event. `start_us` is relative to the tracer's epoch;
/// `dur_us == 0` marks an instant event.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    pub phase: Phase,
    pub start_us: f64,
    pub dur_us: f64,
    /// Value of the step clock when the event closed.
    pub step: u64,
    /// Span nesting depth at the event's open (0 = top level).
    pub depth: u32,
    pub attrs: Attrs,
}

#[derive(Debug, Default)]
struct Inner {
    ring: VecDeque<TraceEvent>,
    dropped: u64,
    step: u64,
    depth: u32,
    /// Indexed by `Phase::index`; empty when the tracer is disabled.
    hists: Vec<LogHistogram>,
}

/// The structured tracer. Interior-mutable so span guards and record
/// calls take `&Tracer` — the engine holds one alongside `&mut self`
/// hot-path state.
#[derive(Debug, Default)]
pub struct Tracer {
    capacity: usize,
    /// `None` when disabled — the cheap-path discriminant.
    epoch: Option<Instant>,
    inner: RefCell<Inner>,
}

impl Tracer {
    /// A tracer that records nothing and never reads the clock.
    pub fn disabled() -> Tracer {
        Tracer::default()
    }

    /// An enabled tracer whose ring keeps the newest `capacity` events.
    pub fn enabled(capacity: usize) -> Tracer {
        Tracer {
            capacity: capacity.max(1),
            epoch: Some(Instant::now()),
            inner: RefCell::new(Inner {
                hists: vec![LogHistogram::new(); Phase::ALL.len()],
                ..Default::default()
            }),
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.epoch.is_some()
    }

    /// Ring capacity (0 when disabled).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Advance the monotonic step clock (once per engine step).
    pub fn advance_step(&self) {
        if self.is_enabled() {
            self.inner.borrow_mut().step += 1;
        }
    }

    /// Current step-clock value.
    pub fn step(&self) -> u64 {
        self.inner.borrow().step
    }

    /// Events currently in the ring (oldest first).
    pub fn events(&self) -> Vec<TraceEvent> {
        self.inner.borrow().ring.iter().cloned().collect()
    }

    /// Events in the ring.
    pub fn len(&self) -> usize {
        self.inner.borrow().ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events dropped to ring overflow so far (monotonic).
    pub fn dropped(&self) -> u64 {
        self.inner.borrow().dropped
    }

    /// Clock read for a begin/end pair; `None` when disabled, so the
    /// matching [`Self::record_since`] is a no-op.
    pub fn now(&self) -> Option<Instant> {
        self.is_enabled().then(Instant::now)
    }

    /// Close a begin/end pair opened with [`Self::now`].
    pub fn record_since(&self, phase: Phase, start: Option<Instant>, attrs: Attrs) {
        let (Some(epoch), Some(start)) = (self.epoch, start) else {
            return;
        };
        let start_us = start.duration_since(epoch).as_secs_f64() * 1e6;
        let dur_us = start.elapsed().as_secs_f64() * 1e6;
        let depth = self.inner.borrow().depth;
        self.push(TraceEvent { phase, start_us, dur_us, step: 0, depth, attrs });
    }

    /// Record a zero-duration event at the current time.
    pub fn instant(&self, phase: Phase, attrs: Attrs) {
        let Some(epoch) = self.epoch else {
            return;
        };
        let start_us = epoch.elapsed().as_secs_f64() * 1e6;
        let depth = self.inner.borrow().depth;
        self.push(TraceEvent { phase, start_us, dur_us: 0.0, step: 0, depth, attrs });
    }

    /// Open a lexically-scoped span; it records on drop. Attributes can
    /// be attached to the returned guard as they become known.
    pub fn span(&self, phase: Phase) -> Span<'_> {
        let start = self.now();
        if start.is_some() {
            self.inner.borrow_mut().depth += 1;
        }
        Span { tracer: self, phase, start, attrs: Attrs::default() }
    }

    fn close_span(&self, phase: Phase, start: Instant, attrs: Attrs) {
        let Some(epoch) = self.epoch else {
            return;
        };
        let depth = {
            let mut inner = self.inner.borrow_mut();
            inner.depth = inner.depth.saturating_sub(1);
            inner.depth
        };
        let start_us = start.duration_since(epoch).as_secs_f64() * 1e6;
        let dur_us = start.elapsed().as_secs_f64() * 1e6;
        self.push(TraceEvent { phase, start_us, dur_us, step: 0, depth, attrs });
    }

    fn push(&self, mut ev: TraceEvent) {
        let mut inner = self.inner.borrow_mut();
        ev.step = inner.step;
        let idx = ev.phase.index();
        inner.hists[idx].record(ev.dur_us);
        if inner.ring.len() == self.capacity {
            inner.ring.pop_front();
            inner.dropped += 1;
        }
        inner.ring.push_back(ev);
    }

    /// Clone of the per-phase duration histogram (`None` when disabled
    /// or the phase never fired). Unlike the ring these survive overflow.
    pub fn phase_hist(&self, phase: Phase) -> Option<LogHistogram> {
        let inner = self.inner.borrow();
        let h = inner.hists.get(phase.index())?;
        (!h.is_empty()).then(|| h.clone())
    }

    /// Per-phase timing table: count and p50/p95/p99/p999 microseconds
    /// for every phase that fired — the serving report's breakdown.
    pub fn phase_report(&self) -> String {
        let mut s = String::new();
        for phase in Phase::ALL {
            let Some(h) = self.phase_hist(phase) else {
                continue;
            };
            s.push_str(&format!(
                "  {:<13} n={:<6} p50={:.1}us p95={:.1}us p99={:.1}us p999={:.1}us\n",
                phase.as_str(),
                h.count(),
                h.quantile(0.5),
                h.quantile(0.95),
                h.quantile(0.99),
                h.quantile(0.999),
            ));
        }
        s
    }

    /// Export the ring as a Chrome trace-event JSON array (complete
    /// events, `ph: "X"`, microsecond timestamps), sorted by start time.
    /// Open with Perfetto (`ui.perfetto.dev`) or `chrome://tracing`.
    pub fn export_chrome_trace(&self) -> Json {
        let mut events = self.events();
        events.sort_by(|a, b| a.start_us.total_cmp(&b.start_us));
        let arr = events
            .iter()
            .map(|ev| {
                let mut args = std::collections::BTreeMap::new();
                args.insert("step".to_string(), Json::Num(ev.step as f64));
                args.insert("depth".to_string(), Json::Num(f64::from(ev.depth)));
                if let Some(seq) = ev.attrs.seq {
                    args.insert("seq".to_string(), Json::Num(seq as f64));
                }
                if let Some(pages) = ev.attrs.pages {
                    args.insert("pages".to_string(), Json::Num(pages as f64));
                }
                if let Some(bytes) = ev.attrs.bytes {
                    args.insert("bytes".to_string(), Json::Num(bytes as f64));
                }
                if let Some(flops) = ev.attrs.flops {
                    args.insert("flops".to_string(), Json::Num(flops as f64));
                }
                if let Some(k) = ev.attrs.k {
                    args.insert("k".to_string(), Json::Num(k as f64));
                }
                if let Some(tile) = ev.attrs.tile {
                    args.insert("tile".to_string(), Json::Num(tile as f64));
                }
                let mut o = std::collections::BTreeMap::new();
                o.insert("name".to_string(), Json::Str(ev.phase.as_str().to_string()));
                o.insert("cat".to_string(), Json::Str("engine".to_string()));
                o.insert("ph".to_string(), Json::Str("X".to_string()));
                o.insert("ts".to_string(), Json::Num(ev.start_us));
                o.insert("dur".to_string(), Json::Num(ev.dur_us));
                o.insert("pid".to_string(), Json::Num(0.0));
                o.insert("tid".to_string(), Json::Num(0.0));
                o.insert("args".to_string(), Json::Obj(args));
                Json::Obj(o)
            })
            .collect();
        Json::Arr(arr)
    }
}

/// Validate a value against the Chrome trace-event schema this module
/// exports: a JSON array of complete events whose names come from the
/// span taxonomy — the check `leanattn bench --obs` runs on its export.
pub fn validate_chrome_trace(trace: &Json) -> Result<()> {
    let Some(events) = trace.as_arr() else {
        bail!("trace must be a JSON array of events");
    };
    for (i, ev) in events.iter().enumerate() {
        ensure!(ev.as_obj().is_some(), "event {i} is not an object");
        let name = ev
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("event {i} has no name"))?;
        ensure!(
            Phase::ALL.iter().any(|p| p.as_str() == name),
            "event {i} name {name:?} is not a known phase"
        );
        ensure!(
            ev.get("ph").and_then(Json::as_str) == Some("X"),
            "event {i} is not a complete event (ph=X)"
        );
        for key in ["ts", "dur", "pid", "tid"] {
            let v = ev
                .get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("event {i} field {key} not a number"))?;
            ensure!(v >= 0.0, "event {i} field {key} is negative");
        }
        let args = ev
            .get("args")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("event {i} has no args object"))?;
        ensure!(
            args.get("step").and_then(Json::as_f64).is_some(),
            "event {i} args missing the step clock"
        );
        // Optional work-accounting attrs must be non-negative numbers
        // when present — Perfetto derives bandwidth tracks from them.
        for key in ["seq", "pages", "bytes", "flops", "k", "tile", "depth"] {
            if let Some(v) = args.get(key) {
                let n = v.as_f64().ok_or_else(|| {
                    anyhow::anyhow!("event {i} arg {key} not a number")
                })?;
                ensure!(n >= 0.0, "event {i} arg {key} is negative");
            }
        }
    }
    Ok(())
}

/// Lexically-scoped span guard: records its phase event (with whatever
/// attributes were attached) when dropped. Free when the tracer is
/// disabled — no clock was read at open and drop is a single branch.
pub struct Span<'a> {
    tracer: &'a Tracer,
    phase: Phase,
    start: Option<Instant>,
    attrs: Attrs,
}

impl Span<'_> {
    pub fn set_seq(&mut self, seq: u64) {
        self.attrs.seq = Some(seq);
    }

    pub fn set_pages(&mut self, pages: usize) {
        self.attrs.pages = Some(pages);
    }

    pub fn set_bytes(&mut self, bytes: u64) {
        self.attrs.bytes = Some(bytes);
    }

    pub fn set_flops(&mut self, flops: u64) {
        self.attrs.flops = Some(flops);
    }

    pub fn set_k(&mut self, k: usize) {
        self.attrs.k = Some(k);
    }

    pub fn set_tile(&mut self, tile: usize) {
        self.attrs.tile = Some(tile);
    }
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        if let Some(start) = self.start {
            self.tracer.close_span(self.phase, start, self.attrs);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Tracer::disabled();
        assert!(!t.is_enabled());
        {
            let mut s = t.span(Phase::LeanExec);
            s.set_bytes(128);
        }
        t.instant(Phase::Evict, Attrs::default());
        t.record_since(Phase::Gather, t.now(), Attrs::default());
        t.advance_step();
        assert!(t.is_empty());
        assert_eq!(t.dropped(), 0);
        assert_eq!(t.step(), 0);
        assert!(t.phase_hist(Phase::LeanExec).is_none());
    }

    #[test]
    fn span_records_phase_step_and_attrs() {
        let t = Tracer::enabled(16);
        t.advance_step();
        {
            let mut s = t.span(Phase::Gather);
            s.set_seq(7);
            s.set_bytes(4096);
            s.set_pages(3);
        }
        let evs = t.events();
        assert_eq!(evs.len(), 1);
        let e = &evs[0];
        assert_eq!(e.phase, Phase::Gather);
        assert_eq!(e.step, 1);
        assert_eq!(e.depth, 0);
        assert_eq!(e.attrs.seq, Some(7));
        assert_eq!(e.attrs.bytes, Some(4096));
        assert_eq!(e.attrs.pages, Some(3));
        assert_eq!(e.attrs.k, None);
        assert!(e.dur_us >= 0.0);
        assert!(t.phase_hist(Phase::Gather).is_some());
    }

    #[test]
    fn nested_spans_track_depth_and_close_inner_first() {
        let t = Tracer::enabled(16);
        {
            let _outer = t.span(Phase::LeanExec);
            {
                let _inner = t.span(Phase::Gather);
            }
        }
        let evs = t.events();
        assert_eq!(evs.len(), 2);
        // The inner span closes (and records) first, at depth 1.
        assert_eq!(evs[0].phase, Phase::Gather);
        assert_eq!(evs[0].depth, 1);
        assert_eq!(evs[1].phase, Phase::LeanExec);
        assert_eq!(evs[1].depth, 0);
        // The outer span's interval contains the inner's.
        assert!(evs[1].start_us <= evs[0].start_us);
        assert!(
            evs[0].start_us + evs[0].dur_us
                <= evs[1].start_us + evs[1].dur_us + 1e-3
        );
    }

    #[test]
    fn ring_overflow_keeps_newest_and_counts_drops() {
        let t = Tracer::enabled(4);
        for i in 0..10u64 {
            t.instant(Phase::Admit, Attrs { seq: Some(i), ..Default::default() });
        }
        assert_eq!(t.len(), 4);
        assert_eq!(t.dropped(), 6);
        let seqs: Vec<u64> =
            t.events().iter().map(|e| e.attrs.seq.unwrap()).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9], "newest survive");
        // Histograms keep counting past overflow.
        assert_eq!(t.phase_hist(Phase::Admit).unwrap().count(), 10);
    }

    #[test]
    fn chrome_export_validates_and_sorts() {
        let t = Tracer::enabled(16);
        {
            let mut s = t.span(Phase::LeanExec);
            s.set_bytes(8192);
            s.set_flops(65_536);
        }
        t.instant(Phase::SpecCommit, Attrs { k: Some(3), ..Default::default() });
        let trace = t.export_chrome_trace();
        validate_chrome_trace(&trace).expect("export matches the schema");
        let arr = trace.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        for w in arr.windows(2) {
            assert!(
                w[0].at("ts").as_f64().unwrap() <= w[1].at("ts").as_f64().unwrap()
            );
        }
        // The work-accounting attrs ride into the exported args.
        let exec = arr
            .iter()
            .find(|e| e.str_at("name") == "lean_exec")
            .expect("lean_exec event exported");
        assert_eq!(exec.at("args").at("bytes").as_f64(), Some(8192.0));
        assert_eq!(exec.at("args").at("flops").as_f64(), Some(65_536.0));
    }

    #[test]
    fn tile_attr_exports_and_validates() {
        let t = Tracer::enabled(16);
        {
            let mut s = t.span(Phase::LeanExec);
            s.set_tile(7);
            s.set_flops(1024);
        }
        {
            let mut s = t.span(Phase::Gather);
            s.set_tile(7);
            s.set_bytes(4096);
        }
        let trace = t.export_chrome_trace();
        validate_chrome_trace(&trace).expect("tile attr passes the schema");
        let arr = trace.as_arr().unwrap();
        let exec = arr
            .iter()
            .find(|e| e.str_at("name") == "lean_exec")
            .expect("lean_exec event exported");
        assert_eq!(exec.at("args").at("tile").as_f64(), Some(7.0));
        // A negative tile index is rejected like every work attr.
        let bad = Json::parse(
            r#"[{"name":"lean_exec","ph":"X","ts":0,"dur":1,"pid":0,"tid":0,
                 "args":{"step":0,"tile":-1}}]"#,
        )
        .unwrap();
        assert!(validate_chrome_trace(&bad).is_err());
    }

    #[test]
    fn validator_rejects_negative_work_attrs() {
        let bad = Json::parse(
            r#"[{"name":"gather","ph":"X","ts":0,"dur":1,"pid":0,"tid":0,
                 "args":{"step":0,"flops":-5}}]"#,
        )
        .unwrap();
        assert!(validate_chrome_trace(&bad).is_err());
        let bad_type = Json::parse(
            r#"[{"name":"gather","ph":"X","ts":0,"dur":1,"pid":0,"tid":0,
                 "args":{"step":0,"bytes":"lots"}}]"#,
        )
        .unwrap();
        assert!(validate_chrome_trace(&bad_type).is_err());
    }

    #[test]
    fn validator_rejects_malformed_traces() {
        assert!(validate_chrome_trace(&Json::Null).is_err());
        let bad_name =
            Json::parse(r#"[{"name":"nope","ph":"X","ts":0,"dur":1,"pid":0,"tid":0,"args":{"step":0}}]"#)
                .unwrap();
        assert!(validate_chrome_trace(&bad_name).is_err());
        let bad_ph =
            Json::parse(r#"[{"name":"gather","ph":"B","ts":0,"dur":1,"pid":0,"tid":0,"args":{"step":0}}]"#)
                .unwrap();
        assert!(validate_chrome_trace(&bad_ph).is_err());
        let no_step =
            Json::parse(r#"[{"name":"gather","ph":"X","ts":0,"dur":1,"pid":0,"tid":0,"args":{}}]"#)
                .unwrap();
        assert!(validate_chrome_trace(&no_step).is_err());
    }
}
