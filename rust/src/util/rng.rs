//! Deterministic, dependency-free RNG (splitmix64 core + xoshiro256++),
//! with the distributions the tests and workload generators need.
//!
//! Not cryptographic; exists because `rand` is not in the offline crate
//! cache. Determinism across runs is load-bearing: workloads in the bench
//! harness and the property tests are all seeded.

/// splitmix64 step — used for seeding and as a cheap standalone generator.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ generator, seeded via splitmix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller output.
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift with rejection for unbiased sampling.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo < hi);
        lo + self.below(hi - lo)
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn urange(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as u64, hi as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        loop {
            let u = self.f64();
            if u <= f64::EPSILON {
                continue;
            }
            let v = self.f64();
            let r = (-2.0 * u.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Standard-normal f32 vector of length `n`.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.urange(0, xs.len())]
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..500 {
            let x = r.range(5, 8);
            assert!((5..8).contains(&x));
        }
    }
}
