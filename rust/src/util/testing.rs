//! Miniature property-testing harness (proptest is not in the offline
//! crate cache). Runs a closure over many seeded random cases and reports
//! the first failing seed so failures reproduce deterministically:
//!
//! ```no_run
//! use lean_attention::util::testing::prop_check;
//! prop_check("addition commutes", 256, |rng| {
//!     let a = rng.next_u64() / 2;
//!     let b = rng.next_u64() / 2;
//!     if a + b == b + a { Ok(()) } else { Err(format!("{a} {b}")) }
//! });
//! ```

use super::rng::Rng;

/// Run `cases` random trials of `f`; panic with the failing seed + message
/// on the first failure. Seeds are derived deterministically so a failure
/// is reproducible by running the same test again.
pub fn prop_check(
    name: &str,
    cases: u64,
    mut f: impl FnMut(&mut Rng) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property {name:?} failed on case {case} (seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Assert two f32 slices are element-wise close.
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        assert!(
            (x - y).abs() <= tol || (x.is_nan() && y.is_nan()),
            "{what}: element {i}: {x} vs {y} (tol {tol})"
        );
    }
}

/// Relative max-abs error between two slices (0 for identical).
pub fn max_abs_err(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prop_check_passes_trivial_property() {
        prop_check("u64 halves sum", 64, |rng| {
            let a = rng.next_u64() >> 1;
            let b = rng.next_u64() >> 1;
            (a + b >= a).then_some(()).ok_or_else(|| "overflow".into())
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn prop_check_reports_failures() {
        prop_check("always fails", 4, |_| Err("nope".into()));
    }

    #[test]
    fn allclose_accepts_close() {
        assert_allclose(&[1.0, 2.0], &[1.0 + 1e-7, 2.0], 1e-6, 1e-6, "x");
    }

    #[test]
    #[should_panic(expected = "element 1")]
    fn allclose_rejects_far() {
        assert_allclose(&[1.0, 2.0], &[1.0, 3.0], 1e-6, 1e-6, "x");
    }
}
