//! Descriptive statistics for bench reporting (mean, stddev, percentiles).

/// Summary statistics over a sample of f64 measurements.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p95: percentile_sorted(&sorted, 0.95),
            p99: percentile_sorted(&sorted, 0.99),
            max: sorted[n - 1],
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Geometric mean (used for the paper's "average speedup" aggregates).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::of(&[5.0; 10]);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.p50, 5.0);
        assert_eq!(s.min, 5.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let sorted: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        assert_eq!(percentile_sorted(&sorted, 0.5), 50.0);
        assert_eq!(percentile_sorted(&sorted, 0.9), 90.0);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 1.0), 100.0);
    }

    #[test]
    fn p95_between_p90_and_p99() {
        let samples: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        let s = Summary::of(&samples);
        assert_eq!(s.p95, 95.0);
        assert!(s.p90 <= s.p95 && s.p95 <= s.p99);
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn summary_orders_unsorted_input() {
        let s = Summary::of(&[3.0, 1.0, 2.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.p50, 2.0);
    }
}
