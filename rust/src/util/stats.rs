//! Descriptive statistics for bench reporting (mean, stddev, percentiles).
//!
//! [`Summary::of`] keeps (and sorts) the raw sample — fine for benches
//! with a few hundred iterations, unbounded for a long-running engine.
//! Serving hot paths therefore accumulate into a fixed-memory
//! [`LogHistogram`] instead and summarize via
//! [`Summary::from_histogram`], which is exact for n/mean/stddev/min/max
//! and within one bucket width (~9%) for the percentiles.

use crate::obs::hist::LogHistogram;

/// Summary statistics over a sample of f64 measurements.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p95: percentile_sorted(&sorted, 0.95),
            p99: percentile_sorted(&sorted, 0.99),
            max: sorted[n - 1],
        }
    }

    /// Summarize a bounded-memory histogram (the long-serving-run path:
    /// no raw samples are retained). `None` when the histogram is empty.
    /// Moments and extrema are exact; percentiles are the histogram's
    /// one-bucket-width estimates.
    pub fn from_histogram(h: &LogHistogram) -> Option<Summary> {
        if h.is_empty() {
            return None;
        }
        Some(Summary {
            n: h.count() as usize,
            mean: h.mean(),
            stddev: h.stddev(),
            min: h.min(),
            p50: h.quantile(0.50),
            p90: h.quantile(0.90),
            p95: h.quantile(0.95),
            p99: h.quantile(0.99),
            max: h.max(),
        })
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Geometric mean (used for the paper's "average speedup" aggregates).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::of(&[5.0; 10]);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.p50, 5.0);
        assert_eq!(s.min, 5.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let sorted: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        assert_eq!(percentile_sorted(&sorted, 0.5), 50.0);
        assert_eq!(percentile_sorted(&sorted, 0.9), 90.0);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 1.0), 100.0);
    }

    #[test]
    fn p95_between_p90_and_p99() {
        let samples: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        let s = Summary::of(&samples);
        assert_eq!(s.p95, 95.0);
        assert!(s.p90 <= s.p95 && s.p95 <= s.p99);
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn from_histogram_matches_exact_moments() {
        let samples: Vec<f64> = (1..=200).map(|i| i as f64 * 1.3).collect();
        let mut h = LogHistogram::new();
        for &s in &samples {
            h.record(s);
        }
        let exact = Summary::of(&samples);
        let est = Summary::from_histogram(&h).unwrap();
        assert_eq!(est.n, exact.n);
        assert!((est.mean - exact.mean).abs() < 1e-9);
        assert!((est.stddev - exact.stddev).abs() < 1e-6);
        assert_eq!(est.min, exact.min);
        assert_eq!(est.max, exact.max);
        // Percentiles: within one bucket width, plus 1% slack for
        // Summary::of's interpolation between adjacent samples.
        let g = LogHistogram::growth();
        for (e, q) in [(exact.p50, est.p50), (exact.p95, est.p95), (exact.p99, est.p99)] {
            assert!(q <= e * 1.0001 && e <= q * g * 1.01, "est {q} vs exact {e}");
        }
        assert!(Summary::from_histogram(&LogHistogram::new()).is_none());
    }

    #[test]
    fn summary_orders_unsorted_input() {
        let s = Summary::of(&[3.0, 1.0, 2.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.p50, 2.0);
    }
}
