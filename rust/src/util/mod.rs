//! Small self-contained substrates the offline environment forced us to
//! build rather than depend on: a JSON parser ([`json`]), a deterministic
//! RNG ([`rng`]), a property-testing helper ([`testing`]), descriptive
//! statistics ([`stats`]) and a wall-clock timer ([`timer`]).

pub mod json;
pub mod rng;
pub mod stats;
pub mod testing;
pub mod timer;
