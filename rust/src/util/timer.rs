//! Wall-clock measurement helpers shared by the bench harness and the
//! engine's metrics.

use std::time::Instant;

/// Time one invocation of `f` in microseconds.
pub fn time_us<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64() * 1e6)
}

/// Run `f` repeatedly for at least `min_iters` iterations and
/// `min_duration_s` seconds (whichever is later), returning per-iteration
/// microsecond samples. A cheap stand-in for criterion (not in the offline
/// crate cache).
pub fn sample_us(
    min_iters: usize,
    min_duration_s: f64,
    mut f: impl FnMut(),
) -> Vec<f64> {
    // Warmup: a few runs to populate caches/JIT-ish effects.
    for _ in 0..3.min(min_iters) {
        f();
    }
    let mut samples = Vec::with_capacity(min_iters);
    let start = Instant::now();
    while samples.len() < min_iters || start.elapsed().as_secs_f64() < min_duration_s
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
        if samples.len() > 1_000_000 {
            break; // hard cap for pathologically fast bodies
        }
    }
    samples
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_us_returns_value_and_positive_time() {
        let (v, us) = time_us(|| (0..1000).sum::<u64>());
        assert_eq!(v, 499500);
        assert!(us >= 0.0);
    }

    #[test]
    fn sample_us_collects_at_least_min_iters() {
        let s = sample_us(10, 0.0, || {
            black_box((0..100).sum::<u64>());
        });
        assert!(s.len() >= 10);
        assert!(s.iter().all(|&x| x >= 0.0));
    }
}
