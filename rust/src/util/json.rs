//! Minimal JSON parser — enough to read `artifacts/manifest.json` and to
//! serialize bench reports. Exists because `serde_json` is not in the
//! offline crate cache.
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, bool, null); numbers are held as f64 which is lossless for the
//! integer ranges the manifest uses (< 2^53).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context. (Display/Error are implemented
/// by hand: `thiserror` is not in the offline cargo cache.)
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style chained access; panics with a useful message.
    pub fn at(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing json key {key:?} in {self:.60?}"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn str_at(&self, key: &str) -> &str {
        self.at(key)
            .as_str()
            .unwrap_or_else(|| panic!("json key {key:?} is not a string"))
    }

    pub fn usize_at(&self, key: &str) -> usize {
        self.at(key)
            .as_usize()
            .unwrap_or_else(|| panic!("json key {key:?} is not a number"))
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs: only BMP needed for manifest;
                            // combine if a high surrogate is followed by \u.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    self.i += 2;
                                    let hex2 = self
                                        .b
                                        .get(self.i..self.i + 4)
                                        .ok_or_else(|| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(
                                        std::str::from_utf8(hex2).unwrap_or("!"),
                                        16,
                                    )
                                    .map_err(|_| self.err("bad surrogate"))?;
                                    self.i += 4;
                                    let c =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                Some(c) => {
                    // Consume one UTF-8 character (manifest is ASCII, but be
                    // correct anyway).
                    let len = utf8_len(c);
                    let chunk = self
                        .b
                        .get(self.i..self.i + len)
                        .ok_or_else(|| self.err("bad utf8"))?;
                    s.push_str(
                        std::str::from_utf8(chunk).map_err(|_| self.err("bad utf8"))?,
                    );
                    self.i += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ---- serialization -------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"hi\\n\\u0041\"").unwrap(),
            Json::Str("hi\nA".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": "c"}, null], "d": true}"#).unwrap();
        assert_eq!(v.at("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.at("a").as_arr().unwrap()[1].str_at("b"), "c");
        assert_eq!(v.at("d"), &Json::Bool(true));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn round_trips() {
        let src = r#"{"a":[1,2.5,"x\"y"],"b":{"c":null,"d":false}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn surrogate_pair() {
        let v = Json::parse("\"\\ud83d\\ude00\"").unwrap();
        assert_eq!(v, Json::Str("😀".into()));
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(p) {
            let v = Json::parse(&text).expect("manifest parses");
            assert_eq!(v.usize_at("version"), 1);
            assert!(!v.at("attention").as_arr().unwrap().is_empty());
        }
    }
}
