//! # LeanAttention — hardware-aware scalable decode-phase attention
//!
//! Reproduction of *LeanAttention: Hardware-Aware Scalable Attention
//! Mechanism for the Decode-Phase of Transformers* (Sanovar et al.,
//! Microsoft, 2024) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`) — Pallas online-softmax kernels
//!   (decode attention, un-scaled partials, rescale-reduce), AOT-lowered
//!   to HLO text.
//! * **L2** (`python/compile/model.py`) — a decoder-only transformer whose
//!   decode step routes attention through the L1 kernel.
//! * **L3** (this crate) — the paper's *coordination* contribution:
//!   [`attention`] implements the softmax re-scaling reduction operator
//!   (§IV-A), [`partition`] the LeanTile stream-K decomposition plus the
//!   FlashAttention-2 / FlashDecoding / FlashInfer baselines (§IV-B/C)
//!   and the cascade shared-prefix planner ([`partition::cascade`]),
//!   [`sim`] the GPU execution-model simulator that regenerates every
//!   figure of the evaluation (plus modeled KV traffic for cascade),
//!   [`runtime`] the PJRT loader for the AOT artifacts,
//!   [`coordinator`] a decode-serving engine (router → continuous
//!   batcher → radix prefix cache → copy-on-write paged KV cache →
//!   stream-K attention with Rust-side reduction), [`sampling`] the
//!   deterministic logits pipeline plus parallel-sampling controllers,
//!   [`spec`] speculative decoding (draft-and-verify over the
//!   multi-query lean pass, bit-identical to sequential decoding),
//!   [`sparse`] page-granular top-k KV selection for long-context decode
//!   (score → select → gather → lean over a pruned page set), and
//!   [`obs`] the engine observability plane (structured step tracing,
//!   phase-timing histograms, request timelines, serving SLO reports).
//!
//! Quick start (after `make artifacts`):
//!
//! ```no_run
//! use lean_attention::partition::{DecodeProblem, Strategy};
//! use lean_attention::sim::{self, GpuArch};
//!
//! let problem = DecodeProblem::uniform(4, 32, 65536, 64); // B=4, H=32, 64k ctx
//! let arch = GpuArch::a100();
//! let lean = sim::simulate(&problem, Strategy::StreamK, &arch);
//! let fd = sim::simulate(&problem, Strategy::fixed_split_auto(&problem, arch.num_sms), &arch);
//! println!("speedup over FlashDecoding: {:.2}x", fd.latency_us / lean.latency_us);
//! ```

pub mod attention;
pub mod bench_harness;
pub mod coordinator;
pub mod model;
pub mod obs;
pub mod partition;
pub mod runtime;
pub mod sampling;
pub mod sim;
pub mod sparse;
pub mod spec;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
