//! One generator per table/figure of the paper's evaluation (§V-VI).
//! Every function returns [`Table`]s whose rows mirror what the paper
//! plots; the `rust/benches/figXX_*.rs` binaries call these and emit the
//! results. The acceptance criterion is the *shape* of each result (who
//! wins, by roughly what factor, where crossovers fall) — see DESIGN.md §4.

use crate::model::ModelConfig;
use crate::partition::plan::{build_plan, DecodeProblem, Strategy};
use crate::sim::schedule::{schedule_detail, simulate, simulate_all};
use crate::sim::timeshare::{timeshare, FD_AUTO};
use crate::sim::GpuArch;
use crate::util::stats::geomean;

use super::table::{ctx_label, f2, f3, Table};
use super::workload::{ragged_batch, sweep_population};

/// Speedup of LeanAttention over each baseline for one problem.
fn speedups(problem: &DecodeProblem, arch: &GpuArch) -> (f64, f64, f64, f64) {
    let rs = simulate_all(problem, arch);
    let (fa2, fd, fi, la) = (&rs[0], &rs[1], &rs[2], &rs[3]);
    (
        fd.latency_us / la.latency_us,
        fi.latency_us / la.latency_us,
        fa2.latency_us / la.latency_us,
        la.latency_us,
    )
}

/// Table I: self-attention operation shapes, prefill vs decode.
pub fn table1() -> Table {
    let mut t = Table::new(
        "Table I — operations in self-attention (M x N x K)",
        &["operation", "type", "prefill", "decode"],
    );
    t.row(vec![
        "query x key".into(),
        "MatMul".into(),
        "N x d x N".into(),
        "1 x d x N".into(),
    ]);
    t.row(vec![
        "softmax".into(),
        "EleWise".into(),
        "N x N".into(),
        "1 x N".into(),
    ]);
    t.row(vec![
        "attn_score x value".into(),
        "MatMul".into(),
        "N x N x d".into(),
        "1 x N x d".into(),
    ]);
    t
}

/// Fig 1: ASCII execution schedules of FA2 / FD / LA on a hypothetical
/// 5-SM GPU running 2 heads (10 LeanTiles of context each).
pub fn fig01_schedule() -> String {
    let arch = GpuArch::toy(5);
    let problem = DecodeProblem::uniform(1, 2, 5 * 256, 64); // 2 heads x 5 tiles
    let mut out = String::new();
    for (label, strategy) in [
        ("FlashAttention-2", Strategy::Dense),
        ("FlashDecoding (fixed-split s=2)", Strategy::FixedSplit { splits: 2 }),
        ("LeanAttention (stream-K)", Strategy::StreamK),
    ] {
        let plan = build_plan(&problem, strategy, arch.sm_slots());
        let detail = schedule_detail(&plan, &problem, &arch);
        let r = simulate(&problem, strategy, &arch);
        let makespan = detail.iter().map(|c| c.finish_us).fold(0.0, f64::max);
        out.push_str(&format!(
            "{label}  (occupancy {:.0}%, latency {:.1}us)\n",
            r.occupancy * 100.0,
            r.latency_us
        ));
        let cols = 60usize;
        for sm in 0..arch.num_sms {
            let mut bar = vec![b'.'; cols];
            for c in detail.iter().filter(|c| c.slot == sm) {
                let a = (c.start_us / makespan * cols as f64) as usize;
                let b = ((c.finish_us / makespan * cols as f64) as usize).min(cols);
                let glyph = b'0' + (c.groups[0] % 10) as u8;
                for x in bar.iter_mut().take(b).skip(a) {
                    *x = glyph;
                }
            }
            out.push_str(&format!(
                "  SM{sm} |{}|\n",
                String::from_utf8_lossy(&bar)
            ));
        }
        out.push('\n');
    }
    out.push_str("digits = head id owning each time slice; '.' = idle\n");
    out
}

/// Fig 2: prefill/decode timeshare for Phi-3 Medium, 8:1 token ratio.
pub fn fig02_timeshare() -> Table {
    let cfg = ModelConfig::phi3_medium();
    let arch = GpuArch::a100();
    let mut t = Table::new(
        "Fig 2 — timeshare, Phi-3 Medium, prompt:output = 8:1, BS 1 (A100)",
        &["prompt", "prefill%", "decode_qkv_mlp%", "decode_attn%", "decode_total%"],
    );
    for p in [1024usize, 4096, 8192, 16384, 32768, 65536, 131_072] {
        let ts = timeshare(&cfg, &arch, p, 8, 1, FD_AUTO);
        let total = ts.total_s();
        t.row(vec![
            ctx_label(p),
            f2(100.0 * ts.prefill_s / total),
            f2(100.0 * ts.decode_qkv_mlp_s / total),
            f2(100.0 * ts.decode_attention_s / total),
            f2(100.0 * ts.decode_fraction()),
        ]);
    }
    t.note("paper: decode >50% of time even at 8:1; attention 40-50% of decode at long prompts");
    t
}

/// Fig 3: resource utilization (the paper's Nsight view), LA vs FD,
/// 56 heads, BS 1 (A100): SM occupancy plus achieved-DRAM-bandwidth
/// fraction (decode attention is bandwidth-bound, so DRAM% tracks
/// occupancy — exactly the coupling Fig 3 shows).
pub fn fig03_occupancy() -> Table {
    let arch = GpuArch::a100();
    let mut t = Table::new(
        "Fig 3 — resource utilization, heads=56 BS=1 d=64 (A100, 108 SMs)",
        &[
            "ctx",
            "FD_occupancy%",
            "LA_occupancy%",
            "FD_dram%",
            "LA_dram%",
            "FD_grid",
            "LA_grid",
        ],
    );
    for p in 12..=18 {
        let ctx = 1usize << p;
        let problem = DecodeProblem::uniform(1, 56, ctx, 64);
        let fd = simulate(
            &problem,
            Strategy::fixed_split_auto(&problem, arch.num_sms),
            &arch,
        );
        let la = simulate(&problem, Strategy::StreamK, &arch);
        // Achieved DRAM fraction: total K+V bytes (fp16) over bw * latency.
        let bytes =
            2.0 * (problem.groups() * ctx * 64) as f64 * crate::sim::cost::KV_BYTES;
        let dram = |lat_us: f64| 100.0 * bytes / (arch.hbm_bw_gbs * 1e3 * lat_us);
        t.row(vec![
            ctx_label(ctx),
            f2(fd.occupancy * 100.0),
            f2(la.occupancy * 100.0),
            f2(dram(fd.latency_us)),
            f2(dram(la.latency_us)),
            fd.grid.to_string(),
            la.grid.to_string(),
        ]);
    }
    t.note("paper: FD suffers quantization inefficiency on 108 SMs; LA occupies all SMs");
    t.note("DRAM% = achieved KV-stream bandwidth / peak (bandwidth-bound op)");
    t
}

/// Shared builder for the Fig 7/8/9 speedup panels.
fn speedup_panel(
    title: &str,
    arch: &GpuArch,
    problems: Vec<(String, DecodeProblem)>,
) -> Table {
    let mut t = Table::new(
        title,
        &["x", "LA/FD", "LA/FI", "LA/FA2", "LA_us"],
    );
    for (label, p) in problems {
        let (fd, fi, fa2, la_us) = speedups(&p, arch);
        t.row(vec![label, f2(fd), f2(fi), f2(fa2), f2(la_us)]);
    }
    t
}

/// Fig 7: A100 speedups (a) vs context, (b) vs heads, (c) vs batch.
pub fn fig07_a100() -> Vec<Table> {
    let arch = GpuArch::a100();
    let a = speedup_panel(
        "Fig 7a — A100, heads=32 BS=4 d=64, speedup vs context",
        &arch,
        (10..=18)
            .map(|p| {
                let ctx = 1usize << p;
                (ctx_label(ctx), DecodeProblem::uniform(4, 32, ctx, 64))
            })
            .collect(),
    );
    let b = speedup_panel(
        "Fig 7b — A100, ctx=256k BS=4 d=64, speedup vs heads",
        &arch,
        [8usize, 12, 16, 24, 32, 40, 48, 56, 64]
            .iter()
            .map(|&h| (h.to_string(), DecodeProblem::uniform(4, h, 262_144, 64)))
            .collect(),
    );
    let c = speedup_panel(
        "Fig 7c — A100, heads=32 ctx=64k d=64, speedup vs batch",
        &arch,
        [1usize, 2, 4, 8, 16, 32]
            .iter()
            .map(|&bs| (bs.to_string(), DecodeProblem::uniform(bs, 32, 65536, 64)))
            .collect(),
    );
    vec![a, b, c]
}

/// Fig 8: H100 speedups.
pub fn fig08_h100() -> Vec<Table> {
    let arch = GpuArch::h100();
    let a = speedup_panel(
        "Fig 8a — H100, heads=48 BS=6 d=64, speedup vs context",
        &arch,
        (10..=16)
            .map(|p| {
                let ctx = 1usize << p;
                (ctx_label(ctx), DecodeProblem::uniform(6, 48, ctx, 64))
            })
            .collect(),
    );
    let b = speedup_panel(
        "Fig 8b — H100, ctx=64k BS=6 d=64, speedup vs heads",
        &arch,
        [8usize, 16, 24, 32, 48, 56, 64]
            .iter()
            .map(|&h| (h.to_string(), DecodeProblem::uniform(6, h, 65536, 64)))
            .collect(),
    );
    let c = speedup_panel(
        "Fig 8c — H100, heads=48 ctx=64k d=64, speedup vs batch",
        &arch,
        [1usize, 2, 4, 6, 8, 16, 32]
            .iter()
            .map(|&bs| (bs.to_string(), DecodeProblem::uniform(bs, 48, 65536, 64)))
            .collect(),
    );
    vec![a, b, c]
}

/// Fig 9d (GQA extension): tensor parallelism shards **KV heads** — the
/// unit that owns KV bytes — so each GPU keeps whole query-head groups
/// and per-GPU KV traffic shrinks by the group size. The dense row
/// (`kv_heads == heads`) reproduces plain per-head sharding.
fn fig09d_gqa_tp() -> Table {
    use crate::partition::tensor_parallel::{shard_heads, simulate_sharded};
    use crate::sim::cost::kv_stream_bytes;
    let gpu = GpuArch::a100();
    let mut t = Table::new(
        "Fig 9d — 8xA100 TP over kv heads, heads=256 BS=4 ctx=256k d=64",
        &["kv_heads", "group", "kv/gpu", "q/gpu", "LA_us", "KV_MiB/gpu", "dense_KV_x"],
    );
    let mut dense_bytes = None;
    for kv in [256usize, 64, 32, 8] {
        let p = DecodeProblem::uniform(4, 256, 262_144, 64).with_kv_heads(kv);
        let shards = shard_heads(&p, 8, Strategy::StreamK, gpu.sm_slots())
            .expect("256 query heads shard over 8 GPUs at every grouping");
        let r = simulate_sharded(&shards, &gpu);
        let bytes =
            kv_stream_bytes(shards[0].problem.total_tiles(), p.tile, p.head_dim);
        let dense = *dense_bytes.get_or_insert(bytes);
        t.row(vec![
            kv.to_string(),
            (256 / kv).to_string(),
            shards[0].problem.kv_heads.to_string(),
            shards[0].problem.heads.to_string(),
            f2(r.latency_us),
            f2(bytes / (1024.0 * 1024.0)),
            f2(dense / bytes),
        ]);
    }
    t
}

/// Fig 9: 8×A100 tensor-parallel speedups.
pub fn fig09_multigpu() -> Vec<Table> {
    let arch = GpuArch::a100().multi(8);
    let a = speedup_panel(
        "Fig 9a — 8xA100, heads=256 BS=4 d=64, speedup vs context",
        &arch,
        (10..=20)
            .map(|p| {
                let ctx = 1usize << p;
                (ctx_label(ctx), DecodeProblem::uniform(4, 256, ctx, 64))
            })
            .collect(),
    );
    let b = speedup_panel(
        "Fig 9b — 8xA100, ctx=256k BS=4 d=64, speedup vs heads",
        &arch,
        [64usize, 128, 160, 256, 384, 512]
            .iter()
            .map(|&h| (h.to_string(), DecodeProblem::uniform(4, h, 262_144, 64)))
            .collect(),
    );
    let c = speedup_panel(
        "Fig 9c — 8xA100, heads=256 ctx=256k d=64, speedup vs batch",
        &arch,
        [1usize, 2, 4, 8, 16, 32]
            .iter()
            .map(|&bs| (bs.to_string(), DecodeProblem::uniform(bs, 256, 262_144, 64)))
            .collect(),
    );
    vec![a, b, c, fig09d_gqa_tp()]
}

/// Fig 10: ragged batching — LA/FD speedup vs batch-context-ratio.
pub fn fig10_ragged() -> Table {
    let arch = GpuArch::a100();
    let mut t = Table::new(
        "Fig 10 — ragged batching, heads=32 max_ctx=64k d=64 (A100)",
        &["batch", "context_ratio%", "LA/FD", "LA/FA2"],
    );
    for &batch in &[4usize, 8, 16] {
        for &ratio in &[0.2, 0.4, 0.6, 0.8, 1.0] {
            let p = ragged_batch(batch, 32, 65536, ratio, 42);
            let fd = simulate(
                &p,
                Strategy::fixed_split_auto(&p, arch.num_sms),
                &arch,
            );
            let fa2 = simulate(&p, Strategy::Dense, &arch);
            let la = simulate(&p, Strategy::StreamK, &arch);
            t.row(vec![
                batch.to_string(),
                f2(p.batch_context_ratio() * 100.0),
                f2(fd.latency_us / la.latency_us),
                f2(fa2.latency_us / la.latency_us),
            ]);
        }
    }
    t.note("paper: speedup grows as heterogeneity increases (ratio falls)");
    t
}

/// Fig 11: head-dim-128 model family (LLaMA-2 / Mistral / Phi-3 shapes),
/// 128-token LeanTile.
pub fn fig11_headdim128() -> Table {
    let arch = GpuArch::a100();
    let mut t = Table::new(
        "Fig 11 — head_dim=128 models (128-token LeanTile), BS=1 (A100)",
        &["model", "heads", "ctx", "LA/FD", "LA/FI", "LA/FA2"],
    );
    let models = [
        ModelConfig::llama2_7b(),
        ModelConfig::mistral_7b(),
        ModelConfig::phi3_medium(),
    ];
    for cfg in &models {
        for p in [13usize, 14, 15, 16, 17] {
            let ctx = 1usize << p;
            let problem = DecodeProblem::uniform(1, cfg.n_kv_heads, ctx, cfg.head_dim);
            let (fd, fi, fa2, _) = speedups(&problem, &arch);
            t.row(vec![
                cfg.name.to_string(),
                cfg.n_kv_heads.to_string(),
                ctx_label(ctx),
                f2(fd),
                f2(fi),
                f2(fa2),
            ]);
        }
    }
    t.note("paper: 1.34x at 8k rising to ~3.5x at 128k over FD");
    t
}

/// Fig 12: end-to-end Phi-3 Medium inference speedup (prefill + decode).
pub fn fig12_e2e() -> Table {
    let cfg = ModelConfig::phi3_medium();
    let arch = GpuArch::a100();
    let mut t = Table::new(
        "Fig 12 — e2e Phi-3 Medium, prompt:output = 8:1, BS 1 (A100)",
        &["prompt", "FD_total_s", "FA2_total_s", "LA_total_s", "vs_FD", "vs_FA2"],
    );
    for p in [1024usize, 4096, 8192, 16384, 32768, 65536, 131_072] {
        let fd = timeshare(&cfg, &arch, p, 8, 1, FD_AUTO);
        let fa2 = timeshare(&cfg, &arch, p, 8, 1, Strategy::Dense);
        let la = timeshare(&cfg, &arch, p, 8, 1, Strategy::StreamK);
        t.row(vec![
            ctx_label(p),
            f3(fd.total_s()),
            f3(fa2.total_s()),
            f3(la.total_s()),
            f2(fd.total_s() / la.total_s()),
            f2(fa2.total_s() / la.total_s()),
        ]);
    }
    t.note("paper: 1.12x vs FD at 1k outputs; avg 1.73x vs FA2 beyond 16k");
    t
}

/// Fig 13: attention-kernel energy relative to FlashDecoding.
pub fn fig13_energy() -> Table {
    let arch = GpuArch::a100();
    let mut t = Table::new(
        "Fig 13 — energy ratio vs FlashDecoding, heads=56 BS=1 d=64 (A100)",
        &["ctx", "FA2/FD", "FI/FD", "LA/FD"],
    );
    for p in 10..=19 {
        let ctx = 1usize << p;
        let problem = DecodeProblem::uniform(1, 56, ctx, 64);
        let rs = simulate_all(&problem, &arch);
        let fd = rs[1].energy_j;
        t.row(vec![
            ctx_label(ctx),
            f2(rs[0].energy_j / fd),
            f2(rs[2].energy_j / fd),
            f2(rs[3].energy_j / fd),
        ]);
    }
    t.note("paper: LA more energy-efficient; gap grows past 128k ctx");
    t
}

/// §VI aggregate: the >1000-sample sweep reproducing the headline
/// averages (1.73x over FD, 3.42x over FI on A100; 1.52x/3.63x on H100).
pub fn sweep_aggregate(samples: usize, arch: &GpuArch) -> Table {
    let pop = sweep_population(samples, 0xC0FFEE);
    let mut fd_speed = Vec::with_capacity(pop.len());
    let mut fi_speed = Vec::with_capacity(pop.len());
    let mut max_fd = (0.0f64, String::new());
    let mut max_fi = (0.0f64, String::new());
    for p in &pop {
        let (fd, fi, _, _) = speedups(p, arch);
        let label = format!(
            "heads={} bs={} ctx={}",
            p.heads,
            p.batch(),
            ctx_label(p.ctx_lens[0] as usize)
        );
        if fd > max_fd.0 {
            max_fd = (fd, label.clone());
        }
        if fi > max_fi.0 {
            max_fi = (fi, label);
        }
        fd_speed.push(fd);
        fi_speed.push(fi);
    }
    let mut t = Table::new(
        format!("§VI aggregate — {} samples on {}", pop.len(), arch.name),
        &["baseline", "mean_speedup", "geomean", "max", "max_at"],
    );
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    t.row(vec![
        "FlashDecoding".into(),
        f2(mean(&fd_speed)),
        f2(geomean(&fd_speed)),
        f2(max_fd.0),
        max_fd.1,
    ]);
    t.row(vec![
        "FlashInfer".into(),
        f2(mean(&fi_speed)),
        f2(geomean(&fi_speed)),
        f2(max_fi.0),
        max_fi.1,
    ]);
    t.note("paper A100: avg 1.73x / max 2.18x over FD; avg 3.42x / max 5.66x over FI");
    t
}

// ---- ablations & extensions (DESIGN.md §5; not paper figures) ----------

/// Ablation: LeanTile granularity sweep (§IV-B's 256-token choice for
/// d=64). Sweeps the tile size on a fixed problem and reports simulated
/// latency + balance.
pub fn ablation_lean_tile() -> Table {
    let arch = GpuArch::a100();
    let mut t = Table::new(
        "Ablation — LeanTile size, heads=32 BS=4 ctx=64k d=64 (A100)",
        &["tile", "LA_us", "imbalance", "tiles_total", "partials_max"],
    );
    for tile in [32usize, 64, 128, 256, 512, 1024] {
        let p = DecodeProblem::uniform(4, 32, 65536, 64).with_tile(tile);
        let plan = build_plan(&p, Strategy::StreamK, arch.sm_slots());
        let r = crate::sim::schedule::simulate_plan(&plan, &p, &arch);
        t.row(vec![
            tile.to_string(),
            f2(r.latency_us),
            f3(plan.imbalance()),
            p.total_tiles().to_string(),
            plan.partials_per_group().iter().max().unwrap().to_string(),
        ]);
    }
    t.note("paper §IV-B picks 256 tokens for d=64: small tiles pay setup, large tiles quantize");
    t
}

/// Ablation: co-resident CTAs per SM (grid = SMs × this, Eq. 2).
pub fn ablation_ctas_per_sm() -> Table {
    let mut t = Table::new(
        "Ablation — MaxCTAsPerSM, heads=32 BS=4 ctx=64k d=64 (A100)",
        &["ctas_per_sm", "grid", "LA_us", "occupancy%"],
    );
    for ctas in [1usize, 2, 4] {
        let mut arch = GpuArch::a100();
        arch.max_ctas_per_sm = ctas;
        let p = DecodeProblem::uniform(4, 32, 65536, 64);
        let r = simulate(&p, Strategy::StreamK, &arch);
        t.row(vec![
            ctas.to_string(),
            r.grid.to_string(),
            f2(r.latency_us),
            f2(r.occupancy * 100.0),
        ]);
    }
    t.note("paper: 2 CTAs co-resident for the 256-token tile on A100");
    t
}

/// Ablation: FlashInfer page size — the paper observed *no* latency
/// impact from page size; the model reproduces that (page size only
/// coarsens boundaries, not bandwidth).
pub fn ablation_fi_page() -> Table {
    let arch = GpuArch::a100();
    let mut t = Table::new(
        "Ablation — FlashInfer page size, heads=32 BS=4 ctx=64k d=64 (A100)",
        &["page", "FI_us"],
    );
    let p = DecodeProblem::uniform(4, 32, 65536, 64);
    let splits = match Strategy::fixed_split_auto(&p, arch.num_sms) {
        Strategy::FixedSplit { splits } => splits,
        _ => 1,
    };
    for page in [8usize, 16, 32, 64] {
        let r = simulate(&p, Strategy::PagedFixedSplit { splits, page }, &arch);
        t.row(vec![page.to_string(), f2(r.latency_us)]);
    }
    t.note("paper §V: no impact of page size on FlashInfer latency — reproduced");
    t
}

/// Extension (§V Batching): heterogeneous prefill+decode batches. The
/// generalized stream-K planner balances LeanTiles across phases where
/// fixed-split inherits per-tile imbalance.
pub fn mixed_phase_batching() -> Table {
    use crate::partition::workspec::{
        fixed_split_from_counts, stream_k_from_counts, MixedWorkload, PhaseReq,
    };
    let arch = GpuArch::a100();
    let mut t = Table::new(
        "Extension — mixed prefill+decode batches, heads=32 d=64 (A100)",
        &["mix", "tiles", "LA_imbalance", "FD_imbalance"],
    );
    let mixes: Vec<(&str, Vec<PhaseReq>)> = vec![
        (
            "1 prefill(2k) + 3 decode(64k)",
            vec![
                PhaseReq::Prefill { q_len: 2048, past: 0 },
                PhaseReq::Decode { ctx: 65536 },
                PhaseReq::Decode { ctx: 65536 },
                PhaseReq::Decode { ctx: 65536 },
            ],
        ),
        (
            "chunked prefill + long decode",
            vec![
                PhaseReq::Prefill { q_len: 512, past: 8192 },
                PhaseReq::Decode { ctx: 262_144 },
            ],
        ),
        (
            "decode-heavy ragged",
            vec![
                PhaseReq::Decode { ctx: 1024 },
                PhaseReq::Decode { ctx: 131_072 },
                PhaseReq::Prefill { q_len: 128, past: 0 },
            ],
        ),
    ];
    for (label, reqs) in mixes {
        let w = MixedWorkload::new(32, 64, reqs);
        let counts = w.tile_counts();
        let la = stream_k_from_counts(&counts, w.tile, arch.sm_slots());
        let fd = fixed_split_from_counts(
            &counts,
            w.tile,
            8,
            Strategy::FixedSplit { splits: 8 },
        );
        t.row(vec![
            label.to_string(),
            w.total_tiles().to_string(),
            f3(la.imbalance()),
            f3(fd.imbalance()),
        ]);
    }
    t.note("stream-K keeps max/mean ~1.0 across phase mixes (§V batching claim)");
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn col(t: &Table, name: &str) -> Vec<f64> {
        let idx = t.headers.iter().position(|h| h == name).unwrap();
        t.rows
            .iter()
            .map(|r| r[idx].parse::<f64>().unwrap())
            .collect()
    }

    #[test]
    fn fig01_renders_all_three_mechanisms() {
        let s = fig01_schedule();
        assert!(s.contains("FlashAttention-2"));
        assert!(s.contains("LeanAttention"));
        assert!(s.contains("SM4"));
    }

    #[test]
    fn fig02_decode_majority() {
        let t = fig02_timeshare();
        let decode = col(&t, "decode_total%");
        assert!(decode.iter().all(|&d| d > 50.0), "decode {decode:?}");
        // attention's share of the budget grows with prompt (paper: up to
        // 40-50% of decode time)
        let attn = col(&t, "decode_attn%");
        assert!(attn.last().unwrap() > attn.first().unwrap());
        assert!(*attn.last().unwrap() > 40.0, "attn share {attn:?}");
    }

    #[test]
    fn fig03_la_occupancy_dominates() {
        let t = fig03_occupancy();
        let fd = col(&t, "FD_occupancy%");
        let la = col(&t, "LA_occupancy%");
        for (f, l) in fd.iter().zip(&la) {
            assert!(l >= f, "LA {l} vs FD {f}");
        }
        // near-perfect occupancy once the context provides enough tiles
        // (>= 8k for 56 heads); the 4k point has only ~4 tiles per CTA.
        assert!(la[1..].iter().all(|&o| o > 90.0), "LA occupancy {la:?}");
    }

    #[test]
    fn fig07a_speedup_grows_with_context() {
        let t = &fig07_a100()[0];
        let s = col(t, "LA/FD");
        assert!(s.iter().all(|&x| x >= 0.95), "never slower: {s:?}");
        assert!(
            s.last().unwrap() > &1.3,
            "long-ctx speedup: {s:?}"
        );
    }

    #[test]
    fn fig09d_kv_bytes_shrink_with_the_group_size() {
        let t = fig09_multigpu().pop().unwrap();
        assert!(t.title.contains("Fig 9d"), "{}", t.title);
        // Rows sweep kv_heads 256 (dense), 64, 32, 8: per-GPU KV traffic
        // shrinks by exactly the group size 1, 4, 8, 32.
        let x = col(&t, "dense_KV_x");
        for (got, want) in x.iter().zip([1.0, 4.0, 8.0, 32.0]) {
            assert!((got - want).abs() < 0.01, "{x:?}");
        }
    }

    #[test]
    fn fig10_more_heterogeneity_more_speedup() {
        let t = fig10_ragged();
        // within each batch block, speedup at ratio 20% >= at 100%
        let s = col(&t, "LA/FD");
        let r = col(&t, "context_ratio%");
        for chunk in s.chunks(5).zip(r.chunks(5)) {
            let (sc, _rc) = chunk;
            assert!(
                sc.first().unwrap() >= sc.last().unwrap(),
                "hetero speedup {sc:?}"
            );
        }
    }

    #[test]
    fn fig13_la_uses_less_energy() {
        let t = fig13_energy();
        let la = col(&t, "LA/FD");
        assert!(la.iter().all(|&x| x <= 1.02), "LA energy ratio {la:?}");
        // gap grows with context
        assert!(la.last().unwrap() <= la.first().unwrap());
    }

    #[test]
    fn ablation_fi_page_flat() {
        let t = ablation_fi_page();
        let us = col(&t, "FI_us");
        let (min, max) = (
            us.iter().cloned().fold(f64::MAX, f64::min),
            us.iter().cloned().fold(0.0, f64::max),
        );
        assert!(max / min < 1.05, "page size should not matter: {us:?}");
    }

    #[test]
    fn ablation_tables_nonempty() {
        assert!(!ablation_lean_tile().rows.is_empty());
        assert!(!ablation_ctas_per_sm().rows.is_empty());
        let m = mixed_phase_batching();
        let la = col(&m, "LA_imbalance");
        let fd = col(&m, "FD_imbalance");
        for (a, b) in la.iter().zip(&fd) {
            assert!(a <= b, "stream-K balance {a} vs FD {b}");
        }
    }

    #[test]
    fn sweep_reproduces_headline_band() {
        let t = sweep_aggregate(150, &GpuArch::a100());
        let mean_fd: f64 = t.rows[0][1].parse().unwrap();
        let mean_fi: f64 = t.rows[1][1].parse().unwrap();
        // paper: 1.73x / 3.42x — accept the band, not the digit
        assert!(
            (1.2..2.6).contains(&mean_fd),
            "FD mean speedup {mean_fd}"
        );
        assert!(mean_fi > mean_fd, "FI slower than FD: {mean_fi}");
    }
}
