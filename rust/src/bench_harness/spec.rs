//! Speculative-decoding measurement: the numbers behind
//! `leanattn bench --spec`.
//!
//! Three halves of the speculative story, all artifact-free:
//!
//! 1. **Streams** — run the host draft-and-verify pipeline against its
//!    sequential oracle on a repetitive workload over the synthetic
//!    target model and require the committed streams to be
//!    bit-identical, reporting accepted-tokens-per-pass.
//! 2. **Attention** — pose one verify pass (`k + 1` staggered-causal
//!    query rows over the cached context) to the multi-query lean
//!    executor and compare it against `k + 1` sequential single-query
//!    passes on gathered-KV bytes (exact by construction) and
//!    wall-clock.
//! 3. **Rollback** — exercise the paged-KV side on a real
//!    [`PagedKvCache`]: fork a sibling, eagerly append a draft block,
//!    truncate the rejected tail, and assert the sibling's view and the
//!    page accounting survive untouched.

use anyhow::{ensure, Result};

use crate::coordinator::PagedKvCache;
use crate::obs::attrib::{account_cascade_problem, WorkAccounting};
use crate::obs::benchlog::BenchReport;
use crate::partition::cascade::build_cascade_plan;
use crate::partition::multi_query::{MultiQueryInputs, MultiQueryProblem, MultiQuerySeq};
use crate::runtime::attention_exec::{
    lean_multi_query_host, roll_cascade_tasks, rolled_kv_bytes,
};
use crate::sampling::{seq_rng, SamplingParams};
use crate::spec::{sequential_generate, spec_generate, DraftKind, SpecStats, SyntheticModel};
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use crate::util::timer::sample_us;

/// Shape of one speculative-decoding comparison.
#[derive(Clone, Copy, Debug)]
pub struct SpecCase {
    /// Draft tokens per verify pass.
    pub k: usize,
    /// Tokens to generate in the stream comparison.
    pub max_new: usize,
    /// Prompt length (a repeating pattern of `period` tokens).
    pub prompt_len: usize,
    /// Period of the repetitive workload.
    pub period: usize,
    /// Target-model vocabulary.
    pub vocab: usize,
    /// Draft source (`ngram` self-draft or the smaller-model drafter).
    pub draft: DraftKind,
    /// Cached context tokens for the verify-pass attention comparison.
    pub history: usize,
    pub heads: usize,
    pub head_dim: usize,
    pub layers: usize,
    pub page_tokens: usize,
    pub tile: usize,
}

impl SpecCase {
    /// The `leanattn bench --spec` default shape.
    pub fn default_case() -> SpecCase {
        SpecCase {
            k: 4,
            max_new: 64,
            prompt_len: 32,
            period: 8,
            vocab: 64,
            draft: DraftKind::NGram,
            history: 256,
            heads: 2,
            head_dim: 16,
            layers: 2,
            page_tokens: 16,
            tile: 32,
        }
    }

    /// CI smoke shape: small and fast, still repetitive enough that the
    /// self-drafter keeps its >1-token-per-pass guarantee meaningful.
    pub fn smoke() -> SpecCase {
        SpecCase { max_new: 32, history: 64, ..SpecCase::default_case() }
    }
}

/// Outcome of one speculative comparison.
pub struct SpecComparison {
    pub case: SpecCase,
    /// Draft-and-verify counters of the stream comparison (the stream
    /// itself is asserted identical to the sequential oracle before
    /// anything is measured).
    pub stats: SpecStats,
    /// K+V bytes one multi-query verify pass gathers (context streamed
    /// once for all `k + 1` rows).
    pub verify_kv_bytes: usize,
    /// K+V bytes `k + 1` sequential single-query passes gather.
    pub sequential_kv_bytes: usize,
    pub verify_us: Summary,
    pub sequential_us: Summary,
    /// Draft KV rows rolled back by the paged-cache exercise.
    pub rolled_back_tokens: usize,
    /// COW page clones the eager draft append triggered (shared tail).
    pub cow_copies: usize,
    /// Exact work of the one multi-query verify pass.
    pub work_verify: WorkAccounting,
    /// Exact work of the `k + 1` sequential single-query passes.
    pub work_sequential: WorkAccounting,
}

impl SpecComparison {
    /// Fraction of sequential gather traffic the verify pass avoids.
    pub fn bytes_saved_fraction(&self) -> f64 {
        if self.sequential_kv_bytes == 0 {
            return 0.0;
        }
        1.0 - self.verify_kv_bytes as f64 / self.sequential_kv_bytes as f64
    }

    /// Machine-readable telemetry for `--json-out` / the baseline gate.
    pub fn bench_report(&self, seed: u64, smoke: bool) -> BenchReport {
        let mut r = BenchReport::new("spec", seed, smoke);
        r.count("k", self.case.k as u64);
        r.count("max_new", self.case.max_new as u64);
        r.count("history_tokens", self.case.history as u64);
        r.count("verify_passes", self.stats.verify_passes as u64);
        r.count("drafted", self.stats.drafted as u64);
        r.count("accepted", self.stats.accepted as u64);
        r.count("committed", self.stats.committed as u64);
        r.count("verify_kv_bytes", self.verify_kv_bytes as u64);
        r.count("sequential_kv_bytes", self.sequential_kv_bytes as u64);
        r.count("rolled_back_tokens", self.rolled_back_tokens as u64);
        r.count("cow_copies", self.cow_copies as u64);
        r.work("verify", self.work_verify);
        r.work("sequential", self.work_sequential);
        r.measure("bytes_saved_fraction", self.bytes_saved_fraction());
        r.measure("acceptance_rate", self.stats.acceptance_rate());
        r.measure("tokens_per_pass", self.stats.tokens_per_pass());
        r.info("verify_us_p50", self.verify_us.p50);
        r.info("sequential_us_p50", self.sequential_us.p50);
        r
    }
}

/// Single-row decode problem over `ctx` cached tokens (one sequential
/// step of the baseline).
fn single_step(case: &SpecCase, ctx: usize) -> MultiQueryProblem {
    MultiQueryProblem::new(
        case.heads,
        case.head_dim,
        vec![MultiQuerySeq { base_len: ctx, q_len: 1 }],
        Vec::new(),
    )
    .expect("single-step problems are valid")
    .with_tile(case.tile)
}

/// Run the three-part comparison. The token streams are asserted
/// bit-identical before any timing happens.
pub fn compare_spec(case: SpecCase, iters: usize, seed: u64) -> Result<SpecComparison> {
    ensure!(case.k >= 1, "need at least one draft token");
    ensure!(case.vocab >= 2 && case.period >= 1 && case.prompt_len >= 1, "workload shape");
    ensure!(case.max_new >= 1, "need tokens to generate");
    ensure!(case.period <= case.vocab, "period must fit the vocab");
    // With no cached context there is nothing for the verify pass to
    // deduplicate — the strict verify-vs-sequential byte inequality the
    // bench asserts would be vacuously violated.
    ensure!(case.history >= 1, "need a nonzero cached context (--history)");

    // --- 1. streams: spec vs sequential over the synthetic target -----
    let target = SyntheticModel::new(case.vocab, seed, 6.0);
    let prompt: Vec<i32> = (0..case.prompt_len)
        .map(|i| (i % case.period) as i32)
        .collect();
    let params = SamplingParams::greedy();
    let mut oracle_rng = seq_rng(seed, 1);
    let sequential = sequential_generate(&target, &prompt, case.max_new, &params, &mut oracle_rng);
    let mut drafter = case.draft.build(case.vocab, seed);
    let mut spec_rng = seq_rng(seed, 1);
    let run = spec_generate(
        &target,
        drafter.as_mut(),
        case.k,
        &prompt,
        case.max_new,
        &params,
        &mut spec_rng,
    );
    ensure!(
        run.tokens == sequential,
        "speculative stream diverged from the sequential oracle"
    );

    // --- 2. attention: one multi-query verify pass vs k+1 single-query
    // passes over the same context ------------------------------------
    let q_len = case.k + 1;
    let mq = MultiQueryProblem::new(
        case.heads,
        case.head_dim,
        vec![MultiQuerySeq { base_len: case.history, q_len }],
        Vec::new(),
    )?
    .with_tile(case.tile);
    let inputs = MultiQueryInputs::random(&mq, seed ^ 0x5A5A);
    let slots = 64;
    let batch_rows = 64;
    let cp = mq.expand();
    let cplan = build_cascade_plan(&cp, slots);
    let verify_kv_bytes = rolled_kv_bytes(&roll_cascade_tasks(&cp, &cplan), case.head_dim);

    // The sequential baseline re-streams the (growing) context once per
    // committed token.
    let steps: Vec<(MultiQueryProblem, MultiQueryInputs)> = (0..q_len)
        .map(|i| {
            let p = single_step(&case, case.history + i);
            let inp = MultiQueryInputs::random(&p, seed ^ (i as u64));
            (p, inp)
        })
        .collect();
    let sequential_kv_bytes: usize = steps
        .iter()
        .map(|(p, _)| {
            let cp = p.expand();
            let plan = build_cascade_plan(&cp, slots);
            rolled_kv_bytes(&roll_cascade_tasks(&cp, &plan), case.head_dim)
        })
        .sum();
    let work_verify = account_cascade_problem(&cp);
    let work_sequential = steps
        .iter()
        .map(|(p, _)| account_cascade_problem(&p.expand()))
        .fold(WorkAccounting::default(), |a, w| a + w);
    debug_assert_eq!(work_verify.gathered_kv_bytes, verify_kv_bytes as u64);
    debug_assert_eq!(work_sequential.gathered_kv_bytes, sequential_kv_bytes as u64);

    let verify_samples = sample_us(iters, 0.0, || {
        let _ = lean_multi_query_host(&mq, &inputs, slots, batch_rows).expect("verify pass");
    });
    let sequential_samples = sample_us(iters, 0.0, || {
        for (p, inp) in &steps {
            let _ = lean_multi_query_host(p, inp, slots, batch_rows).expect("decode step");
        }
    });

    // --- 3. paged-KV rollback: fork, eager draft append, truncate ----
    let tokens_peak = case.history + case.k + 1;
    let total_pages = 2 * tokens_peak.div_ceil(case.page_tokens) + 2;
    let mut cache = PagedKvCache::new(
        case.layers,
        case.heads,
        case.head_dim,
        case.page_tokens,
        total_pages,
    );
    let mut rng = Rng::new(seed ^ 0xBEEF);
    let n = case.layers * case.heads * case.history * case.head_dim;
    let (hk, hv) = (rng.normal_vec(n), rng.normal_vec(n));
    cache.insert_seq(0, &hk, &hv, case.history)?;
    cache.fork_seq(0, 1)?;

    // Sibling's view before the parent's speculative churn.
    let ctx = tokens_peak.next_multiple_of(case.page_tokens);
    let view = case.layers * case.heads * ctx * case.head_dim;
    let (mut sk0, mut sv0) = (vec![0.0f32; view], vec![0.0f32; view]);
    cache.gather(&[Some(1)], ctx, &mut sk0, &mut sv0)?;

    // Eagerly append the whole draft block to the parent, then roll
    // everything but one committed token back (the worst case).
    let plane = case.layers * case.heads * case.head_dim;
    let mut cow_copies = 0usize;
    for _ in 0..case.k + 1 {
        let (nk, nv) = (rng.normal_vec(plane), rng.normal_vec(plane));
        if cache.append_token(0, &nk, &nv)? {
            cow_copies += 1;
        }
    }
    let rolled_back_tokens = case.k;
    cache.truncate_seq(0, case.history + 1)?;
    ensure!(cache.seq_len(0) == Some(case.history + 1), "rollback length");

    let (mut sk1, mut sv1) = (vec![0.0f32; view], vec![0.0f32; view]);
    cache.gather(&[Some(1)], ctx, &mut sk1, &mut sv1)?;
    ensure!(
        sk0 == sk1 && sv0 == sv1,
        "sibling view changed under speculative append + rollback"
    );
    cache.free_seq(0);
    cache.free_seq(1);
    ensure!(
        cache.free_pages() == total_pages,
        "speculative rollback leaked pages ({} of {total_pages} free)",
        cache.free_pages()
    );

    Ok(SpecComparison {
        case,
        stats: run.stats,
        verify_kv_bytes,
        sequential_kv_bytes,
        verify_us: Summary::of(&verify_samples),
        sequential_us: Summary::of(&sequential_samples),
        rolled_back_tokens,
        cow_copies,
        work_verify,
        work_sequential,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_case_commits_more_than_one_token_per_pass() {
        let c = compare_spec(SpecCase::default_case(), 1, 7).expect("comparison");
        assert!(
            c.stats.committed > c.stats.verify_passes,
            "committed {} <= passes {}",
            c.stats.committed,
            c.stats.verify_passes
        );
        assert!(c.stats.tokens_per_pass() > 1.0);
        assert!(
            c.verify_kv_bytes < c.sequential_kv_bytes,
            "verify {} vs sequential {}",
            c.verify_kv_bytes,
            c.sequential_kv_bytes
        );
        assert!(c.bytes_saved_fraction() > 0.5, "{}", c.bytes_saved_fraction());
        assert_eq!(c.rolled_back_tokens, c.case.k);
    }

    #[test]
    fn smoke_case_upholds_the_bench_assertions() {
        for draft in [DraftKind::NGram, DraftKind::Model] {
            let case = SpecCase { draft, ..SpecCase::smoke() };
            let c = compare_spec(case, 1, 3).expect("smoke");
            assert!(c.stats.committed > c.stats.verify_passes, "draft {draft}");
            assert!(c.verify_kv_bytes < c.sequential_kv_bytes);
            assert_eq!(c.work_verify.gathered_kv_bytes, c.verify_kv_bytes as u64);
            let rep = c.bench_report(3, true);
            crate::obs::benchlog::validate_bench_report(&rep.to_json()).unwrap();
        }
    }

    #[test]
    fn spec_k_one_still_verifies_and_never_diverges() {
        let case = SpecCase { k: 1, max_new: 16, ..SpecCase::smoke() };
        let c = compare_spec(case, 1, 11).expect("k=1");
        // Streams are asserted equal inside; per-pass commit is in [1, 2].
        assert!(c.stats.tokens_per_pass() >= 1.0);
        assert!(c.stats.tokens_per_pass() <= 2.0);
    }
}
