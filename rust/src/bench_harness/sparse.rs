//! Dense vs **sparse-selected** decode on the paged KV cache: the
//! measurement behind `leanattn bench --sparse`.
//!
//! A batch of long-context sequences runs a host pseudo-decode loop —
//! gather, exact attention, a fixed random readout to logits, the
//! deterministic sampling pipeline, KV append — twice over identical
//! workload randomness:
//!
//! * **dense** — [`PagedKvCache::gather`] materializes every lane's full
//!   context each step;
//! * **sparse** — each lane's pages are scored with the Quest-style
//!   upper bound against the tail-key query proxy (exactly the engine's
//!   selection) and only the selected pages are materialized through
//!   [`PagedKvCache::gather_selected`].
//!
//! At `kv_budget >= context` the selection is complete and the two loops
//! must produce **bit-identical streams** — tokens, logprobs and RNG
//! trajectory — which `leanattn bench --sparse` asserts on every run; at
//! sub-context budgets the sparse loop must read strictly fewer
//! gathered-KV bytes. One context page is planted as a **needle** (keys
//! aligned with the query direction): a sound selector retains it at any
//! budget, measured as needle recall. A one-shot executor check compares
//! [`lean_sparse_host`] against the dense oracle restricted to the same
//! selected pages.

use anyhow::{ensure, Result};

use crate::attention::attention_host;
use crate::coordinator::{PagedKvCache, SparseStats};
use crate::obs::attrib::{account_cascade_problem, WorkAccounting};
use crate::obs::benchlog::BenchReport;
use crate::partition::cascade::CascadeProblem;
use crate::runtime::attention_exec::{lean_sparse_host, sparse_compact_problem};
use crate::sampling::{sample_token, seq_rng, SamplingParams};
use crate::sparse::{selected_token_indices, selected_tokens, SparsePolicy};
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use crate::util::testing::max_abs_err;
use crate::util::timer::sample_us;

/// Shape of one dense-vs-sparse stream comparison (single layer: the
/// query-proxy plane then coincides with the attention head rows).
#[derive(Clone, Copy, Debug)]
pub struct SparseBenchCase {
    /// Concurrent sequences.
    pub seqs: usize,
    /// Context tokens per sequence before stepping.
    pub context: usize,
    /// Pseudo-decode steps.
    pub steps: usize,
    pub heads: usize,
    pub head_dim: usize,
    pub page_tokens: usize,
    pub vocab: usize,
    /// LeanTile width for the executor check.
    pub tile: usize,
    pub policy: SparsePolicy,
    /// Page ordinal the needle (planted attention mass) lands in; must
    /// be a middle page (past the sinks, before the window).
    pub needle_page: usize,
}

impl SparseBenchCase {
    /// The `leanattn bench --sparse` default shape: 16-page contexts
    /// pruned to a 6-page budget.
    pub fn default_case() -> SparseBenchCase {
        SparseBenchCase {
            seqs: 2,
            context: 256,
            steps: 12,
            heads: 2,
            head_dim: 16,
            page_tokens: 16,
            vocab: 64,
            tile: 32,
            policy: SparsePolicy {
                dense_threshold_pages: 4,
                ..SparsePolicy::with_budget(6)
            },
            needle_page: 5,
        }
    }

    /// CI smoke shape: small and fast, budget still below the context so
    /// every assertion stays meaningful.
    pub fn smoke() -> SparseBenchCase {
        SparseBenchCase {
            context: 128,
            steps: 6,
            policy: SparsePolicy {
                dense_threshold_pages: 3,
                ..SparsePolicy::with_budget(4)
            },
            needle_page: 3,
            ..SparseBenchCase::default_case()
        }
    }

    /// Pages a sequence can grow to over the run (context + steps).
    pub fn pages_cap(&self) -> usize {
        (self.context + self.steps).div_ceil(self.page_tokens)
    }

    /// Token capacity of the gathered dense views.
    pub fn ctx_cap(&self) -> usize {
        self.pages_cap() * self.page_tokens
    }
}

/// One loop's outcome: the per-sequence streams plus gather accounting.
pub struct SparseStreamOutcome {
    pub tokens: Vec<Vec<i32>>,
    pub logprobs: Vec<Vec<f32>>,
    /// Post-run draw from every sequence's sampling RNG, folded together:
    /// equal fingerprints mean equal RNG trajectories.
    pub rng_fingerprint: u64,
    /// K+V bytes this loop's gathers materialized.
    pub gathered_bytes: u64,
    /// K+V bytes a dense gather materializes over the same steps.
    pub dense_bytes: u64,
    /// Selection counters (sparse loop only; default for dense).
    pub stats: SparseStats,
    /// Scored steps that kept the needle page / scored steps total.
    pub needle_kept: usize,
    pub needle_chances: usize,
}

/// Outcome of one dense-vs-sparse comparison.
pub struct SparseComparison {
    pub case: SparseBenchCase,
    pub dense: SparseStreamOutcome,
    pub sparse: SparseStreamOutcome,
    /// Gather wall-clock over the final cache state.
    pub dense_us: Summary,
    pub sparse_us: Summary,
    /// Max abs error of the sparse lean executor vs the dense oracle
    /// restricted to the same selected pages (final state, fresh query).
    pub exec_max_err: f32,
    /// Exact work of a dense attention posing over the final state.
    pub work_dense: WorkAccounting,
    /// Exact work of the selected-page posing (the executor check's
    /// compact problem, attrib-accounted).
    pub work_sparse: WorkAccounting,
}

impl SparseComparison {
    /// Whether the two loops produced bit-identical streams (tokens,
    /// logprobs and RNG trajectories).
    pub fn streams_equal(&self) -> bool {
        self.dense.tokens == self.sparse.tokens
            && self.dense.logprobs == self.sparse.logprobs
            && self.dense.rng_fingerprint == self.sparse.rng_fingerprint
    }

    /// Fraction of dense gather traffic the sparse loop avoided.
    pub fn bytes_saved_fraction(&self) -> f64 {
        if self.sparse.dense_bytes == 0 {
            return 0.0;
        }
        1.0 - self.sparse.gathered_bytes as f64 / self.sparse.dense_bytes as f64
    }

    /// Fraction of scored steps that retained the needle page.
    pub fn needle_recall(&self) -> f64 {
        if self.sparse.needle_chances == 0 {
            return 1.0;
        }
        self.sparse.needle_kept as f64 / self.sparse.needle_chances as f64
    }

    /// Machine-readable telemetry for `--json-out` / the baseline gate.
    /// Byte counters and work sections are deterministic for a given
    /// shape and seed (selection scores depend only on workload keys);
    /// RNG fingerprints are folded to 32 bits so the counts stay exact
    /// through the f64-backed JSON layer.
    pub fn bench_report(&self, seed: u64, smoke: bool) -> BenchReport {
        let fold32 = |fp: u64| (fp >> 32) ^ (fp & 0xffff_ffff);
        let mut r = BenchReport::new("sparse", seed, smoke);
        r.count("seqs", self.case.seqs as u64);
        r.count("context_tokens", self.case.context as u64);
        r.count("steps", self.case.steps as u64);
        r.count("budget_pages", self.case.policy.budget_pages as u64);
        r.count("dense_gathered_bytes", self.dense.gathered_bytes);
        r.count("sparse_gathered_bytes", self.sparse.gathered_bytes);
        r.count("selection_steps", self.sparse.stats.selection_steps as u64);
        r.count("rng_fingerprint_dense", fold32(self.dense.rng_fingerprint));
        r.count("rng_fingerprint_sparse", fold32(self.sparse.rng_fingerprint));
        r.work("exec_dense", self.work_dense);
        r.work("exec_sparse", self.work_sparse);
        r.measure("bytes_saved_fraction", self.bytes_saved_fraction());
        r.measure("needle_recall", self.needle_recall());
        r.measure("exec_max_err", f64::from(self.exec_max_err));
        r.info("dense_us_p50", self.dense_us.p50);
        r.info("sparse_us_p50", self.sparse_us.p50);
        r
    }
}

/// The workload's K row for absolute position `t`: needle-page rows are
/// strongly aligned with the shared direction `u`, the final context row
/// and every appended row weakly aligned (they serve as query proxies),
/// everything else is low-amplitude noise.
fn k_row(case: &SparseBenchCase, u: &[f32], t: usize, rng: &mut Rng) -> Vec<f32> {
    let plane = case.heads * case.head_dim;
    let noise = rng.normal_vec(plane);
    let page = t / case.page_tokens;
    if page == case.needle_page && t < case.context {
        (0..plane).map(|j| 4.0 * u[j] + 0.05 * noise[j]).collect()
    } else if t >= case.context - 1 {
        (0..plane).map(|j| u[j] + 0.1 * noise[j]).collect()
    } else {
        noise.iter().map(|x| 0.3 * x).collect()
    }
}

/// One live sequence's selection — the engine's own implementation
/// ([`PagedKvCache::select_seq_pages`]), so the bench measures exactly
/// what serves.
fn select_for(
    cache: &PagedKvCache,
    id: u64,
    policy: &SparsePolicy,
) -> (Vec<usize>, Option<Vec<f32>>) {
    cache.select_seq_pages(id, policy).expect("live sequence")
}

/// Run the pseudo-decode loop once. `sparse` toggles page selection; the
/// workload randomness (context, queries, appended keys) is identical
/// across modes by construction.
fn run_stream(
    case: &SparseBenchCase,
    sparse: bool,
    seed: u64,
) -> Result<(SparseStreamOutcome, PagedKvCache)> {
    let (h, dh, pt) = (case.heads, case.head_dim, case.page_tokens);
    let plane = h * dh;
    let mut cache =
        PagedKvCache::new(1, h, dh, pt, case.seqs * case.pages_cap() + 2);
    let mut wl = Rng::new(seed);
    let u = wl.normal_vec(plane);
    let readout = wl.normal_vec(plane * case.vocab);

    // Prefill: identical contexts-by-construction across modes.
    for s in 0..case.seqs as u64 {
        let mut k = vec![0.0f32; plane * case.context];
        let mut v = vec![0.0f32; k.len()];
        for t in 0..case.context {
            let row = k_row(case, &u, t, &mut wl);
            let vrow = wl.normal_vec(plane);
            for hi in 0..h {
                // [layers=1, heads, len, dh] insert layout.
                let dst = (hi * case.context + t) * dh;
                k[dst..dst + dh].copy_from_slice(&row[hi * dh..(hi + 1) * dh]);
                for j in 0..dh {
                    v[dst + j] = 0.5 * vrow[hi * dh + j];
                }
            }
        }
        cache.insert_seq(s, &k, &v, case.context)?;
    }

    let slots: Vec<Option<u64>> = (0..case.seqs as u64).map(Some).collect();
    let ctx_cap = case.ctx_cap();
    let g = case.seqs * h;
    let nelem = case.seqs * h * ctx_cap * dh;
    let (mut kbuf, mut vbuf) = (vec![0.0f32; nelem], vec![0.0f32; nelem]);
    let params = SamplingParams::stochastic(0.8);
    let mut rngs: Vec<Rng> =
        (0..case.seqs as u64).map(|s| seq_rng(seed, s)).collect();
    let mut hists: Vec<Vec<i32>> = vec![Vec::new(); case.seqs];
    let mut tokens: Vec<Vec<i32>> = vec![Vec::new(); case.seqs];
    let mut logprobs: Vec<Vec<f32>> = vec![Vec::new(); case.seqs];
    let mut stats = SparseStats::default();
    let mut gathered_bytes = 0u64;
    let mut dense_bytes = 0u64;
    let (mut needle_kept, mut needle_chances) = (0usize, 0usize);
    let token_bytes = cache.page_bytes() / pt;

    for _ in 0..case.steps {
        // Per-lane selection (complete selections when dense or covered).
        let mut sels: Vec<Vec<usize>> = Vec::with_capacity(case.seqs);
        let mut views: Vec<u32> = Vec::with_capacity(case.seqs);
        let mut engaged = false;
        for s in 0..case.seqs as u64 {
            let len = cache.seq_len(s).unwrap();
            dense_bytes += (len * token_bytes) as u64;
            let (sel, scores) = if sparse {
                select_for(&cache, s, &case.policy)
            } else {
                let used = cache.seq_pages(s).unwrap().len().min(len.div_ceil(pt));
                ((0..used).collect(), None)
            };
            let scored = scores.is_some();
            if let Some(scores) = scores {
                stats.record_scored_lane(&scores, &sel);
                needle_chances += 1;
                if sel.contains(&case.needle_page) {
                    needle_kept += 1;
                }
            }
            if sparse {
                // The engine's engagement predicate, verbatim: covering
                // budgets count as sparse steps there too.
                engaged |= case.policy.engages(sel.len(), scored);
            }
            views.push(selected_tokens(len, pt, &sel) as u32);
            sels.push(sel);
        }

        // Gather: the dense loop takes the flat gather, the sparse loop
        // the selected-page gather (complete selections at full budget).
        if sparse {
            let sg = cache.gather_selected(&slots, &sels)?;
            sg.compose_dense(ctx_cap, &mut kbuf, &mut vbuf)?;
            gathered_bytes += sg.shared_bytes as u64;
            if engaged {
                stats.selection_steps += 1;
                stats.gather_bytes_dense += sg.flat_bytes as u64;
                // Per-lane selected bytes (engine semantics): the ratio
                // isolates pure selection, not cascade dedup.
                stats.gather_bytes_sparse += views
                    .iter()
                    .map(|&t| t as u64 * token_bytes as u64)
                    .sum::<u64>();
            }
        } else {
            cache.gather(&slots, ctx_cap, &mut kbuf, &mut vbuf)?;
            for s in 0..case.seqs as u64 {
                gathered_bytes += (cache.seq_len(s).unwrap() * token_bytes) as u64;
            }
        }

        // Attention over the gathered views, fixed readout, sample.
        let mut q_all = vec![0.0f32; g * dh];
        for s in 0..case.seqs {
            let noise = wl.normal_vec(plane);
            let q: Vec<f32> =
                (0..plane).map(|j| u[j] + 0.1 * noise[j]).collect();
            q_all[s * plane..(s + 1) * plane].copy_from_slice(&q);
        }
        let lens_rep: Vec<u32> = (0..g).map(|gi| views[gi / h]).collect();
        let o = attention_host(&q_all, &kbuf, &vbuf, g, ctx_cap, dh, &lens_rep);

        for s in 0..case.seqs {
            let orow = &o[s * plane..(s + 1) * plane];
            let mut logits = vec![0.0f32; case.vocab];
            for (j, &oj) in orow.iter().enumerate() {
                for (w, l) in logits.iter_mut().enumerate() {
                    *l += oj * readout[j * case.vocab + w];
                }
            }
            let samp = sample_token(&logits, &hists[s], &params, &mut rngs[s]);
            hists[s].push(samp.token);
            tokens[s].push(samp.token);
            logprobs[s].push(samp.logprob);
            // Append: the key stays a query-proxy row; only V carries
            // the sampled token, so divergent streams keep comparable
            // selection behavior.
            let noise = wl.normal_vec(plane);
            let nk: Vec<f32> =
                (0..plane).map(|j| u[j] + 0.1 * noise[j]).collect();
            let vnoise = wl.normal_vec(plane);
            let nv: Vec<f32> = (0..plane)
                .map(|j| 0.2 * vnoise[j] + samp.token as f32 * 0.01)
                .collect();
            cache.append_token(s as u64, &nk, &nv)?;
        }
    }

    let mut fp = 0u64;
    for r in &mut rngs {
        fp = fp.rotate_left(7) ^ r.next_u64();
    }
    Ok((
        SparseStreamOutcome {
            tokens,
            logprobs,
            rng_fingerprint: fp,
            gathered_bytes,
            dense_bytes,
            stats,
            needle_kept,
            needle_chances,
        },
        cache,
    ))
}

/// Run the dense and sparse loops over identical workload randomness,
/// time both gather paths on the final state, and check the sparse lean
/// executor against the dense oracle restricted to the selected pages.
pub fn compare_sparse(
    case: SparseBenchCase,
    iters: usize,
    seed: u64,
) -> Result<SparseComparison> {
    ensure!(case.seqs >= 1 && case.context >= 1, "empty case");
    case.policy.validate()?;
    let pages = case.context.div_ceil(case.page_tokens);
    ensure!(
        case.needle_page >= case.policy.sink_pages
            && case.needle_page + case.policy.window_pages < pages,
        "needle page {} must be a middle page of a {pages}-page context",
        case.needle_page
    );

    let (dense, _) = run_stream(&case, false, seed)?;
    let (sparse, cache) = run_stream(&case, true, seed)?;

    // Gather timing over the sparse run's final state.
    let slots: Vec<Option<u64>> = (0..case.seqs as u64).map(Some).collect();
    let sels: Vec<Vec<usize>> = (0..case.seqs as u64)
        .map(|s| select_for(&cache, s, &case.policy).0)
        .collect();
    let ctx_cap = case.ctx_cap();
    let (h, dh, pt) = (case.heads, case.head_dim, case.page_tokens);
    let g = case.seqs * h;
    let nelem = g * ctx_cap * dh;
    let (mut kf, mut vf) = (vec![0.0f32; nelem], vec![0.0f32; nelem]);
    let dense_samples = sample_us(iters, 0.0, || {
        cache.gather(&slots, ctx_cap, &mut kf, &mut vf).expect("dense gather");
    });
    let sparse_samples = sample_us(iters, 0.0, || {
        let sg = cache.gather_selected(&slots, &sels).expect("sparse gather");
        sg.compose_dense(ctx_cap, &mut kf, &mut vf).expect("compose");
    });

    // Executor check: sparse lean vs the oracle on the same selection.
    cache.gather(&slots, ctx_cap, &mut kf, &mut vf)?;
    let lens: Vec<u32> =
        (0..case.seqs as u64).map(|s| cache.seq_len(s).unwrap() as u32).collect();
    let mut qrng = Rng::new(seed ^ 0xA5A5_5A5A);
    let q = qrng.normal_vec(g * dh);
    let (o_lean, _) = lean_sparse_host(
        &q, &kf, &vf, &lens, h, h, ctx_cap, dh, pt, &sels, case.tile, 48, 64,
    )?;
    // Independent oracle: token-index compaction + exact attention.
    let mut o_ref = vec![0.0f32; g * dh];
    for s in 0..case.seqs {
        let idx = selected_token_indices(lens[s] as usize, pt, &sels[s]);
        let n_sel = idx.len().max(1);
        let mut kc = vec![0.0f32; h * n_sel * dh];
        let mut vc = vec![0.0f32; kc.len()];
        for hi in 0..h {
            for (j, &t) in idx.iter().enumerate() {
                let src = ((s * h + hi) * ctx_cap + t) * dh;
                let dst = (hi * n_sel + j) * dh;
                kc[dst..dst + dh].copy_from_slice(&kf[src..src + dh]);
                vc[dst..dst + dh].copy_from_slice(&vf[src..src + dh]);
            }
        }
        let qs = &q[s * h * dh..(s + 1) * h * dh];
        let lens_c = vec![idx.len() as u32; h];
        let os = attention_host(qs, &kc, &vc, h, n_sel, dh, &lens_c);
        o_ref[s * h * dh..(s + 1) * h * dh].copy_from_slice(&os);
    }
    let exec_max_err = max_abs_err(&o_lean, &o_ref);

    // Work accounting over the final state: the selected-page posing is
    // exactly the compact problem the sparse executor runs, the dense
    // twin the same contexts with no selection.
    let (sp, _) = sparse_compact_problem(
        &q, &kf, &vf, &lens, h, h, ctx_cap, dh, pt, &sels, case.tile,
    )?;
    let work_sparse = account_cascade_problem(&sp);
    let work_dense = account_cascade_problem(
        &CascadeProblem::new(h, lens.clone(), dh, Vec::new())?.with_tile(case.tile),
    );
    debug_assert!(work_sparse.gathered_kv_bytes <= work_dense.gathered_kv_bytes);

    Ok(SparseComparison {
        case,
        dense,
        sparse,
        dense_us: Summary::of(&dense_samples),
        sparse_us: Summary::of(&sparse_samples),
        exec_max_err,
        work_dense,
        work_sparse,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sub_budget_sheds_bytes_and_keeps_the_needle() {
        let c = compare_sparse(SparseBenchCase::smoke(), 1, 11).expect("smoke");
        assert!(
            c.sparse.gathered_bytes < c.dense.gathered_bytes,
            "{} vs {}",
            c.sparse.gathered_bytes,
            c.dense.gathered_bytes
        );
        assert!((c.needle_recall() - 1.0).abs() < 1e-12, "{}", c.needle_recall());
        assert!(c.exec_max_err < 1e-3, "executor err {}", c.exec_max_err);
        assert!(c.sparse.stats.selection_steps > 0);
        assert!(c.sparse.stats.pages_scanned < c.sparse.stats.pages_total);
        // Selection sheds executor work too, and the telemetry report is
        // schema-valid with the u64 fingerprints folded into f64-safe
        // 32-bit counts.
        assert!(c.work_sparse.gathered_kv_bytes < c.work_dense.gathered_kv_bytes);
        assert!(c.work_sparse.softmax_flops < c.work_dense.softmax_flops);
        let rep = c.bench_report(11, true);
        crate::obs::benchlog::validate_bench_report(&rep.to_json()).unwrap();
        assert!(rep.counts["rng_fingerprint_sparse"] <= u64::from(u32::MAX));
    }

    #[test]
    fn covering_budget_is_bit_identical_to_dense() {
        let mut case = SparseBenchCase::smoke();
        case.policy.budget_pages = case.pages_cap() + 1;
        let c = compare_sparse(case, 1, 13).expect("full budget");
        assert!(c.streams_equal(), "full-budget streams must be identical");
        assert_eq!(c.sparse.gathered_bytes, c.dense.gathered_bytes);
        // Past the dense threshold the sparse path stays engaged with
        // complete selections (the engine's semantics), scoring nothing.
        assert_eq!(c.sparse.stats.selection_steps, case.steps);
        assert_eq!(c.sparse.stats.lanes_scored, 0, "nothing scored");
        assert_eq!(
            c.sparse.stats.gather_bytes_sparse,
            c.sparse.stats.gather_bytes_dense
        );
    }
}
