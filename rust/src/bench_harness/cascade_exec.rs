//! Flat-lean vs cascade **execution** comparison over identical numbers:
//! the measurement backing `leanattn bench --cascade-exec` and the
//! executor section of `benches/cascade.rs`.
//!
//! Both paths run through the same task-rolling + group-broadcast-fold
//! driver ([`crate::runtime::attention_exec`]); the only difference is the
//! problem's prefix structure. The flat path poses the batch with **no**
//! prefix groups (every lane streams its full context), the cascade path
//! poses the same contexts with the shared prefix as a first-class group —
//! so the gathered-KV-byte gap and the latency gap are attributable to
//! the cascade mechanism alone. With PJRT artifacts on disk the partials
//! execute through the `attn_partial` kernel; without them the host
//! oracle stands in (same driver, same fold).

use anyhow::Result;

use crate::obs::attrib::{account_cascade_problem, WorkAccounting};
use crate::obs::benchlog::BenchReport;
use crate::partition::cascade::{
    build_cascade_plan, CascadeProblem, CascadeTensors, PrefixGroup,
};
use crate::runtime::attention_exec::{
    lean_cascade_host, roll_cascade_tasks, rolled_kv_bytes,
};
use crate::runtime::AttentionExecutor;
use crate::util::stats::Summary;
use crate::util::testing::max_abs_err;
use crate::util::timer::sample_us;

/// Shape of one comparison case.
#[derive(Clone, Copy, Debug)]
pub struct ExecCase {
    pub batch: usize,
    /// Shared prefix tokens (every sequence in one group).
    pub prefix: u32,
    /// Private suffix tokens per sequence.
    pub suffix: u32,
    pub heads: usize,
    pub head_dim: usize,
    pub tile: usize,
    /// CTA slots handed to the stream-K planner.
    pub slots: usize,
}

/// Outcome of one flat-vs-cascade execution comparison.
#[derive(Clone, Debug)]
pub struct ExecComparison {
    pub case: ExecCase,
    /// K+V bytes the flat lean path gathers from its KV streams.
    pub flat_kv_bytes: usize,
    /// K+V bytes the cascade path gathers (shared prefix once per group).
    pub cascade_kv_bytes: usize,
    pub flat_us: Summary,
    pub cascade_us: Summary,
    /// Max abs error of the cascade output vs the flat output (both exact
    /// up to float association; this bounds the numerical agreement).
    pub max_err: f32,
    /// Whether the partials ran through the PJRT artifact (vs host math).
    pub pjrt: bool,
    /// Exact work of the flat posing (attrib-accounted).
    pub work_flat: WorkAccounting,
    /// Exact work of the cascade posing (attrib-accounted).
    pub work_cascade: WorkAccounting,
}

impl ExecComparison {
    pub fn bytes_saved_fraction(&self) -> f64 {
        if self.flat_kv_bytes == 0 {
            return 0.0;
        }
        1.0 - self.cascade_kv_bytes as f64 / self.flat_kv_bytes as f64
    }

    /// Machine-readable telemetry for `--json-out` / the baseline gate.
    /// Counts and work sections are deterministic for a given shape and
    /// seed; timings go into the ungated `info` section.
    pub fn bench_report(&self, seed: u64, smoke: bool) -> BenchReport {
        let mut r = BenchReport::new("cascade-exec", seed, smoke);
        r.count("batch", self.case.batch as u64);
        r.count("prefix_tokens", u64::from(self.case.prefix));
        r.count("suffix_tokens", u64::from(self.case.suffix));
        r.count("heads", self.case.heads as u64);
        r.count("head_dim", self.case.head_dim as u64);
        r.count("tile", self.case.tile as u64);
        r.count("flat_kv_bytes", self.flat_kv_bytes as u64);
        r.count("cascade_kv_bytes", self.cascade_kv_bytes as u64);
        r.work("flat", self.work_flat);
        r.work("cascade", self.work_cascade);
        r.measure("bytes_saved_fraction", self.bytes_saved_fraction());
        r.measure("max_err", f64::from(self.max_err));
        r.info("flat_us_p50", self.flat_us.p50);
        r.info("cascade_us_p50", self.cascade_us.p50);
        r.info("pjrt", if self.pjrt { 1.0 } else { 0.0 });
        r
    }
}

/// Derive the flat twin of a grouped problem: same contexts, same numbers,
/// no prefix structure — each sequence's suffix tensor is its composed
/// full-context KV.
fn flat_twin(
    p: &CascadeProblem,
    t: &CascadeTensors,
) -> (CascadeProblem, CascadeTensors) {
    let pf = CascadeProblem::new(p.heads, p.ctx_lens.clone(), p.head_dim, Vec::new())
        .expect("flat twin is always valid")
        .with_tile(p.tile);
    let (k_full, v_full, n_max) = t.full_kv(p);
    let (h, d) = (p.heads, p.head_dim);
    let mut k_suffix = Vec::with_capacity(p.batch());
    let mut v_suffix = Vec::with_capacity(p.batch());
    for (seq, &ctx) in p.ctx_lens.iter().enumerate() {
        let ctx = ctx as usize;
        let mut k = Vec::with_capacity(h * ctx * d);
        let mut v = Vec::with_capacity(h * ctx * d);
        for hi in 0..h {
            let base = (seq * h + hi) * n_max * d;
            k.extend_from_slice(&k_full[base..base + ctx * d]);
            v.extend_from_slice(&v_full[base..base + ctx * d]);
        }
        k_suffix.push(k);
        v_suffix.push(v);
    }
    let tf = CascadeTensors {
        q: t.q.clone(),
        k_shared: Vec::new(),
        v_shared: Vec::new(),
        k_suffix,
        v_suffix,
    };
    (pf, tf)
}

/// Run one flat-vs-cascade comparison. `exec` routes partials through the
/// PJRT artifact when present; `iters` bounds the timing samples per path.
pub fn compare_exec(
    case: ExecCase,
    iters: usize,
    exec: Option<&AttentionExecutor>,
    seed: u64,
) -> Result<ExecComparison> {
    let members: Vec<u32> = (0..case.batch as u32).collect();
    let p = CascadeProblem::new(
        case.heads,
        vec![case.prefix + case.suffix; case.batch],
        case.head_dim,
        vec![PrefixGroup { prefix_len: case.prefix, members }],
    )?
    .with_tile(case.tile);
    let t = CascadeTensors::random(&p, seed);
    let (pf, tf) = flat_twin(&p, &t);

    let cp = build_cascade_plan(&p, case.slots);
    cp.plan.validate(&cp.segment_problem)?;
    let cpf = build_cascade_plan(&pf, case.slots);
    cpf.plan.validate(&cpf.segment_problem)?;

    let cascade_kv_bytes = rolled_kv_bytes(&roll_cascade_tasks(&p, &cp), case.head_dim);
    let flat_kv_bytes = rolled_kv_bytes(&roll_cascade_tasks(&pf, &cpf), case.head_dim);

    // The emulated partial-batch capacity for the host path (the PJRT
    // path takes its capacity from the artifact manifest).
    let batch_rows = 64;
    let run_cascade = || -> Result<Vec<f32>> {
        Ok(match exec {
            Some(e) => e.lean_cascade(&p, &t, &cp)?.0,
            None => lean_cascade_host(&p, &t, &cp, batch_rows).0,
        })
    };
    let run_flat = || -> Result<Vec<f32>> {
        Ok(match exec {
            Some(e) => e.lean_cascade(&pf, &tf, &cpf)?.0,
            None => lean_cascade_host(&pf, &tf, &cpf, batch_rows).0,
        })
    };

    let o_cascade = run_cascade()?;
    let o_flat = run_flat()?;
    let max_err = max_abs_err(&o_cascade, &o_flat);

    let flat_samples = sample_us(iters, 0.0, || {
        let _ = std::hint::black_box(run_flat());
    });
    let cascade_samples = sample_us(iters, 0.0, || {
        let _ = std::hint::black_box(run_cascade());
    });

    let work_cascade = account_cascade_problem(&p);
    let work_flat = account_cascade_problem(&pf);
    debug_assert_eq!(work_cascade.gathered_kv_bytes, cascade_kv_bytes as u64);
    debug_assert_eq!(work_flat.gathered_kv_bytes, flat_kv_bytes as u64);

    Ok(ExecComparison {
        case,
        flat_kv_bytes,
        cascade_kv_bytes,
        flat_us: Summary::of(&flat_samples),
        cascade_us: Summary::of(&cascade_samples),
        max_err,
        pjrt: exec.is_some(),
        work_flat,
        work_cascade,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_comparison_agrees_and_dedups() {
        let case = ExecCase {
            batch: 3,
            prefix: 64,
            suffix: 32,
            heads: 2,
            head_dim: 16,
            tile: 32,
            slots: 12,
        };
        let c = compare_exec(case, 2, None, 7).expect("host comparison");
        assert!(c.max_err < 1e-4, "paths disagree: {}", c.max_err);
        assert!(
            c.cascade_kv_bytes < c.flat_kv_bytes,
            "cascade gathered {} vs flat {}",
            c.cascade_kv_bytes,
            c.flat_kv_bytes
        );
        // 3 lanes × (64+32) tokens flat vs 64 + 3×32 cascade, × heads.
        let token = 2 * case.head_dim * 4;
        assert_eq!(c.flat_kv_bytes, 3 * 96 * 2 * token);
        assert_eq!(c.cascade_kv_bytes, (64 + 3 * 32) * 2 * token);
        assert!(!c.pjrt);
        assert!((c.bytes_saved_fraction() - (1.0 - 160.0 / 288.0)).abs() < 1e-12);
        // Work accounting agrees with the rolled byte counters, and the
        // telemetry report is schema-valid.
        assert_eq!(c.work_flat.gathered_kv_bytes, c.flat_kv_bytes as u64);
        assert_eq!(c.work_cascade.gathered_kv_bytes, c.cascade_kv_bytes as u64);
        let rep = c.bench_report(7, true);
        crate::obs::benchlog::validate_bench_report(&rep.to_json()).unwrap();
        assert_eq!(rep.name, "cascade-exec");
    }
}
