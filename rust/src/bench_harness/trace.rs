//! Trace-driven load generation for the serving engine: Poisson (or
//! fixed-interval) arrivals with sampled prompt/generation lengths,
//! replayed open-loop against the engine's step clock. Reports the
//! serving metrics a deployment cares about (TTFT, end-to-end latency
//! percentiles, throughput) — the engine-level complement of the paper's
//! operation-level benchmarks.

use anyhow::Result;

use crate::coordinator::Engine;
use crate::util::rng::Rng;
use crate::util::stats::Summary;

/// One synthetic request in a trace.
#[derive(Clone, Debug)]
pub struct TraceEntry {
    /// Arrival time in engine steps (iteration-level clock).
    pub arrival_step: usize,
    pub prompt_len: usize,
    pub max_new: usize,
}

/// Workload trace description.
#[derive(Clone, Debug)]
pub struct TraceSpec {
    pub requests: usize,
    /// Mean inter-arrival gap in engine steps (Poisson when `poisson`).
    pub mean_gap_steps: f64,
    pub poisson: bool,
    pub prompt_min: usize,
    pub prompt_max: usize,
    pub new_min: usize,
    pub new_max: usize,
    pub seed: u64,
}

impl TraceSpec {
    /// Materialize the trace deterministically.
    pub fn generate(&self) -> Vec<TraceEntry> {
        let mut rng = Rng::new(self.seed);
        let mut t = 0.0f64;
        (0..self.requests)
            .map(|_| {
                let gap = if self.poisson {
                    // exponential inter-arrival via inverse CDF
                    -self.mean_gap_steps * (1.0 - rng.f64()).ln()
                } else {
                    self.mean_gap_steps
                };
                t += gap;
                TraceEntry {
                    arrival_step: t as usize,
                    prompt_len: rng.urange(self.prompt_min, self.prompt_max + 1),
                    max_new: rng.urange(self.new_min, self.new_max + 1),
                }
            })
            .collect()
    }
}

/// Result of replaying a trace.
#[derive(Clone, Debug)]
pub struct TraceReport {
    pub requests: usize,
    pub steps: usize,
    pub wall_s: f64,
    pub tokens: usize,
    /// Time-to-first-token per request (seconds, includes queue).
    pub ttft_s: Summary,
    /// End-to-end latency per request (seconds).
    pub e2e_s: Summary,
    pub tokens_per_s: f64,
}

impl TraceReport {
    pub fn render(&self) -> String {
        format!(
            "trace: {} requests in {} steps / {:.2}s wall, {} tokens ({:.1} tok/s)\n\
             TTFT  s: mean {:.3} p50 {:.3} p99 {:.3}\n\
             e2e   s: mean {:.3} p50 {:.3} p99 {:.3}",
            self.requests,
            self.steps,
            self.wall_s,
            self.tokens,
            self.tokens_per_s,
            self.ttft_s.mean,
            self.ttft_s.p50,
            self.ttft_s.p99,
            self.e2e_s.mean,
            self.e2e_s.p50,
            self.e2e_s.p99
        )
    }
}

/// Replay a trace against an engine: submissions are released when the
/// engine's step counter reaches each arrival step (open-loop on the
/// iteration clock), and the engine is stepped until drained.
pub fn replay(engine: &mut Engine, spec: &TraceSpec) -> Result<TraceReport> {
    let mut trace = spec.generate();
    // clamp to the engine's buckets
    let pmax = engine.prefill_bucket();
    for e in &mut trace {
        e.prompt_len = e.prompt_len.clamp(1, pmax);
        e.max_new = e.max_new.max(1);
    }

    let mut rng = Rng::new(spec.seed ^ 0xABCD);
    let t0 = std::time::Instant::now();
    let mut finished = Vec::new();
    let mut next = 0usize;
    let mut step = 0usize;
    while next < trace.len() || !engine.is_idle() {
        while next < trace.len() && trace[next].arrival_step <= step {
            let e = &trace[next];
            let prompt: Vec<i32> =
                (0..e.prompt_len).map(|_| rng.range(0, 512) as i32).collect();
            engine.submit(prompt, e.max_new)?;
            next += 1;
        }
        finished.extend(engine.step()?);
        step += 1;
        if step > 1_000_000 {
            anyhow::bail!("trace replay did not drain");
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();

    let ttft: Vec<f64> = finished.iter().map(|f| f.queue_s + f.prefill_s).collect();
    let e2e: Vec<f64> = finished.iter().map(|f| f.total_s()).collect();
    let tokens: usize = finished.iter().map(|f| f.output.len()).sum();
    Ok(TraceReport {
        requests: finished.len(),
        steps: step,
        wall_s,
        tokens,
        ttft_s: Summary::of(&ttft),
        e2e_s: Summary::of(&e2e),
        tokens_per_s: tokens as f64 / wall_s,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_generation_deterministic_and_monotonic() {
        let spec = TraceSpec {
            requests: 50,
            mean_gap_steps: 2.0,
            poisson: true,
            prompt_min: 1,
            prompt_max: 64,
            new_min: 1,
            new_max: 16,
            seed: 9,
        };
        let a = spec.generate();
        let b = spec.generate();
        assert_eq!(a.len(), 50);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_step, y.arrival_step);
            assert_eq!(x.prompt_len, y.prompt_len);
        }
        assert!(a.windows(2).all(|w| w[0].arrival_step <= w[1].arrival_step));
    }

    #[test]
    fn fixed_gap_arrivals_evenly_spaced() {
        let spec = TraceSpec {
            requests: 5,
            mean_gap_steps: 3.0,
            poisson: false,
            prompt_min: 4,
            prompt_max: 4,
            new_min: 2,
            new_max: 2,
            seed: 0,
        };
        let t = spec.generate();
        let arrivals: Vec<usize> = t.iter().map(|e| e.arrival_step).collect();
        assert_eq!(arrivals, vec![3, 6, 9, 12, 15]);
    }

    #[test]
    fn poisson_mean_gap_approximate() {
        let spec = TraceSpec {
            requests: 2000,
            mean_gap_steps: 5.0,
            poisson: true,
            prompt_min: 1,
            prompt_max: 2,
            new_min: 1,
            new_max: 2,
            seed: 17,
        };
        let t = spec.generate();
        let mean = t.last().unwrap().arrival_step as f64 / t.len() as f64;
        assert!((mean - 5.0).abs() < 0.5, "mean gap {mean}");
    }
}
