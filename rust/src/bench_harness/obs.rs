//! Observability bench: the measurement behind `leanattn bench --obs`.
//!
//! Artifact-free pseudo-serving over the host executors, with the
//! structured tracer enabled end to end:
//!
//! 1. **Traced run** — each synthetic "request" admits (`admit`
//!    instant), runs one traced cascade pass as its prefill-shaped
//!    phase (`gather` + `lean_exec` spans inside
//!    [`lean_cascade_host_traced`]) and one speculative draft-and-verify
//!    stream as its decode phase (`spec_draft`/`spec_verify`/
//!    `spec_commit`/`rollback` via [`spec_generate_traced`]), feeding a
//!    [`TimelineRecorder`] with the measured lifecycle.
//! 2. **Schema** — the Chrome trace-event export is validated against
//!    the span taxonomy and required to contain non-trivial `gather`,
//!    `lean_exec` and `spec_verify` spans.
//! 3. **Overhead bound** — the cascade body is sampled through its
//!    untraced entry point and through the traced entry point with a
//!    **disabled** tracer; the min-of-samples gap is asserted under
//!    [`ObsCase::overhead_limit`] (near-no-op call sites). The enabled
//!    tracer's cost is measured too, reported but not asserted.

use anyhow::{ensure, Result};

use crate::obs::attrib::{account_cascade_problem, WorkAccounting};
use crate::obs::benchlog::BenchReport;
use crate::obs::{
    validate_chrome_trace, Attrs, Phase, RequestTimeline, SloReport,
    TimelineRecorder, Tracer,
};
use crate::partition::cascade::{
    build_cascade_plan, CascadePlan, CascadeProblem, CascadeTensors, PrefixGroup,
};
use crate::runtime::attention_exec::{lean_cascade_host, lean_cascade_host_traced};
use crate::sampling::{seq_rng, SamplingParams};
use crate::spec::{sequential_generate, spec_generate_traced, DraftKind, SyntheticModel};
use crate::util::json::Json;
use crate::util::timer::{sample_us, time_us};

/// Shape of one observability bench run.
#[derive(Clone, Copy, Debug)]
pub struct ObsCase {
    /// Synthetic requests to serve through the traced loop.
    pub requests: usize,
    /// Cascade-body shape (one shared-prefix group over `batch` lanes).
    pub batch: usize,
    pub prefix: u32,
    pub suffix: u32,
    pub heads: usize,
    pub head_dim: usize,
    pub tile: usize,
    pub slots: usize,
    /// Draft length of the per-request speculative stream.
    pub spec_k: usize,
    /// Tokens each request's decode phase commits.
    pub max_new: usize,
    pub vocab: usize,
    /// Tracer ring capacity (small enough rings overflow by design —
    /// the report carries the drop counter).
    pub trace_capacity: usize,
    /// End-to-end latency target of the SLO report, milliseconds.
    pub slo_ms: f64,
    /// Timing samples per path in the overhead measurement.
    pub overhead_iters: usize,
    /// Asserted bound on the disabled tracer's min-of-samples overhead.
    pub overhead_limit: f64,
    /// Asserted bound on the page-heat tracker's min-of-samples
    /// overhead (the twin-cache gather measurement).
    pub heat_overhead_limit: f64,
}

impl ObsCase {
    /// The `leanattn bench --obs` default shape.
    pub fn default_case() -> ObsCase {
        ObsCase {
            requests: 24,
            batch: 3,
            prefix: 64,
            suffix: 32,
            heads: 2,
            head_dim: 16,
            tile: 32,
            slots: 12,
            spec_k: 4,
            max_new: 48,
            vocab: 64,
            trace_capacity: 8192,
            slo_ms: 50.0,
            overhead_iters: 40,
            overhead_limit: 0.02,
            heat_overhead_limit: 0.02,
        }
    }

    /// CI smoke shape: small and fast, same assertions.
    pub fn smoke() -> ObsCase {
        ObsCase {
            requests: 8,
            max_new: 24,
            overhead_iters: 20,
            ..ObsCase::default_case()
        }
    }
}

/// Outcome of one observability bench run.
pub struct ObsReport {
    pub case: ObsCase,
    /// Trace events resident in the ring at export time.
    pub events: usize,
    /// Events dropped to ring overflow.
    pub dropped: u64,
    /// Per-phase p50/p95/p99/p999 table.
    pub phase_report: String,
    /// The serving SLO report over the measured request lifecycles.
    pub slo: SloReport,
    /// The validated Chrome trace-event export.
    pub chrome: Json,
    /// Min-of-samples overhead of the instrumented-but-disabled path vs
    /// the untraced entry point (asserted `< overhead_limit`).
    pub overhead_disabled: f64,
    /// Min-of-samples overhead of the *enabled* tracer on the same body
    /// (reported, not asserted — enabled tracing is opt-in).
    pub overhead_enabled: f64,
    /// Min-of-samples overhead of the page-heat tracker on a flat
    /// gather: twin caches with identical contents, one tracking heat
    /// and one with the tracker disabled (asserted
    /// `< heat_overhead_limit` — the heat plane is always on in the
    /// engine, so it must be gather-cheap).
    pub overhead_heat: f64,
    /// Exact work of one cascade-body pass (attrib-accounted — the same
    /// numbers the traced spans carry as `bytes`/`flops` attributes).
    pub work_body: WorkAccounting,
}

impl ObsReport {
    pub fn render(&self) -> String {
        let mut s = format!(
            "observability bench: {} requests traced, {} events in ring \
             ({} dropped to overflow)\n\
             tracer overhead (min-of-samples on the cascade body): \
             disabled {:.2}% (bound {:.0}%), enabled {:.2}%\n\
             heat-tracker overhead (twin-cache flat gather): {:.2}% \
             (bound {:.0}%)\n\
             phase timings:\n{}",
            self.case.requests,
            self.events,
            self.dropped,
            self.overhead_disabled * 100.0,
            self.case.overhead_limit * 100.0,
            self.overhead_enabled * 100.0,
            self.overhead_heat * 100.0,
            self.case.heat_overhead_limit * 100.0,
            self.phase_report,
        );
        s.push_str(&self.slo.render());
        s
    }

    /// Machine-readable telemetry for `--json-out` / the baseline gate.
    /// Event and drop counts are deterministic for a given shape and
    /// seed (the span stream is a pure function of the workload);
    /// overheads and SLO timings are machine-dependent `info`.
    pub fn bench_report(&self, seed: u64, smoke: bool) -> BenchReport {
        let mut r = BenchReport::new("obs", seed, smoke);
        r.count("requests", self.case.requests as u64);
        r.count("batch", self.case.batch as u64);
        r.count("prefix_tokens", u64::from(self.case.prefix));
        r.count("suffix_tokens", u64::from(self.case.suffix));
        r.count("trace_capacity", self.case.trace_capacity as u64);
        r.count("events", self.events as u64);
        r.count("dropped", self.dropped);
        r.work("cascade_body", self.work_body);
        r.work(
            "traced_loop",
            (0..self.case.requests)
                .fold(WorkAccounting::default(), |acc, _| acc + self.work_body),
        );
        r.info("overhead_disabled", self.overhead_disabled);
        r.info("overhead_enabled", self.overhead_enabled);
        r.info("overhead_heat", self.overhead_heat);
        r.info("slo_attainment", self.slo.attainment);
        r.info("tokens_per_s", self.slo.tokens_per_s);
        r
    }
}

/// The cascade body every phase of the bench runs: one shared-prefix
/// group over `batch` lanes, planned once.
fn cascade_body(case: &ObsCase, seed: u64) -> Result<(CascadeProblem, CascadeTensors, CascadePlan)> {
    let members: Vec<u32> = (0..case.batch as u32).collect();
    let p = CascadeProblem::new(
        case.heads,
        vec![case.prefix + case.suffix; case.batch],
        case.head_dim,
        vec![PrefixGroup { prefix_len: case.prefix, members }],
    )?
    .with_tile(case.tile);
    let t = CascadeTensors::random(&p, seed);
    let cp = build_cascade_plan(&p, case.slots);
    cp.plan.validate(&cp.segment_problem)?;
    Ok((p, t, cp))
}

/// Page-heat tracker overhead: the same flat gather sampled over twin
/// caches with identical contents — one tracking heat (the engine
/// default), one with the tracker disabled. Min-of-samples on each side
/// isolates the per-page `Cell` bumps from scheduler noise.
fn heat_overhead(case: &ObsCase, seed: u64) -> Result<f64> {
    use crate::coordinator::PagedKvCache;
    use crate::util::rng::Rng;

    let (layers, page_tokens, pages, lanes) = (2usize, 8usize, 64usize, 4u64);
    let len = (case.prefix + case.suffix) as usize;
    let mut hot = PagedKvCache::new(layers, case.heads, case.head_dim, page_tokens, pages);
    let mut cold = PagedKvCache::new(layers, case.heads, case.head_dim, page_tokens, pages);
    cold.disable_heat();
    let plane = layers * case.heads * case.head_dim;
    let mut rng = Rng::new(seed);
    for id in 1..=lanes {
        let k: Vec<f32> =
            (0..plane * len).map(|_| rng.range(0, 2048) as f32 / 1024.0 - 1.0).collect();
        let v: Vec<f32> =
            (0..plane * len).map(|_| rng.range(0, 2048) as f32 / 1024.0 - 1.0).collect();
        hot.insert_seq(id, &k, &v, len)?;
        cold.insert_seq(id, &k, &v, len)?;
    }
    let slots: Vec<Option<u64>> = (1..=lanes).map(Some).collect();
    let ctx = pages * page_tokens;
    let n = layers * slots.len() * case.heads * ctx * case.head_dim;
    let (mut kb, mut vb) = (vec![0.0f32; n], vec![0.0f32; n]);
    let on = sample_us(case.overhead_iters, 0.0, || {
        hot.gather(&slots, ctx, &mut kb, &mut vb).expect("hot gather");
        std::hint::black_box(&kb);
    });
    let off = sample_us(case.overhead_iters, 0.0, || {
        cold.gather(&slots, ctx, &mut kb, &mut vb).expect("cold gather");
        std::hint::black_box(&kb);
    });
    let min_of = |s: &[f64]| s.iter().copied().fold(f64::INFINITY, f64::min);
    let (mon, moff) = (min_of(&on), min_of(&off));
    Ok(((mon - moff) / moff).max(0.0))
}

/// Run the observability bench. The speculative stream is asserted
/// bit-identical to its sequential oracle before anything is reported —
/// tracing must observe the run, never perturb it.
pub fn run_obs(case: ObsCase, seed: u64) -> Result<ObsReport> {
    ensure!(case.requests >= 1, "need at least one request");
    ensure!(case.spec_k >= 1 && case.max_new >= 1, "spec stream shape");
    let (p, t, cplan) = cascade_body(&case, seed)?;
    let batch_rows = 64;

    // --- 1. the traced pseudo-serving loop ----------------------------
    let tracer = Tracer::enabled(case.trace_capacity);
    let mut timelines = TimelineRecorder::default();
    let target = SyntheticModel::new(case.vocab, seed, 6.0);
    let params = SamplingParams::greedy();
    let wall0 = std::time::Instant::now();
    for r in 0..case.requests {
        tracer.advance_step();
        tracer.instant(
            Phase::Admit,
            Attrs { seq: Some(r as u64), ..Default::default() },
        );
        // Prefill-shaped phase: one cascade pass (gather + lean_exec
        // spans recorded inside the executor).
        let (_, prefill_us) = time_us(|| {
            std::hint::black_box(lean_cascade_host_traced(
                &p, &t, &cplan, batch_rows, &tracer,
            ))
        });
        // Decode-shaped phase: a speculative draft-and-verify stream
        // (spec_draft / spec_verify / spec_commit / rollback spans).
        let prompt: Vec<i32> = (0..16).map(|i| ((i + r) % 8) as i32).collect();
        let mut drafter = DraftKind::NGram.build(case.vocab, seed);
        let mut rng = seq_rng(seed, r as u64 + 1);
        let (run, decode_us) = time_us(|| {
            spec_generate_traced(
                &target,
                drafter.as_mut(),
                case.spec_k,
                &prompt,
                case.max_new,
                &params,
                &mut rng,
                &tracer,
            )
        });
        // Tracing observes; it must not perturb the stream.
        let mut oracle_rng = seq_rng(seed, r as u64 + 1);
        let oracle =
            sequential_generate(&target, &prompt, case.max_new, &params, &mut oracle_rng);
        ensure!(run.tokens == oracle, "traced stream diverged from the oracle");
        timelines.observe(RequestTimeline {
            id: r as u64,
            queue_us: 0.0,
            prefill_us,
            decode_us,
            tokens: run.tokens.len(),
        });
    }
    let wall_s = wall0.elapsed().as_secs_f64();

    // --- 2. export + schema validation --------------------------------
    let chrome = tracer.export_chrome_trace();
    validate_chrome_trace(&chrome)?;
    for phase in [Phase::Gather, Phase::LeanExec, Phase::SpecVerify, Phase::SpecDraft] {
        let h = tracer.phase_hist(phase);
        let ok = h.as_ref().is_some_and(|h| h.count() > 0 && h.max() > 0.0);
        ensure!(ok, "phase {} has no non-trivial spans", phase.as_str());
    }

    // --- 3. overhead: untraced entry vs disabled tracer vs enabled ----
    let off = Tracer::disabled();
    let plain = sample_us(case.overhead_iters, 0.0, || {
        std::hint::black_box(lean_cascade_host(&p, &t, &cplan, batch_rows));
    });
    let disabled = sample_us(case.overhead_iters, 0.0, || {
        std::hint::black_box(lean_cascade_host_traced(&p, &t, &cplan, batch_rows, &off));
    });
    let enabled = sample_us(case.overhead_iters, 0.0, || {
        std::hint::black_box(lean_cascade_host_traced(&p, &t, &cplan, batch_rows, &tracer));
    });
    let min_of = |s: &[f64]| s.iter().copied().fold(f64::INFINITY, f64::min);
    let (mp, md, me) = (min_of(&plain), min_of(&disabled), min_of(&enabled));
    let overhead_disabled = ((md - mp) / mp).max(0.0);
    let overhead_enabled = ((me - mp) / mp).max(0.0);
    ensure!(
        overhead_disabled < case.overhead_limit,
        "disabled-tracer overhead {:.2}% exceeds the {:.0}% bound",
        overhead_disabled * 100.0,
        case.overhead_limit * 100.0
    );

    // --- 4. page-heat tracker overhead on the flat gather -------------
    let overhead_heat = heat_overhead(&case, seed)?;
    ensure!(
        overhead_heat < case.heat_overhead_limit,
        "heat-tracker overhead {:.2}% exceeds the {:.0}% bound",
        overhead_heat * 100.0,
        case.heat_overhead_limit * 100.0
    );

    Ok(ObsReport {
        case,
        events: tracer.len(),
        dropped: tracer.dropped(),
        phase_report: tracer.phase_report(),
        slo: timelines.slo_report(case.slo_ms, wall_s),
        chrome,
        overhead_disabled,
        overhead_enabled,
        overhead_heat,
        work_body: account_cascade_problem(&p),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loose(case: ObsCase) -> ObsCase {
        // Debug builds + shared CI machines: keep the structural
        // assertions, drop the timing bounds out of flake range.
        ObsCase {
            overhead_limit: 10.0,
            heat_overhead_limit: 10.0,
            overhead_iters: 3,
            ..case
        }
    }

    #[test]
    fn smoke_case_traces_every_required_phase() {
        let r = run_obs(loose(ObsCase::smoke()), 7).expect("obs bench");
        assert!(r.events > 0);
        assert!(r.phase_report.contains("lean_exec"), "{}", r.phase_report);
        assert!(r.phase_report.contains("gather"));
        assert!(r.phase_report.contains("spec_verify"));
        assert_eq!(r.slo.requests, r.case.requests as u64);
        assert!(r.slo.tokens_per_s > 0.0);
        let out = r.render();
        assert!(out.contains("observability bench"), "{out}");
        assert!(out.contains("serving SLO report"), "{out}");
    }

    #[test]
    fn chrome_export_round_trips_through_the_json_parser() {
        let r = run_obs(loose(ObsCase::smoke()), 3).expect("obs bench");
        let text = r.chrome.to_string();
        let parsed = Json::parse(&text).expect("export parses back");
        validate_chrome_trace(&parsed).expect("parsed export still validates");
        assert_eq!(parsed.as_arr().unwrap().len(), r.events);
    }

    #[test]
    fn tiny_ring_overflows_and_counts_drops() {
        let case = ObsCase { trace_capacity: 16, ..ObsCase::smoke() };
        let r = run_obs(loose(case), 5).expect("obs bench");
        assert_eq!(r.events, 16, "ring holds exactly its capacity");
        assert!(r.dropped > 0, "overflow must be counted");
    }

    #[test]
    fn same_seed_runs_emit_identical_work_accounting_sections() {
        // The baseline gate compares counts and work bit-exactly, so two
        // runs over the same seed must agree on every gated section —
        // the span stream (events, drops) included.
        let a = run_obs(loose(ObsCase::smoke()), 21).expect("first run");
        let b = run_obs(loose(ObsCase::smoke()), 21).expect("second run");
        let (ra, rb) = (a.bench_report(21, true), b.bench_report(21, true));
        assert_eq!(ra.counts, rb.counts);
        assert_eq!(ra.work, rb.work);
        crate::obs::benchlog::validate_bench_report(&ra.to_json()).unwrap();
        assert_eq!(
            ra.work["traced_loop"].softmax_flops,
            ra.work["cascade_body"].softmax_flops * a.case.requests as u64
        );
    }
}
