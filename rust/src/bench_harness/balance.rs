//! Partition-balance bench: the measurement behind `leanattn bench
//! --balance`.
//!
//! Artifact-free, in three movements:
//!
//! 1. **Ragged-batch balance report** — the cross-strategy
//!    [`PartitionReport`] over a Fig-10-style ragged batch,
//!    self-validated against its schema. Asserted on every run:
//!    stream-K's load-imbalance factor is **strictly below** the
//!    fixed-split (FlashDecoding) baseline's.
//! 2. **Traced execution + per-tile join** — a smaller plan actually
//!    runs on the host ([`execute_plan_traced`]), its rescale-fold
//!    output is asserted exact against the direct-softmax [`oracle`],
//!    and every CTA's measured `gather`/`lean_exec` span joins its
//!    ledger row by the [`Attrs::tile`](crate::obs::Attrs) index.
//! 3. **Stationary drift stream** — the same executed plan feeds a
//!    [`DriftDetector`] one `(exact work, measured µs)` pair per
//!    iteration. On a stationary workload the detector must stay
//!    quiet: zero breaches, relative-error EWMA within the limit.

use anyhow::{ensure, Result};

use crate::obs::attrib::account_decode_problem;
use crate::obs::balance::{
    execute_plan_traced, join_measured_events, oracle, partition_report,
    validate_partition_report, BalanceTensors, PartitionReport, StrategyBalance,
};
use crate::obs::benchlog::BenchReport;
use crate::obs::{DriftDetector, Tracer};
use crate::partition::plan::{build_plan, DecodeProblem, Strategy};
use crate::sim::{CostCoefficients, GpuArch};

/// Shape of one partition-balance bench run.
#[derive(Clone, Debug)]
pub struct BalanceCase {
    /// Ragged per-lane context lengths of the report problem (the
    /// Fig-10 x-axis is how ragged this batch is).
    pub ctx_lens: Vec<u32>,
    pub heads: usize,
    pub head_dim: usize,
    /// Traced-execution shape: small enough to actually run on the
    /// host, ragged enough that stream-K has something to level.
    pub exec_ctx_lens: Vec<u32>,
    pub exec_heads: usize,
    pub exec_head_dim: usize,
    /// LeanTile size of the executed problem (small, so the plan has
    /// many tiles to split).
    pub exec_tile: usize,
    /// CTA slots the executed stream-K plan targets.
    pub exec_slots: usize,
    /// Drift-stream iterations (must exceed the detector warmup so at
    /// least some samples are judged).
    pub drift_iters: usize,
    /// Drift EWMA limit. Generous: a stationary run on a noisy shared
    /// CI machine must never breach.
    pub drift_limit: f64,
}

impl BalanceCase {
    /// The `leanattn bench --balance` default shape.
    pub fn default_case() -> BalanceCase {
        BalanceCase {
            ctx_lens: vec![511, 64, 1290, 32, 777, 96, 2048, 130],
            heads: 4,
            head_dim: 32,
            exec_ctx_lens: vec![100, 37, 260, 64],
            exec_heads: 2,
            exec_head_dim: 16,
            exec_tile: 32,
            exec_slots: 8,
            drift_iters: 48,
            drift_limit: 0.75,
        }
    }

    /// CI smoke shape: a shorter drift stream, same assertions.
    pub fn smoke() -> BalanceCase {
        BalanceCase { drift_iters: 24, ..BalanceCase::default_case() }
    }

    fn report_problem(&self) -> DecodeProblem {
        DecodeProblem::ragged(self.heads, self.ctx_lens.clone(), self.head_dim)
    }

    fn exec_problem(&self) -> DecodeProblem {
        DecodeProblem::ragged(self.exec_heads, self.exec_ctx_lens.clone(), self.exec_head_dim)
            .with_tile(self.exec_tile)
    }
}

/// Outcome of one partition-balance bench run.
pub struct BalanceComparison {
    pub case: BalanceCase,
    /// Cross-strategy report over the ragged batch (schema-validated).
    pub report: PartitionReport,
    /// Stream-K balance of the *executed* plan, with every ledger row
    /// joined to its measured span time.
    pub exec_balance: StrategyBalance,
    /// Max |fold − oracle| over the executed plan's outputs.
    pub exec_max_err: f32,
    /// Ledger rows that joined a measured span (== the exec grid).
    pub measured_rows: usize,
    /// Drift detector state after the stationary stream.
    pub drift_observations: u64,
    pub drift_breaches: u64,
    pub drift_rel_err: f64,
    pub drift_gain: f64,
}

impl BalanceComparison {
    /// The stream-K and fixed-split rows of the ragged report.
    fn anchor_rows(&self) -> (&StrategyBalance, &StrategyBalance) {
        let lean = self.report.stream_k().expect("report always has a stream-K row");
        let fd = self
            .report
            .strategies
            .iter()
            .find(|s| s.strategy == "flashdecoding")
            .expect("report always has a fixed-split row");
        (lean, fd)
    }

    pub fn render(&self) -> String {
        let (lean, fd) = self.anchor_rows();
        format!(
            "{}\
             ragged batch: stream-K imbalance {:.3} vs fixed-split {:.3} \
             ({:.2}x more level)\n\
             traced execution: {} CTAs, fold-vs-oracle max err {:.2e}, \
             {}/{} ledger rows joined a measured span\n\
             drift (stationary, {} observations): {} breaches, rel err \
             EWMA {:.3} (limit {:.2}), gain {:.2}\n",
            self.report.render(),
            lean.imbalance,
            fd.imbalance,
            fd.imbalance / lean.imbalance,
            self.exec_balance.grid,
            self.exec_max_err,
            self.measured_rows,
            self.exec_balance.grid,
            self.drift_observations,
            self.drift_breaches,
            self.drift_rel_err,
            self.case.drift_limit,
            self.drift_gain,
        )
    }

    /// Machine-readable telemetry for `--json-out` / the baseline gate.
    /// Shape echoes, grid sizes and work totals are deterministic
    /// (pure functions of the case); simulated balance factors are
    /// machine-independent `measures`; wall-clock-derived drift numbers
    /// are ungated `info`.
    pub fn bench_report(&self, seed: u64, smoke: bool) -> BenchReport {
        let (lean, fd) = self.anchor_rows();
        let mut r = BenchReport::new("balance", seed, smoke);
        r.count("lanes", self.case.ctx_lens.len() as u64);
        r.count("heads", self.case.heads as u64);
        r.count("head_dim", self.case.head_dim as u64);
        r.count("exec_lanes", self.case.exec_ctx_lens.len() as u64);
        r.count("exec_heads", self.case.exec_heads as u64);
        r.count("exec_head_dim", self.case.exec_head_dim as u64);
        r.count("exec_tile", self.case.exec_tile as u64);
        r.count("exec_slots", self.case.exec_slots as u64);
        r.count("exec_grid", self.exec_balance.grid as u64);
        r.count("measured_rows", self.measured_rows as u64);
        r.count("drift_iters", self.case.drift_iters as u64);
        r.count("drift_observations", self.drift_observations);
        r.count("drift_breaches", self.drift_breaches);
        r.count("report_grid_streamk", lean.grid as u64);
        r.count("report_grid_fixed_split", fd.grid as u64);
        r.work("report_streamk_total", lean.total);
        r.work("exec_total", self.exec_balance.total);
        r.measure("imbalance_streamk", lean.imbalance);
        r.measure("imbalance_fixed_split", fd.imbalance);
        r.measure("wave_efficiency_streamk", lean.wave_efficiency);
        r.measure("batch_context_ratio", self.report.batch_context_ratio);
        r.info("exec_max_err", self.exec_max_err as f64);
        r.info("drift_rel_err", self.drift_rel_err);
        r.info("drift_gain", self.drift_gain);
        r.info("exec_makespan_us", self.exec_balance.makespan_us);
        r
    }
}

/// Run the partition-balance bench. Every run asserts: the report
/// validates against its schema, stream-K's imbalance is strictly below
/// fixed-split's on the ragged batch, the executed plan's fold is exact
/// against the oracle, every ledger row joins a measured span, and the
/// stationary drift stream stays quiet.
pub fn run_balance(case: BalanceCase, seed: u64) -> Result<BalanceComparison> {
    ensure!(!case.ctx_lens.is_empty(), "need at least one report lane");
    ensure!(!case.exec_ctx_lens.is_empty(), "need at least one exec lane");
    ensure!(
        case.drift_iters > DriftDetector::WARMUP,
        "--drift-iters {} must exceed the detector warmup ({})",
        case.drift_iters,
        DriftDetector::WARMUP
    );
    let arch = GpuArch::a100();

    // --- 1. the ragged-batch cross-strategy report --------------------
    let p = case.report_problem();
    let report = partition_report(&p, &arch);
    validate_partition_report(&report.to_json())
        .map_err(|e| e.context("partition report failed self-validation"))?;
    let lean = report.stream_k().expect("stream-K row");
    let fd = report
        .strategies
        .iter()
        .find(|s| s.strategy == "flashdecoding")
        .expect("fixed-split row");
    ensure!(
        lean.imbalance < fd.imbalance,
        "stream-K imbalance {:.3} is not strictly below fixed-split {:.3} \
         on the ragged batch",
        lean.imbalance,
        fd.imbalance
    );
    ensure!(lean.imbalance >= 1.0 - 1e-9, "imbalance factor below 1");

    // --- 2. traced host execution + per-tile join ---------------------
    let ep = case.exec_problem();
    let plan = build_plan(&ep, Strategy::StreamK, case.exec_slots);
    let t = BalanceTensors::random(&ep, seed);
    let tracer = Tracer::enabled((4 * plan.grid()).max(256));
    let m = execute_plan_traced(&ep, &plan, &t, &tracer);
    let want = oracle(&ep, &t);
    let mut exec_max_err = 0.0f32;
    for (got, want) in m.outputs.iter().zip(&want) {
        for (a, b) in got.iter().zip(want) {
            exec_max_err = exec_max_err.max((a - b).abs());
        }
    }
    ensure!(
        exec_max_err < 1e-3,
        "partition fold diverged from the direct-softmax oracle: {exec_max_err}"
    );
    let mut exec_balance =
        crate::obs::balance::plan_balance(&ep, &plan, &arch);
    join_measured_events(&mut exec_balance, &tracer.events());
    let measured_rows =
        exec_balance.ledger.iter().filter(|r| r.measured_us.is_some()).count();
    ensure!(
        measured_rows == exec_balance.grid,
        "only {measured_rows} of {} ledger rows joined a measured span",
        exec_balance.grid
    );

    // --- 3. the stationary drift stream -------------------------------
    // A few unobserved warmup passes first, so cache/branch warm-up on
    // a cold machine does not skew the gain the detector fits.
    let off = Tracer::disabled();
    for _ in 0..3 {
        std::hint::black_box(execute_plan_traced(&ep, &plan, &t, &off));
    }
    let work = account_decode_problem(&ep);
    let mut detector =
        DriftDetector::new(CostCoefficients::nominal(), case.drift_limit);
    for _ in 0..case.drift_iters {
        let run = execute_plan_traced(&ep, &plan, &t, &off);
        let measured_us: f64 = run.cta_us.iter().sum();
        detector.observe(&work, measured_us);
    }
    ensure!(
        detector.observations() == case.drift_iters as u64,
        "drift stream dropped observations ({} of {})",
        detector.observations(),
        case.drift_iters
    );
    ensure!(
        detector.breaches() == 0,
        "drift detector breached {} time(s) on a stationary workload",
        detector.breaches()
    );
    let drift_rel_err = detector.rel_err().unwrap_or(0.0);
    ensure!(
        drift_rel_err <= case.drift_limit,
        "stationary rel-err EWMA {drift_rel_err:.3} exceeds the {:.2} limit",
        case.drift_limit
    );

    Ok(BalanceComparison {
        drift_observations: detector.observations(),
        drift_breaches: detector.breaches(),
        drift_rel_err,
        drift_gain: detector.gain().unwrap_or(0.0),
        case,
        report,
        exec_balance,
        exec_max_err,
        measured_rows,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_case_passes_every_balance_assertion() {
        let c = run_balance(BalanceCase::smoke(), 11).expect("balance bench");
        assert_eq!(c.measured_rows, c.exec_balance.grid);
        assert_eq!(c.drift_breaches, 0);
        let out = c.render();
        assert!(out.contains("partition balance"), "{out}");
        assert!(out.contains("drift (stationary"), "{out}");
    }

    #[test]
    fn same_seed_runs_emit_identical_gated_sections() {
        // The baseline gate compares counts and work bit-exactly; both
        // are pure functions of the case shape, so two same-seed runs
        // must agree even though the wall-clock info differs.
        let a = run_balance(BalanceCase::smoke(), 5).expect("first run");
        let b = run_balance(BalanceCase::smoke(), 5).expect("second run");
        let (ra, rb) = (a.bench_report(5, true), b.bench_report(5, true));
        assert_eq!(ra.counts, rb.counts);
        assert_eq!(ra.work, rb.work);
        assert_eq!(ra.measures, rb.measures);
        crate::obs::benchlog::validate_bench_report(&ra.to_json()).unwrap();
    }

    #[test]
    fn exec_work_total_matches_the_closed_form() {
        let c = run_balance(BalanceCase::smoke(), 3).expect("balance bench");
        let ep = c.case.exec_problem();
        assert_eq!(c.exec_balance.total, account_decode_problem(&ep));
    }
}
