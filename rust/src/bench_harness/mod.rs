//! Benchmark harness: workload generators, the paper-figure generators
//! (one per table/figure in the evaluation section), and plain-text /
//! JSON table formatting. The `rust/benches/figXX_*.rs` binaries are thin
//! wrappers over [`figures`].

pub mod balance;
pub mod cascade_exec;
pub mod figures;
pub mod gqa;
pub mod obs;
pub mod runner;
pub mod sampling;
pub mod sparse;
pub mod spec;
pub mod table;
pub mod trace;
pub mod workload;

pub use balance::{run_balance, BalanceCase, BalanceComparison};
pub use cascade_exec::{compare_exec, ExecCase, ExecComparison};
pub use gqa::{compare_gqa, GqaCase, GqaComparison};
pub use obs::{run_obs, ObsCase, ObsReport};
pub use runner::{bench, BenchResult};
pub use sampling::{compare_sampling, SamplingCase, SamplingComparison};
pub use sparse::{compare_sparse, SparseBenchCase, SparseComparison};
pub use spec::{compare_spec, SpecCase, SpecComparison};
pub use table::Table;
