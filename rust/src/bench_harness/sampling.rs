//! Flat vs **sibling-cascade** decode for parallel sampling: the
//! measurement behind `leanattn bench --sampling`.
//!
//! A fork family — one parent plus `siblings - 1` zero-copy forks — is
//! built on a real [`PagedKvCache`] (refcount-only forks, divergent
//! suffixes appended with copy-on-write), and the decode-side gather is
//! measured both ways over the identical physical pages:
//!
//! * **flat** — [`PagedKvCache::gather`] materializes every sibling's
//!   full context, shared history included, once per sibling;
//! * **sibling-cascade** — [`PagedKvCache::gather_shared`] materializes
//!   the family's shared leading page run once per *group*.
//!
//! The same shape is also posed to the cascade attention executor
//! (flat-lean vs cascade over identical numbers, via
//! [`compare_exec`]), so the report covers both halves of a decode
//! step: KV gather traffic and attention execution. Gathered-KV byte
//! counts are exact by construction; wall-clock columns carry the usual
//! timing noise.

use anyhow::{ensure, Result};

use crate::coordinator::PagedKvCache;
use crate::obs::benchlog::BenchReport;
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use crate::util::timer::sample_us;

use super::cascade_exec::{compare_exec, ExecCase, ExecComparison};

/// Shape of one fork-family comparison.
#[derive(Clone, Copy, Debug)]
pub struct SamplingCase {
    /// Sequences in the fork family (parent + forks), >= 1.
    pub siblings: usize,
    /// Tokens shared by the family at fork time.
    pub history: usize,
    /// Divergent tokens appended per sibling after the fork.
    pub suffix: usize,
    pub layers: usize,
    pub heads: usize,
    pub head_dim: usize,
    pub page_tokens: usize,
    /// LeanTile width for the attention-executor comparison.
    pub tile: usize,
}

impl SamplingCase {
    /// The `leanattn bench --sampling` default shape.
    pub fn default_case() -> SamplingCase {
        SamplingCase {
            siblings: 4,
            history: 256,
            suffix: 64,
            layers: 2,
            heads: 2,
            head_dim: 16,
            page_tokens: 16,
            tile: 32,
        }
    }

    /// CI smoke shape: small and fast, still >= 2 siblings with a
    /// nonzero shared history so every assertion stays meaningful.
    pub fn smoke() -> SamplingCase {
        SamplingCase {
            siblings: 2,
            history: 64,
            suffix: 16,
            ..SamplingCase::default_case()
        }
    }
}

/// Outcome of one flat vs sibling-cascade comparison.
pub struct SamplingComparison {
    pub case: SamplingCase,
    /// Pages allocated by the fork calls themselves (refcount-only
    /// forking means exactly 0).
    pub fork_fresh_pages: usize,
    /// Copy-on-write page clones performed as the siblings diverged.
    pub cow_copies: usize,
    /// K+V bytes the flat gather materializes per decode step.
    pub flat_gather_bytes: usize,
    /// K+V bytes the sibling-cascade gather materializes per step.
    pub shared_gather_bytes: usize,
    pub flat_us: Summary,
    pub shared_us: Summary,
    /// Attention-executor comparison over the same prefix structure.
    pub attention: ExecComparison,
}

impl SamplingComparison {
    /// Fraction of flat gather traffic the sibling-cascade path avoids.
    pub fn bytes_saved_fraction(&self) -> f64 {
        if self.flat_gather_bytes == 0 {
            return 0.0;
        }
        1.0 - self.shared_gather_bytes as f64 / self.flat_gather_bytes as f64
    }

    /// Machine-readable telemetry for `--json-out` / the baseline gate.
    pub fn bench_report(&self, seed: u64, smoke: bool) -> BenchReport {
        let mut r = BenchReport::new("sampling", seed, smoke);
        r.count("siblings", self.case.siblings as u64);
        r.count("history_tokens", self.case.history as u64);
        r.count("suffix_tokens", self.case.suffix as u64);
        r.count("fork_fresh_pages", self.fork_fresh_pages as u64);
        r.count("cow_copies", self.cow_copies as u64);
        r.count("flat_gather_bytes", self.flat_gather_bytes as u64);
        r.count("shared_gather_bytes", self.shared_gather_bytes as u64);
        r.work("attention_flat", self.attention.work_flat);
        r.work("attention_cascade", self.attention.work_cascade);
        r.measure("bytes_saved_fraction", self.bytes_saved_fraction());
        r.measure("attention_max_err", f64::from(self.attention.max_err));
        r.info("flat_us_p50", self.flat_us.p50);
        r.info("shared_us_p50", self.shared_us.p50);
        r
    }
}

/// Build the fork family on a paged cache, diverge it, and measure both
/// gather paths plus the attention-executor comparison. Asserts the two
/// gathers agree bit-for-bit before timing anything.
pub fn compare_sampling(
    case: SamplingCase,
    iters: usize,
    seed: u64,
) -> Result<SamplingComparison> {
    ensure!(case.siblings >= 1, "need at least one sequence");
    ensure!(case.history >= 1, "need a nonzero shared history");
    let tokens_per_seq = case.history + case.suffix;
    let pages_per_seq = tokens_per_seq.div_ceil(case.page_tokens);
    let total_pages = case.siblings * pages_per_seq + 2;
    let mut cache = PagedKvCache::new(
        case.layers,
        case.heads,
        case.head_dim,
        case.page_tokens,
        total_pages,
    );
    let mut rng = Rng::new(seed);

    // Parent holds the shared history; forks are refcount-only.
    let n = case.layers * case.heads * case.history * case.head_dim;
    let (k, v) = (rng.normal_vec(n), rng.normal_vec(n));
    cache.insert_seq(0, &k, &v, case.history)?;
    let free_before = cache.free_pages();
    for child in 1..case.siblings as u64 {
        cache.fork_seq(0, child)?;
    }
    let fork_fresh_pages = free_before - cache.free_pages();

    // Diverge: every sibling appends its own suffix (COW clones the
    // shared partial last page on first touch, at most once per holder).
    let plane = case.layers * case.heads * case.head_dim;
    let mut cow_copies = 0usize;
    for _ in 0..case.suffix {
        for id in 0..case.siblings as u64 {
            let (nk, nv) = (rng.normal_vec(plane), rng.normal_vec(plane));
            if cache.append_token(id, &nk, &nv)? {
                cow_copies += 1;
            }
        }
    }

    // Both gathers over the whole family, proven bit-identical first.
    let slots: Vec<Option<u64>> = (0..case.siblings as u64).map(Some).collect();
    let ctx = pages_per_seq * case.page_tokens;
    let nelem = case.layers * case.siblings * case.heads * ctx * case.head_dim;
    let (mut kf, mut vf) = (vec![0.0f32; nelem], vec![0.0f32; nelem]);
    cache.gather(&slots, ctx, &mut kf, &mut vf)?;
    let sg = cache.gather_shared(&slots)?;
    let (mut ks, mut vs) = (vec![1.0f32; nelem], vec![1.0f32; nelem]);
    sg.compose_dense(ctx, &mut ks, &mut vs)?;
    ensure!(kf == ks && vf == vs, "sibling-cascade gather diverged from flat");
    let (flat_gather_bytes, shared_gather_bytes) = (sg.flat_bytes, sg.shared_bytes);

    let flat_samples = sample_us(iters, 0.0, || {
        cache.gather(&slots, ctx, &mut kf, &mut vf).expect("flat gather");
    });
    let shared_samples = sample_us(iters, 0.0, || {
        let sg = cache.gather_shared(&slots).expect("shared gather");
        sg.compose_dense(ctx, &mut ks, &mut vs).expect("compose");
    });

    // Attention side: the same prefix structure through the cascade
    // executor (host oracle; `leanattn bench --cascade-exec` covers the
    // PJRT-artifact variant).
    let attention = compare_exec(
        ExecCase {
            batch: case.siblings.max(2),
            prefix: case.history as u32,
            suffix: case.suffix.max(1) as u32,
            heads: case.heads,
            head_dim: case.head_dim,
            tile: case.tile,
            slots: 64,
        },
        iters,
        None,
        seed ^ 0x5A5A,
    )?;

    Ok(SamplingComparison {
        case,
        fork_fresh_pages,
        cow_copies,
        flat_gather_bytes,
        shared_gather_bytes,
        flat_us: Summary::of(&flat_samples),
        shared_us: Summary::of(&shared_samples),
        attention,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fork_family_dedups_and_accounts_cow() {
        let case = SamplingCase {
            siblings: 3,
            history: 20, // 2.5 pages of 8 -> partial last page at fork
            suffix: 5,
            layers: 1,
            heads: 2,
            head_dim: 4,
            page_tokens: 8,
            tile: 16,
        };
        let c = compare_sampling(case, 2, 7).expect("comparison");
        assert_eq!(c.fork_fresh_pages, 0, "forks are refcount-only");
        // 3 holders of the partial page -> 2 COW clones (the last holder
        // owns the only remaining reference and writes in place).
        assert_eq!(c.cow_copies, 2);
        assert!(
            c.shared_gather_bytes < c.flat_gather_bytes,
            "{} vs {}",
            c.shared_gather_bytes,
            c.flat_gather_bytes
        );
        // Shared run = the 2 full history pages (16 tokens), counted once
        // instead of three times. K+V × layers(1) × heads(2) × dh(4) × f32.
        let token_bytes = 2 * 2 * 4 * 4;
        assert_eq!(c.flat_gather_bytes, 3 * 25 * token_bytes);
        assert_eq!(c.shared_gather_bytes, (16 + 3 * 9) * token_bytes);
        assert!(c.bytes_saved_fraction() > 0.0);
        assert!(c.attention.cascade_kv_bytes < c.attention.flat_kv_bytes);
    }

    #[test]
    fn page_aligned_fork_never_cows() {
        let case = SamplingCase {
            siblings: 4,
            history: 16, // exactly 2 pages of 8
            suffix: 3,
            layers: 1,
            heads: 1,
            head_dim: 4,
            page_tokens: 8,
            tile: 8,
        };
        let c = compare_sampling(case, 2, 9).expect("comparison");
        assert_eq!(c.fork_fresh_pages, 0);
        assert_eq!(c.cow_copies, 0, "page-aligned fork never copies");
        assert!(c.shared_gather_bytes < c.flat_gather_bytes);
    }

    #[test]
    fn smoke_case_upholds_the_bench_assertions() {
        let c = compare_sampling(SamplingCase::smoke(), 1, 3).expect("smoke");
        assert_eq!(c.fork_fresh_pages, 0);
        assert!(c.shared_gather_bytes < c.flat_gather_bytes);
        assert!(c.attention.cascade_kv_bytes < c.attention.flat_kv_bytes);
        assert!(c.attention.max_err < 1e-3);
        let rep = c.bench_report(3, true);
        crate::obs::benchlog::validate_bench_report(&rep.to_json()).unwrap();
        assert_eq!(rep.counts["cow_copies"], c.cow_copies as u64);
    }
}
