//! Dense-per-head vs grouped (GQA/MQA) decode comparison over identical
//! numbers: the measurement backing `leanattn bench --gqa`.
//!
//! Both paths run the same stream-K planner and host executor over a
//! short decode loop (context grows by one LeanTile per step). The
//! grouped path poses the problem at **kv-head** granularity — one KV
//! stream per (lane, kv head) serving a whole query-head group — while
//! the dense path poses the classic one-KV-stream-per-query-head layout,
//! its K/V materialized by repeating each kv-head stream `h/h_kv` times
//! from the *same* random draws. The exactness oracle is plain dense
//! attention over that repeated KV, so the gathered-KV-byte gap between
//! the two paths is attributable to the grouping alone and both streams
//! must agree with the oracle bit-for-float.

use anyhow::{ensure, Result};

use crate::attention::attention_host;
use crate::partition::host_exec::{execute_plan_host, HostTensors};
use crate::partition::plan::{build_plan, DecodeProblem, Plan, Strategy};
use crate::util::stats::Summary;
use crate::util::testing::max_abs_err;
use crate::util::timer::sample_us;

/// Shape of one grouped-vs-dense decode comparison.
#[derive(Clone, Copy, Debug)]
pub struct GqaCase {
    pub batch: usize,
    /// Query heads.
    pub heads: usize,
    /// KV heads; divides `heads` (1 = MQA, `heads` = ungrouped).
    pub kv_heads: usize,
    /// Context tokens at the first decode step.
    pub ctx: usize,
    /// Decode steps; the context grows by one tile per step.
    pub steps: usize,
    pub head_dim: usize,
    pub tile: usize,
    /// CTA slots handed to the stream-K planner.
    pub slots: usize,
}

impl GqaCase {
    /// CI-sized case (seconds, not minutes).
    pub fn smoke() -> GqaCase {
        GqaCase {
            batch: 2,
            heads: 4,
            kv_heads: 1,
            ctx: 96,
            steps: 2,
            head_dim: 16,
            tile: 32,
            slots: 24,
        }
    }

    pub fn default_case() -> GqaCase {
        GqaCase {
            batch: 2,
            heads: 8,
            kv_heads: 2,
            ctx: 512,
            steps: 4,
            head_dim: 64,
            tile: 64,
            slots: 64,
        }
    }
}

/// Outcome of one grouped-vs-dense comparison.
#[derive(Clone, Debug)]
pub struct GqaComparison {
    pub case: GqaCase,
    /// K+V bytes the grouped plan streams over the loop (one KV walk per
    /// kv head).
    pub grouped_kv_bytes: u64,
    /// K+V bytes the dense per-query-head plan streams over the loop.
    pub dense_kv_bytes: u64,
    pub grouped_us: Summary,
    pub dense_us: Summary,
    /// Worst-step max abs error of the grouped stream vs the repeated-KV
    /// dense oracle.
    pub grouped_err: f32,
    /// Worst-step max abs error of the dense stream vs the same oracle.
    pub dense_err: f32,
}

impl GqaComparison {
    /// Dense-over-grouped gathered-KV byte ratio — `h / h_kv` up to tile
    /// padding.
    pub fn bytes_ratio(&self) -> f64 {
        if self.grouped_kv_bytes == 0 {
            return 0.0;
        }
        self.dense_kv_bytes as f64 / self.grouped_kv_bytes as f64
    }
}

/// One prepared decode step: plans for both paths plus the oracle output,
/// all derived from a single set of random draws.
struct PreparedStep {
    grouped_problem: DecodeProblem,
    grouped_plan: Plan,
    grouped_tensors: HostTensors,
    dense_problem: DecodeProblem,
    dense_plan: Plan,
    dense_tensors: HostTensors,
    oracle: Vec<f32>,
}

/// KV bytes a plan streams: every LeanTile moves `tile × d` K rows and as
/// many V rows (f32 host storage).
fn plan_kv_bytes(problem: &DecodeProblem) -> u64 {
    problem.total_tiles() * (2 * problem.tile * problem.head_dim * 4) as u64
}

/// Run one grouped-vs-dense decode-loop comparison.
pub fn compare_gqa(case: GqaCase, iters: usize, seed: u64) -> Result<GqaComparison> {
    ensure!(case.kv_heads >= 1, "--kv-heads must be >= 1");
    ensure!(
        case.heads % case.kv_heads == 0,
        "kv heads {} must divide query heads {}",
        case.kv_heads,
        case.heads
    );
    ensure!(case.steps >= 1, "need at least one decode step");

    let d = case.head_dim;
    let mut steps = Vec::with_capacity(case.steps);
    let mut grouped_kv_bytes = 0u64;
    let mut dense_kv_bytes = 0u64;
    for s in 0..case.steps {
        let ctx = case.ctx + s * case.tile;
        let gp = DecodeProblem::uniform(case.batch, case.heads, ctx, d)
            .with_tile(case.tile)
            .with_kv_heads(case.kv_heads);
        let gt = HostTensors::random(&gp, seed.wrapping_add(s as u64));
        // Dense twin: same queries, KV repeated to query-head count —
        // identical randomness by construction.
        let dp = DecodeProblem::uniform(case.batch, case.heads, ctx, d)
            .with_tile(case.tile);
        let (rk, rv) = gt.repeated_kv(&gp);
        let dt = HostTensors { q: gt.q.clone(), k: rk, v: rv, n_max: gt.n_max };
        let oracle = attention_host(
            &gt.q,
            &dt.k,
            &dt.v,
            gp.outputs(),
            gt.n_max,
            d,
            &gt.output_lens(&gp),
        );
        let grouped_plan = build_plan(&gp, Strategy::StreamK, case.slots);
        grouped_plan.validate(&gp)?;
        let dense_plan = build_plan(&dp, Strategy::StreamK, case.slots);
        dense_plan.validate(&dp)?;
        grouped_kv_bytes += plan_kv_bytes(&gp);
        dense_kv_bytes += plan_kv_bytes(&dp);
        steps.push(PreparedStep {
            grouped_problem: gp,
            grouped_plan,
            grouped_tensors: gt,
            dense_problem: dp,
            dense_plan,
            dense_tensors: dt,
            oracle,
        });
    }

    // Exactness: both streams against the repeated-KV dense oracle.
    let mut grouped_err = 0.0f32;
    let mut dense_err = 0.0f32;
    for st in &steps {
        let g = execute_plan_host(
            &st.grouped_plan,
            &st.grouped_problem,
            &st.grouped_tensors,
            None,
        );
        grouped_err = grouped_err.max(max_abs_err(&g, &st.oracle));
        let de = execute_plan_host(
            &st.dense_plan,
            &st.dense_problem,
            &st.dense_tensors,
            None,
        );
        dense_err = dense_err.max(max_abs_err(&de, &st.oracle));
    }

    let grouped_samples = sample_us(iters, 0.0, || {
        for st in &steps {
            std::hint::black_box(execute_plan_host(
                &st.grouped_plan,
                &st.grouped_problem,
                &st.grouped_tensors,
                None,
            ));
        }
    });
    let dense_samples = sample_us(iters, 0.0, || {
        for st in &steps {
            std::hint::black_box(execute_plan_host(
                &st.dense_plan,
                &st.dense_problem,
                &st.dense_tensors,
                None,
            ));
        }
    });

    Ok(GqaComparison {
        case,
        grouped_kv_bytes,
        dense_kv_bytes,
        grouped_us: Summary::of(&grouped_samples),
        dense_us: Summary::of(&dense_samples),
        grouped_err,
        dense_err,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grouped_stream_is_exact_and_shrinks_bytes_by_the_group_size() {
        // 8 query heads at h_kv ∈ {1 (MQA), 2 (h/4), 8 (ungrouped)}.
        for kv_heads in [1usize, 2, 8] {
            let case = GqaCase {
                batch: 2,
                heads: 8,
                kv_heads,
                ctx: 96,
                steps: 2,
                head_dim: 16,
                tile: 32,
                slots: 24,
            };
            let c = compare_gqa(case, 1, 11).unwrap();
            assert!(c.grouped_err < 1e-4, "kv {kv_heads}: grouped err {}", c.grouped_err);
            assert!(c.dense_err < 1e-4, "kv {kv_heads}: dense err {}", c.dense_err);
            let want = 8.0 / kv_heads as f64;
            let got = c.bytes_ratio();
            assert!(
                (got - want).abs() <= 0.1 * want,
                "kv {kv_heads}: bytes ratio {got}, want ~{want}"
            );
        }
    }

    #[test]
    fn non_dividing_kv_heads_are_rejected() {
        let case = GqaCase { kv_heads: 3, ..GqaCase::default_case() };
        assert!(compare_gqa(case, 1, 0).is_err());
    }
}
