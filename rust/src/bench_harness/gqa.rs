//! Dense-per-head vs grouped (GQA/MQA) decode comparison over identical
//! numbers: the measurement backing `leanattn bench --gqa`.
//!
//! Both paths run the same stream-K planner and host executor over a
//! short decode loop (context grows by one LeanTile per step). The
//! grouped path poses the problem at **kv-head** granularity — one KV
//! stream per (lane, kv head) serving a whole query-head group — while
//! the dense path poses the classic one-KV-stream-per-query-head layout,
//! its K/V materialized by repeating each kv-head stream `h/h_kv` times
//! from the *same* random draws. The exactness oracle is plain dense
//! attention over that repeated KV, so the gathered-KV-byte gap between
//! the two paths is attributable to the grouping alone and both streams
//! must agree with the oracle bit-for-float.

use anyhow::{ensure, Result};

use crate::attention::attention_host;
use crate::obs::attrib::{account_decode_problem, WorkAccounting};
use crate::obs::benchlog::BenchReport;
use crate::partition::host_exec::{execute_plan_host, HostTensors};
use crate::partition::plan::{build_plan, DecodeProblem, Plan, Strategy};
use crate::util::stats::Summary;
use crate::util::testing::max_abs_err;
use crate::util::timer::sample_us;

/// Shape of one grouped-vs-dense decode comparison.
#[derive(Clone, Copy, Debug)]
pub struct GqaCase {
    pub batch: usize,
    /// Query heads.
    pub heads: usize,
    /// KV heads; divides `heads` (1 = MQA, `heads` = ungrouped).
    pub kv_heads: usize,
    /// Context tokens at the first decode step.
    pub ctx: usize,
    /// Decode steps; the context grows by one tile per step.
    pub steps: usize,
    pub head_dim: usize,
    pub tile: usize,
    /// CTA slots handed to the stream-K planner.
    pub slots: usize,
}

impl GqaCase {
    /// CI-sized case (seconds, not minutes).
    pub fn smoke() -> GqaCase {
        GqaCase {
            batch: 2,
            heads: 4,
            kv_heads: 1,
            ctx: 96,
            steps: 2,
            head_dim: 16,
            tile: 32,
            slots: 24,
        }
    }

    pub fn default_case() -> GqaCase {
        GqaCase {
            batch: 2,
            heads: 8,
            kv_heads: 2,
            ctx: 512,
            steps: 4,
            head_dim: 64,
            tile: 64,
            slots: 64,
        }
    }
}

/// Outcome of one grouped-vs-dense comparison.
#[derive(Clone, Debug)]
pub struct GqaComparison {
    pub case: GqaCase,
    /// K+V bytes the grouped plan streams over the loop (one KV walk per
    /// kv head).
    pub grouped_kv_bytes: u64,
    /// K+V bytes the dense per-query-head plan streams over the loop.
    pub dense_kv_bytes: u64,
    pub grouped_us: Summary,
    pub dense_us: Summary,
    /// Worst-step max abs error of the grouped stream vs the repeated-KV
    /// dense oracle.
    pub grouped_err: f32,
    /// Worst-step max abs error of the dense stream vs the same oracle.
    pub dense_err: f32,
    /// Exact work of the grouped posing, summed over the decode loop.
    pub work_grouped: WorkAccounting,
    /// Exact work of the dense per-query-head posing over the same loop.
    pub work_dense: WorkAccounting,
}

impl GqaComparison {
    /// Dense-over-grouped gathered-KV byte ratio — exactly `h / h_kv`.
    pub fn bytes_ratio(&self) -> f64 {
        if self.grouped_kv_bytes == 0 {
            return 0.0;
        }
        self.dense_kv_bytes as f64 / self.grouped_kv_bytes as f64
    }

    /// Machine-readable telemetry for `--json-out` / the baseline gate.
    /// Counts, work sections and the error maxima are deterministic for
    /// a given shape and seed; only the wall-clock columns vary.
    pub fn bench_report(&self, seed: u64, smoke: bool) -> BenchReport {
        let mut r = BenchReport::new("gqa", seed, smoke);
        r.count("batch", self.case.batch as u64);
        r.count("heads", self.case.heads as u64);
        r.count("kv_heads", self.case.kv_heads as u64);
        r.count("ctx_tokens", self.case.ctx as u64);
        r.count("steps", self.case.steps as u64);
        r.count("head_dim", self.case.head_dim as u64);
        r.count("tile", self.case.tile as u64);
        r.count("grouped_kv_bytes", self.grouped_kv_bytes);
        r.count("dense_kv_bytes", self.dense_kv_bytes);
        r.work("grouped", self.work_grouped);
        r.work("dense", self.work_dense);
        r.measure("bytes_ratio", self.bytes_ratio());
        r.measure("grouped_err", f64::from(self.grouped_err));
        r.measure("dense_err", f64::from(self.dense_err));
        r.info("grouped_us_p50", self.grouped_us.p50);
        r.info("dense_us_p50", self.dense_us.p50);
        r
    }
}

/// One prepared decode step: plans for both paths plus the oracle output,
/// all derived from a single set of random draws.
struct PreparedStep {
    grouped_problem: DecodeProblem,
    grouped_plan: Plan,
    grouped_tensors: HostTensors,
    dense_problem: DecodeProblem,
    dense_plan: Plan,
    dense_tensors: HostTensors,
    oracle: Vec<f32>,
}

// (KV-byte accounting lives in `crate::obs::attrib` — exact context
// bytes per KV stream, shared with the engine counters and simulator.)

/// Run one grouped-vs-dense decode-loop comparison.
pub fn compare_gqa(case: GqaCase, iters: usize, seed: u64) -> Result<GqaComparison> {
    ensure!(case.kv_heads >= 1, "--kv-heads must be >= 1");
    ensure!(
        case.heads % case.kv_heads == 0,
        "kv heads {} must divide query heads {}",
        case.kv_heads,
        case.heads
    );
    ensure!(case.steps >= 1, "need at least one decode step");

    let d = case.head_dim;
    let mut steps = Vec::with_capacity(case.steps);
    let mut work_grouped = WorkAccounting::default();
    let mut work_dense = WorkAccounting::default();
    for s in 0..case.steps {
        let ctx = case.ctx + s * case.tile;
        let gp = DecodeProblem::uniform(case.batch, case.heads, ctx, d)
            .with_tile(case.tile)
            .with_kv_heads(case.kv_heads);
        let gt = HostTensors::random(&gp, seed.wrapping_add(s as u64));
        // Dense twin: same queries, KV repeated to query-head count —
        // identical randomness by construction.
        let dp = DecodeProblem::uniform(case.batch, case.heads, ctx, d)
            .with_tile(case.tile);
        let (rk, rv) = gt.repeated_kv(&gp);
        let dt = HostTensors { q: gt.q.clone(), k: rk, v: rv, n_max: gt.n_max };
        let oracle = attention_host(
            &gt.q,
            &dt.k,
            &dt.v,
            gp.outputs(),
            gt.n_max,
            d,
            &gt.output_lens(&gp),
        );
        let grouped_plan = build_plan(&gp, Strategy::StreamK, case.slots);
        grouped_plan.validate(&gp)?;
        let dense_plan = build_plan(&dp, Strategy::StreamK, case.slots);
        dense_plan.validate(&dp)?;
        work_grouped += account_decode_problem(&gp);
        work_dense += account_decode_problem(&dp);
        steps.push(PreparedStep {
            grouped_problem: gp,
            grouped_plan,
            grouped_tensors: gt,
            dense_problem: dp,
            dense_plan,
            dense_tensors: dt,
            oracle,
        });
    }

    // Exactness: both streams against the repeated-KV dense oracle.
    let mut grouped_err = 0.0f32;
    let mut dense_err = 0.0f32;
    for st in &steps {
        let g = execute_plan_host(
            &st.grouped_plan,
            &st.grouped_problem,
            &st.grouped_tensors,
            None,
        );
        grouped_err = grouped_err.max(max_abs_err(&g, &st.oracle));
        let de = execute_plan_host(
            &st.dense_plan,
            &st.dense_problem,
            &st.dense_tensors,
            None,
        );
        dense_err = dense_err.max(max_abs_err(&de, &st.oracle));
    }

    let grouped_samples = sample_us(iters, 0.0, || {
        for st in &steps {
            std::hint::black_box(execute_plan_host(
                &st.grouped_plan,
                &st.grouped_problem,
                &st.grouped_tensors,
                None,
            ));
        }
    });
    let dense_samples = sample_us(iters, 0.0, || {
        for st in &steps {
            std::hint::black_box(execute_plan_host(
                &st.dense_plan,
                &st.dense_problem,
                &st.dense_tensors,
                None,
            ));
        }
    });

    Ok(GqaComparison {
        case,
        grouped_kv_bytes: work_grouped.gathered_kv_bytes,
        dense_kv_bytes: work_dense.gathered_kv_bytes,
        grouped_us: Summary::of(&grouped_samples),
        dense_us: Summary::of(&dense_samples),
        grouped_err,
        dense_err,
        work_grouped,
        work_dense,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grouped_stream_is_exact_and_shrinks_bytes_by_the_group_size() {
        // 8 query heads at h_kv ∈ {1 (MQA), 2 (h/4), 8 (ungrouped)}.
        for kv_heads in [1usize, 2, 8] {
            let case = GqaCase {
                batch: 2,
                heads: 8,
                kv_heads,
                ctx: 96,
                steps: 2,
                head_dim: 16,
                tile: 32,
                slots: 24,
            };
            let c = compare_gqa(case, 1, 11).unwrap();
            assert!(c.grouped_err < 1e-4, "kv {kv_heads}: grouped err {}", c.grouped_err);
            assert!(c.dense_err < 1e-4, "kv {kv_heads}: dense err {}", c.dense_err);
            let want = 8.0 / kv_heads as f64;
            let got = c.bytes_ratio();
            assert!(
                (got - want).abs() <= 0.1 * want,
                "kv {kv_heads}: bytes ratio {got}, want ~{want}"
            );
            // The byte counters *are* the attrib work sections now, and
            // grouping never changes the softmax flop count (every query
            // head still walks its full context).
            assert_eq!(c.grouped_kv_bytes, c.work_grouped.gathered_kv_bytes);
            assert_eq!(c.dense_kv_bytes, c.work_dense.gathered_kv_bytes);
            assert_eq!(c.work_grouped.softmax_flops, c.work_dense.softmax_flops);
        }
    }

    #[test]
    fn same_seed_runs_emit_identical_reports() {
        // The baseline gate compares counts and work bit-exactly and the
        // error maxima are pure float functions of the seed, so two runs
        // must agree on every gated section.
        let a = compare_gqa(GqaCase::smoke(), 1, 17).unwrap().bench_report(17, true);
        let b = compare_gqa(GqaCase::smoke(), 1, 17).unwrap().bench_report(17, true);
        assert_eq!(a.counts, b.counts);
        assert_eq!(a.work, b.work);
        assert_eq!(a.measures, b.measures);
        crate::obs::benchlog::validate_bench_report(&a.to_json()).unwrap();
        assert_eq!(a.name, "gqa");
    }

    #[test]
    fn non_dividing_kv_heads_are_rejected() {
        let case = GqaCase { kv_heads: 3, ..GqaCase::default_case() };
        assert!(compare_gqa(case, 1, 0).is_err());
    }
}
