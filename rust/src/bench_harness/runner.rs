//! Micro-bench runner (criterion is not in the offline crate cache):
//! warmup + timed samples + a one-line summary, plus a JSON record under
//! `target/benches/`.

use crate::util::json::Json;
use crate::util::stats::Summary;
use crate::util::timer::sample_us;
use std::collections::BTreeMap;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<44} mean {:>10.2}us  p50 {:>10.2}us  p99 {:>10.2}us  (n={})",
            self.name, self.summary.mean, self.summary.p50, self.summary.p99, self.summary.n
        )
    }
}

/// Run one benchmark case: at least `min_iters` iterations and 0.3s.
pub fn bench(name: &str, min_iters: usize, f: impl FnMut()) -> BenchResult {
    let samples = sample_us(min_iters, 0.3, f);
    let r = BenchResult { name: name.to_string(), summary: Summary::of(&samples) };
    println!("{}", r.line());
    r
}

/// Persist a set of results as JSON under `target/benches/<group>.json`.
pub fn save(group: &str, results: &[BenchResult]) {
    let arr: Vec<Json> = results
        .iter()
        .map(|r| {
            let mut o = BTreeMap::new();
            o.insert("name".into(), Json::Str(r.name.clone()));
            o.insert("mean_us".into(), Json::Num(r.summary.mean));
            o.insert("p50_us".into(), Json::Num(r.summary.p50));
            o.insert("p99_us".into(), Json::Num(r.summary.p99));
            o.insert("n".into(), Json::Num(r.summary.n as f64));
            Json::Obj(o)
        })
        .collect();
    let dir = std::path::Path::new("target/benches");
    if std::fs::create_dir_all(dir).is_ok() {
        let _ = std::fs::write(
            dir.join(format!("{group}.json")),
            Json::Arr(arr).to_string(),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_summary() {
        let r = bench("noop", 5, || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.summary.n >= 5);
        assert!(r.summary.mean >= 0.0);
    }
}
