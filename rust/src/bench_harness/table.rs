//! Plain-text + JSON result tables (the offline stand-in for criterion's
//! reports). Every figure generator returns one of these; benches print it
//! and drop a machine-readable copy under `target/figures/`.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A titled table of string cells with float-aware formatting.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    /// Free-form notes rendered under the table (assumptions, paper refs).
    pub notes: Vec<String>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width");
        self.rows.push(cells);
        self
    }

    pub fn note(&mut self, note: impl Into<String>) -> &mut Self {
        self.notes.push(note.into());
        self
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |out: &mut String, cells: &[String]| {
            let mut s = String::from("|");
            for (w, c) in widths.iter().zip(cells) {
                let _ = write!(s, " {c:>w$} |", w = w);
            }
            let _ = writeln!(out, "{s}");
        };
        line(&mut out, &self.headers);
        let total: usize = widths.iter().map(|w| w + 3).sum::<usize>() + 1;
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            line(&mut out, row);
        }
        for n in &self.notes {
            let _ = writeln!(out, "  note: {n}");
        }
        out
    }

    /// Machine-readable JSON (array of header-keyed objects).
    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|row| {
                let mut obj = BTreeMap::new();
                for (h, c) in self.headers.iter().zip(row) {
                    let v = c
                        .parse::<f64>()
                        .map(Json::Num)
                        .unwrap_or_else(|_| Json::Str(c.clone()));
                    obj.insert(h.clone(), v);
                }
                Json::Obj(obj)
            })
            .collect();
        let mut top = BTreeMap::new();
        top.insert("title".to_string(), Json::Str(self.title.clone()));
        top.insert("rows".to_string(), Json::Arr(rows));
        top.insert(
            "notes".to_string(),
            Json::Arr(self.notes.iter().cloned().map(Json::Str).collect()),
        );
        Json::Obj(top)
    }

    /// Print to stdout and persist text+json under `target/figures/<name>`.
    pub fn emit(&self, name: &str) {
        let text = self.render();
        println!("{text}");
        let dir = std::path::Path::new("target/figures");
        if std::fs::create_dir_all(dir).is_ok() {
            let _ = std::fs::write(dir.join(format!("{name}.txt")), &text);
            let _ = std::fs::write(
                dir.join(format!("{name}.json")),
                self.to_json().to_string(),
            );
        }
    }
}

/// Format a float with 2 decimals (shared row-building helper).
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

/// Format a float with 3 decimals.
pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

/// Human context-length label: 1024 -> "1k", 262144 -> "256k", 1048576 -> "1M".
pub fn ctx_label(ctx: usize) -> String {
    if ctx >= 1 << 20 && ctx % (1 << 20) == 0 {
        format!("{}M", ctx >> 20)
    } else if ctx >= 1024 && ctx % 1024 == 0 {
        format!("{}k", ctx >> 10)
    } else {
        ctx.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("T", &["a", "long_header"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["100".into(), "x".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.lines().count() >= 4);
        // all data lines same length
        let lens: Vec<usize> = s
            .lines()
            .filter(|l| l.starts_with('|'))
            .map(|l| l.len())
            .collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn json_round_trip_types() {
        let mut t = Table::new("T", &["n", "s"]);
        t.row(vec!["1.5".into(), "abc".into()]);
        let j = t.to_json();
        let rows = j.at("rows").as_arr().unwrap();
        assert_eq!(rows[0].at("n").as_f64(), Some(1.5));
        assert_eq!(rows[0].str_at("s"), "abc");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        let mut t = Table::new("T", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn ctx_labels() {
        assert_eq!(ctx_label(1024), "1k");
        assert_eq!(ctx_label(262_144), "256k");
        assert_eq!(ctx_label(1 << 20), "1M");
        assert_eq!(ctx_label(100), "100");
    }
}
