//! Workload generators for the evaluation sweeps: the >1000-sample random
//! problem population of §VI, and ragged batches at controlled
//! heterogeneity (Fig 10's batch-context-ratio).

use crate::partition::plan::DecodeProblem;
use crate::util::rng::Rng;

/// The sweep population of §VI: varying batch sizes, context lengths and
/// attention heads, head_dim 64.
pub fn sweep_population(samples: usize, seed: u64) -> Vec<DecodeProblem> {
    let mut rng = Rng::new(seed);
    let heads = [8usize, 12, 16, 24, 32, 40, 48, 56, 64, 96, 128];
    let batches = [1usize, 2, 4, 6, 8, 16, 32];
    let ctx_pows = 10..=19; // 1k .. 512k
    let ctxs: Vec<usize> = ctx_pows.map(|p| 1usize << p).collect();
    (0..samples)
        .map(|_| {
            DecodeProblem::uniform(
                *rng.choose(&batches),
                *rng.choose(&heads),
                *rng.choose(&ctxs),
                64,
            )
        })
        .collect()
}

/// Build a ragged batch whose average/max context ratio is approximately
/// `ratio` (Fig 10's heterogeneity metric). The longest sequence is pinned
/// at `max_ctx`; the rest are spread uniformly so the mean hits the target.
pub fn ragged_batch(
    batch: usize,
    heads: usize,
    max_ctx: usize,
    ratio: f64,
    seed: u64,
) -> DecodeProblem {
    assert!(batch >= 1);
    assert!((0.0..=1.0).contains(&ratio));
    let mut rng = Rng::new(seed);
    let mut lens = vec![max_ctx as u32];
    if batch > 1 {
        // Remaining sequences need mean m = (ratio*batch*max - max)/(batch-1).
        let target = ((ratio * batch as f64 - 1.0) * max_ctx as f64
            / (batch - 1) as f64)
            .max(1.0);
        for _ in 1..batch {
            // jitter ±25% around the target, clamped to [1, max].
            let jitter = 0.75 + 0.5 * rng.f64();
            let len = (target * jitter).round().clamp(1.0, max_ctx as f64);
            lens.push(len as u32);
        }
    }
    DecodeProblem::ragged(heads, lens, 64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn population_is_deterministic_and_sized() {
        let a = sweep_population(100, 1);
        let b = sweep_population(100, 1);
        assert_eq!(a.len(), 100);
        assert_eq!(a[0], b[0]);
        assert_eq!(a[99], b[99]);
    }

    #[test]
    fn population_varies() {
        let pop = sweep_population(50, 2);
        let distinct: std::collections::BTreeSet<_> = pop
            .iter()
            .map(|p| (p.batch(), p.heads, p.ctx_lens[0]))
            .collect();
        assert!(distinct.len() > 10);
    }

    #[test]
    fn ragged_ratio_hits_target() {
        for &ratio in &[0.3, 0.5, 0.8, 1.0] {
            let p = ragged_batch(8, 32, 65536, ratio, 7);
            let got = p.batch_context_ratio();
            assert!(
                (got - ratio).abs() < 0.15,
                "ratio target {ratio} got {got}"
            );
            assert_eq!(p.ctx_lens[0], 65536);
        }
    }

    #[test]
    fn ragged_single_sequence() {
        let p = ragged_batch(1, 8, 4096, 0.5, 3);
        assert_eq!(p.batch(), 1);
        assert_eq!(p.batch_context_ratio(), 1.0);
    }
}
