//! Multi-replica request router (the vLLM-router-shaped front door).
//!
//! PJRT handles are not `Send`, so replicas live on the router's thread
//! and are stepped round-robin. Dispatch is **prefix-affine**: every
//! replica's radix index is probed for the incoming prompt and the
//! request steers to the replica holding the longest cached prefix — a
//! request that lands where its prefix pages already live skips
//! re-prefilling and re-storing them, and joins that replica's cascade
//! groups. Ties (including the all-cold case) break round-robin so load
//! still spreads. Affinity deliberately outranks load: a single hot
//! prefix therefore concentrates on its warm replica — the bounded
//! admission queue absorbs the burst, but a load-pressure valve
//! (replicate the hot prefix, or cap queue skew before overriding
//! affinity) is an open ROADMAP item. With one replica this degrades to
//! a thin queue — the structure matters for the scheduling tests and
//! for swapping in a process-per-replica transport later.

use anyhow::Result;

use super::engine::Engine;
use super::request::{FinishedRequest, RequestId};

/// Prefix-affinity dispatcher over engine replicas.
pub struct Router {
    engines: Vec<Engine>,
    /// (engine index, id within engine) per external request id.
    routes: Vec<(usize, RequestId)>,
    /// Round-robin cursor for prefix-length ties.
    rr: usize,
}

/// Pick the replica holding the longest cached prefix; break ties
/// (including "nobody holds anything") round-robin via `rr`. Pure so the
/// policy is unit-testable without engines.
pub fn route_by_prefix(prefix_tokens: &[usize], rr: &mut usize) -> usize {
    assert!(!prefix_tokens.is_empty());
    let best = prefix_tokens.iter().copied().max().unwrap();
    let tied: Vec<usize> = (0..prefix_tokens.len())
        .filter(|&i| prefix_tokens[i] == best)
        .collect();
    let pick = tied[*rr % tied.len()];
    *rr += 1;
    pick
}

impl Router {
    pub fn new(engines: Vec<Engine>) -> Router {
        assert!(!engines.is_empty());
        Router { engines, routes: Vec::new(), rr: 0 }
    }

    pub fn num_replicas(&self) -> usize {
        self.engines.len()
    }

    /// Probe every replica's radix index and submit to the one holding
    /// the longest cached prefix (round-robin tiebreak). Returns a
    /// router-level id.
    pub fn submit(&mut self, prompt: Vec<i32>, max_new: usize) -> Result<RequestId> {
        let matched: Vec<usize> = self
            .engines
            .iter()
            .map(|e| e.peek_prefix_tokens(&prompt))
            .collect();
        let ei = route_by_prefix(&matched, &mut self.rr);
        let inner = self.engines[ei].submit(prompt, max_new)?;
        self.routes.push((ei, inner));
        Ok(self.routes.len() as RequestId - 1)
    }

    /// The replica a router-level request was dispatched to.
    pub fn route_of(&self, id: RequestId) -> Option<usize> {
        self.routes.get(id as usize).map(|&(e, _)| e)
    }

    /// Step every replica once; collect finished requests (with router
    /// ids rewritten).
    pub fn step_all(&mut self) -> Result<Vec<FinishedRequest>> {
        let mut out = Vec::new();
        for ei in 0..self.engines.len() {
            for mut f in self.engines[ei].step()? {
                if let Some(router_id) = self
                    .routes
                    .iter()
                    .position(|&(e, id)| e == ei && id == f.id)
                {
                    f.id = router_id as RequestId;
                }
                out.push(f);
            }
        }
        Ok(out)
    }

    pub fn is_idle(&self) -> bool {
        self.engines.iter().all(|e| e.is_idle())
    }

    /// Drive all replicas until idle.
    pub fn run_until_idle(&mut self) -> Result<Vec<FinishedRequest>> {
        let mut all = Vec::new();
        while !self.is_idle() {
            all.extend(self.step_all()?);
        }
        Ok(all)
    }

    pub fn engines(&self) -> &[Engine] {
        &self.engines
    }
}

// Engine-driving integration tests live in rust/tests/engine_e2e.rs
// (they need artifacts); the routing policy itself is pure and tested
// here.
#[cfg(test)]
mod tests {
    use super::route_by_prefix;

    #[test]
    fn longest_prefix_wins_regardless_of_cursor() {
        for start in 0..5usize {
            let mut rr = start;
            // Replica 2 holds the longest cached prefix.
            assert_eq!(route_by_prefix(&[0, 16, 48, 16], &mut rr), 2);
        }
    }

    #[test]
    fn same_prefix_requests_colocate() {
        // Once one replica holds the prefix, every later probe returns a
        // unique maximum there — same-prefix requests stick together
        // while the rr cursor keeps moving.
        let mut rr = 0;
        let after_warm = [32usize, 0, 0];
        for _ in 0..6 {
            assert_eq!(route_by_prefix(&after_warm, &mut rr), 0);
        }
        assert_eq!(rr, 6, "cursor advances even on affinity hits");
    }

    #[test]
    fn cold_prompts_round_robin() {
        let mut rr = 0;
        let cold = [0usize, 0, 0];
        let picks: Vec<usize> =
            (0..6).map(|_| route_by_prefix(&cold, &mut rr)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn ties_cycle_only_the_tied_set() {
        let mut rr = 0;
        // Replicas 1 and 2 tie at 16 tokens; 0 is cold.
        let picks: Vec<usize> =
            (0..4).map(|_| route_by_prefix(&[0, 16, 16], &mut rr)).collect();
        assert_eq!(picks, vec![1, 2, 1, 2]);
    }
}
