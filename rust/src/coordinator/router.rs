//! Multi-replica request router (the vLLM-router-shaped front door).
//!
//! PJRT handles are not `Send`, so replicas live on the router's thread
//! and are stepped round-robin. Dispatch is **prefix-affine**: every
//! replica's radix index is probed for the incoming prompt and the
//! request steers to the replica holding the longest cached prefix — a
//! request that lands where its prefix pages already live skips
//! re-prefilling and re-storing them, and joins that replica's cascade
//! groups. Ties (including the all-cold case) break round-robin so load
//! still spreads.
//!
//! **Load valve.** Affinity outranks load only while load is sane: a
//! replica whose waiting queue exceeds the shortest queue by more than
//! the queue-skew cap ([`Router::with_queue_skew_cap`], default
//! [`DEFAULT_QUEUE_SKEW_CAP`]) is excluded from the affinity choice, so
//! one hot prefix cannot concentrate unboundedly on its warm replica —
//! under pressure the request pays the one-time re-prefill on a cooler
//! replica (which then warms its own copy of the prefix) instead of
//! queueing behind the herd. With one replica this degrades to a thin
//! queue — the structure matters for the scheduling tests and for
//! swapping in a process-per-replica transport later.

use anyhow::Result;

use crate::obs::TimelineRecorder;

use super::engine::Engine;
use super::metrics::Metrics;
use super::request::{FinishedRequest, RequestId};

/// Prefix-affinity dispatcher over engine replicas.
pub struct Router {
    engines: Vec<Engine>,
    /// (engine index, id within engine) per external request id.
    routes: Vec<(usize, RequestId)>,
    /// Round-robin cursor for prefix-length ties.
    rr: usize,
    /// Load valve: replicas whose waiting queue exceeds the shortest
    /// queue by more than this are excluded from the affinity choice.
    queue_skew_cap: usize,
}

/// Default waiting-queue skew before affinity loses to load.
pub const DEFAULT_QUEUE_SKEW_CAP: usize = 4;

/// Pick the replica holding the longest cached prefix; break ties
/// (including "nobody holds anything") round-robin via `rr`. Pure so the
/// policy is unit-testable without engines.
pub fn route_by_prefix(prefix_tokens: &[usize], rr: &mut usize) -> usize {
    let zeros = vec![0usize; prefix_tokens.len()];
    route_by_prefix_with_load(prefix_tokens, &zeros, usize::MAX, rr)
}

/// Prefix affinity with the load valve: only replicas whose waiting
/// queue is within `max_skew` of the shortest queue are eligible, and
/// among those the longest cached prefix wins (round-robin on ties).
/// `max_skew = usize::MAX` disables the valve and recovers
/// [`route_by_prefix`]. Pure so the policy is unit-testable without
/// engines.
pub fn route_by_prefix_with_load(
    prefix_tokens: &[usize],
    queue_lens: &[usize],
    max_skew: usize,
    rr: &mut usize,
) -> usize {
    assert!(!prefix_tokens.is_empty());
    assert_eq!(prefix_tokens.len(), queue_lens.len());
    let min_q = queue_lens.iter().copied().min().unwrap();
    let cap = min_q.saturating_add(max_skew);
    let best = prefix_tokens
        .iter()
        .zip(queue_lens)
        .filter(|&(_, &q)| q <= cap)
        .map(|(&p, _)| p)
        .max()
        .expect("the min-queue replica is always eligible");
    let tied: Vec<usize> = (0..prefix_tokens.len())
        .filter(|&i| queue_lens[i] <= cap && prefix_tokens[i] == best)
        .collect();
    let pick = tied[*rr % tied.len()];
    *rr += 1;
    pick
}

impl Router {
    pub fn new(engines: Vec<Engine>) -> Router {
        assert!(!engines.is_empty());
        Router {
            engines,
            routes: Vec::new(),
            rr: 0,
            queue_skew_cap: DEFAULT_QUEUE_SKEW_CAP,
        }
    }

    /// Override the load valve's queue-skew cap (`usize::MAX` restores
    /// unconditional prefix affinity).
    pub fn with_queue_skew_cap(mut self, cap: usize) -> Router {
        self.queue_skew_cap = cap;
        self
    }

    pub fn num_replicas(&self) -> usize {
        self.engines.len()
    }

    /// Probe every replica's radix index and submit to the one holding
    /// the longest cached prefix among replicas within the load valve's
    /// queue-skew cap (round-robin tiebreak). Returns a router-level id.
    pub fn submit(&mut self, prompt: Vec<i32>, max_new: usize) -> Result<RequestId> {
        let matched: Vec<usize> = self
            .engines
            .iter()
            .map(|e| e.peek_prefix_tokens(&prompt))
            .collect();
        let queues: Vec<usize> = self.engines.iter().map(|e| e.waiting()).collect();
        let ei = route_by_prefix_with_load(
            &matched,
            &queues,
            self.queue_skew_cap,
            &mut self.rr,
        );
        let inner = self.engines[ei].submit(prompt, max_new)?;
        self.routes.push((ei, inner));
        Ok(self.routes.len() as RequestId - 1)
    }

    /// The replica a router-level request was dispatched to.
    pub fn route_of(&self, id: RequestId) -> Option<usize> {
        self.routes.get(id as usize).map(|&(e, _)| e)
    }

    /// Step every replica once; collect finished requests (with router
    /// ids rewritten).
    pub fn step_all(&mut self) -> Result<Vec<FinishedRequest>> {
        let mut out = Vec::new();
        for ei in 0..self.engines.len() {
            for mut f in self.engines[ei].step()? {
                if let Some(router_id) = self
                    .routes
                    .iter()
                    .position(|&(e, id)| e == ei && id == f.id)
                {
                    f.id = router_id as RequestId;
                }
                out.push(f);
            }
        }
        Ok(out)
    }

    pub fn is_idle(&self) -> bool {
        self.engines.iter().all(|e| e.is_idle())
    }

    /// Drive all replicas until idle.
    pub fn run_until_idle(&mut self) -> Result<Vec<FinishedRequest>> {
        let mut all = Vec::new();
        while !self.is_idle() {
            all.extend(self.step_all()?);
        }
        Ok(all)
    }

    pub fn engines(&self) -> &[Engine] {
        &self.engines
    }

    /// Fleet-wide serving counters: every replica's [`Metrics`] folded
    /// into one (histograms merge exactly, so fleet percentiles are as
    /// tight as any single replica's).
    pub fn merged_metrics(&self) -> Metrics {
        let mut m = Metrics::default();
        for e in &self.engines {
            m.merge(&e.metrics);
        }
        m
    }

    /// Fleet-wide request lifecycles — the recorder the serving SLO
    /// report aggregates across replicas.
    pub fn merged_timelines(&self) -> TimelineRecorder {
        let mut t = TimelineRecorder::default();
        for e in &self.engines {
            t.merge(&e.timelines);
        }
        t
    }
}

// Engine-driving integration tests live in rust/tests/engine_e2e.rs
// (they need artifacts); the routing policy itself is pure and tested
// here.
#[cfg(test)]
mod tests {
    use super::{route_by_prefix, route_by_prefix_with_load};

    #[test]
    fn longest_prefix_wins_regardless_of_cursor() {
        for start in 0..5usize {
            let mut rr = start;
            // Replica 2 holds the longest cached prefix.
            assert_eq!(route_by_prefix(&[0, 16, 48, 16], &mut rr), 2);
        }
    }

    #[test]
    fn same_prefix_requests_colocate() {
        // Once one replica holds the prefix, every later probe returns a
        // unique maximum there — same-prefix requests stick together
        // while the rr cursor keeps moving.
        let mut rr = 0;
        let after_warm = [32usize, 0, 0];
        for _ in 0..6 {
            assert_eq!(route_by_prefix(&after_warm, &mut rr), 0);
        }
        assert_eq!(rr, 6, "cursor advances even on affinity hits");
    }

    #[test]
    fn cold_prompts_round_robin() {
        let mut rr = 0;
        let cold = [0usize, 0, 0];
        let picks: Vec<usize> =
            (0..6).map(|_| route_by_prefix(&cold, &mut rr)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn ties_cycle_only_the_tied_set() {
        let mut rr = 0;
        // Replicas 1 and 2 tie at 16 tokens; 0 is cold.
        let picks: Vec<usize> =
            (0..4).map(|_| route_by_prefix(&[0, 16, 16], &mut rr)).collect();
        assert_eq!(picks, vec![1, 2, 1, 2]);
    }

    #[test]
    fn valve_overrides_affinity_when_queue_skew_exceeds_the_cap() {
        // Replica 0 is warm (48 cached tokens) but its queue is 6 deep
        // vs 0 elsewhere: with cap 4 it is ineligible, and the request
        // routes to the best *eligible* replica instead.
        let mut rr = 0;
        let pick = route_by_prefix_with_load(&[48, 16, 0], &[6, 0, 0], 4, &mut rr);
        assert_eq!(pick, 1, "warmest eligible replica wins");
        // Once the hot replica's queue drains within the cap, affinity
        // returns to it.
        let pick = route_by_prefix_with_load(&[48, 16, 0], &[4, 0, 0], 4, &mut rr);
        assert_eq!(pick, 0);
    }

    #[test]
    fn valve_respects_skew_relative_to_the_minimum_queue() {
        // Every queue is deep but balanced: nobody is excluded.
        let mut rr = 0;
        let pick = route_by_prefix_with_load(&[0, 32, 0], &[100, 103, 101], 4, &mut rr);
        assert_eq!(pick, 1, "uniform pressure leaves affinity in charge");
        // Skew beyond the cap on the warm replica flips the choice.
        let pick = route_by_prefix_with_load(&[0, 32, 0], &[100, 105, 100], 4, &mut rr);
        assert_ne!(pick, 1);
    }

    #[test]
    fn valve_ties_among_eligible_replicas_round_robin() {
        let mut rr = 0;
        // Replica 2 is overloaded; 0 and 1 tie cold.
        let picks: Vec<usize> = (0..4)
            .map(|_| route_by_prefix_with_load(&[0, 0, 64], &[0, 0, 9], 4, &mut rr))
            .collect();
        assert_eq!(picks, vec![0, 1, 0, 1]);
    }

    #[test]
    fn unbounded_cap_recovers_plain_prefix_affinity() {
        for (prefixes, queues) in [
            (vec![0usize, 16, 48, 16], vec![9usize, 0, 7, 3]),
            (vec![5, 5, 5], vec![0, 100, 0]),
        ] {
            let mut a = 2;
            let mut b = 2;
            assert_eq!(
                route_by_prefix_with_load(&prefixes, &queues, usize::MAX, &mut a),
                route_by_prefix(&prefixes, &mut b),
            );
        }
    }
}
