//! Multi-replica request router (the vLLM-router-shaped front door).
//!
//! PJRT handles are not `Send`, so replicas live on the router's thread
//! and are stepped round-robin; dispatch is least-loaded (fewest waiting,
//! then fewest active). With one replica this degrades to a thin queue —
//! the structure matters for the scheduling tests and for swapping in a
//! process-per-replica transport later.

use anyhow::Result;

use super::engine::Engine;
use super::request::{FinishedRequest, RequestId};

/// Least-loaded dispatcher over engine replicas.
pub struct Router {
    engines: Vec<Engine>,
    /// (engine index, id within engine) per external request id.
    routes: Vec<(usize, RequestId)>,
}

impl Router {
    pub fn new(engines: Vec<Engine>) -> Router {
        assert!(!engines.is_empty());
        Router { engines, routes: Vec::new() }
    }

    pub fn num_replicas(&self) -> usize {
        self.engines.len()
    }

    /// Pick the least-loaded replica and submit. Returns a router-level id.
    pub fn submit(&mut self, prompt: Vec<i32>, max_new: usize) -> Result<RequestId> {
        let (ei, _) = self
            .engines
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| (e.waiting(), e.active()))
            .unwrap();
        let inner = self.engines[ei].submit(prompt, max_new)?;
        self.routes.push((ei, inner));
        Ok(self.routes.len() as RequestId - 1)
    }

    /// Step every replica once; collect finished requests (with router
    /// ids rewritten).
    pub fn step_all(&mut self) -> Result<Vec<FinishedRequest>> {
        let mut out = Vec::new();
        for ei in 0..self.engines.len() {
            for mut f in self.engines[ei].step()? {
                if let Some(router_id) = self
                    .routes
                    .iter()
                    .position(|&(e, id)| e == ei && id == f.id)
                {
                    f.id = router_id as RequestId;
                }
                out.push(f);
            }
        }
        Ok(out)
    }

    pub fn is_idle(&self) -> bool {
        self.engines.iter().all(|e| e.is_idle())
    }

    /// Drive all replicas until idle.
    pub fn run_until_idle(&mut self) -> Result<Vec<FinishedRequest>> {
        let mut all = Vec::new();
        while !self.is_idle() {
            all.extend(self.step_all()?);
        }
        Ok(all)
    }

    pub fn engines(&self) -> &[Engine] {
        &self.engines
    }
}

// Integration tests in rust/tests/engine_e2e.rs (need artifacts).
