//! Fork-join worker pool on std threads (tokio is not in the offline
//! crate cache). Used for the host-math stream-K execution path, where a
//! pool of workers stands in for the GPU's SMs: each worker drains CTA
//! work items, computes partials with the Rust oracle, and the caller
//! reduces — the same topology the CUDA kernel realizes on hardware.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Map `f` over `items` with `workers` threads, preserving order.
pub fn parallel_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return items.into_iter().map(f).collect();
    }

    let inputs: Vec<Mutex<Option<T>>> =
        items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let outputs: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = inputs[i].lock().unwrap().take().unwrap();
                let r = f(item);
                *outputs[i].lock().unwrap() = Some(r);
            });
        }
    });

    outputs
        .into_iter()
        .map(|m| m.into_inner().unwrap().unwrap())
        .collect()
}

/// Execute a partition plan's CTAs on a worker pool with host math —
/// the multi-core analogue of the kernel's SM dispatch. Returns the exact
/// attention output; see `partition::host_exec` for the sequential twin.
pub fn execute_plan_host_parallel(
    plan: &crate::partition::Plan,
    problem: &crate::partition::DecodeProblem,
    t: &crate::partition::host_exec::HostTensors,
    workers: usize,
) -> Vec<f32> {
    use crate::attention::{partial_attention_host, Partials};

    let g = problem.groups();
    let d = problem.head_dim;
    let tile = plan.tile;
    let lens = t.group_lens(problem);

    // Phase 1 (parallel): each CTA computes its partials.
    let cta_parts: Vec<Vec<(usize, Partials)>> = parallel_map(
        plan.ctas.iter().collect::<Vec<_>>(),
        workers,
        |cta| {
            cta.segments
                .iter()
                .map(|seg| {
                    let gi = seg.group as usize;
                    let start = seg.tile_begin as usize * tile;
                    let end = ((seg.tile_begin + seg.tile_count) as usize * tile)
                        .min(t.n_max);
                    let k = &t.k[gi * t.n_max * d + start * d
                        ..gi * t.n_max * d + end * d];
                    let v = &t.v[gi * t.n_max * d + start * d
                        ..gi * t.n_max * d + end * d];
                    let q = &t.q[gi * d..(gi + 1) * d];
                    (
                        gi,
                        partial_attention_host(
                            q,
                            k,
                            v,
                            1,
                            end - start,
                            d,
                            &[lens[gi]],
                            start,
                        ),
                    )
                })
                .collect()
        },
    );

    // Phase 2 (sequential): host-side reduction per group.
    let mut accs: Vec<Partials> = (0..g).map(|_| Partials::identity(1, d)).collect();
    for parts in &cta_parts {
        for (gi, p) in parts {
            accs[*gi].reduce_from(p);
        }
    }
    let mut out = vec![0.0f32; g * d];
    for (gi, acc) in accs.into_iter().enumerate() {
        out[gi * d..(gi + 1) * d].copy_from_slice(&acc.finalize());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::attention_host;
    use crate::partition::host_exec::HostTensors;
    use crate::partition::plan::{build_plan, DecodeProblem, Strategy};
    use crate::util::testing::max_abs_err;

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map((0..100).collect(), 4, |x: i32| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_empty_and_single() {
        let empty: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |x| x);
        assert!(empty.is_empty());
        assert_eq!(parallel_map(vec![7], 8, |x: i32| x + 1), vec![8]);
    }

    #[test]
    fn parallel_plan_execution_matches_direct() {
        let problem = DecodeProblem::uniform(2, 3, 900, 32).with_tile(64);
        let t = HostTensors::random(&problem, 11);
        let want = attention_host(
            &t.q,
            &t.k,
            &t.v,
            problem.groups(),
            t.n_max,
            32,
            &t.group_lens(&problem),
        );
        for workers in [1usize, 2, 4] {
            let plan = build_plan(&problem, Strategy::StreamK, 16);
            let got = execute_plan_host_parallel(&plan, &problem, &t, workers);
            assert!(max_abs_err(&got, &want) < 1e-4, "workers={workers}");
        }
    }
}
