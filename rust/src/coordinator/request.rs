//! Request lifecycle types for the decode-serving engine.

use std::time::Instant;

use crate::obs::RequestTimeline;
use crate::sampling::SamplingParams;

/// Monotonic request identifier.
pub type RequestId = u64;

/// An inference request: a tokenized prompt plus generation budget and
/// logits-processing parameters.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub arrival: Instant,
    /// Logits pipeline for this request (greedy by default).
    pub params: SamplingParams,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<i32>, max_new_tokens: usize) -> Request {
        assert!(!prompt.is_empty(), "empty prompt");
        assert!(max_new_tokens >= 1);
        Request {
            id,
            prompt,
            max_new_tokens,
            arrival: Instant::now(),
            params: SamplingParams::default(),
        }
    }

    /// Attach non-default sampling parameters.
    pub fn with_params(mut self, params: SamplingParams) -> Request {
        self.params = params;
        self
    }
}

/// Why a sequence stopped generating.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Hit its `max_new_tokens` budget.
    Length,
    /// Hit the model's context bucket (cache full).
    ContextFull,
    /// Cancelled mid-generation (beam pruning); the output is partial.
    Cancelled,
}

/// A completed request with its generation, scores and timing.
#[derive(Clone, Debug)]
pub struct FinishedRequest {
    pub id: RequestId,
    pub prompt_len: usize,
    pub output: Vec<i32>,
    pub reason: FinishReason,
    /// Queue time until prefill started, seconds.
    pub queue_s: f64,
    /// Time from prefill start to first token, seconds.
    pub prefill_s: f64,
    /// Time spent decoding, seconds.
    pub decode_s: f64,
    /// Sum of the sampled tokens' logprobs under the processed
    /// distribution (the candidate score for best-of-n / beam search).
    pub cum_logprob: f64,
    /// Per-token logprob trace, one entry per `output` token,
    /// reproducible by the `sampling::sample_token` oracle.
    pub logprobs: Vec<f32>,
    /// The sequence this one was forked off, if any.
    pub parent: Option<RequestId>,
}

impl FinishedRequest {
    pub fn total_s(&self) -> f64 {
        self.queue_s + self.prefill_s + self.decode_s
    }

    /// Decode throughput in tokens/s (excluding prefill).
    pub fn decode_tps(&self) -> f64 {
        if self.decode_s <= 0.0 {
            0.0
        } else {
            self.output.len() as f64 / self.decode_s
        }
    }

    /// This request's lifecycle timeline, the unit the observability
    /// plane's [`crate::obs::TimelineRecorder`] aggregates.
    pub fn timeline(&self) -> RequestTimeline {
        RequestTimeline {
            id: self.id,
            queue_us: self.queue_s * 1e6,
            prefill_us: self.prefill_s * 1e6,
            decode_us: self.decode_s * 1e6,
            tokens: self.output.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_construction() {
        let r = Request::new(1, vec![1, 2, 3], 8);
        assert_eq!(r.prompt.len(), 3);
        assert!(r.params.is_greedy(), "default sampling is greedy");
        let r = r.with_params(SamplingParams::stochastic(0.7));
        assert!(!r.params.is_greedy());
    }

    #[test]
    #[should_panic(expected = "empty prompt")]
    fn empty_prompt_rejected() {
        Request::new(1, vec![], 8);
    }

    #[test]
    fn finished_request_stats() {
        let f = FinishedRequest {
            id: 1,
            prompt_len: 4,
            output: vec![5, 6, 7, 8],
            reason: FinishReason::Length,
            queue_s: 0.1,
            prefill_s: 0.2,
            decode_s: 2.0,
            cum_logprob: -2.0,
            logprobs: vec![-0.5; 4],
            parent: None,
        };
        assert!((f.total_s() - 2.3).abs() < 1e-12);
        assert!((f.decode_tps() - 2.0).abs() < 1e-12);
        let trace_sum: f64 = f.logprobs.iter().map(|&x| f64::from(x)).sum();
        assert!((f.cum_logprob - trace_sum).abs() < 1e-9);

        let tl = f.timeline();
        assert_eq!(tl.id, 1);
        assert_eq!(tl.tokens, 4);
        assert!((tl.queue_us - 0.1e6).abs() < 1e-6);
        assert!((tl.e2e_us() - f.total_s() * 1e6).abs() < 1e-3);
    }
}
