//! Paged KV cache with block tables (the vLLM/FlashInfer storage model,
//! built as a substrate for the serving engine).
//!
//! Storage unit is a **page** of `page_tokens` tokens holding all layers
//! and heads: `[layers, heads, page_tokens, head_dim]` f32, one buffer for
//! K and one for V. Sequences own ordered page lists; the engine gathers
//! a sequence's pages into the contiguous `[l, b, h, ctx_bucket, dh]`
//! views the decode artifact consumes (the CPU-PJRT analogue of the
//! paper's constant-stride tensor requirement, §IV-C).
//!
//! Pages are **reference-counted** so the radix prefix index
//! ([`super::radix`]) can share one physical copy of a common prefix
//! across many sequences (cascade/shared-prefix serving). Writes go
//! through **copy-on-write**: appending into a page another holder still
//! references first clones it, so a shared prefix is immutable in place.
//! A page returns to the free list only when its last reference drops —
//! the refcount invariants (no leak, no double free, eviction only at
//! zero) are property-tested in `rust/tests/kv_cache_props.rs`.

use anyhow::{bail, ensure, Result};
use std::collections::HashMap;

use super::request::RequestId;

/// Paged K/V storage for many sequences.
pub struct PagedKvCache {
    pub layers: usize,
    pub heads: usize,
    pub head_dim: usize,
    pub page_tokens: usize,
    k_pages: Vec<Vec<f32>>,
    v_pages: Vec<Vec<f32>>,
    /// Holders per page: sequences + the prefix index. 0 = free.
    ref_counts: Vec<u32>,
    free: Vec<usize>,
    seqs: HashMap<RequestId, SeqEntry>,
}

struct SeqEntry {
    pages: Vec<usize>,
    len: usize,
}

impl PagedKvCache {
    /// Allocate a cache with a fixed budget of `num_pages` pages.
    pub fn new(
        layers: usize,
        heads: usize,
        head_dim: usize,
        page_tokens: usize,
        num_pages: usize,
    ) -> PagedKvCache {
        let page_elems = layers * heads * page_tokens * head_dim;
        PagedKvCache {
            layers,
            heads,
            head_dim,
            page_tokens,
            k_pages: (0..num_pages).map(|_| vec![0.0; page_elems]).collect(),
            v_pages: (0..num_pages).map(|_| vec![0.0; page_elems]).collect(),
            ref_counts: vec![0; num_pages],
            free: (0..num_pages).rev().collect(),
            seqs: HashMap::new(),
        }
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn total_pages(&self) -> usize {
        self.k_pages.len()
    }

    pub fn used_pages(&self) -> usize {
        self.total_pages() - self.free.len()
    }

    /// K+V bytes held by one page (f32 host storage).
    pub fn page_bytes(&self) -> usize {
        2 * self.layers * self.heads * self.page_tokens * self.head_dim
            * std::mem::size_of::<f32>()
    }

    pub fn seq_len(&self, id: RequestId) -> Option<usize> {
        self.seqs.get(&id).map(|s| s.len)
    }

    /// A sequence's in-order physical page list.
    pub fn seq_pages(&self, id: RequestId) -> Option<&[usize]> {
        self.seqs.get(&id).map(|s| s.pages.as_slice())
    }

    /// Current holder count of a page (0 = free).
    pub fn page_ref(&self, page: usize) -> u32 {
        self.ref_counts.get(page).copied().unwrap_or(0)
    }

    /// Pages needed to hold `tokens` tokens.
    pub fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_tokens)
    }

    /// Whether a sequence of `tokens` tokens can currently be admitted.
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.pages_for(tokens) <= self.free.len()
    }

    fn alloc_page(&mut self) -> Option<usize> {
        let p = self.free.pop()?;
        debug_assert_eq!(self.ref_counts[p], 0);
        self.ref_counts[p] = 1;
        Some(p)
    }

    /// Take an additional reference on a live page (prefix index or a
    /// sequence sharing a cached prefix).
    pub fn retain_page(&mut self, page: usize) -> Result<()> {
        ensure!(page < self.total_pages(), "retain of page {page} out of range");
        ensure!(self.ref_counts[page] > 0, "retain of unallocated page {page}");
        self.ref_counts[page] += 1;
        Ok(())
    }

    /// Drop one reference; the page returns to the free list only when
    /// the count reaches zero. Returns whether the page was freed.
    pub fn release_page(&mut self, page: usize) -> Result<bool> {
        ensure!(page < self.total_pages(), "release of page {page} out of range");
        ensure!(
            self.ref_counts[page] > 0,
            "double free of page {page} (refcount already 0)"
        );
        self.ref_counts[page] -= 1;
        if self.ref_counts[page] == 0 {
            self.free.push(page);
            return Ok(true);
        }
        Ok(false)
    }

    /// Register a new sequence and copy in its prefill K/V
    /// (`[layers, heads, len, head_dim]` row-major per tensor).
    pub fn insert_seq(&mut self, id: RequestId, k: &[f32], v: &[f32], len: usize) -> Result<()> {
        ensure!(!self.seqs.contains_key(&id), "sequence {id} already cached");
        let plane = self.heads * self.head_dim;
        ensure!(k.len() == self.layers * plane * len, "prefill k size");
        ensure!(v.len() == k.len(), "prefill v size");
        let need = self.pages_for(len.max(1));
        if need > self.free.len() {
            bail!("cache full: need {need} pages, {} free", self.free.len());
        }
        let pages: Vec<usize> = (0..need).map(|_| self.alloc_page().unwrap()).collect();
        let mut entry = SeqEntry { pages, len: 0 };
        let (heads, dh) = (self.heads, self.head_dim);
        for t in 0..len {
            self.write_token(&mut entry, t, |l, h| {
                let base = (l * heads + h) * len * dh + t * dh;
                (&k[base..base + dh], &v[base..base + dh])
            });
        }
        entry.len = len;
        self.seqs.insert(id, entry);
        Ok(())
    }

    /// Register a new sequence whose first `shared.len() * page_tokens`
    /// tokens live in already-cached (prefix index) pages. The sequence
    /// takes one reference per shared page; only the suffix K/V
    /// (`[layers, heads, suffix_len, head_dim]`, the tokens *after* the
    /// shared prefix) is written into freshly allocated pages.
    pub fn insert_seq_shared(
        &mut self,
        id: RequestId,
        shared: &[usize],
        k_suffix: &[f32],
        v_suffix: &[f32],
        suffix_len: usize,
    ) -> Result<()> {
        ensure!(!self.seqs.contains_key(&id), "sequence {id} already cached");
        let plane = self.heads * self.head_dim;
        ensure!(k_suffix.len() == self.layers * plane * suffix_len, "suffix k size");
        ensure!(v_suffix.len() == k_suffix.len(), "suffix v size");
        for &p in shared {
            ensure!(p < self.total_pages(), "shared page {p} out of range");
            ensure!(self.ref_counts[p] > 0, "shared page {p} is not live");
        }
        let shared_tokens = shared.len() * self.page_tokens;
        let total = shared_tokens + suffix_len;
        ensure!(total >= 1, "empty sequence");
        let need = self.pages_for(total.max(1)) - shared.len();
        if need > self.free.len() {
            bail!("cache full: need {need} pages, {} free", self.free.len());
        }

        for &p in shared {
            self.ref_counts[p] += 1;
        }
        let mut pages = shared.to_vec();
        pages.extend((0..need).map(|_| self.alloc_page().unwrap()));
        let mut entry = SeqEntry { pages, len: 0 };
        let (heads, dh) = (self.heads, self.head_dim);
        for s in 0..suffix_len {
            // Absolute position: suffix token `s` lands after the shared
            // prefix, which is page-aligned by construction.
            self.write_token(&mut entry, shared_tokens + s, |l, h| {
                let base = (l * heads + h) * suffix_len * dh + s * dh;
                (&k_suffix[base..base + dh], &v_suffix[base..base + dh])
            });
        }
        entry.len = total;
        self.seqs.insert(id, entry);
        Ok(())
    }

    /// Append one token's K/V rows (`[layers, heads, head_dim]` each).
    /// Returns whether a copy-on-write page clone happened (the target
    /// page was shared with another holder).
    pub fn append_token(&mut self, id: RequestId, k: &[f32], v: &[f32]) -> Result<bool> {
        let plane = self.layers * self.heads * self.head_dim;
        ensure!(k.len() == plane, "append k size");
        ensure!(v.len() == plane, "append v size");
        let mut entry = self.seqs.remove(&id).ok_or_else(|| {
            anyhow::anyhow!("sequence {id} not cached")
        })?;
        let t = entry.len;
        let mut cow = false;
        if t >= entry.pages.len() * self.page_tokens {
            let Some(p) = self.alloc_page() else {
                self.seqs.insert(id, entry);
                bail!("cache full appending to sequence {id}");
            };
            entry.pages.push(p);
        } else {
            // Writing into an existing page: if anyone else holds it,
            // clone first so the shared copy stays immutable.
            let pi = t / self.page_tokens;
            let page = entry.pages[pi];
            if self.ref_counts[page] > 1 {
                let Some(fresh) = self.alloc_page() else {
                    self.seqs.insert(id, entry);
                    bail!("cache full (copy-on-write) appending to sequence {id}");
                };
                copy_page(&mut self.k_pages, page, fresh);
                copy_page(&mut self.v_pages, page, fresh);
                self.ref_counts[page] -= 1; // still >= 1: not freed
                entry.pages[pi] = fresh;
                cow = true;
            }
        }
        let (heads, dh) = (self.heads, self.head_dim);
        self.write_token(&mut entry, t, |l, h| {
            let base = (l * heads + h) * dh;
            (&k[base..base + dh], &v[base..base + dh])
        });
        entry.len = t + 1;
        self.seqs.insert(id, entry);
        Ok(cow)
    }

    fn write_token<'a>(
        &mut self,
        entry: &mut SeqEntry,
        t: usize,
        src: impl Fn(usize, usize) -> (&'a [f32], &'a [f32]),
    ) {
        let page = entry.pages[t / self.page_tokens];
        let slot = t % self.page_tokens;
        let dh = self.head_dim;
        for l in 0..self.layers {
            for h in 0..self.heads {
                let off = ((l * self.heads + h) * self.page_tokens + slot) * dh;
                let (ks, vs) = src(l, h);
                self.k_pages[page][off..off + dh].copy_from_slice(ks);
                self.v_pages[page][off..off + dh].copy_from_slice(vs);
            }
        }
    }

    /// Gather a batch of sequences into contiguous decode-artifact views
    /// `[layers, batch, heads, ctx_bucket, head_dim]` (zero-padded).
    /// `slots[i] = Some(request)` maps batch lane `i` to a sequence.
    pub fn gather(
        &self,
        slots: &[Option<RequestId>],
        ctx_bucket: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) -> Result<()> {
        let b = slots.len();
        let dh = self.head_dim;
        let expect = self.layers * b * self.heads * ctx_bucket * dh;
        ensure!(k_out.len() == expect, "k_out size");
        ensure!(v_out.len() == expect, "v_out size");
        k_out.fill(0.0);
        v_out.fill(0.0);
        for (bi, slot) in slots.iter().enumerate() {
            let Some(id) = slot else { continue };
            let entry = self
                .seqs
                .get(id)
                .ok_or_else(|| anyhow::anyhow!("sequence {id} not cached"))?;
            ensure!(entry.len <= ctx_bucket, "sequence longer than ctx bucket");
            for l in 0..self.layers {
                for h in 0..self.heads {
                    let dst_base =
                        (((l * b) + bi) * self.heads + h) * ctx_bucket * dh;
                    // copy page by page
                    for (pi, &page) in entry.pages.iter().enumerate() {
                        let t0 = pi * self.page_tokens;
                        if t0 >= entry.len {
                            break;
                        }
                        let count = self.page_tokens.min(entry.len - t0);
                        let src_base =
                            ((l * self.heads + h) * self.page_tokens) * dh;
                        let dst = dst_base + t0 * dh;
                        k_out[dst..dst + count * dh].copy_from_slice(
                            &self.k_pages[page][src_base..src_base + count * dh],
                        );
                        v_out[dst..dst + count * dh].copy_from_slice(
                            &self.v_pages[page][src_base..src_base + count * dh],
                        );
                    }
                }
            }
        }
        Ok(())
    }

    /// Release a sequence's references; pages with no other holder (e.g.
    /// the prefix index) return to the free list.
    pub fn free_seq(&mut self, id: RequestId) {
        if let Some(entry) = self.seqs.remove(&id) {
            for page in entry.pages {
                // A sequence's pages are live by construction.
                let _ = self.release_page(page);
            }
        }
    }
}

/// Copy one page buffer over another without a temporary allocation
/// (split borrows around the larger index; `src != dst` by construction —
/// the destination comes off the free list while the source is live).
fn copy_page(pages: &mut [Vec<f32>], src: usize, dst: usize) {
    debug_assert_ne!(src, dst);
    if src < dst {
        let (lo, hi) = pages.split_at_mut(dst);
        hi[0].copy_from_slice(&lo[src]);
    } else {
        let (lo, hi) = pages.split_at_mut(src);
        lo[dst].copy_from_slice(&hi[0]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cache() -> PagedKvCache {
        PagedKvCache::new(2, 3, 4, 8, 16)
    }

    fn rows(rng: &mut Rng, layers: usize, heads: usize, len: usize, dh: usize) -> Vec<f32> {
        rng.normal_vec(layers * heads * len * dh)
    }

    #[test]
    fn insert_gather_round_trip() {
        let mut c = cache();
        let mut rng = Rng::new(1);
        let len = 13; // crosses a page boundary (page=8)
        let k = rows(&mut rng, 2, 3, len, 4);
        let v = rows(&mut rng, 2, 3, len, 4);
        c.insert_seq(7, &k, &v, len).unwrap();
        assert_eq!(c.seq_len(7), Some(13));
        assert_eq!(c.free_pages(), 16 - 2);

        let ctx = 16;
        let mut ko = vec![0.0; 2 * 1 * 3 * ctx * 4];
        let mut vo = vec![0.0; ko.len()];
        c.gather(&[Some(7)], ctx, &mut ko, &mut vo).unwrap();
        // spot-check token t=9, layer 1, head 2
        let (l, h, t) = (1usize, 2usize, 9usize);
        let src = (l * 3 + h) * len * 4 + t * 4;
        let dst = ((l * 1) * 3 + h) * ctx * 4 + t * 4;
        assert_eq!(&ko[dst..dst + 4], &k[src..src + 4]);
        assert_eq!(&vo[dst..dst + 4], &v[src..src + 4]);
        // padding is zero
        let pad = ((0 * 1) * 3 + 0) * ctx * 4 + 15 * 4;
        assert_eq!(&ko[pad..pad + 4], &[0.0; 4]);
    }

    #[test]
    fn append_token_and_page_growth() {
        let mut c = cache();
        let mut rng = Rng::new(2);
        let k = rows(&mut rng, 2, 3, 8, 4);
        let v = rows(&mut rng, 2, 3, 8, 4);
        c.insert_seq(1, &k, &v, 8).unwrap(); // exactly one page
        assert_eq!(c.free_pages(), 15);
        let nk = rng.normal_vec(2 * 3 * 4);
        let nv = rng.normal_vec(2 * 3 * 4);
        let cow = c.append_token(1, &nk, &nv).unwrap(); // forces a second page
        assert!(!cow, "fresh page, no copy-on-write");
        assert_eq!(c.free_pages(), 14);
        assert_eq!(c.seq_len(1), Some(9));

        let mut ko = vec![0.0; 2 * 1 * 3 * 16 * 4];
        let mut vo = vec![0.0; ko.len()];
        c.gather(&[Some(1)], 16, &mut ko, &mut vo).unwrap();
        // token 8 row for layer 0 head 1
        let dst = ((0 * 1) * 3 + 1) * 16 * 4 + 8 * 4;
        assert_eq!(&ko[dst..dst + 4], &nk[4..8]);
    }

    #[test]
    fn free_seq_returns_pages() {
        let mut c = cache();
        let mut rng = Rng::new(3);
        let k = rows(&mut rng, 2, 3, 20, 4);
        let v = rows(&mut rng, 2, 3, 20, 4);
        c.insert_seq(5, &k, &v, 20).unwrap();
        let used = 16 - c.free_pages();
        assert_eq!(used, 3); // ceil(20/8)
        c.free_seq(5);
        assert_eq!(c.free_pages(), 16);
        assert_eq!(c.seq_len(5), None);
    }

    #[test]
    fn admission_control() {
        let mut c = cache();
        assert!(c.can_admit(16 * 8));
        assert!(!c.can_admit(16 * 8 + 1));
        let mut rng = Rng::new(4);
        let k = rows(&mut rng, 2, 3, 100, 4);
        let v = rows(&mut rng, 2, 3, 100, 4);
        c.insert_seq(1, &k, &v, 100).unwrap(); // 13 pages
        assert!(!c.can_admit(8 * 4)); // only 3 pages left
        let err = c.insert_seq(2, &k, &v, 100).unwrap_err();
        assert!(err.to_string().contains("cache full"));
    }

    #[test]
    fn cache_full_append_is_recoverable() {
        let mut c = PagedKvCache::new(1, 1, 2, 2, 1);
        c.insert_seq(1, &[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0], 2)
            .unwrap();
        let err = c.append_token(1, &[9.0, 9.0], &[9.0, 9.0]).unwrap_err();
        assert!(err.to_string().contains("cache full"));
        // sequence still intact
        assert_eq!(c.seq_len(1), Some(2));
    }

    #[test]
    fn gather_multi_batch_lanes() {
        let mut c = cache();
        let mut rng = Rng::new(5);
        for id in 0..3u64 {
            let len = 4 + id as usize;
            let k = rows(&mut rng, 2, 3, len, 4);
            let v = rows(&mut rng, 2, 3, len, 4);
            c.insert_seq(id, &k, &v, len).unwrap();
        }
        let mut ko = vec![0.0; 2 * 4 * 3 * 8 * 4];
        let mut vo = vec![0.0; ko.len()];
        c.gather(&[Some(2), None, Some(0), Some(1)], 8, &mut ko, &mut vo)
            .unwrap();
        // lane 1 is empty -> zeros
        let lane1 = ((0 * 4 + 1) * 3) * 8 * 4;
        assert!(ko[lane1..lane1 + 8 * 4].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn shared_prefix_dedups_pages() {
        let mut c = cache();
        let mut rng = Rng::new(6);
        // Seq 1 owns a 16-token (2-page) prompt.
        let k = rows(&mut rng, 2, 3, 16, 4);
        let v = rows(&mut rng, 2, 3, 16, 4);
        c.insert_seq(1, &k, &v, 16).unwrap();
        let shared: Vec<usize> = c.seq_pages(1).unwrap().to_vec();
        assert_eq!(c.used_pages(), 2);

        // Seq 2 shares both pages and adds a 5-token suffix (1 new page).
        let ks = rows(&mut rng, 2, 3, 5, 4);
        let vs = rows(&mut rng, 2, 3, 5, 4);
        c.insert_seq_shared(2, &shared, &ks, &vs, 5).unwrap();
        assert_eq!(c.used_pages(), 3, "prefix pages are shared, not copied");
        assert_eq!(c.seq_len(2), Some(21));
        for &p in &shared {
            assert_eq!(c.page_ref(p), 2);
        }

        // Gather sees the shared prefix + private suffix.
        let mut ko = vec![0.0; 2 * 1 * 3 * 24 * 4];
        let mut vo = vec![0.0; ko.len()];
        c.gather(&[Some(2)], 24, &mut ko, &mut vo).unwrap();
        // prefix token 3, layer 1, head 2 comes from seq 1's prompt
        let (l, h, t) = (1usize, 2usize, 3usize);
        let src = (l * 3 + h) * 16 * 4 + t * 4;
        let dst = ((l * 1) * 3 + h) * 24 * 4 + t * 4;
        assert_eq!(&ko[dst..dst + 4], &k[src..src + 4]);
        // suffix token 16 (= suffix row 0)
        let ssrc = (l * 3 + h) * 5 * 4;
        let sdst = ((l * 1) * 3 + h) * 24 * 4 + 16 * 4;
        assert_eq!(&ko[sdst..sdst + 4], &ks[ssrc..ssrc + 4]);

        // Freeing seq 1 keeps the shared pages alive for seq 2.
        c.free_seq(1);
        for &p in &shared {
            assert_eq!(c.page_ref(p), 1);
        }
        assert_eq!(c.used_pages(), 3);
        c.free_seq(2);
        assert_eq!(c.free_pages(), 16);
    }

    #[test]
    fn full_page_share_appends_into_fresh_pages_without_cow() {
        // The engine's steady state: a shared prefix is always whole
        // pages, so a sharer's first append lands in a new page and the
        // shared copy is never even COW'd.
        let mut c = PagedKvCache::new(1, 1, 2, 4, 4);
        let k: Vec<f32> = (0..8).map(|x| x as f32).collect();
        let v: Vec<f32> = (0..8).map(|x| 100.0 + x as f32).collect();
        c.insert_seq(1, &k, &v, 4).unwrap(); // one full page
        let page = c.seq_pages(1).unwrap()[0];
        c.insert_seq_shared(2, &[page], &[], &[], 0).unwrap();
        assert_eq!(c.page_ref(page), 2);
        let cow = c.append_token(2, &[9.0, 9.0], &[9.0, 9.0]).unwrap();
        assert!(!cow, "page-aligned append allocates, never copies");
        assert_eq!(c.seq_pages(2).unwrap()[0], page, "prefix page still shared");
        // Seq 1's view is untouched.
        let mut ko = vec![0.0; 8];
        let mut vo = vec![0.0; 8];
        c.gather(&[Some(1)], 4, &mut ko, &mut vo).unwrap();
        assert_eq!(ko, k);
    }

    #[test]
    fn copy_on_write_preserves_the_shared_copy() {
        // COW is for *partial-page* sharing — the parallel-sampling fork
        // scenario, where two branches continue from the same half-filled
        // page. Model the second holder with an explicit retain.
        let mut c = PagedKvCache::new(1, 1, 2, 4, 4);
        c.insert_seq(1, &[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0], 2)
            .unwrap(); // 2 of 4 slots used: partial page
        let page = c.seq_pages(1).unwrap()[0];
        c.retain_page(page).unwrap(); // forked holder
        assert_eq!(c.page_ref(page), 2);

        // Appending writes into the shared partial page: must clone.
        let cow = c.append_token(1, &[9.0, 9.0], &[9.0, 9.0]).unwrap();
        assert!(cow, "append into a shared page must copy");
        let new_page = c.seq_pages(1).unwrap()[0];
        assert_ne!(new_page, page);
        assert_eq!(c.page_ref(page), 1, "forked holder keeps the original");
        assert_eq!(c.page_ref(new_page), 1);

        // The sequence reads the cloned prefix plus its new token.
        let mut ko = vec![0.0; 8];
        let mut vo = vec![0.0; 8];
        c.gather(&[Some(1)], 4, &mut ko, &mut vo).unwrap();
        assert_eq!(&ko[..6], &[1.0, 2.0, 3.0, 4.0, 9.0, 9.0]);
        assert_eq!(&vo[4..6], &[9.0, 9.0]);

        // Releasing the fork's reference frees the original page.
        assert!(c.release_page(page).unwrap());
        c.free_seq(1);
        assert_eq!(c.free_pages(), 4);
    }

    #[test]
    fn double_free_is_rejected() {
        let mut c = PagedKvCache::new(1, 1, 2, 2, 2);
        c.insert_seq(1, &[1.0, 2.0], &[3.0, 4.0], 1).unwrap();
        let page = c.seq_pages(1).unwrap()[0];
        c.free_seq(1);
        assert_eq!(c.page_ref(page), 0);
        let err = c.release_page(page).unwrap_err();
        assert!(err.to_string().contains("double free"));
        assert!(c.retain_page(page).is_err(), "cannot retain a free page");
        assert_eq!(c.free_pages(), 2, "free list not corrupted");
    }
}
