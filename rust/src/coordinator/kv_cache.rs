//! Paged KV cache with block tables (the vLLM/FlashInfer storage model,
//! built as a substrate for the serving engine).
//!
//! Storage unit is a **page** of `page_tokens` tokens holding all layers
//! and **KV heads**: `[layers, h_kv, page_tokens, head_dim]` f32, one
//! buffer for K and one for V. The cache is kv-head granular end to end:
//! under GQA/MQA `heads` is the model's `n_kv_heads` (< query heads), so
//! every page, gather and byte counter shrinks by the query-head group
//! size; ungrouped models pass `n_kv_heads == n_heads` and nothing
//! changes. Sequences own ordered page lists; the engine gathers a
//! sequence's pages into the contiguous `[l, b, h_kv, ctx_bucket, dh]`
//! views the decode artifact consumes (the CPU-PJRT analogue of the
//! paper's constant-stride tensor requirement, §IV-C).
//!
//! Pages are **reference-counted** so the radix prefix index
//! ([`super::radix`]) can share one physical copy of a common prefix
//! across many sequences (cascade/shared-prefix serving). Writes go
//! through **copy-on-write**: appending into a page another holder still
//! references first clones it, so a shared prefix is immutable in place.
//! A page returns to the free list only when its last reference drops —
//! the refcount invariants (no leak, no double free, eviction only at
//! zero) are property-tested in `rust/tests/kv_cache_props.rs`.

use anyhow::{bail, ensure, Result};
use std::collections::{BTreeMap, HashMap};

use crate::obs::cache_stats::{CacheReport, HeatTracker, RadixStats, TouchKind};
use crate::sparse::{page_upper_bound, select_pages, PageMeta, SparsePolicy};

use super::request::RequestId;

/// Paged K/V storage for many sequences.
pub struct PagedKvCache {
    pub layers: usize,
    /// KV heads per layer — the grouped (GQA/MQA) plane when the model
    /// shares KV heads across query heads, the query-head count otherwise.
    pub heads: usize,
    pub head_dim: usize,
    pub page_tokens: usize,
    k_pages: Vec<Vec<f32>>,
    v_pages: Vec<Vec<f32>>,
    /// Per-page key statistics (channel-wise min/max) the sparse page
    /// selector scores against — maintained incrementally with every
    /// write, recomputed on copy-on-write clones and exclusive-page
    /// truncations so they always match a from-scratch recompute over
    /// the page's filled rows.
    meta: Vec<PageMeta>,
    /// Holders per page: sequences + the prefix index. 0 = free.
    ref_counts: Vec<u32>,
    free: Vec<usize>,
    seqs: HashMap<RequestId, SeqEntry>,
    /// Page-heat telemetry, maintained at the gather / append / select /
    /// alloc sites below (interior-mutable: gathers take `&self`).
    heat: HeatTracker,
}

struct SeqEntry {
    pages: Vec<usize>,
    len: usize,
}

impl PagedKvCache {
    /// Allocate a cache with a fixed budget of `num_pages` pages.
    pub fn new(
        layers: usize,
        heads: usize,
        head_dim: usize,
        page_tokens: usize,
        num_pages: usize,
    ) -> PagedKvCache {
        let page_elems = layers * heads * page_tokens * head_dim;
        let plane = layers * heads * head_dim;
        PagedKvCache {
            layers,
            heads,
            head_dim,
            page_tokens,
            k_pages: (0..num_pages).map(|_| vec![0.0; page_elems]).collect(),
            v_pages: (0..num_pages).map(|_| vec![0.0; page_elems]).collect(),
            meta: (0..num_pages).map(|_| PageMeta::empty(plane)).collect(),
            ref_counts: vec![0; num_pages],
            free: (0..num_pages).rev().collect(),
            seqs: HashMap::new(),
            heat: HeatTracker::enabled(num_pages),
        }
    }

    /// The page-heat telemetry state.
    pub fn heat(&self) -> &HeatTracker {
        &self.heat
    }

    /// Advance the heat tracker's logical tick clock (once per engine /
    /// churn step) — the unit page age is measured in.
    pub fn heat_tick(&self) {
        self.heat.tick();
    }

    /// Replace the heat tracker with an inert one — the bench harness's
    /// comparison baseline for the heat-overhead measurement.
    pub fn disable_heat(&mut self) {
        self.heat = HeatTracker::disabled();
    }

    /// Build the versioned cache introspection report: every aggregate is
    /// recomputed from scratch over the refcount map and heat state.
    pub fn report(&self, radix: Option<RadixStats>, top_k: usize) -> CacheReport {
        CacheReport::build(
            &self.ref_counts,
            &self.heat,
            self.page_tokens,
            self.token_bytes(),
            radix,
            top_k,
        )
    }

    /// Per-page reference count attributable to cached sequences alone —
    /// the sequence-side input to the engine's refcount-exactness audit
    /// (the engine adds one per radix-indexed page and compares against
    /// [`Self::page_ref`]).
    pub fn seq_page_refs(&self) -> Vec<u32> {
        let mut refs = vec![0u32; self.total_pages()];
        for entry in self.seqs.values() {
            for &p in &entry.pages {
                refs[p] += 1;
            }
        }
        refs
    }

    /// Free-list consistency audit: every free-list entry is unique, in
    /// range and refcount-zero, and the list covers every refcount-zero
    /// page. Test/debug surface alongside [`Self::validate_page_meta`].
    pub fn audit_free_list(&self) -> Result<()> {
        let mut seen = vec![false; self.total_pages()];
        for &p in &self.free {
            ensure!(p < self.total_pages(), "free-list page {p} out of range");
            ensure!(!seen[p], "free-list page {p} listed twice");
            ensure!(
                self.ref_counts[p] == 0,
                "free-list page {p} has refcount {}",
                self.ref_counts[p]
            );
            seen[p] = true;
        }
        let zero = self.ref_counts.iter().filter(|&&r| r == 0).count();
        ensure!(
            zero == self.free.len(),
            "{zero} pages have refcount 0 but the free list holds {}",
            self.free.len()
        );
        Ok(())
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn total_pages(&self) -> usize {
        self.k_pages.len()
    }

    pub fn used_pages(&self) -> usize {
        self.total_pages() - self.free.len()
    }

    /// K+V bytes held by one page (f32 host storage).
    pub fn page_bytes(&self) -> usize {
        2 * self.layers * self.heads * self.page_tokens * self.head_dim
            * std::mem::size_of::<f32>()
    }

    /// K+V bytes held by one cached token (f32 host storage) — the unit
    /// the gather spans and bandwidth counters report in.
    pub fn token_bytes(&self) -> usize {
        self.page_bytes() / self.page_tokens
    }

    pub fn seq_len(&self, id: RequestId) -> Option<usize> {
        self.seqs.get(&id).map(|s| s.len)
    }

    /// A sequence's in-order physical page list.
    pub fn seq_pages(&self, id: RequestId) -> Option<&[usize]> {
        self.seqs.get(&id).map(|s| s.pages.as_slice())
    }

    /// Current holder count of a page (0 = free).
    pub fn page_ref(&self, page: usize) -> u32 {
        self.ref_counts.get(page).copied().unwrap_or(0)
    }

    /// One cached token's K rows as a `[layers, heads, head_dim]` plane —
    /// the sparse selector's tail-row query proxy reads the most recent
    /// key this way before each decode step.
    pub fn token_k(&self, id: RequestId, t: usize) -> Option<Vec<f32>> {
        let entry = self.seqs.get(&id)?;
        if t >= entry.len {
            return None;
        }
        let page = entry.pages[t / self.page_tokens];
        let slot = t % self.page_tokens;
        let dh = self.head_dim;
        let mut out = vec![0.0f32; self.layers * self.heads * dh];
        for l in 0..self.layers {
            for h in 0..self.heads {
                let src = ((l * self.heads + h) * self.page_tokens + slot) * dh;
                let dst = (l * self.heads + h) * dh;
                out[dst..dst + dh].copy_from_slice(&self.k_pages[page][src..src + dh]);
            }
        }
        Some(out)
    }

    /// Pages needed to hold `tokens` tokens.
    pub fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_tokens)
    }

    /// Whether a sequence of `tokens` tokens can currently be admitted.
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.pages_for(tokens) <= self.free.len()
    }

    fn alloc_page(&mut self) -> Option<usize> {
        let p = self.free.pop()?;
        debug_assert_eq!(self.ref_counts[p], 0);
        self.ref_counts[p] = 1;
        self.meta[p].reset();
        // A reallocated page holds a new incarnation's data: its heat
        // history belongs to the old one.
        self.heat.reset_page(p);
        Some(p)
    }

    /// Key statistics of a live page.
    pub fn page_meta(&self, page: usize) -> &PageMeta {
        &self.meta[page]
    }

    /// From-scratch recompute of a page's key statistics over its first
    /// `rows` token slots — the consistency oracle the incremental
    /// maintenance is property-tested against.
    pub fn recompute_page_meta(&self, page: usize, rows: usize) -> PageMeta {
        let dh = self.head_dim;
        let mut m = PageMeta::empty(self.layers * self.heads * dh);
        for slot in 0..rows.min(self.page_tokens) {
            for l in 0..self.layers {
                for h in 0..self.heads {
                    let off = ((l * self.heads + h) * self.page_tokens + slot) * dh;
                    m.observe(
                        (l * self.heads + h) * dh,
                        &self.k_pages[page][off..off + dh],
                    );
                }
            }
            m.commit_row(slot);
        }
        m
    }

    /// Check the page-statistics invariants over the whole cache: every
    /// live page's statistics equal a from-scratch recompute over its
    /// filled rows, and every sequence's view of every page it holds is
    /// covered by those rows (so an upper-bound score derived from the
    /// statistics is sound for every reader). Test/debug surface.
    pub fn validate_page_meta(&self) -> Result<()> {
        for p in 0..self.total_pages() {
            if self.ref_counts[p] == 0 {
                continue;
            }
            let want = self.recompute_page_meta(p, self.meta[p].filled());
            ensure!(
                self.meta[p] == want,
                "page {p} statistics drifted from a from-scratch recompute \
                 over {} rows",
                self.meta[p].filled()
            );
        }
        for (id, entry) in &self.seqs {
            for (pi, &p) in entry.pages.iter().enumerate() {
                let view = entry
                    .len
                    .saturating_sub(pi * self.page_tokens)
                    .min(self.page_tokens);
                ensure!(
                    view <= self.meta[p].filled(),
                    "sequence {id} reads {view} rows of page {p} but its \
                     statistics cover only {}",
                    self.meta[p].filled()
                );
            }
        }
        Ok(())
    }

    /// Take an additional reference on a live page (prefix index or a
    /// sequence sharing a cached prefix).
    pub fn retain_page(&mut self, page: usize) -> Result<()> {
        ensure!(page < self.total_pages(), "retain of page {page} out of range");
        ensure!(self.ref_counts[page] > 0, "retain of unallocated page {page}");
        self.ref_counts[page] += 1;
        Ok(())
    }

    /// Drop one reference; the page returns to the free list only when
    /// the count reaches zero. Returns whether the page was freed.
    pub fn release_page(&mut self, page: usize) -> Result<bool> {
        ensure!(page < self.total_pages(), "release of page {page} out of range");
        ensure!(
            self.ref_counts[page] > 0,
            "double free of page {page} (refcount already 0)"
        );
        self.ref_counts[page] -= 1;
        if self.ref_counts[page] == 0 {
            self.free.push(page);
            return Ok(true);
        }
        Ok(false)
    }

    /// Register a new sequence and copy in its prefill K/V
    /// (`[layers, heads, len, head_dim]` row-major per tensor).
    pub fn insert_seq(&mut self, id: RequestId, k: &[f32], v: &[f32], len: usize) -> Result<()> {
        ensure!(!self.seqs.contains_key(&id), "sequence {id} already cached");
        let plane = self.heads * self.head_dim;
        ensure!(k.len() == self.layers * plane * len, "prefill k size");
        ensure!(v.len() == k.len(), "prefill v size");
        let need = self.pages_for(len.max(1));
        if need > self.free.len() {
            bail!("cache full: need {need} pages, {} free", self.free.len());
        }
        let pages: Vec<usize> = (0..need).map(|_| self.alloc_page().unwrap()).collect();
        let mut entry = SeqEntry { pages, len: 0 };
        let (heads, dh) = (self.heads, self.head_dim);
        for t in 0..len {
            self.write_token(&mut entry, t, |l, h| {
                let base = (l * heads + h) * len * dh + t * dh;
                (&k[base..base + dh], &v[base..base + dh])
            });
        }
        entry.len = len;
        self.seqs.insert(id, entry);
        Ok(())
    }

    /// Register a new sequence whose first `shared.len() * page_tokens`
    /// tokens live in already-cached (prefix index) pages. The sequence
    /// takes one reference per shared page; only the suffix K/V
    /// (`[layers, heads, suffix_len, head_dim]`, the tokens *after* the
    /// shared prefix) is written into freshly allocated pages.
    pub fn insert_seq_shared(
        &mut self,
        id: RequestId,
        shared: &[usize],
        k_suffix: &[f32],
        v_suffix: &[f32],
        suffix_len: usize,
    ) -> Result<()> {
        ensure!(!self.seqs.contains_key(&id), "sequence {id} already cached");
        let plane = self.heads * self.head_dim;
        ensure!(k_suffix.len() == self.layers * plane * suffix_len, "suffix k size");
        ensure!(v_suffix.len() == k_suffix.len(), "suffix v size");
        for &p in shared {
            ensure!(p < self.total_pages(), "shared page {p} out of range");
            ensure!(self.ref_counts[p] > 0, "shared page {p} is not live");
        }
        let shared_tokens = shared.len() * self.page_tokens;
        let total = shared_tokens + suffix_len;
        ensure!(total >= 1, "empty sequence");
        let need = self.pages_for(total.max(1)) - shared.len();
        if need > self.free.len() {
            bail!("cache full: need {need} pages, {} free", self.free.len());
        }

        for &p in shared {
            self.ref_counts[p] += 1;
        }
        let mut pages = shared.to_vec();
        pages.extend((0..need).map(|_| self.alloc_page().unwrap()));
        let mut entry = SeqEntry { pages, len: 0 };
        let (heads, dh) = (self.heads, self.head_dim);
        for s in 0..suffix_len {
            // Absolute position: suffix token `s` lands after the shared
            // prefix, which is page-aligned by construction.
            self.write_token(&mut entry, shared_tokens + s, |l, h| {
                let base = (l * heads + h) * suffix_len * dh + s * dh;
                (&k_suffix[base..base + dh], &v_suffix[base..base + dh])
            });
        }
        entry.len = total;
        self.seqs.insert(id, entry);
        Ok(())
    }

    /// Fork a live sequence: register `child` with the same page list
    /// and length as `parent`, taking one reference per page. **No page
    /// is copied** — the fork is pure refcount bookkeeping, and the
    /// shared partial last page (if any) is cloned lazily by
    /// copy-on-write on each holder's next [`Self::append_token`]. This
    /// is the storage half of parallel sampling (best-of-n, beam
    /// search): `n` siblings of a `t`-token parent cost zero bytes at
    /// fork time and at most one page clone each as they diverge.
    pub fn fork_seq(&mut self, parent: RequestId, child: RequestId) -> Result<()> {
        ensure!(
            !self.seqs.contains_key(&child),
            "fork target sequence {child} already cached"
        );
        let entry = self
            .seqs
            .get(&parent)
            .ok_or_else(|| anyhow::anyhow!("fork source sequence {parent} not cached"))?;
        let pages = entry.pages.clone();
        let len = entry.len;
        for &p in &pages {
            // Parent pages are live by construction.
            self.ref_counts[p] += 1;
        }
        self.seqs.insert(child, SeqEntry { pages, len });
        Ok(())
    }

    /// Append one token's K/V rows (`[layers, heads, head_dim]` each).
    /// Returns whether a copy-on-write page clone happened (the target
    /// page was shared with another holder).
    pub fn append_token(&mut self, id: RequestId, k: &[f32], v: &[f32]) -> Result<bool> {
        let plane = self.layers * self.heads * self.head_dim;
        ensure!(k.len() == plane, "append k size");
        ensure!(v.len() == plane, "append v size");
        let mut entry = self.seqs.remove(&id).ok_or_else(|| {
            anyhow::anyhow!("sequence {id} not cached")
        })?;
        let t = entry.len;
        let mut cow = false;
        if t >= entry.pages.len() * self.page_tokens {
            let Some(p) = self.alloc_page() else {
                self.seqs.insert(id, entry);
                bail!("cache full appending to sequence {id}");
            };
            entry.pages.push(p);
        } else {
            // Writing into an existing page: if anyone else holds it,
            // clone first so the shared copy stays immutable.
            let pi = t / self.page_tokens;
            let page = entry.pages[pi];
            let kept = t % self.page_tokens;
            if self.ref_counts[page] > 1 {
                let Some(fresh) = self.alloc_page() else {
                    self.seqs.insert(id, entry);
                    bail!("cache full (copy-on-write) appending to sequence {id}");
                };
                copy_page(&mut self.k_pages, page, fresh);
                copy_page(&mut self.v_pages, page, fresh);
                self.heat.record_cow();
                // The clone's statistics cover exactly the rows this
                // holder's view keeps — rows past `kept` are another
                // holder's (or rolled-back) data about to be overwritten.
                self.meta[fresh] = self.recompute_page_meta(fresh, kept);
                self.ref_counts[page] -= 1; // still >= 1: not freed
                entry.pages[pi] = fresh;
                cow = true;
            } else if self.meta[page].filled() != kept {
                // Exclusive page whose statistics still cover rows a
                // truncation dropped while the page was shared (the
                // shrink was skipped to keep the then-sibling's bounds
                // sound): repair before the overwrite lands.
                self.meta[page] = self.recompute_page_meta(page, kept);
            }
        }
        let (heads, dh) = (self.heads, self.head_dim);
        self.write_token(&mut entry, t, |l, h| {
            let base = (l * heads + h) * dh;
            (&k[base..base + dh], &v[base..base + dh])
        });
        entry.len = t + 1;
        self.seqs.insert(id, entry);
        Ok(cow)
    }

    fn write_token<'a>(
        &mut self,
        entry: &mut SeqEntry,
        t: usize,
        src: impl Fn(usize, usize) -> (&'a [f32], &'a [f32]),
    ) {
        let page = entry.pages[t / self.page_tokens];
        let slot = t % self.page_tokens;
        let dh = self.head_dim;
        for l in 0..self.layers {
            for h in 0..self.heads {
                let off = ((l * self.heads + h) * self.page_tokens + slot) * dh;
                let (ks, vs) = src(l, h);
                self.k_pages[page][off..off + dh].copy_from_slice(ks);
                self.v_pages[page][off..off + dh].copy_from_slice(vs);
                // Fold the fresh K row into the page's running min/max.
                self.meta[page].observe((l * self.heads + h) * dh, ks);
            }
        }
        self.meta[page].commit_row(slot);
        self.heat.touch(TouchKind::Append, page);
    }

    /// Gather a batch of sequences into contiguous decode-artifact views
    /// `[layers, batch, heads, ctx_bucket, head_dim]` (zero-padded).
    /// `slots[i] = Some(request)` maps batch lane `i` to a sequence.
    pub fn gather(
        &self,
        slots: &[Option<RequestId>],
        ctx_bucket: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) -> Result<()> {
        let b = slots.len();
        let dh = self.head_dim;
        let expect = self.layers * b * self.heads * ctx_bucket * dh;
        ensure!(k_out.len() == expect, "k_out size");
        ensure!(v_out.len() == expect, "v_out size");
        k_out.fill(0.0);
        v_out.fill(0.0);
        for (bi, slot) in slots.iter().enumerate() {
            let Some(id) = slot else { continue };
            let entry = self
                .seqs
                .get(id)
                .ok_or_else(|| anyhow::anyhow!("sequence {id} not cached"))?;
            ensure!(entry.len <= ctx_bucket, "sequence longer than ctx bucket");
            // One gather touch per (lane, page) actually materialized —
            // the same unit the deduplicated paths count per run entry.
            for (pi, &page) in entry.pages.iter().enumerate() {
                if pi * self.page_tokens >= entry.len {
                    break;
                }
                self.heat.touch(TouchKind::Gather, page);
            }
            for l in 0..self.layers {
                for h in 0..self.heads {
                    let dst_base =
                        (((l * b) + bi) * self.heads + h) * ctx_bucket * dh;
                    // copy page by page
                    for (pi, &page) in entry.pages.iter().enumerate() {
                        let t0 = pi * self.page_tokens;
                        if t0 >= entry.len {
                            break;
                        }
                        let count = self.page_tokens.min(entry.len - t0);
                        let src_base =
                            ((l * self.heads + h) * self.page_tokens) * dh;
                        let dst = dst_base + t0 * dh;
                        k_out[dst..dst + count * dh].copy_from_slice(
                            &self.k_pages[page][src_base..src_base + count * dh],
                        );
                        v_out[dst..dst + count * dh].copy_from_slice(
                            &self.v_pages[page][src_base..src_base + count * dh],
                        );
                    }
                }
            }
        }
        Ok(())
    }

    /// Deduplicated gather for the cascade execution path: each physical
    /// page run is materialized **once**, so a prefix shared by several
    /// batch lanes costs one copy instead of one per lane. Sharing is
    /// detected from the page lists themselves — lanes whose lists begin
    /// with the same physical page share exactly their longest common
    /// leading full-page run (sharing is always a leading run:
    /// [`Self::insert_seq_shared`] prepends the shared pages, and
    /// copy-on-write only ever diverges the tail).
    pub fn gather_shared(&self, slots: &[Option<RequestId>]) -> Result<SharedGather> {
        let token_bytes = self.page_bytes() / self.page_tokens;
        let mut lanes: Vec<(usize, &SeqEntry)> = Vec::new();
        for (bi, slot) in slots.iter().enumerate() {
            if let Some(id) = slot {
                let entry = self
                    .seqs
                    .get(id)
                    .ok_or_else(|| anyhow::anyhow!("sequence {id} not cached"))?;
                lanes.push((bi, entry));
            }
        }

        // Group lanes by their first physical page (BTreeMap: the segment
        // order is deterministic). Physical pages are shared only through
        // explicit prefix sharing, so equal first pages mean a real group.
        let mut by_first: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (i, (_, entry)) in lanes.iter().enumerate() {
            if let Some(&p0) = entry.pages.first() {
                by_first.entry(p0).or_default().push(i);
            }
        }

        let mut segments = Vec::new();
        let mut flat_bytes = 0usize;
        for idxs in by_first.values() {
            for &i in idxs {
                flat_bytes += lanes[i].1.len * token_bytes;
            }
            // Longest common leading page run, clamped to full pages of
            // the shortest member.
            let mut shared_pages = if idxs.len() >= 2 {
                let head = &lanes[idxs[0]].1.pages;
                let mut common = head.len();
                for &i in &idxs[1..] {
                    common = head
                        .iter()
                        .zip(&lanes[i].1.pages)
                        .take(common)
                        .take_while(|(a, b)| a == b)
                        .count();
                }
                common
            } else {
                0
            };
            let min_len = idxs.iter().map(|&i| lanes[i].1.len).min().unwrap_or(0);
            shared_pages = shared_pages.min(min_len / self.page_tokens);

            if shared_pages > 0 {
                let run = &lanes[idxs[0]].1.pages[..shared_pages];
                let tokens = shared_pages * self.page_tokens;
                let (k, v) = self.materialize_run(run, tokens);
                segments.push(SharedSegment {
                    lanes: idxs.iter().map(|&i| lanes[i].0).collect(),
                    start: 0,
                    tokens,
                    k,
                    v,
                });
            }
            // Per-lane remainder (the whole context for unshared lanes).
            let skip = shared_pages * self.page_tokens;
            for &i in idxs {
                let (lane, entry) = (lanes[i].0, lanes[i].1);
                if entry.len <= skip {
                    continue;
                }
                let tokens = entry.len - skip;
                let (k, v) = self.materialize_run(&entry.pages[shared_pages..], tokens);
                segments.push(SharedSegment { lanes: vec![lane], start: skip, tokens, k, v });
            }
        }

        let shared_bytes = segments.iter().map(|s| s.tokens * token_bytes).sum();
        Ok(SharedGather {
            segments,
            batch: slots.len(),
            layers: self.layers,
            heads: self.heads,
            head_dim: self.head_dim,
            flat_bytes,
            shared_bytes,
        })
    }

    /// Score one live sequence's pages against its tail-key query proxy
    /// and select under `policy` — THE selection: the engine's decode
    /// loop, the bench harness and the property tests all call this one
    /// implementation, so what is measured is what serves. Returns the
    /// ascending selected ordinals plus the page scores when scoring
    /// actually ran (`None` on the dense bypass and on budgets covering
    /// the context, where the selection is complete by construction).
    /// `None` overall when the sequence is unknown.
    pub fn select_seq_pages(
        &self,
        id: RequestId,
        policy: &SparsePolicy,
    ) -> Option<(Vec<usize>, Option<Vec<f32>>)> {
        let len = self.seq_len(id)?;
        if len == 0 {
            return Some((Vec::new(), None));
        }
        let pages = self.seq_pages(id)?;
        // Pages actually holding tokens (a rolled-back sequence can
        // briefly own one empty page more than its length needs).
        let used = pages.len().min(len.div_ceil(self.page_tokens));
        if policy.bypasses(used) || policy.budget_pages >= used {
            for &p in &pages[..used] {
                self.heat.touch(TouchKind::Select, p);
            }
            return Some(((0..used).collect(), None));
        }
        // Query proxy: the most recent cached K row. The true decode
        // query is a per-layer artifact intermediate unavailable before
        // the step runs; the tail key row is the causal stand-in
        // (scores are exact upper bounds against *it*, and selection is
        // exact-by-construction at covering budgets).
        let q = self.token_k(id, len - 1)?;
        let scores: Vec<f32> = pages[..used]
            .iter()
            .map(|&p| page_upper_bound(&q, &self.meta[p]))
            .collect();
        let sel = select_pages(policy, &scores);
        for &o in &sel {
            self.heat.touch(TouchKind::Select, pages[o]);
        }
        Some((sel, Some(scores)))
    }

    /// Sparse gather: materialize only each lane's **selected** pages,
    /// packed contiguously in context order. `selections[i]` lists
    /// strictly ascending page ordinals (indices into lane `i`'s page
    /// list) for `slots[i]`; a lane selecting every page reproduces the
    /// dense [`Self::gather_shared`] views bit-for-bit (property-tested
    /// in `rust/tests/sparse_props.rs`). A leading full-page run that
    /// every member of a first-page group selects — the retained sink
    /// pages of a shared prefix — is still materialized once per group.
    /// The result's `flat_bytes` counts the **dense** traffic (every
    /// lane's full context), so `shared_bytes / flat_bytes` measures the
    /// sparse byte saving directly.
    pub fn gather_selected(
        &self,
        slots: &[Option<RequestId>],
        selections: &[Vec<usize>],
    ) -> Result<SharedGather> {
        ensure!(selections.len() == slots.len(), "one selection per slot");
        let token_bytes = self.page_bytes() / self.page_tokens;
        // Per live lane: (slot index, [(ordinal, physical, tokens)]).
        let mut lanes: Vec<(usize, Vec<(usize, usize, usize)>)> = Vec::new();
        let mut flat_bytes = 0usize;
        for (bi, slot) in slots.iter().enumerate() {
            let Some(id) = slot else { continue };
            let entry = self
                .seqs
                .get(id)
                .ok_or_else(|| anyhow::anyhow!("sequence {id} not cached"))?;
            flat_bytes += entry.len * token_bytes;
            let selection = &selections[bi];
            ensure!(
                selection.windows(2).all(|w| w[0] < w[1]),
                "selection for lane {bi} must be strictly ascending"
            );
            let mut sel = Vec::with_capacity(selection.len());
            for &o in selection {
                ensure!(
                    o < entry.pages.len(),
                    "lane {bi}: selected ordinal {o} out of range"
                );
                let tokens = self
                    .page_tokens
                    .min(entry.len.saturating_sub(o * self.page_tokens));
                ensure!(tokens > 0, "lane {bi}: selected ordinal {o} holds no tokens");
                sel.push((o, entry.pages[o], tokens));
            }
            lanes.push((bi, sel));
        }

        // Group lanes by first selected physical page, as in
        // [`Self::gather_shared`]: equal first pages mean real sharing.
        let mut by_first: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (i, (_, sel)) in lanes.iter().enumerate() {
            if let Some(&(_, p0, _)) = sel.first() {
                by_first.entry(p0).or_default().push(i);
            }
        }

        let mut segments = Vec::new();
        for idxs in by_first.values() {
            // Longest common leading run of selected (ordinal, physical)
            // pairs, clamped to pages every member streams in full — the
            // compacted offsets of a shared run must agree across lanes.
            let mut common = if idxs.len() >= 2 {
                let head = &lanes[idxs[0]].1;
                let mut c = head.len();
                for &i in &idxs[1..] {
                    c = head
                        .iter()
                        .zip(&lanes[i].1)
                        .take(c)
                        .take_while(|(a, b)| a.0 == b.0 && a.1 == b.1)
                        .count();
                }
                c
            } else {
                0
            };
            for &i in idxs {
                let full = lanes[i]
                    .1
                    .iter()
                    .take_while(|s| s.2 == self.page_tokens)
                    .count();
                common = common.min(full);
            }

            if common > 0 {
                let runs: Vec<(usize, usize)> = lanes[idxs[0]].1[..common]
                    .iter()
                    .map(|&(_, p, t)| (p, t))
                    .collect();
                let tokens = common * self.page_tokens;
                let (k, v) = self.materialize_pages(&runs, tokens);
                segments.push(SharedSegment {
                    lanes: idxs.iter().map(|&i| lanes[i].0).collect(),
                    start: 0,
                    tokens,
                    k,
                    v,
                });
            }
            for &i in idxs {
                let (lane, sel) = (lanes[i].0, &lanes[i].1);
                if sel.len() <= common {
                    continue;
                }
                let runs: Vec<(usize, usize)> =
                    sel[common..].iter().map(|&(_, p, t)| (p, t)).collect();
                let tokens: usize = runs.iter().map(|r| r.1).sum();
                let (k, v) = self.materialize_pages(&runs, tokens);
                segments.push(SharedSegment {
                    lanes: vec![lane],
                    start: common * self.page_tokens,
                    tokens,
                    k,
                    v,
                });
            }
        }

        let shared_bytes = segments.iter().map(|s| s.tokens * token_bytes).sum();
        Ok(SharedGather {
            segments,
            batch: slots.len(),
            layers: self.layers,
            heads: self.heads,
            head_dim: self.head_dim,
            flat_bytes,
            shared_bytes,
        })
    }

    /// Copy an ordered run of `(page, tokens)` spans — not necessarily
    /// contiguous in context space — into fresh packed
    /// `[layers, heads, total, head_dim]` K/V buffers.
    fn materialize_pages(
        &self,
        runs: &[(usize, usize)],
        total: usize,
    ) -> (Vec<f32>, Vec<f32>) {
        let dh = self.head_dim;
        let mut k = vec![0.0f32; self.layers * self.heads * total * dh];
        let mut v = vec![0.0f32; k.len()];
        let mut t0 = 0usize;
        for &(page, count) in runs {
            self.heat.touch(TouchKind::Gather, page);
            for l in 0..self.layers {
                for h in 0..self.heads {
                    let src = ((l * self.heads + h) * self.page_tokens) * dh;
                    let dst = ((l * self.heads + h) * total + t0) * dh;
                    k[dst..dst + count * dh]
                        .copy_from_slice(&self.k_pages[page][src..src + count * dh]);
                    v[dst..dst + count * dh]
                        .copy_from_slice(&self.v_pages[page][src..src + count * dh]);
                }
            }
            t0 += count;
        }
        debug_assert_eq!(t0, total);
        (k, v)
    }

    /// Copy `tokens` tokens spanning `pages` (first token at the first
    /// page's first slot) into a fresh `[layers, heads, tokens, head_dim]`
    /// pair of K/V buffers — the contiguous special case of
    /// [`Self::materialize_pages`].
    fn materialize_run(&self, pages: &[usize], tokens: usize) -> (Vec<f32>, Vec<f32>) {
        let runs: Vec<(usize, usize)> = pages
            .iter()
            .enumerate()
            .take_while(|(pi, _)| pi * self.page_tokens < tokens)
            .map(|(pi, &p)| (p, self.page_tokens.min(tokens - pi * self.page_tokens)))
            .collect();
        self.materialize_pages(&runs, tokens)
    }

    /// Truncate a live sequence to `new_len` tokens — the speculative-
    /// decoding rollback: draft-block K/V rows the verifier rejected are
    /// dropped without copying or mutating anything. Whole pages past
    /// the new length give up this sequence's reference (a page shared
    /// with a fork sibling or the prefix index survives for its other
    /// holders); rows past `new_len` inside the kept tail page become
    /// invisible (every reader bounds itself by `len`) and are
    /// overwritten by future appends, which copy-on-write the tail page
    /// first if it is still shared — so a sibling's view is never
    /// touched, property-tested in `rust/tests/kv_cache_props.rs`.
    /// Returns the number of page references released.
    pub fn truncate_seq(&mut self, id: RequestId, new_len: usize) -> Result<usize> {
        let keep = new_len.div_ceil(self.page_tokens);
        let dropped = {
            let entry = self
                .seqs
                .get_mut(&id)
                .ok_or_else(|| anyhow::anyhow!("sequence {id} not cached"))?;
            ensure!(
                new_len <= entry.len,
                "truncate of sequence {id} to {new_len} exceeds its length {}",
                entry.len
            );
            entry.len = new_len;
            entry.pages.split_off(keep)
        };
        let released = dropped.len();
        for p in dropped {
            // A sequence's pages are live by construction.
            self.release_page(p)?;
        }
        // Shrink the kept tail page's statistics to the surviving rows
        // when this sequence is its only holder. A still-shared tail
        // keeps its wider bounds — the sibling reads those rows, and
        // this sequence's next append copy-on-writes (or lazily repairs
        // an exclusive page) before overwriting anything.
        let tail_rows = new_len % self.page_tokens;
        if tail_rows != 0 {
            let tail = *self
                .seqs
                .get(&id)
                .expect("sequence checked above")
                .pages
                .last()
                .expect("a partial tail implies at least one kept page");
            if self.ref_counts[tail] == 1 && self.meta[tail].filled() > tail_rows {
                self.meta[tail] = self.recompute_page_meta(tail, tail_rows);
            }
        }
        Ok(released)
    }

    /// Release a sequence's references; pages with no other holder (e.g.
    /// the prefix index) return to the free list.
    pub fn free_seq(&mut self, id: RequestId) {
        if let Some(entry) = self.seqs.remove(&id) {
            for page in entry.pages {
                // A sequence's pages are live by construction.
                let _ = self.release_page(page);
            }
        }
    }
}

/// One contiguous token run of a decode batch, materialized once by
/// [`PagedKvCache::gather_shared`]. Shared-prefix runs list several lanes;
/// exclusive runs list one.
pub struct SharedSegment {
    /// Batch lanes (indices into the `slots` slice passed to
    /// [`PagedKvCache::gather_shared`]) whose context contains this run.
    pub lanes: Vec<usize>,
    /// Token offset of the run within each lane's context (identical for
    /// all lanes: sharing is always a leading run).
    pub start: usize,
    /// Tokens in the run.
    pub tokens: usize,
    /// `[layers, heads, tokens, head_dim]` row-major K rows.
    pub k: Vec<f32>,
    /// Same layout, V rows.
    pub v: Vec<f32>,
}

/// A deduplicated gather: every physical page run appears in exactly one
/// [`SharedSegment`], so a shared prefix is materialized once per group
/// instead of once per member lane. `shared_bytes / flat_bytes` is the
/// measured KV-gather traffic ratio of the cascade path vs the flat path.
pub struct SharedGather {
    pub segments: Vec<SharedSegment>,
    /// Lanes the gather spans (`slots.len()`).
    pub batch: usize,
    layers: usize,
    heads: usize,
    head_dim: usize,
    /// K+V bytes a flat [`PagedKvCache::gather`] materializes for the same
    /// slots (every lane's full context, shared or not).
    pub flat_bytes: usize,
    /// K+V bytes this gather materialized (each run once).
    pub shared_bytes: usize,
}

impl SharedGather {
    /// Lanes that read at least one multi-lane (shared) segment.
    pub fn shared_lane_count(&self) -> usize {
        self.segments
            .iter()
            .filter(|s| s.lanes.len() >= 2)
            .map(|s| s.lanes.len())
            .sum()
    }

    /// Scatter the materialized runs into the dense decode views
    /// `[layers, batch, heads, ctx_bucket, head_dim]` (zero-padded) —
    /// equivalent to [`PagedKvCache::gather`] over the same slots.
    pub fn compose_dense(
        &self,
        ctx_bucket: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) -> Result<()> {
        let (ln, b, hn, dh) = (self.layers, self.batch, self.heads, self.head_dim);
        let expect = ln * b * hn * ctx_bucket * dh;
        ensure!(k_out.len() == expect, "k_out size");
        ensure!(v_out.len() == expect, "v_out size");
        k_out.fill(0.0);
        v_out.fill(0.0);
        for seg in &self.segments {
            ensure!(
                seg.start + seg.tokens <= ctx_bucket,
                "segment beyond ctx bucket"
            );
            for &lane in &seg.lanes {
                ensure!(lane < b, "lane {lane} out of range");
                for l in 0..ln {
                    for h in 0..hn {
                        let src = ((l * hn + h) * seg.tokens) * dh;
                        let dst = ((((l * b) + lane) * hn + h) * ctx_bucket + seg.start) * dh;
                        k_out[dst..dst + seg.tokens * dh]
                            .copy_from_slice(&seg.k[src..src + seg.tokens * dh]);
                        v_out[dst..dst + seg.tokens * dh]
                            .copy_from_slice(&seg.v[src..src + seg.tokens * dh]);
                    }
                }
            }
        }
        Ok(())
    }
}

/// Copy one page buffer over another without a temporary allocation
/// (split borrows around the larger index; `src != dst` by construction —
/// the destination comes off the free list while the source is live).
fn copy_page(pages: &mut [Vec<f32>], src: usize, dst: usize) {
    debug_assert_ne!(src, dst);
    if src < dst {
        let (lo, hi) = pages.split_at_mut(dst);
        hi[0].copy_from_slice(&lo[src]);
    } else {
        let (lo, hi) = pages.split_at_mut(src);
        lo[dst].copy_from_slice(&hi[0]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cache() -> PagedKvCache {
        PagedKvCache::new(2, 3, 4, 8, 16)
    }

    fn rows(rng: &mut Rng, layers: usize, heads: usize, len: usize, dh: usize) -> Vec<f32> {
        rng.normal_vec(layers * heads * len * dh)
    }

    #[test]
    fn insert_gather_round_trip() {
        let mut c = cache();
        let mut rng = Rng::new(1);
        let len = 13; // crosses a page boundary (page=8)
        let k = rows(&mut rng, 2, 3, len, 4);
        let v = rows(&mut rng, 2, 3, len, 4);
        c.insert_seq(7, &k, &v, len).unwrap();
        assert_eq!(c.seq_len(7), Some(13));
        assert_eq!(c.free_pages(), 16 - 2);

        let ctx = 16;
        let mut ko = vec![0.0; 2 * 1 * 3 * ctx * 4];
        let mut vo = vec![0.0; ko.len()];
        c.gather(&[Some(7)], ctx, &mut ko, &mut vo).unwrap();
        // spot-check token t=9, layer 1, head 2
        let (l, h, t) = (1usize, 2usize, 9usize);
        let src = (l * 3 + h) * len * 4 + t * 4;
        let dst = ((l * 1) * 3 + h) * ctx * 4 + t * 4;
        assert_eq!(&ko[dst..dst + 4], &k[src..src + 4]);
        assert_eq!(&vo[dst..dst + 4], &v[src..src + 4]);
        // padding is zero
        let pad = ((0 * 1) * 3 + 0) * ctx * 4 + 15 * 4;
        assert_eq!(&ko[pad..pad + 4], &[0.0; 4]);
    }

    #[test]
    fn append_token_and_page_growth() {
        let mut c = cache();
        let mut rng = Rng::new(2);
        let k = rows(&mut rng, 2, 3, 8, 4);
        let v = rows(&mut rng, 2, 3, 8, 4);
        c.insert_seq(1, &k, &v, 8).unwrap(); // exactly one page
        assert_eq!(c.free_pages(), 15);
        let nk = rng.normal_vec(2 * 3 * 4);
        let nv = rng.normal_vec(2 * 3 * 4);
        let cow = c.append_token(1, &nk, &nv).unwrap(); // forces a second page
        assert!(!cow, "fresh page, no copy-on-write");
        assert_eq!(c.free_pages(), 14);
        assert_eq!(c.seq_len(1), Some(9));

        let mut ko = vec![0.0; 2 * 1 * 3 * 16 * 4];
        let mut vo = vec![0.0; ko.len()];
        c.gather(&[Some(1)], 16, &mut ko, &mut vo).unwrap();
        // token 8 row for layer 0 head 1
        let dst = ((0 * 1) * 3 + 1) * 16 * 4 + 8 * 4;
        assert_eq!(&ko[dst..dst + 4], &nk[4..8]);
    }

    #[test]
    fn free_seq_returns_pages() {
        let mut c = cache();
        let mut rng = Rng::new(3);
        let k = rows(&mut rng, 2, 3, 20, 4);
        let v = rows(&mut rng, 2, 3, 20, 4);
        c.insert_seq(5, &k, &v, 20).unwrap();
        let used = 16 - c.free_pages();
        assert_eq!(used, 3); // ceil(20/8)
        c.free_seq(5);
        assert_eq!(c.free_pages(), 16);
        assert_eq!(c.seq_len(5), None);
    }

    #[test]
    fn admission_control() {
        let mut c = cache();
        assert!(c.can_admit(16 * 8));
        assert!(!c.can_admit(16 * 8 + 1));
        let mut rng = Rng::new(4);
        let k = rows(&mut rng, 2, 3, 100, 4);
        let v = rows(&mut rng, 2, 3, 100, 4);
        c.insert_seq(1, &k, &v, 100).unwrap(); // 13 pages
        assert!(!c.can_admit(8 * 4)); // only 3 pages left
        let err = c.insert_seq(2, &k, &v, 100).unwrap_err();
        assert!(err.to_string().contains("cache full"));
    }

    #[test]
    fn cache_full_append_is_recoverable() {
        let mut c = PagedKvCache::new(1, 1, 2, 2, 1);
        c.insert_seq(1, &[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0], 2)
            .unwrap();
        let err = c.append_token(1, &[9.0, 9.0], &[9.0, 9.0]).unwrap_err();
        assert!(err.to_string().contains("cache full"));
        // sequence still intact
        assert_eq!(c.seq_len(1), Some(2));
    }

    #[test]
    fn gather_multi_batch_lanes() {
        let mut c = cache();
        let mut rng = Rng::new(5);
        for id in 0..3u64 {
            let len = 4 + id as usize;
            let k = rows(&mut rng, 2, 3, len, 4);
            let v = rows(&mut rng, 2, 3, len, 4);
            c.insert_seq(id, &k, &v, len).unwrap();
        }
        let mut ko = vec![0.0; 2 * 4 * 3 * 8 * 4];
        let mut vo = vec![0.0; ko.len()];
        c.gather(&[Some(2), None, Some(0), Some(1)], 8, &mut ko, &mut vo)
            .unwrap();
        // lane 1 is empty -> zeros
        let lane1 = ((0 * 4 + 1) * 3) * 8 * 4;
        assert!(ko[lane1..lane1 + 8 * 4].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn shared_prefix_dedups_pages() {
        let mut c = cache();
        let mut rng = Rng::new(6);
        // Seq 1 owns a 16-token (2-page) prompt.
        let k = rows(&mut rng, 2, 3, 16, 4);
        let v = rows(&mut rng, 2, 3, 16, 4);
        c.insert_seq(1, &k, &v, 16).unwrap();
        let shared: Vec<usize> = c.seq_pages(1).unwrap().to_vec();
        assert_eq!(c.used_pages(), 2);

        // Seq 2 shares both pages and adds a 5-token suffix (1 new page).
        let ks = rows(&mut rng, 2, 3, 5, 4);
        let vs = rows(&mut rng, 2, 3, 5, 4);
        c.insert_seq_shared(2, &shared, &ks, &vs, 5).unwrap();
        assert_eq!(c.used_pages(), 3, "prefix pages are shared, not copied");
        assert_eq!(c.seq_len(2), Some(21));
        for &p in &shared {
            assert_eq!(c.page_ref(p), 2);
        }

        // Gather sees the shared prefix + private suffix.
        let mut ko = vec![0.0; 2 * 1 * 3 * 24 * 4];
        let mut vo = vec![0.0; ko.len()];
        c.gather(&[Some(2)], 24, &mut ko, &mut vo).unwrap();
        // prefix token 3, layer 1, head 2 comes from seq 1's prompt
        let (l, h, t) = (1usize, 2usize, 3usize);
        let src = (l * 3 + h) * 16 * 4 + t * 4;
        let dst = ((l * 1) * 3 + h) * 24 * 4 + t * 4;
        assert_eq!(&ko[dst..dst + 4], &k[src..src + 4]);
        // suffix token 16 (= suffix row 0)
        let ssrc = (l * 3 + h) * 5 * 4;
        let sdst = ((l * 1) * 3 + h) * 24 * 4 + 16 * 4;
        assert_eq!(&ko[sdst..sdst + 4], &ks[ssrc..ssrc + 4]);

        // Freeing seq 1 keeps the shared pages alive for seq 2.
        c.free_seq(1);
        for &p in &shared {
            assert_eq!(c.page_ref(p), 1);
        }
        assert_eq!(c.used_pages(), 3);
        c.free_seq(2);
        assert_eq!(c.free_pages(), 16);
    }

    #[test]
    fn full_page_share_appends_into_fresh_pages_without_cow() {
        // The engine's steady state: a shared prefix is always whole
        // pages, so a sharer's first append lands in a new page and the
        // shared copy is never even COW'd.
        let mut c = PagedKvCache::new(1, 1, 2, 4, 4);
        let k: Vec<f32> = (0..8).map(|x| x as f32).collect();
        let v: Vec<f32> = (0..8).map(|x| 100.0 + x as f32).collect();
        c.insert_seq(1, &k, &v, 4).unwrap(); // one full page
        let page = c.seq_pages(1).unwrap()[0];
        c.insert_seq_shared(2, &[page], &[], &[], 0).unwrap();
        assert_eq!(c.page_ref(page), 2);
        let cow = c.append_token(2, &[9.0, 9.0], &[9.0, 9.0]).unwrap();
        assert!(!cow, "page-aligned append allocates, never copies");
        assert_eq!(c.seq_pages(2).unwrap()[0], page, "prefix page still shared");
        // Seq 1's view is untouched.
        let mut ko = vec![0.0; 8];
        let mut vo = vec![0.0; 8];
        c.gather(&[Some(1)], 4, &mut ko, &mut vo).unwrap();
        assert_eq!(ko, k);
    }

    #[test]
    fn copy_on_write_preserves_the_shared_copy() {
        // COW is for *partial-page* sharing — the parallel-sampling fork
        // scenario, where two branches continue from the same half-filled
        // page. Model the second holder with an explicit retain.
        let mut c = PagedKvCache::new(1, 1, 2, 4, 4);
        c.insert_seq(1, &[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0], 2)
            .unwrap(); // 2 of 4 slots used: partial page
        let page = c.seq_pages(1).unwrap()[0];
        c.retain_page(page).unwrap(); // forked holder
        assert_eq!(c.page_ref(page), 2);

        // Appending writes into the shared partial page: must clone.
        let cow = c.append_token(1, &[9.0, 9.0], &[9.0, 9.0]).unwrap();
        assert!(cow, "append into a shared page must copy");
        let new_page = c.seq_pages(1).unwrap()[0];
        assert_ne!(new_page, page);
        assert_eq!(c.page_ref(page), 1, "forked holder keeps the original");
        assert_eq!(c.page_ref(new_page), 1);

        // The sequence reads the cloned prefix plus its new token.
        let mut ko = vec![0.0; 8];
        let mut vo = vec![0.0; 8];
        c.gather(&[Some(1)], 4, &mut ko, &mut vo).unwrap();
        assert_eq!(&ko[..6], &[1.0, 2.0, 3.0, 4.0, 9.0, 9.0]);
        assert_eq!(&vo[4..6], &[9.0, 9.0]);

        // Releasing the fork's reference frees the original page.
        assert!(c.release_page(page).unwrap());
        c.free_seq(1);
        assert_eq!(c.free_pages(), 4);
    }

    /// gather and gather_shared+compose_dense must agree bit-for-bit.
    fn assert_gather_equivalent(c: &PagedKvCache, slots: &[Option<RequestId>], ctx: usize) {
        let n = c.layers * slots.len() * c.heads * ctx * c.head_dim;
        let (mut kf, mut vf) = (vec![0.0; n], vec![0.0; n]);
        c.gather(slots, ctx, &mut kf, &mut vf).unwrap();
        let sg = c.gather_shared(slots).unwrap();
        let (mut ks, mut vs) = (vec![1.0; n], vec![1.0; n]); // poison: fill must clear
        sg.compose_dense(ctx, &mut ks, &mut vs).unwrap();
        assert_eq!(kf, ks, "k views differ");
        assert_eq!(vf, vs, "v views differ");
    }

    #[test]
    fn gather_shared_dedups_interleaved_shared_and_exclusive_pages() {
        let mut c = cache(); // 2 layers, 3 heads, dh 4, page 8
        let mut rng = Rng::new(21);
        // Seqs 1 and 2 share a 2-page (16-token) prefix; 2 adds a 5-token
        // suffix. Seq 3 is solo. Lane order interleaves solo between the
        // sharers.
        let k = rows(&mut rng, 2, 3, 16, 4);
        let v = rows(&mut rng, 2, 3, 16, 4);
        c.insert_seq(1, &k, &v, 16).unwrap();
        let shared: Vec<usize> = c.seq_pages(1).unwrap().to_vec();
        let ks = rows(&mut rng, 2, 3, 5, 4);
        let vs = rows(&mut rng, 2, 3, 5, 4);
        c.insert_seq_shared(2, &shared, &ks, &vs, 5).unwrap();
        let k3 = rows(&mut rng, 2, 3, 10, 4);
        let v3 = rows(&mut rng, 2, 3, 10, 4);
        c.insert_seq(3, &k3, &v3, 10).unwrap();

        let slots = [Some(1), Some(3), Some(2)];
        let sg = c.gather_shared(&slots).unwrap();
        // One shared run (lanes 0 and 2, 16 tokens), seq 2's suffix, and
        // the solo lane — seq 1 has no remainder beyond the shared run.
        assert_eq!(sg.segments.len(), 3);
        let shared_seg = sg
            .segments
            .iter()
            .find(|s| s.lanes.len() == 2)
            .expect("shared segment");
        assert_eq!(shared_seg.lanes, vec![0, 2]);
        assert_eq!((shared_seg.start, shared_seg.tokens), (0, 16));
        assert!(sg
            .segments
            .iter()
            .any(|s| s.lanes == vec![2] && s.start == 16 && s.tokens == 5));
        assert!(sg
            .segments
            .iter()
            .any(|s| s.lanes == vec![1] && s.start == 0 && s.tokens == 10));
        assert_eq!(sg.shared_lane_count(), 2);
        // Flat materializes 16+21+10 tokens; shared 16+5+10.
        let token_bytes = c.page_bytes() / c.page_tokens;
        assert_eq!(sg.flat_bytes, 47 * token_bytes);
        assert_eq!(sg.shared_bytes, 31 * token_bytes);
        assert_gather_equivalent(&c, &slots, 24);
        // Empty lanes stay zero through either path.
        assert_gather_equivalent(&c, &[Some(2), None, Some(1)], 24);
    }

    #[test]
    fn gather_shared_forked_suffixes_share_only_the_common_run() {
        // Two sequences share one page then diverge (the COW-fork shape):
        // only the common leading run may be deduplicated.
        let mut c = PagedKvCache::new(1, 1, 2, 4, 8);
        let mut rng = Rng::new(22);
        let k = rows(&mut rng, 1, 1, 4, 2);
        let v = rows(&mut rng, 1, 1, 4, 2);
        c.insert_seq(1, &k, &v, 4).unwrap(); // one full page
        let page = c.seq_pages(1).unwrap()[0];
        let (ka, va) = (rows(&mut rng, 1, 1, 3, 2), rows(&mut rng, 1, 1, 3, 2));
        c.insert_seq_shared(2, &[page], &ka, &va, 3).unwrap();
        // Seq 1 grows its own divergent suffix.
        for _ in 0..2 {
            let (nk, nv) = (rng.normal_vec(2), rng.normal_vec(2));
            c.append_token(1, &nk, &nv).unwrap();
        }
        assert_eq!(c.seq_len(1), Some(6));
        assert_eq!(c.seq_len(2), Some(7));

        let slots = [Some(1), Some(2)];
        let sg = c.gather_shared(&slots).unwrap();
        let shared_seg = sg
            .segments
            .iter()
            .find(|s| s.lanes.len() == 2)
            .expect("shared segment");
        assert_eq!((shared_seg.start, shared_seg.tokens), (0, 4));
        // Both forks keep private suffixes starting at the fork point.
        assert!(sg.segments.iter().any(|s| s.lanes == vec![0] && s.start == 4 && s.tokens == 2));
        assert!(sg.segments.iter().any(|s| s.lanes == vec![1] && s.start == 4 && s.tokens == 3));
        assert_gather_equivalent(&c, &slots, 8);
    }

    #[test]
    fn gather_shared_without_sharing_matches_flat_bytes() {
        let mut c = cache();
        let mut rng = Rng::new(23);
        for id in 0..3u64 {
            let len = 5 + 3 * id as usize;
            let k = rows(&mut rng, 2, 3, len, 4);
            let v = rows(&mut rng, 2, 3, len, 4);
            c.insert_seq(id, &k, &v, len).unwrap();
        }
        let slots = [Some(0), Some(1), Some(2)];
        let sg = c.gather_shared(&slots).unwrap();
        assert_eq!(sg.shared_bytes, sg.flat_bytes, "no sharing, no dedup");
        assert_eq!(sg.shared_lane_count(), 0);
        assert_gather_equivalent(&c, &slots, 16);
    }

    #[test]
    fn fork_seq_is_refcount_only_zero_page_copies() {
        // The acceptance invariant of `Engine::fork`: forking allocates
        // nothing — n siblings of a live sequence cost zero pages at
        // fork time, only refcounts move.
        let mut c = cache(); // page_tokens 8
        let mut rng = Rng::new(31);
        let len = 13; // 2 pages, the second partial
        let k = rows(&mut rng, 2, 3, len, 4);
        let v = rows(&mut rng, 2, 3, len, 4);
        c.insert_seq(1, &k, &v, len).unwrap();
        let free_before = c.free_pages();
        let pages: Vec<usize> = c.seq_pages(1).unwrap().to_vec();

        for child in 2..=4u64 {
            c.fork_seq(1, child).unwrap();
        }
        assert_eq!(c.free_pages(), free_before, "fork must allocate zero pages");
        for &p in &pages {
            assert_eq!(c.page_ref(p), 4, "parent + 3 forks hold every page");
        }
        for child in 2..=4u64 {
            assert_eq!(c.seq_len(child), Some(len));
            assert_eq!(c.seq_pages(child).unwrap(), pages.as_slice());
        }

        // Every fork reads the identical bytes as the parent.
        let ctx = 16;
        let n = 2 * 2 * 3 * ctx * 4;
        let (mut ko, mut vo) = (vec![0.0; n], vec![0.0; n]);
        c.gather(&[Some(1), Some(3)], ctx, &mut ko, &mut vo).unwrap();
        // Lanes interleave per layer; spot-check layer 0's two lanes.
        let lane = 3 * ctx * 4;
        assert_eq!(&ko[..lane], &ko[lane..2 * lane], "fork view == parent view");

        // Freeing forks returns only refcounts; the last holder frees.
        for child in 2..=4u64 {
            c.free_seq(child);
        }
        assert_eq!(c.free_pages(), free_before);
        c.free_seq(1);
        assert_eq!(c.free_pages(), 16);
    }

    #[test]
    fn forked_partial_page_cows_once_per_sibling() {
        // Fork with a partial last page: every holder's first divergent
        // append clones that page exactly once — except the last holder,
        // which by then owns the only reference and writes in place. So
        // `siblings` holders yield `siblings - 1` COW copies.
        let mut c = PagedKvCache::new(1, 1, 2, 4, 12);
        let mut rng = Rng::new(32);
        let len = 6; // page 0 full, page 1 half-full
        let k = rows(&mut rng, 1, 1, len, 2);
        let v = rows(&mut rng, 1, 1, len, 2);
        c.insert_seq(0, &k, &v, len).unwrap();
        for child in 1..4u64 {
            c.fork_seq(0, child).unwrap();
        }
        let mut cows = 0;
        for id in 0..4u64 {
            let (nk, nv) = (rng.normal_vec(2), rng.normal_vec(2));
            if c.append_token(id, &nk, &nv).unwrap() {
                cows += 1;
            }
        }
        assert_eq!(cows, 3, "4 holders of a partial page -> 3 COW clones");
        // Divergent tails: every sequence kept its own token 6 while the
        // shared 6-token history stayed identical.
        let full_page0: Vec<usize> =
            (0..4u64).map(|id| c.seq_pages(id).unwrap()[0]).collect();
        assert!(full_page0.windows(2).all(|w| w[0] == w[1]), "full page still shared");
        for id in 0..4u64 {
            c.free_seq(id);
        }
        assert_eq!(c.free_pages(), 12);
    }

    #[test]
    fn forked_page_aligned_history_never_cows() {
        // Fork exactly at a page boundary: appends go into fresh pages,
        // the shared history is immutable, zero COW copies.
        let mut c = PagedKvCache::new(1, 1, 2, 4, 12);
        let mut rng = Rng::new(33);
        let len = 8; // exactly 2 full pages
        let k = rows(&mut rng, 1, 1, len, 2);
        let v = rows(&mut rng, 1, 1, len, 2);
        c.insert_seq(0, &k, &v, len).unwrap();
        for child in 1..3u64 {
            c.fork_seq(0, child).unwrap();
        }
        for id in 0..3u64 {
            for _ in 0..3 {
                let (nk, nv) = (rng.normal_vec(2), rng.normal_vec(2));
                assert!(
                    !c.append_token(id, &nk, &nv).unwrap(),
                    "page-aligned fork must never copy"
                );
            }
        }
        for id in 0..3u64 {
            c.free_seq(id);
        }
        assert_eq!(c.free_pages(), 12);
    }

    #[test]
    fn fork_of_unknown_or_duplicate_sequence_is_rejected() {
        let mut c = PagedKvCache::new(1, 1, 2, 2, 2);
        assert!(c.fork_seq(9, 10).is_err(), "unknown parent");
        c.insert_seq(1, &[1.0, 2.0], &[3.0, 4.0], 1).unwrap();
        c.fork_seq(1, 2).unwrap();
        assert!(c.fork_seq(1, 2).is_err(), "duplicate child id");
        // Failed forks must not corrupt refcounts.
        let p = c.seq_pages(1).unwrap()[0];
        assert_eq!(c.page_ref(p), 2);
    }

    #[test]
    fn truncate_releases_whole_pages_and_keeps_the_tail() {
        let mut c = PagedKvCache::new(1, 1, 2, 4, 8);
        let mut rng = Rng::new(41);
        let len = 11; // 3 pages of 4
        let k = rows(&mut rng, 1, 1, len, 2);
        let v = rows(&mut rng, 1, 1, len, 2);
        c.insert_seq(1, &k, &v, len).unwrap();
        assert_eq!(c.used_pages(), 3);

        // Roll back 5 tokens (the spec-decode rejected-draft shape):
        // page 2 empties and returns; page 1 keeps tokens 4..6.
        let released = c.truncate_seq(1, 6).unwrap();
        assert_eq!(released, 1);
        assert_eq!(c.seq_len(1), Some(6));
        assert_eq!(c.used_pages(), 2);

        // The surviving prefix reads back bit-identically.
        let mut ko = vec![0.0; 8 * 2];
        let mut vo = vec![0.0; ko.len()];
        c.gather(&[Some(1)], 8, &mut ko, &mut vo).unwrap();
        assert_eq!(&ko[..6 * 2], &k[..6 * 2]);
        assert!(ko[6 * 2..].iter().all(|&x| x == 0.0), "stale rows invisible");

        // Appending after the rollback reuses the tail page slot.
        let (nk, nv) = (rng.normal_vec(2), rng.normal_vec(2));
        assert!(!c.append_token(1, &nk, &nv).unwrap());
        assert_eq!(c.seq_len(1), Some(7));
        c.gather(&[Some(1)], 8, &mut ko, &mut vo).unwrap();
        assert_eq!(&ko[6 * 2..7 * 2], &nk[..2]);

        c.free_seq(1);
        assert_eq!(c.free_pages(), 8);
    }

    #[test]
    fn truncate_of_shared_pages_releases_refs_not_pages() {
        let mut c = PagedKvCache::new(1, 1, 2, 4, 8);
        let mut rng = Rng::new(42);
        let len = 8; // 2 full pages
        let k = rows(&mut rng, 1, 1, len, 2);
        let v = rows(&mut rng, 1, 1, len, 2);
        c.insert_seq(1, &k, &v, len).unwrap();
        c.fork_seq(1, 2).unwrap();
        let pages: Vec<usize> = c.seq_pages(1).unwrap().to_vec();

        // The fork rolls back its whole second page: the page survives
        // for the parent, only the fork's reference drops.
        assert_eq!(c.truncate_seq(2, 4).unwrap(), 1);
        assert_eq!(c.page_ref(pages[1]), 1, "parent still holds page 1");
        assert_eq!(c.seq_len(1), Some(8));
        assert_eq!(c.seq_len(2), Some(4));
        let mut ko = vec![0.0; 8 * 2];
        let mut vo = vec![0.0; ko.len()];
        c.gather(&[Some(1)], 8, &mut ko, &mut vo).unwrap();
        assert_eq!(&ko[..], &k[..], "parent view untouched by the fork's rollback");

        c.free_seq(1);
        c.free_seq(2);
        assert_eq!(c.free_pages(), 8);
    }

    #[test]
    fn truncate_into_a_shared_partial_page_cows_on_the_next_append() {
        // Fork mid-page, roll the parent back inside the shared partial
        // page, then append: the write must copy-on-write, never mutate
        // the sibling's bytes.
        let mut c = PagedKvCache::new(1, 1, 2, 4, 8);
        let mut rng = Rng::new(43);
        let len = 6; // page 0 full, page 1 half-full
        let k = rows(&mut rng, 1, 1, len, 2);
        let v = rows(&mut rng, 1, 1, len, 2);
        c.insert_seq(1, &k, &v, len).unwrap();
        c.fork_seq(1, 2).unwrap();
        let tail = c.seq_pages(1).unwrap()[1];

        assert_eq!(c.truncate_seq(1, 5).unwrap(), 0, "partial page is kept");
        assert_eq!(c.page_ref(tail), 2, "both holders keep the tail page");
        let (nk, nv) = (rng.normal_vec(2), rng.normal_vec(2));
        assert!(c.append_token(1, &nk, &nv).unwrap(), "shared tail must COW");

        // The sibling still reads the original token 5.
        let mut ko = vec![0.0; 8 * 2];
        let mut vo = vec![0.0; ko.len()];
        c.gather(&[Some(2)], 8, &mut ko, &mut vo).unwrap();
        assert_eq!(&ko[..6 * 2], &k[..6 * 2], "sibling view survives the rollback");
        // The parent reads its replacement.
        c.gather(&[Some(1)], 8, &mut ko, &mut vo).unwrap();
        assert_eq!(&ko[5 * 2..6 * 2], &nk[..2]);

        c.free_seq(1);
        c.free_seq(2);
        assert_eq!(c.free_pages(), 8);
    }

    #[test]
    fn truncate_rejects_growth_and_unknown_sequences() {
        let mut c = PagedKvCache::new(1, 1, 2, 4, 4);
        assert!(c.truncate_seq(9, 0).is_err(), "unknown sequence");
        c.insert_seq(1, &[1.0, 2.0], &[3.0, 4.0], 1).unwrap();
        let err = c.truncate_seq(1, 2).unwrap_err();
        assert!(err.to_string().contains("exceeds"), "{err}");
        // Truncating to the current length is a no-op.
        assert_eq!(c.truncate_seq(1, 1).unwrap(), 0);
        assert_eq!(c.seq_len(1), Some(1));
        // Truncating to zero releases everything but keeps the entry.
        assert_eq!(c.truncate_seq(1, 0).unwrap(), 1);
        assert_eq!(c.seq_len(1), Some(0));
        assert_eq!(c.free_pages(), 4);
        let (nk, nv) = ([5.0f32, 6.0], [7.0f32, 8.0]);
        assert!(!c.append_token(1, &nk, &nv).unwrap());
        assert_eq!(c.seq_len(1), Some(1));
        c.free_seq(1);
        assert_eq!(c.free_pages(), 4);
    }

    #[test]
    fn double_free_is_rejected() {
        let mut c = PagedKvCache::new(1, 1, 2, 2, 2);
        c.insert_seq(1, &[1.0, 2.0], &[3.0, 4.0], 1).unwrap();
        let page = c.seq_pages(1).unwrap()[0];
        c.free_seq(1);
        assert_eq!(c.page_ref(page), 0);
        let err = c.release_page(page).unwrap_err();
        assert!(err.to_string().contains("double free"));
        assert!(c.retain_page(page).is_err(), "cannot retain a free page");
        assert_eq!(c.free_pages(), 2, "free list not corrupted");
    }

    #[test]
    fn token_k_reads_one_cached_key_plane() {
        let mut c = cache(); // 2 layers, 3 heads, dh 4, page 8
        let mut rng = Rng::new(51);
        let len = 11;
        let k = rows(&mut rng, 2, 3, len, 4);
        let v = rows(&mut rng, 2, 3, len, 4);
        c.insert_seq(1, &k, &v, len).unwrap();
        let t = 9; // second page
        let plane = c.token_k(1, t).unwrap();
        for l in 0..2 {
            for h in 0..3 {
                let src = (l * 3 + h) * len * 4 + t * 4;
                let dst = (l * 3 + h) * 4;
                assert_eq!(&plane[dst..dst + 4], &k[src..src + 4]);
            }
        }
        assert!(c.token_k(1, len).is_none(), "past the end");
        assert!(c.token_k(9, 0).is_none(), "unknown sequence");
    }

    #[test]
    fn page_meta_tracks_inserts_appends_and_cow() {
        let mut c = PagedKvCache::new(1, 1, 2, 4, 6);
        let mut rng = Rng::new(52);
        let len = 6; // page 0 full, page 1 half
        let k = rows(&mut rng, 1, 1, len, 2);
        let v = rows(&mut rng, 1, 1, len, 2);
        c.insert_seq(1, &k, &v, len).unwrap();
        c.validate_page_meta().unwrap();
        let pages: Vec<usize> = c.seq_pages(1).unwrap().to_vec();
        assert_eq!(c.page_meta(pages[0]).filled(), 4);
        assert_eq!(c.page_meta(pages[1]).filled(), 2);
        // Bounds match the written rows exactly.
        let m0 = c.page_meta(pages[0]);
        let lo = k[..4 * 2].chunks(2).map(|r| r[0]).fold(f32::INFINITY, f32::min);
        assert_eq!(m0.k_min()[0], lo);

        // A shared partial tail: the COW clone's statistics cover exactly
        // the cloning holder's view, the original is untouched.
        c.fork_seq(1, 2).unwrap();
        let cow = c
            .append_token(1, &rng.normal_vec(2), &rng.normal_vec(2))
            .unwrap();
        assert!(cow);
        c.validate_page_meta().unwrap();
        let new_tail = *c.seq_pages(1).unwrap().last().unwrap();
        assert_ne!(new_tail, pages[1]);
        assert_eq!(c.page_meta(new_tail).filled(), 3);
        assert_eq!(c.page_meta(pages[1]).filled(), 2, "sibling's stats intact");

        c.free_seq(1);
        c.free_seq(2);
        assert_eq!(c.free_pages(), 6);
    }

    #[test]
    fn page_meta_shrinks_on_truncate_and_repairs_lazily() {
        let mut c = PagedKvCache::new(1, 1, 2, 4, 6);
        let mut rng = Rng::new(53);
        let len = 6;
        let k = rows(&mut rng, 1, 1, len, 2);
        let v = rows(&mut rng, 1, 1, len, 2);
        c.insert_seq(1, &k, &v, len).unwrap();
        let tail = *c.seq_pages(1).unwrap().last().unwrap();

        // Exclusive truncate shrinks the tail statistics immediately.
        c.truncate_seq(1, 5).unwrap();
        assert_eq!(c.page_meta(tail).filled(), 1);
        c.validate_page_meta().unwrap();

        // Shared truncate cannot shrink (the sibling still reads the
        // rows); the next append repairs before overwriting.
        c.append_token(1, &rng.normal_vec(2), &rng.normal_vec(2)).unwrap();
        c.fork_seq(1, 2).unwrap();
        c.truncate_seq(1, 5).unwrap();
        assert_eq!(c.page_meta(tail).filled(), 2, "shared stats stay wide");
        c.validate_page_meta().unwrap();
        c.free_seq(2); // tail becomes exclusive again, stats still wide
        assert!(
            c.append_token(1, &rng.normal_vec(2), &rng.normal_vec(2)).is_ok()
        );
        assert_eq!(c.page_meta(tail).filled(), 2, "repair happened at slot 1");
        c.validate_page_meta().unwrap();

        c.free_seq(1);
        assert_eq!(c.free_pages(), 6);
    }

    #[test]
    fn gather_selected_full_selection_matches_dense_gather() {
        let mut c = cache();
        let mut rng = Rng::new(54);
        let k = rows(&mut rng, 2, 3, 16, 4);
        let v = rows(&mut rng, 2, 3, 16, 4);
        c.insert_seq(1, &k, &v, 16).unwrap();
        let shared: Vec<usize> = c.seq_pages(1).unwrap().to_vec();
        let ks = rows(&mut rng, 2, 3, 5, 4);
        let vs = rows(&mut rng, 2, 3, 5, 4);
        c.insert_seq_shared(2, &shared, &ks, &vs, 5).unwrap();

        let slots = [Some(1), Some(2)];
        let full: Vec<Vec<usize>> = vec![vec![0, 1], vec![0, 1, 2]];
        let ctx = 24;
        let n = 2 * 2 * 3 * ctx * 4;
        let (mut kf, mut vf) = (vec![0.0; n], vec![0.0; n]);
        c.gather(&slots, ctx, &mut kf, &mut vf).unwrap();
        let sg = c.gather_selected(&slots, &full).unwrap();
        let (mut ks2, mut vs2) = (vec![1.0; n], vec![1.0; n]);
        sg.compose_dense(ctx, &mut ks2, &mut vs2).unwrap();
        assert_eq!(kf, ks2, "full selection must reproduce the dense view");
        assert_eq!(vf, vs2);
        // The shared 2-page prefix still dedups: one 16-token segment.
        assert!(sg.segments.iter().any(|s| s.lanes.len() == 2 && s.tokens == 16));
        let sg_dense = c.gather_shared(&slots).unwrap();
        assert_eq!(sg.flat_bytes, sg_dense.flat_bytes);
        assert_eq!(sg.shared_bytes, sg_dense.shared_bytes);
    }

    #[test]
    fn gather_selected_prunes_middle_pages_and_packs_the_rest() {
        let mut c = PagedKvCache::new(1, 1, 2, 4, 8);
        let mut rng = Rng::new(55);
        let len = 12; // 3 full pages
        let k = rows(&mut rng, 1, 1, len, 2);
        let v = rows(&mut rng, 1, 1, len, 2);
        c.insert_seq(1, &k, &v, len).unwrap();

        let sg = c.gather_selected(&[Some(1)], &[vec![0, 2]]).unwrap();
        assert!(sg.shared_bytes < sg.flat_bytes, "pruning must shed bytes");
        let token_bytes = c.page_bytes() / c.page_tokens;
        assert_eq!(sg.flat_bytes, 12 * token_bytes);
        assert_eq!(sg.shared_bytes, 8 * token_bytes);

        let ctx = 8;
        let n = ctx * 2;
        let (mut ko, mut vo) = (vec![1.0; n], vec![1.0; n]);
        sg.compose_dense(ctx, &mut ko, &mut vo).unwrap();
        // Packed view: tokens 0..4 then 8..12, back to back.
        assert_eq!(&ko[..4 * 2], &k[..4 * 2]);
        assert_eq!(&ko[4 * 2..8 * 2], &k[8 * 2..12 * 2]);

        // Selections must be ascending, in range, and non-empty per page.
        assert!(c.gather_selected(&[Some(1)], &[vec![2, 0]]).is_err());
        assert!(c.gather_selected(&[Some(1)], &[vec![3]]).is_err());
        c.free_seq(1);
    }

    #[test]
    fn gather_selected_shares_the_selected_sink_run_across_lanes() {
        let mut c = PagedKvCache::new(1, 1, 2, 4, 12);
        let mut rng = Rng::new(56);
        let k = rows(&mut rng, 1, 1, 8, 2);
        let v = rows(&mut rng, 1, 1, 8, 2);
        c.insert_seq(1, &k, &v, 8).unwrap(); // 2 full pages
        let shared: Vec<usize> = c.seq_pages(1).unwrap().to_vec();
        let ks = rows(&mut rng, 1, 1, 8, 2);
        let vs = rows(&mut rng, 1, 1, 8, 2);
        c.insert_seq_shared(2, &shared, &ks, &vs, 8).unwrap(); // 4 pages

        // Both lanes keep the sink page 0 and their own tail; lane 2 also
        // keeps ordinal 2. The common selected run is the sink page only.
        let sels = vec![vec![0, 1], vec![0, 2, 3]];
        let sg = c.gather_selected(&[Some(1), Some(2)], &sels).unwrap();
        let sink = sg
            .segments
            .iter()
            .find(|s| s.lanes.len() == 2)
            .expect("shared sink segment");
        assert_eq!((sink.start, sink.tokens), (0, 4));
        let token_bytes = c.page_bytes() / c.page_tokens;
        // 4 (sink, once) + 4 (lane 1 tail) + 8 (lane 2 ordinals 2,3).
        assert_eq!(sg.shared_bytes, 16 * token_bytes);
        c.free_seq(1);
        c.free_seq(2);
    }

    #[test]
    fn heat_tracks_every_data_plane_site() {
        let mut c = PagedKvCache::new(1, 1, 2, 4, 6);
        let mut rng = Rng::new(61);
        let len = 6; // page 0 full, page 1 half-full
        let k = rows(&mut rng, 1, 1, len, 2);
        let v = rows(&mut rng, 1, 1, len, 2);
        c.insert_seq(1, &k, &v, len).unwrap();
        let pages: Vec<usize> = c.seq_pages(1).unwrap().to_vec();
        // Insert lands one append touch per token written.
        assert_eq!(c.heat().append_hits(pages[0]), 4);
        assert_eq!(c.heat().append_hits(pages[1]), 2);
        assert_eq!(c.heat().append_total(), 6);

        // Flat gather: one touch per (lane, page) materialized.
        let mut ko = vec![0.0; 8 * 2];
        let mut vo = vec![0.0; ko.len()];
        c.gather(&[Some(1)], 8, &mut ko, &mut vo).unwrap();
        assert_eq!(c.heat().gather_hits(pages[0]), 1);
        assert_eq!(c.heat().gather_hits(pages[1]), 1);
        // Deduplicated gather: one touch per run entry.
        c.gather_shared(&[Some(1)]).unwrap();
        assert_eq!(c.heat().gather_hits(pages[0]), 2);
        assert_eq!(c.heat().gather_total(), 4);

        // COW clone: counted, and the fresh page starts cold.
        c.fork_seq(1, 2).unwrap();
        assert!(c
            .append_token(1, &rng.normal_vec(2), &rng.normal_vec(2))
            .unwrap());
        assert_eq!(c.heat().cow_clones(), 1);
        let fresh = *c.seq_pages(1).unwrap().last().unwrap();
        assert_ne!(fresh, pages[1]);
        assert_eq!(
            c.heat().append_hits(fresh),
            1,
            "reset on alloc, then exactly the new token's append"
        );

        // The live-cache report validates and matches the tracker totals.
        c.heat_tick();
        let rep = c.report(None, 4);
        assert_eq!(rep.heat.clock, 1);
        assert_eq!(rep.heat.append_touches_total, c.heat().append_total());
        assert_eq!(rep.sharing.cow_clones_total, 1);
        crate::obs::validate_cache_report(&rep.to_json()).unwrap();
        c.audit_free_list().unwrap();

        // Sequence-side refcounts: page 0 held by both holders, the old
        // tail by the fork only, the fresh tail by seq 1 only.
        let refs = c.seq_page_refs();
        assert_eq!(refs[pages[0]], 2);
        assert_eq!(refs[pages[1]], 1);
        assert_eq!(refs[fresh], 1);
        for p in 0..c.total_pages() {
            assert_eq!(refs[p], c.page_ref(p), "no radix holder in this test");
        }
        c.free_seq(1);
        c.free_seq(2);
    }

    #[test]
    fn heat_select_touches_and_disable() {
        let mut c = PagedKvCache::new(1, 1, 2, 4, 8);
        let mut rng = Rng::new(62);
        let len = 16; // 4 full pages
        let k = rows(&mut rng, 1, 1, len, 2);
        let v = rows(&mut rng, 1, 1, len, 2);
        c.insert_seq(1, &k, &v, len).unwrap();
        let pages: Vec<usize> = c.seq_pages(1).unwrap().to_vec();

        // Budget 3 < 4 used pages: scoring runs, 3 pages selected.
        let policy = SparsePolicy::with_budget(3);
        let (sel, scores) = c.select_seq_pages(1, &policy).unwrap();
        assert_eq!(sel.len(), 3);
        assert!(scores.is_some());
        let selected: u64 = pages.iter().map(|&p| c.heat().select_hits(p)).sum();
        assert_eq!(selected, 3);
        assert_eq!(c.heat().select_total(), 3);

        // A covering budget bypasses scoring but still counts selection.
        let (all, none) = c.select_seq_pages(1, &SparsePolicy::with_budget(4)).unwrap();
        assert_eq!(all, vec![0, 1, 2, 3]);
        assert!(none.is_none());
        assert_eq!(c.heat().select_total(), 7);

        // Disabling swaps in the inert tracker: no further recording.
        c.disable_heat();
        assert!(!c.heat().is_enabled());
        c.select_seq_pages(1, &policy).unwrap();
        let mut ko = vec![0.0; 16 * 2];
        let mut vo = vec![0.0; ko.len()];
        c.gather(&[Some(1)], 16, &mut ko, &mut vo).unwrap();
        assert_eq!(c.heat().select_total(), 0);
        assert_eq!(c.heat().gather_total(), 0);
        c.free_seq(1);
    }
}
